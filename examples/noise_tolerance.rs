//! Empirical per-layer-class noise tolerance (validates Fig. 1(A)/Fig. 4
//! and the netstats models): sweep the per-conversion read-noise σ for
//! one layer class at a time through the real AOT ViT artifact and
//! measure accuracy. The ratio of tolerable σ between attention and MLP
//! *is* the paper's "attention needs ~10 dB less CSNR" claim, measured
//! end-to-end instead of modeled.
//!
//! Run: `make artifacts && cargo run --release --example noise_tolerance`

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use cr_cim::runtime::{Manifest, Runtime, VitExecutable};
use cr_cim::util::json::Json;
use cr_cim::workload::EvalSet;

fn accuracy(exe: &VitExecutable, eval: &EvalSet, count: usize, sa: f32, sm: f32) -> Result<f64> {
    let w = eval.image_floats();
    let count = count.min(eval.n);
    let mut correct = 0usize;
    let mut done = 0usize;
    while done < count {
        let b = exe.batch.min(count - done);
        let mut flat = vec![0f32; exe.batch * w];
        for i in 0..b {
            flat[i * w..(i + 1) * w].copy_from_slice(eval.image_slice(done + i));
        }
        let logits = exe.infer(&flat, (done + 7919) as i32, sa, sm)?;
        let preds = exe.predict(&logits);
        for i in 0..b {
            if preds[i] == eval.labels[done + i] as usize {
                correct += 1;
            }
        }
        done += b;
    }
    Ok(correct as f64 / count as f64)
}

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let dir = PathBuf::from(&artifacts);
    let manifest = Manifest::load(&dir).map_err(|e| anyhow!(e))?;
    let eval = EvalSet::load(&dir).map_err(|e| anyhow!(e))?;
    let rt = Runtime::cpu()?;
    let exe = VitExecutable::new(
        &rt,
        manifest.get("vit_cim_b16").ok_or_else(|| anyhow!("no artifact"))?,
    )?;
    let count: usize = std::env::var("CRCIM_EVAL_COUNT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);

    let baseline = accuracy(&exe, &eval, count, 0.0, 0.0)?;
    println!("zero-noise (PTQ-only) accuracy: {:.1}%  ({count} images)", baseline * 100.0);
    println!("\n{:<10} {:>16} {:>16}", "σ [LSB]", "attn-only noisy", "MLP-only noisy");

    // Sweep one class at a time. The grid is geometric: the interesting
    // question is "how many dB apart are the two tolerance cliffs".
    let sigmas = [0.25f64, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];
    let mut att_acc = Vec::new();
    let mut mlp_acc = Vec::new();
    for &s in &sigmas {
        let a = accuracy(&exe, &eval, count, s as f32, 0.0)?;
        let m = accuracy(&exe, &eval, count, 0.0, s as f32)?;
        att_acc.push(a);
        mlp_acc.push(m);
        println!("{s:<10} {:>15.1}% {:>15.1}%", a * 100.0, m * 100.0);
    }

    // Tolerable sigma: largest sweep point within 2 pt of baseline.
    let tolerable = |accs: &[f64]| -> f64 {
        let mut best = sigmas[0] / 2.0;
        for (i, &a) in accs.iter().enumerate() {
            if a >= baseline - 0.02 {
                best = sigmas[i];
            }
        }
        best
    };
    let t_att = tolerable(&att_acc);
    let t_mlp = tolerable(&mlp_acc);
    let gap_db = 20.0 * (t_att / t_mlp).log10();
    println!("\ntolerable σ (≤2 pt drop): attention {t_att} LSB, MLP {t_mlp} LSB ({gap_db:.1} dB apart)");
    println!(
        "note: equal per-conversion σ gives roughly equal *layer* SNR by\n\
         construction (the noise bridge normalizes the shift-add factors),\n\
         so on this axis the classes cliff together — the paper's 10 dB\n\
         class asymmetry is exercised through the bit-width dimension\n\
         (attention stays accurate at 4b where MLP needs 6b; see\n\
         vit_inference's all-4b corner) and the netstats models (fig4 bench)."
    );

    let mut report = Json::obj();
    report.set("sigmas", Json::arr_f64(&sigmas));
    report.set("attention_accuracy", Json::arr_f64(&att_acc));
    report.set("mlp_accuracy", Json::arr_f64(&mlp_acc));
    report.set("gap_db", Json::num(gap_db));
    std::fs::create_dir_all("target").ok();
    std::fs::write("target/noise_tolerance.json", Json::Obj(report).to_string_pretty())?;
    println!("report written to target/noise_tolerance.json");
    Ok(())
}
