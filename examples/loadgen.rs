//! Load generator for the event-driven connection tier: sustained mixed
//! classify/forward/stream/generate traffic over real TCP through the
//! reactor, at a swept series of offered loads. Prints one table row per point
//! (offered vs achieved rate, p50/p99 latency, shed rate) and finishes
//! with a `stats` probe and a graceful-drain shutdown, so a run doubles
//! as an end-to-end smoke of admission, backpressure, per-token push and
//! drain semantics.
//!
//! The executor is a deterministic stand-in (no PJRT, no artifacts):
//! this example measures the *serving tier* — reactor wakeups, admission
//! permits, wave formation — not model math. Saturation numbers anchored
//! to the silicon model live in the hotpath bench's saturation curve
//! (`target/bench-reports/BENCH_pipeline.json`).
//!
//! Usage:
//!   cargo run --release --example loadgen            # full sweep
//!   cargo run --release --example loadgen -- --smoke # CI-sized run

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use cr_cim::cim::params::MacroParams;
use cr_cim::coordinator::decode::GenStep;
use cr_cim::coordinator::sac::{evaluate_plan, PlanCost};
use cr_cim::coordinator::scheduler::Scheduler;
use cr_cim::coordinator::server::{
    BatchExecutor, Server, ServerConfig, SHED_DRAINING, SHED_INFLIGHT, SHED_QUEUE_FULL,
};
use cr_cim::util::json;
use cr_cim::vit::plan::PrecisionPlan;
use cr_cim::vit::VitConfig;

/// Deterministic executor: logits[c] = mean(image) + c, for both the
/// fixed-batch and the streaming (forward) paths.
struct LoadExec {
    cost: PlanCost,
}

impl LoadExec {
    fn new() -> Self {
        let sched = Scheduler::new(&MacroParams::default());
        LoadExec {
            cost: evaluate_plan(&sched, &VitConfig::default(), 1, &PrecisionPlan::paper_sac()),
        }
    }

    fn logits(images: &[Vec<f32>]) -> Vec<Vec<f32>> {
        images
            .iter()
            .map(|img| {
                let m: f32 = img.iter().sum::<f32>() / img.len().max(1) as f32;
                (0..10).map(|c| m + c as f32).collect()
            })
            .collect()
    }
}

impl BatchExecutor for LoadExec {
    fn execute(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(Self::logits(images))
    }
    fn forward(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(Self::logits(images))
    }
    fn decode_many(&mut self, waves: &[Vec<GenStep>]) -> Vec<Result<Vec<Vec<f32>>, String>> {
        // Deterministic per-step logits keyed on (token, position), so
        // the generate path exercises wave coalescing and per-token push
        // without model math.
        waves
            .iter()
            .map(|w| {
                Ok(w.iter()
                    .map(|s| {
                        let m =
                            ((s.tok as u64 * 7 + s.pos as u64) % 13) as f32 / 13.0 - 0.5;
                        (0..10).map(|c| m + c as f32).collect()
                    })
                    .collect())
            })
            .collect()
    }
    fn cost(&self) -> &PlanCost {
        &self.cost
    }
    fn num_classes(&self) -> usize {
        10
    }
}

/// One request line of the mixed workload: round-robin
/// classify / forward / stream / generate, with a fraction of the
/// stream and generate requests opting into per-token push events.
fn request_line(id: u64) -> String {
    let px: Vec<String> =
        (0..16).map(|j| format!("{:.3}", ((id * 7 + j) % 13) as f64 / 13.0 - 0.5)).collect();
    let image = format!("[{}]", px.join(", "));
    match id % 4 {
        0 => format!("{{\"id\": {id}, \"kind\": \"classify\", \"image\": {image}}}"),
        1 => format!("{{\"id\": {id}, \"kind\": \"forward\", \"image\": {image}}}"),
        2 => {
            let push = if id % 8 == 2 { ", \"push\": true" } else { "" };
            let kind = "\"kind\": \"stream\", \"tokens\": 4";
            format!("{{\"id\": {id}, {kind}{push}, \"image\": {image}}}")
        }
        _ => {
            // Autoregressive generation: a short prompt keyed on the id
            // plus a couple of decode steps that self-schedule through
            // the continuous-batching tier.
            let toks: Vec<String> = (0..3).map(|j| format!("{}", (id * 5 + j) % 32)).collect();
            let push = if id % 8 == 3 { ", \"push\": true" } else { "" };
            format!(
                "{{\"id\": {id}, \"kind\": \"generate\", \"prompt\": [{}], \"max_new_tokens\": 2{push}}}",
                toks.join(", ")
            )
        }
    }
}

#[derive(Default)]
struct PointStats {
    sent: u64,
    ok: u64,
    shed: u64,
    errors: u64,
    progress: u64,
    lat_us: Vec<f64>,
}

impl PointStats {
    fn merge(&mut self, other: PointStats) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.errors += other.errors;
        self.progress += other.progress;
        self.lat_us.extend(other.lat_us);
    }

    fn pct_us(&mut self, q: f64) -> f64 {
        if self.lat_us.is_empty() {
            return 0.0;
        }
        self.lat_us.sort_by(f64::total_cmp);
        let idx = ((self.lat_us.len() as f64 - 1.0) * q).round() as usize;
        self.lat_us[idx.min(self.lat_us.len() - 1)]
    }
}

/// One client connection: a writer pacing `n` requests at the offered
/// inter-arrival gap (open loop — the schedule never waits for
/// responses), with a reader thread draining final lines concurrently so
/// a full server write queue can never deadlock the sender.
fn run_conn(addr: &str, ids: Vec<u64>, gap: Duration) -> std::io::Result<PointStats> {
    let sock = TcpStream::connect(addr)?;
    sock.set_read_timeout(Some(Duration::from_secs(10)))?;
    sock.set_nodelay(true)?;
    let mut wr = sock.try_clone()?;
    let sends: Arc<Mutex<HashMap<u64, Instant>>> = Arc::new(Mutex::new(HashMap::new()));
    let sends_rd = sends.clone();
    let expect = ids.len() as u64;
    let reader = std::thread::spawn(move || {
        let mut stats = PointStats::default();
        let mut lines = BufReader::new(sock);
        let mut buf = String::new();
        let mut finals = 0u64;
        while finals < expect {
            buf.clear();
            match lines.read_line(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            let Ok(j) = json::parse(buf.trim()) else { continue };
            if j.get_path("event").is_some() {
                stats.progress += 1;
                continue;
            }
            let err = j.get_path("error").and_then(|e| e.as_str());
            match err {
                None => stats.ok += 1,
                Some(SHED_DRAINING) | Some(SHED_INFLIGHT) | Some(SHED_QUEUE_FULL) => {
                    stats.shed += 1
                }
                Some(_) => stats.errors += 1,
            }
            finals += 1;
            if let Some(id) = j.get_path("id").and_then(|v| v.as_f64()) {
                if let Some(t0) = sends_rd.lock().unwrap().remove(&(id as u64)) {
                    stats.lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                }
            }
        }
        stats
    });
    let start = Instant::now();
    let mut sent = 0u64;
    for (i, id) in ids.iter().enumerate() {
        let due = start + gap * i as u32;
        if let Some(wait) = due.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        sends.lock().unwrap().insert(*id, Instant::now());
        writeln!(wr, "{}", request_line(*id))?;
        sent += 1;
    }
    wr.flush()?;
    let mut stats = reader.join().unwrap_or_default();
    stats.sent = sent;
    Ok(stats)
}

fn run_point(addr: &str, offered_rps: f64, total: u64, conns: u64) -> PointStats {
    let gap = Duration::from_secs_f64(conns as f64 / offered_rps);
    let mut handles = Vec::new();
    for c in 0..conns {
        let ids: Vec<u64> = (0..total).filter(|i| i % conns == c).collect();
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || run_conn(&addr, ids, gap)));
    }
    let mut stats = PointStats::default();
    for h in handles {
        match h.join() {
            Ok(Ok(s)) => stats.merge(s),
            Ok(Err(e)) => eprintln!("loadgen conn error: {e}"),
            Err(_) => eprintln!("loadgen conn panicked"),
        }
    }
    stats
}

fn main() -> std::io::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (points, total, conns): (&[f64], u64, u64) =
        if smoke { (&[500.0], 120, 4) } else { (&[1000.0, 4000.0, 16000.0], 600, 4) };

    // Bind first to learn the ephemeral port, then serve on it.
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    drop(listener);
    let cfg = ServerConfig {
        addr: addr.clone(),
        batch_sizes: vec![1, 8],
        max_wait: Duration::from_millis(1),
        wave_tokens: 8,
        max_waves: 2,
        // Small admission bounds on purpose: the sweep should cross the
        // shed knee, demonstrating bounded queues instead of unbounded
        // latency growth.
        max_inflight: 64,
        queue_depth: 48,
        drain_timeout: Duration::from_secs(5),
        ..ServerConfig::default()
    };
    let srv = Arc::new(
        Server::new(&cfg).map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e))?,
    );
    let srv2 = srv.clone();
    let scfg = ServerConfig { addr: addr.clone(), ..cfg };
    let server = std::thread::spawn(move || srv2.serve(&scfg, Box::new(LoadExec::new())));
    std::thread::sleep(Duration::from_millis(50));

    println!("loadgen against {addr} ({} points, {total} reqs/point, {conns} conns)", points.len());
    println!(
        "{:>12} {:>12} {:>9} {:>9} {:>7} {:>9}",
        "offered r/s", "achieved r/s", "p50 us", "p99 us", "shed %", "progress"
    );
    for &rps in points {
        let t0 = Instant::now();
        let mut s = run_point(&addr, rps, total, conns);
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let finals = s.ok + s.shed + s.errors;
        println!(
            "{:>12.0} {:>12.0} {:>9.0} {:>9.0} {:>7.2} {:>9}",
            rps,
            finals as f64 / wall,
            s.pct_us(0.50),
            s.pct_us(0.99),
            100.0 * s.shed as f64 / s.sent.max(1) as f64,
            s.progress
        );
        if s.errors > 0 {
            eprintln!("warn: {} non-shed error responses at {rps} r/s", s.errors);
        }
    }

    // Final stats probe + graceful drain over the same wire.
    let sock = TcpStream::connect(&addr)?;
    sock.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut wr = sock.try_clone()?;
    let mut rd = BufReader::new(sock);
    let mut line = String::new();
    writeln!(wr, "{{\"cmd\": \"stats\"}}")?;
    rd.read_line(&mut line)?;
    let stats = json::parse(line.trim())
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e))?;
    for key in ["requests", "shed_requests", "rejected_total", "inflight_permits", "queue_depth"] {
        if let Some(v) = stats.get_path(key).and_then(|v| v.as_f64()) {
            println!("stats {key}: {v}");
        }
    }
    line.clear();
    writeln!(wr, "{{\"cmd\": \"shutdown\"}}")?;
    rd.read_line(&mut line)?;
    if !line.contains("ok") {
        eprintln!("warn: unexpected shutdown ack: {}", line.trim());
    }
    match server.join() {
        Ok(r) => r?,
        Err(_) => eprintln!("warn: server thread panicked"),
    }
    println!("loadgen done: server drained cleanly");
    Ok(())
}
