//! Column characterization deep-dive (the Fig. 5 measurement, full
//! resolution): sweeps every code, reports INL/DNL/noise curves, and
//! writes the raw series to `target/column_char.json` for plotting.
//!
//! Run: `cargo run --release --example column_characterization [-- --column N]`

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::Column;
use cr_cim::metrics::sqnr::ErrorBudget;
use cr_cim::metrics::{characterize, measure_csnr, sqnr_db, CharacterizeOpts, CsnrEnsemble};
use cr_cim::util::args::Args;
use cr_cim::util::json::Json;
use cr_cim::util::pool::default_threads;

fn main() -> Result<(), String> {
    let args = Args::new("column_characterization", "Fig.5 full measurement")
        .opt("column", "0", "column index")
        .opt("trials", "96", "reads per code")
        .opt("seed", "1517599488", "die seed")
        .parse_env()
        .map_err(|e| e.to_string())?;
    let column: usize = args.get_parse("column").map_err(|e| e.to_string())?;
    let trials: usize = args.get_parse("trials").map_err(|e| e.to_string())?;
    let threads = default_threads();

    let mut params = MacroParams::default();
    params.seed = args.get_parse("seed").map_err(|e| e.to_string())?;
    let col = Column::new(&params, column)?;
    let opts = CharacterizeOpts { step: 1, trials, threads, stream: 0 };

    let mut report = Json::obj();
    for mode in [CbMode::On, CbMode::Off] {
        println!("characterizing column {column} {} (step 1, {trials} reads/code)...", mode.label());
        let curve = characterize(&col, mode, &opts);
        let csnr = measure_csnr(&col, mode, &CsnrEnsemble::default(), threads);
        let budget = ErrorBudget::from_curve(&curve);
        let inl = curve.inl_lsb();
        let dnl = curve.dnl_lsb();

        println!("  max |INL|      : {:.2} LSB   (paper: <2)", curve.max_abs_inl());
        println!(
            "  max |DNL|      : {:.2} LSB",
            dnl.iter().fold(0.0f64, |m, x| m.max(x.abs()))
        );
        println!("  mean read noise: {:.3} LSB  (paper: 0.58 w/CB)", curve.mean_noise_lsb());
        println!(
            "  error budget   : q={:.3} inl={:.3} noise={:.3} (var, LSB^2)",
            budget.quantization_var, budget.inl_var, budget.noise_var
        );
        println!("  SQNR           : {:.1} dB    (paper: 45.3 w/CB)", sqnr_db(&curve));
        println!("  CSNR           : {:.1} dB    (paper: 31.3 w/CB)", csnr.csnr_db);

        let mut o = Json::obj();
        o.set("counts", Json::arr_f64(&curve.counts.iter().map(|&c| c as f64).collect::<Vec<_>>()));
        o.set("mean_code", Json::arr_f64(&curve.mean_code));
        o.set("noise_lsb", Json::arr_f64(&curve.noise_lsb));
        o.set("inl_lsb", Json::arr_f64(&inl));
        o.set("dnl_lsb", Json::arr_f64(&dnl));
        o.set("sqnr_db", Json::num(sqnr_db(&curve)));
        o.set("csnr_db", Json::num(csnr.csnr_db));
        report.set(mode.label(), Json::Obj(o));
    }

    std::fs::create_dir_all("target").ok();
    let path = "target/column_char.json";
    std::fs::write(path, Json::Obj(report).to_string_pretty()).map_err(|e| e.to_string())?;
    println!("\nraw series written to {path}");
    Ok(())
}
