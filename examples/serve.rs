//! Trace-driven serving experiment: open-loop request arrivals against
//! the batched PJRT ViT executor — the "serving paper" view of the
//! system: throughput, batch occupancy, queue + execute latency
//! percentiles, and energy per request under the SAC plan.
//!
//! The same trace then replays through the **streaming admission** tier
//! (`coordinator::stream`): padding-free token waves instead of padded
//! fixed batches, with wave occupancy and p50/p99 token latency
//! compared against the fixed-batch numbers, plus the scheduler's
//! planned wave model (`Scheduler::plan_stream`). The PJRT executable
//! consumes whole images, so each request is one token here; the
//! macro-simulator server streams true patch chunks (see
//! docs/SERVING.md §Worked example).
//!
//! Run: `make artifacts && cargo run --release --example serve [-- --rate 200]`

use std::collections::VecDeque;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Result};

use cr_cim::cim::params::MacroParams;
use cr_cim::coordinator::batcher::{Batcher, Request};
use cr_cim::coordinator::ledger::Ledger;
use cr_cim::coordinator::sac::{self, NoiseCalibration};
use cr_cim::coordinator::stream::{StreamConfig, TokenStream};
use cr_cim::coordinator::Scheduler;
use cr_cim::runtime::{Manifest, Runtime, VitExecutable};
use cr_cim::util::args::Args;
use cr_cim::util::pool::default_threads;
use cr_cim::util::stats::percentile;
use cr_cim::vit::graph::ModelGraph;
use cr_cim::vit::plan::PrecisionPlan;
use cr_cim::vit::VitConfig;
use cr_cim::workload::{trace, ArrivalProcess, EvalSet};

fn main() -> Result<()> {
    let args = Args::new("serve", "trace-driven serving experiment")
        .opt("artifacts", "artifacts", "artifacts dir")
        .opt("requests", "400", "number of requests")
        .opt("rate", "200", "mean arrival rate [req/s]")
        .opt("max-wait-ms", "20", "batching window")
        .flag("bursty", "use the bursty arrival process")
        .parse_env()
        .map_err(|e| anyhow!("{e}"))?;

    let dir = PathBuf::from(args.get("artifacts").unwrap());
    let manifest = Manifest::load(&dir).map_err(|e| anyhow!(e))?;
    let eval = EvalSet::load(&dir).map_err(|e| anyhow!(e))?;
    let rt = Runtime::cpu()?;
    let exe = VitExecutable::new(
        &rt,
        manifest.get("vit_cim_b16").ok_or_else(|| anyhow!("no artifact"))?,
    )?;

    let params = MacroParams::default();
    let calib = NoiseCalibration::measure(&params, default_threads()).map_err(|e| anyhow!(e))?;
    let (sa, sm) = sac::plan_sigmas(&PrecisionPlan::paper_sac(), &calib);
    let sched = Scheduler::new(&params);
    let cost = sac::evaluate_plan(&sched, &VitConfig::default(), 1, &PrecisionPlan::paper_sac());

    let n: usize = args.get_parse("requests").map_err(|e| anyhow!("{e}"))?;
    let rate: f64 = args.get_parse("rate").map_err(|e| anyhow!("{e}"))?;
    let process = if args.get_flag("bursty") {
        ArrivalProcess::Bursty { rate_low: rate * 0.2, rate_high: rate * 4.0, dwell_ms: 100.0 }
    } else {
        ArrivalProcess::Poisson { rate }
    };
    let events = trace::generate(process, n, eval.n, 99);
    let batcher = Batcher::new(
        vec![1, exe.batch],
        std::time::Duration::from_millis(args.get_parse("max-wait-ms").map_err(|e| anyhow!("{e}"))?),
    )
    .map_err(|e| anyhow!(e))?;

    println!(
        "serving {n} requests at ~{rate}/s ({}), batch {} window {:?}",
        if args.get_flag("bursty") { "bursty" } else { "poisson" },
        exe.batch,
        batcher.max_wait
    );

    // Open-loop replay: requests arrive on the trace clock; the executor
    // drains with the batching policy.
    let w = eval.image_floats();
    let mut pending: VecDeque<Request<usize>> = VecDeque::new();
    let mut ledger = Ledger::new();
    let mut latencies_us: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut next_event = 0usize;
    let mut seed = 0i32;
    while latencies_us.len() < n {
        let now_us = start.elapsed().as_secs_f64() * 1e6;
        // Admit due arrivals.
        while next_event < events.len() && events[next_event].t_us <= now_us {
            pending.push_back(Request {
                id: next_event as u64,
                payload: events[next_event].image_index,
                arrived: Instant::now(),
            });
            next_event += 1;
        }
        let Some(batch) = batcher.form_batch(&mut pending, Instant::now()) else {
            std::thread::sleep(std::time::Duration::from_micros(100));
            continue;
        };
        // Execute.
        let t0 = Instant::now();
        let mut flat = vec![0f32; exe.batch * w];
        for (i, req) in batch.requests.iter().enumerate() {
            flat[i * w..(i + 1) * w].copy_from_slice(eval.image_slice(req.payload));
        }
        seed += 1;
        let _logits = exe.infer(&flat, seed, sa as f32, sm as f32)?;
        let wall = t0.elapsed();
        ledger.record_batch(batch.requests.len(), batch.exec_size, &cost, wall);
        let done = Instant::now();
        for req in &batch.requests {
            latencies_us.push(done.duration_since(req.arrived).as_secs_f64() * 1e6);
        }
    }
    let span_s = start.elapsed().as_secs_f64();

    println!("\n== results ==");
    println!("throughput          : {:.1} req/s over {:.1} s", n as f64 / span_s, span_s);
    println!(
        "latency p50/p90/p99 : {:.1} / {:.1} / {:.1} ms",
        percentile(&latencies_us, 0.5) / 1e3,
        percentile(&latencies_us, 0.9) / 1e3,
        percentile(&latencies_us, 0.99) / 1e3
    );
    println!("mean batch occupancy: {:.2}", ledger.mean_occupancy());
    println!("macro energy/request: {:.1} µJ (modeled)", ledger.energy_per_request_uj());
    println!("effective TOPS/W    : {:.0}", ledger.effective_tops_per_watt());

    // §8: the same trace through the streaming admission tier — waves
    // of up to `exe.batch` tokens, closed by size or by the batching
    // window, with no padded inferences counted. Each request is one
    // token against the fixed-image PJRT executable.
    let mut stream = TokenStream::new(&StreamConfig {
        wave_tokens: exe.batch,
        max_wait: batcher.max_wait,
    })
    .map_err(|e| anyhow!(e))?;
    let start2 = Instant::now();
    let mut next2 = 0usize;
    let mut done = 0usize;
    while done < n {
        let now_us = start2.elapsed().as_secs_f64() * 1e6;
        while next2 < events.len() && events[next2].t_us <= now_us {
            stream.enqueue_request(
                0,
                Some(next2 as f64),
                eval.image_slice(events[next2].image_index),
                1,
                false,
                Instant::now(),
            );
            next2 += 1;
        }
        let Some(wave) = stream.form_wave(Instant::now()) else {
            std::thread::sleep(std::time::Duration::from_micros(100));
            continue;
        };
        let mut flat = vec![0f32; exe.batch * w];
        for (i, item) in wave.items.iter().enumerate() {
            flat[i * w..(i + 1) * w].copy_from_slice(&item.chunk);
        }
        seed += 1;
        let logits = exe.infer(&flat, seed, sa as f32, sm as f32)?;
        let rows: Vec<Vec<f32>> = (0..wave.items.len())
            .map(|i| logits[i * exe.num_classes..(i + 1) * exe.num_classes].to_vec())
            .collect();
        done += stream
            .complete_wave(&wave, &rows, Instant::now())
            .iter()
            .filter(|f| f.result.is_ok())
            .count();
    }
    let snap = stream.snapshot();
    println!("\n== streaming admission (token waves, padding-free) ==");
    println!(
        "waves {} | wave occupancy {:.2} (fixed-batch occupancy above: {:.2})",
        snap.waves,
        snap.mean_wave_occupancy,
        ledger.mean_occupancy()
    );
    println!(
        "token latency p50/p99: {:.1} / {:.1} ms",
        snap.token_latency_p50_us / 1e3,
        snap.token_latency_p99_us / 1e3
    );
    // The planned wave model for the full token-level ViT workload.
    let cfg = VitConfig::default();
    let graph = ModelGraph::encoder(&cfg, 1, &PrecisionPlan::paper_sac());
    let sp = sched.plan_stream(&graph, exe.batch * cfg.tokens());
    println!(
        "planned wave ({} tokens): {:.1} µs warm, {:.0}% die utilization, p99 token {:.1} µs",
        sp.wave_tokens,
        sp.warm_wave_ns * 1e-3,
        sp.die_utilization * 100.0,
        sp.p99_token_latency_ns * 1e-3
    );
    println!("\nledger: {}", ledger.to_json().to_string_pretty());
    Ok(())
}
