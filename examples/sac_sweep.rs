//! SAC design-space sweep: how the co-design decision changes with the
//! accuracy budget, the network shape, and the supply point.
//!
//! Three sweeps:
//!   1. accuracy budget → chosen per-class operating points (the policy
//!      flips attention to wo/CB long before MLP);
//!   2. network geometry (MLP ratio) → SAC gain (the more MLP-heavy the
//!      network, the closer the gain is to the CB-only ceiling);
//!   3. supply sweep under the SAC plan (Fig. 6's TOPS panel, SAC view).
//!
//! Run: `cargo run --release --example sac_sweep`

use cr_cim::cim::energy::supply_sweep;
use cr_cim::cim::netstats::LayerClass;
use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::sac::{self, choose_operating_point, NoiseCalibration};
use cr_cim::coordinator::Scheduler;
use cr_cim::util::pool::default_threads;
use cr_cim::vit::plan::PrecisionPlan;
use cr_cim::vit::VitConfig;

fn main() -> Result<(), String> {
    let params = MacroParams::default();
    let threads = default_threads();
    let calib = NoiseCalibration::measure(&params, threads)?;
    let sched = Scheduler::new(&params);

    println!("== 1. policy vs accuracy budget ==");
    println!("{:<14} {:<26} {:<26}", "max drop", "attention", "MLP");
    for drop in [0.05, 0.02, 0.01, 0.005, 0.002] {
        let att = choose_operating_point(LayerClass::TransformerAttention, &calib, drop);
        let mlp = choose_operating_point(LayerClass::TransformerMlp, &calib, drop);
        println!(
            "{:<14} {:<26} {:<26}",
            format!("{:.1} pt", drop * 100.0),
            format!("{}b {}", att.a_bits, att.cb.label()),
            format!("{}b {}", mlp.a_bits, mlp.cb.label()),
        );
    }

    println!("\n== 2. SAC gain vs network geometry ==");
    println!("{:<28} {:>12} {:>12} {:>8}", "network", "None µJ", "SAC µJ", "gain");
    for (name, cfg) in [
        ("ViT-tiny (d96, r2)", VitConfig::default()),
        (
            "ViT-small (d384, r4)",
            VitConfig::vit_small(),
        ),
        (
            "attention-heavy (r1)",
            VitConfig { dim: 256, depth: 8, mlp_ratio: 1, ..VitConfig::default() },
        ),
        (
            "mlp-heavy (r8)",
            VitConfig { dim: 256, depth: 8, mlp_ratio: 8, ..VitConfig::default() },
        ),
    ] {
        let none = sac::evaluate_plan(&sched, &cfg, 1, &PrecisionPlan::uniform_safe());
        let sacp = sac::evaluate_plan(&sched, &cfg, 1, &PrecisionPlan::paper_sac());
        println!(
            "{:<28} {:>12.1} {:>12.1} {:>7.2}x",
            name,
            none.energy_uj,
            sacp.energy_uj,
            none.energy_uj / sacp.energy_uj
        );
    }

    println!("\n== 3. supply sweep (CB off / peak mode) ==");
    println!("{:>8} {:>10} {:>12}", "V", "TOPS", "TOPS/W");
    for p in supply_sweep(&params, CbMode::Off, 6) {
        println!("{:>8.2} {:>10.2} {:>12.0}", p.supply_v, p.tops, p.tops_per_watt);
    }

    println!(
        "\nSAC end-to-end gain on ViT-small: {:.2}x (paper: up to 2.1x)",
        sac::sac_efficiency_improvement(&sched, &VitConfig::vit_small(), 1)
    );
    Ok(())
}
