//! Quickstart: a five-minute tour of the CR-CIM library.
//!
//! 1. Instantiate a die (mismatch + noise Monte-Carlo model).
//! 2. Read one column's accuracy metrics with and without CSNR boost.
//! 3. Run an integer matvec through the full macro and compare with the
//!    exact digital result — the conversions fan out across the
//!    column-parallel engine (`MacroParams::threads`), bit-identical at
//!    any thread count.
//! 4. Ask the SAC policy engine what the ViT workload costs.
//! 5. Batch vectors through column-sharded parallel macros.
//! 6. Row-tile a k = 3072 MLP `fc2` layer across 2 dies — the 2-D tiled
//!    multi-die serving path (see docs/ARCHITECTURE.md).
//! 7. Serve a whole ViT encoder forward pass through the model-graph
//!    pipeline executor: per-layer-class die pools, double-buffered
//!    weight reloads, per-layer accounting.
//! 8. Drive a serving session that exercises every server request kind
//!    — `classify`, `forward` and token-level `stream` (continuous
//!    batching into conversion waves, out-of-order completion) — and
//!    read the ledger's streaming stats (see docs/SERVING.md).
//!
//! Run: `cargo run --release --example quickstart`

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::{CimMacro, Column};
use cr_cim::coordinator::sac::{self, NoiseCalibration};
use cr_cim::coordinator::server::{BatchExecutor, Server, ServerConfig};
use cr_cim::coordinator::{DieBank, MacroShards, ModelExecutor, PipelineConfig, Scheduler};
use cr_cim::metrics::{characterize, measure_csnr, sqnr_db, CharacterizeOpts, CsnrEnsemble};
use cr_cim::util::pool::default_threads;
use cr_cim::util::rng::Rng;
use cr_cim::vit::graph::ModelGraph;
use cr_cim::vit::plan::PrecisionPlan;
use cr_cim::vit::VitConfig;

fn main() -> Result<(), String> {
    let threads = default_threads();
    println!("== 1. a CR-CIM die ==");
    let params = MacroParams::default();
    println!(
        "array {}x{}, {}-bit reconfigured SAR, {} fF unit caps, {:.2} V",
        params.rows, params.cols, params.adc_bits, params.c_unit_ff, params.supply_v
    );

    println!("\n== 2. column accuracy (Fig. 5 in miniature) ==");
    let col = Column::new(&params, 0)?;
    let opts = CharacterizeOpts { step: 16, trials: 32, threads, stream: 0 };
    for mode in [CbMode::On, CbMode::Off] {
        let curve = characterize(&col, mode, &opts);
        let csnr = measure_csnr(&col, mode, &CsnrEnsemble::default(), threads);
        println!(
            "  {:>6}: INL {:.2} LSB | noise {:.2} LSB | SQNR {:.1} dB | CSNR {:.1} dB",
            mode.label(),
            curve.max_abs_inl(),
            curve.mean_noise_lsb(),
            sqnr_db(&curve),
            csnr.csnr_db,
        );
    }

    println!("\n== 3. a multi-bit matvec on the macro ==");
    // The engine fans column conversions across `threads` workers; the
    // result is bit-identical at any setting (owned per-column substreams).
    let mut m = CimMacro::new(&params.clone().with_threads(threads))?;
    let mut rng = Rng::new(7);
    let rows = 512;
    let n_out = 8;
    let w: Vec<Vec<i32>> = (0..rows)
        .map(|_| (0..n_out).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    let x: Vec<i32> = (0..rows).map(|_| rng.below(15) as i32 - 7).collect();
    m.load_weights(&w, 4)?;
    let exact = m.matvec_exact(&w, &x);
    let got = m.matvec(&x, 4, CbMode::On)?;
    println!("  exact digital: {exact:?}");
    println!("  CR-CIM w/CB:   {:?}", got.y);
    println!(
        "  {} conversions, {:.1} nJ, {:.2} µs",
        got.conversions,
        got.energy_pj * 1e-3,
        got.latency_ns * 1e-3
    );

    println!("\n== 4. SAC policy over the ViT workload ==");
    let calib = NoiseCalibration::measure(&params, threads)?;
    println!(
        "  calibrated read noise: {:.2} LSB w/CB, {:.2} LSB wo/CB",
        calib.sigma_cb_on, calib.sigma_cb_off
    );
    let sched = Scheduler::new(&params);
    let cfg = VitConfig::vit_small();
    for plan in PrecisionPlan::ablation_series() {
        let cost = sac::evaluate_plan(&sched, &cfg, 1, &plan);
        println!(
            "  {:<44} {:>8.1} µJ/inf {:>9.1} µs",
            plan.name, cost.energy_uj, cost.latency_us
        );
    }
    println!(
        "  SAC end-to-end efficiency gain: {:.2}x (paper: up to 2.1x)",
        sac::sac_efficiency_improvement(&sched, &cfg, 1)
    );

    println!("\n== 5. column-sharded batch execution ==");
    let op = PrecisionPlan::paper_sac().mlp;
    let wide_n = 26; // 26 outputs x 6b = 156 planes: needs 2 macros
    let w_wide: Vec<Vec<i32>> = (0..rows)
        .map(|_| (0..wide_n).map(|_| rng.below(63) as i32 - 31).collect())
        .collect();
    let mut bank = MacroShards::new(&params, &w_wide, op, 2)?;
    let xs: Vec<Vec<i32>> = (0..4)
        .map(|_| (0..rows).map(|_| rng.below(63) as i32 - 31).collect())
        .collect();
    let ys = bank.matvec_batch(&xs)?;
    println!(
        "  {} vectors x {} outputs over {} shards: {} conversions, {:.1} nJ",
        ys.len(),
        wide_n,
        bank.shard_count(),
        bank.total_conversions,
        bank.total_energy_pj * 1e-3
    );

    println!("\n== 6. row-tiled multi-die serving (k = 3072 MLP fc2) ==");
    // d_ff = 3072 exceeds the 1024-row tile, so the layer splits into 3
    // row tiles whose partial sums accumulate digitally; two dies share
    // the batch. Noise of accumulated tiles composes in quadrature —
    // kernel_sigma reports the tiled σ the SAC planner must use.
    let deep_k = 3072;
    let deep_n = 8;
    let w_deep: Vec<Vec<i32>> = (0..deep_k)
        .map(|_| (0..deep_n).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    let op4 = cr_cim::vit::plan::OperatingPoint::new(4, 4, CbMode::On);
    let mut dies = DieBank::new(&params, &w_deep, op4, 1, 2)?;
    let xs_deep: Vec<Vec<i32>> = (0..2)
        .map(|_| (0..deep_k).map(|_| rng.below(15) as i32 - 7).collect())
        .collect();
    let ys_deep = dies.matvec_batch(&xs_deep)?;
    println!(
        "  {} dies x {} row tiles x {} shard(s): {} vectors served, {} conversions, {:.1} nJ",
        dies.die_count(),
        dies.row_tile_count(),
        dies.shard_count(),
        ys_deep.len(),
        dies.total_conversions(),
        dies.total_energy_pj() * 1e-3
    );
    let calib_sigma = calib.sigma(op4.cb);
    println!(
        "  tiled output noise: {:.1} LSB ({} tiles in quadrature; single tile {:.1} LSB)",
        sac::kernel_noise_sigma_for_row_tiles(dies.row_tile_count(), 4, 4, calib_sigma),
        dies.row_tile_count(),
        sac::kernel_noise_sigma_for_row_tiles(1, 4, 4, calib_sigma)
    );

    println!("\n== 7. model-graph pipeline: a ViT encoder forward pass ==");
    // The unit of work becomes the whole encoder: a 2-block graph walks
    // layer by layer through per-layer-class die pools (attention and
    // MLP on disjoint silicon, sized by the router's LPT mass), and the
    // scheduler prices each layer's weight reload double-buffered
    // behind the previous layer's conversions.
    let small = VitConfig {
        image: 16,
        patch: 4,
        dim: 48,
        depth: 2,
        heads: 4,
        mlp_ratio: 2,
        num_classes: 10,
    };
    let graph = ModelGraph::encoder(&small, 2, &PrecisionPlan::paper_sac());
    let pool_cfg = PipelineConfig::sized_by_router(&params, &graph, 2, 4);
    println!(
        "  graph: {} layers, {} weights | pools: {} attention dies, {} MLP dies",
        graph.layer_count(),
        graph.weight_params(),
        pool_cfg.attention_dies,
        pool_cfg.mlp_dies,
    );
    let mut pipe = ModelExecutor::new(&params, graph, pool_cfg)?;
    let imgs: Vec<Vec<f32>> = (0..2)
        .map(|i| (0..16).map(|j| ((i + j) % 7) as f32 / 7.0 - 0.4).collect())
        .collect();
    let logits = pipe.execute(&imgs)?;
    println!("  served {} images -> {} logits each", logits.len(), logits[0].len());
    println!(
        "  {:<16} {:>8} {:>12} {:>12} {:>12}",
        "layer", "class", "conversions", "compute µs", "reload µs"
    );
    for l in pipe.layer_costs() {
        println!(
            "  {:<16} {:>8} {:>12} {:>12.2} {:>12.2}",
            l.name,
            if l.class.contains("attention") { "attn" } else { "mlp" },
            l.conversions,
            l.compute_ns * 1e-3,
            l.reload_ns * 1e-3,
        );
    }
    // A second pass is warm: the resident-weight cache kept the pool
    // banks programmed, so no layer reloads (outputs stay governed by
    // the same determinism contract either way).
    let _ = pipe.execute(&imgs)?;
    let pp = pipe.pipeline();
    println!(
        "  full pass: serial reloads {:.1} µs, double-buffered {:.1} µs ({:.0}% saved)",
        pp.serial_ns * 1e-3,
        pp.pipelined_ns * 1e-3,
        pp.overlap_saving() * 100.0
    );
    println!(
        "  warm pass (weights resident): {:.1} µs — {} of {} layers resident",
        pp.warm_pipelined_ns * 1e-3,
        pp.resident_layers(),
        pipe.graph.layer_count(),
    );
    let res = pipe.residency_stats();
    println!(
        "  reloads over {} passes: {} misses, {} hits, amortized {:.1} µs/pass",
        res.passes,
        res.reload_misses,
        res.reload_hits,
        res.amortized_reload_ns() * 1e-3,
    );

    println!("\n== 8. streaming token-level serving (every server kind) ==");
    // The same executor serves a whole session through the server's
    // request path (no TCP needed — handle_line + executor_step is the
    // same code the socket loop runs). One classify, one forward, one
    // stream request whose image splits into 3 tokens: the tokens
    // coalesce into 2-token conversion waves (no padding), complete out
    // of order across waves, and reassemble into one pooled response.
    let srv = Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 2],
        max_wait: std::time::Duration::from_millis(1),
        wave_tokens: 2,
        ..ServerConfig::default()
    })?;
    let conn = srv.open_conn();
    let body: Vec<String> = imgs[0].iter().map(|v| format!("{v}")).collect();
    let body = body.join(", ");
    srv.handle_line(&format!(r#"{{"id": 1, "image": [{body}]}}"#), conn)?;
    srv.handle_line(&format!(r#"{{"id": 2, "kind": "forward", "image": [{body}]}}"#), conn)?;
    srv.handle_line(
        &format!(r#"{{"id": 3, "kind": "stream", "tokens": 3, "image": [{body}]}}"#),
        conn,
    )?;
    // Step the executor until everything is answered (the last 1-token
    // wave closes on the max_wait deadline).
    let mut answers = Vec::new();
    while answers.len() < 3 {
        srv.executor_step(&mut pipe);
        answers.extend(srv.take_responses(conn));
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    for line in &answers {
        println!("  <- {line}");
    }
    // The scheduler's streaming occupancy model, next to the measured
    // stats: planned wave utilization and the saturation latency tail.
    let sp = Scheduler::new(&params).plan_stream(&pipe.graph, 2);
    println!(
        "  planned 2-token wave: {:.1} µs warm, {:.0}% die utilization, p99 token {:.1} µs",
        sp.warm_wave_ns * 1e-3,
        sp.die_utilization * 100.0,
        sp.p99_token_latency_ns * 1e-3,
    );
    let stats = srv.ledger_json();
    for key in [
        "stream_requests",
        "stream_tokens_served",
        "stream_waves",
        "mean_wave_occupancy",
        "token_latency_p50_us",
        "token_latency_p99_us",
    ] {
        if let Some(v) = stats.get_path(key) {
            println!("  stats.{key} = {v}");
        }
    }
    Ok(())
}
