//! END-TO-END DRIVER: ViT inference through the full three-layer stack.
//!
//! Loads the AOT-compiled ViT artifacts (JAX+Pallas → HLO text → PJRT),
//! the shared held-out eval set, and the circuit-calibrated noise sigmas,
//! then measures:
//!
//!   - ideal (fp32) accuracy            — the paper's 96.8% row
//!   - CIM + SAC plan accuracy          — the paper's 95.8% row
//!   - CIM all-4b-no-CB accuracy        — why SAC is needed
//!   - modeled macro energy/latency per inference for each plan
//!
//! Results are appended to EXPERIMENTS.md by hand; the JSON goes to
//! `target/vit_inference.json`.
//!
//! Run: `make artifacts && cargo run --release --example vit_inference`

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Result};

use cr_cim::cim::params::MacroParams;
use cr_cim::coordinator::sac::{self, NoiseCalibration};
use cr_cim::coordinator::Scheduler;
use cr_cim::runtime::{Manifest, Runtime, VitExecutable};
use cr_cim::util::json::Json;
use cr_cim::util::pool::default_threads;
use cr_cim::vit::plan::PrecisionPlan;
use cr_cim::vit::VitConfig;
use cr_cim::workload::EvalSet;

struct EvalOutcome {
    accuracy: f64,
    wall_s: f64,
    images: usize,
}

fn eval_accuracy(
    exe: &VitExecutable,
    eval: &EvalSet,
    count: usize,
    sigma_attn: f32,
    sigma_mlp: f32,
) -> Result<EvalOutcome> {
    let w = eval.image_floats();
    let count = count.min(eval.n);
    let mut correct = 0usize;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < count {
        let b = exe.batch.min(count - done);
        let mut flat = vec![0f32; exe.batch * w];
        for i in 0..b {
            flat[i * w..(i + 1) * w].copy_from_slice(eval.image_slice(done + i));
        }
        let logits = exe.infer(&flat, done as i32 + 1, sigma_attn, sigma_mlp)?;
        let preds = exe.predict(&logits);
        for i in 0..b {
            if preds[i] == eval.labels[done + i] as usize {
                correct += 1;
            }
        }
        done += b;
    }
    Ok(EvalOutcome {
        accuracy: correct as f64 / count as f64,
        wall_s: t0.elapsed().as_secs_f64(),
        images: count,
    })
}

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let dir = PathBuf::from(&artifacts);
    let manifest = Manifest::load(&dir).map_err(|e| anyhow!(e))?;
    manifest.check_files().map_err(|e| anyhow!(e))?;
    let eval = EvalSet::load(&dir).map_err(|e| anyhow!(e))?;
    let count: usize = std::env::var("CRCIM_EVAL_COUNT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);

    println!("== CR-CIM end-to-end: ViT on the synthetic CIFAR-like corpus ==");
    println!("artifacts: {artifacts}; eval images: {count}/{}", eval.n);

    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let t0 = Instant::now();
    let fp = VitExecutable::new(&rt, manifest.get("vit_fp_b16").ok_or_else(|| anyhow!("no fp artifact"))?)?;
    let cim = VitExecutable::new(&rt, manifest.get("vit_cim_b16").ok_or_else(|| anyhow!("no cim artifact"))?)?;
    println!("compile time: {:.1} s", t0.elapsed().as_secs_f64());

    // Circuit-sim calibration → L2 noise inputs.
    let params = MacroParams::default();
    let threads = default_threads();
    let calib = NoiseCalibration::measure(&params, threads).map_err(|e| anyhow!(e))?;
    println!(
        "calibrated read noise: {:.3} LSB (CB on) / {:.3} LSB (CB off)",
        calib.sigma_cb_on, calib.sigma_cb_off
    );

    let sched = Scheduler::new(&params);
    let cfg = VitConfig::default(); // matches the trained artifact
    let mut report = Json::obj();
    report.set("eval_images", Json::num(count as f64));
    if let Some(acc) = manifest.acc_fp {
        report.set("trainer_reported_fp_acc", Json::num(acc));
    }

    // 1. Ideal inference.
    let ideal = eval_accuracy(&fp, &eval, count, 0.0, 0.0)?;
    println!(
        "\nideal (fp32)        : {:.1}%  ({} imgs, {:.1} s)   [paper: 96.8%]",
        ideal.accuracy * 100.0,
        ideal.images,
        ideal.wall_s
    );
    report.set("ideal_accuracy", Json::num(ideal.accuracy));

    // 2/3. CIM plans.
    let plans = [
        ("cim_sac (paper plan)", PrecisionPlan::paper_sac(), "[paper: 95.8%]"),
        ("cim_all4b_noCB", PrecisionPlan::uniform_fast(), "(why SAC is needed)"),
    ];
    for (name, plan, tag) in plans {
        let (sa, sm) = sac::plan_sigmas(&plan, &calib);
        // The artifact's bit-widths are baked (attn 4b / mlp 6b); the σ
        // inputs carry the CB decision. For the all-4b plan we push the
        // no-CB σ into both classes.
        let out = eval_accuracy(&cim, &eval, count, sa as f32, sm as f32)?;
        let cost = sac::evaluate_plan(&sched, &cfg, 1, &plan);
        println!(
            "{name:<20}: {:.1}%  ({} imgs, {:.1} s)   {tag}",
            out.accuracy * 100.0,
            out.images,
            out.wall_s
        );
        println!(
            "  modeled macro cost: {:.1} µJ/inf, {:.1} µs/inf, eff {:.0} TOPS/W",
            cost.energy_uj, cost.latency_us, cost.tops_per_watt_effective
        );
        let mut o = Json::obj();
        o.set("accuracy", Json::num(out.accuracy));
        o.set("energy_uj", Json::num(cost.energy_uj));
        o.set("latency_us", Json::num(cost.latency_us));
        o.set("sigma_attn", Json::num(sa));
        o.set("sigma_mlp", Json::num(sm));
        report.set(name, Json::Obj(o));
    }

    // 4. Efficiency headline.
    let gain = sac::sac_efficiency_improvement(&sched, &VitConfig::vit_small(), 1);
    println!("\nSAC efficiency gain (ViT-small workload): {gain:.2}x   [paper: up to 2.1x]");
    report.set("sac_gain_x", Json::num(gain));

    std::fs::create_dir_all("target").ok();
    std::fs::write("target/vit_inference.json", Json::Obj(report).to_string_pretty())?;
    println!("report written to target/vit_inference.json");
    Ok(())
}
