//! Yield / corner analysis: the shmoo view a chip team would run before
//! committing the design — multi-die Monte-Carlo against the published
//! spec, a temperature sweep, and the post-calibration recovery.
//!
//! Run: `cargo run --release --example yield_analysis [-- --dies 24]`

use cr_cim::cim::calibration::CalibrationTable;
use cr_cim::cim::montecarlo::{summarize, sweep_dies, temperature_sweep, YieldSpec};
use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::Column;
use cr_cim::metrics::CharacterizeOpts;
use cr_cim::util::args::Args;
use cr_cim::util::pool::default_threads;
use cr_cim::util::stats::rms;

fn main() -> Result<(), String> {
    let args = Args::new("yield_analysis", "multi-die Monte-Carlo")
        .opt("dies", "24", "dies to sample")
        .parse_env()
        .map_err(|e| e.to_string())?;
    let dies: usize = args.get_parse("dies").map_err(|e| e.to_string())?;
    let threads = default_threads();
    let base = MacroParams::default();
    let opts = CharacterizeOpts { step: 8, trials: 32, threads: 1, stream: 21 };

    println!("== lot sweep: {dies} dies, CB on ==");
    let results = sweep_dies(&base, CbMode::On, dies, &opts, threads);
    let spec = YieldSpec::default();
    let lot = summarize(&results, &spec);
    println!(
        "spec: INL<= {} LSB, SQNR >= {} dB, CSNR >= {} dB",
        spec.max_inl_lsb, spec.min_sqnr_db, spec.min_csnr_db
    );
    println!("yield: {:.0}%", lot.yield_fraction * 100.0);
    println!(
        "SQNR: {:.1} ± {:.1} dB [{:.1}, {:.1}]",
        lot.sqnr.mean(),
        lot.sqnr.std(),
        lot.sqnr.min(),
        lot.sqnr.max()
    );
    println!(
        "CSNR: {:.1} ± {:.1} dB | max|INL|: {:.2} ± {:.2} LSB",
        lot.csnr.mean(),
        lot.csnr.std(),
        lot.inl.mean(),
        lot.inl.std()
    );

    println!("\n== temperature sweep (die 0, CB on) ==");
    println!("{:>8} {:>12} {:>10}", "T [K]", "noise [LSB]", "SQNR [dB]");
    for (t, noise, sqnr) in
        temperature_sweep(&base, CbMode::On, &[250.0, 300.0, 350.0, 400.0], &opts)
    {
        println!("{t:>8.0} {noise:>12.3} {sqnr:>10.1}");
    }

    println!("\n== per-die calibration recovery (static error rms, LSB) ==");
    println!("{:>6} {:>10} {:>12}", "die", "raw", "calibrated");
    for i in 0..4.min(dies) {
        let p = base.clone().with_seed(base.seed.wrapping_add(1 + i as u64 * 7919));
        let col = Column::new(&p, 0)?;
        let raw: Vec<f64> =
            (0..1024).map(|c| col.static_code(c) as f64 - c as f64).collect();
        let table = CalibrationTable::measure(&col, CbMode::On, 12, threads);
        let res = table.residual_inl(&col);
        println!("{i:>6} {:>10.3} {:>12.3}", rms(&raw), rms(&res));
    }
    Ok(())
}
