//! Fig. 5: measured CR-CIM column characteristics.
//!
//! Reproduces the full measurement: transfer curve (INL < 2 LSB), read
//! noise per code (0.58 LSB avg w/CB, higher without), and the derived
//! SQNR (paper 45.3 dB) and CSNR (paper 31.3 dB). Also reports the
//! across-column spread (the chip has 78 of them) and times the
//! characterization pipeline itself.

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::Column;
use cr_cim::metrics::{
    characterize, measure_csnr, sqnr_db, CharacterizeOpts, CsnrEnsemble,
};
use cr_cim::util::bench::{black_box, BenchSuite};
use cr_cim::util::json::Json;
use cr_cim::util::pool::default_threads;
use cr_cim::util::stats;

fn main() {
    let mut suite = BenchSuite::new("Fig 5 - column characteristics");
    let params = MacroParams::default();
    let threads = default_threads();
    let opts = CharacterizeOpts { step: 4, trials: 64, threads, stream: 0 };

    // --- the headline column (column 0 of the die) ---------------------------
    let col = Column::new(&params, 0).unwrap();
    let mut table = Json::obj();
    for mode in [CbMode::On, CbMode::Off] {
        let curve = characterize(&col, mode, &opts);
        let csnr = measure_csnr(&col, mode, &CsnrEnsemble::default(), threads);
        let mut o = Json::obj();
        o.set("max_abs_inl_lsb (paper: <2)", Json::num(curve.max_abs_inl()));
        o.set("inl_rms_lsb", Json::num(curve.inl_rms()));
        o.set(
            "mean_read_noise_lsb (paper: 0.58 w/CB, 2x wo)",
            Json::num(curve.mean_noise_lsb()),
        );
        o.set("sqnr_db (paper: 45.3 w/CB)", Json::num(sqnr_db(&curve)));
        o.set("csnr_db (paper: 31.3 w/CB)", Json::num(csnr.csnr_db));
        o.set("signal_sigma_lsb", Json::num(csnr.sigma_signal_lsb));
        table.set(mode.label(), Json::Obj(o));
    }
    suite.note("column0", Json::Obj(table));

    // --- across-column spread (process variation) ----------------------------
    let quick = CharacterizeOpts { step: 16, trials: 24, threads, stream: 1 };
    let mut inls = Vec::new();
    let mut noises = Vec::new();
    for c in 0..12 {
        let col = Column::new(&params, c).unwrap();
        let curve = characterize(&col, CbMode::On, &quick);
        inls.push(curve.max_abs_inl());
        noises.push(curve.mean_noise_lsb());
    }
    let mut spread = Json::obj();
    spread.set("columns_measured", Json::num(inls.len() as f64));
    spread.set("inl_max_mean", Json::num(stats::mean(&inls)));
    spread.set("inl_max_worst", Json::num(inls.iter().fold(0.0f64, |m, &x| m.max(x))));
    spread.set("noise_mean", Json::num(stats::mean(&noises)));
    spread.set("noise_std_across_cols", Json::num(stats::std(&noises)));
    suite.note("across_columns", Json::Obj(spread));

    // --- characterization pipeline cost ---------------------------------------
    let fast = CharacterizeOpts { step: 64, trials: 8, threads: 1, stream: 2 };
    suite.bench("characterize column (step 64, 8 trials, 1 thread)", || {
        black_box(characterize(&col, CbMode::On, &fast));
    });
    let fast_mt = CharacterizeOpts { step: 64, trials: 8, threads, stream: 2 };
    suite.bench(
        &format!("characterize column ({} threads)", threads),
        || {
            black_box(characterize(&col, CbMode::On, &fast_mt));
        },
    );

    suite.finish();
}
