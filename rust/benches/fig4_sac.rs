//! Fig. 4: software-analog co-design.
//!
//! Reproduces:
//!   - the per-layer-class required CSNR (attention ≈ MLP − 10 dB),
//!   - the CB trade: +CSNR, 1.9× power, 2.5× SAR time,
//!   - the end-to-end efficiency ablation "None → w/CB → w/CB+BW-opt"
//!     reaching ≈2.1× (also Fig. 6's SAC bars).

use cr_cim::cim::netstats::LayerClass;
use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::sac::{
    self, choose_operating_point, required_csnr_db, NoiseCalibration,
};
use cr_cim::coordinator::Scheduler;
use cr_cim::util::bench::{black_box, BenchSuite};
use cr_cim::util::json::Json;
use cr_cim::util::pool::default_threads;
use cr_cim::vit::plan::PrecisionPlan;
use cr_cim::vit::VitConfig;

fn main() {
    let mut suite = BenchSuite::new("Fig 4 - software-analog co-design (SAC)");
    let params = MacroParams::default();
    let sched = Scheduler::new(&params);
    let cfg = VitConfig::vit_small();

    // --- per-layer required CSNR + chosen operating points -------------------
    let calib = NoiseCalibration::measure(&params, default_threads()).unwrap();
    let mut req = Json::obj();
    for class in [LayerClass::TransformerAttention, LayerClass::TransformerMlp] {
        let op = choose_operating_point(class, &calib, 0.01);
        let mut o = Json::obj();
        o.set("required_csnr_db", Json::num(required_csnr_db(class, 0.01)));
        o.set("chosen_bits", Json::num(op.a_bits as f64));
        o.set("chosen_cb", Json::str(op.cb.label()));
        req.set(class.label(), Json::Obj(o));
    }
    req.set(
        "mlp_minus_attention_db (paper: 10)",
        Json::num(
            required_csnr_db(LayerClass::TransformerMlp, 0.01)
                - required_csnr_db(LayerClass::TransformerAttention, 0.01),
        ),
    );
    suite.note("required_csnr_and_policy", Json::Obj(req));

    // --- the CB trade itself --------------------------------------------------
    let e = cr_cim::cim::EnergyModel::cr_cim(&params);
    let mut cb = Json::obj();
    cb.set("csnr_boost_db (paper: 5.5)", Json::num(calib.csnr_on.csnr_db - calib.csnr_off.csnr_db));
    cb.set(
        "power_overhead_x (paper: 1.9)",
        Json::num(e.conversion_energy_pj(CbMode::On) / e.conversion_energy_pj(CbMode::Off)),
    );
    cb.set(
        "sar_time_overhead_x (paper: 2.5)",
        Json::num(
            params.comparisons_per_conversion(CbMode::On) as f64
                / params.comparisons_per_conversion(CbMode::Off) as f64,
        ),
    );
    cb.set("read_noise_on_lsb (paper: 0.58)", Json::num(calib.sigma_cb_on));
    cb.set("read_noise_off_lsb (paper: ~1.16)", Json::num(calib.sigma_cb_off));
    suite.note("cb_tradeoff", Json::Obj(cb));

    // --- the ablation bars (Fig. 6 bottom-right) ------------------------------
    let mut bars = Json::obj();
    let base = sac::evaluate_plan(&sched, &cfg, 1, &PrecisionPlan::uniform_safe());
    for plan in PrecisionPlan::ablation_series() {
        let cost = sac::evaluate_plan(&sched, &cfg, 1, &plan);
        let mut o = Json::obj();
        o.set("energy_uj_per_inference", Json::num(cost.energy_uj));
        o.set("latency_us", Json::num(cost.latency_us));
        o.set("efficiency_gain_x", Json::num(base.energy_uj / cost.energy_uj));
        bars.set(plan.name, Json::Obj(o));
    }
    bars.set(
        "sac_total_gain_x (paper: 2.1)",
        Json::num(sac::sac_efficiency_improvement(&sched, &cfg, 1)),
    );
    suite.note("sac_ablation", Json::Obj(bars));

    // --- microbenchmarks: policy + plan evaluation hot paths -----------------
    suite.bench("choose_operating_point", || {
        black_box(choose_operating_point(
            black_box(LayerClass::TransformerMlp),
            &calib,
            0.01,
        ));
    });
    suite.bench("evaluate_plan (ViT-small)", || {
        black_box(sac::evaluate_plan(&sched, &cfg, 1, &PrecisionPlan::paper_sac()));
    });

    suite.finish();
}
