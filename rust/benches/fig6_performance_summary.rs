//! Fig. 6: the performance-summary table + the TOPS-vs-supply panel.
//!
//! Emits three sections:
//!   1. the comparison table — our *regenerated* rows (CR-CIM from the
//!      energy/area/metric models; [4]-like and [2]-like from their
//!      mechanism baselines) next to the published rows, with FoMs;
//!   2. the supply sweep (0.6–1.1 V): TOPS vs TOPS/W;
//!   3. FoM ratio headlines (paper: 2.3× SQNR-FoM, 1.5× CSNR-FoM).

use cr_cim::cim::area::AreaModel;
use cr_cim::cim::baselines::{conventional, current, digital, published, ChipSummary};
use cr_cim::cim::energy::{supply_sweep, EnergyModel};
use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::Column;
use cr_cim::metrics::{characterize, measure_csnr, sqnr_db, CharacterizeOpts, CsnrEnsemble};
use cr_cim::util::bench::{black_box, BenchSuite};
use cr_cim::util::json::Json;
use cr_cim::util::pool::default_threads;

fn chip_row(c: &ChipSummary) -> Json {
    let mut o = Json::obj();
    o.set("type", Json::str(c.cim_type));
    o.set("process_nm", Json::num(c.process_nm as f64));
    o.set("bits", Json::str(format!("{}b/{}b", c.act_bits, c.weight_bits)));
    o.set("adc_bits", Json::num(c.adc_bits as f64));
    o.set("tops_1b", Json::num(c.tops));
    o.set("tops_per_mm2_1b", Json::num(c.tops_per_mm2));
    o.set("tops_per_w_1b", Json::num(c.tops_per_watt));
    o.set("sqnr_db", c.sqnr_db.map(Json::num).unwrap_or(Json::Null));
    o.set("csnr_db", c.csnr_db.map(Json::num).unwrap_or(Json::Null));
    o.set("sqnr_fom", c.sqnr_fom().map(Json::num).unwrap_or(Json::Null));
    o.set("csnr_fom", c.csnr_fom().map(Json::num).unwrap_or(Json::Null));
    o.set("transformer", Json::Bool(c.supports_transformer));
    Json::Obj(o)
}

/// Regenerate "this work"'s row from the simulator, not the paper.
fn this_work_simulated(params: &MacroParams, threads: usize) -> ChipSummary {
    let col = Column::new(params, 0).unwrap();
    let opts = CharacterizeOpts { step: 8, trials: 48, threads, stream: 0 };
    let curve = characterize(&col, CbMode::On, &opts);
    let csnr = measure_csnr(&col, CbMode::On, &CsnrEnsemble::default(), threads);
    let e06 = EnergyModel::cr_cim(&params.clone().with_supply(0.6));
    let e11 = EnergyModel::cr_cim(&params.clone().with_supply(1.1));
    let area = AreaModel::default();
    let tops = e11.tops(CbMode::Off);
    ChipSummary {
        name: "This work (simulated)",
        cim_type: "Charge",
        process_nm: 65,
        array_kb: (params.rows * params.cols) as f64 / 8.0 / 1024.0,
        act_bits: 6,
        weight_bits: 6,
        adc_bits: params.adc_bits,
        tops,
        tops_per_mm2: area.tops_per_mm2(params, tops),
        tops_per_watt: e06.tops_per_watt(CbMode::Off),
        sqnr_db: Some(sqnr_db(&curve)),
        csnr_db: Some(csnr.csnr_db),
        supports_transformer: true,
    }
}

fn main() {
    let mut suite = BenchSuite::new("Fig 6 - performance summary");
    let params = MacroParams::default();
    let threads = default_threads();

    // --- section 1: the comparison table -------------------------------------
    let sim = this_work_simulated(&params, threads);
    let mut table = Json::obj();
    table.set(sim.name, chip_row(&sim));
    let conv = conventional::summary(&params);
    table.set(conv.name, chip_row(&conv));
    let cur = current::summary();
    table.set(cur.name, chip_row(&cur));
    let dig = digital::summary();
    table.set(dig.name, chip_row(&dig));
    for row in published::all_published() {
        table.set(row.name, chip_row(&row));
    }
    suite.note("comparison_table", Json::Obj(table));

    // --- section 2: TOPS vs supply (0.6-1.1 V) --------------------------------
    let sweep = supply_sweep(&params, CbMode::Off, 6);
    let mut sw = Json::obj();
    sw.set("supply_v", Json::arr_f64(&sweep.iter().map(|p| p.supply_v).collect::<Vec<_>>()));
    sw.set("tops_1b", Json::arr_f64(&sweep.iter().map(|p| p.tops).collect::<Vec<_>>()));
    sw.set(
        "tops_per_w_1b",
        Json::arr_f64(&sweep.iter().map(|p| p.tops_per_watt).collect::<Vec<_>>()),
    );
    suite.note("supply_sweep", Json::Obj(sw));

    // --- section 3: FoM headlines ---------------------------------------------
    let best_other_sqnr = [&conv, &cur]
        .iter()
        .filter_map(|c| c.sqnr_fom())
        .chain(published::vlsi2021_published().sqnr_fom())
        .fold(0.0f64, f64::max);
    let best_other_csnr = [&conv]
        .iter()
        .filter_map(|c| c.csnr_fom())
        .chain(published::vlsi2021_published().csnr_fom())
        .fold(0.0f64, f64::max);
    let mut fom = Json::obj();
    fom.set("this_work_sqnr_fom", sim.sqnr_fom().map(Json::num).unwrap_or(Json::Null));
    fom.set("this_work_csnr_fom", sim.csnr_fom().map(Json::num).unwrap_or(Json::Null));
    fom.set(
        "sqnr_fom_ratio_vs_best_other (paper: 2.3x)",
        Json::num(sim.sqnr_fom().unwrap_or(0.0) / best_other_sqnr),
    );
    fom.set(
        "csnr_fom_ratio_vs_best_other (paper: 1.5x)",
        Json::num(sim.csnr_fom().unwrap_or(0.0) / best_other_csnr),
    );
    fom.set(
        "cifar10_accuracy (paper: 95.8 vs ideal 96.8)",
        Json::str("see examples/vit_inference + EXPERIMENTS.md"),
    );
    suite.note("fom_headlines", Json::Obj(fom));

    // --- microbenchmarks -------------------------------------------------------
    let e = EnergyModel::cr_cim(&params);
    suite.bench("energy model conversion breakdown", || {
        black_box(e.conversion(black_box(CbMode::On)));
    });
    suite.bench("supply sweep (6 points)", || {
        black_box(supply_sweep(&params, CbMode::Off, 6));
    });

    suite.finish();
}
