//! Design-choice ablations (DESIGN.md calls these out):
//!
//!   A. Majority-vote count (2/4/6/8/12 votes) — noise vs power: shows
//!      why the paper stops at 6 (diminishing σ return vs linear energy).
//!   B. How many trailing bits to vote (1..5) — the 3-bit choice is the
//!      knee of the noise/time curve.
//!   C. Comparator sigma sweep — CSNR and TOPS/W move oppositely; the
//!      CR-CIM swing advantage shifts the whole frontier.
//!   D. Row replication on/off — why small-K layers need the idle rows.

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::Column;
use cr_cim::coordinator::sac::kernel_noise_sigma;
use cr_cim::metrics::{characterize, CharacterizeOpts};
use cr_cim::util::bench::BenchSuite;
use cr_cim::util::json::Json;
use cr_cim::util::pool::default_threads;

fn mean_noise(params: &MacroParams, mode: CbMode, threads: usize) -> f64 {
    let col = Column::new(params, 0).unwrap();
    let opts = CharacterizeOpts { step: 16, trials: 48, threads, stream: 11 };
    characterize(&col, mode, &opts).mean_noise_lsb()
}

fn main() {
    let mut suite = BenchSuite::new("ablation - design choices");
    let threads = default_threads();
    let base = MacroParams::default();

    // --- A: vote count ---------------------------------------------------
    let mut votes_tbl = Json::obj();
    for votes in [2usize, 4, 6, 8, 12] {
        let mut p = base.clone();
        p.mv_votes = votes;
        let noise = mean_noise(&p, CbMode::On, threads);
        let comparisons = p.comparisons_per_conversion(CbMode::On);
        let mut o = Json::obj();
        o.set("mean_noise_lsb", Json::num(noise));
        o.set("comparisons", Json::num(comparisons as f64));
        o.set("rel_power_proxy", Json::num(comparisons as f64 / 10.0));
        votes_tbl.set(&format!("votes_{votes}"), Json::Obj(o));
    }
    suite.note("A_vote_count (paper picks 6)", Json::Obj(votes_tbl));

    // --- B: voted-bit count ------------------------------------------------
    let mut bits_tbl = Json::obj();
    for last in [1usize, 2, 3, 4, 5] {
        let mut p = base.clone();
        p.mv_last_bits = last;
        let noise = mean_noise(&p, CbMode::On, threads);
        let mut o = Json::obj();
        o.set("mean_noise_lsb", Json::num(noise));
        o.set("comparisons", Json::num(p.comparisons_per_conversion(CbMode::On) as f64));
        bits_tbl.set(&format!("mv_last_bits_{last}"), Json::Obj(o));
    }
    suite.note("B_voted_bits (paper picks 3)", Json::Obj(bits_tbl));

    // --- C: comparator sigma --------------------------------------------------
    let mut sig_tbl = Json::obj();
    for sigma in [0.55, 0.8, 1.1, 1.6, 2.2] {
        let mut p = base.clone();
        p.sigma_cmp_lsb = sigma;
        let noise_on = mean_noise(&p, CbMode::On, threads);
        let noise_off = mean_noise(&p, CbMode::Off, threads);
        // Noise-limited comparator: energy ∝ 1/σ².
        let e = cr_cim::cim::EnergyModel::cr_cim(&p);
        let rel_cmp_e = (base.sigma_cmp_lsb / sigma).powi(2);
        let mut o = Json::obj();
        o.set("noise_on_lsb", Json::num(noise_on));
        o.set("noise_off_lsb", Json::num(noise_off));
        o.set("rel_comparator_energy", Json::num(rel_cmp_e));
        o.set("tops_per_watt_off", Json::num(e.tops_per_watt(CbMode::Off)));
        sig_tbl.set(&format!("sigma_{sigma}"), Json::Obj(o));
    }
    suite.note("C_comparator_sigma", Json::Obj(sig_tbl));

    // --- D: row replication ------------------------------------------------
    let mut rep_tbl = Json::obj();
    for k in [96usize, 192, 384, 1024] {
        let with = kernel_noise_sigma(k, 6, 6, 0.55);
        let r = cr_cim::coordinator::sac::row_replication(k) as f64;
        let without = with * r;
        let mut o = Json::obj();
        o.set("replication", Json::num(r));
        o.set("sigma_with_replication", Json::num(with));
        o.set("sigma_without", Json::num(without));
        rep_tbl.set(&format!("k_{k}"), Json::Obj(o));
    }
    suite.note("D_row_replication (6b/6b, sigma_read 0.55)", Json::Obj(rep_tbl));

    suite.finish();
}
