//! Accuracy-vs-energy bench: runs the per-layer vote sweep over the
//! workload corpus and writes `target/bench-reports/BENCH_accuracy.json`
//! — the repo's stand-in for the paper's accuracy/power co-design
//! figure (CIFAR accuracy vs TOPS/W across operating points). The same
//! report is produced by `crcim sweep`; CI runs the `--smoke` sizing
//! and checks the schema (`scripts/check_bench_schema.sh`).

use cr_cim::coordinator::sweep::{run_sweep, SweepConfig};
use cr_cim::util::bench::{black_box, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("accuracy - vote sweep and co-design");
    let fast = std::env::var_os("CRCIM_BENCH_FAST").is_some();
    let cfg = if fast { SweepConfig::smoke() } else { SweepConfig::full() };

    // The sweep itself is the measured unit: corpus forward passes over
    // every vote point plus the co-design search.
    let report = run_sweep(&cfg).expect("sweep must run on the synthetic corpus");
    suite.bench("codesign search", || {
        black_box(cr_cim::coordinator::sweep::codesign_votes(
            &cr_cim::coordinator::sweep::rig_params(),
            &cr_cim::vit::graph::ModelGraph::encoder(
                &cfg.cfg,
                1,
                &cr_cim::coordinator::sweep::rig_plan(),
            ),
            &cfg.grid,
            cfg.mv_last_bits,
            6,
        ));
    });

    for p in &report.points {
        println!(
            "{:>12}: accuracy {:.3} | SQNR {:>5.1} dB | {:>9.1} pJ/inf",
            p.label, p.accuracy, p.sqnr_db, p.energy_pj
        );
    }
    println!(
        "codesign: {:.3}x uniform-6 energy at modeled noise {:.1} (budget {:.1})",
        report.codesign.energy_pj / report.codesign.uniform_energy_pj.max(1e-12),
        report.codesign.noise,
        report.codesign.budget
    );
    suite.note("accuracy_sweep", report.json.clone());

    let report_dir = std::path::Path::new("target/bench-reports");
    if std::fs::create_dir_all(report_dir).is_ok() {
        let path = report_dir.join("BENCH_accuracy.json");
        match std::fs::write(&path, report.json.to_string_pretty()) {
            Ok(()) => println!("[accuracy report written to {}]", path.display()),
            Err(e) => eprintln!("warn: failed to write {}: {e}", path.display()),
        }
    }
    suite.finish();
}
