//! Fig. 1(A): accuracy vs compute CSNR for CNN vs Transformer layers —
//! the motivation figure: Transformers need ~10+ dB more compute accuracy
//! than CNNs, and within a Transformer the MLP needs more than attention.
//!
//! Regenerates the accuracy-vs-CSNR series from the tolerance models
//! (calibrated against the ViT-through-macro runs; see EXPERIMENTS.md) and
//! times the underlying noisy-layer simulation primitive.

use cr_cim::cim::netstats::{LayerClass, ToleranceModel};
use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::Column;
use cr_cim::util::bench::{black_box, BenchSuite};
use cr_cim::util::json::Json;
use cr_cim::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("Fig 1(A) - accuracy vs CSNR requirement");

    // --- the figure's series -------------------------------------------------
    let classes = [
        LayerClass::CnnConv,
        LayerClass::TransformerAttention,
        LayerClass::TransformerMlp,
    ];
    let csnr_grid: Vec<f64> = (0..=40).map(|i| i as f64).collect();
    let mut series = Json::obj();
    for class in classes {
        let m = ToleranceModel::for_class(class);
        let accs: Vec<f64> = csnr_grid.iter().map(|&c| m.accuracy(c)).collect();
        let mut o = Json::obj();
        o.set("csnr_db", Json::arr_f64(&csnr_grid));
        o.set("accuracy", Json::arr_f64(&accs));
        o.set("required_csnr_1pt_drop_db", Json::num(m.required_csnr_db(0.01)));
        series.set(class.label(), Json::Obj(o));
    }
    suite.note("accuracy_vs_csnr", Json::Obj(series));

    // Headline deltas the paper's Fig. 1(A)/Fig. 4 quote.
    let cnn_req = ToleranceModel::for_class(LayerClass::CnnConv).required_csnr_db(0.01);
    let att_req =
        ToleranceModel::for_class(LayerClass::TransformerAttention).required_csnr_db(0.01);
    let mlp_req = ToleranceModel::for_class(LayerClass::TransformerMlp).required_csnr_db(0.01);
    let mut headline = Json::obj();
    headline.set("cnn_required_db", Json::num(cnn_req));
    headline.set("attention_required_db", Json::num(att_req));
    headline.set("mlp_required_db", Json::num(mlp_req));
    headline.set("transformer_minus_cnn_db", Json::num(mlp_req - cnn_req));
    headline.set("mlp_minus_attention_db (paper: ~10)", Json::num(mlp_req - att_req));
    suite.note("headline", Json::Obj(headline));

    // --- microbenchmark: the noisy-MAC primitive the sweep rests on ---------
    let params = MacroParams::default();
    let col = Column::new(&params, 0).unwrap();
    let mut rng = Rng::new(42);
    suite.bench_throughput("column read (CB off)", 1.0, || {
        black_box(col.read_count(black_box(512), CbMode::Off, &mut rng));
    });
    suite.bench_throughput("column read (CB on)", 1.0, || {
        black_box(col.read_count(black_box(512), CbMode::On, &mut rng));
    });

    suite.finish();
}
