//! Hot-path microbenchmarks for the §Perf optimization pass: the
//! simulator's conversion inner loop, the macro matvec, the scheduler,
//! and the serving-path bookkeeping. EXPERIMENTS.md §Perf records the
//! before/after of each optimization against these numbers.

use cr_cim::cim::capacitor::CapacitorBank;
use cr_cim::cim::comparator::Comparator;
use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::sar::SarAdc;
use cr_cim::cim::{CimMacro, Column};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::sac::evaluate_plan;
use cr_cim::coordinator::server::{Server, ServerConfig};
use cr_cim::coordinator::Scheduler;
use cr_cim::metrics::{characterize, CharacterizeOpts};
use cr_cim::util::bench::{black_box, BenchSuite};
use cr_cim::util::json::{self, Json};
use cr_cim::util::pool::default_threads;
use cr_cim::util::rng::Rng;
use cr_cim::vit::graph::{GraphConfig, ModelGraph};
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn main() {
    let mut suite = BenchSuite::new("hotpath - simulator and coordinator");
    let params = MacroParams::default();
    let threads = default_threads();

    // L3 sim primitive: single SAR conversion (the Monte-Carlo unit).
    let bank = CapacitorBank::sample(&params, 0);
    let cmp = Comparator::new(params.sigma_cmp_lsb, 0.1);
    let adc = SarAdc::new(&params, &bank, &cmp);
    let mut rng = Rng::new(1);
    suite.bench_throughput("sar conversion (CB off)", 1.0, || {
        black_box(adc.convert(black_box(0.497), CbMode::Off, &mut rng));
    });
    suite.bench_throughput("sar conversion (CB on)", 1.0, || {
        black_box(adc.convert(black_box(0.497), CbMode::On, &mut rng));
    });

    // Column read including compute phase + noise sampling.
    let col = Column::new(&params, 0).unwrap();
    suite.bench_throughput("column read_count", 1.0, || {
        black_box(col.read_count(black_box(700), CbMode::Off, &mut rng));
    });

    // Full characterization sweep (the fig5 workload), single vs multi.
    let opts1 = CharacterizeOpts { step: 16, trials: 16, threads: 1, stream: 0 };
    suite.bench("characterize (1 thread)", || {
        black_box(characterize(&col, CbMode::Off, &opts1));
    });
    let optsn = CharacterizeOpts { step: 16, trials: 16, threads, stream: 0 };
    suite.bench(&format!("characterize ({threads} threads)"), || {
        black_box(characterize(&col, CbMode::Off, &optsn));
    });

    // Macro-level multi-bit matvec (the hardware-accurate path).
    let mut tiny = MacroParams::default();
    tiny.adc_bits = 8;
    tiny.active_rows = 256;
    tiny.rows = 256;
    tiny.cols = 24;
    tiny.threads = 1;
    let mut m = CimMacro::new(&tiny).unwrap();
    let mut wrng = Rng::new(2);
    let w: Vec<Vec<i32>> = (0..256)
        .map(|_| (0..6).map(|_| wrng.below(15) as i32 - 7).collect())
        .collect();
    m.load_weights(&w, 4).unwrap();
    let x: Vec<i32> = (0..256).map(|_| wrng.below(15) as i32 - 7).collect();
    suite.bench_throughput("macro matvec 256x6 @4b (ops)", (2 * 256 * 6) as f64, || {
        black_box(m.matvec(black_box(&x), 4, CbMode::Off).unwrap());
    });

    // Column-parallel engine: serial vs parallel matvec on a full-scale
    // tile (1088×78 die, 13 outputs × 6b planes, 1024 active rows) — the
    // §Perf headline for this pass. Determinism contract: the parallel
    // run produces bit-identical outputs to the serial one.
    let full = MacroParams::default();
    let w_full: Vec<Vec<i32>> = (0..1024)
        .map(|_| (0..13).map(|_| wrng.below(63) as i32 - 31).collect())
        .collect();
    let x_full: Vec<i32> = (0..1024).map(|_| wrng.below(63) as i32 - 31).collect();
    let ops_full = (2 * 1024 * 13 * 6 * 6) as f64; // 1b-normalized
    let mut m_ser = CimMacro::new(&full.clone().with_threads(1)).unwrap();
    m_ser.load_weights(&w_full, 6).unwrap();
    let serial_ns = suite
        .bench_throughput("macro matvec 1024x13 @6b serial (1b ops)", ops_full, || {
            black_box(m_ser.matvec(black_box(&x_full), 6, CbMode::Off).unwrap());
        })
        .median_ns();
    let mut m_par = CimMacro::new(&full.clone().with_threads(threads)).unwrap();
    m_par.load_weights(&w_full, 6).unwrap();
    let par_ns = suite
        .bench_throughput(
            &format!("macro matvec 1024x13 @6b {threads}T (1b ops)"),
            ops_full,
            || {
                black_box(m_par.matvec(black_box(&x_full), 6, CbMode::Off).unwrap());
            },
        )
        .median_ns();
    let xs_batch: Vec<Vec<i32>> = (0..16)
        .map(|_| (0..1024).map(|_| wrng.below(63) as i32 - 31).collect())
        .collect();
    suite.bench_throughput(
        &format!("macro matvec_batch 16 vecs {threads}T (1b ops)"),
        ops_full * 16.0,
        || {
            black_box(m_par.matvec_batch(black_box(&xs_batch), 6, CbMode::Off).unwrap());
        },
    );
    suite.note("matvec_parallel_speedup", Json::num(serial_ns / par_ns.max(1e-9)));
    println!(
        "matvec parallel speedup at {threads} threads: {:.2}x",
        serial_ns / par_ns.max(1e-9)
    );

    // Coordinator: plan evaluation over ViT-small.
    let sched = Scheduler::new(&params);
    let cfg = VitConfig::vit_small();
    suite.bench("evaluate_plan ViT-small", || {
        black_box(evaluate_plan(&sched, &cfg, 1, &PrecisionPlan::paper_sac()));
    });

    // Model-graph pipeline plan: ViT-Base batch 8, serial vs
    // double-buffered weight reloads. The comparison is written to
    // target/bench-reports/BENCH_pipeline.json so the full-pass latency
    // trajectory is tracked from this PR on.
    let vitb = VitConfig::vit_base();
    let graph8 = ModelGraph::encoder(&vitb, 8, &PrecisionPlan::paper_sac());
    let topo = Scheduler::with_topology(&params, 4, 2);
    suite.bench("plan_graph ViT-Base b8 (48 layers)", || {
        black_box(topo.plan_graph(black_box(&graph8)));
    });
    let pp = topo.plan_graph(&graph8);
    // Cold vs warm full-pass latency: the default deployment (one array
    // of weight SRAM per macro) cannot hold ViT-Base resident, so its
    // warm pass equals the cold pass; a banked-SRAM deployment keeps the
    // whole model resident and its warm pass is conversion-bound.
    let resident_sram_bits: u64 = 1 << 26;
    let banked = Scheduler::with_topology(
        &params.clone().with_sram_bits(resident_sram_bits),
        topo.shards,
        topo.dies,
    );
    let wp = banked.plan_graph(&graph8);
    // Streaming occupancy model: one conversion wave of the same total
    // token stream (8 images × 197 tokens) on the banked deployment —
    // planned die utilization (wave occupancy) and the saturation-model
    // token latency tail, comparable against the fixed-batch numbers.
    let wave_tokens = graph8.layers[0].shape.m;
    suite.bench("plan_stream ViT-Base wave (48 layers)", || {
        black_box(banked.plan_stream(black_box(&graph8), wave_tokens));
    });
    let sp = banked.plan_stream(&graph8, wave_tokens);
    // Measured (wall-clock) pass through the staged wavefront engine:
    // the same ViT-Base graph probed at 1b so a full 48-layer
    // program+convert pass stays bench-sized, executed with overlap off
    // (every task inline, in wave order) and on (program/convert tasks
    // stolen off the work queue by a worker pool). Cold pass each time,
    // on a fresh executor; best of two runs per setting. This is the
    // acceptance number behind `pipeline_speedup`: the overlapped
    // engine must beat its own serial schedule on real silicon time,
    // not just in the planner's model.
    let probe = OperatingPoint::new(1, 1, CbMode::Off);
    let probe_plan = PrecisionPlan { name: "bench probe", attention: probe, mlp: probe };
    let graph1b = ModelGraph::encoder(&vitb, 8, &probe_plan);
    let exec_params = params.clone().with_sram_bits(resident_sram_bits).with_threads(threads);
    let imgs: Vec<Vec<f32>> = (0..8)
        .map(|i| (0..32).map(|j| ((i * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect())
        .collect();
    let cold_pass_wall_ns = |overlap: bool| -> f64 {
        let cfg = PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap };
        let mut exec = ModelExecutor::new(&exec_params, graph1b.clone(), cfg).unwrap();
        let xs = exec.featurize_images(&imgs);
        let t0 = std::time::Instant::now();
        black_box(exec.forward_ints(&xs).unwrap());
        t0.elapsed().as_nanos() as f64
    };
    let serial_wall_ns = (0..2).map(|_| cold_pass_wall_ns(false)).fold(f64::MAX, f64::min);
    let overlapped_wall_ns = (0..2).map(|_| cold_pass_wall_ns(true)).fold(f64::MAX, f64::min);
    let pipeline_speedup = serial_wall_ns / overlapped_wall_ns.max(1.0);
    let mut pipe = Json::obj();
    pipe.set("model", Json::str("vit-base"));
    pipe.set("batch", Json::num(8.0));
    pipe.set("layers", Json::num(pp.layers.len() as f64));
    pipe.set("shards", Json::num(topo.shards as f64));
    pipe.set("dies", Json::num(topo.dies as f64));
    pipe.set("serial_reload_latency_us", Json::num(pp.serial_ns * 1e-3));
    pipe.set("pipelined_reload_latency_us", Json::num(pp.pipelined_ns * 1e-3));
    pipe.set("overlap_saving_frac", Json::num(pp.overlap_saving()));
    pipe.set("cold_pass_latency_us", Json::num(pp.pipelined_ns * 1e-3));
    pipe.set("warm_pass_latency_us", Json::num(wp.warm_pipelined_ns * 1e-3));
    pipe.set("warm_resident_layers", Json::num(wp.resident_layers() as f64));
    pipe.set("warm_saving_frac", Json::num(wp.residency_saving()));
    pipe.set("resident_sram_bits_per_macro", Json::num(resident_sram_bits as f64));
    pipe.set("stream_wave_tokens", Json::num(sp.wave_tokens as f64));
    pipe.set("stream_wave_latency_us", Json::num(sp.warm_wave_ns * 1e-3));
    pipe.set("stream_tokens_per_s", Json::num(sp.tokens_per_s));
    pipe.set("stream_wave_occupancy", Json::num(sp.die_utilization));
    pipe.set("stream_token_latency_p50_us", Json::num(sp.p50_token_latency_ns * 1e-3));
    pipe.set("stream_token_latency_p99_us", Json::num(sp.p99_token_latency_ns * 1e-3));
    // Autoregressive decode pricing on the banked deployment: one
    // sequence's prefill pass vs the steady-state decode step with 4
    // live sequences, plus the KV residency replay over the canonical
    // serving trace (`Scheduler::plan_decode`). The KV budget reuses
    // the resident-SRAM figure so hit rate reflects the same silicon.
    let dec_graph = ModelGraph::decoder(
        &GraphConfig { vit: vitb, context: GraphConfig::decoder_base().context },
        &PrecisionPlan::paper_sac(),
    );
    let dp = banked.plan_decode(&dec_graph, 4, 32, 32, resident_sram_bits);
    suite.bench("plan_decode ViT-Base decoder (48 layers)", || {
        black_box(banked.plan_decode(black_box(&dec_graph), 4, 32, 32, resident_sram_bits));
    });
    pipe.set("prefill_pass_us", Json::num(dp.prefill_pass_ns * 1e-3));
    pipe.set("decode_step_us", Json::num(dp.decode_step_ns * 1e-3));
    pipe.set("decode_tokens_per_s", Json::num(dp.decode_tokens_per_s));
    pipe.set("kv_hit_rate", Json::num(dp.kv_hit_rate));
    println!(
        "decoder live=4 prompt=32: prefill {:.1} µs, decode step {:.2} µs, {:.3e} tok/s, kv hit {:.2}",
        dp.prefill_pass_ns * 1e-3,
        dp.decode_step_ns * 1e-3,
        dp.decode_tokens_per_s,
        dp.kv_hit_rate
    );
    pipe.set("serial_pass_us", Json::num(serial_wall_ns * 1e-3));
    pipe.set("overlapped_pass_us", Json::num(overlapped_wall_ns * 1e-3));
    pipe.set("pipeline_speedup", Json::num(pipeline_speedup));
    println!(
        "vit-base b8 @1b measured cold pass: serial {:.1} µs, overlapped {:.1} µs ({:.2}x)",
        serial_wall_ns * 1e-3,
        overlapped_wall_ns * 1e-3,
        pipeline_speedup
    );
    println!(
        "vit-base stream wave ({} tokens): {:.1} µs, occupancy {:.2}, p99 token {:.1} µs",
        sp.wave_tokens,
        sp.warm_wave_ns * 1e-3,
        sp.die_utilization,
        sp.p99_token_latency_ns * 1e-3
    );
    println!(
        "vit-base b8 full pass: cold {:.1} µs, warm/resident {:.1} µs ({:.2}% saved)",
        pp.pipelined_ns * 1e-3,
        wp.warm_pipelined_ns * 1e-3,
        wp.residency_saving() * 100.0
    );
    // Saturation curve: the event-driven serving tier (admission,
    // wave formation, completion staging — the exact code path the
    // reactor drives) swept across offered loads, measured in *modeled*
    // silicon time. Arrivals are scheduled on a modeled clock that
    // advances by the engine's `last_pass_ns` per executed wave, so the
    // curve is a property of the admission policy and the staged
    // wavefront model, not of host wall-clock jitter — and therefore
    // anchorable against `Scheduler::plan_stream`.
    //
    // Anchor construction: the engine prices every wave at the
    // construction-time plan's per-layer `compute_ns` (a warm wave's
    // staged fold is exactly the plan's warm fold), so the server is
    // run with `max_waves: 1` — the plan's saturation model is one
    // wave in flight; letting the staged engine overlap two waves
    // would double the measured modeled rate against a one-wave plan.
    // Only *full* warm waves enter the anchor numerator/denominator
    // (partial drain-tail waves deliver fewer tokens at the same
    // modeled cost). The documented acceptance tolerance on
    // `saturation_anchor_rel_err` is 15% (docs/ARCHITECTURE.md); the
    // expected value is ~0 since both sides reduce to the same
    // conversion sum on a fully resident deployment.
    use std::time::Duration;
    let sat_wave_imgs = 2usize;
    let graph_w = ModelGraph::encoder(&vitb, sat_wave_imgs, &probe_plan);
    let wave_m = graph_w.layers[0].shape.m;
    let seq_per_img = (wave_m / sat_wave_imgs).max(1);
    let sat_sched = Scheduler::with_topology(&exec_params, 4, 2);
    let sat_plan = sat_sched.plan_stream(&graph_w, wave_m);
    let fast = std::env::var("CRCIM_BENCH_FAST").ok().as_deref() == Some("1");
    let offered_factors: &[f64] = if fast { &[0.5, 1.5, 4.0] } else { &[0.5, 0.9, 1.5, 4.0] };
    let point_imgs: usize = if fast { 8 } else { 16 };
    let sat_cfg = || ServerConfig {
        batch_sizes: vec![1],
        max_wait: Duration::ZERO,
        wave_tokens: sat_wave_imgs,
        max_waves: 1,
        max_inflight: 64,
        queue_depth: 4 * sat_wave_imgs,
        drain_timeout: Duration::from_secs(1),
        ..ServerConfig::default()
    };
    let stream_line = |id: usize| {
        let img = &imgs[id % imgs.len()];
        format!(
            "{{\"id\": {id}, \"kind\": \"stream\", \"tokens\": 1, \"image\": {}}}",
            Json::arr_f64(&img.iter().map(|&x| x as f64).collect::<Vec<_>>())
        )
    };
    let sat_pipe_cfg = PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap: true };
    let mut sat_exec = ModelExecutor::new(&exec_params, graph_w, sat_pipe_cfg).unwrap();
    // One throwaway wave programs every layer so the measured sweep is
    // all warm passes (the plan's saturation model is the warm steady
    // state; the banked deployment keeps the 1b graph fully resident).
    {
        let warm = Server::new(&sat_cfg()).unwrap();
        let c = warm.open_conn();
        warm.handle_line(&stream_line(0), c).unwrap();
        warm.executor_step(&mut sat_exec);
    }
    let mut curve: Vec<Json> = Vec::new();
    let mut anchor_tokens = 0.0f64;
    let mut anchor_busy_ns = 0.0f64;
    for &f in offered_factors {
        let srv = Server::new(&sat_cfg()).unwrap();
        let conn = srv.open_conn();
        // Offered load f: images arrive at f × the planned saturation
        // rate, uniformly spaced on the modeled clock.
        let rate_imgs_per_ns = f * sat_wave_imgs as f64 / sat_plan.warm_wave_ns;
        let mut model_ns = 0.0f64;
        let mut injected = 0usize;
        let mut sheds = 0usize;
        let mut done = 0usize;
        let mut arrivals: std::collections::BTreeMap<i64, f64> = std::collections::BTreeMap::new();
        let mut lats_ns: Vec<f64> = Vec::new();
        while done + sheds < point_imgs {
            // Release every arrival due at the current modeled instant;
            // if the tier is idle with arrivals still to come, jump the
            // clock to the next one.
            loop {
                if injected >= point_imgs {
                    break;
                }
                let due_ns = injected as f64 / rate_imgs_per_ns;
                if due_ns <= model_ns {
                    match srv.handle_line(&stream_line(injected), conn).unwrap() {
                        Some(_) => sheds += 1,
                        None => {
                            arrivals.insert(injected as i64, model_ns);
                        }
                    }
                    injected += 1;
                } else if injected == sheds + done {
                    model_ns = due_ns;
                } else {
                    break;
                }
            }
            let queued = injected - sheds - done;
            if queued == 0 {
                continue;
            }
            srv.executor_step(&mut sat_exec);
            let pass_ns = sat_exec.last_pass_ns();
            if queued >= sat_wave_imgs {
                anchor_tokens += sat_wave_imgs as f64;
                anchor_busy_ns += pass_ns;
            }
            model_ns += pass_ns;
            for line in srv.take_responses(conn) {
                let j = json::parse(&line).unwrap();
                if j.get_path("pred").is_some() || j.get_path("error").is_some() {
                    done += 1;
                    let id = j.get_path("id").and_then(|v| v.as_f64()).unwrap_or(-1.0);
                    if let Some(t0) = arrivals.remove(&(id as i64)) {
                        lats_ns.push(model_ns - t0);
                    }
                }
            }
        }
        lats_ns.sort_by(f64::total_cmp);
        let pct = |q: f64| -> f64 {
            if lats_ns.is_empty() {
                return 0.0;
            }
            let idx = ((lats_ns.len() as f64 - 1.0) * q).round() as usize;
            lats_ns[idx.min(lats_ns.len() - 1)]
        };
        // Shed accounting comes from the ledger (the contract clients
        // see over `stats`), cross-checked against the synchronous shed
        // lines counted above.
        let ledger_sheds = srv
            .ledger_json()
            .get_path("shed_requests")
            .and_then(|v| v.as_f64())
            .unwrap_or(sheds as f64);
        let served_tps = done as f64 * seq_per_img as f64 / model_ns.max(1e-9) * 1e9;
        let shed_rate = ledger_sheds / injected.max(1) as f64;
        let mut pt = Json::obj();
        pt.set("offered_factor", Json::num(f));
        pt.set("offered_tokens_per_s", Json::num(f * sat_plan.tokens_per_s));
        pt.set("tokens_per_s", Json::num(served_tps));
        pt.set("p50_us", Json::num(pct(0.50) * 1e-3));
        pt.set("p99_us", Json::num(pct(0.99) * 1e-3));
        pt.set("shed_rate", Json::num(shed_rate));
        curve.push(Json::Obj(pt));
        println!(
            "saturation f={f:.2}: {served_tps:.3e} tok/s, p50 {:.1} us, p99 {:.1} us, shed {:.0}%",
            pct(0.50) * 1e-3,
            pct(0.99) * 1e-3,
            shed_rate * 100.0
        );
    }
    let saturated_tps = anchor_tokens * seq_per_img as f64 / anchor_busy_ns.max(1e-9) * 1e9;
    let anchor_rel_err = (saturated_tps - sat_plan.tokens_per_s).abs() / sat_plan.tokens_per_s;
    pipe.set("saturation_curve", Json::arr(curve));
    pipe.set("saturation_wave_tokens", Json::num(wave_m as f64));
    pipe.set("saturated_tokens_per_s_modeled", Json::num(saturated_tps));
    pipe.set("plan_stream_tokens_per_s", Json::num(sat_plan.tokens_per_s));
    pipe.set("saturation_anchor_rel_err", Json::num(anchor_rel_err));
    println!(
        "saturation anchor: measured {saturated_tps:.3e} vs plan {:.3e} tok/s (rel err {:.2e})",
        sat_plan.tokens_per_s, anchor_rel_err
    );

    let pipe = Json::Obj(pipe);
    suite.note("pipeline_reload_overlap", pipe.clone());
    let report_dir = std::path::Path::new("target/bench-reports");
    if std::fs::create_dir_all(report_dir).is_ok() {
        let path = report_dir.join("BENCH_pipeline.json");
        match std::fs::write(&path, pipe.to_string_pretty()) {
            Ok(()) => println!("[pipeline report written to {}]", path.display()),
            Err(e) => eprintln!("warn: failed to write {}: {e}", path.display()),
        }
    }

    suite.finish();
}
