//! Fig. 1(B): why conventional charge CIMs cannot afford a 10-bit ADC —
//! per-column ADC area and comparator energy vs resolution, conventional
//! (separate C-DAC, attenuated swing) vs CR-CIM (reconfigured bank, full
//! swing).
//!
//! Shape to reproduce: conventional cost explodes ~2^B while CR-CIM stays
//! flat in area and pays 4× less comparator energy at equal accuracy.

use cr_cim::cim::area::AreaModel;
use cr_cim::cim::comparator::comparator_energy_pj;
use cr_cim::cim::energy::EnergyModel;
use cr_cim::cim::params::MacroParams;
use cr_cim::util::bench::{black_box, BenchSuite};
use cr_cim::util::json::Json;

fn main() {
    let mut suite = BenchSuite::new("Fig 1(B) - ADC scaling: conventional vs CR-CIM");
    let area = AreaModel::default();
    let params = MacroParams::default();

    // --- area vs ADC bits ----------------------------------------------------
    let series = area.fig1b_series(4..=12);
    let mut a = Json::obj();
    a.set("bits", Json::arr_f64(&series.iter().map(|s| s.0 as f64).collect::<Vec<_>>()));
    a.set(
        "conventional_area_norm",
        Json::arr_f64(&series.iter().map(|s| s.1).collect::<Vec<_>>()),
    );
    a.set("cr_cim_area_norm", Json::arr_f64(&series.iter().map(|s| s.2).collect::<Vec<_>>()));
    let ten = series.iter().find(|s| s.0 == 10).unwrap();
    a.set("area_gap_at_10b", Json::num(ten.1 / ten.2));
    suite.note("adc_area_vs_bits (normalized to 4b conventional)", Json::Obj(a));

    // --- comparator energy vs ADC bits at equal conversion accuracy ---------
    // σ requirement halves per extra bit; conventional pays a further 2×
    // tighter σ (half swing) ⇒ 4× energy at every resolution.
    let mut e = Json::obj();
    let bits: Vec<f64> = (4..=12).map(|b| b as f64).collect();
    let energy_at = |b: f64, swing: f64| {
        let sigma_ref = 1.0; // LSB at 10b reference
        let sigma_v = sigma_ref * 2f64.powf(10.0 - b) * swing;
        comparator_energy_pj(params.e_cmp_pj, sigma_ref, 0.6, sigma_v, 0.6) * b
    };
    e.set("bits", Json::arr_f64(&bits));
    e.set(
        "conventional_energy_pj",
        Json::arr_f64(&bits.iter().map(|&b| energy_at(b, 0.5)).collect::<Vec<_>>()),
    );
    e.set(
        "cr_cim_energy_pj",
        Json::arr_f64(&bits.iter().map(|&b| energy_at(b, 1.0)).collect::<Vec<_>>()),
    );
    suite.note("comparator_energy_vs_bits (per conversion)", Json::Obj(e));

    // Headline: the 4× comparator-energy saving at 10 bits.
    let cr = EnergyModel::cr_cim(&params);
    let conv = EnergyModel::conventional(&params);
    let mut h = Json::obj();
    h.set(
        "comparator_energy_ratio_conventional_over_crcim (paper: 4x)",
        Json::num(conv.comparator_energy_per_firing_pj() / cr.comparator_energy_per_firing_pj()),
    );
    h.set("area_gap_at_10b_x", Json::num(ten.1 / ten.2));
    suite.note("headline", Json::Obj(h));

    // --- microbenchmark ------------------------------------------------------
    suite.bench("area model full sweep", || {
        black_box(area.fig1b_series(4..=12));
    });

    suite.finish();
}
