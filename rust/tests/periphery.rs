//! Accuracy-tier integration tests for the deterministic digital
//! periphery (`coordinator::periphery`) and the per-layer vote points:
//!
//! - golden vectors per kernel — exact integer outputs pinned, plus the
//!   documented ULP bands against the f64 references;
//! - thread/shard determinism — kernels and glue are pure integer maps,
//!   byte-identical from any thread, and the zero-noise executor equals
//!   the exact reference walk across shard/thread configurations;
//! - planner/executor energy agreement — a heterogeneous per-layer vote
//!   assignment is priced by `Scheduler::plan_linear` exactly as the
//!   executor's bank counters measure it, per vote point.

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::periphery::{
    gelu_ref, glue, iexp_q, iexp_ref, igelu_q, int_layernorm, int_softmax, isqrt, layernorm_ref,
    softmax_ref, ONE_Q,
};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::scheduler::Scheduler;
use cr_cim::coordinator::sweep::{planned_energy_pj, rig_params, rig_plan, set_votes, SweepConfig};
use cr_cim::util::stats::sum_ordered;
use cr_cim::vit::graph::{LayerRole, ModelGraph};
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

// ---------------------------------------------------------------- golden

#[test]
fn iexp_golden_vectors_and_ulp_band() {
    // Exact pinned outputs (any change to constants or rounding shows
    // up here first, not as a downstream serving diff).
    for (z, want) in [
        (0i64, 65_557i64),
        (-ONE_Q, 24_129),
        (-2 * ONE_Q, 8_846),
        (-5 * ONE_Q, 442),
        (-8 * ONE_Q, 21),
        (-15 * ONE_Q, 0),
        (-ONE_Q / 2, 39_640),
        (-3 * ONE_Q / 4, 31_009),
    ] {
        assert_eq!(iexp_q(z), want, "iexp_q({z})");
    }
    // Documented band: ≤ 262 Q16 ULP vs the true exponential.
    for i in 0..=3200 {
        let z = -(i * ONE_Q) / 200; // [-16, 0] in half-percent steps
        let want = (iexp_ref(z as f64 / ONE_Q as f64) * ONE_Q as f64).round() as i64;
        assert!(
            (iexp_q(z) - want).abs() <= 262,
            "z={z}: {} vs {want}",
            iexp_q(z)
        );
    }
}

#[test]
fn softmax_golden_vector_and_ulp_band() {
    let x: Vec<i64> = vec![-1200, 3400, 0, 911, -77, 2600, 15];
    assert_eq!(int_softmax(&x), vec![17, 51_566, 140, 685, 122, 12_859, 143]);
    // ≤ 328 Q16 ULP per probability vs the f64 softmax at the same
    // integer scale.
    for (pi, ri) in int_softmax(&x).iter().zip(softmax_ref(&x)) {
        let want = (ri * ONE_Q as f64).round() as i64;
        assert!((pi - want).abs() <= 328, "{pi} vs {want}");
    }
}

#[test]
fn layernorm_golden_vector_and_band() {
    let x: Vec<i64> = vec![900, -150, 42, -2044, 512, 7, -333, 1200];
    assert_eq!(
        int_layernorm(&x),
        vec![62_766, -11_786, 1_846, -146_266, 35_217, -639, -24_780, 84_067]
    );
    // Band: |Δz| ≤ (1 + |z_ref|)/σ + 4·2⁻¹⁶ (floored mean + floored σ).
    let n = x.len() as f64;
    let mean = sum_ordered(x.iter().map(|&v| v as f64)) / n;
    let sigma =
        (sum_ordered(x.iter().map(|&v| (v as f64 - mean).powi(2))) / n).sqrt();
    for (zi, ri) in int_layernorm(&x).iter().zip(layernorm_ref(&x)) {
        let got = *zi as f64 / ONE_Q as f64;
        let band = (1.0 + ri.abs()) / sigma + 4.0 / ONE_Q as f64;
        assert!((got - ri).abs() <= band, "got {got} want {ri} band {band}");
    }
}

#[test]
fn gelu_golden_vectors_and_band() {
    for (z, want) in [
        (ONE_Q, 55_424i64),
        (-ONE_Q, -10_112),
        (2 * ONE_Q, 126_864),
        (-2 * ONE_Q, -4_208),
        (ONE_Q / 2, 22_945),
        (-ONE_Q / 2, -9_823),
        (4 * ONE_Q, 261_856),
        (-4 * ONE_Q, -288),
    ] {
        assert_eq!(igelu_q(z), want, "igelu_q({z})");
    }
    for i in -800..=800 {
        let z = (i * ONE_Q) / 200; // [-4, 4]
        let got = igelu_q(z) as f64 / ONE_Q as f64;
        let want = gelu_ref(z as f64 / ONE_Q as f64);
        assert!((got - want).abs() <= 0.02, "z={z}: {got} vs {want}");
    }
}

#[test]
fn glue_golden_vectors() {
    let y: Vec<i64> = vec![120, -3400, 77, 0, 55_000, -9, 1234];
    assert_eq!(glue(LayerRole::Qkv, &y, 9, 6), vec![0, 0, 0, 0, 30, 0, 0, 0, 0]);
    assert_eq!(glue(LayerRole::Fc1, &y, 9, 6), vec![0, 0, 0, 0, 30, 0, 0, 0, 0]);
    let ln = vec![-2, -4, -2, -3, 18, -3, -2, -2, -4];
    assert_eq!(glue(LayerRole::AttnProj, &y, 9, 6), ln);
    assert_eq!(glue(LayerRole::Fc2, &y, 9, 6), ln);
}

#[test]
fn isqrt_floor_holds_on_probe_points() {
    for &v in &[0i64, 1, 2, 3, 4, 99, 10_000, (1 << 40) + 17, i64::MAX] {
        let r = isqrt(v);
        assert!(r as i128 * r as i128 <= v as i128);
        assert!((r as i128 + 1) * (r as i128 + 1) > v as i128);
    }
}

// --------------------------------------------------------- determinism

#[test]
fn kernels_are_byte_identical_across_threads() {
    let y: Vec<i64> = (0..96i64).map(|i| (i * 9973) % 7001 - 3500).collect();
    let golden = (
        int_softmax(&y),
        int_layernorm(&y),
        y.iter().map(|&v| igelu_q(v)).collect::<Vec<i64>>(),
        glue(LayerRole::Qkv, &y, 48, 4),
        glue(LayerRole::Fc1, &y, 48, 4),
        glue(LayerRole::Fc2, &y, 48, 4),
    );
    let results: Vec<_> = (0..8)
        .map(|_| {
            let y = y.clone();
            std::thread::spawn(move || {
                (
                    int_softmax(&y),
                    int_layernorm(&y),
                    y.iter().map(|&v| igelu_q(v)).collect::<Vec<i64>>(),
                    glue(LayerRole::Qkv, &y, 48, 4),
                    glue(LayerRole::Fc1, &y, 48, 4),
                    glue(LayerRole::Fc2, &y, 48, 4),
                )
            })
        })
        .collect();
    for h in results {
        assert_eq!(h.join().unwrap(), golden, "periphery must not depend on the thread");
    }
}

fn quiet_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

/// d_ff = 96 > 64 active rows: fc2 row-tiles even on the tiny geometry.
fn tiny_cfg() -> VitConfig {
    VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
}

fn images(n: usize, floats: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..floats).map(|j| (((i + 3) * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
        })
        .collect()
}

#[test]
fn zero_noise_serving_equals_reference_across_shards_threads_and_votes() {
    let base = quiet_params();
    let op = OperatingPoint::new(2, 2, CbMode::On);
    let plan = PrecisionPlan { name: "periphery probe", attention: op, mlp: op };
    let graph = ModelGraph::encoder(&tiny_cfg(), 2, &plan);
    let imgs = images(3, 32);
    let reference = {
        let exec = ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_ints(&exec.featurize_images(&imgs))
    };
    // Periphery outputs are non-trivial: some activation past layer 0
    // must be nonzero or the glue collapsed the signal.
    assert!(reference.iter().any(|r| r.iter().any(|&v| v != 0)));
    let votes: Vec<u32> = (0..graph.layer_count()).map(|i| [1u32, 6, 12][i % 3]).collect();
    for threads in [1usize, 4] {
        for shards in [1usize, 2] {
            for per_layer_votes in [false, true] {
                let mut g = graph.clone();
                if per_layer_votes {
                    set_votes(&mut g, &votes, 3);
                }
                let p = base.clone().with_threads(threads);
                let cfg = PipelineConfig {
                    shards,
                    attention_dies: 2,
                    mlp_dies: 1,
                    overlap: per_layer_votes,
                };
                let mut exec = ModelExecutor::new(&p, g, cfg).unwrap();
                let xs = exec.featurize_images(&imgs);
                let got = exec.forward_ints(&xs).unwrap();
                assert_eq!(
                    got, reference,
                    "threads {threads}, shards {shards}, votes {per_layer_votes}"
                );
            }
        }
    }
}

// ------------------------------------------------- planner == executor

#[test]
fn heterogeneous_vote_energy_is_priced_exactly_as_measured() {
    let params = rig_params();
    let mut graph = ModelGraph::encoder(&SweepConfig::full().cfg, 1, &rig_plan());
    // A deliberately lopsided assignment: every grid step appears.
    let votes: Vec<u32> =
        (0..graph.layer_count()).map(|i| [1u32, 2, 3, 6, 8, 12][i % 6]).collect();
    set_votes(&mut graph, &votes, 3);
    let sched = Scheduler::with_topology(&params, 1, 1);
    let imgs = images(4, 32);
    let mut exec = ModelExecutor::new(&params, graph.clone(), PipelineConfig::default()).unwrap();
    let xs = exec.featurize_images(&imgs);
    exec.forward_ints(&xs).unwrap();
    let measured = sum_ordered(exec.layer_costs().iter().map(|c| c.energy_pj));
    let planned = planned_energy_pj(&sched, &graph, xs.len());
    let rel = (measured - planned).abs() / planned.max(1e-12);
    assert!(rel < 1e-9, "measured {measured} pJ != planned {planned} pJ (rel {rel:.2e})");
    // And the ledger reports the effective per-layer vote point.
    for (c, &v) in exec.layer_costs().iter().zip(&votes) {
        assert_eq!(c.mv_votes, v as u64, "{}", c.name);
        assert_eq!(c.mv_last_bits, 3, "{}", c.name);
    }
}
