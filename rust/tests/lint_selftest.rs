//! `crcim lint` acceptance: the analyzer runs clean over this repo's
//! own sources, and actually fails when a violation is planted.
//!
//! The clean run is the load-bearing half: it is what keeps the
//! determinism contract enforced on every future change, because any
//! new unordered map, ad-hoc RNG, stray wall-clock read, lock-order
//! inversion, or raw float reduction in the compute tiers turns this
//! test (and the CI lint job) red.

use std::path::Path;

use cr_cim::analysis;

#[test]
fn lint_runs_clean_on_the_full_source_tree() {
    // cargo runs integration tests from the workspace root.
    let report = analysis::run_path(Path::new("rust/src")).expect("source tree is readable");
    assert!(
        report.is_clean(),
        "determinism lint must pass on the shipped tree:\n{}",
        report.to_text()
    );
    assert!(
        report.files_scanned > 40,
        "the walk should cover the whole crate, saw {} files",
        report.files_scanned
    );
}

#[test]
fn lint_fails_on_an_injected_violation() {
    // Plant a compute-scope file with an unordered map in a scratch tree
    // shaped like the real one (rule scope keys off the `cim/` prefix).
    let root = std::env::temp_dir().join(format!("detlint_selftest_{}", std::process::id()));
    let dir = root.join("cim");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("bad.rs"),
        "use std::collections::HashMap;\npub fn f() -> HashMap<u32, u32> { HashMap::new() }\n",
    )
    .unwrap();
    let report = analysis::run_path(&root).expect("scratch tree is readable");
    std::fs::remove_dir_all(&root).ok();
    assert!(!report.is_clean(), "planted HashMap must be flagged");
    assert!(
        report.findings.iter().any(|f| f.rule == "unordered-iter" && f.path == "cim/bad.rs"),
        "expected an unordered-iter finding, got:\n{}",
        report.to_text()
    );
}

#[test]
fn lint_respects_a_justified_allow_in_the_scratch_tree() {
    let root = std::env::temp_dir().join(format!("detlint_allow_{}", std::process::id()));
    let dir = root.join("cim");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("annotated.rs"),
        "// detlint: allow(unordered-iter) -- scratch fixture, order never observed\n\
         use std::collections::HashMap;\n\
         pub fn f() -> usize { HashMap::<u32, u32>::new().len() }\n",
    )
    .unwrap();
    let report = analysis::run_path(&root).expect("scratch tree is readable");
    std::fs::remove_dir_all(&root).ok();
    // The annotation suppresses the next line's finding but not the
    // second, unannotated HashMap use two lines below.
    assert!(
        report.findings.iter().all(|f| f.line != 2),
        "annotated line must be suppressed:\n{}",
        report.to_text()
    );
    assert!(
        report.findings.iter().any(|f| f.rule == "unordered-iter" && f.line == 3),
        "unannotated use must still fire:\n{}",
        report.to_text()
    );
}
