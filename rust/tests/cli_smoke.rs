//! CLI smoke tests: every `crcim` subcommand runs and prints the shape
//! of output its docs promise. Artifact-dependent commands are skipped
//! when `make artifacts` hasn't run.

use std::process::Command;

fn crcim(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_crcim"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn crcim");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn no_args_prints_usage() {
    let (ok, text) = crcim(&[]);
    assert!(!ok);
    assert!(text.contains("usage"), "{text}");
}

#[test]
fn unknown_command_fails() {
    let (ok, text) = crcim(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
}

#[test]
fn help_flags_work() {
    for cmd in ["characterize", "plan", "serve", "infer"] {
        let (ok, text) = crcim(&[cmd, "--help"]);
        assert!(ok, "{cmd} --help failed: {text}");
        assert!(text.contains("Options"), "{cmd}: {text}");
    }
}

#[test]
fn characterize_reports_both_modes() {
    let (ok, text) = crcim(&["characterize", "--step", "32", "--trials", "16"]);
    assert!(ok, "{text}");
    assert!(text.contains("w/CB"), "{text}");
    assert!(text.contains("wo/CB"), "{text}");
    assert!(text.contains("SQNR"), "{text}");
}

#[test]
fn summary_prints_headlines() {
    let (ok, text) = crcim(&["summary"]);
    assert!(ok, "{text}");
    assert!(text.contains("TOPS/W"), "{text}");
    assert!(text.contains("CB power overhead"), "{text}");
}

#[test]
fn plan_prints_ablation_rows() {
    let (ok, text) = crcim(&["plan", "--vit-small"]);
    assert!(ok, "{text}");
    assert!(text.contains("SAC (paper)"), "{text}");
    assert!(text.contains("µJ/inf"), "{text}");
}

#[test]
fn bad_option_reports_usage() {
    let (ok, text) = crcim(&["plan", "--nonsense"]);
    assert!(!ok);
    assert!(text.contains("unknown option"), "{text}");
}

#[test]
fn serve_rejects_zero_admission_knobs() {
    // The admission knobs are validated before any artifact loads, so
    // these fail fast with the knob's name even without `make artifacts`.
    for (flag, msg) in [
        ("--max-waves", "max_waves"),
        ("--max-inflight", "max_inflight"),
        ("--queue-depth", "queue_depth"),
        ("--drain-timeout-ms", "drain_timeout"),
    ] {
        let (ok, text) = crcim(&["serve", flag, "0"]);
        assert!(!ok, "serve {flag} 0 must fail");
        assert!(text.contains(msg), "serve {flag} 0: {text}");
    }
}
