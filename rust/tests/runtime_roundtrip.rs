//! Integration tests across the python→rust AOT boundary.
//!
//! These need `make artifacts` to have run; they skip (with a note)
//! otherwise so `cargo test` stays green on a fresh checkout.

use std::path::{Path, PathBuf};

use cr_cim::runtime::{Manifest, Runtime, VitExecutable};
use cr_cim::workload::EvalSet;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    m.check_files().unwrap();
    for name in ["vit_cim_b1", "vit_cim_b16", "vit_fp_b16", "cim_linear_micro"] {
        assert!(m.get(name).is_some(), "missing artifact {name}");
    }
    // CIM artifacts take (images, seed, sigma_attn, sigma_mlp).
    assert_eq!(m.get("vit_cim_b16").unwrap().inputs.len(), 4);
    assert_eq!(m.get("vit_fp_b16").unwrap().inputs.len(), 1);
}

/// The core cross-language numerics check: execute the standalone L1
/// kernel artifact via PJRT and compare against the same quantized-matmul
/// math computed independently in rust.
#[test]
fn cim_linear_micro_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let art = m.get("cim_linear_micro").unwrap();
    let (mm, kk) = (art.inputs[0].shape[0], art.inputs[0].shape[1]);
    let nn = art.inputs[1].shape[1];

    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(art).unwrap();

    // Deterministic pseudo-random inputs.
    let mut rng = cr_cim::util::rng::Rng::new(0xA07);
    let x: Vec<f32> = (0..mm * kk).map(|_| rng.gauss() as f32).collect();
    let w: Vec<f32> = (0..kk * nn).map(|_| rng.gauss() as f32).collect();

    let lx = xla::Literal::vec1(&x).reshape(&[mm as i64, kk as i64]).unwrap();
    let lw = xla::Literal::vec1(&w).reshape(&[kk as i64, nn as i64]).unwrap();
    let got = exe.run_f32(&[lx, lw]).unwrap();
    assert_eq!(got.len(), mm * nn);

    // Rust mirror of kernels/cim_matmul.py::cim_linear at 6b/6b.
    let bits = 6u32;
    let qmax = (1i64 << (bits - 1)) - 1;
    let maxabs = |v: &[f32]| v.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-6);
    let sx = maxabs(&x) / qmax as f32;
    let sw = maxabs(&w) / qmax as f32;
    let q = |v: f32, s: f32| ((v / s).round() as i64).clamp(-qmax - 1, qmax) as f64;
    let mut want = vec![0f64; mm * nn];
    for i in 0..mm {
        for j in 0..nn {
            let mut acc = 0f64;
            for t in 0..kk {
                acc += q(x[i * kk + t], sx) * q(w[t * nn + j], sw);
            }
            want[i * nn + j] = acc * (sx as f64) * (sw as f64);
        }
    }
    for (idx, (g, e)) in got.iter().zip(&want).enumerate() {
        assert!(
            ((*g as f64) - e).abs() < 1e-3,
            "mismatch at {idx}: pjrt {g} vs rust {e}"
        );
    }
}

#[test]
fn vit_fp_artifact_predicts_eval_set_well() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let eval = EvalSet::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = VitExecutable::new(&rt, m.get("vit_fp_b16").unwrap()).unwrap();
    assert!(!exe.is_cim);

    let count = 32.min(eval.n);
    let w = eval.image_floats();
    let mut correct = 0usize;
    let mut done = 0;
    while done < count {
        let b = exe.batch.min(count - done);
        let mut flat = vec![0f32; exe.batch * w];
        for i in 0..b {
            flat[i * w..(i + 1) * w].copy_from_slice(eval.image_slice(done + i));
        }
        let logits = exe.infer(&flat, 0, 0.0, 0.0).unwrap();
        let preds = exe.predict(&logits);
        for i in 0..b {
            if preds[i] == eval.labels[done + i] as usize {
                correct += 1;
            }
        }
        done += b;
    }
    let acc = correct as f64 / count as f64;
    // Trainer reported ~99%; through the AOT round-trip it must stay high.
    assert!(acc > 0.85, "fp artifact accuracy {acc} over {count} images");
}

/// Cross-language contract: rust's kernel_noise_sigma must equal python's
/// output_noise_sigma on the vector grid the manifest carries.
#[test]
fn noise_bridge_vectors_match() {
    let Some(dir) = artifacts_dir() else { return };
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let j = cr_cim::util::json::parse(&text).unwrap();
    let Some(bridge) = j.get_path("noise_bridge").and_then(|b| b.as_arr()) else {
        eprintln!("skipping: manifest has no noise_bridge (old artifacts)");
        return;
    };
    assert!(!bridge.is_empty());
    for entry in bridge {
        let g = |k: &str| entry.get_path(k).and_then(|v| v.as_f64()).unwrap();
        let k = g("k") as usize;
        let (a, w) = (g("a_bits") as u32, g("w_bits") as u32);
        let py_rep = g("replication") as usize;
        let py_sigma = g("sigma_factor");
        assert_eq!(
            cr_cim::coordinator::sac::row_replication(k),
            py_rep,
            "replication mismatch at k={k}"
        );
        let rs_sigma = cr_cim::coordinator::sac::kernel_noise_sigma(k, a, w, 1.0);
        assert!(
            (rs_sigma - py_sigma).abs() / py_sigma < 1e-9,
            "sigma mismatch at k={k} a={a} w={w}: rust {rs_sigma} python {py_sigma}"
        );
    }
}

#[test]
fn cim_artifact_noise_inputs_behave() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let eval = EvalSet::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = VitExecutable::new(&rt, m.get("vit_cim_b1").unwrap()).unwrap();
    assert!(exe.is_cim);

    let img = eval.image_slice(0);
    // Same seed, same sigma → identical logits.
    let a = exe.infer(img, 7, 0.5, 0.5).unwrap();
    let b = exe.infer(img, 7, 0.5, 0.5).unwrap();
    assert_eq!(a, b, "same-seed inference must be deterministic");
    // Different seed → different noise.
    let c = exe.infer(img, 8, 0.5, 0.5).unwrap();
    assert_ne!(a, c, "seed must drive the injected read noise");
    // Zero noise is argmax-stable vs small noise on most images.
    let z = exe.infer(img, 1, 0.0, 0.0).unwrap();
    assert_eq!(z.len(), exe.num_classes);
}
