//! Planner/executor agreement anchors for the staged wavefront engine:
//! the measured (stage-accounted) pass latency of the overlapped
//! executor must land on `PipelinePlan`'s double-buffered bounds, never
//! exceed the serial accounting, and keep residency behavior equal to
//! the planner's `lru_steady_hits` simulation even while die
//! programming runs concurrently with conversion waves.
//!
//! Tolerance contract: the executor's staged fold and the planner's
//! `double_buffer_fold` sum the same per-layer `reload_ns`/`compute_ns`
//! terms, so agreement is exact up to f64 round-off — asserted at a
//! relative 1e-9, documented here and in `docs/ARCHITECTURE.md`
//! ("Pipelined execution").

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::vit::graph::ModelGraph;
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn zero_noise(mut p: MacroParams) -> MacroParams {
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

fn plan(a_bits: u32, w_bits: u32) -> PrecisionPlan {
    let op = OperatingPoint::new(a_bits, w_bits, CbMode::Off);
    PrecisionPlan { name: "probe plan", attention: op, mlp: op }
}

fn images(n: usize, floats: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..floats).map(|j| ((i * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect())
        .collect()
}

/// Relative agreement at the documented 1e-9 tolerance.
fn close(measured: f64, planned: f64, what: &str) {
    let tol = 1e-9 * planned.abs().max(1.0);
    assert!(
        (measured - planned).abs() <= tol,
        "{what}: measured {measured} vs planned {planned}"
    );
}

#[test]
fn measured_pass_latency_matches_planned_bound_for_vit_base_b8() {
    // The acceptance anchor at real scale: ViT-Base batch 8 (probed at
    // 1b so the pass stays test-sized) on a deployment whose weight
    // SRAM holds the whole model. The overlapped executor's measured
    // stage accounting must land on the planner's double-buffered cold
    // bound, then on the warm (fully resident) bound.
    let p = zero_noise(MacroParams::default()).with_sram_bits(1 << 26).with_threads(4);
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &plan(1, 1));
    let cfg = PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap: true };
    let mut exec = ModelExecutor::new(&p, graph, cfg).unwrap();
    let px = exec.pipeline().clone();
    assert_eq!(px.resident_layers(), 48, "1<<26 bits hold all of ViT-Base");
    let xs = exec.featurize_images(&images(8, 32));

    // Cold pass: every layer programs, overlapped with the previous
    // layer's conversions — the planned pipelined (double-buffered)
    // bound, strictly below the serial accounting.
    exec.forward_ints(&xs).unwrap();
    close(exec.last_pass_ns(), px.pipelined_ns, "cold overlapped pass");
    close(exec.last_serial_ns(), px.serial_ns, "cold serial accounting");
    assert!(
        exec.last_pass_ns() < exec.last_serial_ns(),
        "overlap must beat serial on the cold pass: {} vs {}",
        exec.last_pass_ns(),
        exec.last_serial_ns()
    );

    // Warm pass: everything resident, no programming tasks at all —
    // the planned warm bound, bounded below by the widest stage.
    exec.forward_ints(&xs).unwrap();
    close(exec.last_pass_ns(), px.warm_pipelined_ns, "warm overlapped pass");
    assert!(exec.last_pass_ns() <= exec.last_serial_ns() + 1e-9);
    assert!(
        px.stage_period_ns() <= exec.last_pass_ns() + 1e-9,
        "no pass can beat the widest stage: {} vs {}",
        px.stage_period_ns(),
        exec.last_pass_ns()
    );

    // Residency under concurrent programming equals the planner's
    // lru_steady_hits simulation: the warm pass hits on exactly the
    // layers the plan marks resident.
    let r = exec.residency_stats();
    assert_eq!(r.reload_misses, 48, "cold pass misses every layer");
    assert_eq!(r.reload_hits as usize, px.resident_layers(), "warm hits == lru_steady_hits");
    assert_eq!(r.evictions, 0);
}

#[test]
fn overlap_toggle_changes_nothing_but_wall_clock() {
    // The same model through the staged engine with overlap on and off:
    // outputs, residency counters and the *accounted* latencies are all
    // identical — the toggle only changes which thread runs a task.
    let p = zero_noise(MacroParams::default()).with_sram_bits(1 << 26).with_threads(4);
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 2, &plan(1, 1));
    let run = |overlap: bool| {
        let cfg = PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap };
        let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
        let xs = exec.featurize_images(&images(2, 32));
        let cold = exec.forward_ints(&xs).unwrap();
        let cold_ns = (exec.last_pass_ns(), exec.last_serial_ns());
        let warm = exec.forward_ints(&xs).unwrap();
        let warm_ns = (exec.last_pass_ns(), exec.last_serial_ns());
        let r = exec.residency_stats();
        (cold, warm, cold_ns, warm_ns, (r.reload_hits, r.reload_misses, r.evictions))
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.0, off.0, "cold outputs");
    assert_eq!(on.1, off.1, "warm outputs");
    assert_eq!(on.2, off.2, "cold accounted latencies");
    assert_eq!(on.3, off.3, "warm accounted latencies");
    assert_eq!(on.4, off.4, "residency counters");
}

#[test]
fn full_eviction_pays_every_reload_under_concurrent_programming() {
    // A zero SRAM budget forces lru_steady_hits to all-false: even with
    // concurrent programming, every pass misses every layer and the
    // measured warm pass equals the planned *cold* pipelined bound.
    let p = {
        let mut q = zero_noise(MacroParams::default()).with_threads(4);
        q.sram_bits_per_macro = 0;
        q
    };
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 2, &plan(1, 1));
    let cfg = PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap: true };
    let mut exec = ModelExecutor::new(&p, graph, cfg).unwrap();
    let px = exec.pipeline().clone();
    assert_eq!(px.resident_layers(), 0);
    let xs = exec.featurize_images(&images(2, 32));
    exec.forward_ints(&xs).unwrap();
    close(exec.last_pass_ns(), px.pipelined_ns, "cold pass, nothing resident");
    exec.forward_ints(&xs).unwrap();
    close(exec.last_pass_ns(), px.warm_pipelined_ns, "warm == cold when nothing sticks");
    close(exec.last_pass_ns(), px.pipelined_ns, "warm pass re-pays every reload");
    let r = exec.residency_stats();
    assert_eq!(r.reload_hits, 0, "hits == lru_steady_hits == none");
    assert_eq!(r.reload_misses, 96, "2 passes × 48 layers, all misses");
}
