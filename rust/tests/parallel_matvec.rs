//! Integration tests for the column-parallel matvec engine: the
//! determinism contract (bit-identical results at any thread count) and a
//! guarded throughput smoke check on a full-scale 1088×78 tile.

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::CimMacro;
use cr_cim::util::rng::Rng;

fn full_tile(seed: u64) -> (Vec<Vec<i32>>, Vec<i32>, Vec<Vec<i32>>) {
    let mut rng = Rng::new(seed);
    let w: Vec<Vec<i32>> = (0..1024)
        .map(|_| (0..13).map(|_| rng.below(63) as i32 - 31).collect())
        .collect();
    let x: Vec<i32> = (0..1024).map(|_| rng.below(63) as i32 - 31).collect();
    let xs: Vec<Vec<i32>> = (0..4)
        .map(|_| (0..1024).map(|_| rng.below(63) as i32 - 31).collect())
        .collect();
    (w, x, xs)
}

fn run_at(threads: usize, w: &[Vec<i32>], x: &[i32], mode: CbMode) -> Vec<i64> {
    let p = MacroParams::default().with_threads(threads);
    let mut m = CimMacro::new(&p).unwrap();
    m.load_weights(w, 6).unwrap();
    m.matvec(x, 6, mode).unwrap().y
}

#[test]
fn matvec_is_bit_identical_for_threads_1_4_8() {
    let (w, x, _) = full_tile(17);
    for mode in [CbMode::Off, CbMode::On] {
        let y1 = run_at(1, &w, &x, mode);
        let y4 = run_at(4, &w, &x, mode);
        let y8 = run_at(8, &w, &x, mode);
        assert_eq!(y1, y4, "threads 1 vs 4, {mode:?}");
        assert_eq!(y1, y8, "threads 1 vs 8, {mode:?}");
    }
}

#[test]
fn batch_is_bit_identical_across_thread_counts() {
    let (w, _, xs) = full_tile(23);
    let run = |threads: usize| {
        let p = MacroParams::default().with_threads(threads);
        let mut m = CimMacro::new(&p).unwrap();
        m.load_weights(&w, 6).unwrap();
        m.matvec_batch(&xs, 6, CbMode::On)
            .unwrap()
            .into_iter()
            .map(|r| r.y)
            .collect::<Vec<_>>()
    };
    assert_eq!(run(1), run(8));
}

/// Throughput smoke check for the §Perf claim: on a full 1088×78-scale
/// tile, 8 worker threads must beat the serial engine. Guarded: shared CI
/// runners (ubuntu-latest is 4 noisy vCPUs) make wall-clock assertions
/// flaky, so the speedup bound is only enforced on ≥ 8-core boxes; the
/// timing still runs and is printed everywhere.
#[test]
fn parallel_matvec_speedup_smoke() {
    use std::time::Instant;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (w, x, _) = full_tile(31);
    let time_at = |threads: usize| {
        let p = MacroParams::default().with_threads(threads);
        let mut m = CimMacro::new(&p).unwrap();
        m.load_weights(&w, 6).unwrap();
        let reps = 6;
        // Warm-up conversion so allocator/page effects don't skew rep 1.
        let first = m.matvec(&x, 6, CbMode::Off).unwrap().y;
        let t0 = Instant::now();
        for _ in 0..reps {
            let y = m.matvec(&x, 6, CbMode::Off).unwrap().y;
            assert_eq!(y.len(), first.len());
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    let serial = time_at(1);
    let parallel = time_at(8);
    let speedup = serial / parallel.max(1e-12);
    eprintln!("matvec speedup at 8 threads over serial: {speedup:.2}x ({cores} cores)");
    // CRCIM_PERF_ASSERT=0 opts out on loaded shared boxes where any
    // wall-clock bound is noise; the measurement still prints above.
    let assert_enabled = std::env::var("CRCIM_PERF_ASSERT").as_deref() != Ok("0");
    if cores >= 8 && assert_enabled {
        assert!(
            speedup >= 1.3,
            "expected parallel speedup on a {cores}-core box, measured {speedup:.2}x \
             (set CRCIM_PERF_ASSERT=0 to skip on loaded machines)"
        );
    }
}
