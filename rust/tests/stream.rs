//! Integration tests for streaming token-level batching: the
//! determinism acceptance anchors of the serving tier.
//!
//! - At zero noise, streamed per-request outputs are bit-identical to
//!   the fixed-batch forward path AND to the exact reference walk, for
//!   distinct arrival interleavings (which produce distinct wave
//!   compositions) — on the tiny grid and on a ViT-Base config.
//! - With real comparator noise, streamed responses are bit-identical
//!   at any worker-thread count and any column-shard count for a fixed
//!   request trace.
//! - Out-of-order completion: a short request admitted behind a long
//!   one completes first, and the stats report carries the streaming
//!   fields (tokens in flight, wave occupancy, token latency p50/p99).
//! - Multi-wave in flight (`max_waves > 1`): several waves execute per
//!   step and complete in wave order with unchanged results.
//! - Mid-flight request death (disconnect or a sibling wave's failure)
//!   settles the dead request's in-flight tokens without failing or
//!   mis-counting the waves it shares with live requests.
//! - Property campaign: random arrival interleavings, wave schedules,
//!   purges and failures always reassemble every surviving request in
//!   token-index order with no cross-request leakage.

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::server::{BatchExecutor, Server, ServerConfig};
use cr_cim::coordinator::stream::{pool_tokens, split_tokens, StreamConfig, TokenStream, Wave};
use cr_cim::util::json::{self, Json};
use cr_cim::util::prop::assert_prop;
use cr_cim::vit::graph::ModelGraph;
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn zero_noise(mut p: MacroParams) -> MacroParams {
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

fn tiny_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    zero_noise(p)
}

fn plan(a_bits: u32, w_bits: u32) -> PrecisionPlan {
    let op = OperatingPoint::new(a_bits, w_bits, CbMode::Off);
    PrecisionPlan { name: "probe plan", attention: op, mlp: op }
}

/// d_ff = 96 > 64 active rows: fc2 row-tiles even on the tiny geometry.
fn tiny_cfg() -> VitConfig {
    VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
}

fn image(seed: usize, floats: usize) -> Vec<f32> {
    (0..floats).map(|j| ((seed * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
}

fn multiwave_server(wave_tokens: usize, max_wait_ms: u64, max_waves: usize) -> Server {
    Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 4],
        max_wait: Duration::from_millis(max_wait_ms),
        wave_tokens,
        max_waves,
        ..ServerConfig::default()
    })
    .unwrap()
}

/// Single-wave-per-step server: the tests that count requests completed
/// per `executor_step` depend on one wave per step.
fn server_with(wave_tokens: usize, max_wait_ms: u64) -> Server {
    multiwave_server(wave_tokens, max_wait_ms, 1)
}

fn test_server(wave_tokens: usize) -> Server {
    server_with(wave_tokens, 1)
}

fn stream_line(id: usize, tokens: usize, img: &[f32]) -> String {
    let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    format!(
        r#"{{"id": {id}, "kind": "stream", "tokens": {tokens}, "image": [{}]}}"#,
        body.join(", ")
    )
}

/// Drain the server: step until every expected response is staged (the
/// tail wave needs its deadline, so idle steps sleep past `max_wait`).
fn drain_responses(
    srv: &Server,
    exec: &mut dyn BatchExecutor,
    conn: u64,
    want: usize,
) -> Vec<Json> {
    let mut out = Vec::new();
    for _ in 0..200 {
        srv.executor_step(exec);
        for line in srv.take_responses(conn) {
            out.push(json::parse(&line).unwrap());
        }
        if out.len() >= want {
            return out;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("server drained only {} of {want} responses", out.len());
}

fn logits_of(j: &Json) -> Vec<f64> {
    j.get_path("logits")
        .unwrap_or_else(|| panic!("response carries logits: {j:?}"))
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

/// The fixed-batch ground truth for a streamed request: run its token
/// chunks as one forward batch and mean-pool, exactly as the streaming
/// tier reassembles.
fn pooled_fixed_batch(exec: &mut ModelExecutor, img: &[f32], tokens: usize) -> Vec<f32> {
    let chunks = split_tokens(img, tokens);
    let per_token = exec.forward(&chunks).unwrap();
    pool_tokens(&per_token)
}

#[test]
fn zero_noise_streamed_equals_fixed_batch_and_reference_for_two_interleavings() {
    let p = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let img_a = image(1, 48); // 3 tokens
    let img_b = image(2, 32); // 2 tokens
    // Ground truth, twice over: the fixed-batch forward path on the same
    // token chunks, and the exact digital reference walk. At zero noise
    // the three serving paths must agree f32-for-f32.
    let (want_a, want_b, ref_a, ref_b) = {
        let mut exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let want_a = pooled_fixed_batch(&mut exec, &img_a, 3);
        let want_b = pooled_fixed_batch(&mut exec, &img_b, 2);
        let ref_a = pool_tokens(&exec.reference_logits(&split_tokens(&img_a, 3)));
        let ref_b = pool_tokens(&exec.reference_logits(&split_tokens(&img_b, 2)));
        (want_a, want_b, ref_a, ref_b)
    };
    assert_eq!(want_a, ref_a, "fixed batch == exact reference (request a)");
    assert_eq!(want_b, ref_b, "fixed batch == exact reference (request b)");
    // Two distinct arrival interleavings → distinct wave compositions
    // (wave size 2 mixes the requests' tokens differently); at zero
    // noise both must still reproduce the reference exactly.
    for (order, label) in [([0usize, 1], "a then b"), ([1, 0], "b then a")] {
        let mut exec =
            ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let srv = test_server(2);
        let conn = srv.open_conn();
        for &r in &order {
            match r {
                0 => srv.handle_line(&stream_line(10, 3, &img_a), conn).unwrap(),
                _ => srv.handle_line(&stream_line(20, 2, &img_b), conn).unwrap(),
            };
        }
        let resps = drain_responses(&srv, &mut exec, conn, 2);
        assert_eq!(resps.len(), 2, "{label}");
        for j in &resps {
            let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
            let want = if id == 10 { &want_a } else { &want_b };
            let got = logits_of(j);
            let want_f64: Vec<f64> = want.iter().map(|&x| x as f64).collect();
            assert_eq!(got, want_f64, "{label}, request {id}");
            assert_eq!(
                j.get_path("tokens").unwrap().as_f64().unwrap(),
                if id == 10 { 3.0 } else { 2.0 },
                "{label}, request {id}"
            );
        }
    }
}

#[test]
fn vit_base_zero_noise_streamed_equals_fixed_batch_and_reference() {
    // The acceptance anchor at real scale: ViT-Base (12 blocks,
    // d_ff = 3072) on the paper's 1024-row geometry, probed at 1b so a
    // full pass stays test-sized. Two interleavings of two requests.
    let p = zero_noise(MacroParams::default());
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 1, &plan(1, 1));
    let img_a = image(3, 32); // 2 tokens
    let img_b = image(4, 16); // 1 token
    let (want_a, want_b) = {
        let mut exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let want_a = pooled_fixed_batch(&mut exec, &img_a, 2);
        let want_b = pooled_fixed_batch(&mut exec, &img_b, 1);
        // Anchor the fixed-batch truth to the exact reference walk.
        assert_eq!(want_a, pool_tokens(&exec.reference_logits(&split_tokens(&img_a, 2))));
        assert_eq!(want_b, pool_tokens(&exec.reference_logits(&split_tokens(&img_b, 1))));
        (want_a, want_b)
    };
    assert_eq!(want_a.len(), 768);
    for (order, label) in [([0usize, 1], "a then b"), ([1, 0], "b then a")] {
        let mut exec =
            ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let srv = test_server(2);
        let conn = srv.open_conn();
        for &r in &order {
            match r {
                0 => srv.handle_line(&stream_line(1, 2, &img_a), conn).unwrap(),
                _ => srv.handle_line(&stream_line(2, 1, &img_b), conn).unwrap(),
            };
        }
        let resps = drain_responses(&srv, &mut exec, conn, 2);
        for j in &resps {
            let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
            let want = if id == 1 { &want_a } else { &want_b };
            let want_f64: Vec<f64> = want.iter().map(|&x| x as f64).collect();
            assert_eq!(logits_of(j), want_f64, "{label}, request {id}");
        }
    }
}

#[test]
fn noisy_streamed_responses_are_thread_and_shard_invariant() {
    // The strong half of the contract: with real comparator noise and a
    // fixed request trace, the worker-thread count and the column-shard
    // split must be invisible to the streamed results, wave after wave.
    let mut p = tiny_params();
    p.sigma_cmp_lsb = 1.1;
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let img_a = image(5, 48);
    let img_b = image(6, 32);
    // 3 + 3 tokens over 2-token waves: every wave closes full, by size,
    // so the wave partition is a pure function of the request trace —
    // no deadline/aging path whose timing could vary between runs (the
    // generous max_wait keeps both switched off).
    let run = |threads: usize, shards: usize| -> Vec<(u64, Vec<f64>)> {
        let cfg = PipelineConfig { shards, attention_dies: 1, mlp_dies: 1, overlap: true };
        let mut exec =
            ModelExecutor::new(&p.clone().with_threads(threads), graph.clone(), cfg).unwrap();
        let srv = server_with(2, 60_000);
        let conn = srv.open_conn();
        srv.handle_line(&stream_line(1, 3, &img_a), conn).unwrap();
        srv.handle_line(&stream_line(2, 3, &img_b), conn).unwrap();
        let mut got: Vec<(u64, Vec<f64>)> = drain_responses(&srv, &mut exec, conn, 2)
            .iter()
            .map(|j| (j.get_path("id").unwrap().as_f64().unwrap() as u64, logits_of(j)))
            .collect();
        got.sort_by_key(|(id, _)| *id);
        got
    };
    let one = run(1, 1);
    // shards = 40 > every tiny layer's minimum: a truly different grid.
    for (threads, shards) in [(4usize, 1usize), (1, 40), (4, 40)] {
        assert_eq!(run(threads, shards), one, "threads {threads} shards {shards}");
    }
    // Noise is actually present: the streamed walk deviates from the
    // zero-noise reference.
    let exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
    let quiet = pool_tokens(&exec.reference_logits(&split_tokens(&img_a, 3)));
    let quiet_f64: Vec<f64> = quiet.iter().map(|&x| x as f64).collect();
    assert_ne!(one[0].1, quiet_f64, "noisy streamed walk should deviate from exact");
}

#[test]
fn short_requests_complete_out_of_order_with_streaming_stats() {
    let p = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
    // Generous max_wait: all three waves close full, by size, so the
    // depth-fair order (not the aging fallback) governs deterministically.
    let srv = server_with(2, 60_000);
    let conn = srv.open_conn();
    // A long request (4 tokens) admitted before a short one (2 tokens):
    // depth-fair waves of 2 are {l0, s0}, {l1, s1}, {l2, l3} — the short
    // request's response lands a full wave before the long one's.
    srv.handle_line(&stream_line(100, 4, &image(7, 48)), conn).unwrap();
    srv.handle_line(&stream_line(200, 2, &image(8, 32)), conn).unwrap();
    assert_eq!(srv.executor_step(&mut exec), 0, "wave 1 completes nothing");
    assert!(srv.take_responses(conn).is_empty());
    assert_eq!(srv.executor_step(&mut exec), 1, "wave 2 completes the short request");
    let first = srv.take_responses(conn);
    assert_eq!(first.len(), 1);
    let j = json::parse(&first[0]).unwrap();
    assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 200.0);
    assert_eq!(j.get_path("waves").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(srv.executor_step(&mut exec), 1, "wave 3 completes the long request");
    let second = srv.take_responses(conn);
    assert_eq!(second.len(), 1);
    let j2 = json::parse(&second[0]).unwrap();
    assert_eq!(j2.get_path("id").unwrap().as_f64().unwrap(), 100.0);
    assert_eq!(j2.get_path("tokens").unwrap().as_f64().unwrap(), 4.0);
    assert_eq!(j2.get_path("waves").unwrap().as_f64().unwrap(), 3.0);
    // The stats report carries the streaming fields: all six tokens
    // served over three full waves, nothing left in flight.
    let stats = srv.ledger_json();
    assert_eq!(stats.get_path("stream_requests").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(stats.get_path("stream_tokens_served").unwrap().as_f64().unwrap(), 6.0);
    assert_eq!(stats.get_path("tokens_in_flight").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(stats.get_path("stream_waves").unwrap().as_f64().unwrap(), 3.0);
    let occ = stats.get_path("mean_wave_occupancy").unwrap().as_f64().unwrap();
    assert!((occ - 1.0).abs() < 1e-12, "all waves were full: {occ}");
    let p50 = stats.get_path("token_latency_p50_us").unwrap().as_f64().unwrap();
    let p99 = stats.get_path("token_latency_p99_us").unwrap().as_f64().unwrap();
    assert!(p50 > 0.0 && p99 >= p50);
}

#[test]
fn mixed_kinds_serve_side_by_side_with_streams() {
    // classify + forward + stream in one session: the batch tier and the
    // streaming tier share the executor loop without starving each other.
    let p = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
    let srv = test_server(2);
    let conn = srv.open_conn();
    let img = image(9, 32);
    let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    srv.handle_line(&format!(r#"{{"id": 1, "image": [{}]}}"#, body.join(", ")), conn).unwrap();
    srv.handle_line(
        &format!(r#"{{"id": 2, "kind": "forward", "image": [{}]}}"#, body.join(", ")),
        conn,
    )
    .unwrap();
    srv.handle_line(&stream_line(3, 2, &img), conn).unwrap();
    let resps = drain_responses(&srv, &mut exec, conn, 3);
    assert_eq!(resps.len(), 3);
    for j in &resps {
        assert!(j.get_path("pred").is_some(), "every kind answers: {j:?}");
        let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
        match id {
            2 => assert!(j.get_path("layers").is_some(), "forward reports layers"),
            3 => assert!(j.get_path("tokens").is_some(), "stream reports tokens"),
            _ => assert!(j.get_path("batch").is_some(), "classify reports batch"),
        }
    }
    // Both accounting tiers populated: batch requests and stream fields.
    let stats = srv.ledger_json();
    assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(stats.get_path("stream_requests").unwrap().as_f64().unwrap(), 1.0);
}

#[test]
fn multi_wave_steps_complete_requests_in_one_executor_step() {
    // max_waves = 2: a 4-token request over 2-token waves forms both
    // waves in one stream-lock session and completes in one step —
    // with the same logits a one-wave-at-a-time server produces.
    let p = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let mut exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
    let srv = multiwave_server(2, 60_000, 2);
    let conn = srv.open_conn();
    srv.handle_line(&stream_line(1, 4, &image(7, 48)), conn).unwrap();
    assert_eq!(srv.executor_step(&mut exec), 1, "both waves run in a single step");
    let resps = srv.take_responses(conn);
    assert_eq!(resps.len(), 1);
    let j = json::parse(&resps[0]).unwrap();
    assert_eq!(j.get_path("waves").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(j.get_path("tokens").unwrap().as_f64().unwrap(), 4.0);
    let stats = srv.ledger_json();
    assert_eq!(stats.get_path("tokens_in_flight").unwrap().as_f64().unwrap(), 0.0);
    // Single-wave control: identical wave partition, identical logits.
    let mut exec1 = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
    let srv1 = server_with(2, 60_000);
    let conn1 = srv1.open_conn();
    srv1.handle_line(&stream_line(1, 4, &image(7, 48)), conn1).unwrap();
    let r1 = drain_responses(&srv1, &mut exec1, conn1, 1);
    assert_eq!(logits_of(&j), logits_of(&r1[0]));
}

#[test]
fn mid_wave_disconnect_fails_only_that_requests_tokens_as_a_unit() {
    // Two connections share a wave; one disconnects while the wave is
    // in flight. The dead request's remaining tokens die as a unit —
    // queued ones dropped, in-flight ones settled silently — and the
    // surviving request completes with uncontaminated stats.
    let mut ts = TokenStream::new(&StreamConfig {
        wave_tokens: 2,
        max_wait: Duration::from_millis(1),
    })
    .unwrap();
    let t0 = Instant::now();
    ts.enqueue_request(1, Some(1.0), &[0.0, 1.0], 2, false, t0); // seq 1, conn 1
    ts.enqueue_request(2, Some(2.0), &[2.0, 3.0], 2, false, t0); // seq 2, conn 2
    let w1 = ts.form_wave(t0).unwrap(); // depth-fair: {(1,0), (2,0)}
    let keys1: Vec<(u64, usize)> = w1.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
    assert_eq!(keys1, vec![(1, 0), (2, 0)]);
    ts.purge_conn(1); // disconnect while w1 is in flight
    assert_eq!(ts.queued_tokens(), 1, "conn 1's queued token is dropped");
    let done1 = ts.complete_wave(&w1, &[vec![10.0], vec![20.0]], t0);
    assert!(done1.is_empty());
    let w2 = ts.form_wave(t0 + Duration::from_millis(5)).unwrap();
    let keys2: Vec<(u64, usize)> = w2.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
    assert_eq!(keys2, vec![(2, 1)]);
    let done2 = ts.complete_wave(&w2, &[vec![30.0]], t0);
    assert_eq!(done2.len(), 1);
    assert_eq!(done2[0].client_req_id, Some(2.0));
    let out = done2[0].result.as_ref().unwrap();
    assert_eq!(out.logits, vec![25.0], "mean of the surviving request's tokens only");
    assert_eq!(ts.tokens_in_flight(), 0);
    let snap = ts.snapshot();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.tokens_served, 2, "the dead request's tokens never count as served");
}

#[test]
fn failing_one_wave_settles_the_requests_tokens_in_other_waves() {
    // Request A's tokens ride two concurrent waves; request B shares
    // the second. Failing wave 1 fails A as a unit; wave 2 then settles
    // A's stray token silently and completes B normally.
    let mut ts = TokenStream::new(&StreamConfig {
        wave_tokens: 2,
        max_wait: Duration::from_millis(1),
    })
    .unwrap();
    let t0 = Instant::now();
    ts.enqueue_request(1, Some(1.0), &[0.0, 1.0, 2.0], 3, false, t0); // A: seq 1
    let w1 = ts.form_wave(t0).unwrap();
    let keys1: Vec<(u64, usize)> = w1.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
    assert_eq!(keys1, vec![(1, 0), (1, 1)]);
    ts.enqueue_request(2, Some(2.0), &[3.0], 1, false, t0); // B: seq 2
    let w2 = ts.form_wave(t0).unwrap(); // depth-fair: {(1,2), (2,0)}
    let keys2: Vec<(u64, usize)> = w2.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
    assert_eq!(keys2, vec![(1, 2), (2, 0)]);
    let failed = ts.fail_wave(&w1, "die bank fault");
    assert_eq!(failed.len(), 1, "only A fails");
    assert_eq!(failed[0].client_req_id, Some(1.0));
    assert!(failed[0].result.is_err());
    let done = ts.complete_wave(&w2, &[vec![50.0], vec![60.0]], t0);
    assert_eq!(done.len(), 1, "B completes despite sharing a wave with failed A");
    assert_eq!(done[0].client_req_id, Some(2.0));
    assert_eq!(done[0].result.as_ref().unwrap().logits, vec![60.0]);
    assert_eq!(ts.tokens_in_flight(), 0);
    let snap = ts.snapshot();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.tokens_served, 1, "only B's token counts as served");
}

/// Synthetic wave execution for the property campaign: each token's
/// "logits" encode its identity, so pooled responses prove reassembly
/// order and the absence of cross-request leakage arithmetically
/// (any foreign or duplicated token shifts the mean).
fn identity_outputs(wave: &Wave) -> Vec<Vec<f32>> {
    wave.items.iter().map(|t| vec![t.req_seq as f32, t.token_index as f32]).collect()
}

#[test]
fn prop_random_interleavings_reassemble_in_token_order_without_leakage() {
    assert_prop("stream-wave-interleaving", 60, |g| {
        let wave_tokens = g.usize(1, 4);
        let mut ts = TokenStream::new(&StreamConfig {
            wave_tokens,
            max_wait: Duration::from_millis(10),
        })
        .map_err(|e| e.to_string())?;
        let t0 = Instant::now();
        let n_req = g.usize(1, 4);
        let tokens: Vec<usize> = (0..n_req).map(|_| g.usize(1, 5)).collect();
        let mut next_enqueue = 0usize;
        let mut seq_of = vec![0u64; n_req]; // filled at enqueue (1-based)
        let mut inflight: Vec<Wave> = Vec::new();
        let mut seen: BTreeSet<(u64, usize)> = BTreeSet::new();
        let mut purged: BTreeSet<u64> = BTreeSet::new(); // conn ids
        let mut finished_ok: BTreeSet<u64> = BTreeSet::new(); // conn ids
        let mut finished_err: BTreeSet<u64> = BTreeSet::new();
        // Validate one formed wave: sorted, in-bounds, never duplicated.
        let check_wave = |w: &Wave, seen: &mut BTreeSet<(u64, usize)>| -> Result<(), String> {
            for pair in w.items.windows(2) {
                let a = (pair[0].req_seq, pair[0].token_index);
                let b = (pair[1].req_seq, pair[1].token_index);
                if a >= b {
                    return Err(format!("wave not sorted by (req_seq, token_index): {a:?} {b:?}"));
                }
            }
            for it in &w.items {
                if !seen.insert((it.req_seq, it.token_index)) {
                    return Err(format!(
                        "token admitted twice: seq {} idx {}",
                        it.req_seq, it.token_index
                    ));
                }
            }
            Ok(())
        };
        // Settle one wave's completions against the identity encoding.
        let settle = |done: Vec<cr_cim::coordinator::stream::FinishedRequest>,
                      seq_of: &[u64],
                      tokens: &[usize],
                      purged: &BTreeSet<u64>,
                      finished_ok: &mut BTreeSet<u64>,
                      finished_err: &mut BTreeSet<u64>|
         -> Result<(), String> {
            for f in done {
                if purged.contains(&f.conn_id) {
                    return Err(format!("purged conn {} got a response", f.conn_id));
                }
                match &f.result {
                    Ok(out) => {
                        let idx = (f.conn_id - 1) as usize;
                        let n = tokens[idx];
                        if out.tokens != n {
                            return Err(format!("req {idx}: {} tokens, want {n}", out.tokens));
                        }
                        // Mean over exactly tokens 0..n of this request's
                        // seq: any leaked or missing token shifts it.
                        let want =
                            vec![seq_of[idx] as f32, (n as f32 - 1.0) / 2.0];
                        if out.logits != want {
                            return Err(format!(
                                "req {idx}: pooled {:?}, want {want:?}",
                                out.logits
                            ));
                        }
                        if !finished_ok.insert(f.conn_id) {
                            return Err(format!("conn {} finished twice", f.conn_id));
                        }
                    }
                    Err(_) => {
                        finished_err.insert(f.conn_id);
                    }
                }
            }
            Ok(())
        };
        // Random phase: interleave enqueues, wave formation (fresh and
        // deadline-aged), completion, failure and connection purges.
        for _ in 0..40 {
            match g.usize(0, 5) {
                0 if next_enqueue < n_req => {
                    let conn = next_enqueue as u64 + 1;
                    let n = tokens[next_enqueue];
                    let img: Vec<f32> = (0..n).map(|t| t as f32).collect();
                    ts.enqueue_request(conn, Some(conn as f64), &img, n, false, t0);
                    // Requests enqueue in index order, so the stream's
                    // seq counter (1-based) tracks the index exactly.
                    seq_of[next_enqueue] = next_enqueue as u64 + 1;
                    next_enqueue += 1;
                }
                1 => {
                    if let Some(w) = ts.form_wave(t0) {
                        check_wave(&w, &mut seen)?;
                        inflight.push(w);
                    }
                }
                2 => {
                    // Deadline-aged formation closes partial waves.
                    if let Some(w) = ts.form_wave(t0 + Duration::from_secs(3600)) {
                        check_wave(&w, &mut seen)?;
                        inflight.push(w);
                    }
                }
                3 if !inflight.is_empty() => {
                    let w = inflight.remove(0);
                    let outs = identity_outputs(&w);
                    let done = ts.complete_wave(&w, &outs, t0 + Duration::from_millis(1));
                    settle(done, &seq_of, &tokens, &purged, &mut finished_ok, &mut finished_err)?;
                }
                4 if !inflight.is_empty() && g.bool() => {
                    let w = inflight.remove(0);
                    let done = ts.fail_wave(&w, "injected wave fault");
                    settle(done, &seq_of, &tokens, &purged, &mut finished_ok, &mut finished_err)?;
                }
                5 if next_enqueue > 0 && g.bool() => {
                    let conn = g.usize(1, next_enqueue) as u64;
                    ts.purge_conn(conn);
                    purged.insert(conn);
                }
                _ => {}
            }
        }
        // Drain phase: enqueue stragglers, close every remaining wave
        // and complete all in-flight work.
        while next_enqueue < n_req {
            let conn = next_enqueue as u64 + 1;
            let n = tokens[next_enqueue];
            let img: Vec<f32> = (0..n).map(|t| t as f32).collect();
            ts.enqueue_request(conn, Some(conn as f64), &img, n, false, t0);
            seq_of[next_enqueue] = next_enqueue as u64 + 1;
            next_enqueue += 1;
        }
        while let Some(w) = ts.form_wave(t0 + Duration::from_secs(3600)) {
            check_wave(&w, &mut seen)?;
            inflight.push(w);
        }
        for w in inflight.drain(..) {
            let outs = identity_outputs(&w);
            let done = ts.complete_wave(&w, &outs, t0 + Duration::from_millis(2));
            settle(done, &seq_of, &tokens, &purged, &mut finished_ok, &mut finished_err)?;
        }
        if ts.tokens_in_flight() != 0 {
            return Err(format!("{} tokens leaked in flight", ts.tokens_in_flight()));
        }
        // Every admitted request is accounted for exactly one way.
        for idx in 0..n_req {
            let conn = idx as u64 + 1;
            let settled = finished_ok.contains(&conn)
                || finished_err.contains(&conn)
                || purged.contains(&conn);
            if !settled {
                return Err(format!("request {idx} (conn {conn}) vanished unanswered"));
            }
        }
        Ok(())
    });
}
