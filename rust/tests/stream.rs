//! Integration tests for streaming token-level batching: the
//! determinism acceptance anchors of the serving tier.
//!
//! - At zero noise, streamed per-request outputs are bit-identical to
//!   the fixed-batch forward path AND to the exact reference walk, for
//!   distinct arrival interleavings (which produce distinct wave
//!   compositions) — on the tiny grid and on a ViT-Base config.
//! - With real comparator noise, streamed responses are bit-identical
//!   at any worker-thread count and any column-shard count for a fixed
//!   request trace.
//! - Out-of-order completion: a short request admitted behind a long
//!   one completes first, and the stats report carries the streaming
//!   fields (tokens in flight, wave occupancy, token latency p50/p99).

use std::time::Duration;

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::server::{BatchExecutor, Server, ServerConfig};
use cr_cim::coordinator::stream::{pool_tokens, split_tokens};
use cr_cim::util::json::{self, Json};
use cr_cim::vit::graph::ModelGraph;
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn zero_noise(mut p: MacroParams) -> MacroParams {
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

fn tiny_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    zero_noise(p)
}

fn plan(a_bits: u32, w_bits: u32) -> PrecisionPlan {
    let op = OperatingPoint { a_bits, w_bits, cb: CbMode::Off };
    PrecisionPlan { name: "probe plan", attention: op, mlp: op }
}

/// d_ff = 96 > 64 active rows: fc2 row-tiles even on the tiny geometry.
fn tiny_cfg() -> VitConfig {
    VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
}

fn image(seed: usize, floats: usize) -> Vec<f32> {
    (0..floats).map(|j| ((seed * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
}

fn server_with(wave_tokens: usize, max_wait_ms: u64) -> Server {
    Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 4],
        max_wait: Duration::from_millis(max_wait_ms),
        wave_tokens,
    })
    .unwrap()
}

fn test_server(wave_tokens: usize) -> Server {
    server_with(wave_tokens, 1)
}

fn stream_line(id: usize, tokens: usize, img: &[f32]) -> String {
    let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    format!(
        r#"{{"id": {id}, "kind": "stream", "tokens": {tokens}, "image": [{}]}}"#,
        body.join(", ")
    )
}

/// Drain the server: step until every expected response is staged (the
/// tail wave needs its deadline, so idle steps sleep past `max_wait`).
fn drain_responses(
    srv: &Server,
    exec: &mut dyn BatchExecutor,
    conn: u64,
    want: usize,
) -> Vec<Json> {
    let mut out = Vec::new();
    for _ in 0..200 {
        srv.executor_step(exec);
        for line in srv.take_responses(conn) {
            out.push(json::parse(&line).unwrap());
        }
        if out.len() >= want {
            return out;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("server drained only {} of {want} responses", out.len());
}

fn logits_of(j: &Json) -> Vec<f64> {
    j.get_path("logits")
        .unwrap_or_else(|| panic!("response carries logits: {j:?}"))
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

/// The fixed-batch ground truth for a streamed request: run its token
/// chunks as one forward batch and mean-pool, exactly as the streaming
/// tier reassembles.
fn pooled_fixed_batch(exec: &mut ModelExecutor, img: &[f32], tokens: usize) -> Vec<f32> {
    let chunks = split_tokens(img, tokens);
    let per_token = exec.forward(&chunks).unwrap();
    pool_tokens(&per_token)
}

#[test]
fn zero_noise_streamed_equals_fixed_batch_and_reference_for_two_interleavings() {
    let p = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let img_a = image(1, 48); // 3 tokens
    let img_b = image(2, 32); // 2 tokens
    // Ground truth, twice over: the fixed-batch forward path on the same
    // token chunks, and the exact digital reference walk. At zero noise
    // the three serving paths must agree f32-for-f32.
    let (want_a, want_b, ref_a, ref_b) = {
        let mut exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let want_a = pooled_fixed_batch(&mut exec, &img_a, 3);
        let want_b = pooled_fixed_batch(&mut exec, &img_b, 2);
        let ref_a = pool_tokens(&exec.reference_logits(&split_tokens(&img_a, 3)));
        let ref_b = pool_tokens(&exec.reference_logits(&split_tokens(&img_b, 2)));
        (want_a, want_b, ref_a, ref_b)
    };
    assert_eq!(want_a, ref_a, "fixed batch == exact reference (request a)");
    assert_eq!(want_b, ref_b, "fixed batch == exact reference (request b)");
    // Two distinct arrival interleavings → distinct wave compositions
    // (wave size 2 mixes the requests' tokens differently); at zero
    // noise both must still reproduce the reference exactly.
    for (order, label) in [([0usize, 1], "a then b"), ([1, 0], "b then a")] {
        let mut exec =
            ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let srv = test_server(2);
        let conn = srv.open_conn();
        for &r in &order {
            match r {
                0 => srv.handle_line(&stream_line(10, 3, &img_a), conn).unwrap(),
                _ => srv.handle_line(&stream_line(20, 2, &img_b), conn).unwrap(),
            };
        }
        let resps = drain_responses(&srv, &mut exec, conn, 2);
        assert_eq!(resps.len(), 2, "{label}");
        for j in &resps {
            let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
            let want = if id == 10 { &want_a } else { &want_b };
            let got = logits_of(j);
            let want_f64: Vec<f64> = want.iter().map(|&x| x as f64).collect();
            assert_eq!(got, want_f64, "{label}, request {id}");
            assert_eq!(
                j.get_path("tokens").unwrap().as_f64().unwrap(),
                if id == 10 { 3.0 } else { 2.0 },
                "{label}, request {id}"
            );
        }
    }
}

#[test]
fn vit_base_zero_noise_streamed_equals_fixed_batch_and_reference() {
    // The acceptance anchor at real scale: ViT-Base (12 blocks,
    // d_ff = 3072) on the paper's 1024-row geometry, probed at 1b so a
    // full pass stays test-sized. Two interleavings of two requests.
    let p = zero_noise(MacroParams::default());
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 1, &plan(1, 1));
    let img_a = image(3, 32); // 2 tokens
    let img_b = image(4, 16); // 1 token
    let (want_a, want_b) = {
        let mut exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let want_a = pooled_fixed_batch(&mut exec, &img_a, 2);
        let want_b = pooled_fixed_batch(&mut exec, &img_b, 1);
        // Anchor the fixed-batch truth to the exact reference walk.
        assert_eq!(want_a, pool_tokens(&exec.reference_logits(&split_tokens(&img_a, 2))));
        assert_eq!(want_b, pool_tokens(&exec.reference_logits(&split_tokens(&img_b, 1))));
        (want_a, want_b)
    };
    assert_eq!(want_a.len(), 768);
    for (order, label) in [([0usize, 1], "a then b"), ([1, 0], "b then a")] {
        let mut exec =
            ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let srv = test_server(2);
        let conn = srv.open_conn();
        for &r in &order {
            match r {
                0 => srv.handle_line(&stream_line(1, 2, &img_a), conn).unwrap(),
                _ => srv.handle_line(&stream_line(2, 1, &img_b), conn).unwrap(),
            };
        }
        let resps = drain_responses(&srv, &mut exec, conn, 2);
        for j in &resps {
            let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
            let want = if id == 1 { &want_a } else { &want_b };
            let want_f64: Vec<f64> = want.iter().map(|&x| x as f64).collect();
            assert_eq!(logits_of(j), want_f64, "{label}, request {id}");
        }
    }
}

#[test]
fn noisy_streamed_responses_are_thread_and_shard_invariant() {
    // The strong half of the contract: with real comparator noise and a
    // fixed request trace, the worker-thread count and the column-shard
    // split must be invisible to the streamed results, wave after wave.
    let mut p = tiny_params();
    p.sigma_cmp_lsb = 1.1;
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let img_a = image(5, 48);
    let img_b = image(6, 32);
    // 3 + 3 tokens over 2-token waves: every wave closes full, by size,
    // so the wave partition is a pure function of the request trace —
    // no deadline/aging path whose timing could vary between runs (the
    // generous max_wait keeps both switched off).
    let run = |threads: usize, shards: usize| -> Vec<(u64, Vec<f64>)> {
        let cfg = PipelineConfig { shards, attention_dies: 1, mlp_dies: 1 };
        let mut exec =
            ModelExecutor::new(&p.clone().with_threads(threads), graph.clone(), cfg).unwrap();
        let srv = server_with(2, 60_000);
        let conn = srv.open_conn();
        srv.handle_line(&stream_line(1, 3, &img_a), conn).unwrap();
        srv.handle_line(&stream_line(2, 3, &img_b), conn).unwrap();
        let mut got: Vec<(u64, Vec<f64>)> = drain_responses(&srv, &mut exec, conn, 2)
            .iter()
            .map(|j| (j.get_path("id").unwrap().as_f64().unwrap() as u64, logits_of(j)))
            .collect();
        got.sort_by_key(|(id, _)| *id);
        got
    };
    let one = run(1, 1);
    // shards = 40 > every tiny layer's minimum: a truly different grid.
    for (threads, shards) in [(4usize, 1usize), (1, 40), (4, 40)] {
        assert_eq!(run(threads, shards), one, "threads {threads} shards {shards}");
    }
    // Noise is actually present: the streamed walk deviates from the
    // zero-noise reference.
    let exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
    let quiet = pool_tokens(&exec.reference_logits(&split_tokens(&img_a, 3)));
    let quiet_f64: Vec<f64> = quiet.iter().map(|&x| x as f64).collect();
    assert_ne!(one[0].1, quiet_f64, "noisy streamed walk should deviate from exact");
}

#[test]
fn short_requests_complete_out_of_order_with_streaming_stats() {
    let p = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
    // Generous max_wait: all three waves close full, by size, so the
    // depth-fair order (not the aging fallback) governs deterministically.
    let srv = server_with(2, 60_000);
    let conn = srv.open_conn();
    // A long request (4 tokens) admitted before a short one (2 tokens):
    // depth-fair waves of 2 are {l0, s0}, {l1, s1}, {l2, l3} — the short
    // request's response lands a full wave before the long one's.
    srv.handle_line(&stream_line(100, 4, &image(7, 48)), conn).unwrap();
    srv.handle_line(&stream_line(200, 2, &image(8, 32)), conn).unwrap();
    assert_eq!(srv.executor_step(&mut exec), 0, "wave 1 completes nothing");
    assert!(srv.take_responses(conn).is_empty());
    assert_eq!(srv.executor_step(&mut exec), 1, "wave 2 completes the short request");
    let first = srv.take_responses(conn);
    assert_eq!(first.len(), 1);
    let j = json::parse(&first[0]).unwrap();
    assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 200.0);
    assert_eq!(j.get_path("waves").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(srv.executor_step(&mut exec), 1, "wave 3 completes the long request");
    let second = srv.take_responses(conn);
    assert_eq!(second.len(), 1);
    let j2 = json::parse(&second[0]).unwrap();
    assert_eq!(j2.get_path("id").unwrap().as_f64().unwrap(), 100.0);
    assert_eq!(j2.get_path("tokens").unwrap().as_f64().unwrap(), 4.0);
    assert_eq!(j2.get_path("waves").unwrap().as_f64().unwrap(), 3.0);
    // The stats report carries the streaming fields: all six tokens
    // served over three full waves, nothing left in flight.
    let stats = srv.ledger_json();
    assert_eq!(stats.get_path("stream_requests").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(stats.get_path("stream_tokens_served").unwrap().as_f64().unwrap(), 6.0);
    assert_eq!(stats.get_path("tokens_in_flight").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(stats.get_path("stream_waves").unwrap().as_f64().unwrap(), 3.0);
    let occ = stats.get_path("mean_wave_occupancy").unwrap().as_f64().unwrap();
    assert!((occ - 1.0).abs() < 1e-12, "all waves were full: {occ}");
    let p50 = stats.get_path("token_latency_p50_us").unwrap().as_f64().unwrap();
    let p99 = stats.get_path("token_latency_p99_us").unwrap().as_f64().unwrap();
    assert!(p50 > 0.0 && p99 >= p50);
}

#[test]
fn mixed_kinds_serve_side_by_side_with_streams() {
    // classify + forward + stream in one session: the batch tier and the
    // streaming tier share the executor loop without starving each other.
    let p = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
    let srv = test_server(2);
    let conn = srv.open_conn();
    let img = image(9, 32);
    let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    srv.handle_line(&format!(r#"{{"id": 1, "image": [{}]}}"#, body.join(", ")), conn).unwrap();
    srv.handle_line(
        &format!(r#"{{"id": 2, "kind": "forward", "image": [{}]}}"#, body.join(", ")),
        conn,
    )
    .unwrap();
    srv.handle_line(&stream_line(3, 2, &img), conn).unwrap();
    let resps = drain_responses(&srv, &mut exec, conn, 3);
    assert_eq!(resps.len(), 3);
    for j in &resps {
        assert!(j.get_path("pred").is_some(), "every kind answers: {j:?}");
        let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
        match id {
            2 => assert!(j.get_path("layers").is_some(), "forward reports layers"),
            3 => assert!(j.get_path("tokens").is_some(), "stream reports tokens"),
            _ => assert!(j.get_path("batch").is_some(), "classify reports batch"),
        }
    }
    // Both accounting tiers populated: batch requests and stream fields.
    let stats = srv.ledger_json();
    assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 2.0);
    assert_eq!(stats.get_path("stream_requests").unwrap().as_f64().unwrap(), 1.0);
}
