//! Autoregressive decode acceptance: the tentpole contract of the
//! decode tier (docs/ARCHITECTURE.md §Decode tier).
//!
//! 1. **Serving determinism**: a zero-noise `generate` request served
//!    through the continuous-batching tier is bit-identical to
//!    `ModelExecutor::reference_decode` — the schedule-free exact
//!    greedy walk — for every arrival interleaving × thread count ×
//!    overlap setting. The wave partition differs across interleavings;
//!    the produced tokens must not.
//! 2. **KV planning = KV measurement**: the scheduler's `plan_decode`
//!    replays the canonical KV trace on the same eviction policy the
//!    executor runs, so planned hits/misses/evictions equal the
//!    executor's measured counters for a warm multi-sequence run.

use std::time::Duration;

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::server::{BatchExecutor, Server, ServerConfig};
use cr_cim::coordinator::Scheduler;
use cr_cim::util::json::{self, Json};
use cr_cim::vit::graph::{GraphConfig, ModelGraph};
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn tiny_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

fn plan_2b() -> PrecisionPlan {
    let op = OperatingPoint::new(2, 2, CbMode::Off);
    PrecisionPlan { name: "decode probe", attention: op, mlp: op }
}

fn tiny_cfg() -> VitConfig {
    VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
}

fn decoder_graph() -> ModelGraph {
    ModelGraph::decoder(&GraphConfig { vit: tiny_cfg(), context: 8 }, &plan_2b())
}

fn generate_line(id: u64, prompt: &[u32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(
        r#"{{"id": {id}, "kind": "generate", "prompt": [{}], "max_new_tokens": {max_new}}}"#,
        toks.join(", ")
    )
}

/// A server whose waves close full, by size: the huge `max_wait` keeps
/// the deadline and aging paths switched off, so the wave partition is
/// a pure function of the admitted trace.
fn full_wave_server() -> Server {
    Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 4],
        max_wait: Duration::from_millis(60_000),
        wave_tokens: 2,
        max_waves: 2,
        ..ServerConfig::default()
    })
    .unwrap()
}

/// Step the executor until `want` responses are staged for `conn`.
fn drain_responses(
    srv: &Server,
    exec: &mut dyn BatchExecutor,
    conn: u64,
    want: usize,
) -> Vec<Json> {
    let mut out = Vec::new();
    for _ in 0..200 {
        srv.executor_step(exec);
        for line in srv.take_responses(conn) {
            out.push(json::parse(&line).unwrap());
        }
        if out.len() >= want {
            return out;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("server drained only {} of {want} responses", out.len());
}

fn generated_of(j: &Json) -> Vec<u32> {
    j.get_path("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u32)
        .collect()
}

const PROMPT_A: [u32; 3] = [3, 1, 2];
const PROMPT_B: [u32; 3] = [2, 0, 1];
const MAX_NEW: usize = 3;

#[test]
fn zero_noise_generate_matches_reference_for_interleavings_threads_overlap() {
    let base = tiny_params();
    let graph = decoder_graph();
    // Ground truth: the schedule-free exact greedy walk per prompt.
    let (want_a, want_b) = {
        let exec = ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        (exec.reference_decode(&PROMPT_A, MAX_NEW).0, exec.reference_decode(&PROMPT_B, MAX_NEW).0)
    };
    assert_eq!(want_a.len(), MAX_NEW);
    assert_eq!(want_b.len(), MAX_NEW);
    // Two arrival interleavings: A-then-B and B-then-A. They assign the
    // sequences opposite stream numbers, so item order inside every
    // shared wave flips — the produced tokens must not.
    let orders: [[(u64, &[u32]); 2]; 2] =
        [[(10, &PROMPT_A), (20, &PROMPT_B)], [(20, &PROMPT_B), (10, &PROMPT_A)]];
    for (oi, order) in orders.iter().enumerate() {
        for threads in [2usize, 4] {
            for overlap in [false, true] {
                let p = base.clone().with_threads(threads);
                let cfg =
                    PipelineConfig { shards: 2, attention_dies: 1, mlp_dies: 1, overlap };
                let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
                let srv = full_wave_server();
                let conn = srv.open_conn();
                for (id, prompt) in order {
                    srv.handle_line(&generate_line(*id, prompt, MAX_NEW), conn).unwrap();
                }
                let resps = drain_responses(&srv, &mut exec, conn, 2);
                assert_eq!(
                    resps.len(),
                    2,
                    "order {oi}, threads {threads}, overlap {overlap}"
                );
                for j in &resps {
                    let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
                    let want = if id == 10 { &want_a } else { &want_b };
                    assert_eq!(
                        &generated_of(j),
                        want,
                        "order {oi}, threads {threads}, overlap {overlap}, id {id}"
                    );
                }
            }
        }
    }
}

#[test]
fn planner_kv_replay_equals_measured_counters_for_warm_multi_sequence_run() {
    let base = tiny_params();
    let graph = decoder_graph();
    let capacity_bits: u64 = 1 << 20;
    let mut exec =
        ModelExecutor::new(&base.clone().with_threads(2), graph.clone(), PipelineConfig::default())
            .unwrap();
    exec.set_kv_capacity_bits(capacity_bits);
    let srv = full_wave_server();
    let conn = srv.open_conn();
    srv.handle_line(&generate_line(1, &PROMPT_A, MAX_NEW), conn).unwrap();
    srv.handle_line(&generate_line(2, &PROMPT_B, MAX_NEW), conn).unwrap();
    let resps = drain_responses(&srv, &mut exec, conn, 2);
    assert_eq!(resps.len(), 2);
    let measured = exec.gen_stats();
    // The planner replays the same trace shape (2 live sequences,
    // 3-token prompts, max_new − 1 decode feedbacks) on a fresh cache
    // with the identical eviction policy and capacity.
    let sched = Scheduler::new(&base);
    let planned = sched.plan_decode(&graph, 2, PROMPT_A.len(), MAX_NEW - 1, capacity_bits);
    assert_eq!(measured.kv_hits, planned.kv_hits, "planned vs measured KV hits");
    assert_eq!(measured.kv_misses, planned.kv_misses, "planned vs measured KV misses");
    assert_eq!(measured.kv_evictions, planned.kv_evictions, "planned vs measured KV evictions");
    assert!(measured.kv_hits > 0, "a warm run must hit the KV cache");
    assert_eq!(measured.kv_evictions, 0, "ample capacity must not evict");
    // Phase token accounting: both prompts prefilled in full, and each
    // sequence fed back max_new − 1 decode steps.
    assert_eq!(measured.prefill_tokens, (2 * PROMPT_A.len()) as u64);
    assert_eq!(measured.decode_tokens, (2 * (MAX_NEW - 1)) as u64);
}
