//! Integration tests for the 2-D tiled (row tiles × column shards) and
//! multi-die execution paths — the determinism/equivalence contract of
//! `docs/ARCHITECTURE.md`:
//!
//! 1. at zero noise, the tiled result equals the exact integer matvec at
//!    **any** (thread count × shard count × row-tile count × die count);
//! 2. with real noise, results are bit-identical at any thread count and
//!    at any column-shard count (global-column noise keying), and
//!    run-to-run reproducible;
//! 3. the output noise of digitally accumulated row tiles composes in
//!    quadrature against a single-tile calibration;
//! 4. the paper-geometry acceptance case: a ViT MLP fc2 layer
//!    (k = d_ff = 3072) runs on 1024-row macros across 3 row tiles and
//!    2 dies, exactly.

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::cim::CimMacro;
use cr_cim::coordinator::multidie::DieBank;
use cr_cim::coordinator::MacroShards;
use cr_cim::util::rng::Rng;
use cr_cim::vit::plan::OperatingPoint;

/// Small quiet (noise-free) geometry: 32-row tiles so row tiling kicks in
/// at tiny k.
fn quiet32() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 5;
    p.active_rows = 32;
    p.rows = 32;
    p.cols = 12;
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

/// 64-row variant used by the noise tests.
fn quiet64() -> MacroParams {
    let mut p = quiet32();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p
}

fn op_2b() -> OperatingPoint {
    OperatingPoint::new(2, 2, CbMode::Off)
}

fn tile(k: usize, n: usize, nvec: usize, seed: u64) -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
    let mut rng = Rng::new(seed);
    let w = (0..k).map(|_| (0..n).map(|_| rng.below(4) as i32 - 2).collect()).collect();
    let xs = (0..nvec).map(|_| (0..k).map(|_| rng.below(4) as i32 - 2).collect()).collect();
    (w, xs)
}

#[test]
fn zero_noise_tiled_equals_exact_on_the_full_grid() {
    let base = quiet32();
    // k = 80 on 32-row tiles (≥ 3 row tiles), 10 outputs at 2b (≥ 2
    // column shards on 12-column macros).
    let (w, xs) = tile(80, 10, 3, 101);
    let reference = CimMacro::ideal(&base).unwrap();
    let want: Vec<Vec<i64>> = xs.iter().map(|x| reference.matvec_exact(&w, x)).collect();
    for threads in [1usize, 4] {
        for shards in [1usize, 3, 5] {
            for tiles in [1usize, 5] {
                let p = base.clone().with_threads(threads);
                let mut bank = MacroShards::with_tiling(&p, &w, op_2b(), shards, tiles).unwrap();
                assert!(bank.row_tile_count() >= 3);
                assert!(bank.shard_count() >= 2);
                let got = bank.matvec_batch(&xs).unwrap();
                assert_eq!(
                    got, want,
                    "threads={threads} shards={shards} tiles={tiles}"
                );
            }
        }
    }
}

#[test]
fn noisy_results_are_thread_and_shard_invariant() {
    let mut p = quiet64();
    p.sigma_cmp_lsb = 1.1;
    p.sigma_cmp_offset_lsb = 0.5;
    p.sigma_cu_rel = 0.01;
    // k = 150: 3 row tiles; 6 outputs at 2b: up to 6 shards.
    let (w, xs) = tile(150, 6, 3, 103);
    let run = |threads: usize, shards: usize| {
        let pp = p.clone().with_threads(threads);
        let mut bank = MacroShards::new(&pp, &w, op_2b(), shards).unwrap();
        bank.matvec_batch(&xs).unwrap()
    };
    let baseline = run(1, 1);
    for threads in [1usize, 2, 8] {
        for shards in [1usize, 2, 6] {
            assert_eq!(run(threads, shards), baseline, "threads={threads} shards={shards}");
        }
    }
}

#[test]
fn noisy_tiled_runs_replay_exactly() {
    let mut p = quiet64();
    p.sigma_cmp_lsb = 1.1;
    p.sigma_cu_rel = 0.01;
    let (w, xs) = tile(200, 4, 3, 107);
    let run = || {
        let mut bank = MacroShards::with_tiling(&p, &w, op_2b(), 2, 4).unwrap();
        assert_eq!(bank.row_tile_count(), 4);
        bank.matvec_batch(&xs).unwrap()
    };
    assert_eq!(run(), run());
}

/// Per-output noise std around the per-output mean, rms'd over outputs,
/// measured by streaming `trials` copies of one activation vector (each
/// conversion draws fresh noise from its counter-keyed substream).
fn measured_noise_std(bank: &mut MacroShards, x: &[i32], trials: usize) -> f64 {
    let xs: Vec<Vec<i32>> = (0..trials).map(|_| x.to_vec()).collect();
    let ys = bank.matvec_batch(&xs).unwrap();
    let n = bank.n;
    let mut var_sum = 0.0;
    for j in 0..n {
        let vals: Vec<f64> = ys.iter().map(|y| y[j] as f64).collect();
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        var_sum += vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / (vals.len() - 1) as f64;
    }
    (var_sum / n as f64).sqrt()
}

#[test]
fn accumulated_tile_noise_composes_in_quadrature() {
    // Comparator noise only: per-conversion read noise is then identical
    // across tiles, so 4 accumulated tiles should show ~2x the output σ
    // of a single-tile calibration (independent per-tile substreams).
    let mut p = quiet64();
    p.sigma_cmp_lsb = 1.1;
    let (w1, _) = tile(64, 2, 0, 109);
    let (w4, _) = tile(256, 2, 0, 109);
    let x1: Vec<i32> = (0..64).map(|i| (i % 4) as i32 - 2).collect();
    let x4: Vec<i32> = (0..256).map(|i| (i % 4) as i32 - 2).collect();
    let mut one = MacroShards::new(&p, &w1, op_2b(), 1).unwrap();
    let mut four = MacroShards::new(&p, &w4, op_2b(), 1).unwrap();
    assert_eq!(one.row_tile_count(), 1);
    assert_eq!(four.row_tile_count(), 4);
    let trials = 128;
    let s1 = measured_noise_std(&mut one, &x1, trials);
    let s4 = measured_noise_std(&mut four, &x4, trials);
    assert!(s1 > 0.1, "single-tile calibration must see noise, got {s1}");
    let ratio = s4 / s1;
    assert!(
        (1.4..=2.7).contains(&ratio),
        "4-tile σ should be ~2x single-tile (quadrature), got {ratio:.2} (s1={s1:.2} s4={s4:.2})"
    );
    // The analytic bridge the SAC planner uses agrees exactly.
    assert!((four.kernel_sigma(1.0) / one.kernel_sigma(1.0) - 2.0).abs() < 1e-12);
}

#[test]
fn multi_die_grid_matches_exact_at_zero_noise() {
    let base = quiet32();
    let (w, xs) = tile(80, 5, 6, 113);
    let reference = CimMacro::ideal(&base).unwrap();
    let want: Vec<Vec<i64>> = xs.iter().map(|x| reference.matvec_exact(&w, x)).collect();
    for threads in [1usize, 4] {
        for dies in [1usize, 2, 4] {
            let p = base.clone().with_threads(threads);
            let mut bank = DieBank::new(&p, &w, op_2b(), 2, dies).unwrap();
            assert_eq!(bank.matvec_batch(&xs).unwrap(), want, "threads={threads} dies={dies}");
        }
    }
}

#[test]
fn vit_mlp_fc2_k3072_on_paper_geometry_across_dies() {
    // The acceptance case: d_ff = 3072 on the true 1088x78 / 1024-row
    // geometry needs exactly 3 row tiles and serves across 2 dies with
    // results equal to the exact integer matvec at zero noise.
    let mut p = MacroParams::default();
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    let (w, xs) = tile(3072, 8, 2, 127);
    let reference = CimMacro::ideal(&p).unwrap();
    let want: Vec<Vec<i64>> = xs.iter().map(|x| reference.matvec_exact(&w, x)).collect();
    let mut bank = DieBank::new(&p, &w, op_2b(), 2, 2).unwrap();
    assert_eq!(bank.die_count(), 2);
    assert_eq!(bank.row_tile_count(), 3);
    assert_eq!(bank.matvec_batch(&xs).unwrap(), want);
    // Thread count never changes the answer, even on the deep layer.
    let mut serial = DieBank::new(&p.clone().with_threads(1), &w, op_2b(), 2, 2).unwrap();
    assert_eq!(serial.matvec_batch(&xs).unwrap(), want);
}
