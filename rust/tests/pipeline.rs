//! Integration tests for the model-graph pipeline executor: the
//! determinism contract at graph scale (zero-noise equality with the
//! exact reference walk for any thread × shard × die-pool
//! decomposition, bit-identical noisy results across threads/shards),
//! and the ViT-Base end-to-end serving path with per-layer ledger
//! accounting.

use std::time::Duration;

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::server::{BatchExecutor, Server, ServerConfig};
use cr_cim::coordinator::Scheduler;
use cr_cim::util::json;
use cr_cim::vit::graph::ModelGraph;
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn zero_noise(mut p: MacroParams) -> MacroParams {
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

fn tiny_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    zero_noise(p)
}

fn plan(a_bits: u32, w_bits: u32) -> PrecisionPlan {
    let op = OperatingPoint::new(a_bits, w_bits, CbMode::Off);
    PrecisionPlan { name: "probe plan", attention: op, mlp: op }
}

/// d_ff = 96 > 64 active rows: fc2 row-tiles even on the tiny geometry.
fn tiny_cfg() -> VitConfig {
    VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
}

fn images(n: usize, floats: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..floats).map(|j| ((i * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect())
        .collect()
}

#[test]
fn zero_noise_full_pass_equals_reference_for_any_decomposition() {
    let base = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 2, &plan(2, 2));
    let imgs = images(3, 32);
    // The reference walk is decomposition-free by construction.
    let reference = {
        let exec =
            ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_ints(&exec.featurize_images(&imgs))
    };
    // shards = 40 exceeds every tiny layer's minimum shard count, so the
    // two shard settings instantiate genuinely different unit grids.
    for threads in [1usize, 4] {
        for shards in [1usize, 40] {
            for (att, mlp) in [(1usize, 1usize), (2, 1), (1, 2), (2, 2)] {
                let p = base.clone().with_threads(threads);
                let cfg =
                    PipelineConfig { shards, attention_dies: att, mlp_dies: mlp, overlap: true };
                let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
                let xs = exec.featurize_images(&imgs);
                let got = exec.forward_ints(&xs).unwrap();
                assert_eq!(
                    got, reference,
                    "threads {threads} shards {shards} pools ({att},{mlp})"
                );
                // Warm pass: the resident-weight cache reuses the
                // programmed pool banks — cache state may change when
                // reloads are priced, never what a conversion computes.
                let warm = exec.forward_ints(&xs).unwrap();
                assert_eq!(
                    warm, reference,
                    "warm pass, threads {threads} shards {shards} pools ({att},{mlp})"
                );
            }
        }
    }
}

#[test]
fn warm_pass_beats_cold_when_model_fits_and_matches_cold_when_evicted() {
    // Acceptance anchor: ViT-Base batch 8 under the paper SAC plan.
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
    // A deployment whose weight SRAM holds the whole model: the warm
    // (steady-state) pass is strictly below the cold pass and is exactly
    // conversion-bound.
    let fits = MacroParams::default().with_sram_bits(1 << 26);
    let sched = Scheduler::with_topology(&fits, 4, 2);
    let pp = sched.plan_graph(&graph);
    assert_eq!(pp.resident_layers(), 48);
    assert!(
        pp.warm_pipelined_ns < pp.pipelined_ns,
        "warm {} must beat cold {}",
        pp.warm_pipelined_ns,
        pp.pipelined_ns
    );
    let conv: f64 = pp.layers.iter().map(|t| t.compute_ns).sum();
    assert!((pp.warm_pipelined_ns - conv).abs() < 1e-9);
    // Capacity forcing full eviction: the warm pass pays every reload,
    // exactly the cold accounting.
    let evicted = Scheduler::with_topology(&MacroParams::default().with_sram_bits(0), 4, 2);
    let pe = evicted.plan_graph(&graph);
    assert_eq!(pe.resident_layers(), 0);
    assert!((pe.warm_pipelined_ns - pe.pipelined_ns).abs() < 1e-9);
    // The executor installs the same accounting (construction only
    // prices the graph; no silicon is built until a forward runs).
    let exec = ModelExecutor::new(
        &zero_noise(fits),
        graph,
        PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap: true },
    )
    .unwrap();
    let px = exec.pipeline();
    assert_eq!(px.resident_layers(), 48);
    assert!(px.warm_pipelined_ns < px.pipelined_ns);
    let r = exec.residency_stats();
    assert!((r.warm_pass_ns - px.warm_pipelined_ns).abs() < 1e-9);
    assert!((r.cold_pass_ns - px.pipelined_ns).abs() < 1e-9);
    assert!(r.capacity_bits > 0);
}

#[test]
fn resident_cache_skips_reloads_and_preserves_exact_outputs() {
    // An explicit budget that holds the whole tiny graph resident
    // (~74 kbit of weights against a ≥1 Mbit pool capacity).
    let p = tiny_params().with_sram_bits(1 << 20);
    let graph = ModelGraph::encoder(&tiny_cfg(), 2, &plan(2, 2));
    let mut exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
    let xs = exec.featurize_images(&images(3, 32));
    let want = exec.reference_ints(&xs);
    // Cold pass: every layer (re)programs its pool.
    assert_eq!(exec.forward_ints(&xs).unwrap(), want);
    let r1 = exec.residency_stats();
    assert_eq!((r1.reload_misses, r1.reload_hits), (8, 0));
    assert!(r1.resident_bits > 0 && r1.resident_bits <= r1.capacity_bits);
    assert!(r1.paid_reload_ns > 0.0);
    // Warm pass: every layer hits; outputs still equal the exact
    // reference walk.
    assert_eq!(exec.forward_ints(&xs).unwrap(), want);
    let r2 = exec.residency_stats();
    assert_eq!((r2.reload_misses, r2.reload_hits), (8, 8));
    assert_eq!(r2.evictions, 0);
    assert_eq!(r2.passes, 2);
    // Nothing new was paid on the warm pass, so the amortized reload
    // charge halves.
    assert!((r2.paid_reload_ns - r1.paid_reload_ns).abs() < 1e-9);
    assert!(r2.amortized_reload_ns() < r1.amortized_reload_ns());
    // Per-layer rows carry the hit/miss split, and the measured warm
    // hits match the planned steady-state residency flags.
    let costs = exec.layer_costs();
    assert!(costs.iter().all(|l| l.reload_hits == 1 && l.reload_misses == 1));
    assert!(exec.pipeline().layers.iter().all(|t| t.resident));
    assert!(exec.pipeline().warm_pipelined_ns < exec.pipeline().pipelined_ns);

    // A zero SRAM budget forces full eviction: no hits, warm == cold —
    // and the outputs are *still* byte-identical, pass after pass.
    let none = {
        let mut q = p.clone();
        q.sram_bits_per_macro = 0;
        q
    };
    let mut cold = ModelExecutor::new(&none, graph, PipelineConfig::default()).unwrap();
    let xs2 = cold.featurize_images(&images(3, 32));
    assert_eq!(cold.forward_ints(&xs2).unwrap(), want);
    assert_eq!(cold.forward_ints(&xs2).unwrap(), want);
    let rc = cold.residency_stats();
    assert_eq!((rc.reload_misses, rc.reload_hits), (16, 0));
    assert_eq!(rc.resident_bits, 0);
    let ppc = cold.pipeline();
    assert_eq!(ppc.resident_layers(), 0);
    assert!((ppc.warm_pipelined_ns - ppc.pipelined_ns).abs() < 1e-9);
}

#[test]
fn noisy_warm_passes_are_reproducible_and_counters_continue() {
    // Budget big enough that warm passes actually hit (resident dies).
    let mut p = tiny_params().with_sram_bits(1 << 20);
    p.sigma_cmp_lsb = 1.1;
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let run_two = || {
        let mut exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        let xs = exec.featurize_images(&images(2, 32));
        let cold = exec.forward_ints(&xs).unwrap();
        let warm = exec.forward_ints(&xs).unwrap();
        (cold, warm)
    };
    let (cold1, warm1) = run_two();
    let (cold2, warm2) = run_two();
    // Exactly reproducible for a fixed configuration and request
    // sequence — residency does not break determinism.
    assert_eq!(cold1, cold2);
    assert_eq!(warm1, warm2);
    // Resident silicon keeps converting: the warm pass draws the next
    // conversion noise instead of replaying the cold pass (the chip
    // does not reset between inferences).
    assert_ne!(cold1, warm1, "conversion counters must continue on resident dies");
}

#[test]
fn noisy_full_pass_is_bit_identical_across_threads_and_shards() {
    // The strong half of the contract at graph scale: with real
    // comparator noise, the thread count and the column-shard split are
    // invisible to the noise model — layer after layer.
    let mut p = tiny_params();
    p.sigma_cmp_lsb = 1.1;
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let imgs = images(2, 32);
    let run = |threads: usize, shards: usize| {
        let cfg = PipelineConfig { shards, attention_dies: 1, mlp_dies: 1, overlap: true };
        let mut exec =
            ModelExecutor::new(&p.clone().with_threads(threads), graph.clone(), cfg).unwrap();
        let xs = exec.featurize_images(&imgs);
        exec.forward_ints(&xs).unwrap()
    };
    let one = run(1, 1);
    // shards = 40 > every layer's minimum: a truly different shard grid.
    for (threads, shards) in [(4usize, 1usize), (1, 40), (4, 40)] {
        assert_eq!(run(threads, shards), one, "threads {threads} shards {shards}");
    }
    // Noise is actually present: the macro walk differs from exact.
    let exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
    let xs = exec.featurize_images(&imgs);
    assert_ne!(one, exec.reference_ints(&xs), "noisy walk should deviate from exact");
}

#[test]
fn vit_base_zero_noise_equals_reference_across_decompositions() {
    // The acceptance anchor at real scale: ViT-Base (12 blocks,
    // d_ff = 3072) on the paper's 1024-row geometry, probed at 1b so a
    // full pass stays test-sized. fc2 row-tiles 3×; qkv spans 30 column
    // shards; pools re-route layers onto per-class silicon — all of it
    // must collapse to the exact reference at zero noise.
    let p = zero_noise(MacroParams::default());
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 2, &plan(1, 1));
    let imgs = images(2, 32);
    let reference = {
        let exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_ints(&exec.featurize_images(&imgs))
    };
    assert_eq!(reference.len(), 2);
    assert!(reference.iter().all(|y| y.len() == 768));
    for cfg in [
        PipelineConfig { shards: 1, attention_dies: 1, mlp_dies: 1, overlap: false },
        PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap: true },
    ] {
        let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
        let xs = exec.featurize_images(&imgs);
        let got = exec.forward_ints(&xs).unwrap();
        assert_eq!(got, reference, "{cfg:?}");
    }
}

#[test]
fn vit_base_forward_serves_through_server_with_layer_ledger() {
    let p = zero_noise(MacroParams::default());
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 2, &plan(1, 1));
    // Router-sized pools over a 3-die budget: MLP mass dominates.
    let cfg = PipelineConfig::sized_by_router(&p, &graph, 2, 3);
    assert_eq!(cfg.attention_dies + cfg.mlp_dies, 3);
    let mut exec = ModelExecutor::new(&p, graph, cfg).unwrap();
    let srv = Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 4],
        max_wait: Duration::from_millis(1),
        wave_tokens: 2,
        max_waves: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let conn = srv.open_conn();
    for (i, img) in images(2, 16).iter().enumerate() {
        let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
        srv.handle_line(
            &format!(r#"{{"id": {i}, "kind": "forward", "image": [{}]}}"#, body.join(", ")),
            conn,
        )
        .unwrap();
    }
    std::thread::sleep(Duration::from_millis(3));
    assert_eq!(srv.executor_step(&mut exec), 2);
    let resps = srv.take_responses(conn);
    assert_eq!(resps.len(), 2);
    for r in &resps {
        let j = json::parse(r).unwrap();
        assert_eq!(j.get_path("layers").unwrap().as_f64().unwrap(), 48.0);
        let logits = j.get_path("logits").unwrap().as_arr().unwrap();
        assert_eq!(logits.len(), 768);
        assert!(logits.iter().all(|v| v.as_f64().unwrap().is_finite()));
        let pred = j.get_path("pred").unwrap().as_f64().unwrap();
        assert!((0.0..768.0).contains(&pred));
    }
    // Per-layer breakdown: 48 rows, every layer executed once, both
    // classes accounted, conversions and energy strictly positive.
    let stats = srv.ledger_json();
    assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 2.0);
    let layers = stats.get_path("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), 48);
    for l in layers {
        assert_eq!(l.get_path("calls").unwrap().as_f64().unwrap(), 1.0);
        assert!(l.get_path("conversions").unwrap().as_f64().unwrap() > 0.0);
        assert!(l.get_path("energy_uj").unwrap().as_f64().unwrap() > 0.0);
        assert!(l.get_path("reload_us").unwrap().as_f64().unwrap() > 0.0);
        // One pass so far: every layer was a reload miss.
        assert_eq!(l.get_path("reload_hits").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(l.get_path("reload_misses").unwrap().as_f64().unwrap(), 1.0);
    }
    // The residency snapshot rides the same stats report: 48 cold-pass
    // misses, the amortized reload charge, and the modeled cold/warm
    // full-pass latencies.
    assert_eq!(stats.get_path("reload_hits").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(stats.get_path("reload_misses").unwrap().as_f64().unwrap(), 48.0);
    assert!(stats.get_path("amortized_reload_us").unwrap().as_f64().unwrap() > 0.0);
    let cold = stats.get_path("cold_pass_us").unwrap().as_f64().unwrap();
    let warm = stats.get_path("warm_pass_us").unwrap().as_f64().unwrap();
    assert!(cold > 0.0 && warm > 0.0 && warm <= cold);
    let classes: Vec<&str> =
        layers.iter().map(|l| l.get_path("class").unwrap().as_str().unwrap()).collect();
    assert!(classes.contains(&"Transformer attention"));
    assert!(classes.contains(&"Transformer MLP"));
    assert_eq!(layers[0].get_path("layer").unwrap().as_str().unwrap(), "block0.qkv");
}

#[test]
fn reload_overlap_beats_serial_accounting_for_vit_base_batch8() {
    // Acceptance criterion, end to end: the Scheduler's pipelined
    // (double-buffered) reload latency is strictly below the serial
    // accounting for ViT-Base at batch 8 under the paper's SAC plan.
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
    let sched = Scheduler::with_topology(&MacroParams::default(), 4, 2);
    let pp = sched.plan_graph(&graph);
    assert!(
        pp.pipelined_ns < pp.serial_ns,
        "pipelined {} must beat serial {}",
        pp.pipelined_ns,
        pp.serial_ns
    );
    assert!(pp.overlap_saving() > 0.0);
    // The executor's installed cost is per-inference, priced with the
    // same reload-overlapped model; its full-batch pipeline keeps the
    // strict serial > pipelined ordering.
    let exec = ModelExecutor::new(
        &zero_noise(MacroParams::default()),
        graph,
        PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2, overlap: true },
    )
    .unwrap();
    let pp2 = exec.pipeline();
    assert!(pp2.pipelined_ns < pp2.serial_ns);
    // Per-inference latency ≤ the 8-image pass latency, and nonzero.
    assert!(exec.cost().total.latency_ns > 0.0);
    assert!(exec.cost().total.latency_ns < pp2.pipelined_ns);
}
