//! Integration tests for the model-graph pipeline executor: the
//! determinism contract at graph scale (zero-noise equality with the
//! exact reference walk for any thread × shard × die-pool
//! decomposition, bit-identical noisy results across threads/shards),
//! and the ViT-Base end-to-end serving path with per-layer ledger
//! accounting.

use std::time::Duration;

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::server::{BatchExecutor, Server, ServerConfig};
use cr_cim::coordinator::Scheduler;
use cr_cim::util::json;
use cr_cim::vit::graph::ModelGraph;
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn zero_noise(mut p: MacroParams) -> MacroParams {
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

fn tiny_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    zero_noise(p)
}

fn plan(a_bits: u32, w_bits: u32) -> PrecisionPlan {
    let op = OperatingPoint { a_bits, w_bits, cb: CbMode::Off };
    PrecisionPlan { name: "probe plan", attention: op, mlp: op }
}

/// d_ff = 96 > 64 active rows: fc2 row-tiles even on the tiny geometry.
fn tiny_cfg() -> VitConfig {
    VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
}

fn images(n: usize, floats: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| (0..floats).map(|j| ((i * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect())
        .collect()
}

#[test]
fn zero_noise_full_pass_equals_reference_for_any_decomposition() {
    let base = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 2, &plan(2, 2));
    let imgs = images(3, 32);
    // The reference walk is decomposition-free by construction.
    let reference = {
        let exec =
            ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_ints(&exec.featurize_images(&imgs))
    };
    // shards = 40 exceeds every tiny layer's minimum shard count, so the
    // two shard settings instantiate genuinely different unit grids.
    for threads in [1usize, 4] {
        for shards in [1usize, 40] {
            for (att, mlp) in [(1usize, 1usize), (2, 1), (1, 2), (2, 2)] {
                let p = base.clone().with_threads(threads);
                let cfg = PipelineConfig { shards, attention_dies: att, mlp_dies: mlp };
                let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
                let xs = exec.featurize_images(&imgs);
                let got = exec.forward_ints(&xs).unwrap();
                assert_eq!(
                    got, reference,
                    "threads {threads} shards {shards} pools ({att},{mlp})"
                );
            }
        }
    }
}

#[test]
fn noisy_full_pass_is_bit_identical_across_threads_and_shards() {
    // The strong half of the contract at graph scale: with real
    // comparator noise, the thread count and the column-shard split are
    // invisible to the noise model — layer after layer.
    let mut p = tiny_params();
    p.sigma_cmp_lsb = 1.1;
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    let imgs = images(2, 32);
    let run = |threads: usize, shards: usize| {
        let cfg = PipelineConfig { shards, attention_dies: 1, mlp_dies: 1 };
        let mut exec =
            ModelExecutor::new(&p.clone().with_threads(threads), graph.clone(), cfg).unwrap();
        let xs = exec.featurize_images(&imgs);
        exec.forward_ints(&xs).unwrap()
    };
    let one = run(1, 1);
    // shards = 40 > every layer's minimum: a truly different shard grid.
    for (threads, shards) in [(4usize, 1usize), (1, 40), (4, 40)] {
        assert_eq!(run(threads, shards), one, "threads {threads} shards {shards}");
    }
    // Noise is actually present: the macro walk differs from exact.
    let exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
    let xs = exec.featurize_images(&imgs);
    assert_ne!(one, exec.reference_ints(&xs), "noisy walk should deviate from exact");
}

#[test]
fn vit_base_zero_noise_equals_reference_across_decompositions() {
    // The acceptance anchor at real scale: ViT-Base (12 blocks,
    // d_ff = 3072) on the paper's 1024-row geometry, probed at 1b so a
    // full pass stays test-sized. fc2 row-tiles 3×; qkv spans 30 column
    // shards; pools re-route layers onto per-class silicon — all of it
    // must collapse to the exact reference at zero noise.
    let p = zero_noise(MacroParams::default());
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 2, &plan(1, 1));
    let imgs = images(2, 32);
    let reference = {
        let exec = ModelExecutor::new(&p, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_ints(&exec.featurize_images(&imgs))
    };
    assert_eq!(reference.len(), 2);
    assert!(reference.iter().all(|y| y.len() == 768));
    for cfg in [
        PipelineConfig { shards: 1, attention_dies: 1, mlp_dies: 1 },
        PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2 },
    ] {
        let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
        let xs = exec.featurize_images(&imgs);
        let got = exec.forward_ints(&xs).unwrap();
        assert_eq!(got, reference, "{cfg:?}");
    }
}

#[test]
fn vit_base_forward_serves_through_server_with_layer_ledger() {
    let p = zero_noise(MacroParams::default());
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 2, &plan(1, 1));
    // Router-sized pools over a 3-die budget: MLP mass dominates.
    let cfg = PipelineConfig::sized_by_router(&p, &graph, 2, 3);
    assert_eq!(cfg.attention_dies + cfg.mlp_dies, 3);
    let mut exec = ModelExecutor::new(&p, graph, cfg).unwrap();
    let srv = Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 4],
        max_wait: Duration::from_millis(1),
    })
    .unwrap();
    let conn = srv.open_conn();
    for (i, img) in images(2, 16).iter().enumerate() {
        let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
        srv.handle_line(
            &format!(r#"{{"id": {i}, "kind": "forward", "image": [{}]}}"#, body.join(", ")),
            conn,
        )
        .unwrap();
    }
    std::thread::sleep(Duration::from_millis(3));
    assert_eq!(srv.executor_step(&mut exec), 2);
    let resps = srv.take_responses(conn);
    assert_eq!(resps.len(), 2);
    for r in &resps {
        let j = json::parse(r).unwrap();
        assert_eq!(j.get_path("layers").unwrap().as_f64().unwrap(), 48.0);
        let logits = j.get_path("logits").unwrap().as_arr().unwrap();
        assert_eq!(logits.len(), 768);
        assert!(logits.iter().all(|v| v.as_f64().unwrap().is_finite()));
        let pred = j.get_path("pred").unwrap().as_f64().unwrap();
        assert!((0.0..768.0).contains(&pred));
    }
    // Per-layer breakdown: 48 rows, every layer executed once, both
    // classes accounted, conversions and energy strictly positive.
    let stats = srv.ledger_json();
    assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 2.0);
    let layers = stats.get_path("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), 48);
    for l in layers {
        assert_eq!(l.get_path("calls").unwrap().as_f64().unwrap(), 1.0);
        assert!(l.get_path("conversions").unwrap().as_f64().unwrap() > 0.0);
        assert!(l.get_path("energy_uj").unwrap().as_f64().unwrap() > 0.0);
        assert!(l.get_path("reload_us").unwrap().as_f64().unwrap() > 0.0);
    }
    let classes: Vec<&str> =
        layers.iter().map(|l| l.get_path("class").unwrap().as_str().unwrap()).collect();
    assert!(classes.contains(&"Transformer attention"));
    assert!(classes.contains(&"Transformer MLP"));
    assert_eq!(layers[0].get_path("layer").unwrap().as_str().unwrap(), "block0.qkv");
}

#[test]
fn reload_overlap_beats_serial_accounting_for_vit_base_batch8() {
    // Acceptance criterion, end to end: the Scheduler's pipelined
    // (double-buffered) reload latency is strictly below the serial
    // accounting for ViT-Base at batch 8 under the paper's SAC plan.
    let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
    let sched = Scheduler::with_topology(&MacroParams::default(), 4, 2);
    let pp = sched.plan_graph(&graph);
    assert!(
        pp.pipelined_ns < pp.serial_ns,
        "pipelined {} must beat serial {}",
        pp.pipelined_ns,
        pp.serial_ns
    );
    assert!(pp.overlap_saving() > 0.0);
    // The executor's installed cost is per-inference, priced with the
    // same reload-overlapped model; its full-batch pipeline keeps the
    // strict serial > pipelined ordering.
    let exec = ModelExecutor::new(
        &zero_noise(MacroParams::default()),
        graph,
        PipelineConfig { shards: 4, attention_dies: 2, mlp_dies: 2 },
    )
    .unwrap();
    let pp2 = exec.pipeline();
    assert!(pp2.pipelined_ns < pp2.serial_ns);
    // Per-inference latency ≤ the 8-image pass latency, and nonzero.
    assert!(exec.cost().total.latency_ns > 0.0);
    assert!(exec.cost().total.latency_ns < pp2.pipelined_ns);
}
