//! Schedule-perturbation acceptance tests: the dynamic half of the
//! determinism contract.
//!
//! The static analyzer (`crcim lint`) rules out the *sources* of
//! schedule sensitivity (unordered maps, ad-hoc RNG, raw float
//! reductions, lock-order inversions); these tests attack the *effect*
//! directly. `util::pool::perturb` injects seeded bursts of
//! `thread::yield_now()` at every worker-pool task boundary and queue
//! transfer, forcing worker interleavings the OS scheduler would only
//! produce under rare load. Under every perturbation seed, every
//! thread-grid point and **both overlap settings** (the staged
//! wavefront engine on and off), the zero-noise pipeline and the
//! streaming server must reproduce the exact reference walk
//! bit-for-bit — with yield bursts injected at the pipelined engine's
//! program/convert stage boundaries and at every queue transfer.
//!
//! The decode tier rides the same harness: autoregressive `generate`
//! serving feeds every produced token back through the wave queue, so
//! yield injection at decode-step boundaries perturbs the prefill →
//! decode handoff and the continuous-batching coalescer. Zero-noise
//! generation must still be bit-identical to the schedule-free
//! [`ModelExecutor::reference_decode`] walk, and a mid-generation
//! disconnect must settle in-flight decode tokens without poisoning
//! the wave the other sequences share.

use std::time::Duration;

use cr_cim::cim::params::{CbMode, MacroParams};
use cr_cim::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use cr_cim::coordinator::server::{BatchExecutor, Server, ServerConfig};
use cr_cim::coordinator::stream::{pool_tokens, split_tokens};
use cr_cim::coordinator::sweep::set_votes;
use cr_cim::util::json::{self, Json};
use cr_cim::util::pool::perturb;
use cr_cim::vit::graph::{GraphConfig, ModelGraph};
use cr_cim::vit::plan::{OperatingPoint, PrecisionPlan};
use cr_cim::vit::VitConfig;

fn tiny_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

fn plan(a_bits: u32, w_bits: u32) -> PrecisionPlan {
    let op = OperatingPoint::new(a_bits, w_bits, CbMode::Off);
    PrecisionPlan { name: "perturb probe", attention: op, mlp: op }
}

/// d_ff = 96 > 64 active rows: fc2 row-tiles even on the tiny geometry.
fn tiny_cfg() -> VitConfig {
    VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
}

fn image(seed: usize, floats: usize) -> Vec<f32> {
    (0..floats).map(|j| ((seed * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
}

fn images(n: usize, floats: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| image(i + 11, floats)).collect()
}

#[test]
fn perturbed_pipeline_matches_reference_across_seeds_and_threads() {
    let base = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 2, &plan(2, 2));
    let imgs = images(3, 32);
    // The reference walk is schedule-free by construction.
    let reference = {
        let exec = ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_ints(&exec.featurize_images(&imgs))
    };
    let before = perturb::injected_yields();
    let mut overlapped_yields = 0u64;
    for seed in [1u64, 7, 99] {
        for threads in [2usize, 4] {
            for overlap in [false, true] {
                let p = base.clone().with_threads(threads);
                let cfg =
                    PipelineConfig { shards: 2, attention_dies: 2, mlp_dies: 1, overlap };
                let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
                let xs = exec.featurize_images(&imgs);
                let at = perturb::injected_yields();
                let got = perturb::with_seed(seed, || exec.forward_ints(&xs).unwrap());
                if overlap {
                    overlapped_yields += perturb::injected_yields() - at;
                }
                assert_eq!(
                    got, reference,
                    "perturb seed {seed}, threads {threads}, overlap {overlap}"
                );
                // Multi-wave submission through the same engine: two
                // waves in flight must equal two sequential passes.
                let many = perturb::with_seed(seed, || {
                    exec.forward_ints_many(&[xs.clone(), xs.clone()])
                });
                for got in many {
                    assert_eq!(
                        got.unwrap(),
                        reference,
                        "multi-wave, seed {seed}, threads {threads}, overlap {overlap}"
                    );
                }
            }
        }
    }
    // The harness actually fired: yields were injected at task boundaries.
    assert!(
        perturb::injected_yields() > before,
        "perturbation sections must inject at least one yield"
    );
    // The pipelined engine's only perturbation hooks are the program /
    // convert stage boundaries and the work-queue transfers, so armed
    // overlapped runs prove the new boundaries are exercised.
    assert!(
        overlapped_yields > 0,
        "overlapped runs must inject yields at program/convert stage boundaries"
    );
}

#[test]
fn zero_noise_outputs_are_invariant_across_vote_assignments() {
    let base = tiny_params();
    // CB on: the per-layer vote point controls the boosted trailing
    // comparisons, so this grid exercises majority voting inside the
    // conversion path itself — at zero noise every vote count must
    // reproduce the exact reference walk bit for bit, under the same
    // schedule perturbations as the rest of the campaign.
    let op = OperatingPoint::new(2, 2, CbMode::On);
    let cb_plan = PrecisionPlan { name: "vote probe", attention: op, mlp: op };
    let graph = ModelGraph::encoder(&tiny_cfg(), 2, &cb_plan);
    let imgs = images(3, 32);
    // The reference is vote-independent: votes only repeat comparator
    // decisions, and at sigma = 0 every repeat is identical.
    let reference = {
        let exec = ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_ints(&exec.featurize_images(&imgs))
    };
    let layer_count = graph.layer_count();
    let ladder = [1u32, 2, 6, 12];
    let assignments: Vec<Vec<u32>> = vec![
        vec![1; layer_count],
        vec![12; layer_count],
        (0..layer_count).map(|i| ladder[i % ladder.len()]).collect(),
    ];
    for votes in &assignments {
        let mut g = graph.clone();
        set_votes(&mut g, votes, 3);
        for seed in [1u64, 7] {
            for threads in [2usize, 4] {
                for overlap in [false, true] {
                    let p = base.clone().with_threads(threads);
                    let cfg =
                        PipelineConfig { shards: 2, attention_dies: 2, mlp_dies: 1, overlap };
                    let mut exec = ModelExecutor::new(&p, g.clone(), cfg).unwrap();
                    let xs = exec.featurize_images(&imgs);
                    let got = perturb::with_seed(seed, || exec.forward_ints(&xs).unwrap());
                    assert_eq!(
                        got, reference,
                        "votes {votes:?}, seed {seed}, threads {threads}, overlap {overlap}"
                    );
                }
            }
        }
    }
    // The decode tier rides the same invariance: generation through a
    // vote-reassigned CB-on decoder equals the exact greedy reference.
    let mut dg = ModelGraph::decoder(&GraphConfig { vit: tiny_cfg(), context: 8 }, &cb_plan);
    let prompt = [3u32, 1, 2];
    let want = {
        let exec = ModelExecutor::new(&base, dg.clone(), PipelineConfig::default()).unwrap();
        exec.reference_decode(&prompt, 3).0
    };
    let votes: Vec<u32> =
        (0..dg.layer_count()).map(|i| ladder[(i + 1) % ladder.len()]).collect();
    set_votes(&mut dg, &votes, 3);
    let p = base.clone().with_threads(2);
    let cfg = PipelineConfig { shards: 2, attention_dies: 1, mlp_dies: 1, overlap: true };
    let mut exec = ModelExecutor::new(&p, dg, cfg).unwrap();
    let srv = Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 4],
        max_wait: Duration::from_millis(60_000),
        wave_tokens: 2,
        max_waves: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let conn = srv.open_conn();
    let resps = perturb::with_seed(5, || {
        srv.handle_line(&generate_line(10, &prompt, 3), conn).unwrap();
        drain_responses(&srv, &mut exec, conn, 1)
    });
    assert_eq!(generated_of(&resps[0]), want, "generate must be vote-invariant at zero noise");
}

fn stream_line(id: usize, tokens: usize, img: &[f32]) -> String {
    let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    format!(
        r#"{{"id": {id}, "kind": "stream", "tokens": {tokens}, "image": [{}]}}"#,
        body.join(", ")
    )
}

/// Drain the server: step until every expected response is staged.
fn drain_responses(
    srv: &Server,
    exec: &mut dyn BatchExecutor,
    conn: u64,
    want: usize,
) -> Vec<Json> {
    let mut out = Vec::new();
    for _ in 0..200 {
        srv.executor_step(exec);
        for line in srv.take_responses(conn) {
            out.push(json::parse(&line).unwrap());
        }
        if out.len() >= want {
            return out;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("server drained only {} of {want} responses", out.len());
}

fn logits_of(j: &Json) -> Vec<f64> {
    j.get_path("logits").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect()
}

#[test]
fn perturbed_stream_matches_reference_across_seeds_and_threads() {
    let base = tiny_params();
    let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan(2, 2));
    // 3 + 3 tokens over 2-token waves: every wave closes full, by size,
    // so the wave partition is a pure function of the request trace and
    // the generous max_wait keeps the deadline/aging paths switched off.
    let img_a = image(1, 48); // 3 tokens
    let img_b = image(2, 48); // 3 tokens
    // Ground truth: the exact reference walk, mean-pooled per request.
    let (want_a, want_b) = {
        let exec = ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        let a = pool_tokens(&exec.reference_logits(&split_tokens(&img_a, 3)));
        let b = pool_tokens(&exec.reference_logits(&split_tokens(&img_b, 3)));
        (a, b)
    };
    // Seed 0 is the disarmed control: the same code path with no
    // injected yields must agree with every armed run. `max_waves: 2`
    // keeps both conversion waves of the trace in flight at once, so
    // the campaign also covers multi-wave pipelined serving.
    for seed in [0u64, 1, 2, 3] {
        for threads in [2usize, 4] {
            for overlap in [false, true] {
                let p = base.clone().with_threads(threads);
                let cfg =
                    PipelineConfig { shards: 2, attention_dies: 1, mlp_dies: 1, overlap };
                let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
                let srv = Server::new(&ServerConfig {
                    addr: "unused".into(),
                    batch_sizes: vec![1, 4],
                    max_wait: Duration::from_millis(60_000),
                    wave_tokens: 2,
                    max_waves: 2,
                    ..ServerConfig::default()
                })
                .unwrap();
                let conn = srv.open_conn();
                let resps = perturb::with_seed(seed, || {
                    srv.handle_line(&stream_line(10, 3, &img_a), conn).unwrap();
                    srv.handle_line(&stream_line(20, 3, &img_b), conn).unwrap();
                    drain_responses(&srv, &mut exec, conn, 2)
                });
                assert_eq!(resps.len(), 2, "seed {seed}, threads {threads}, overlap {overlap}");
                for j in &resps {
                    let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
                    let want = if id == 10 { &want_a } else { &want_b };
                    let want_f64: Vec<f64> = want.iter().map(|&x| x as f64).collect();
                    assert_eq!(
                        logits_of(j),
                        want_f64,
                        "seed {seed}, threads {threads}, overlap {overlap}, id {id}"
                    );
                }
            }
        }
    }
}

fn generate_line(id: usize, prompt: &[u32], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(
        r#"{{"id": {id}, "kind": "generate", "prompt": [{}], "max_new_tokens": {max_new}}}"#,
        toks.join(", ")
    )
}

fn generated_of(j: &Json) -> Vec<u32> {
    j.get_path("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u32)
        .collect()
}

fn decoder_graph() -> ModelGraph {
    ModelGraph::decoder(&GraphConfig { vit: tiny_cfg(), context: 8 }, &plan(2, 2))
}

#[test]
fn perturbed_generate_matches_reference_across_seeds_and_threads() {
    let base = tiny_params();
    let graph = decoder_graph();
    let prompt_a = [3u32, 1, 2];
    let prompt_b = [2u32, 0, 1];
    // Ground truth: the schedule-free exact greedy walk per prompt.
    let (want_a, want_b) = {
        let exec = ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        (exec.reference_decode(&prompt_a, 3).0, exec.reference_decode(&prompt_b, 3).0)
    };
    // Equal-length prompts decode in lockstep, so every wave — prefill
    // and decode feedback alike — closes full, by size, and the wave
    // partition stays a pure function of the trace under perturbation.
    // Seed 0 is the disarmed control.
    for seed in [0u64, 5, 11] {
        for threads in [2usize, 4] {
            for overlap in [false, true] {
                let p = base.clone().with_threads(threads);
                let cfg =
                    PipelineConfig { shards: 2, attention_dies: 1, mlp_dies: 1, overlap };
                let mut exec = ModelExecutor::new(&p, graph.clone(), cfg).unwrap();
                let srv = Server::new(&ServerConfig {
                    addr: "unused".into(),
                    batch_sizes: vec![1, 4],
                    max_wait: Duration::from_millis(60_000),
                    wave_tokens: 2,
                    max_waves: 2,
                    ..ServerConfig::default()
                })
                .unwrap();
                let conn = srv.open_conn();
                let resps = perturb::with_seed(seed, || {
                    srv.handle_line(&generate_line(10, &prompt_a, 3), conn).unwrap();
                    srv.handle_line(&generate_line(20, &prompt_b, 3), conn).unwrap();
                    drain_responses(&srv, &mut exec, conn, 2)
                });
                assert_eq!(resps.len(), 2, "seed {seed}, threads {threads}, overlap {overlap}");
                for j in &resps {
                    let id = j.get_path("id").unwrap().as_f64().unwrap() as u64;
                    let want = if id == 10 { &want_a } else { &want_b };
                    assert_eq!(
                        &generated_of(j),
                        want,
                        "seed {seed}, threads {threads}, overlap {overlap}, id {id}"
                    );
                }
            }
        }
    }
}

#[test]
fn mid_generation_disconnect_settles_without_poisoning_the_wave() {
    let base = tiny_params();
    let graph = decoder_graph();
    let prompt_a = [3u32, 1, 2];
    let prompt_b = [2u32, 2, 1];
    let want_a = {
        let exec = ModelExecutor::new(&base, graph.clone(), PipelineConfig::default()).unwrap();
        exec.reference_decode(&prompt_a, 3).0
    };
    let p = base.clone().with_threads(2);
    let cfg = PipelineConfig { shards: 2, attention_dies: 1, mlp_dies: 1, overlap: true };
    let mut exec = ModelExecutor::new(&p, graph, cfg).unwrap();
    // Short deadline: once B is gone, A's solo decode feedbacks close
    // partial waves by deadline rather than wedging behind wave_tokens.
    let srv = Server::new(&ServerConfig {
        addr: "unused".into(),
        batch_sizes: vec![1, 4],
        max_wait: Duration::from_millis(2),
        wave_tokens: 2,
        max_waves: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let conn_a = srv.open_conn();
    let conn_b = srv.open_conn();
    let resps = perturb::with_seed(7, || {
        srv.handle_line(&generate_line(10, &prompt_a, 3), conn_a).unwrap();
        srv.handle_line(&generate_line(20, &prompt_b, 3), conn_b).unwrap();
        // Run one step so both sequences are mid-flight (prefill waves
        // formed, possibly executing), then drop B's connection.
        std::thread::sleep(Duration::from_millis(4));
        srv.executor_step(&mut exec);
        srv.close_conn(conn_b);
        drain_responses(&srv, &mut exec, conn_a, 1)
    });
    let j = &resps[0];
    assert_eq!(j.get_path("id").unwrap().as_f64().unwrap() as u64, 10);
    assert!(j.get_path("error").is_none(), "survivor must finish cleanly: {:?}", j.get_path("error"));
    assert_eq!(generated_of(j), want_a, "survivor output must match the reference walk");
    // The purged sequence never stages output on the dead connection.
    assert!(srv.take_responses(conn_b).is_empty());
    // The disconnect released B's admission permit and sequence state:
    // a fresh generate on the surviving connection is admitted and
    // completes with the same reference output.
    let again = perturb::with_seed(9, || {
        srv.handle_line(&generate_line(11, &prompt_a, 3), conn_a).unwrap();
        drain_responses(&srv, &mut exec, conn_a, 1)
    });
    assert_eq!(generated_of(&again[0]), want_a, "server must keep serving after a disconnect");
}
