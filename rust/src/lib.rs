//! # CR-CIM: Capacitor-Reconfiguring Computing-in-Memory for Transformers
//!
//! Reproduction of "An 818-TOPS/W CSNR-31dB SQNR-45dB 10-bit
//! Capacitor-Reconfiguring Computing-in-Memory Macro with Software-Analog
//! Co-Design for Transformers" (K. Yoshioka, 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordinator: tile scheduler, SAC (CSNR
//!   boost) policy engine, batcher, power/latency ledger, request server —
//!   plus the circuit-level macro simulator that stands in for the 65 nm
//!   silicon, the metric definitions (CSNR/SQNR/INL/FoM), and a PJRT
//!   runtime that executes the AOT-compiled ViT.
//! - **L2 (python/compile/model.py)** — the ViT forward pass in JAX,
//!   calling the L1 kernel; lowered once to HLO text at build time.
//! - **L1 (python/compile/kernels/)** — the behavioral-CIM matmul as a
//!   Pallas kernel, validated against a pure-jnp oracle.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured numbers.

pub mod cim;
pub mod coordinator;
pub mod metrics;
pub mod runtime;
pub mod util;
pub mod vit;
pub mod workload;

/// Crate version (from Cargo).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
