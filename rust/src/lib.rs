//! # CR-CIM: Capacitor-Reconfiguring Computing-in-Memory for Transformers
//!
//! Reproduction of "An 818-TOPS/W CSNR-31dB SQNR-45dB 10-bit
//! Capacitor-Reconfiguring Computing-in-Memory Macro with Software-Analog
//! Co-Design for Transformers" (K. Yoshioka, 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordinator: tile scheduler, SAC (CSNR
//!   boost) policy engine, batcher, power/latency ledger, request server —
//!   plus the circuit-level macro simulator that stands in for the 65 nm
//!   silicon, the metric definitions (CSNR/SQNR/INL/FoM), and a PJRT
//!   runtime that executes the AOT-compiled ViT.
//! - **L2 (python/compile/model.py)** — the ViT forward pass in JAX,
//!   calling the L1 kernel; lowered once to HLO text at build time.
//! - **L1 (python/compile/kernels/)** — the behavioral-CIM matmul as a
//!   Pallas kernel, validated against a pure-jnp oracle.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured numbers.
//!
//! ## Parallel execution model
//!
//! The macro simulator is **column-parallel and deterministic**: the chip
//! converts every used column in the same cycle, and the simulator mirrors
//! that by fanning the `n_out × w_bits` column conversions of a matvec
//! across a worker pool (`MacroParams::threads`, 0 = auto). Layers larger
//! than one tile run through the **2-D tiling executor**
//! (`coordinator::MacroShards`): outputs split into column shards,
//! reduction dimensions deeper than `active_rows` (every ViT MLP `fc2`,
//! d_ff = 3072) split into row tiles whose partial sums accumulate
//! digitally with quadrature noise composition; a multi-die tier
//! (`coordinator::DieBank`) routes served batches across independent
//! dies.
//!
//! The unit of served work is a **model graph** (`vit::ModelGraph`):
//! the pipeline executor (`coordinator::ModelExecutor`) walks the ViT
//! encoder's per-block qkv / attn-proj / fc1 / fc2 linears, drawing
//! macros from **per-layer-class die pools** (attention and MLP classes
//! own disjoint silicon), keeping programmed pool dies **resident**
//! across passes in an LRU weight cache bounded by
//! `MacroParams::sram_bits_per_macro`, and pricing each layer's weight
//! reload double-buffered behind the previous layer's conversions —
//! cold (every layer reloads) and warm (resident layers skip it)
//! (`coordinator::Scheduler::plan_graph`). The server's `forward`
//! request kind runs a whole encoder pass with a per-layer ledger
//! breakdown plus reload hit/miss and amortized-reload accounting.
//!
//! The determinism contract is the substream hierarchy
//! `seed → class pool → die → row tile → global column → conversion
//! counter`: every RNG consumer owns a splittable substream, so
//! **results are bit-identical at any worker-thread count and at any
//! column-shard count** (the shard split is invisible to the noise
//! model), and equal to the exact integer matvec — or, for a graph, the
//! exact reference walk — at zero noise for any decomposition.
//! Monte-Carlo sweeps (`cim::montecarlo`), CSNR calibration
//! (`coordinator::NoiseCalibration`) and the serving path
//! (`coordinator::SimExecutor`, `coordinator::ModelExecutor`) all ride
//! the same engine. See `docs/ARCHITECTURE.md` for the full layer map,
//! tiling and pipeline model.
//!
//! The PJRT runtime (`runtime`) is gated behind the `pjrt` cargo feature
//! because the `xla` / `anyhow` crates are only present in images that
//! vendor them; the simulator, coordinator and metrics layers are
//! dependency-free.
//!
//! The contract above is *enforced mechanically* by the [`analysis`]
//! module (`crcim lint`): six lexer-level rules — RNG discipline, no
//! hash-ordered containers in compute modules, wall-clock hygiene, a
//! declared lock-order table, fixed-order float reduction, and
//! `SAFETY`-justified `unsafe` — plus the schedule-perturbation harness
//! in [`util::pool::perturb`] that proves results bit-identical under
//! adversarial thread interleavings. See the "Determinism enforcement"
//! section of `docs/ARCHITECTURE.md`.

// Unsafe is deny (not forbid) because the scoped worker pool needs two
// audited sites (`util::pool::SendPtr`); each carries a `// SAFETY:`
// justification and a per-site `#[allow]`, checked by `crcim lint`.
#![deny(unsafe_code)]

pub mod analysis;
pub mod cim;
pub mod coordinator;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod util;
pub mod vit;
pub mod workload;

/// Crate version (from Cargo).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
