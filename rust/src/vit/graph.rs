//! Typed ViT encoder layer graph: the unit of work for the model-graph
//! pipeline executor.
//!
//! The serving stack's unit of work used to be a single linear layer;
//! the paper's headline result, however, is an *end-to-end* ViT forward
//! pass with per-layer software-analog co-design (attention 4b wo/CB,
//! MLP 6b w/CB). [`ModelGraph`] captures that pass as a typed chain of
//! the macro-mapped operators — per-block `qkv`, `attn_proj`, `fc1`,
//! `fc2` linears — each carrying its [`LinearShape`], its
//! [`LayerClass`] and the [`OperatingPoint`] the precision plan
//! resolves for that class. Softmax, GELU and layernorm run in the
//! digital periphery between linears and are not macro work; the
//! pipeline executor models them as a deterministic digital
//! re-quantization (see `coordinator::pipeline`).
//!
//! The graph is consumed by three tiers that previously disagreed about
//! layer decomposition:
//! - `coordinator::Scheduler::plan_graph` — full-pass latency with
//!   serial vs double-buffered weight reloads;
//! - `coordinator::Router::route` — LPT placement of every
//!   (row tile × column tile) unit;
//! - `coordinator::pipeline::ModelExecutor` — simulated execution
//!   through per-layer-class die pools.

use crate::cim::netstats::LayerClass;
use crate::vit::plan::{OperatingPoint, PrecisionPlan};
use crate::vit::{LinearShape, VitConfig};

/// Role of one linear layer inside an encoder block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerRole {
    /// Fused query/key/value projection (d → 3d), attention class.
    Qkv,
    /// Attention output projection (d → d), attention class.
    AttnProj,
    /// MLP expansion (d → d_ff), MLP class.
    Fc1,
    /// MLP contraction (d_ff → d), MLP class — the deep-reduction layer
    /// that forces row tiling on the 1024-row macro whenever d_ff > 1024.
    Fc2,
}

impl LayerRole {
    pub fn label(self) -> &'static str {
        match self {
            LayerRole::Qkv => "qkv",
            LayerRole::AttnProj => "attn_proj",
            LayerRole::Fc1 => "fc1",
            LayerRole::Fc2 => "fc2",
        }
    }

    /// SAC class of the role (which operating point it draws from a plan).
    pub fn class(self) -> LayerClass {
        match self {
            LayerRole::Qkv | LayerRole::AttnProj => LayerClass::TransformerAttention,
            LayerRole::Fc1 | LayerRole::Fc2 => LayerClass::TransformerMlp,
        }
    }

    /// The four roles of one encoder block, in execution order.
    pub fn block_order() -> [LayerRole; 4] {
        [LayerRole::Qkv, LayerRole::AttnProj, LayerRole::Fc1, LayerRole::Fc2]
    }
}

/// One linear layer of the model graph: shape plus the operating point
/// the SAC plan resolved for its class at graph-build time.
#[derive(Clone, Debug)]
pub struct GraphLayer {
    /// Position in the execution order (0-based across the whole graph).
    pub index: usize,
    /// Encoder block this layer belongs to (0-based).
    pub block: usize,
    pub role: LayerRole,
    /// Layer shape; `shape.m` is the true per-pass activation stream
    /// (batch × tokens) — the quantity the `Scheduler` prices.
    pub shape: LinearShape,
    /// Operating point (bits + CB mode) resolved from the plan.
    pub op: OperatingPoint,
    /// Maximum attention context for decoder graphs: 0 on encoder
    /// layers (shapes are position-independent), > 0 on decoder
    /// attention-class layers, whose effective decode-time work grows
    /// with the sequence position up to this bound (the KV window).
    pub context: usize,
}

impl GraphLayer {
    /// Stable display name, e.g. `block3.fc2`.
    pub fn name(&self) -> String {
        format!("block{}.{}", self.block, self.role.label())
    }

    /// Effective shape of this layer at decode position `pos` (0-based).
    /// Encoder layers (`context == 0`) are position-independent.
    /// Decoder attention layers fold the sequence's KV state over all
    /// prior positions, so their effective activation stream at position
    /// `pos` is `min(pos + 1, context)` vectors — the quantity
    /// `Scheduler::plan_decode` prices per step. MLP layers stay one
    /// vector per step regardless of position.
    pub fn shape_at(&self, pos: usize) -> LinearShape {
        if self.context == 0 {
            return self.shape;
        }
        let mut s = self.shape;
        s.m = (pos + 1).min(self.context).max(1);
        s
    }
}

/// Decoder graph configuration: the model hyperparameters plus the
/// attention-context bound carried by the decoder's attention layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GraphConfig {
    pub vit: VitConfig,
    /// Maximum sequence positions of per-sequence KV state (the window
    /// `GraphLayer::shape_at` saturates at).
    pub context: usize,
}

impl GraphConfig {
    /// The canonical decoder target: ViT-Base-scale blocks repurposed as
    /// a causal decoder with a 256-position context window.
    pub fn decoder_base() -> Self {
        GraphConfig { vit: VitConfig::vit_base(), context: 256 }
    }
}

/// The typed layer graph of a ViT encoder under a precision plan: a
/// linear chain of `4 × depth` macro-mapped linears.
#[derive(Clone, Debug)]
pub struct ModelGraph {
    pub cfg: VitConfig,
    /// Images per forward pass.
    pub batch: usize,
    /// Name of the precision plan the operating points came from.
    pub plan_name: &'static str,
    /// Layers in execution order.
    pub layers: Vec<GraphLayer>,
}

impl ModelGraph {
    /// Build the encoder graph: `depth` blocks × (qkv, attn-proj, fc1,
    /// fc2), each layer carrying its class's operating point from `plan`.
    pub fn encoder(cfg: &VitConfig, batch: usize, plan: &PrecisionPlan) -> Self {
        let d = cfg.dim;
        let batch = batch.max(1);
        let m = batch * cfg.tokens();
        let mut layers = Vec::with_capacity(4 * cfg.depth);
        for block in 0..cfg.depth {
            for role in LayerRole::block_order() {
                let (k, n) = match role {
                    LayerRole::Qkv => (d, 3 * d),
                    LayerRole::AttnProj => (d, d),
                    LayerRole::Fc1 => (d, cfg.mlp_dim()),
                    LayerRole::Fc2 => (cfg.mlp_dim(), d),
                };
                let class = role.class();
                layers.push(GraphLayer {
                    index: layers.len(),
                    block,
                    role,
                    shape: LinearShape { class, k, n, m },
                    op: plan.point(class),
                    context: 0,
                });
            }
        }
        ModelGraph { cfg: *cfg, batch, plan_name: plan.name, layers }
    }

    /// Build a causal decoder graph: the same `4 × depth` macro-mapped
    /// linear chain as [`encoder`](Self::encoder), shaped for
    /// autoregressive generation — every layer's baseline activation
    /// stream is **one token** (`m = 1`, a single decode step), and the
    /// attention-class layers carry `gc.context` so
    /// [`GraphLayer::shape_at`] grows their effective decode work with
    /// the sequence position. The pipeline executor runs prefill and
    /// decode waves through this graph; `Scheduler::plan_decode` prices
    /// them.
    pub fn decoder(gc: &GraphConfig, plan: &PrecisionPlan) -> Self {
        let cfg = gc.vit;
        let d = cfg.dim;
        let context = gc.context.max(1);
        let mut layers = Vec::with_capacity(4 * cfg.depth);
        for block in 0..cfg.depth {
            for role in LayerRole::block_order() {
                let (k, n) = match role {
                    LayerRole::Qkv => (d, 3 * d),
                    LayerRole::AttnProj => (d, d),
                    LayerRole::Fc1 => (d, cfg.mlp_dim()),
                    LayerRole::Fc2 => (cfg.mlp_dim(), d),
                };
                let class = role.class();
                let attention = class == LayerClass::TransformerAttention;
                layers.push(GraphLayer {
                    index: layers.len(),
                    block,
                    role,
                    shape: LinearShape { class, k, n, m: 1 },
                    op: plan.point(class),
                    context: if attention { context } else { 0 },
                });
            }
        }
        ModelGraph { cfg, batch: 1, plan_name: plan.name, layers }
    }

    /// Whether this is a decoder graph (any layer carries a context
    /// window for position-dependent decode shapes).
    pub fn is_decoder(&self) -> bool {
        self.layers.iter().any(|l| l.context > 0)
    }

    /// The decoder's attention-context bound (0 on encoder graphs).
    pub fn context(&self) -> usize {
        self.layers.iter().map(|l| l.context).max().unwrap_or(0)
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// A copy of the graph re-shaped to one streaming **conversion
    /// wave**: every layer's activation stream becomes `wave_tokens`
    /// vectors (`batch` collapses to 1 — a wave has no image-batch
    /// structure, only tokens). This is what
    /// `coordinator::Scheduler::plan_stream` prices, so streaming and
    /// fixed-batch plans stay comparable layer for layer.
    pub fn with_stream_m(&self, wave_tokens: usize) -> ModelGraph {
        let mut g = self.clone();
        g.batch = 1;
        for l in &mut g.layers {
            l.shape.m = wave_tokens.max(1);
        }
        g
    }

    /// Layers of one SAC class, in execution order.
    pub fn class_layers(&self, class: LayerClass) -> impl Iterator<Item = &GraphLayer> {
        self.layers.iter().filter(move |l| l.shape.class == class)
    }

    /// Input width of the first layer (what a featurized image must be).
    pub fn input_dim(&self) -> usize {
        self.layers.first().map(|l| l.shape.k).unwrap_or(0)
    }

    /// Output width of the last layer (the served logit vector width).
    pub fn output_dim(&self) -> usize {
        self.layers.last().map(|l| l.shape.n).unwrap_or(0)
    }

    /// Total weight parameters across the graph's linears.
    pub fn weight_params(&self) -> u64 {
        self.layers.iter().map(|l| (l.shape.k * l.shape.n) as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::linear_workload;

    #[test]
    fn encoder_mirrors_linear_workload_block_shapes() {
        let cfg = VitConfig::default();
        let batch = 3;
        let graph = ModelGraph::encoder(&cfg, batch, &PrecisionPlan::paper_sac());
        assert_eq!(graph.layer_count(), 4 * cfg.depth);
        // The per-block entries of the flat workload catalog (skip patch
        // embed, drop the head) must coincide with the graph layers.
        let wl = linear_workload(&cfg, batch);
        let body = &wl[1..wl.len() - 1];
        assert_eq!(body.len(), graph.layer_count());
        for (g, w) in graph.layers.iter().zip(body) {
            assert_eq!((g.shape.k, g.shape.n, g.shape.m), (w.k, w.n, w.m), "{}", g.name());
            assert_eq!(g.shape.class, w.class, "{}", g.name());
        }
    }

    #[test]
    fn vit_base_graph_has_48_layers_with_dff_3072() {
        let graph = ModelGraph::encoder(&VitConfig::vit_base(), 1, &PrecisionPlan::paper_sac());
        assert_eq!(graph.layer_count(), 48);
        let fc2: Vec<_> = graph.layers.iter().filter(|l| l.role == LayerRole::Fc2).collect();
        assert_eq!(fc2.len(), 12);
        assert!(fc2.iter().all(|l| l.shape.k == 3072 && l.shape.n == 768));
        assert_eq!(graph.input_dim(), 768);
        assert_eq!(graph.output_dim(), 768);
        // 12 × (768·2304 + 768·768 + 768·3072 + 3072·768) ≈ 85M weights.
        assert_eq!(graph.weight_params(), 12 * (768 * 2304 + 768 * 768 + 2 * 768 * 3072));
    }

    #[test]
    fn operating_points_follow_the_plan_per_class() {
        let plan = PrecisionPlan::paper_sac();
        let graph = ModelGraph::encoder(&VitConfig::default(), 1, &plan);
        for l in &graph.layers {
            let want = plan.point(l.shape.class);
            assert_eq!(l.op, want, "{}", l.name());
        }
        let att = graph.class_layers(LayerClass::TransformerAttention).count();
        let mlp = graph.class_layers(LayerClass::TransformerMlp).count();
        assert_eq!(att, 2 * graph.cfg.depth);
        assert_eq!(mlp, 2 * graph.cfg.depth);
    }

    #[test]
    fn names_and_indices_are_stable() {
        let graph = ModelGraph::encoder(&VitConfig::default(), 1, &PrecisionPlan::paper_sac());
        assert_eq!(graph.layers[0].name(), "block0.qkv");
        assert_eq!(graph.layers[7].name(), "block1.fc2");
        for (i, l) in graph.layers.iter().enumerate() {
            assert_eq!(l.index, i);
        }
    }

    #[test]
    fn with_stream_m_reshapes_every_layer_and_keeps_ops() {
        let graph = ModelGraph::encoder(&VitConfig::default(), 4, &PrecisionPlan::paper_sac());
        let wave = graph.with_stream_m(24);
        assert_eq!(wave.batch, 1);
        assert_eq!(wave.layer_count(), graph.layer_count());
        for (w, g) in wave.layers.iter().zip(&graph.layers) {
            assert_eq!(w.shape.m, 24, "{}", w.name());
            assert_eq!((w.shape.k, w.shape.n), (g.shape.k, g.shape.n), "{}", w.name());
            assert_eq!(w.op, g.op, "{}", w.name());
        }
        // A wave of exactly the graph's stream replays its shapes.
        let m = graph.layers[0].shape.m;
        let same = graph.with_stream_m(m);
        for (s, g) in same.layers.iter().zip(&graph.layers) {
            assert_eq!(s.shape.m, g.shape.m);
        }
        // Zero clamps to one.
        assert_eq!(graph.with_stream_m(0).layers[0].shape.m, 1);
    }

    #[test]
    fn decoder_graph_is_one_token_with_position_dependent_attention() {
        let gc = GraphConfig { vit: VitConfig::default(), context: 8 };
        let g = ModelGraph::decoder(&gc, &PrecisionPlan::paper_sac());
        assert!(g.is_decoder());
        assert_eq!(g.context(), 8);
        assert_eq!(g.layer_count(), 4 * gc.vit.depth);
        assert_eq!(g.batch, 1);
        for l in &g.layers {
            // Baseline decode step: one token through every linear.
            assert_eq!(l.shape.m, 1, "{}", l.name());
            // Same (k, n) chain as the encoder.
            let enc = ModelGraph::encoder(&gc.vit, 1, &PrecisionPlan::paper_sac());
            let e = &enc.layers[l.index];
            assert_eq!((l.shape.k, l.shape.n), (e.shape.k, e.shape.n), "{}", l.name());
            // Attention layers carry the context window; MLP layers don't.
            let attention = l.shape.class == crate::cim::netstats::LayerClass::TransformerAttention;
            assert_eq!(l.context, if attention { 8 } else { 0 }, "{}", l.name());
            // shape_at grows with position and saturates at the window.
            assert_eq!(l.shape_at(0).m, 1, "{}", l.name());
            if attention {
                assert_eq!(l.shape_at(3).m, 4, "{}", l.name());
                assert_eq!(l.shape_at(100).m, 8, "{}", l.name());
            } else {
                assert_eq!(l.shape_at(3).m, 1, "{}", l.name());
                assert_eq!(l.shape_at(100).m, 1, "{}", l.name());
            }
        }
        // Encoder graphs are position-independent throughout.
        let enc = ModelGraph::encoder(&VitConfig::default(), 2, &PrecisionPlan::paper_sac());
        assert!(!enc.is_decoder());
        assert_eq!(enc.context(), 0);
        for l in &enc.layers {
            assert_eq!(l.shape_at(5), l.shape, "{}", l.name());
        }
        // decoder_base: ViT-Base blocks, 256-position window.
        let base = GraphConfig::decoder_base();
        assert_eq!(base.vit, VitConfig::vit_base());
        assert_eq!(base.context, 256);
    }

    #[test]
    fn batch_zero_is_clamped_to_one() {
        let g0 = ModelGraph::encoder(&VitConfig::default(), 0, &PrecisionPlan::paper_sac());
        let g1 = ModelGraph::encoder(&VitConfig::default(), 1, &PrecisionPlan::paper_sac());
        assert_eq!(g0.batch, 1);
        assert_eq!(g0.layers[0].shape.m, g1.layers[0].shape.m);
    }
}
