//! SAC precision plans: which (bit-width, CB mode) each layer class runs
//! at. The paper's plan (Fig. 6): MLP-class linears w/CB at 6b/6b,
//! attention-class linears wo/CB at 4b/4b.

use crate::cim::netstats::LayerClass;
use crate::cim::params::CbMode;

/// Per-layer majority-voting point: how hard the SAR ADC votes on its
/// noise-critical LSB decisions when the CSNR boost (`CbMode::On`) is
/// active. The paper's co-design thesis is that this is a *per-layer*
/// knob: noise-tolerant layers take cheap (low-vote) points while
/// noise-critical layers pay for more comparisons. `Default` is the
/// paper's 6×-MV-on-last-3-bits point, matching
/// `MacroParams::default()`, so a plan that never mentions voting is
/// byte-for-byte the pre-NoisePoint behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NoisePoint {
    /// Majority votes per boosted comparison (≥ 1; 1 = no voting).
    pub mv_votes: u32,
    /// How many trailing (LSB) SAR bits are boosted.
    pub mv_last_bits: u32,
}

impl Default for NoisePoint {
    fn default() -> Self {
        NoisePoint { mv_votes: 6, mv_last_bits: 3 }
    }
}

impl NoisePoint {
    /// The paper's Fig. 5 point: 6 votes on the last 3 bits.
    pub fn paper() -> Self {
        Self::default()
    }

    /// A voting point at `votes` keeping the paper's 3 boosted bits.
    pub fn votes(mv_votes: u32) -> Self {
        NoisePoint { mv_votes, mv_last_bits: 3 }
    }
}

/// Per-class operating point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OperatingPoint {
    /// Activation precision (bit-serial conversion cycles).
    pub a_bits: u32,
    /// Weight precision (bit-sliced physical column planes).
    pub w_bits: u32,
    /// Whether the CSNR boost (majority voting) is active.
    pub cb: CbMode,
    /// Majority-voting point used when `cb` is `On` (ignored when `Off`).
    pub noise: NoisePoint,
}

impl OperatingPoint {
    /// Operating point at the default (paper) voting point.
    pub fn new(a_bits: u32, w_bits: u32, cb: CbMode) -> Self {
        OperatingPoint { a_bits, w_bits, cb, noise: NoisePoint::default() }
    }

    /// Same point with an explicit voting configuration.
    pub fn with_votes(mut self, mv_votes: u32, mv_last_bits: u32) -> Self {
        self.noise = NoisePoint { mv_votes, mv_last_bits };
        self
    }

    /// Check the bit widths fit the integer datapath (two's complement
    /// operands in `i32`, shift-safe reconstruction in `i64`). Every
    /// executor that accepts a caller-supplied operating point routes
    /// through this guard so oversized widths return `Err` instead of
    /// panicking on a shift overflow.
    pub fn validate(&self) -> Result<(), String> {
        if self.a_bits == 0 || self.a_bits > 31 || self.w_bits == 0 || self.w_bits > 31 {
            return Err(format!(
                "operating point bits out of range 1..=31 (a_bits {}, w_bits {})",
                self.a_bits, self.w_bits
            ));
        }
        if self.noise.mv_votes < 1 {
            return Err("operating point mv_votes must be >= 1".into());
        }
        Ok(())
    }

    /// Two's-complement activation range `(lo, hi)` at `a_bits`.
    /// Callers must have routed through [`validate`](Self::validate).
    pub fn a_range(&self) -> (i32, i32) {
        (-(1i32 << (self.a_bits - 1)), (1i32 << (self.a_bits - 1)) - 1)
    }

    /// Two's-complement weight range `(lo, hi)` at `w_bits`.
    pub fn w_range(&self) -> (i32, i32) {
        (-(1i32 << (self.w_bits - 1)), (1i32 << (self.w_bits - 1)) - 1)
    }
}

/// A full precision/CB plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrecisionPlan {
    pub name: &'static str,
    pub attention: OperatingPoint,
    pub mlp: OperatingPoint,
}

impl PrecisionPlan {
    /// The paper's SAC plan: attention 4b wo/CB, MLP 6b w/CB.
    pub fn paper_sac() -> Self {
        PrecisionPlan {
            name: "SAC (paper): attn 4b wo/CB, MLP 6b w/CB",
            attention: OperatingPoint::new(4, 4, CbMode::Off),
            mlp: OperatingPoint::new(6, 6, CbMode::On),
        }
    }

    /// Baseline "None": no co-design at all — everything at the blanket
    /// accuracy-safe point an 8b-operand CIM would use ([4]'s precision),
    /// CB always on. This is the Fig. 6 ablation's leftmost bar.
    pub fn uniform_safe() -> Self {
        PrecisionPlan {
            name: "None: all 8b w/CB (no co-design)",
            attention: OperatingPoint::new(8, 8, CbMode::On),
            mlp: OperatingPoint::new(8, 8, CbMode::On),
        }
    }

    /// Intermediate ablation: CB adapted per layer class, bit-width not
    /// yet optimized (Fig. 6's middle bar, "w/CB").
    pub fn cb_only() -> Self {
        PrecisionPlan {
            name: "w/CB: attn 8b wo/CB, MLP 8b w/CB",
            attention: OperatingPoint::new(8, 8, CbMode::Off),
            mlp: OperatingPoint::new(8, 8, CbMode::On),
        }
    }

    /// Aggressive (accuracy-unsafe) corner used in Fig. 1(A)-style sweeps.
    pub fn uniform_fast() -> Self {
        PrecisionPlan {
            name: "all 4b wo/CB",
            attention: OperatingPoint::new(4, 4, CbMode::Off),
            mlp: OperatingPoint::new(4, 4, CbMode::Off),
        }
    }

    /// The Fig. 6 SAC ablation series, in presentation order.
    pub fn ablation_series() -> Vec<PrecisionPlan> {
        vec![Self::uniform_safe(), Self::cb_only(), Self::paper_sac()]
    }

    pub fn point(&self, class: LayerClass) -> OperatingPoint {
        match class {
            LayerClass::TransformerAttention => self.attention,
            // CNN conv layers (Fig. 1A comparisons) take the MLP point.
            LayerClass::TransformerMlp | LayerClass::CnnConv => self.mlp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_plan_matches_fig6() {
        let p = PrecisionPlan::paper_sac();
        assert_eq!(p.attention.a_bits, 4);
        assert_eq!(p.attention.cb, CbMode::Off);
        assert_eq!(p.mlp.a_bits, 6);
        assert_eq!(p.mlp.cb, CbMode::On);
    }

    #[test]
    fn ablation_series_ordering() {
        let s = PrecisionPlan::ablation_series();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], PrecisionPlan::uniform_safe());
        assert_eq!(s[2], PrecisionPlan::paper_sac());
    }

    #[test]
    fn operating_point_bit_guard() {
        assert!(OperatingPoint::new(4, 4, CbMode::Off).validate().is_ok());
        assert!(OperatingPoint::new(31, 1, CbMode::On).validate().is_ok());
        for bad in [
            OperatingPoint::new(0, 4, CbMode::Off),
            OperatingPoint::new(4, 0, CbMode::Off),
            OperatingPoint::new(32, 4, CbMode::Off),
            OperatingPoint::new(4, 33, CbMode::Off),
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn default_noise_point_is_the_paper_point() {
        let op = OperatingPoint::new(6, 6, CbMode::On);
        assert_eq!(op.noise, NoisePoint { mv_votes: 6, mv_last_bits: 3 });
        assert_eq!(NoisePoint::paper(), NoisePoint::default());
        assert_eq!(NoisePoint::votes(12), NoisePoint { mv_votes: 12, mv_last_bits: 3 });
    }

    #[test]
    fn zero_vote_operating_point_is_rejected() {
        let op = OperatingPoint::new(6, 6, CbMode::On).with_votes(0, 3);
        assert!(op.validate().is_err());
        assert!(OperatingPoint::new(6, 6, CbMode::On).with_votes(1, 3).validate().is_ok());
    }

    #[test]
    fn operand_ranges_are_twos_complement() {
        let op = OperatingPoint::new(4, 6, CbMode::Off);
        assert_eq!(op.a_range(), (-8, 7));
        assert_eq!(op.w_range(), (-32, 31));
        let one = OperatingPoint::new(1, 1, CbMode::Off);
        assert_eq!(one.a_range(), (-1, 0));
        assert_eq!(one.w_range(), (-1, 0));
    }

    #[test]
    fn class_dispatch() {
        let p = PrecisionPlan::paper_sac();
        assert_eq!(p.point(LayerClass::TransformerAttention), p.attention);
        assert_eq!(p.point(LayerClass::TransformerMlp), p.mlp);
        assert_eq!(p.point(LayerClass::CnnConv), p.mlp);
    }
}
