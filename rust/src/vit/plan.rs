//! SAC precision plans: which (bit-width, CB mode) each layer class runs
//! at. The paper's plan (Fig. 6): MLP-class linears w/CB at 6b/6b,
//! attention-class linears wo/CB at 4b/4b.

use crate::cim::netstats::LayerClass;
use crate::cim::params::CbMode;

/// Per-class operating point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OperatingPoint {
    /// Activation precision (bit-serial conversion cycles).
    pub a_bits: u32,
    /// Weight precision (bit-sliced physical column planes).
    pub w_bits: u32,
    /// Whether the CSNR boost (majority voting) is active.
    pub cb: CbMode,
}

impl OperatingPoint {
    /// Check the bit widths fit the integer datapath (two's complement
    /// operands in `i32`, shift-safe reconstruction in `i64`). Every
    /// executor that accepts a caller-supplied operating point routes
    /// through this guard so oversized widths return `Err` instead of
    /// panicking on a shift overflow.
    pub fn validate(&self) -> Result<(), String> {
        if self.a_bits == 0 || self.a_bits > 31 || self.w_bits == 0 || self.w_bits > 31 {
            return Err(format!(
                "operating point bits out of range 1..=31 (a_bits {}, w_bits {})",
                self.a_bits, self.w_bits
            ));
        }
        Ok(())
    }

    /// Two's-complement activation range `(lo, hi)` at `a_bits`.
    /// Callers must have routed through [`validate`](Self::validate).
    pub fn a_range(&self) -> (i32, i32) {
        (-(1i32 << (self.a_bits - 1)), (1i32 << (self.a_bits - 1)) - 1)
    }

    /// Two's-complement weight range `(lo, hi)` at `w_bits`.
    pub fn w_range(&self) -> (i32, i32) {
        (-(1i32 << (self.w_bits - 1)), (1i32 << (self.w_bits - 1)) - 1)
    }
}

/// A full precision/CB plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrecisionPlan {
    pub name: &'static str,
    pub attention: OperatingPoint,
    pub mlp: OperatingPoint,
}

impl PrecisionPlan {
    /// The paper's SAC plan: attention 4b wo/CB, MLP 6b w/CB.
    pub fn paper_sac() -> Self {
        PrecisionPlan {
            name: "SAC (paper): attn 4b wo/CB, MLP 6b w/CB",
            attention: OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::Off },
            mlp: OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::On },
        }
    }

    /// Baseline "None": no co-design at all — everything at the blanket
    /// accuracy-safe point an 8b-operand CIM would use ([4]'s precision),
    /// CB always on. This is the Fig. 6 ablation's leftmost bar.
    pub fn uniform_safe() -> Self {
        PrecisionPlan {
            name: "None: all 8b w/CB (no co-design)",
            attention: OperatingPoint { a_bits: 8, w_bits: 8, cb: CbMode::On },
            mlp: OperatingPoint { a_bits: 8, w_bits: 8, cb: CbMode::On },
        }
    }

    /// Intermediate ablation: CB adapted per layer class, bit-width not
    /// yet optimized (Fig. 6's middle bar, "w/CB").
    pub fn cb_only() -> Self {
        PrecisionPlan {
            name: "w/CB: attn 8b wo/CB, MLP 8b w/CB",
            attention: OperatingPoint { a_bits: 8, w_bits: 8, cb: CbMode::Off },
            mlp: OperatingPoint { a_bits: 8, w_bits: 8, cb: CbMode::On },
        }
    }

    /// Aggressive (accuracy-unsafe) corner used in Fig. 1(A)-style sweeps.
    pub fn uniform_fast() -> Self {
        PrecisionPlan {
            name: "all 4b wo/CB",
            attention: OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::Off },
            mlp: OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::Off },
        }
    }

    /// The Fig. 6 SAC ablation series, in presentation order.
    pub fn ablation_series() -> Vec<PrecisionPlan> {
        vec![Self::uniform_safe(), Self::cb_only(), Self::paper_sac()]
    }

    pub fn point(&self, class: LayerClass) -> OperatingPoint {
        match class {
            LayerClass::TransformerAttention => self.attention,
            // CNN conv layers (Fig. 1A comparisons) take the MLP point.
            LayerClass::TransformerMlp | LayerClass::CnnConv => self.mlp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_plan_matches_fig6() {
        let p = PrecisionPlan::paper_sac();
        assert_eq!(p.attention.a_bits, 4);
        assert_eq!(p.attention.cb, CbMode::Off);
        assert_eq!(p.mlp.a_bits, 6);
        assert_eq!(p.mlp.cb, CbMode::On);
    }

    #[test]
    fn ablation_series_ordering() {
        let s = PrecisionPlan::ablation_series();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], PrecisionPlan::uniform_safe());
        assert_eq!(s[2], PrecisionPlan::paper_sac());
    }

    #[test]
    fn operating_point_bit_guard() {
        assert!(OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::Off }.validate().is_ok());
        assert!(OperatingPoint { a_bits: 31, w_bits: 1, cb: CbMode::On }.validate().is_ok());
        for bad in [
            OperatingPoint { a_bits: 0, w_bits: 4, cb: CbMode::Off },
            OperatingPoint { a_bits: 4, w_bits: 0, cb: CbMode::Off },
            OperatingPoint { a_bits: 32, w_bits: 4, cb: CbMode::Off },
            OperatingPoint { a_bits: 4, w_bits: 33, cb: CbMode::Off },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn operand_ranges_are_twos_complement() {
        let op = OperatingPoint { a_bits: 4, w_bits: 6, cb: CbMode::Off };
        assert_eq!(op.a_range(), (-8, 7));
        assert_eq!(op.w_range(), (-32, 31));
        let one = OperatingPoint { a_bits: 1, w_bits: 1, cb: CbMode::Off };
        assert_eq!(one.a_range(), (-1, 0));
        assert_eq!(one.w_range(), (-1, 0));
    }

    #[test]
    fn class_dispatch() {
        let p = PrecisionPlan::paper_sac();
        assert_eq!(p.point(LayerClass::TransformerAttention), p.attention);
        assert_eq!(p.point(LayerClass::TransformerMlp), p.mlp);
        assert_eq!(p.point(LayerClass::CnnConv), p.mlp);
    }
}
