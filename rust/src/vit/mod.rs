//! ViT model catalog: shapes, per-layer precision plans, the typed
//! encoder layer graph, and the linear-layer workload the scheduler
//! maps onto the macro.
//!
//! Mirrors `python/compile/model.py` (`VitConfig`, `count_linear_workload`)
//! — the two sides are kept in sync by the manifest check in
//! `runtime::artifact` and the bridge tests in `rust/tests/`.

pub mod graph;
pub mod plan;

pub use graph::{GraphConfig, GraphLayer, LayerRole, ModelGraph};

use crate::cim::netstats::LayerClass;

/// Model hyperparameters (mirror of python VitConfig).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VitConfig {
    pub image: usize,
    pub patch: usize,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub mlp_ratio: usize,
    pub num_classes: usize,
}

impl Default for VitConfig {
    fn default() -> Self {
        VitConfig { image: 32, patch: 4, dim: 96, depth: 4, heads: 4, mlp_ratio: 2, num_classes: 10 }
    }
}

impl VitConfig {
    /// ViT-small-like configuration (the paper's network: 12 blocks).
    pub fn vit_small() -> Self {
        VitConfig { image: 32, patch: 4, dim: 384, depth: 12, heads: 6, mlp_ratio: 4, num_classes: 10 }
    }

    /// ViT-Base: 12 blocks at dim 768, d_ff = 3072 — the canonical
    /// transformer whose MLP `fc2` reduction (k = 3072) exceeds the
    /// macro's 1024-row tile and therefore exercises the full
    /// (row tile × column shard × die pool) pipeline path.
    pub fn vit_base() -> Self {
        VitConfig {
            image: 224,
            patch: 16,
            dim: 768,
            depth: 12,
            heads: 12,
            mlp_ratio: 4,
            num_classes: 1000,
        }
    }

    pub fn tokens(&self) -> usize {
        (self.image / self.patch).pow(2) + 1
    }

    pub fn patch_dim(&self) -> usize {
        self.patch * self.patch * 3
    }

    pub fn mlp_dim(&self) -> usize {
        self.dim * self.mlp_ratio
    }

    /// Total parameters of the linear layers (weights only).
    pub fn linear_params(&self) -> usize {
        let d = self.dim;
        self.patch_dim() * d
            + self.depth * (d * 3 * d + d * d + 2 * d * self.mlp_dim())
            + d * self.num_classes
    }
}

/// One linear-layer invocation: `m` activation vectors of length `k`
/// against a (k × n) weight matrix, of a given SAC class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinearShape {
    pub class: LayerClass,
    /// Input (reduction) dimension = macro rows used.
    pub k: usize,
    /// Output channels.
    pub n: usize,
    /// Activation vectors per inference (batch × tokens).
    pub m: usize,
}

impl LinearShape {
    /// Multiply-accumulates (not 1b-normalized).
    pub fn macs(&self) -> u64 {
        (self.k * self.n * self.m) as u64
    }
}

/// The per-inference linear workload (mirror of count_linear_workload).
pub fn linear_workload(cfg: &VitConfig, batch: usize) -> Vec<LinearShape> {
    let t = cfg.tokens();
    let d = cfg.dim;
    let mut v = Vec::new();
    let att = LayerClass::TransformerAttention;
    let mlp = LayerClass::TransformerMlp;
    v.push(LinearShape { class: mlp, k: cfg.patch_dim(), n: d, m: batch * (t - 1) });
    for _ in 0..cfg.depth {
        v.push(LinearShape { class: att, k: d, n: 3 * d, m: batch * t });
        v.push(LinearShape { class: att, k: d, n: d, m: batch * t });
        v.push(LinearShape { class: mlp, k: d, n: cfg.mlp_dim(), m: batch * t });
        v.push(LinearShape { class: mlp, k: cfg.mlp_dim(), n: d, m: batch * t });
    }
    v.push(LinearShape { class: mlp, k: d, n: cfg.num_classes, m: batch });
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_count_includes_cls() {
        assert_eq!(VitConfig::default().tokens(), 65);
        assert_eq!(VitConfig::vit_small().tokens(), 65);
    }

    #[test]
    fn workload_mirrors_python_catalog() {
        let cfg = VitConfig::default();
        let wl = linear_workload(&cfg, 1);
        // patch embed + depth×4 + head.
        assert_eq!(wl.len(), 2 + 4 * cfg.depth);
        let att: Vec<_> =
            wl.iter().filter(|s| s.class == LayerClass::TransformerAttention).collect();
        assert_eq!(att.len(), 2 * cfg.depth);
        // qkv shape.
        assert_eq!(att[0].k, cfg.dim);
        assert_eq!(att[0].n, 3 * cfg.dim);
        assert_eq!(att[0].m, cfg.tokens());
        // head shape.
        let head = wl.last().unwrap();
        assert_eq!((head.k, head.n, head.m), (cfg.dim, cfg.num_classes, 1));
    }

    #[test]
    fn batch_scales_m_only() {
        let cfg = VitConfig::default();
        let w1 = linear_workload(&cfg, 1);
        let w4 = linear_workload(&cfg, 4);
        for (a, b) in w1.iter().zip(&w4) {
            assert_eq!(a.k, b.k);
            assert_eq!(a.n, b.n);
            assert_eq!(b.m, 4 * a.m);
        }
    }

    #[test]
    fn vit_base_matches_canonical_shapes() {
        let cfg = VitConfig::vit_base();
        assert_eq!(cfg.tokens(), 197); // 14×14 patches + CLS
        assert_eq!(cfg.mlp_dim(), 3072);
        // ≈85M encoder linear params (plus embed/head).
        let p = cfg.linear_params();
        assert!(p > 80_000_000 && p < 95_000_000, "{p}");
    }

    #[test]
    fn vit_small_param_count_plausible() {
        // ViT-small @ dim 384 / depth 12 / mlp 4x ≈ 21M linear params.
        let p = VitConfig::vit_small().linear_params();
        assert!(p > 15_000_000 && p < 30_000_000, "{p}");
    }

    #[test]
    fn macs_count() {
        let s = LinearShape { class: LayerClass::TransformerMlp, k: 10, n: 20, m: 3 };
        assert_eq!(s.macs(), 600);
    }
}
