//! Metric definitions used across the evaluation: transfer-curve
//! characterization (INL/DNL/read-noise), SQNR/ENOB, CSNR, and the Fig. 6
//! FoMs. Exact conventions are documented per module; EXPERIMENTS.md
//! records paper-vs-measured for each.

pub mod csnr;
pub mod fom;
pub mod sqnr;
pub mod transfer;

pub use csnr::{measure_csnr, CsnrEnsemble, CsnrResult};
pub use sqnr::{enob, sqnr_db};
pub use transfer::{characterize, CharacterizeOpts, TransferCurve};
