//! SQNR / ENOB: the static-accuracy metric of Fig. 5/6.
//!
//! Definition (following [4]'s convention, measured on a full-scale
//! ramp/sine): the signal is a full-scale sinusoid (amplitude FS/2, power
//! A²/2) and the error power is the sum of
//!   - ideal quantization (LSB²/12),
//!   - static INL (rms over the curve), and
//!   - read noise (rms over the curve),
//! all in LSB². SQNR = 10·log10(P_signal/P_error);
//! ENOB = (SQNR − 1.76)/6.02. An ideal 10-bit converter gives 61.96 dB.

use super::transfer::TransferCurve;

/// Error budget extracted from a transfer curve [LSB²].
#[derive(Clone, Copy, Debug)]
pub struct ErrorBudget {
    pub quantization_var: f64,
    pub inl_var: f64,
    pub noise_var: f64,
}

impl ErrorBudget {
    pub fn from_curve(curve: &TransferCurve) -> Self {
        ErrorBudget {
            quantization_var: 1.0 / 12.0,
            inl_var: curve.inl_rms().powi(2),
            noise_var: curve.rms_noise_lsb().powi(2),
        }
    }

    pub fn total_var(&self) -> f64 {
        self.quantization_var + self.inl_var + self.noise_var
    }

    pub fn total_rms_lsb(&self) -> f64 {
        self.total_var().sqrt()
    }
}

/// SQNR [dB] for a converter with `bits` resolution and the given error
/// budget, full-scale-sine referenced.
pub fn sqnr_db_from_budget(bits: u32, budget: &ErrorBudget) -> f64 {
    let amplitude = (1u64 << bits) as f64 / 2.0; // LSB
    let p_signal = amplitude * amplitude / 2.0;
    10.0 * (p_signal / budget.total_var()).log10()
}

/// SQNR [dB] measured from a characterized transfer curve.
pub fn sqnr_db(curve: &TransferCurve) -> f64 {
    sqnr_db_from_budget(curve.bits, &ErrorBudget::from_curve(curve))
}

/// Effective number of bits from an SQNR.
pub fn enob(sqnr_db: f64) -> f64 {
    (sqnr_db - 1.76) / 6.02
}

/// The "SQNR-bit" used by the Fig. 6 FoM footnote (same as ENOB).
pub fn sqnr_bit(sqnr_db: f64) -> f64 {
    enob(sqnr_db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::column::Column;
    use crate::cim::params::{CbMode, MacroParams};
    use crate::metrics::transfer::{characterize, CharacterizeOpts};

    fn ideal_budget() -> ErrorBudget {
        ErrorBudget { quantization_var: 1.0 / 12.0, inl_var: 0.0, noise_var: 0.0 }
    }

    #[test]
    fn ideal_10bit_is_61_96_db() {
        let s = sqnr_db_from_budget(10, &ideal_budget());
        assert!((s - 61.96).abs() < 0.05, "ideal 10b SQNR = {s}");
        assert!((enob(s) - 10.0).abs() < 0.01);
    }

    #[test]
    fn ideal_8bit_is_49_92_db() {
        let s = sqnr_db_from_budget(8, &ideal_budget());
        assert!((s - 49.92).abs() < 0.05);
    }

    #[test]
    fn error_terms_lower_sqnr_monotonically() {
        let mut b = ideal_budget();
        let s0 = sqnr_db_from_budget(10, &b);
        b.inl_var = 1.0;
        let s1 = sqnr_db_from_budget(10, &b);
        b.noise_var = 1.0;
        let s2 = sqnr_db_from_budget(10, &b);
        assert!(s0 > s1 && s1 > s2);
    }

    #[test]
    fn characterized_ideal_column_hits_quantization_limit() {
        let p = MacroParams::default();
        let col = Column::ideal(&p).unwrap();
        let opts = CharacterizeOpts { step: 16, trials: 8, threads: 2, stream: 0 };
        let curve = characterize(&col, CbMode::Off, &opts);
        let s = sqnr_db(&curve);
        assert!((s - 61.96).abs() < 0.1, "ideal column SQNR = {s}");
    }

    #[test]
    fn default_die_sqnr_near_paper_45db_with_cb() {
        // The headline Fig. 5 number: SQNR ≈ 45.3 dB with CB. Our
        // calibration targets ±3 dB of the paper (documented in
        // EXPERIMENTS.md §Calibration).
        let p = MacroParams::default();
        let col = Column::new(&p, 0).unwrap();
        let opts = CharacterizeOpts { step: 4, trials: 48, threads: 4, stream: 1 };
        let curve = characterize(&col, CbMode::On, &opts);
        let s = sqnr_db(&curve);
        assert!((s - 45.3).abs() < 3.0, "SQNR w/CB = {s:.1} dB (paper 45.3)");
    }
}
