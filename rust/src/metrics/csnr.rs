//! CSNR: compute signal-to-noise ratio, the metric of [1] (Gonugondla et
//! al., ICCAD 2020) that Fig. 5/6 headline.
//!
//! CSNR compares the *useful* MAC signal power against the *dynamic*
//! compute error power at the readout:
//!
//!   CSNR = 10·log10( Var[ideal MAC] / (Var[read noise] + LSB²/12) )
//!
//! over a benchmark input ensemble. Static per-die INL is excluded: it is
//! a fixed, calibratable weight perturbation (the software half of the
//! co-design absorbs it), whereas read noise hits every inference. This
//! convention reproduces both of the paper's numbers simultaneously
//! (SQNR 45 dB — which *does* include INL — and CSNR 31 dB).
//!
//! Benchmark ensemble: activations are Bernoulli(p) with per-vector
//! density p ~ U(0.45, 0.55) (activation-level variation of real layer
//! inputs), weights Bernoulli(0.5). On 1024 rows this gives a MAC σ of
//! ≈ 22 LSB.

use crate::cim::column::Column;
use crate::cim::params::CbMode;
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;
use crate::util::stats::Moments;

/// Ensemble definition for the CSNR measurement.
#[derive(Clone, Copy, Debug)]
pub struct CsnrEnsemble {
    /// Input density lower/upper bound (per-vector uniform draw).
    pub p_lo: f64,
    pub p_hi: f64,
    /// Weight density.
    pub w_density: f64,
    /// Vectors in the ensemble.
    pub vectors: usize,
    /// Repeated reads per vector (to estimate read noise).
    pub reads_per_vector: usize,
}

impl Default for CsnrEnsemble {
    fn default() -> Self {
        CsnrEnsemble { p_lo: 0.42, p_hi: 0.58, w_density: 0.5, vectors: 160, reads_per_vector: 24 }
    }
}

/// Result of a CSNR measurement.
#[derive(Clone, Copy, Debug)]
pub struct CsnrResult {
    pub csnr_db: f64,
    /// Signal std over the ensemble [LSB].
    pub sigma_signal_lsb: f64,
    /// Dynamic error std (read noise ⊕ quantization) [LSB].
    pub sigma_error_lsb: f64,
}

/// Measure CSNR of `column` in `mode` over the benchmark ensemble.
pub fn measure_csnr(
    column: &Column,
    mode: CbMode,
    ens: &CsnrEnsemble,
    threads: usize,
) -> CsnrResult {
    let n = column.params.active_rows;
    let root = Rng::salted(column.params.seed, 0xC5A4_0001);
    // Weights for this measurement (one draw, like loading a layer).
    let mut wrng = root.substream(1, 0);
    let weights: Vec<bool> = (0..n).map(|_| wrng.bool(ens.w_density)).collect();

    let per_vector = parallel_map(ens.vectors, threads, |v| {
        let mut rng = root.substream(2 + mode as u64, v as u64);
        let p = rng.range(ens.p_lo, ens.p_hi);
        let inputs: Vec<bool> = (0..n).map(|_| rng.bool(p)).collect();
        let ideal: u32 = inputs.iter().zip(&weights).filter(|(&i, &w)| i & w).count() as u32;
        // Repeated reads of the same vector: spread = read noise.
        let mut col = column.clone();
        col.load_weights(&weights);
        let mut m = Moments::new();
        for _ in 0..ens.reads_per_vector {
            m.push(col.mac_convert(&inputs, mode, &mut rng).code as f64);
        }
        (ideal as f64, m.var())
    });

    let mut sig = Moments::new();
    let mut noise_var_sum = 0.0;
    for (ideal, nv) in &per_vector {
        sig.push(*ideal);
        noise_var_sum += nv;
    }
    let noise_var = noise_var_sum / per_vector.len() as f64;
    let err_var = noise_var + 1.0 / 12.0;
    let csnr_db = 10.0 * (sig.var() / err_var).log10();
    CsnrResult {
        csnr_db,
        sigma_signal_lsb: sig.std(),
        sigma_error_lsb: err_var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::params::MacroParams;

    fn quick() -> CsnrEnsemble {
        CsnrEnsemble { vectors: 48, reads_per_vector: 12, ..Default::default() }
    }

    #[test]
    fn ideal_column_csnr_is_quantization_limited() {
        let p = MacroParams::default();
        let col = Column::ideal(&p).unwrap();
        let r = measure_csnr(&col, CbMode::Off, &quick(), 4);
        // σ_sig ≈ 22 LSB, σ_err = 1/√12: CSNR ≈ 20·log10(22·√12) ≈ 37.6 dB.
        assert!(r.csnr_db > 33.0 && r.csnr_db < 42.0, "ideal CSNR = {}", r.csnr_db);
        assert!(r.sigma_signal_lsb > 12.0 && r.sigma_signal_lsb < 40.0);
    }

    #[test]
    fn cb_boosts_csnr_measurably() {
        let p = MacroParams::default();
        let col = Column::new(&p, 0).unwrap();
        let ens = quick();
        let off = measure_csnr(&col, CbMode::Off, &ens, 4);
        let on = measure_csnr(&col, CbMode::On, &ens, 4);
        let boost = on.csnr_db - off.csnr_db;
        // Paper: +5.5 dB (the ideal majority-of-6 single-comparison
        // factor). Post-quantization we measure ~3.2 dB; see
        // EXPERIMENTS.md §Deviations for the order-statistics argument.
        assert!(
            boost > 2.0 && boost < 6.5,
            "CB boost = {boost:.1} dB (paper: 5.5): off={:.1} on={:.1}",
            off.csnr_db,
            on.csnr_db
        );
    }

    #[test]
    fn csnr_with_cb_near_paper_31db() {
        let p = MacroParams::default();
        let col = Column::new(&p, 1).unwrap();
        let r = measure_csnr(&col, CbMode::On, &CsnrEnsemble::default(), 4);
        assert!(
            (r.csnr_db - 31.3).abs() < 3.0,
            "CSNR w/CB = {:.1} dB (paper 31.3)",
            r.csnr_db
        );
    }

    #[test]
    fn deterministic_across_threads() {
        let p = MacroParams::default();
        let col = Column::new(&p, 2).unwrap();
        let a = measure_csnr(&col, CbMode::Off, &quick(), 1);
        let b = measure_csnr(&col, CbMode::Off, &quick(), 8);
        assert!((a.csnr_db - b.csnr_db).abs() < 1e-9);
    }
}
