//! Column transfer-curve characterization (the Fig. 5 measurement).
//!
//! Sweeps the MAC input count over the full range, Monte-Carlo-reads each
//! point, and extracts the static curve (INL/DNL) and the per-code read
//! noise. Runs the sweep in parallel with per-point RNG substreams so the
//! result is independent of thread count.

use crate::cim::column::Column;
use crate::cim::params::CbMode;
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;
use crate::util::stats::{self, Moments};

/// Characterized transfer curve of one column.
#[derive(Clone, Debug)]
pub struct TransferCurve {
    /// Input MAC counts swept (ascending).
    pub counts: Vec<usize>,
    /// Mean read code per count (Monte-Carlo).
    pub mean_code: Vec<f64>,
    /// Read-noise std per count [LSB].
    pub noise_lsb: Vec<f64>,
    /// Static (noise-free) code per count.
    pub static_code: Vec<u32>,
    /// ADC resolution (codes = 2^bits).
    pub bits: u32,
}

impl TransferCurve {
    /// Static INL per swept point [LSB]: deviation of the static curve
    /// from the straight line through its endpoints.
    pub fn inl_lsb(&self) -> Vec<f64> {
        let n = self.counts.len();
        assert!(n >= 2);
        let x0 = self.counts[0] as f64;
        let x1 = self.counts[n - 1] as f64;
        let y0 = self.static_code[0] as f64;
        let y1 = self.static_code[n - 1] as f64;
        let slope = (y1 - y0) / (x1 - x0);
        self.counts
            .iter()
            .zip(&self.static_code)
            .map(|(&c, &code)| code as f64 - (y0 + slope * (c as f64 - x0)))
            .collect()
    }

    /// DNL per adjacent swept pair [LSB] (meaningful when the sweep step
    /// is one count).
    pub fn dnl_lsb(&self) -> Vec<f64> {
        let ideal_step = (self.static_code[self.counts.len() - 1] as f64
            - self.static_code[0] as f64)
            / (self.counts[self.counts.len() - 1] - self.counts[0]) as f64;
        self.static_code
            .windows(2)
            .zip(self.counts.windows(2))
            .map(|(codes, counts)| {
                let step = (codes[1] as f64 - codes[0] as f64) / (counts[1] - counts[0]) as f64;
                step / ideal_step - 1.0
            })
            .collect()
    }

    pub fn max_abs_inl(&self) -> f64 {
        self.inl_lsb().iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }

    pub fn inl_rms(&self) -> f64 {
        stats::rms(&self.inl_lsb())
    }

    /// Mean read noise across the curve [LSB] (Fig. 5 quotes this).
    pub fn mean_noise_lsb(&self) -> f64 {
        stats::mean(&self.noise_lsb)
    }

    pub fn rms_noise_lsb(&self) -> f64 {
        stats::rms(&self.noise_lsb)
    }
}

/// Characterization settings.
#[derive(Clone, Copy, Debug)]
pub struct CharacterizeOpts {
    /// Sweep step in counts (1 = every code; Fig. 5-grade).
    pub step: usize,
    /// Monte-Carlo reads per point.
    pub trials: usize,
    /// Worker threads (1 = serial).
    pub threads: usize,
    /// RNG stream id (vary to get independent characterization runs).
    pub stream: u64,
}

impl Default for CharacterizeOpts {
    fn default() -> Self {
        CharacterizeOpts { step: 8, trials: 64, threads: crate::util::pool::default_threads(), stream: 0 }
    }
}

/// Run the Fig. 5 measurement on `column` in `mode`.
pub fn characterize(column: &Column, mode: CbMode, opts: &CharacterizeOpts) -> TransferCurve {
    // Sweep to levels−1 (1023): the count==levels point saturates at the
    // top code and would contaminate the endpoint fit.
    let max_count = column.params.levels() - 1;
    let counts: Vec<usize> = (0..=max_count).step_by(opts.step.max(1)).collect();
    let root = Rng::salted(column.params.seed, 0x74A4_5FE4 ^ opts.stream);
    let points = parallel_map(counts.len(), opts.threads, |i| {
        let count = counts[i];
        let mut rng = root.substream(mode as u64 + 1, count as u64);
        let mut m = Moments::new();
        for _ in 0..opts.trials {
            m.push(column.read_count(count, mode, &mut rng).code as f64);
        }
        (m.mean(), m.std(), column.static_code(count))
    });
    TransferCurve {
        counts,
        mean_code: points.iter().map(|p| p.0).collect(),
        noise_lsb: points.iter().map(|p| p.1).collect(),
        static_code: points.iter().map(|p| p.2).collect(),
        bits: column.params.adc_bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::params::MacroParams;

    fn quick_opts() -> CharacterizeOpts {
        CharacterizeOpts { step: 32, trials: 24, threads: 2, stream: 7 }
    }

    #[test]
    fn ideal_column_curve_is_perfect() {
        let p = MacroParams::default();
        let col = Column::ideal(&p).unwrap();
        let curve = characterize(&col, CbMode::Off, &quick_opts());
        assert!(curve.max_abs_inl() < 1e-9);
        assert!(curve.mean_noise_lsb() < 1e-9);
        // Static curve equals counts exactly over the sweep.
        for (c, s) in curve.counts.iter().zip(&curve.static_code) {
            assert_eq!(*s as usize, *c);
        }
    }

    #[test]
    fn real_column_inl_in_spec_and_noise_positive() {
        let p = MacroParams::default();
        let col = Column::new(&p, 0).unwrap();
        let curve = characterize(&col, CbMode::On, &quick_opts());
        let inl = curve.max_abs_inl();
        assert!(inl > 0.2 && inl < 3.5, "max INL = {inl}");
        assert!(curve.mean_noise_lsb() > 0.2, "noise = {}", curve.mean_noise_lsb());
    }

    #[test]
    fn cb_reduces_mean_noise_roughly_2x() {
        let p = MacroParams::default();
        let col = Column::new(&p, 1).unwrap();
        let mut opts = quick_opts();
        opts.trials = 48;
        let off = characterize(&col, CbMode::Off, &opts).mean_noise_lsb();
        let on = characterize(&col, CbMode::On, &opts).mean_noise_lsb();
        let ratio = off / on;
        // Paper quotes "2x"; majority-of-6 caps the code-noise ratio at
        // ~1.9 and quantization floors it further — we measure ~1.55
        // (EXPERIMENTS.md §Deviations).
        assert!(ratio > 1.35 && ratio < 2.1, "noise ratio off/on = {ratio}");
        assert!((on - 0.58).abs() < 0.12, "w/CB noise {on} LSB (paper 0.58)");
    }

    #[test]
    fn characterization_deterministic_across_threads() {
        let p = MacroParams::default();
        let col = Column::new(&p, 2).unwrap();
        let mut o1 = quick_opts();
        o1.threads = 1;
        let mut o8 = quick_opts();
        o8.threads = 8;
        let a = characterize(&col, CbMode::Off, &o1);
        let b = characterize(&col, CbMode::Off, &o8);
        assert_eq!(a.mean_code, b.mean_code);
        assert_eq!(a.noise_lsb, b.noise_lsb);
    }

    #[test]
    fn inl_endpoints_are_zero() {
        let p = MacroParams::default();
        let col = Column::new(&p, 3).unwrap();
        let curve = characterize(&col, CbMode::Off, &quick_opts());
        let inl = curve.inl_lsb();
        assert!(inl[0].abs() < 1e-9);
        assert!(inl[inl.len() - 1].abs() < 1e-9);
    }
}
