//! Figures of merit from the Fig. 6 footnote:
//!
//!   SQNR-FoM = TOPS/W · 2^SQNR-bit,  SQNR-bit = (SQNR[dB] − 1.76)/6.02
//!   CSNR-FoM = TOPS/W · 2^CSNR-bit,  CSNR-bit = (CSNR[dB] − 1.76)/6.02
//!
//! These weight raw energy efficiency by *delivered compute accuracy*, the
//! paper's core argument for why a 65 nm 818-TOPS/W chip beats 7 nm
//! 5616-TOPS/W chips for Transformer workloads.

use super::sqnr::sqnr_bit;

pub fn sqnr_fom(tops_per_watt: f64, sqnr_db: f64) -> f64 {
    tops_per_watt * 2f64.powf(sqnr_bit(sqnr_db))
}

pub fn csnr_fom(tops_per_watt: f64, csnr_db: f64) -> f64 {
    tops_per_watt * 2f64.powf(sqnr_bit(csnr_db))
}

/// How many dB of accuracy buy one doubling of FoM at fixed power: 6.02.
pub const DB_PER_FOM_DOUBLING: f64 = 6.02;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_values_reproduce() {
        // This work: 818 TOPS/W, 45.3 dB SQNR, 31.3 dB CSNR.
        let sq = sqnr_fom(818.0, 45.3);
        let cs = csnr_fom(818.0, 31.3);
        assert!((sq - 118841.0).abs() / 118841.0 < 0.05, "{sq}");
        assert!((cs - 24541.0).abs() / 24541.0 < 0.05, "{cs}");
        // [5]: 5796 TOPS/W but only 17.5 dB SQNR. (The published table
        // rounds its inputs; the recomputed value is ~6% off.)
        let sq5 = sqnr_fom(5796.0, 17.5);
        assert!((sq5 - 33512.0).abs() / 33512.0 < 0.10, "{sq5}");
        // [2]: 5616 TOPS/W at 21 dB.
        let sq2 = sqnr_fom(5616.0, 21.0);
        assert!((sq2 - 51466.0).abs() / 51466.0 < 0.05, "{sq2}");
        // [4]: 400 TOPS/W at 22 dB.
        let sq4 = sqnr_fom(400.0, 22.0);
        assert!((sq4 - 4113.0).abs() / 4113.0 < 0.05, "{sq4}");
    }

    #[test]
    fn six_db_doubles_fom() {
        let a = sqnr_fom(100.0, 30.0);
        let b = sqnr_fom(100.0, 30.0 + DB_PER_FOM_DOUBLING);
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_beats_raw_efficiency_for_transformers() {
        // The paper's argument in one assert: this work's SQNR-FoM tops
        // every baseline despite 7x lower raw TOPS/W than [5]/[2].
        let this = sqnr_fom(818.0, 45.3);
        for (tpw, sqnr) in [(400.0, 22.0), (5796.0, 17.5), (5616.0, 21.0)] {
            assert!(this > sqnr_fom(tpw, sqnr));
        }
    }
}
