//! Request-arrival traces for the serving experiments: Poisson and bursty
//! (Markov-modulated) processes, deterministic in the seed.

use crate::util::rng::Rng;

/// One request arrival.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Arrival time [µs since trace start].
    pub t_us: f64,
    /// Which eval-set image this request asks for.
    pub image_index: usize,
}

/// Arrival process shapes.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Poisson with the given mean rate [requests/s].
    Poisson { rate: f64 },
    /// Two-state burst process: high/low rates with mean dwell times.
    Bursty {
        rate_low: f64,
        rate_high: f64,
        dwell_ms: f64,
    },
}

/// Generate `n` arrivals over the process, cycling image indices over
/// `num_images`.
pub fn generate(process: ArrivalProcess, n: usize, num_images: usize, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut events = Vec::with_capacity(n);
    let mut high = false;
    let mut state_left_us = 0.0f64;
    for i in 0..n {
        let rate = match process {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { rate_low, rate_high, dwell_ms } => {
                if state_left_us <= 0.0 {
                    high = !high;
                    // Exponential dwell.
                    state_left_us = -dwell_ms * 1e3 * (1.0 - rng.f64()).ln();
                }
                if high {
                    rate_high
                } else {
                    rate_low
                }
            }
        };
        // Exponential inter-arrival at `rate` req/s → mean 1e6/rate µs.
        let dt = -(1.0 - rng.f64()).ln() * 1e6 / rate;
        t += dt;
        if let ArrivalProcess::Bursty { .. } = process {
            state_left_us -= dt;
        }
        events.push(TraceEvent { t_us: t, image_index: rng.index(num_images.max(1)) });
        let _ = i;
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let rate = 5000.0;
        let ev = generate(ArrivalProcess::Poisson { rate }, 20_000, 100, 1);
        let span_s = ev.last().unwrap().t_us * 1e-6;
        let emp_rate = ev.len() as f64 / span_s;
        assert!((emp_rate - rate).abs() / rate < 0.05, "emp {emp_rate}");
    }

    #[test]
    fn arrivals_are_sorted_and_deterministic() {
        let a = generate(ArrivalProcess::Poisson { rate: 100.0 }, 500, 10, 3);
        let b = generate(ArrivalProcess::Poisson { rate: 100.0 }, 500, 10, 3);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[1].t_us >= w[0].t_us);
        }
        assert!(a.iter().all(|e| e.image_index < 10));
    }

    #[test]
    fn bursty_has_higher_variance_than_poisson() {
        let n = 30_000;
        let pois = generate(ArrivalProcess::Poisson { rate: 1000.0 }, n, 10, 5);
        let burst = generate(
            ArrivalProcess::Bursty { rate_low: 200.0, rate_high: 5000.0, dwell_ms: 20.0 },
            n,
            10,
            5,
        );
        // Compare coefficient of variation of arrivals-per-window.
        let cv = |ev: &[TraceEvent]| {
            let end = ev.last().unwrap().t_us;
            let win = end / 200.0;
            let mut counts = vec![0f64; 200];
            for e in ev {
                let k = ((e.t_us / win) as usize).min(199);
                counts[k] += 1.0;
            }
            let m = counts.iter().sum::<f64>() / counts.len() as f64;
            let v = counts.iter().map(|c| (c - m) * (c - m)).sum::<f64>() / counts.len() as f64;
            v.sqrt() / m
        };
        assert!(cv(&burst) > 2.0 * cv(&pois), "burst {} pois {}", cv(&burst), cv(&pois));
    }
}
