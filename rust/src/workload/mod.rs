//! Workload generators: the synthetic evaluation corpus (mirroring
//! `python/compile/data.py` via the shared `artifacts/eval_set.npz` is the
//! authoritative path; this module additionally provides pure-rust
//! generators for benches that must run without artifacts) and request
//! traces for the serving experiments.

pub mod corpus;
pub mod trace;

pub use corpus::EvalSet;
pub use trace::{ArrivalProcess, TraceEvent};
