//! Evaluation corpus loading (the held-out slice the python trainer wrote
//! to `artifacts/eval_set.json` + `eval_images.bin`) and a pure-rust
//! CIFAR-like generator for benches that run before artifacts exist.

use std::path::Path;

use crate::util::json;
use crate::util::rng::Rng;

/// The held-out evaluation set shared with python.
#[derive(Clone, Debug)]
pub struct EvalSet {
    /// NHWC f32 pixels, flattened.
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
    pub n: usize,
    pub image: usize,
    pub channels: usize,
}

impl EvalSet {
    /// Load from the artifacts directory (written by train.py).
    pub fn load(dir: &Path) -> Result<EvalSet, String> {
        let meta_text = std::fs::read_to_string(dir.join("eval_set.json"))
            .map_err(|e| format!("read eval_set.json: {e}"))?;
        let meta = json::parse(&meta_text).map_err(|e| format!("eval_set.json: {e}"))?;
        let shape: Vec<usize> = meta
            .get_path("shape")
            .and_then(|s| s.as_arr())
            .ok_or("missing shape")?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as usize)
            .collect();
        if shape.len() != 4 {
            return Err(format!("expected NHWC shape, got {shape:?}"));
        }
        let labels: Vec<u8> = meta
            .get_path("labels")
            .and_then(|l| l.as_arr())
            .ok_or("missing labels")?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as u8)
            .collect();
        let bin_name = meta
            .get_path("images_bin")
            .and_then(|b| b.as_str())
            .ok_or("missing images_bin")?;
        let bytes = std::fs::read(dir.join(bin_name)).map_err(|e| format!("read bin: {e}"))?;
        let expect = shape.iter().product::<usize>();
        if bytes.len() != expect * 4 {
            return Err(format!(
                "eval bin size {} != {} floats",
                bytes.len(),
                expect
            ));
        }
        let images: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        if labels.len() != shape[0] {
            return Err("labels/images count mismatch".into());
        }
        Ok(EvalSet { images, labels, n: shape[0], image: shape[1], channels: shape[3] })
    }

    pub fn image_floats(&self) -> usize {
        self.image * self.image * self.channels
    }

    /// Borrow image `i` as a flat slice.
    pub fn image_slice(&self, i: usize) -> &[f32] {
        let w = self.image_floats();
        &self.images[i * w..(i + 1) * w]
    }

    /// Pure-rust synthetic stand-in (structure-bearing, deterministic):
    /// used by benches that must run without `make artifacts`. NOT the
    /// same distribution as the python corpus — accuracy experiments use
    /// the shared artifact set.
    pub fn synthetic(n: usize, image: usize, seed: u64) -> EvalSet {
        let mut rng = Rng::new(seed);
        let channels = 3;
        let w = image * image * channels;
        let mut images = Vec::with_capacity(n * w);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = (i % 10) as u8;
            labels.push(class);
            let theta = std::f64::consts::PI * class as f64 / 10.0;
            let freq = 2.0 + (class % 5) as f64;
            let phase = rng.range(0.0, std::f64::consts::TAU);
            for y in 0..image {
                for x in 0..image {
                    let u = x as f64 / image as f64 - 0.5;
                    let v = y as f64 / image as f64 - 0.5;
                    let t = u * theta.cos() + v * theta.sin();
                    let base = (std::f64::consts::TAU * freq * t + phase).sin();
                    for _ in 0..channels {
                        images.push((base + 0.2 * rng.gauss()) as f32);
                    }
                }
            }
        }
        EvalSet { images, labels, n, image, channels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shapes_and_determinism() {
        let a = EvalSet::synthetic(20, 32, 7);
        let b = EvalSet::synthetic(20, 32, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.n, 20);
        assert_eq!(a.image_floats(), 32 * 32 * 3);
        assert_eq!(a.image_slice(3).len(), a.image_floats());
        assert_eq!(a.labels[3], 3);
    }

    #[test]
    fn load_round_trip_via_tempdir() {
        // Write a tiny eval set in the python format and read it back.
        let dir = std::env::temp_dir().join(format!("crcim-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let images: Vec<f32> = (0..2 * 2 * 2 * 3).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = images.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("eval_images.bin"), &bytes).unwrap();
        std::fs::write(
            dir.join("eval_set.json"),
            r#"{"images_bin": "eval_images.bin", "shape": [2, 2, 2, 3], "labels": [4, 9]}"#,
        )
        .unwrap();
        let set = EvalSet::load(&dir).unwrap();
        assert_eq!(set.n, 2);
        assert_eq!(set.labels, vec![4, 9]);
        assert_eq!(set.images, images);
        assert_eq!(set.image_slice(1)[0], 6.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_bad_sizes() {
        let dir = std::env::temp_dir().join(format!("crcim-test-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("eval_images.bin"), [0u8; 8]).unwrap();
        std::fs::write(
            dir.join("eval_set.json"),
            r#"{"images_bin": "eval_images.bin", "shape": [1, 2, 2, 3], "labels": [0]}"#,
        )
        .unwrap();
        assert!(EvalSet::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
