//! 2-D tiled macro execution for the serving path: row tiles × column
//! shards.
//!
//! One macro converts a fixed tile per conversion: at most
//! `MacroParams::active_rows` rows of the reduction dimension and
//! `cols / w_bits` logical outputs. A layer of arbitrary shape therefore
//! splits two ways:
//!
//! - **column shards** (n-dimension): independent [`CimMacro`]s each own a
//!   contiguous slice of the outputs and convert concurrently — the
//!   parallelism the chip's floorplan offers;
//! - **row tiles** (k-dimension): when `k > active_rows` (every ViT MLP
//!   `fc2`, d_ff = 3072, on the 1024-row macro), the reduction splits into
//!   row tiles whose partial sums accumulate **digitally** in the output
//!   periphery. Each row tile is a distinct physical macro with its own
//!   mismatch/noise seed, so per-tile output noise is independent and the
//!   accumulated total composes in quadrature
//!   (see [`kernel_noise_sigma_for_row_tiles`]).
//!
//! [`MacroShards`] owns the (row tile × column shard) unit grid and
//! stitches per-unit outputs into full vectors; [`SimExecutor`] (built on
//! the multi-die [`DieBank`](super::multidie::DieBank)) wraps it in the
//! server's [`BatchExecutor`] interface so a served batch runs an
//! arbitrary-shape layer across parallel macros instead of one serial
//! loop.
//!
//! # Determinism contract
//!
//! The substream hierarchy is `seed → die → row tile → global column →
//! conversion counter`:
//!
//! - each row tile derives its macro seed from the die seed and the tile
//!   index ([`MacroParams::for_row_tile`]);
//! - each column keys its mismatch and conversion noise on its **global**
//!   column index (`MacroParams::col_base` + physical index), not its
//!   index within a shard.
//!
//! Consequences, test-enforced in `rust/tests/tiled_shards.rs`:
//! results are **bit-identical at any worker-thread count and at any
//! column-shard count** (even with noise — the shard decomposition is
//! invisible to the noise model), bit-identical across row-tile counts at
//! zero noise, and run-to-run reproducible always. Changing the row-tile
//! count redistributes rows across *different physical macros*, so noisy
//! outputs legitimately differ — exactly as re-mapping a layer onto other
//! dies would on silicon.

use crate::cim::netstats::LayerClass;
use crate::cim::{CimMacro, MacroParams};
use crate::util::pool::parallel_map_mut;
use crate::vit::plan::OperatingPoint;
use crate::vit::LinearShape;

use super::multidie::DieBank;
use super::sac::{kernel_noise_sigma_for_row_tiles, PlanCost};
use super::scheduler::Scheduler;
use super::server::BatchExecutor;

/// One execution unit: a macro plus the (row, output) ranges it owns.
struct Unit {
    mac: CimMacro,
    /// First logical output this unit computes.
    out_lo: usize,
    /// One past the last logical output.
    out_hi: usize,
    /// First row of the reduction dimension this unit integrates.
    row_lo: usize,
    /// One past the last row.
    row_hi: usize,
}

/// A logical (k × n) integer linear layer split across a 2-D grid of
/// macros: row tiles over the reduction dimension × column shards over
/// the outputs. Partial sums from the row tiles of each output accumulate
/// digitally; see the module docs for the tiling and determinism model.
pub struct MacroShards {
    units: Vec<Unit>,
    /// Operating point (bit widths + CB mode) the layer runs at.
    pub op: OperatingPoint,
    /// Reduction dimension (rows of the weight matrix).
    pub k: usize,
    /// Logical outputs across all shards.
    pub n: usize,
    /// Row tiles the reduction dimension is split into.
    row_tiles: usize,
    /// Column shards the outputs are split into.
    col_shards: usize,
    /// Worker threads for the cross-unit fan-out.
    threads: usize,
    /// Cumulative conversions across all `matvec_batch` calls.
    pub total_conversions: u64,
    /// Cumulative conversion energy [pJ] across all calls.
    pub total_energy_pj: f64,
}

impl MacroShards {
    /// Build a shard bank for the signed weight matrix `w[row][out]` at
    /// the given operating point, with the minimum number of row tiles
    /// (`⌈k / active_rows⌉` — one for k ≤ 1024 on the default geometry).
    /// `shards` is a request: it is raised to the minimum number of
    /// macros the outputs need and capped at one output per shard.
    ///
    /// Any `k ≥ 1` is accepted: a reduction dimension deeper than one
    /// tile (k > `active_rows`, e.g. the d_ff = 3072 MLP `fc2`) row-tiles
    /// automatically instead of erroring.
    pub fn new(
        params: &MacroParams,
        w: &[Vec<i32>],
        op: OperatingPoint,
        shards: usize,
    ) -> Result<Self, String> {
        Self::with_tiling(params, w, op, shards, 1)
    }

    /// Like [`new`](Self::new), but with an explicit row-tile request.
    /// `row_tiles` is raised to the minimum the geometry needs
    /// (`⌈k / active_rows⌉`) and capped at one row per tile; requesting
    /// more tiles than needed splits the reduction across more, smaller
    /// physical macros (useful to spread a hot layer over idle silicon).
    pub fn with_tiling(
        params: &MacroParams,
        w: &[Vec<i32>],
        op: OperatingPoint,
        shards: usize,
        row_tiles: usize,
    ) -> Result<Self, String> {
        op.validate()?;
        // The operating point's per-layer voting configuration overrides
        // the deployment default *here*, at the single point every
        // executor path (DieBank pools, SimExecutor, direct shards)
        // funnels through. The cloned params reach both the SAR model
        // (comparison counts, noise draws) and each macro's EnergyModel,
        // so behavior and measured energy price the same point the
        // planner does (`Scheduler::plan_linear` applies the same
        // override) — planned == measured by construction.
        let params = &params
            .clone()
            .with_mv(op.noise.mv_votes as usize, op.noise.mv_last_bits as usize);
        let k = w.len();
        if k == 0 {
            return Err("empty weight matrix".to_string());
        }
        let n = w[0].len();
        if n == 0 {
            return Err("weight matrix has no outputs".to_string());
        }
        if w.iter().any(|row| row.len() != n) {
            return Err("ragged weight matrix".to_string());
        }
        let cap_out = params.cols / op.w_bits as usize;
        if cap_out == 0 {
            return Err(format!("w_bits {} exceeds macro columns {}", op.w_bits, params.cols));
        }
        let s = shards.max(1).max(n.div_ceil(cap_out)).min(n);
        let t = row_tiles.max(1).max(params.row_tiles_needed(k)).min(k);
        // Units convert concurrently AND each unit keeps a slice of the
        // worker budget for its own column fan-out, so total parallelism
        // stays at the caller's thread count rather than the unit count.
        // Determinism is unaffected: noise is per-column owned.
        let inner_threads = params.effective_threads().div_ceil(t * s).max(1);
        let col_base = |out_lo: usize| out_lo * op.w_bits as usize;
        let mut units = Vec::with_capacity(t * s);
        let (row_base, row_extra) = (k / t, k % t);
        let mut row_lo = 0usize;
        for ti in 0..t {
            let row_hi = row_lo + row_base + usize::from(ti < row_extra);
            // All column shards of one row tile live on the same physical
            // macro seed; columns key globally, so the shard split is
            // noise-invisible (see module docs).
            let tile_params = params.clone().for_row_tile(ti).with_threads(inner_threads);
            let (out_base, out_extra) = (n / s, n % s);
            let mut out_lo = 0usize;
            for si in 0..s {
                let out_hi = out_lo + out_base + usize::from(si < out_extra);
                let p = tile_params.clone().with_col_base(col_base(out_lo));
                let mut mac = CimMacro::new(&p)?;
                let slice: Vec<Vec<i32>> = w[row_lo..row_hi]
                    .iter()
                    .map(|row| row[out_lo..out_hi].to_vec())
                    .collect();
                mac.load_weights(&slice, op.w_bits)?;
                units.push(Unit { mac, out_lo, out_hi, row_lo, row_hi });
                out_lo = out_hi;
            }
            row_lo = row_hi;
        }
        Ok(MacroShards {
            units,
            op,
            k,
            n,
            row_tiles: t,
            col_shards: s,
            threads: params.effective_threads(),
            total_conversions: 0,
            total_energy_pj: 0.0,
        })
    }

    /// Column shards the outputs are split into.
    pub fn shard_count(&self) -> usize {
        self.col_shards
    }

    /// Row tiles the reduction dimension is split into.
    pub fn row_tile_count(&self) -> usize {
        self.row_tiles
    }

    /// Total (row tile × column shard) macros in the bank.
    pub fn unit_count(&self) -> usize {
        self.units.len()
    }

    /// Integer-domain output noise σ of one logical output of this bank,
    /// given the calibrated per-conversion read noise (LSB): the per-tile
    /// σ of the `row_tiles` independently-seeded macros adds in
    /// quadrature through the digital accumulator. This is the bridge
    /// that keeps SAC plans honest for tiled layers.
    pub fn kernel_sigma(&self, sigma_read_lsb: f64) -> f64 {
        let (a, w) = (self.op.a_bits, self.op.w_bits);
        kernel_noise_sigma_for_row_tiles(self.row_tiles, a, w, sigma_read_lsb)
    }

    /// Run a batch of activation vectors through all units concurrently,
    /// accumulate row-tile partial sums digitally, and stitch the
    /// per-shard outputs into full `n`-wide vectors.
    pub fn matvec_batch(&mut self, xs: &[Vec<i32>]) -> Result<Vec<Vec<i64>>, String> {
        let (a_bits, mode) = (self.op.a_bits, self.op.cb);
        let k = self.k;
        for (v, x) in xs.iter().enumerate() {
            if x.len() != k {
                return Err(format!("activation {v} length {} != layer k {k}", x.len()));
            }
        }
        let per_unit = parallel_map_mut(&mut self.units, self.threads, |_, unit| {
            let slices: Vec<&[i32]> =
                xs.iter().map(|x| &x[unit.row_lo..unit.row_hi]).collect();
            unit.mac.matvec_batch(&slices, a_bits, mode)
        });
        let mut outputs = vec![vec![0i64; self.n]; xs.len()];
        for (unit, result) in self.units.iter().zip(per_unit) {
            let runs = result?;
            for (v, run) in runs.into_iter().enumerate() {
                // Digital accumulation: row tiles of the same output add.
                for (j, y) in run.y.into_iter().enumerate() {
                    outputs[v][unit.out_lo + j] += y;
                }
                self.total_conversions += run.conversions;
                self.total_energy_pj += run.energy_pj;
            }
        }
        Ok(outputs)
    }
}

/// Macro-simulator-backed batch executor: a single integer linear
/// classifier head served straight off the tiled multi-die circuit model.
/// Stands in for the PJRT executor in tests, demos and load experiments —
/// every served batch exercises the true column-parallel conversion path,
/// including the row-tile accumulation and cross-die routing.
pub struct SimExecutor {
    bank: DieBank,
    cost: PlanCost,
    classes: usize,
}

impl SimExecutor {
    /// Single-die executor with a deterministic pseudo-random weight tile
    /// derived from `params.seed` (a stand-in classifier head).
    pub fn new(
        params: &MacroParams,
        k: usize,
        classes: usize,
        op: OperatingPoint,
        shards: usize,
    ) -> Result<Self, String> {
        Self::with_dies(params, k, classes, op, shards, 1)
    }

    /// Executor serving across `dies` independent dies: each die holds a
    /// full copy of the layer under its own seed
    /// ([`MacroParams::for_die`]) and batches split across dies by vector
    /// index. Any `k` is accepted — deep reductions row-tile per die.
    pub fn with_dies(
        params: &MacroParams,
        k: usize,
        classes: usize,
        op: OperatingPoint,
        shards: usize,
        dies: usize,
    ) -> Result<Self, String> {
        if op.w_bits == 0 || op.w_bits > 16 {
            return Err(format!("w_bits {} out of range 1..=16", op.w_bits));
        }
        let mut rng = crate::util::rng::Rng::salted(params.seed, 0x51AC_0E5E);
        let (lo, _) = op.w_range();
        let span = 1u64 << op.w_bits;
        let w: Vec<Vec<i32>> = (0..k)
            .map(|_| (0..classes).map(|_| lo + rng.below(span) as i32).collect())
            .collect();
        let bank = DieBank::new(params, &w, op, shards, dies)?;
        let sched = Scheduler::with_topology(params, bank.shard_count(), bank.die_count());
        let shape = LinearShape { class: LayerClass::TransformerMlp, k, n: classes, m: 1 };
        let total = sched.plan_linear(&shape, op);
        let cost = PlanCost::from_total("sim-linear (tiled multi-die macro)", total);
        Ok(SimExecutor { bank, cost, classes })
    }

    /// Independent dies the executor routes batches across.
    pub fn die_count(&self) -> usize {
        self.bank.die_count()
    }

    /// Quantize one image into a k-long activation vector in a_bits range
    /// (the same map the pipeline executor's
    /// [`featurize`](super::pipeline::featurize) applies per layer 0).
    fn featurize(&self, img: &[f32]) -> Vec<i32> {
        super::pipeline::featurize(self.bank.op, self.bank.k, img)
    }
}

impl BatchExecutor for SimExecutor {
    fn execute(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        let xs: Vec<Vec<i32>> = images.iter().map(|img| self.featurize(img)).collect();
        let ys = self.bank.matvec_batch(&xs)?;
        // Normalize so logits stay O(1); argmax is scale-invariant.
        let w_hi = ((1i64 << (self.bank.op.w_bits - 1)) - 1).max(1);
        let a_hi = ((1i64 << (self.bank.op.a_bits - 1)) - 1).max(1);
        let scale = (self.bank.k as f64 * (w_hi * a_hi) as f64).recip();
        Ok(ys
            .into_iter()
            .map(|y| y.into_iter().map(|v| (v as f64 * scale) as f32).collect())
            .collect())
    }

    fn cost(&self) -> &PlanCost {
        &self.cost
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CbMode;
    use crate::util::rng::Rng;

    fn quiet_params() -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        // Noise-free: sharded output must equal the exact integer matvec.
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        p
    }

    fn op_2b() -> OperatingPoint {
        OperatingPoint::new(2, 2, CbMode::Off)
    }

    fn tile(k: usize, n: usize, bits: u32, seed: u64) -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
        let mut rng = Rng::new(seed);
        let lo = -(1i32 << (bits - 1));
        let span = 1u64 << bits;
        let w = (0..k).map(|_| (0..n).map(|_| lo + rng.below(span) as i32).collect()).collect();
        let xs = (0..3).map(|_| (0..k).map(|_| lo + rng.below(span) as i32).collect()).collect();
        (w, xs)
    }

    #[test]
    fn sharded_matvec_matches_exact_reference() {
        let p = quiet_params();
        // 10 outputs at 2b = 20 planes > 12 cols: needs ≥ 2 shards.
        let (w, xs) = tile(64, 10, 2, 3);
        let mut bank = MacroShards::new(&p, &w, op_2b(), 3).unwrap();
        assert_eq!(bank.shard_count(), 3);
        assert_eq!(bank.row_tile_count(), 1);
        let got = bank.matvec_batch(&xs).unwrap();
        let reference = CimMacro::ideal(&p).unwrap();
        for (v, x) in xs.iter().enumerate() {
            assert_eq!(got[v], reference.matvec_exact(&w, x), "vector {v}");
        }
        assert!(bank.total_conversions > 0);
        assert!(bank.total_energy_pj > 0.0);
    }

    #[test]
    fn deep_k_row_tiles_and_matches_exact() {
        let p = quiet_params();
        // k = 150 over 64-row macros: 3 row tiles, accumulated digitally.
        let (w, xs) = tile(150, 4, 2, 9);
        let mut bank = MacroShards::new(&p, &w, op_2b(), 1).unwrap();
        assert_eq!(bank.row_tile_count(), 3);
        assert_eq!(bank.unit_count(), 3);
        let got = bank.matvec_batch(&xs).unwrap();
        let reference = CimMacro::ideal(&p).unwrap();
        for (v, x) in xs.iter().enumerate() {
            assert_eq!(got[v], reference.matvec_exact(&w, x), "vector {v}");
        }
        // Conversions scale with the tile count: 3 tiles × 8 used cols ×
        // 2 a_bits × 3 vectors.
        assert_eq!(bank.total_conversions, 3 * 8 * 2 * 3);
    }

    #[test]
    fn over_requested_row_tiles_split_further() {
        let p = quiet_params();
        let (w, xs) = tile(64, 4, 2, 11);
        // One tile would do; ask for 5 smaller ones.
        let mut bank = MacroShards::with_tiling(&p, &w, op_2b(), 1, 5).unwrap();
        assert_eq!(bank.row_tile_count(), 5);
        let got = bank.matvec_batch(&xs).unwrap();
        let reference = CimMacro::ideal(&p).unwrap();
        for (v, x) in xs.iter().enumerate() {
            assert_eq!(got[v], reference.matvec_exact(&w, x), "vector {v}");
        }
        // A tile request beyond k caps at one row per tile.
        let (w1, _) = tile(3, 2, 2, 12);
        let bank = MacroShards::with_tiling(&p, &w1, op_2b(), 1, 99).unwrap();
        assert_eq!(bank.row_tile_count(), 3);
    }

    #[test]
    fn shard_request_is_raised_to_capacity_and_reproducible() {
        let mut p = quiet_params();
        p.sigma_cmp_lsb = 1.1; // real noise: reproducibility is nontrivial
        let (w, xs) = tile(64, 10, 2, 5);
        // Request 1 shard, but 10 outputs × 2b = 20 planes need 2 macros.
        let run = || {
            let mut bank = MacroShards::new(&p, &w, op_2b(), 1).unwrap();
            assert_eq!(bank.shard_count(), 2);
            bank.matvec_batch(&xs).unwrap()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn noisy_results_are_shard_count_invariant() {
        // The strong half of the determinism contract: columns key on
        // their global index, so the column-shard split is invisible to
        // the noise model even with real noise.
        let mut p = quiet_params();
        p.sigma_cmp_lsb = 1.1;
        p.sigma_cu_rel = 0.01;
        let (w, xs) = tile(64, 6, 2, 6);
        let run = |shards: usize| {
            let mut bank = MacroShards::new(&p, &w, op_2b(), shards).unwrap();
            bank.matvec_batch(&xs).unwrap()
        };
        let one = run(1);
        for shards in [2usize, 3, 6] {
            assert_eq!(run(shards), one, "shards={shards}");
        }
    }

    #[test]
    fn rejects_bad_geometry() {
        let p = quiet_params();
        assert!(MacroShards::new(&p, &[], op_2b(), 1).is_err());
        assert!(MacroShards::new(&p, &[vec![]], op_2b(), 1).is_err());
        let ragged = vec![vec![1, 0], vec![1]];
        assert!(MacroShards::new(&p, &ragged, op_2b(), 1).is_err());
        let wide_op = OperatingPoint::new(2, 13, CbMode::Off);
        assert!(MacroShards::new(&p, &[vec![1i32]], wide_op, 1).is_err());
        // Oversized bit widths return Err (no shift-overflow panics), and
        // SimExecutor inherits the same guard.
        let huge_a = OperatingPoint::new(33, 2, CbMode::Off);
        assert!(MacroShards::new(&p, &[vec![1i32]], huge_a, 1).is_err());
        assert!(SimExecutor::new(&p, 4, 2, huge_a, 1).is_err());
        // Activation length must match the layer's k.
        let (w, _) = tile(64, 2, 2, 8);
        let mut bank = MacroShards::new(&p, &w, op_2b(), 1).unwrap();
        assert!(bank.matvec_batch(&[vec![0i32; 63]]).is_err());
    }

    #[test]
    fn sim_executor_serves_batches() {
        let p = quiet_params();
        let mut exec = SimExecutor::new(&p, 64, 10, op_2b(), 2).unwrap();
        assert_eq!(exec.num_classes(), 10);
        assert_eq!(exec.die_count(), 1);
        assert!(exec.cost().energy_uj > 0.0);
        let images: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..64).map(|j| ((i * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect())
            .collect();
        let logits = exec.execute(&images).unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|l| l.len() == 10));
        assert!(logits.iter().flatten().all(|v| v.is_finite()));
    }

    #[test]
    fn kernel_sigma_composes_in_quadrature_with_tiles() {
        let p = quiet_params();
        let (w1, _) = tile(64, 2, 2, 14);
        let (w4, _) = tile(256, 2, 2, 14);
        let one = MacroShards::new(&p, &w1, op_2b(), 1).unwrap();
        let four = MacroShards::new(&p, &w4, op_2b(), 1).unwrap();
        assert_eq!(four.row_tile_count(), 4);
        let (s1, s4) = (one.kernel_sigma(0.5), four.kernel_sigma(0.5));
        assert!((s4 / s1 - 2.0).abs() < 1e-12, "4 tiles must double σ: {s1} {s4}");
    }
}
