//! Column-sharded macro execution for the serving path.
//!
//! One macro holds `cols / w_bits` logical outputs per tile; a layer with
//! more outputs (or a deployment with idle macros) splits column-wise
//! across independent [`CimMacro`] shards that convert concurrently —
//! exactly the parallelism the chip's floorplan offers. [`MacroShards`]
//! owns the shard bank and stitches per-shard outputs back into full
//! output vectors; [`SimExecutor`] wraps it in the server's
//! [`BatchExecutor`] interface so a served batch runs tiles across
//! parallel macro shards instead of one serial loop.
//!
//! Determinism: each shard derives its die seed from (base seed, shard
//! index) and each column inside a shard owns its conversion substream,
//! so a given (params, weights, shard count) is reproducible regardless
//! of worker-thread counts.

use crate::cim::netstats::LayerClass;
use crate::cim::{CimMacro, MacroParams};
use crate::util::pool::parallel_map_mut;
use crate::util::rng::Rng;
use crate::vit::plan::OperatingPoint;
use crate::vit::LinearShape;

use super::sac::PlanCost;
use super::scheduler::Scheduler;
use super::server::BatchExecutor;

/// One shard: a macro plus the logical output range it owns.
struct Shard {
    mac: CimMacro,
    out_lo: usize,
    out_hi: usize,
}

/// A logical (k × n) integer linear layer split column-wise across
/// parallel macro shards.
pub struct MacroShards {
    shards: Vec<Shard>,
    pub op: OperatingPoint,
    /// Reduction dimension (rows of the weight matrix).
    pub k: usize,
    /// Logical outputs across all shards.
    pub n: usize,
    /// Worker threads for the cross-shard fan-out.
    threads: usize,
    /// Cumulative conversions across all `matvec_batch` calls.
    pub total_conversions: u64,
    /// Cumulative conversion energy [pJ] across all calls.
    pub total_energy_pj: f64,
}

impl MacroShards {
    /// Build a shard bank for the signed weight matrix `w[row][out]` at
    /// the given operating point. `shards` is a request: it is raised to
    /// the minimum number of macros the outputs need, and capped at one
    /// output per shard.
    pub fn new(
        params: &MacroParams,
        w: &[Vec<i32>],
        op: OperatingPoint,
        shards: usize,
    ) -> Result<Self, String> {
        if op.a_bits == 0 || op.a_bits > 31 || op.w_bits == 0 || op.w_bits > 31 {
            return Err(format!(
                "operating point bits out of range 1..=31 (a_bits {}, w_bits {})",
                op.a_bits, op.w_bits
            ));
        }
        let k = w.len();
        if k == 0 {
            return Err("empty weight matrix".to_string());
        }
        if k > params.active_rows {
            return Err(format!("k {k} exceeds macro rows {}", params.active_rows));
        }
        let n = w[0].len();
        if n == 0 {
            return Err("weight matrix has no outputs".to_string());
        }
        if w.iter().any(|row| row.len() != n) {
            return Err("ragged weight matrix".to_string());
        }
        let cap_out = params.cols / op.w_bits as usize;
        if cap_out == 0 {
            return Err(format!("w_bits {} exceeds macro columns {}", op.w_bits, params.cols));
        }
        let s = shards.max(1).max(n.div_ceil(cap_out)).min(n);
        // Shards convert concurrently AND each shard keeps a slice of the
        // worker budget for its own column fan-out, so total parallelism
        // stays at the caller's thread count rather than the shard count.
        // Determinism is unaffected: noise is per-column owned.
        let inner_threads = params.effective_threads().div_ceil(s).max(1);
        let base = n / s;
        let extra = n % s;
        let mut bank = Vec::with_capacity(s);
        let mut out_lo = 0usize;
        for i in 0..s {
            let take = base + usize::from(i < extra);
            let out_hi = out_lo + take;
            let p = params
                .clone()
                .with_seed(params.seed ^ (i as u64).wrapping_mul(0xD1B5_4A32_D192_ED03))
                .with_threads(inner_threads);
            let mut mac = CimMacro::new(&p)?;
            let slice: Vec<Vec<i32>> =
                w.iter().map(|row| row[out_lo..out_hi].to_vec()).collect();
            mac.load_weights(&slice, op.w_bits)?;
            bank.push(Shard { mac, out_lo, out_hi });
            out_lo = out_hi;
        }
        Ok(MacroShards {
            shards: bank,
            op,
            k,
            n,
            threads: params.effective_threads(),
            total_conversions: 0,
            total_energy_pj: 0.0,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Run a batch of activation vectors through all shards concurrently
    /// and stitch the per-shard outputs into full `n`-wide vectors.
    pub fn matvec_batch(&mut self, xs: &[Vec<i32>]) -> Result<Vec<Vec<i64>>, String> {
        let (a_bits, mode) = (self.op.a_bits, self.op.cb);
        let per_shard = parallel_map_mut(&mut self.shards, self.threads, |_, shard| {
            shard.mac.matvec_batch(xs, a_bits, mode)
        });
        let mut outputs = vec![vec![0i64; self.n]; xs.len()];
        for (shard, result) in self.shards.iter().zip(per_shard) {
            let runs = result?;
            for (v, run) in runs.into_iter().enumerate() {
                outputs[v][shard.out_lo..shard.out_hi].copy_from_slice(&run.y);
                self.total_conversions += run.conversions;
                self.total_energy_pj += run.energy_pj;
            }
        }
        Ok(outputs)
    }
}

/// Macro-simulator-backed batch executor: a single integer linear
/// classifier head served straight off the sharded circuit model. Stands
/// in for the PJRT executor in tests, demos and load experiments — every
/// served batch exercises the true column-parallel conversion path.
pub struct SimExecutor {
    shards: MacroShards,
    cost: PlanCost,
    classes: usize,
}

impl SimExecutor {
    /// Build with a deterministic pseudo-random weight tile derived from
    /// `params.seed` (a stand-in classifier head).
    pub fn new(
        params: &MacroParams,
        k: usize,
        classes: usize,
        op: OperatingPoint,
        shards: usize,
    ) -> Result<Self, String> {
        if op.w_bits == 0 || op.w_bits > 16 {
            return Err(format!("w_bits {} out of range 1..=16", op.w_bits));
        }
        let mut rng = Rng::new(params.seed ^ 0x51AC_0E5E);
        let lo = -(1i32 << (op.w_bits - 1));
        let span = 1u64 << op.w_bits;
        let w: Vec<Vec<i32>> = (0..k)
            .map(|_| (0..classes).map(|_| lo + rng.below(span) as i32).collect())
            .collect();
        let shards = MacroShards::new(params, &w, op, shards)?;
        let sched = Scheduler::with_shards(params, shards.shard_count());
        let shape = LinearShape { class: LayerClass::TransformerMlp, k, n: classes, m: 1 };
        let total = sched.plan_linear(&shape, op);
        let cost = PlanCost {
            plan_name: "sim-linear (sharded macro)",
            total,
            energy_uj: total.energy_pj * 1e-6,
            latency_us: total.latency_ns * 1e-3,
            tops_per_watt_effective: total.ops_1b / (total.energy_pj * 1e-12) / 1e12,
        };
        Ok(SimExecutor { shards, cost, classes })
    }

    /// Quantize one image into a k-long activation vector in a_bits range.
    fn featurize(&self, img: &[f32]) -> Vec<i32> {
        let a_hi = (1i32 << (self.shards.op.a_bits - 1)) - 1;
        let a_lo = -(1i32 << (self.shards.op.a_bits - 1));
        (0..self.shards.k)
            .map(|r| {
                if img.is_empty() {
                    return 0;
                }
                let v = img[r * img.len() / self.shards.k];
                let q = (v.clamp(-1.0, 1.0) * a_hi as f32).round() as i32;
                q.clamp(a_lo, a_hi)
            })
            .collect()
    }
}

impl BatchExecutor for SimExecutor {
    fn execute(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        let xs: Vec<Vec<i32>> = images.iter().map(|img| self.featurize(img)).collect();
        let ys = self.shards.matvec_batch(&xs)?;
        // Normalize so logits stay O(1); argmax is scale-invariant.
        let w_hi = ((1i64 << (self.shards.op.w_bits - 1)) - 1).max(1);
        let a_hi = ((1i64 << (self.shards.op.a_bits - 1)) - 1).max(1);
        let scale = (self.shards.k as f64 * (w_hi * a_hi) as f64).recip();
        Ok(ys
            .into_iter()
            .map(|y| y.into_iter().map(|v| (v as f64 * scale) as f32).collect())
            .collect())
    }

    fn cost(&self) -> &PlanCost {
        &self.cost
    }

    fn num_classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CbMode;

    fn quiet_params() -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        // Noise-free: sharded output must equal the exact integer matvec.
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        p
    }

    fn op_2b() -> OperatingPoint {
        OperatingPoint { a_bits: 2, w_bits: 2, cb: CbMode::Off }
    }

    fn tile(k: usize, n: usize, bits: u32, seed: u64) -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
        let mut rng = Rng::new(seed);
        let lo = -(1i32 << (bits - 1));
        let span = 1u64 << bits;
        let w = (0..k).map(|_| (0..n).map(|_| lo + rng.below(span) as i32).collect()).collect();
        let xs = (0..3).map(|_| (0..k).map(|_| lo + rng.below(span) as i32).collect()).collect();
        (w, xs)
    }

    #[test]
    fn sharded_matvec_matches_exact_reference() {
        let p = quiet_params();
        // 10 outputs at 2b = 20 planes > 12 cols: needs ≥ 2 shards.
        let (w, xs) = tile(64, 10, 2, 3);
        let mut bank = MacroShards::new(&p, &w, op_2b(), 3).unwrap();
        assert_eq!(bank.shard_count(), 3);
        let got = bank.matvec_batch(&xs).unwrap();
        let reference = CimMacro::ideal(&p).unwrap();
        for (v, x) in xs.iter().enumerate() {
            assert_eq!(got[v], reference.matvec_exact(&w, x), "vector {v}");
        }
        assert!(bank.total_conversions > 0);
        assert!(bank.total_energy_pj > 0.0);
    }

    #[test]
    fn shard_request_is_raised_to_capacity_and_reproducible() {
        let mut p = quiet_params();
        p.sigma_cmp_lsb = 1.1; // real noise: reproducibility is nontrivial
        let (w, xs) = tile(64, 10, 2, 5);
        // Request 1 shard, but 10 outputs × 2b = 20 planes need 2 macros.
        let run = || {
            let mut bank = MacroShards::new(&p, &w, op_2b(), 1).unwrap();
            assert_eq!(bank.shard_count(), 2);
            bank.matvec_batch(&xs).unwrap()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn rejects_bad_geometry() {
        let p = quiet_params();
        assert!(MacroShards::new(&p, &[], op_2b(), 1).is_err());
        assert!(MacroShards::new(&p, &[vec![]], op_2b(), 1).is_err());
        let ragged = vec![vec![1, 0], vec![1]];
        assert!(MacroShards::new(&p, &ragged, op_2b(), 1).is_err());
        let too_deep = vec![vec![1i32]; 100];
        assert!(MacroShards::new(&p, &too_deep, op_2b(), 1).is_err());
        let wide_op = OperatingPoint { a_bits: 2, w_bits: 13, cb: CbMode::Off };
        assert!(MacroShards::new(&p, &[vec![1i32]], wide_op, 1).is_err());
        // Oversized bit widths return Err (no shift-overflow panics), and
        // SimExecutor inherits the same guard.
        let huge_a = OperatingPoint { a_bits: 33, w_bits: 2, cb: CbMode::Off };
        assert!(MacroShards::new(&p, &[vec![1i32]], huge_a, 1).is_err());
        assert!(SimExecutor::new(&p, 4, 2, huge_a, 1).is_err());
    }

    #[test]
    fn sim_executor_serves_batches() {
        let p = quiet_params();
        let mut exec = SimExecutor::new(&p, 64, 10, op_2b(), 2).unwrap();
        assert_eq!(exec.num_classes(), 10);
        assert!(exec.cost().energy_uj > 0.0);
        let images: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..64).map(|j| ((i * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect())
            .collect();
        let logits = exec.execute(&images).unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|l| l.len() == 10));
        assert!(logits.iter().flatten().all(|v| v.is_finite()));
    }
}
