//! Multi-macro router: a deployment packages several CR-CIM macros
//! behind one coordinator (the chip photo's macro is the unit cell of a
//! bigger accelerator). The router places each layer's column tiles on
//! macros, balancing load so the bit-serial pipelines of all macros
//! finish together, and models weight residency so repeated inferences
//! don't pay reload cost.
//!
//! Placement policy: longest-processing-time (LPT) greedy over per-tile
//! latency — optimal within 4/3 for makespan, fine for this tile
//! granularity.

use crate::cim::params::MacroParams;
use crate::vit::plan::PrecisionPlan;
use crate::vit::{linear_workload, VitConfig};

use super::scheduler::Scheduler;

/// One placed tile.
#[derive(Clone, Debug)]
pub struct Placement {
    pub layer_index: usize,
    pub col_tile: u64,
    pub macro_id: usize,
    pub latency_ns: f64,
    pub energy_pj: f64,
}

/// Routing result for one inference pass.
#[derive(Clone, Debug)]
pub struct RoutePlan {
    pub placements: Vec<Placement>,
    /// Per-macro busy time [ns].
    pub macro_busy_ns: Vec<f64>,
    /// Critical-path (makespan) latency [ns].
    pub makespan_ns: f64,
    /// Total energy [pJ].
    pub energy_pj: f64,
    /// Weight SRAM bits resident per macro (capacity check).
    pub resident_bits: Vec<u64>,
}

impl RoutePlan {
    /// Load imbalance: max/mean busy time (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let max = self.macro_busy_ns.iter().cloned().fold(0.0f64, f64::max);
        let mean =
            self.macro_busy_ns.iter().sum::<f64>() / self.macro_busy_ns.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// The router.
pub struct Router {
    pub sched: Scheduler,
    pub num_macros: usize,
    /// Weight SRAM capacity per macro [bits].
    pub sram_bits_per_macro: u64,
}

impl Router {
    pub fn new(params: &MacroParams, num_macros: usize) -> Self {
        let sram_bits = (params.rows * params.cols) as u64;
        Router { sched: Scheduler::new(params), num_macros, sram_bits_per_macro: sram_bits }
    }

    /// Route one full ViT inference under a precision plan.
    pub fn route(&self, cfg: &VitConfig, batch: usize, plan: &PrecisionPlan) -> RoutePlan {
        // Decompose every layer into column tiles (the unit of placement:
        // a column tile keeps its weights loaded while the m vectors
        // stream through bit-serially).
        struct TileJob {
            layer_index: usize,
            col_tile: u64,
            latency_ns: f64,
            energy_pj: f64,
            weight_bits: u64,
        }
        let mut jobs: Vec<TileJob> = Vec::new();
        for (layer_index, shape) in linear_workload(cfg, batch).iter().enumerate() {
            let op = plan.point(shape.class);
            let tiles = self.sched.col_tiles(shape.n, op.w_bits).max(1);
            let full = self.sched.plan_linear(shape, op);
            for col_tile in 0..tiles {
                jobs.push(TileJob {
                    layer_index,
                    col_tile,
                    latency_ns: full.latency_ns / tiles as f64,
                    energy_pj: full.energy_pj / tiles as f64,
                    weight_bits: (shape.k as u64)
                        * (self.sched.params.cols as u64).min(shape.n as u64 * op.w_bits as u64),
                });
            }
        }
        // LPT greedy: longest job to the least-loaded macro.
        jobs.sort_by(|a, b| b.latency_ns.partial_cmp(&a.latency_ns).unwrap());
        let mut busy = vec![0.0f64; self.num_macros];
        let mut resident = vec![0u64; self.num_macros];
        let mut placements = Vec::with_capacity(jobs.len());
        let mut energy = 0.0;
        for job in jobs {
            let (mid, _) = busy
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            busy[mid] += job.latency_ns;
            resident[mid] += job.weight_bits;
            energy += job.energy_pj;
            placements.push(Placement {
                layer_index: job.layer_index,
                col_tile: job.col_tile,
                macro_id: mid,
                latency_ns: job.latency_ns,
                energy_pj: job.energy_pj,
            });
        }
        let makespan = busy.iter().cloned().fold(0.0f64, f64::max);
        RoutePlan {
            placements,
            macro_busy_ns: busy,
            makespan_ns: makespan,
            energy_pj: energy,
            resident_bits: resident,
        }
    }

    /// Does the routing fit in weight SRAM without per-inference reloads?
    pub fn fits_resident(&self, plan: &RoutePlan) -> bool {
        plan.resident_bits.iter().all(|&b| b <= self.sram_bits_per_macro)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::params::MacroParams;

    fn router(n: usize) -> Router {
        Router::new(&MacroParams::default(), n)
    }

    #[test]
    fn all_tiles_get_placed_once() {
        let r = router(4);
        let cfg = VitConfig::default();
        let plan = r.route(&cfg, 1, &PrecisionPlan::paper_sac());
        assert!(!plan.placements.is_empty());
        // Energy equals the single-macro scheduler total (work conserved).
        let sched_total: f64 = linear_workload(&cfg, 1)
            .iter()
            .map(|s| r.sched.plan_linear(s, PrecisionPlan::paper_sac().point(s.class)).energy_pj)
            .sum();
        assert!((plan.energy_pj - sched_total).abs() / sched_total < 1e-9);
    }

    #[test]
    fn more_macros_shrink_makespan() {
        let cfg = VitConfig::vit_small();
        let m1 = router(1).route(&cfg, 1, &PrecisionPlan::paper_sac()).makespan_ns;
        let m4 = router(4).route(&cfg, 1, &PrecisionPlan::paper_sac()).makespan_ns;
        let m8 = router(8).route(&cfg, 1, &PrecisionPlan::paper_sac()).makespan_ns;
        assert!(m4 < m1 * 0.5, "4 macros: {m4} vs {m1}");
        assert!(m8 <= m4);
    }

    #[test]
    fn load_is_balanced() {
        let r = router(6);
        let plan = r.route(&VitConfig::vit_small(), 1, &PrecisionPlan::paper_sac());
        assert!(plan.imbalance() < 1.35, "imbalance {}", plan.imbalance());
    }

    #[test]
    fn residency_accounting_scales_with_macros() {
        let cfg = VitConfig::vit_small();
        let p2 = router(2).route(&cfg, 1, &PrecisionPlan::paper_sac());
        let p8 = router(8).route(&cfg, 1, &PrecisionPlan::paper_sac());
        let max2 = p2.resident_bits.iter().max().unwrap();
        let max8 = p8.resident_bits.iter().max().unwrap();
        assert!(max8 < max2, "residency per macro should drop: {max2} -> {max8}");
    }

    #[test]
    fn single_macro_route_matches_scheduler_latency_scale() {
        let r = router(1);
        let cfg = VitConfig::default();
        let plan = r.route(&cfg, 1, &PrecisionPlan::paper_sac());
        assert!((plan.makespan_ns - plan.macro_busy_ns[0]).abs() < 1e-9);
        assert_eq!(plan.macro_busy_ns.len(), 1);
    }
}
