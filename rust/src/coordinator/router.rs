//! Multi-macro router: a deployment packages several CR-CIM macros
//! behind one coordinator (the chip photo's macro is the unit cell of a
//! bigger accelerator). The router places every (row tile × column tile)
//! unit of a [`ModelGraph`] on macros, balancing load so the bit-serial
//! pipelines of all macros finish together, and models weight residency
//! so repeated inferences don't pay reload cost.
//!
//! The unit of placement is the same unit the 2-D tiled executor
//! (`coordinator::MacroShards`) actually instantiates: one physical
//! macro holding at most `active_rows` rows × `⌊cols / w_bits⌋` whole
//! outputs — a `w_bits`-bit weight cannot straddle macros, so when
//! `cols % w_bits != 0` (the paper's 4b attention point on 78 columns)
//! a macro leaves `cols % w_bits` columns idle and the unit count
//! exceeds the scheduler's plane-packed `⌈n·w_bits / cols⌉`, which
//! remains the optimistic latency accounting. (An earlier revision
//! placed plane-packed column tiles with all `k` rows attributed to one
//! macro — which overstated `resident_bits` and understated the unit
//! count for every k > `active_rows` layer, i.e. every ViT MLP `fc2`.)
//!
//! Placement policy: longest-processing-time (LPT) greedy over per-unit
//! latency — optimal within 4/3 for makespan, fine for this unit
//! granularity. The same LPT mass, split per SAC layer class, sizes the
//! pipeline executor's per-class die pools
//! ([`Router::class_pool_split`]).

use crate::cim::netstats::LayerClass;
use crate::cim::params::MacroParams;
use crate::vit::graph::ModelGraph;

use super::scheduler::Scheduler;

/// One placed (row tile × column tile) unit.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Graph layer the unit belongs to.
    pub layer_index: usize,
    /// Row tile of the layer's reduction dimension.
    pub row_tile: u64,
    /// Column tile of the layer's weight-bit planes.
    pub col_tile: u64,
    /// Macro the unit was placed on.
    pub macro_id: usize,
    pub latency_ns: f64,
    pub energy_pj: f64,
}

/// Routing result for one full-graph inference pass.
#[derive(Clone, Debug)]
pub struct RoutePlan {
    pub placements: Vec<Placement>,
    /// Per-macro busy time [ns].
    pub macro_busy_ns: Vec<f64>,
    /// Critical-path (makespan) latency [ns].
    pub makespan_ns: f64,
    /// Total energy [pJ].
    pub energy_pj: f64,
    /// Weight SRAM bits resident per macro (capacity check). Each unit
    /// contributes its true tile footprint: (rows in its row tile) ×
    /// (planes in its column tile) — never more than one macro's array.
    pub resident_bits: Vec<u64>,
}

impl RoutePlan {
    /// Load imbalance: max/mean busy time (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let max = self.macro_busy_ns.iter().cloned().fold(0.0f64, f64::max);
        let mean = crate::util::stats::sum_ordered(self.macro_busy_ns.iter().copied())
            / self.macro_busy_ns.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Largest per-macro resident weight footprint [bits].
    pub fn max_resident_bits(&self) -> u64 {
        self.resident_bits.iter().copied().max().unwrap_or(0)
    }
}

/// The router.
pub struct Router {
    pub sched: Scheduler,
    pub num_macros: usize,
    /// Weight SRAM capacity per macro [bits], seeded from
    /// [`MacroParams::sram_bits_per_macro`] — the same budget the
    /// pipeline executor's resident-weight cache accounts against.
    pub sram_bits_per_macro: u64,
}

impl Router {
    pub fn new(params: &MacroParams, num_macros: usize) -> Self {
        Router {
            sched: Scheduler::new(params),
            num_macros: num_macros.max(1),
            sram_bits_per_macro: params.sram_bits_per_macro,
        }
    }

    /// Route one full model-graph pass: decompose every layer into its
    /// (row tile × column tile) units and place them LPT-greedily.
    pub fn route(&self, graph: &ModelGraph) -> RoutePlan {
        struct UnitJob {
            layer_index: usize,
            row_tile: u64,
            col_tile: u64,
            latency_ns: f64,
            energy_pj: f64,
            weight_bits: u64,
        }
        let mut jobs: Vec<UnitJob> = Vec::new();
        for layer in &graph.layers {
            let shape = &layer.shape;
            let w_bits = layer.op.w_bits as u64;
            let rt = self.sched.row_tiles(shape.k).max(1);
            // Whole-output packing, exactly like MacroShards: one unit
            // holds at most ⌊cols / w_bits⌋ outputs (a multi-bit weight
            // never straddles macros).
            let cap_out = (self.sched.params.cols as u64 / w_bits).max(1);
            let ct = (shape.n as u64).div_ceil(cap_out).max(1);
            let full = self.sched.plan_linear(shape, layer.op);
            let units = (rt * ct) as f64;
            // Balanced row split with front-loaded remainders — the same
            // split MacroShards::with_tiling instantiates.
            let (row_base, row_extra) = (shape.k as u64 / rt, shape.k as u64 % rt);
            for ti in 0..rt {
                let rows = row_base + u64::from(ti < row_extra);
                for ci in 0..ct {
                    let outs = (shape.n as u64 - ci * cap_out).min(cap_out);
                    jobs.push(UnitJob {
                        layer_index: layer.index,
                        row_tile: ti,
                        col_tile: ci,
                        latency_ns: full.latency_ns / units,
                        energy_pj: full.energy_pj / units,
                        weight_bits: rows * outs * w_bits,
                    });
                }
            }
        }
        // LPT greedy: longest unit to the least-loaded macro.
        jobs.sort_by(|a, b| b.latency_ns.total_cmp(&a.latency_ns));
        let mut busy = vec![0.0f64; self.num_macros];
        let mut resident = vec![0u64; self.num_macros];
        let mut placements = Vec::with_capacity(jobs.len());
        let mut energy = 0.0;
        for job in jobs {
            let (mid, _) = busy
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .expect("router has at least one macro");
            busy[mid] += job.latency_ns;
            resident[mid] += job.weight_bits;
            energy += job.energy_pj;
            placements.push(Placement {
                layer_index: job.layer_index,
                row_tile: job.row_tile,
                col_tile: job.col_tile,
                macro_id: mid,
                latency_ns: job.latency_ns,
                energy_pj: job.energy_pj,
            });
        }
        let makespan = busy.iter().cloned().fold(0.0f64, f64::max);
        RoutePlan {
            placements,
            macro_busy_ns: busy,
            makespan_ns: makespan,
            energy_pj: energy,
            resident_bits: resident,
        }
    }

    /// Does the routing fit in weight SRAM without per-inference reloads?
    pub fn fits_resident(&self, plan: &RoutePlan) -> bool {
        plan.resident_bits.iter().all(|&b| b <= self.sram_bits_per_macro)
    }

    /// Split a die budget between the attention-class and MLP-class
    /// pools, proportionally to each class's LPT mass (total per-layer
    /// latency) over the graph. Each pool gets at least one die, so the
    /// budget is clamped to a minimum of 2 — a caller asking for fewer
    /// dies than classes receives `(1, 1)`, i.e. more silicon than it
    /// budgeted, never an empty pool. This is how the pipeline executor
    /// sizes its per-class pools
    /// (`coordinator::pipeline::PipelineConfig::sized_by_router`).
    pub fn class_pool_split(&self, graph: &ModelGraph, dies: usize) -> (usize, usize) {
        let mass = |class: LayerClass| -> f64 {
            graph
                .class_layers(class)
                .map(|l| self.sched.plan_linear(&l.shape, l.op).latency_ns)
                .sum()
        };
        let att = mass(LayerClass::TransformerAttention);
        let mlp = mass(LayerClass::TransformerMlp);
        let d = dies.max(2);
        let total = att + mlp;
        if total <= 0.0 {
            return (d / 2, d - d / 2);
        }
        let a = ((att / total * d as f64).round() as usize).clamp(1, d - 1);
        (a, d - a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::params::MacroParams;
    use crate::vit::plan::PrecisionPlan;
    use crate::vit::VitConfig;

    fn router(n: usize) -> Router {
        Router::new(&MacroParams::default(), n)
    }

    fn graph(cfg: &VitConfig, batch: usize) -> ModelGraph {
        ModelGraph::encoder(cfg, batch, &PrecisionPlan::paper_sac())
    }

    #[test]
    fn all_units_get_placed_once_and_energy_is_conserved() {
        let r = router(4);
        let g = graph(&VitConfig::default(), 1);
        let plan = r.route(&g);
        assert!(!plan.placements.is_empty());
        // Energy equals the single-macro scheduler total (work conserved).
        let sched_total: f64 =
            g.layers.iter().map(|l| r.sched.plan_linear(&l.shape, l.op).energy_pj).sum();
        assert!((plan.energy_pj - sched_total).abs() / sched_total < 1e-9);
        // Unit count: Σ row_tiles × output-packed column tiles per layer
        // (whole outputs per macro, ⌊cols / w_bits⌋ each).
        let units: u64 = g
            .layers
            .iter()
            .map(|l| {
                let cap = (r.sched.params.cols as u64 / l.op.w_bits as u64).max(1);
                r.sched.row_tiles(l.shape.k) * (l.shape.n as u64).div_ceil(cap)
            })
            .sum();
        assert_eq!(plan.placements.len() as u64, units);
    }

    #[test]
    fn units_match_macro_shards_output_packing_at_4b() {
        // cols = 78, w_bits = 4: a macro holds ⌊78/4⌋ = 19 whole outputs
        // (76 of 78 planes) — NOT ⌈n·4/78⌉ plane-packed tiles. ViT-Base
        // qkv (n = 2304) therefore routes as ⌈2304/19⌉ = 122 units, the
        // number of macros MacroShards would actually instantiate.
        let r = router(4);
        let g = graph(&VitConfig::vit_base(), 1);
        let plan = r.route(&g);
        let qkv_units =
            plan.placements.iter().filter(|p| p.layer_index == 0).count();
        assert_eq!(qkv_units, 122);
        // Plane packing would have claimed 119 — an undercount no
        // physical macro layout can realize.
        assert_eq!(r.sched.col_tiles(2304, 4), 119);
    }

    #[test]
    fn more_macros_shrink_makespan() {
        let g = graph(&VitConfig::vit_small(), 1);
        let m1 = router(1).route(&g).makespan_ns;
        let m4 = router(4).route(&g).makespan_ns;
        let m8 = router(8).route(&g).makespan_ns;
        assert!(m4 < m1 * 0.5, "4 macros: {m4} vs {m1}");
        assert!(m8 <= m4);
    }

    #[test]
    fn load_is_balanced() {
        let r = router(6);
        let plan = r.route(&graph(&VitConfig::vit_small(), 1));
        assert!(plan.imbalance() < 1.35, "imbalance {}", plan.imbalance());
    }

    #[test]
    fn residency_accounting_scales_with_macros() {
        let g = graph(&VitConfig::vit_small(), 1);
        let p2 = router(2).route(&g);
        let p8 = router(8).route(&g);
        assert!(
            p8.max_resident_bits() < p2.max_resident_bits(),
            "residency per macro should drop: {} -> {}",
            p2.max_resident_bits(),
            p8.max_resident_bits()
        );
    }

    #[test]
    fn deep_k_units_never_exceed_one_macro_array() {
        // The rework's point: a k = 3072 fc2 used to attribute all 3072
        // rows to one macro (3× its physical array). Per-unit footprints
        // must now fit a single macro, so a big enough deployment holds
        // ViT-Base fully resident.
        let g = graph(&VitConfig::vit_base(), 1);
        let r = router(8);
        let plan = r.route(&g);
        let per_macro = r.sram_bits_per_macro;
        let fc2_units: Vec<_> = plan
            .placements
            .iter()
            .filter(|p| g.layers[p.layer_index].shape.k == 3072)
            .collect();
        assert!(!fc2_units.is_empty());
        // Row-tiled placements exist (row_tile > 0 for k = 3072 layers).
        assert!(fc2_units.iter().any(|p| p.row_tile > 0));
        // Total resident bits equal the graph's weight planes exactly:
        // Σ k·n·w_bits per layer.
        let want: u64 =
            g.layers.iter().map(|l| (l.shape.k * l.shape.n) as u64 * l.op.w_bits as u64).sum();
        assert_eq!(plan.resident_bits.iter().sum::<u64>(), want);
        // One macro per unit ⇒ every macro's residency fits its array.
        let units = plan.placements.len();
        let wide = Router::new(&MacroParams::default(), units);
        let plan_wide = wide.route(&g);
        assert!(
            plan_wide.max_resident_bits() <= per_macro,
            "unit footprint {} exceeds one macro array {per_macro}",
            plan_wide.max_resident_bits()
        );
        assert!(wide.fits_resident(&plan_wide));
    }

    #[test]
    fn sram_budget_comes_from_params() {
        let p = MacroParams::default();
        assert_eq!(router(2).sram_bits_per_macro, p.sram_bits_per_macro);
        let banked = Router::new(&p.clone().with_sram_bits(1 << 22), 2);
        assert_eq!(banked.sram_bits_per_macro, 1 << 22);
        // A bigger per-macro budget flips fits_resident for the same
        // routing (capacity is accounting, placement is unchanged).
        let g = graph(&VitConfig::vit_small(), 1);
        let tight = Router::new(&p.clone().with_sram_bits(1), 2);
        let plan = tight.route(&g);
        assert!(!tight.fits_resident(&plan));
        let roomy = Router::new(&p.with_sram_bits(u64::MAX), 2);
        assert!(roomy.fits_resident(&roomy.route(&g)));
    }

    #[test]
    fn single_macro_route_matches_scheduler_latency_scale() {
        let r = router(1);
        let plan = r.route(&graph(&VitConfig::default(), 1));
        assert!((plan.makespan_ns - plan.macro_busy_ns[0]).abs() < 1e-9);
        assert_eq!(plan.macro_busy_ns.len(), 1);
    }

    #[test]
    fn class_pool_split_tracks_lpt_mass() {
        let r = router(4);
        let g = graph(&VitConfig::vit_base(), 8);
        let (att, mlp) = r.class_pool_split(&g, 8);
        assert_eq!(att + mlp, 8);
        assert!(att >= 1 && mlp >= 1);
        // SAC runs MLP at 6b w/CB vs attention 4b wo/CB, and the MLP
        // layers carry more planes — the MLP pool must be the bigger one.
        assert!(mlp > att, "att {att} mlp {mlp}");
        // Degenerate budgets (fewer dies than classes) clamp to one die
        // per class instead of emptying a pool.
        for budget in [0usize, 1] {
            let (a1, m1) = r.class_pool_split(&g, budget);
            assert_eq!((a1, m1), (1, 1), "budget {budget}");
        }
    }
}
