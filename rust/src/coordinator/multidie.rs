//! Multi-die serving tier: one logical layer replicated across several
//! independent dies, with batches routed across them.
//!
//! The chip-level scaling story: a single CR-CIM die converts one
//! (row tile × column tile) per cycle, so a server that must sustain
//! heavy traffic provisions several dies and splits every served batch
//! across them. Each die is a full copy of the layer — its own
//! [`MacroShards`] bank under its own die seed
//! ([`MacroParams::for_die`]), so dies have independent mismatch and
//! noise exactly like distinct physical chips.
//!
//! Routing is deterministic: vector `v` of a batch of `b` goes to die
//! `v·d / b` (contiguous chunks, front-loaded remainders), so a given
//! (params, weights, die count, batch) is reproducible at any worker
//! thread count. Changing the die count re-routes vectors onto different
//! silicon, which legitimately changes noisy outputs — at zero noise
//! every die computes the same exact integer result.
//!
//! In the serving stack this tier sits under everything that executes:
//! the single-layer `SimExecutor` drives one bank directly, while the
//! model-graph pipeline ([`super::pipeline`]) draws one bank per layer
//! from a per-class die pool and keeps programmed banks resident across
//! passes; fixed batches and streaming conversion waves
//! ([`super::stream`]) both land here as `matvec_batch` calls.

use crate::cim::MacroParams;
use crate::util::pool::parallel_map_mut;
use crate::vit::plan::OperatingPoint;

use super::shard::MacroShards;

/// A bank of independent dies, each holding a full copy of one logical
/// (k × n) layer as a 2-D tiled [`MacroShards`] grid.
pub struct DieBank {
    dies: Vec<MacroShards>,
    /// Operating point (bit widths + CB mode) the layer runs at.
    pub op: OperatingPoint,
    /// Reduction dimension (rows of the weight matrix).
    pub k: usize,
    /// Logical outputs.
    pub n: usize,
    /// Worker threads for the cross-die fan-out.
    threads: usize,
}

impl DieBank {
    /// Build `dies` independent copies of the layer. Die `i` runs under
    /// `params.for_die(i)` (die 0 keeps the master seed, so a one-die
    /// bank is byte-for-byte a plain [`MacroShards`]). `shards` is the
    /// per-die column-shard request; row tiles are added automatically
    /// for k > `active_rows`.
    pub fn new(
        params: &MacroParams,
        w: &[Vec<i32>],
        op: OperatingPoint,
        shards: usize,
        dies: usize,
    ) -> Result<Self, String> {
        Self::in_pool(params, w, op, shards, dies, 0)
    }

    /// Like [`new`](Self::new), but drawing the dies from die pool
    /// `pool` (see [`MacroParams::for_pool`]). Pool 0 is the default
    /// shared pool (`new` delegates here unchanged); nonzero pools are
    /// disjoint silicon, which is how the pipeline executor keeps
    /// attention-class and MLP-class layers on separate per-class pools
    /// whose sizes can change independently without re-seeding each
    /// other.
    pub fn in_pool(
        params: &MacroParams,
        w: &[Vec<i32>],
        op: OperatingPoint,
        shards: usize,
        dies: usize,
        pool: usize,
    ) -> Result<Self, String> {
        let pooled = params.clone().for_pool(pool);
        let d = dies.max(1);
        // Each die keeps a slice of the worker budget; its shard bank
        // subdivides further. Total parallelism stays at the caller's
        // thread count.
        let inner = pooled.effective_threads().div_ceil(d).max(1);
        let banks = (0..d)
            .map(|i| {
                let p = pooled.clone().for_die(i).with_threads(inner);
                MacroShards::new(&p, w, op, shards)
            })
            .collect::<Result<Vec<_>, _>>()?;
        let (k, n) = (banks[0].k, banks[0].n);
        Ok(DieBank { dies: banks, op, k, n, threads: pooled.effective_threads() })
    }

    /// Independent dies in the bank.
    pub fn die_count(&self) -> usize {
        self.dies.len()
    }

    /// Column shards per die.
    pub fn shard_count(&self) -> usize {
        self.dies[0].shard_count()
    }

    /// Row tiles per die.
    pub fn row_tile_count(&self) -> usize {
        self.dies[0].row_tile_count()
    }

    /// Weight bits this bank keeps programmed **per die**
    /// (`k · n · w_bits` — each die holds a full copy of the layer, so
    /// per-die accounting is what a residency budget compares against;
    /// matches `Scheduler::layer_weight_bits` and the router's
    /// `resident_bits` unit sum exactly).
    pub fn weight_footprint_bits(&self) -> u64 {
        (self.k as u64) * (self.n as u64) * self.op.w_bits as u64
    }

    /// Cumulative conversions across all dies and calls.
    pub fn total_conversions(&self) -> u64 {
        self.dies.iter().map(|d| d.total_conversions).sum()
    }

    /// Cumulative conversion energy [pJ] across all dies and calls.
    pub fn total_energy_pj(&self) -> f64 {
        self.dies.iter().map(|d| d.total_energy_pj).sum()
    }

    /// Run a batch across the die bank: contiguous vector chunks per die,
    /// dies converting concurrently, outputs stitched back in batch
    /// order. Batches smaller than the die count leave trailing dies
    /// idle (their chunk is empty).
    pub fn matvec_batch(&mut self, xs: &[Vec<i32>]) -> Result<Vec<Vec<i64>>, String> {
        let d = self.dies.len();
        let b = xs.len();
        let (base, extra) = (b / d, b % d);
        // chunk_lo[i] = start of die i's contiguous slice of the batch.
        let mut chunks = Vec::with_capacity(d + 1);
        let mut lo = 0usize;
        chunks.push(0);
        for i in 0..d {
            lo += base + usize::from(i < extra);
            chunks.push(lo);
        }
        let chunks = &chunks;
        let per_die = parallel_map_mut(&mut self.dies, self.threads, |i, die| {
            die.matvec_batch(&xs[chunks[i]..chunks[i + 1]])
        });
        let mut outputs = Vec::with_capacity(b);
        for result in per_die {
            outputs.extend(result?);
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{CbMode, CimMacro};
    use crate::util::rng::Rng;

    fn quiet_params() -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        p
    }

    fn op_2b() -> OperatingPoint {
        OperatingPoint::new(2, 2, CbMode::Off)
    }

    fn tile(k: usize, n: usize, nvec: usize, seed: u64) -> (Vec<Vec<i32>>, Vec<Vec<i32>>) {
        let mut rng = Rng::new(seed);
        let w = (0..k).map(|_| (0..n).map(|_| rng.below(4) as i32 - 2).collect()).collect();
        let xs =
            (0..nvec).map(|_| (0..k).map(|_| rng.below(4) as i32 - 2).collect()).collect();
        (w, xs)
    }

    #[test]
    fn die_bank_matches_exact_at_zero_noise_for_any_die_count() {
        let p = quiet_params();
        // k = 150: 3 row tiles per die; 5 outputs at 2b fit one shard.
        let (w, xs) = tile(150, 5, 7, 42);
        let reference = CimMacro::ideal(&p).unwrap();
        let want: Vec<Vec<i64>> = xs.iter().map(|x| reference.matvec_exact(&w, x)).collect();
        for dies in [1usize, 2, 3, 5] {
            let mut bank = DieBank::new(&p, &w, op_2b(), 1, dies).unwrap();
            assert_eq!(bank.die_count(), dies);
            assert_eq!(bank.matvec_batch(&xs).unwrap(), want, "dies={dies}");
        }
    }

    #[test]
    fn one_die_bank_replays_plain_macro_shards() {
        let mut p = quiet_params();
        p.sigma_cmp_lsb = 1.1; // real noise: the claim is nontrivial
        let (w, xs) = tile(64, 4, 3, 7);
        let mut plain = MacroShards::new(&p.clone().with_threads(1), &w, op_2b(), 1).unwrap();
        let mut bank = DieBank::new(&p, &w, op_2b(), 1, 1).unwrap();
        assert_eq!(bank.matvec_batch(&xs).unwrap(), plain.matvec_batch(&xs).unwrap());
    }

    #[test]
    fn dies_have_independent_noise() {
        let mut p = quiet_params();
        p.sigma_cmp_lsb = 1.4;
        let (w, _) = tile(64, 4, 0, 19);
        let x: Vec<i32> = (0..64).map(|i| (i % 4) as i32 - 2).collect();
        // The same vector replicated: each copy routes to a different die.
        let xs = vec![x; 2];
        let mut bank = DieBank::new(&p, &w, op_2b(), 1, 2).unwrap();
        let ys = bank.matvec_batch(&xs).unwrap();
        assert_ne!(ys[0], ys[1], "distinct dies must draw distinct noise");
    }

    #[test]
    fn pool_zero_replays_the_default_bank_and_pools_are_disjoint() {
        let mut p = quiet_params();
        p.sigma_cmp_lsb = 1.2; // real noise: pool identity is nontrivial
        let (w, xs) = tile(64, 4, 3, 31);
        let mut plain = DieBank::new(&p, &w, op_2b(), 1, 2).unwrap();
        let mut pool0 = DieBank::in_pool(&p, &w, op_2b(), 1, 2, 0).unwrap();
        let want = plain.matvec_batch(&xs).unwrap();
        assert_eq!(pool0.matvec_batch(&xs).unwrap(), want);
        // A nonzero pool is different silicon: same weights, same
        // batch, different noise draws.
        let mut pool1 = DieBank::in_pool(&p, &w, op_2b(), 1, 2, 1).unwrap();
        assert_ne!(pool1.matvec_batch(&xs).unwrap(), want);
        // Distinct pools are mutually disjoint too.
        let mut pool2 = DieBank::in_pool(&p, &w, op_2b(), 1, 2, 2).unwrap();
        let mut pool1b = DieBank::in_pool(&p, &w, op_2b(), 1, 2, 1).unwrap();
        assert_ne!(pool2.matvec_batch(&xs).unwrap(), pool1b.matvec_batch(&xs).unwrap());
        // At zero noise every pool computes the same exact result.
        let q = quiet_params();
        let mut a = DieBank::in_pool(&q, &w, op_2b(), 1, 2, 1).unwrap();
        let mut b = DieBank::in_pool(&q, &w, op_2b(), 1, 2, 2).unwrap();
        assert_eq!(a.matvec_batch(&xs).unwrap(), b.matvec_batch(&xs).unwrap());
    }

    #[test]
    fn batch_smaller_than_die_count_is_served() {
        let p = quiet_params();
        let (w, xs) = tile(64, 3, 2, 23);
        let mut bank = DieBank::new(&p, &w, op_2b(), 1, 4).unwrap();
        let reference = CimMacro::ideal(&p).unwrap();
        let got = bank.matvec_batch(&xs).unwrap();
        assert_eq!(got.len(), 2);
        for (v, x) in xs.iter().enumerate() {
            assert_eq!(got[v], reference.matvec_exact(&w, x), "vector {v}");
        }
        // Empty batches are a no-op.
        assert_eq!(bank.matvec_batch(&[]).unwrap(), Vec::<Vec<i64>>::new());
    }

    #[test]
    fn weight_footprint_is_per_die_layer_bits() {
        let p = quiet_params();
        let (w, _) = tile(64, 5, 0, 11);
        // Footprint is k·n·w_bits regardless of how many dies replicate
        // the layer (per-die accounting).
        for dies in [1usize, 3] {
            let bank = DieBank::new(&p, &w, op_2b(), 1, dies).unwrap();
            assert_eq!(bank.weight_footprint_bits(), 64 * 5 * 2, "dies={dies}");
        }
    }

    #[test]
    fn accounting_sums_across_dies() {
        let p = quiet_params();
        let (w, xs) = tile(64, 3, 4, 29);
        let mut bank = DieBank::new(&p, &w, op_2b(), 1, 2).unwrap();
        assert_eq!(bank.total_conversions(), 0);
        bank.matvec_batch(&xs).unwrap();
        // 4 vectors × 6 used cols × 2 a_bits, wherever they ran.
        assert_eq!(bank.total_conversions(), 4 * 6 * 2);
        assert!(bank.total_energy_pj() > 0.0);
    }
}
