//! Autoregressive decode primitives shared by the executor, the
//! scheduler and the reference walk.
//!
//! Generation turns the linear-chain graph into a stateful workload:
//! every sequence folds per-block **KV state** into its attention
//! outputs, position after position. Three things must agree bit-for-bit
//! for the determinism contract to survive — the token embedding, the KV
//! fold, and next-token selection — so all three live here as pure
//! functions called by both `ModelExecutor` (inside the staged wavefront
//! engine) and `ModelExecutor::reference_decode` (the schedule-free
//! walk).
//!
//! The residency half mirrors PR 4's weight cache: [`SeqStateCache`] is
//! the capacity-bounded LRU *policy* for which sequences' KV state stays
//! pinned on dies. The executor runs it live during its serial decision
//! pass (so measured hits are schedule-independent), and
//! `Scheduler::plan_decode` replays the identical struct over the
//! canonical lockstep trace — planned KV hits equal measured hits by
//! construction, not by parallel implementations kept in sync by prose.
//! Like the weight cache, eviction is a *pricing* event (a restore the
//! planner charges), never a correctness event: the state values
//! themselves live in the executor's host-side map and survive eviction.

use std::collections::BTreeMap;

/// Mix constant for the token embedding and the KV fold (the
/// golden-ratio multiplier; splitmix64's increment).
const MIX: i64 = 0x9E37_79B9_7F4A_7C15_u64 as i64;

/// One generation token inside a conversion wave: which sequence, which
/// position, which token id, and which phase (prefill positions carry
/// prompt tokens; decode positions carry tokens the model produced).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenStep {
    /// Sequence id (the stream tier's request sequence number).
    pub seq: u64,
    /// 0-based position across prompt + generated tokens.
    pub pos: usize,
    /// Token id fed at this position.
    pub tok: u32,
    /// `true` for decode-phase steps (one token per wave per sequence),
    /// `false` for prefill positions (prompt tokens, many per wave).
    pub decode: bool,
}

/// Deterministic token embedding into the activation domain: the decode
/// counterpart of `pipeline::featurize`. Each token id hashes to `k`
/// two's-complement activations at `a_bits`, so the executor and the
/// reference walk feed bit-identical inputs from the same token.
pub fn embed_token(tok: u32, k: usize, a_bits: u32) -> Vec<i32> {
    let span = 1i64 << a_bits;
    let half = span / 2;
    (0..k)
        .map(|i| {
            let h = (tok as i64 + 1)
                .wrapping_mul(MIX)
                .wrapping_add((i as i64).wrapping_mul(0x00C2_B2AE_3D27_D29Fu64 as i64));
            (h.rem_euclid(span) - half) as i32
        })
        .collect()
}

/// Fold one position's raw attention output into the sequence's per-block
/// KV state, **in place on both sides**: `state` accumulates the wrapped
/// digest of every position seen so far, and `y` is replaced by that
/// digest — so the values flowing into the downstream periphery glue
/// genuinely depend on the whole sequence history, exactly like
/// attention over a KV cache. Pure wrapping-integer arithmetic: applied
/// at the same (sequence, block, position) points, the executor and the
/// reference walk produce bit-identical digests.
pub fn fold_kv(state: &mut Vec<i64>, y: &mut [i64]) {
    if state.len() != y.len() {
        state.clear();
        state.resize(y.len(), 0);
    }
    for (s, v) in state.iter_mut().zip(y.iter_mut()) {
        *s = s.wrapping_mul(MIX).wrapping_add(*v);
        *v = *s;
    }
}

/// KV-state footprint of one sequence resident on a die [bits]: the K
/// and V vectors of every position seen so far (capped at the context
/// window), at the attention activation precision. Shared by the
/// executor's live cache accounting and `Scheduler::plan_decode`'s
/// replay, so planned and measured footprints agree by construction.
pub fn kv_footprint_bits(dim: usize, a_bits: u32, pos: usize, context: usize) -> u64 {
    let positions = (pos + 1).min(context.max(1)) as u64;
    positions * 2 * dim as u64 * a_bits as u64
}

/// Next-token selection: argmax over the scaled logits, with the same
/// NaN-safe total-order tie-break the serving tier's `pred` field uses
/// (`util::stats::argmax_rows`). One shared chokepoint so the pipeline
/// path and the reference walk cannot disagree on ties.
pub fn argmax(logits: &[f32]) -> u32 {
    if logits.is_empty() {
        return 0;
    }
    crate::util::stats::argmax_rows(logits, logits.len())[0] as u32
}

/// Cumulative generation counters the executor reports to the ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GenStats {
    /// KV residency hits across all (sequence, block) accesses.
    pub kv_hits: u64,
    /// KV residency misses (state restored/re-pinned).
    pub kv_misses: u64,
    /// Sequences' state evicted by the capacity bound.
    pub kv_evictions: u64,
    /// Prefill positions executed (prompt tokens).
    pub prefill_tokens: u64,
    /// Decode steps executed (generated tokens).
    pub decode_tokens: u64,
}

impl GenStats {
    /// Hit fraction of all KV accesses (0 when nothing ran).
    pub fn kv_hit_rate(&self) -> f64 {
        let total = self.kv_hits + self.kv_misses;
        if total == 0 {
            0.0
        } else {
            self.kv_hits as f64 / total as f64
        }
    }
}

/// One resident entry of the [`SeqStateCache`].
struct SeqEntry {
    footprint_bits: u64,
    last_used: u64,
}

/// Capacity-bounded LRU residency policy for per-sequence KV state,
/// keyed `(sequence id, block)` — the decode sibling of
/// `scheduler::ResidentLru`. Metadata only: it decides and counts which
/// state is die-resident; the state *values* live in the executor's
/// host-side map regardless, so eviction is a pricing event, never a
/// correctness event.
///
/// Policy per access (identical to the weight cache): [`touch`]
/// (Self::touch) a cached key → hit, LRU position refreshed, footprint
/// updated in place (KV state grows with position). On a miss,
/// [`insert`](Self::insert) retains the entry only if its footprint fits
/// the capacity at all (an oversized sequence is dropped and evicts
/// nothing), evicting least-recently-used entries until it fits.
pub struct SeqStateCache {
    // BTreeMap, not a hash map: victim selection iterates `entries`, so
    // the tie-break order must be deterministic (detlint: unordered-iter).
    entries: BTreeMap<(u64, usize), SeqEntry>,
    resident_bits: u64,
    capacity_bits: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl SeqStateCache {
    /// A cache with the given total KV capacity [bits]; 0 disables
    /// residency (every access is a miss, nothing is retained).
    pub fn new(capacity_bits: u64) -> Self {
        SeqStateCache {
            entries: BTreeMap::new(),
            resident_bits: 0,
            capacity_bits,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Advance the LRU clock and report whether `key`'s state is
    /// resident, refreshing its LRU position and growing its footprint
    /// to `footprint_bits` if so (KV state grows with every position).
    /// A grown footprint that overflows capacity evicts other entries —
    /// never the touched one.
    pub fn touch(&mut self, key: (u64, usize), footprint_bits: u64) -> bool {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.last_used = tick;
                let grown = footprint_bits.saturating_sub(e.footprint_bits);
                e.footprint_bits = e.footprint_bits.max(footprint_bits);
                self.resident_bits += grown;
                self.evict_over_budget(Some(key));
                self.hits += 1;
                true
            }
            None => {
                self.misses += 1;
                false
            }
        }
    }

    /// Retain a missed key if the capacity allows, evicting
    /// least-recently-used entries to make room. A footprint bigger than
    /// the whole capacity is never retained (and evicts nothing).
    pub fn insert(&mut self, key: (u64, usize), footprint_bits: u64) {
        if footprint_bits > self.capacity_bits {
            return;
        }
        self.resident_bits += footprint_bits;
        self.entries.insert(key, SeqEntry { footprint_bits, last_used: self.tick });
        self.evict_over_budget(Some(key));
    }

    /// Evict LRU entries until the budget fits, never touching `keep`.
    fn evict_over_budget(&mut self, keep: Option<(u64, usize)>) {
        while self.resident_bits > self.capacity_bits {
            let victim = self
                .entries
                .iter()
                .filter(|(k, _)| Some(**k) != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(victim) = victim else {
                // Only the protected entry remains: drop the overflow on
                // it (its own growth can never evict itself).
                break;
            };
            let gone = self.entries.remove(&victim).expect("victim is resident");
            self.resident_bits -= gone.footprint_bits;
            self.evictions += 1;
        }
    }

    /// Drop every block of a finished sequence (frees its residency).
    pub fn remove_seq(&mut self, seq: u64) {
        let keys: Vec<(u64, usize)> =
            self.entries.range((seq, 0)..=(seq, usize::MAX)).map(|(k, _)| *k).collect();
        for k in keys {
            if let Some(e) = self.entries.remove(&k) {
                self.resident_bits -= e.footprint_bits;
            }
        }
    }

    pub fn resident_bits(&self) -> u64 {
        self.resident_bits
    }
    pub fn capacity_bits(&self) -> u64 {
        self.capacity_bits
    }
    pub fn hits(&self) -> u64 {
        self.hits
    }
    pub fn misses(&self) -> u64 {
        self.misses
    }
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Record one (sequence, block) access: touch, insert on miss.
    /// The single chokepoint both the executor's decision pass and the
    /// planner's replay call, so their counter streams are the same
    /// function of the same trace.
    pub fn access(&mut self, key: (u64, usize), footprint_bits: u64) -> bool {
        let hit = self.touch(key, footprint_bits);
        if !hit {
            self.insert(key, footprint_bits);
        }
        hit
    }
}

/// Geometry of the canonical KV residency replay: what the planner needs
/// to reproduce the executor's decision-pass access stream.
#[derive(Clone, Copy, Debug)]
pub struct ReplayShape {
    /// Live sequences (ids 1..=live, matching the stream tier's 1-based
    /// sequence numbering).
    pub live: usize,
    /// Attention blocks folding KV state (one `Qkv` layer each).
    pub blocks: usize,
    /// Model dimension (the attention reduction width `k`).
    pub dim: usize,
    /// Attention activation precision [bits].
    pub a_bits: u32,
    /// Context window (footprints saturate here).
    pub context: usize,
}

/// Replay the canonical **prefill trace**: each sequence's whole prompt
/// arrives as its own wave, so the executor's serial decision pass
/// touches, per wave (= per sequence), every block in layer order and
/// every prompt position in item order. `Scheduler::plan_decode` runs
/// this against a fresh cache before the decode replay; the acceptance
/// test drives the live executor with the identical arrival pattern, so
/// planned and measured counters see the same access stream.
pub fn replay_prefill(cache: &mut SeqStateCache, shape: &ReplayShape, prompt_tokens: usize) {
    for seq in 1..=shape.live as u64 {
        for block in 0..shape.blocks {
            for pos in 0..prompt_tokens {
                let fp = kv_footprint_bits(shape.dim, shape.a_bits, pos, shape.context);
                cache.access((seq, block), fp);
            }
        }
    }
}

/// Replay the canonical **lockstep decode trace**: `live` sequences
/// advance one position per step for `steps` steps, starting at position
/// `start_pos` (i.e. after a `start_pos`-token prefill), touching every
/// block's KV entry in (step → block → sequence) order — exactly the
/// access order of the executor's serial decision pass over lockstep
/// decode waves.
pub fn replay_lockstep(
    cache: &mut SeqStateCache,
    shape: &ReplayShape,
    start_pos: usize,
    steps: usize,
) {
    for step in 0..steps {
        let pos = start_pos + step;
        let fp = kv_footprint_bits(shape.dim, shape.a_bits, pos, shape.context);
        for block in 0..shape.blocks {
            for seq in 1..=shape.live as u64 {
                cache.access((seq, block), fp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embed_token_is_deterministic_and_in_range() {
        let a = embed_token(7, 48, 4);
        let b = embed_token(7, 48, 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), 48);
        assert!(a.iter().all(|&v| (-8..8).contains(&v)), "{a:?}");
        // Different tokens embed differently.
        assert_ne!(embed_token(7, 48, 4), embed_token(8, 48, 4));
        // Position 0 vs 1 of the same token differ elementwise somewhere.
        let c = embed_token(0, 4, 6);
        assert!(c.iter().all(|&v| (-32..32).contains(&v)));
    }

    #[test]
    fn fold_kv_accumulates_history() {
        let mut state = Vec::new();
        let mut y0 = vec![3i64, -5];
        fold_kv(&mut state, &mut y0);
        assert_eq!(state, vec![3, -5]);
        assert_eq!(y0, vec![3, -5]);
        let mut y1 = vec![1i64, 1];
        fold_kv(&mut state, &mut y1);
        // Digest depends on the prior state, not just this position.
        assert_eq!(y1[0], 3i64.wrapping_mul(0x9E37_79B9_7F4A_7C15_u64 as i64).wrapping_add(1));
        assert_eq!(state, y1);
        // A fresh state over the same inputs replays bit-identically.
        let mut s2 = Vec::new();
        let mut a = vec![3i64, -5];
        let mut b = vec![1i64, 1];
        fold_kv(&mut s2, &mut a);
        fold_kv(&mut s2, &mut b);
        assert_eq!(s2, state);
    }

    #[test]
    fn kv_footprint_grows_with_position_and_caps_at_context() {
        assert_eq!(kv_footprint_bits(48, 4, 0, 8), 2 * 48 * 4);
        assert_eq!(kv_footprint_bits(48, 4, 3, 8), 4 * 2 * 48 * 4);
        assert_eq!(kv_footprint_bits(48, 4, 100, 8), 8 * 2 * 48 * 4);
        // Zero context clamps to one position.
        assert_eq!(kv_footprint_bits(48, 4, 100, 0), 2 * 48 * 4);
    }

    #[test]
    fn argmax_matches_serving_tiebreak() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[]), 0);
        // Shared chokepoint with the serving tier's pred field.
        let row = [0.5f32, 0.5, 0.1];
        assert_eq!(argmax(&row) as usize, crate::util::stats::argmax_rows(&row, 3)[0]);
    }

    #[test]
    fn cache_all_fits_hits_after_first_touch() {
        let mut c = SeqStateCache::new(10_000);
        assert!(!c.access((1, 0), 100));
        assert!(c.access((1, 0), 200)); // grown footprint, still resident
        assert_eq!(c.resident_bits(), 200);
        assert_eq!((c.hits(), c.misses(), c.evictions()), (1, 1, 0));
    }

    #[test]
    fn cache_evicts_lru_when_over_budget() {
        let mut c = SeqStateCache::new(300);
        c.access((1, 0), 100);
        c.access((2, 0), 100);
        c.access((3, 0), 100);
        assert_eq!(c.resident_bits(), 300);
        // Fourth sequence evicts the least-recently-used (seq 1).
        c.access((4, 0), 100);
        assert_eq!(c.evictions(), 1);
        assert!(!c.access((1, 0), 100), "seq 1 was evicted");
        // Touching seq 3 then inserting keeps it resident over seq 2/4.
        assert!(c.access((3, 0), 100));
    }

    #[test]
    fn oversized_entry_is_dropped_without_eviction() {
        let mut c = SeqStateCache::new(100);
        c.access((1, 0), 80);
        c.access((2, 0), 500); // bigger than the whole capacity
        assert_eq!(c.evictions(), 0);
        assert!(c.access((1, 0), 80), "resident entry survives an oversized miss");
        assert!(!c.access((2, 0), 500));
    }

    #[test]
    fn grown_footprint_evicts_others_never_itself() {
        let mut c = SeqStateCache::new(100);
        c.access((1, 0), 40);
        c.access((2, 0), 40);
        // Seq 2 grows past the combined budget: seq 1 is evicted.
        assert!(c.access((2, 0), 90));
        assert_eq!(c.evictions(), 1);
        assert!(!c.access((1, 0), 40));
        // A single entry growing past the whole capacity survives (its
        // own growth cannot evict itself).
        let mut solo = SeqStateCache::new(50);
        solo.access((1, 0), 40);
        assert!(solo.access((1, 0), 80));
    }

    #[test]
    fn remove_seq_frees_every_block() {
        let mut c = SeqStateCache::new(1000);
        c.access((1, 0), 100);
        c.access((1, 1), 100);
        c.access((2, 0), 100);
        c.remove_seq(1);
        assert_eq!(c.resident_bits(), 100);
        assert!(!c.access((1, 0), 100));
        assert!(c.access((2, 0), 100));
    }

    #[test]
    fn lockstep_replay_is_deterministic_and_capacity_sensitive() {
        let shape = ReplayShape { live: 4, blocks: 2, dim: 48, a_bits: 4, context: 64 };
        // Capacity for all live sequences: steady state is all-hit after
        // the first touch of each (seq, block).
        let mut big = SeqStateCache::new(1 << 30);
        replay_lockstep(&mut big, &shape, 1, 8);
        assert_eq!(big.misses(), 4 * 2);
        assert_eq!(big.hits(), 4 * 2 * 7);
        assert_eq!(big.evictions(), 0);
        // Tiny capacity: the round-robin trace thrashes (classic LRU
        // zero-hit cycling once footprints exceed the budget).
        let mut tiny = SeqStateCache::new(2 * 48 * 4 * 3);
        replay_lockstep(&mut tiny, &shape, 1, 8);
        assert!(tiny.evictions() > 0);
        assert!(tiny.hits() < big.hits());
        // Identical parameters replay identical counters.
        let mut again = SeqStateCache::new(2 * 48 * 4 * 3);
        replay_lockstep(&mut again, &shape, 1, 8);
        assert_eq!(
            (tiny.hits(), tiny.misses(), tiny.evictions()),
            (again.hits(), again.misses(), again.evictions())
        );
    }

    #[test]
    fn prefill_replay_counts_one_miss_per_block_then_hits() {
        let shape = ReplayShape { live: 2, blocks: 3, dim: 48, a_bits: 4, context: 64 };
        let mut c = SeqStateCache::new(1 << 30);
        replay_prefill(&mut c, &shape, 5);
        // Each (seq, block) misses once (position 0) then hits 4 times.
        assert_eq!(c.misses(), 2 * 3);
        assert_eq!(c.hits(), 2 * 3 * 4);
        // Decode steps after the prefill are all hits at this capacity.
        replay_lockstep(&mut c, &shape, 5, 3);
        assert_eq!(c.misses(), 2 * 3);
        assert_eq!(c.hits(), 2 * 3 * 4 + 3 * 3 * 2);
    }
}
