//! Tile scheduler: maps linear-layer workloads onto the 1088×78 macro.
//!
//! A linear layer (m × k) · (k × n) at (a_bits, w_bits) decomposes into
//! hardware tiles:
//!   - row tiles: ⌈k / 1024⌉ compute phases per output,
//!   - column tiles: n·w_bits physical columns, ⌈n·w_bits / 78⌉ loads,
//!   - m activation vectors, each a_bits bit-serial cycles.
//!
//! Conversions dominate energy; weight reloads are SRAM writes whose
//! *latency* still matters at the model-graph level, where every layer
//! of a forward pass reprograms the macros it draws from a pool. The
//! scheduler produces a [`TilePlan`] per layer (exact conversion count,
//! energy, conversion latency — the same `EnergyModel` the
//! characterization benches use) and a [`PipelinePlan`] per model graph,
//! pricing reloads both fully serially and double-buffered (layer i+1's
//! reload hidden behind layer i's bit-serial conversions).

use crate::cim::energy::EnergyModel;
use crate::cim::params::MacroParams;
#[cfg(test)]
use crate::cim::params::CbMode;
use crate::vit::graph::ModelGraph;
use crate::vit::plan::OperatingPoint;
use crate::vit::LinearShape;

/// Cost of running one linear layer on the macro.
#[derive(Clone, Copy, Debug, Default)]
pub struct TilePlan {
    /// Column-tile loads (weight reprogramming events).
    pub weight_loads: u64,
    /// Total ADC conversions.
    pub conversions: u64,
    /// Conversion energy [pJ].
    pub energy_pj: f64,
    /// Serial latency [ns] assuming all 78 columns convert in parallel
    /// and column tiles are processed sequentially per vector.
    pub latency_ns: f64,
    /// 1b-normalized op count (for TOPS-effective reporting).
    pub ops_1b: f64,
}

impl TilePlan {
    pub fn add(&mut self, other: &TilePlan) {
        self.weight_loads += other.weight_loads;
        self.conversions += other.conversions;
        self.energy_pj += other.energy_pj;
        self.latency_ns += other.latency_ns;
        self.ops_1b += other.ops_1b;
    }
}

/// Modeled timing of one graph layer inside a [`PipelinePlan`].
#[derive(Clone, Debug)]
pub struct LayerTiming {
    /// Display name (`block3.fc2`).
    pub name: String,
    /// Weight-reload latency [ns] for the layer's (row tile × column
    /// tile) loads, shard-parallel (see [`Scheduler::weight_load_ns`]).
    pub reload_ns: f64,
    /// Bit-serial conversion latency [ns] (the layer's
    /// [`TilePlan::latency_ns`]).
    pub compute_ns: f64,
}

/// Full-graph cost: per-layer timings, the conversion/energy totals, and
/// the two weight-reload accounting models.
#[derive(Clone, Debug)]
pub struct PipelinePlan {
    /// Per-layer timing in execution order.
    pub layers: Vec<LayerTiming>,
    /// Summed per-layer [`TilePlan`]s (conversion latency only — no
    /// reload term; see `serial_ns` / `pipelined_ns` for wall time).
    pub total: TilePlan,
    /// Fully-serial accounting: each layer's reload completes before its
    /// conversions start — Σ (reload + compute).
    pub serial_ns: f64,
    /// Double-buffered accounting: layer i+1's reload overlaps layer i's
    /// bit-serial conversions, so only the first reload and any reload
    /// longer than the conversions it hides behind stay exposed.
    pub pipelined_ns: f64,
}

impl PipelinePlan {
    /// Assemble a plan from per-layer (name, compute plan, reload
    /// latency) triples. The double-buffer fold: wall time is the first
    /// reload plus, per layer, `max(compute_i, reload_{i+1})` — the next
    /// layer's reload runs on its target macros while the current
    /// layer's conversions stream, and the pipeline stalls only when the
    /// reload outlasts them.
    pub fn from_layers(entries: Vec<(String, TilePlan, f64)>) -> Self {
        let mut total = TilePlan::default();
        let mut layers = Vec::with_capacity(entries.len());
        for (name, plan, reload_ns) in entries {
            total.add(&plan);
            layers.push(LayerTiming { name, reload_ns, compute_ns: plan.latency_ns });
        }
        let serial_ns: f64 = layers.iter().map(|t| t.reload_ns + t.compute_ns).sum();
        let mut pipelined_ns = layers.first().map(|t| t.reload_ns).unwrap_or(0.0);
        for (i, t) in layers.iter().enumerate() {
            let next_reload = layers.get(i + 1).map(|n| n.reload_ns).unwrap_or(0.0);
            pipelined_ns += t.compute_ns.max(next_reload);
        }
        PipelinePlan { layers, total, serial_ns, pipelined_ns }
    }

    /// Fraction of the serial-reload latency the overlap saves.
    pub fn overlap_saving(&self) -> f64 {
        if self.serial_ns <= 0.0 {
            0.0
        } else {
            1.0 - self.pipelined_ns / self.serial_ns
        }
    }
}

/// The scheduler: stateless; all methods derive from macro parameters
/// plus the serving topology (how many macros and dies run in parallel).
#[derive(Clone, Debug)]
pub struct Scheduler {
    pub params: MacroParams,
    /// Parallel macro shards serving column tiles. Energy and conversion
    /// counts are shard-independent (the same work happens somewhere);
    /// latency divides across shards because column tiles of the same
    /// layer convert concurrently.
    pub shards: usize,
    /// Independent dies serving the same layer. A served batch's vectors
    /// split across dies, so only `⌈m / dies⌉` of the activation stream
    /// serializes on any one die. Energy is die-independent.
    pub dies: usize,
    energy: EnergyModel,
}

impl Scheduler {
    pub fn new(params: &MacroParams) -> Self {
        Self::with_topology(params, 1, 1)
    }

    /// A scheduler that maps column tiles across `shards` parallel macros.
    pub fn with_shards(params: &MacroParams, shards: usize) -> Self {
        Self::with_topology(params, shards, 1)
    }

    /// Full serving topology: `shards` parallel macros per die, `dies`
    /// independent dies sharing the batch stream.
    pub fn with_topology(params: &MacroParams, shards: usize, dies: usize) -> Self {
        Scheduler {
            params: params.clone(),
            shards: shards.max(1),
            dies: dies.max(1),
            energy: EnergyModel::cr_cim(params),
        }
    }

    /// Row tiles needed for a reduction dimension `k`.
    pub fn row_tiles(&self, k: usize) -> u64 {
        (k as u64).div_ceil(self.params.active_rows as u64)
    }

    /// Column tiles for `n` outputs at `w_bits` weight planes.
    pub fn col_tiles(&self, n: usize, w_bits: u32) -> u64 {
        (n as u64 * w_bits as u64).div_ceil(self.params.cols as u64)
    }

    /// Weight-reload latency [ns] for one layer: every
    /// (row tile × column tile) SRAM load pays `t_wload_ns`; loads of
    /// different column shards target different macros and run
    /// concurrently, so only `⌈tiles / shards⌉` serialize. Dies each
    /// hold a full copy and load concurrently (no die division).
    pub fn weight_load_ns(&self, shape: &LinearShape, op: OperatingPoint) -> f64 {
        let tiles = self.row_tiles(shape.k) * self.col_tiles(shape.n, op.w_bits);
        tiles.div_ceil(self.shards.max(1) as u64) as f64 * self.params.t_wload_ns
    }

    /// Plan a whole model graph: per-layer conversion plans plus the
    /// serial and double-buffered weight-reload accountings. This is the
    /// model the pipeline executor reports — the old per-layer path
    /// ignored reload latency entirely (equivalent to assuming every
    /// layer's weights were already resident, which is false the moment
    /// a forward pass streams 48 layers through a bounded die pool).
    pub fn plan_graph(&self, graph: &ModelGraph) -> PipelinePlan {
        PipelinePlan::from_layers(
            graph
                .layers
                .iter()
                .map(|l| {
                    let reload = self.weight_load_ns(&l.shape, l.op);
                    (l.name(), self.plan_linear(&l.shape, l.op), reload)
                })
                .collect(),
        )
    }

    /// Plan one linear layer at an operating point.
    pub fn plan_linear(&self, shape: &LinearShape, op: OperatingPoint) -> TilePlan {
        let rt = self.row_tiles(shape.k);
        let ct = self.col_tiles(shape.n, op.w_bits);
        // Conversions: every (row tile, column, activation bit, vector).
        // All 78 columns of a column tile convert in parallel but each is
        // one ADC conversion for energy purposes.
        let cols_used = (shape.n as u64 * op.w_bits as u64).min(ct * self.params.cols as u64);
        let conversions = rt * cols_used * op.a_bits as u64 * shape.m as u64;
        // Latency: serial over (row tiles × column tiles × a_bits) cycles
        // per vector; vectors stream (one conversion cycle each, weights
        // stay loaded while m streams). Column tiles spread across macro
        // shards, so only ⌈ct / shards⌉ of them serialize; the batch's
        // vectors spread across dies, so only ⌈m / dies⌉ of the stream
        // serializes on any one die.
        let ct_serial = ct.div_ceil(self.shards.max(1) as u64);
        let m_per_die = (shape.m as u64).div_ceil(self.dies.max(1) as u64);
        let cycles = rt * ct_serial * op.a_bits as u64 * m_per_die;
        let t_cycle = self.params.conversion_latency_ns(op.cb);
        // Row-tile accumulation reduce step: each extra row tile's
        // partial sum folds into the layer accumulator with one digital
        // add per streamed vector (pipelined across columns).
        let reduce_ns = self.params.t_accum_ns * (rt.saturating_sub(1) * m_per_die) as f64;
        let e_conv = self.energy.conversion_energy_pj(op.cb);
        TilePlan {
            weight_loads: rt * ct,
            conversions,
            energy_pj: e_conv * conversions as f64,
            latency_ns: t_cycle * cycles as f64 + reduce_ns,
            ops_1b: 2.0
                * shape.k as f64
                * shape.n as f64
                * shape.m as f64
                * op.a_bits as f64
                * op.w_bits as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::netstats::LayerClass;
    use crate::util::prop::assert_prop;
    use crate::vit::plan::PrecisionPlan;

    fn shape(k: usize, n: usize, m: usize) -> LinearShape {
        LinearShape { class: LayerClass::TransformerMlp, k, n, m }
    }

    #[test]
    fn tile_counts() {
        let s = Scheduler::new(&MacroParams::default());
        assert_eq!(s.row_tiles(96), 1);
        assert_eq!(s.row_tiles(1024), 1);
        assert_eq!(s.row_tiles(1025), 2);
        assert_eq!(s.col_tiles(13, 6), 1); // 78 planes exactly
        assert_eq!(s.col_tiles(14, 6), 2);
        assert_eq!(s.col_tiles(10, 4), 1);
    }

    #[test]
    fn conversions_scale_with_everything() {
        let s = Scheduler::new(&MacroParams::default());
        let op = PrecisionPlan::paper_sac().mlp;
        let base = s.plan_linear(&shape(96, 13, 10), op);
        // 1 row tile × 78 cols × 6 abits × 10 vectors.
        assert_eq!(base.conversions, 78 * 6 * 10);
        let more_m = s.plan_linear(&shape(96, 13, 20), op);
        assert_eq!(more_m.conversions, 2 * base.conversions);
        let more_k = s.plan_linear(&shape(2048, 13, 10), op);
        assert_eq!(more_k.conversions, 2 * base.conversions);
    }

    #[test]
    fn shards_divide_latency_but_not_energy() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp; // 6b: 13 outs/tile
        let sh = shape(96, 52, 10); // 52·6 = 312 planes = 4 column tiles
        let s1 = Scheduler::new(&p).plan_linear(&sh, op);
        let s4 = Scheduler::with_shards(&p, 4).plan_linear(&sh, op);
        assert_eq!(s1.conversions, s4.conversions);
        assert!((s1.energy_pj - s4.energy_pj).abs() < 1e-9);
        assert!((s1.latency_ns / s4.latency_ns - 4.0).abs() < 1e-9, "4 shards must 4x the tiles");
        // More shards than tiles saturates at one serial tile.
        let s9 = Scheduler::with_shards(&p, 9).plan_linear(&sh, op);
        assert!((s9.latency_ns - s4.latency_ns).abs() < 1e-9);
        assert_eq!(Scheduler::with_shards(&p, 0).shards, 1);
    }

    #[test]
    fn dies_divide_stream_latency_but_not_energy() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp;
        let sh = shape(96, 13, 40);
        let d1 = Scheduler::new(&p).plan_linear(&sh, op);
        let d4 = Scheduler::with_topology(&p, 1, 4).plan_linear(&sh, op);
        assert_eq!(d1.conversions, d4.conversions);
        assert!((d1.energy_pj - d4.energy_pj).abs() < 1e-9);
        assert!((d1.latency_ns / d4.latency_ns - 4.0).abs() < 1e-9, "4 dies must 4x the stream");
        // More dies than vectors saturates at one vector per die.
        let d99 = Scheduler::with_topology(&p, 1, 99).plan_linear(&shape(96, 13, 4), op);
        let d4b = Scheduler::with_topology(&p, 1, 4).plan_linear(&shape(96, 13, 4), op);
        assert!((d99.latency_ns - d4b.latency_ns).abs() < 1e-9);
        assert_eq!(Scheduler::with_topology(&p, 0, 0).dies, 1);
    }

    #[test]
    fn row_tiled_layers_pay_the_accumulation_reduce_step() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp;
        let m = 10u64;
        let one = Scheduler::new(&p).plan_linear(&shape(1024, 13, m as usize), op);
        let three = Scheduler::new(&p).plan_linear(&shape(3072, 13, m as usize), op);
        // 3 row tiles: 3x the conversion cycles plus 2 digital adds per
        // streamed vector.
        let want = 3.0 * one.latency_ns + p.t_accum_ns * (2 * m) as f64;
        assert!(
            (three.latency_ns - want).abs() < 1e-9,
            "got {} want {want}",
            three.latency_ns
        );
        // The reduce step scales down with the die count like the stream.
        let three_d2 = Scheduler::with_topology(&p, 1, 2).plan_linear(&shape(3072, 13, 10), op);
        let want_d2 = 3.0 * one.latency_ns / 2.0 + p.t_accum_ns * (2 * m / 2) as f64;
        assert!((three_d2.latency_ns - want_d2).abs() < 1e-9);
    }

    #[test]
    fn cb_on_costs_more_energy_and_time_per_conversion() {
        let s = Scheduler::new(&MacroParams::default());
        let sh = shape(96, 13, 10);
        let on = s.plan_linear(&sh, OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::On });
        let off = s.plan_linear(&sh, OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::Off });
        assert_eq!(on.conversions, off.conversions);
        let e_ratio = on.energy_pj / off.energy_pj;
        assert!((e_ratio - 1.9).abs() < 0.2, "CB energy ratio {e_ratio}");
        assert!(on.latency_ns > off.latency_ns * 1.5);
    }

    #[test]
    fn lower_bits_cost_less() {
        let s = Scheduler::new(&MacroParams::default());
        let sh = shape(96, 13, 10);
        let b6 = s.plan_linear(&sh, OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::Off });
        let b4 = s.plan_linear(&sh, OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::Off });
        // 4b: fewer bit-serial cycles AND fewer weight planes.
        assert!(b4.energy_pj < b6.energy_pj * 0.6);
        assert!(b4.latency_ns < b6.latency_ns);
    }

    #[test]
    fn weight_load_latency_counts_tiles_and_divides_by_shards() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp; // 6b
        // (3072, 768): 3 row tiles × ⌈768·6/78⌉ = 60 column tiles.
        let sh = shape(3072, 768, 1);
        let s1 = Scheduler::new(&p);
        assert!((s1.weight_load_ns(&sh, op) - 180.0 * p.t_wload_ns).abs() < 1e-9);
        let s4 = Scheduler::with_shards(&p, 4);
        assert!((s4.weight_load_ns(&sh, op) - 45.0 * p.t_wload_ns).abs() < 1e-9);
        // Dies do not divide the reload (each die loads its own copy).
        let d2 = Scheduler::with_topology(&p, 1, 2);
        assert!((d2.weight_load_ns(&sh, op) - 180.0 * p.t_wload_ns).abs() < 1e-9);
    }

    #[test]
    fn pipelined_reload_is_strictly_below_serial_for_vit_base_batch8() {
        // Acceptance anchor: double-buffered reloads must beat the
        // fully-serial accounting on the real target workload.
        use crate::vit::graph::ModelGraph;
        use crate::vit::VitConfig;
        let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
        for (shards, dies) in [(1usize, 1usize), (4, 2), (8, 4)] {
            let sched = Scheduler::with_topology(&MacroParams::default(), shards, dies);
            let pp = sched.plan_graph(&graph);
            assert_eq!(pp.layers.len(), 48);
            assert!(
                pp.pipelined_ns < pp.serial_ns,
                "overlap must strictly help: {} vs {} (shards {shards}, dies {dies})",
                pp.pipelined_ns,
                pp.serial_ns
            );
            // But it can never hide the conversions themselves.
            let conv: f64 = pp.layers.iter().map(|t| t.compute_ns).sum();
            assert!(pp.pipelined_ns >= conv);
            assert!(pp.overlap_saving() > 0.0 && pp.overlap_saving() < 1.0);
        }
    }

    #[test]
    fn pipeline_fold_matches_hand_computation() {
        let mk = |latency_ns: f64| TilePlan { latency_ns, ..TilePlan::default() };
        let pp = PipelinePlan::from_layers(vec![
            ("a".into(), mk(100.0), 10.0),
            ("b".into(), mk(50.0), 80.0),
            ("c".into(), mk(70.0), 20.0),
        ]);
        // serial: (10+100) + (80+50) + (20+70) = 330
        assert!((pp.serial_ns - 330.0).abs() < 1e-12);
        // pipelined: 10 + max(100, 80) + max(50, 20) + 70 = 230
        assert!((pp.pipelined_ns - 230.0).abs() < 1e-12);
        assert!((pp.overlap_saving() - (1.0 - 230.0 / 330.0)).abs() < 1e-12);
        // Degenerate cases.
        let empty = PipelinePlan::from_layers(Vec::new());
        assert_eq!(empty.serial_ns, 0.0);
        assert_eq!(empty.pipelined_ns, 0.0);
        assert_eq!(empty.overlap_saving(), 0.0);
        let one = PipelinePlan::from_layers(vec![("x".into(), mk(40.0), 5.0)]);
        assert!((one.serial_ns - one.pipelined_ns).abs() < 1e-12);
    }

    #[test]
    fn prop_energy_positive_and_monotone_in_m() {
        assert_prop("scheduler-monotone", 48, |g| {
            let s = Scheduler::new(&MacroParams::default());
            let k = g.usize(1, 4096);
            let n = g.usize(1, 512);
            let m = g.usize(1, 64);
            let op = OperatingPoint {
                a_bits: g.usize(1, 8) as u32,
                w_bits: g.usize(1, 8) as u32,
                cb: if g.bool() { CbMode::On } else { CbMode::Off },
            };
            let a = s.plan_linear(&shape(k, n, m), op);
            let b = s.plan_linear(&shape(k, n, m + 1), op);
            if a.energy_pj <= 0.0 || a.latency_ns <= 0.0 {
                return Err(format!("non-positive cost {a:?}"));
            }
            if b.conversions <= a.conversions {
                return Err("conversions must grow with m".into());
            }
            Ok(())
        });
    }
}
