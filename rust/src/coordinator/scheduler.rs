//! Tile scheduler: maps linear-layer workloads onto the 1088×78 macro.
//!
//! A linear layer (m × k) · (k × n) at (a_bits, w_bits) decomposes into
//! hardware tiles:
//!   - row tiles: ⌈k / 1024⌉ compute phases per output,
//!   - column tiles: n·w_bits physical columns, ⌈n·w_bits / 78⌉ loads,
//!   - m activation vectors, each a_bits bit-serial cycles.
//!
//! Weight reloads are SRAM writes (cheap, amortized over m); conversions
//! dominate energy/latency. The scheduler produces a [`TilePlan`] with the
//! exact conversion count, energy and latency the macro would spend,
//! using the same `EnergyModel` the characterization benches use.

use crate::cim::energy::EnergyModel;
use crate::cim::params::MacroParams;
#[cfg(test)]
use crate::cim::params::CbMode;
use crate::vit::plan::OperatingPoint;
use crate::vit::LinearShape;

/// Cost of running one linear layer on the macro.
#[derive(Clone, Copy, Debug, Default)]
pub struct TilePlan {
    /// Column-tile loads (weight reprogramming events).
    pub weight_loads: u64,
    /// Total ADC conversions.
    pub conversions: u64,
    /// Conversion energy [pJ].
    pub energy_pj: f64,
    /// Serial latency [ns] assuming all 78 columns convert in parallel
    /// and column tiles are processed sequentially per vector.
    pub latency_ns: f64,
    /// 1b-normalized op count (for TOPS-effective reporting).
    pub ops_1b: f64,
}

impl TilePlan {
    pub fn add(&mut self, other: &TilePlan) {
        self.weight_loads += other.weight_loads;
        self.conversions += other.conversions;
        self.energy_pj += other.energy_pj;
        self.latency_ns += other.latency_ns;
        self.ops_1b += other.ops_1b;
    }
}

/// The scheduler: stateless; all methods derive from macro parameters
/// plus the serving topology (how many macros and dies run in parallel).
#[derive(Clone, Debug)]
pub struct Scheduler {
    pub params: MacroParams,
    /// Parallel macro shards serving column tiles. Energy and conversion
    /// counts are shard-independent (the same work happens somewhere);
    /// latency divides across shards because column tiles of the same
    /// layer convert concurrently.
    pub shards: usize,
    /// Independent dies serving the same layer. A served batch's vectors
    /// split across dies, so only `⌈m / dies⌉` of the activation stream
    /// serializes on any one die. Energy is die-independent.
    pub dies: usize,
    energy: EnergyModel,
}

impl Scheduler {
    pub fn new(params: &MacroParams) -> Self {
        Self::with_topology(params, 1, 1)
    }

    /// A scheduler that maps column tiles across `shards` parallel macros.
    pub fn with_shards(params: &MacroParams, shards: usize) -> Self {
        Self::with_topology(params, shards, 1)
    }

    /// Full serving topology: `shards` parallel macros per die, `dies`
    /// independent dies sharing the batch stream.
    pub fn with_topology(params: &MacroParams, shards: usize, dies: usize) -> Self {
        Scheduler {
            params: params.clone(),
            shards: shards.max(1),
            dies: dies.max(1),
            energy: EnergyModel::cr_cim(params),
        }
    }

    /// Row tiles needed for a reduction dimension `k`.
    pub fn row_tiles(&self, k: usize) -> u64 {
        (k as u64).div_ceil(self.params.active_rows as u64)
    }

    /// Column tiles for `n` outputs at `w_bits` weight planes.
    pub fn col_tiles(&self, n: usize, w_bits: u32) -> u64 {
        (n as u64 * w_bits as u64).div_ceil(self.params.cols as u64)
    }

    /// Plan one linear layer at an operating point.
    pub fn plan_linear(&self, shape: &LinearShape, op: OperatingPoint) -> TilePlan {
        let rt = self.row_tiles(shape.k);
        let ct = self.col_tiles(shape.n, op.w_bits);
        // Conversions: every (row tile, column, activation bit, vector).
        // All 78 columns of a column tile convert in parallel but each is
        // one ADC conversion for energy purposes.
        let cols_used = (shape.n as u64 * op.w_bits as u64).min(ct * self.params.cols as u64);
        let conversions = rt * cols_used * op.a_bits as u64 * shape.m as u64;
        // Latency: serial over (row tiles × column tiles × a_bits) cycles
        // per vector; vectors stream (one conversion cycle each, weights
        // stay loaded while m streams). Column tiles spread across macro
        // shards, so only ⌈ct / shards⌉ of them serialize; the batch's
        // vectors spread across dies, so only ⌈m / dies⌉ of the stream
        // serializes on any one die.
        let ct_serial = ct.div_ceil(self.shards.max(1) as u64);
        let m_per_die = (shape.m as u64).div_ceil(self.dies.max(1) as u64);
        let cycles = rt * ct_serial * op.a_bits as u64 * m_per_die;
        let t_cycle = self.params.conversion_latency_ns(op.cb);
        // Row-tile accumulation reduce step: each extra row tile's
        // partial sum folds into the layer accumulator with one digital
        // add per streamed vector (pipelined across columns).
        let reduce_ns = self.params.t_accum_ns * (rt.saturating_sub(1) * m_per_die) as f64;
        let e_conv = self.energy.conversion_energy_pj(op.cb);
        TilePlan {
            weight_loads: rt * ct,
            conversions,
            energy_pj: e_conv * conversions as f64,
            latency_ns: t_cycle * cycles as f64 + reduce_ns,
            ops_1b: 2.0
                * shape.k as f64
                * shape.n as f64
                * shape.m as f64
                * op.a_bits as f64
                * op.w_bits as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::netstats::LayerClass;
    use crate::util::prop::assert_prop;
    use crate::vit::plan::PrecisionPlan;

    fn shape(k: usize, n: usize, m: usize) -> LinearShape {
        LinearShape { class: LayerClass::TransformerMlp, k, n, m }
    }

    #[test]
    fn tile_counts() {
        let s = Scheduler::new(&MacroParams::default());
        assert_eq!(s.row_tiles(96), 1);
        assert_eq!(s.row_tiles(1024), 1);
        assert_eq!(s.row_tiles(1025), 2);
        assert_eq!(s.col_tiles(13, 6), 1); // 78 planes exactly
        assert_eq!(s.col_tiles(14, 6), 2);
        assert_eq!(s.col_tiles(10, 4), 1);
    }

    #[test]
    fn conversions_scale_with_everything() {
        let s = Scheduler::new(&MacroParams::default());
        let op = PrecisionPlan::paper_sac().mlp;
        let base = s.plan_linear(&shape(96, 13, 10), op);
        // 1 row tile × 78 cols × 6 abits × 10 vectors.
        assert_eq!(base.conversions, 78 * 6 * 10);
        let more_m = s.plan_linear(&shape(96, 13, 20), op);
        assert_eq!(more_m.conversions, 2 * base.conversions);
        let more_k = s.plan_linear(&shape(2048, 13, 10), op);
        assert_eq!(more_k.conversions, 2 * base.conversions);
    }

    #[test]
    fn shards_divide_latency_but_not_energy() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp; // 6b: 13 outs/tile
        let sh = shape(96, 52, 10); // 52·6 = 312 planes = 4 column tiles
        let s1 = Scheduler::new(&p).plan_linear(&sh, op);
        let s4 = Scheduler::with_shards(&p, 4).plan_linear(&sh, op);
        assert_eq!(s1.conversions, s4.conversions);
        assert!((s1.energy_pj - s4.energy_pj).abs() < 1e-9);
        assert!((s1.latency_ns / s4.latency_ns - 4.0).abs() < 1e-9, "4 shards must 4x the tiles");
        // More shards than tiles saturates at one serial tile.
        let s9 = Scheduler::with_shards(&p, 9).plan_linear(&sh, op);
        assert!((s9.latency_ns - s4.latency_ns).abs() < 1e-9);
        assert_eq!(Scheduler::with_shards(&p, 0).shards, 1);
    }

    #[test]
    fn dies_divide_stream_latency_but_not_energy() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp;
        let sh = shape(96, 13, 40);
        let d1 = Scheduler::new(&p).plan_linear(&sh, op);
        let d4 = Scheduler::with_topology(&p, 1, 4).plan_linear(&sh, op);
        assert_eq!(d1.conversions, d4.conversions);
        assert!((d1.energy_pj - d4.energy_pj).abs() < 1e-9);
        assert!((d1.latency_ns / d4.latency_ns - 4.0).abs() < 1e-9, "4 dies must 4x the stream");
        // More dies than vectors saturates at one vector per die.
        let d99 = Scheduler::with_topology(&p, 1, 99).plan_linear(&shape(96, 13, 4), op);
        let d4b = Scheduler::with_topology(&p, 1, 4).plan_linear(&shape(96, 13, 4), op);
        assert!((d99.latency_ns - d4b.latency_ns).abs() < 1e-9);
        assert_eq!(Scheduler::with_topology(&p, 0, 0).dies, 1);
    }

    #[test]
    fn row_tiled_layers_pay_the_accumulation_reduce_step() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp;
        let m = 10u64;
        let one = Scheduler::new(&p).plan_linear(&shape(1024, 13, m as usize), op);
        let three = Scheduler::new(&p).plan_linear(&shape(3072, 13, m as usize), op);
        // 3 row tiles: 3x the conversion cycles plus 2 digital adds per
        // streamed vector.
        let want = 3.0 * one.latency_ns + p.t_accum_ns * (2 * m) as f64;
        assert!(
            (three.latency_ns - want).abs() < 1e-9,
            "got {} want {want}",
            three.latency_ns
        );
        // The reduce step scales down with the die count like the stream.
        let three_d2 = Scheduler::with_topology(&p, 1, 2).plan_linear(&shape(3072, 13, 10), op);
        let want_d2 = 3.0 * one.latency_ns / 2.0 + p.t_accum_ns * (2 * m / 2) as f64;
        assert!((three_d2.latency_ns - want_d2).abs() < 1e-9);
    }

    #[test]
    fn cb_on_costs_more_energy_and_time_per_conversion() {
        let s = Scheduler::new(&MacroParams::default());
        let sh = shape(96, 13, 10);
        let on = s.plan_linear(&sh, OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::On });
        let off = s.plan_linear(&sh, OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::Off });
        assert_eq!(on.conversions, off.conversions);
        let e_ratio = on.energy_pj / off.energy_pj;
        assert!((e_ratio - 1.9).abs() < 0.2, "CB energy ratio {e_ratio}");
        assert!(on.latency_ns > off.latency_ns * 1.5);
    }

    #[test]
    fn lower_bits_cost_less() {
        let s = Scheduler::new(&MacroParams::default());
        let sh = shape(96, 13, 10);
        let b6 = s.plan_linear(&sh, OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::Off });
        let b4 = s.plan_linear(&sh, OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::Off });
        // 4b: fewer bit-serial cycles AND fewer weight planes.
        assert!(b4.energy_pj < b6.energy_pj * 0.6);
        assert!(b4.latency_ns < b6.latency_ns);
    }

    #[test]
    fn prop_energy_positive_and_monotone_in_m() {
        assert_prop("scheduler-monotone", 48, |g| {
            let s = Scheduler::new(&MacroParams::default());
            let k = g.usize(1, 4096);
            let n = g.usize(1, 512);
            let m = g.usize(1, 64);
            let op = OperatingPoint {
                a_bits: g.usize(1, 8) as u32,
                w_bits: g.usize(1, 8) as u32,
                cb: if g.bool() { CbMode::On } else { CbMode::Off },
            };
            let a = s.plan_linear(&shape(k, n, m), op);
            let b = s.plan_linear(&shape(k, n, m + 1), op);
            if a.energy_pj <= 0.0 || a.latency_ns <= 0.0 {
                return Err(format!("non-positive cost {a:?}"));
            }
            if b.conversions <= a.conversions {
                return Err("conversions must grow with m".into());
            }
            Ok(())
        });
    }
}
