//! Tile scheduler: maps linear-layer workloads onto the 1088×78 macro.
//!
//! A linear layer (m × k) · (k × n) at (a_bits, w_bits) decomposes into
//! hardware tiles:
//!   - row tiles: ⌈k / 1024⌉ compute phases per output,
//!   - column tiles: n·w_bits physical columns, ⌈n·w_bits / 78⌉ loads,
//!   - m activation vectors, each a_bits bit-serial cycles.
//!
//! Conversions dominate energy; weight reloads are SRAM writes whose
//! *latency* still matters at the model-graph level, where every layer
//! of a forward pass reprograms the macros it draws from a pool. The
//! scheduler produces a [`TilePlan`] per layer (exact conversion count,
//! energy, conversion latency — the same `EnergyModel` the
//! characterization benches use) and a [`PipelinePlan`] per model graph,
//! pricing reloads fully serially, double-buffered (layer i+1's reload
//! hidden behind layer i's bit-serial conversions), and **warm** —
//! double-buffered with resident layers' reloads skipped. Residency is
//! the point of a CIM macro: weights that stay programmed between
//! inferences cost nothing to "load"; [`Scheduler::steady_residency`]
//! models the pipeline executor's per-pool LRU resident-weight cache
//! against the [`MacroParams::sram_bits_per_macro`] budget so repeated
//! inferences are priced by the warm pass, not a phantom per-pass
//! reload of the whole model.

use std::collections::BTreeMap;

use super::decode;
use crate::cim::energy::EnergyModel;
use crate::cim::netstats::LayerClass;
use crate::cim::params::MacroParams;
#[cfg(test)]
use crate::cim::params::CbMode;
use crate::util::stats;
use crate::vit::graph::ModelGraph;
use crate::vit::plan::OperatingPoint;
use crate::vit::LinearShape;

/// Die-pool index per SAC layer class. Pool 0 is the shared default a
/// standalone [`DieBank`](super::multidie::DieBank) uses; the pipeline
/// executor keeps the attention and MLP classes on disjoint silicon.
/// `CnnConv` rides the MLP pool — the same dispatch
/// `PrecisionPlan::point` and `PipelineConfig::dies_for` apply, so
/// sizing, pricing, residency and execution agree on which silicon a
/// conv layer uses.
pub fn class_pool(class: LayerClass) -> usize {
    match class {
        LayerClass::TransformerAttention => 1,
        LayerClass::TransformerMlp | LayerClass::CnnConv => 2,
    }
}

/// One resident entry of a [`ResidentLru`].
struct ResidentEntry<B> {
    value: B,
    footprint_bits: u64,
    last_used: u64,
}

/// The per-pool LRU resident-weight cache policy, generic over the
/// retained value. `coordinator::pipeline::ModelExecutor` runs it live
/// with `B = DieBank` (programmed pool silicon); the planner's
/// steady-state simulation ([`lru_steady_hits`]) runs the *same* code
/// with `B = ()` — so planned warm-pass hits and measured hits agree
/// structurally, not by parallel implementations kept in sync by prose.
///
/// Policy per access: [`touch`](Self::touch) a cached key → hit (LRU
/// position refreshed). On a miss, [`insert`](Self::insert) retains the
/// value only if its footprint fits the pool's capacity at all (an
/// oversized value is dropped and evicts nothing), evicting the pool's
/// least-recently-used entries until it fits. Capacity and footprints
/// are per pool and per die (each die of a pool holds a full copy of
/// each resident layer, so the die count cancels out).
pub struct ResidentLru<B> {
    // BTreeMaps, not hash maps: victim selection iterates `entries`, so
    // the tie-break order must be deterministic (detlint: unordered-iter).
    entries: BTreeMap<(usize, usize), ResidentEntry<B>>,
    pool_bits: BTreeMap<usize, u64>,
    capacity: BTreeMap<usize, u64>,
    tick: u64,
    evictions: u64,
}

impl<B> ResidentLru<B> {
    /// A cache with the given per-pool capacities [bits] (a pool absent
    /// from the map has capacity 0 — nothing is ever retained for it).
    pub fn new(capacity: BTreeMap<usize, u64>) -> Self {
        ResidentLru {
            entries: BTreeMap::new(),
            pool_bits: BTreeMap::new(),
            capacity,
            tick: 0,
            evictions: 0,
        }
    }

    /// Residency capacity of `pool` [bits].
    pub fn capacity(&self, pool: usize) -> u64 {
        self.capacity.get(&pool).copied().unwrap_or(0)
    }

    /// Advance the LRU clock and report whether `key` is resident
    /// (refreshing its LRU position if so).
    pub fn touch(&mut self, key: (usize, usize)) -> bool {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.last_used = tick;
                true
            }
            None => false,
        }
    }

    /// The resident value under `key`; panics if the key missed — call
    /// after a successful [`touch`](Self::touch).
    pub fn value_mut(&mut self, key: (usize, usize)) -> &mut B {
        &mut self.entries.get_mut(&key).expect("touched entry is resident").value
    }

    /// Retain a value if its pool budget allows, evicting the pool's
    /// least-recently-used entries to make room. A value bigger than its
    /// whole pool is never retained (and evicts nothing).
    pub fn insert(&mut self, key: (usize, usize), value: B, footprint_bits: u64) {
        let pool = key.1;
        let cap = self.capacity(pool);
        if footprint_bits > cap {
            return;
        }
        while self.pool_bits.get(&pool).copied().unwrap_or(0) + footprint_bits > cap {
            let victim = self
                .entries
                .iter()
                .filter(|((_, p), _)| *p == pool)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("pool over budget implies a resident entry");
            let gone = self.entries.remove(&victim).expect("victim is resident");
            *self.pool_bits.get_mut(&pool).expect("pool has bits") -= gone.footprint_bits;
            self.evictions += 1;
        }
        let entry = ResidentEntry { value, footprint_bits, last_used: self.tick };
        self.entries.insert(key, entry);
        *self.pool_bits.entry(pool).or_insert(0) += footprint_bits;
    }

    /// Bits currently resident across all pools.
    pub fn resident_bits(&self) -> u64 {
        self.pool_bits.values().sum()
    }

    /// Total residency capacity across all pools [bits].
    pub fn total_capacity_bits(&self) -> u64 {
        self.capacity.values().sum()
    }

    /// LRU evictions performed so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

/// Simulated warm passes of the [`ResidentLru`] policy over a cyclic
/// access sequence of `(pool, footprint_bits)` items — the planner's
/// model of the pipeline executor's live cache. Returns the hit flag
/// per item of the **third** simulated pass: the cyclic pattern is
/// periodic by then (all-fits → all hit; over-budget cycling → the
/// classic LRU zero-hit steady state).
pub fn lru_steady_hits(items: &[(usize, u64)], capacity: impl Fn(usize) -> u64) -> Vec<bool> {
    let caps: BTreeMap<usize, u64> =
        items.iter().map(|&(pool, _)| (pool, capacity(pool))).collect();
    let mut cache: ResidentLru<()> = ResidentLru::new(caps);
    let mut hits = vec![false; items.len()];
    for _pass in 0..3 {
        for (i, &(pool, fp)) in items.iter().enumerate() {
            let key = (i, pool);
            hits[i] = cache.touch(key);
            if !hits[i] {
                cache.insert(key, (), fp);
            }
        }
    }
    hits
}

/// Cost of running one linear layer on the macro.
#[derive(Clone, Copy, Debug, Default)]
pub struct TilePlan {
    /// Column-tile loads (weight reprogramming events).
    pub weight_loads: u64,
    /// Total ADC conversions.
    pub conversions: u64,
    /// Conversion energy [pJ].
    pub energy_pj: f64,
    /// Serial latency [ns] assuming all 78 columns convert in parallel
    /// and column tiles are processed sequentially per vector.
    pub latency_ns: f64,
    /// 1b-normalized op count (for TOPS-effective reporting).
    pub ops_1b: f64,
}

impl TilePlan {
    pub fn add(&mut self, other: &TilePlan) {
        self.weight_loads += other.weight_loads;
        self.conversions += other.conversions;
        self.energy_pj += other.energy_pj;
        self.latency_ns += other.latency_ns;
        self.ops_1b += other.ops_1b;
    }
}

/// Modeled timing of one graph layer inside a [`PipelinePlan`].
#[derive(Clone, Debug)]
pub struct LayerTiming {
    /// Display name (`block3.fc2`).
    pub name: String,
    /// Weight-reload latency [ns] for the layer's (row tile × column
    /// tile) loads, shard-parallel (see [`Scheduler::weight_load_ns`]).
    pub reload_ns: f64,
    /// Bit-serial conversion latency [ns] (the layer's
    /// [`TilePlan::latency_ns`]).
    pub compute_ns: f64,
    /// Steady-state residency: `true` means a warm pass finds this
    /// layer's weights already programmed on its pool dies (a reload
    /// *hit* — the reload is skipped), `false` means every pass pays the
    /// reload (a *miss*). See [`Scheduler::steady_residency`].
    pub resident: bool,
}

impl LayerTiming {
    /// The reload a warm (steady-state) pass actually pays [ns].
    pub fn warm_reload_ns(&self) -> f64 {
        if self.resident {
            0.0
        } else {
            self.reload_ns
        }
    }
}

/// Full-graph cost: per-layer timings, the conversion/energy totals, and
/// the weight-reload accounting models (serial, double-buffered cold,
/// double-buffered warm under steady-state residency).
#[derive(Clone, Debug)]
pub struct PipelinePlan {
    /// Per-layer timing in execution order.
    pub layers: Vec<LayerTiming>,
    /// Summed per-layer [`TilePlan`]s (conversion latency only — no
    /// reload term; see `serial_ns` / `pipelined_ns` for wall time).
    pub total: TilePlan,
    /// Fully-serial accounting: each layer's reload completes before its
    /// conversions start — Σ (reload + compute).
    pub serial_ns: f64,
    /// Double-buffered **cold-pass** accounting: layer i+1's reload
    /// overlaps layer i's bit-serial conversions, so only the first
    /// reload and any reload longer than the conversions it hides behind
    /// stay exposed. Every layer reloads (nothing resident yet).
    pub pipelined_ns: f64,
    /// Double-buffered **warm-pass** accounting: the same fold with
    /// resident layers' reloads skipped ([`LayerTiming::resident`]).
    /// Equals `pipelined_ns` when nothing is resident (capacity forces
    /// full eviction) and collapses to the pure conversion sum when the
    /// whole graph stays resident.
    pub warm_pipelined_ns: f64,
}

impl PipelinePlan {
    /// Assemble a plan from per-layer (name, compute plan, reload
    /// latency, steady-state residency) entries. The double-buffer fold:
    /// wall time is the first reload plus, per layer,
    /// `max(compute_i, reload_{i+1})` — the next layer's reload runs on
    /// its target macros while the current layer's conversions stream,
    /// and the pipeline stalls only when the reload outlasts them. The
    /// warm fold is identical with resident layers' reloads set to zero.
    pub fn from_layers(entries: Vec<(String, TilePlan, f64, bool)>) -> Self {
        let mut total = TilePlan::default();
        let mut layers = Vec::with_capacity(entries.len());
        for (name, plan, reload_ns, resident) in entries {
            total.add(&plan);
            layers.push(LayerTiming { name, reload_ns, compute_ns: plan.latency_ns, resident });
        }
        let serial_ns = stats::sum_ordered(layers.iter().map(|t| t.reload_ns + t.compute_ns));
        fn double_buffer_fold(layers: &[LayerTiming], reload: impl Fn(&LayerTiming) -> f64) -> f64 {
            let mut ns = layers.first().map(&reload).unwrap_or(0.0);
            for (i, t) in layers.iter().enumerate() {
                let next_reload = layers.get(i + 1).map(&reload).unwrap_or(0.0);
                ns += t.compute_ns.max(next_reload);
            }
            ns
        }
        let pipelined_ns = double_buffer_fold(&layers, |t| t.reload_ns);
        let warm_pipelined_ns = double_buffer_fold(&layers, LayerTiming::warm_reload_ns);
        PipelinePlan { layers, total, serial_ns, pipelined_ns, warm_pipelined_ns }
    }

    /// Fraction of the serial-reload latency the overlap saves.
    pub fn overlap_saving(&self) -> f64 {
        if self.serial_ns <= 0.0 {
            0.0
        } else {
            1.0 - self.pipelined_ns / self.serial_ns
        }
    }

    /// Layers resident on a warm pass (reload hits per pass).
    pub fn resident_layers(&self) -> usize {
        self.layers.iter().filter(|t| t.resident).count()
    }

    /// Fraction of the cold-pass pipelined latency residency saves on a
    /// warm pass.
    pub fn residency_saving(&self) -> f64 {
        if self.pipelined_ns <= 0.0 {
            0.0
        } else {
            1.0 - self.warm_pipelined_ns / self.pipelined_ns
        }
    }

    /// The steady-state **per-stage bound** of the staged
    /// program/convert pipeline [ns]: the widest single stage — the max
    /// over layers of `max(compute, warm reload)`. The pipelined
    /// executor advances in barrier-separated stages (stage `s` programs
    /// layer `s+1` while converting layer `s`), so no stage can finish
    /// faster than its widest task, and a measured warm overlapped pass
    /// is bounded below by `warm_pipelined_ns` — which is exactly the
    /// sum of these per-stage maxima plus the exposed first reload.
    /// `rust/tests/overlap.rs` anchors the executor's measured pass
    /// against this bound.
    pub fn stage_period_ns(&self) -> f64 {
        self.layers
            .iter()
            .map(|t| t.compute_ns.max(t.warm_reload_ns()))
            .fold(0.0f64, f64::max)
    }

    /// Modeled full-pass latency amortized over `passes` inferences of
    /// the same graph: one cold pass, the rest warm.
    pub fn amortized_pass_ns(&self, passes: u64) -> f64 {
        if passes == 0 {
            return self.pipelined_ns;
        }
        (self.pipelined_ns + (passes - 1) as f64 * self.warm_pipelined_ns) / passes as f64
    }
}

/// Streaming occupancy/latency model of one token **conversion wave**:
/// the [`Scheduler::plan_stream`] counterpart to the fixed-batch
/// [`PipelinePlan`], so planned die utilization and tail latency are
/// comparable between the two admission tiers.
///
/// The model assumes **saturated admission**: every wave is full
/// (`wave_tokens` tokens) and waves run back to back, which is the
/// regime streaming exists for — a macro kept busy between batch
/// boundaries. Under saturation a token arrives uniformly at random
/// while the previous wave is in flight, waits out its remainder
/// (`U·warm_wave_ns`, U uniform on [0, 1]) and rides the next wave
/// (`warm_wave_ns`), so modeled token latency is `(1 + U)·warm_wave_ns`:
/// p50 = 1.5×, p99 = 1.99× the warm wave. Waves reuse the same pool
/// silicon back to back, so the steady-state wave is the **warm**
/// (residency-aware) pass; the cold number prices the first wave.
#[derive(Clone, Debug)]
pub struct StreamPlan {
    /// Tokens coalesced per conversion wave.
    pub wave_tokens: usize,
    /// First-wave (cold — every layer reloads) pipelined latency [ns].
    pub cold_wave_ns: f64,
    /// Steady-state (warm — resident layers skip reloads) wave latency
    /// [ns].
    pub warm_wave_ns: f64,
    /// Sustained token throughput at saturation: `wave_tokens /
    /// warm_wave_ns`.
    pub tokens_per_s: f64,
    /// Fraction of the warm wave the dies spend converting
    /// (Σ compute / warm wave); the remainder is exposed weight
    /// reloads. Written to the bench report as
    /// `stream_wave_occupancy`. Distinct from the server's measured
    /// `mean_wave_occupancy`, which is slot fill (admitted tokens /
    /// wave size): a run can have every wave full (slot fill 1.0) while
    /// die utilization stays below 1 on exposed reloads.
    pub die_utilization: f64,
    /// Modeled p50 token latency at saturation [ns] (1.5 × warm wave).
    pub p50_token_latency_ns: f64,
    /// Modeled p99 token latency at saturation [ns] (1.99 × warm wave).
    pub p99_token_latency_ns: f64,
}

/// Generation-serving price: prefill vs steady-state decode throughput
/// for a decoder graph under continuous batching, plus the planner's
/// replay of the KV residency policy ([`decode::SeqStateCache`]) over
/// the canonical serving trace. The raw hit/miss/eviction counters are
/// exposed (not just the rate) so the acceptance test can compare them
/// to the live executor's measured counters for exact equality.
#[derive(Clone, Copy, Debug)]
pub struct DecodePlan {
    /// Concurrently live sequences the plan prices.
    pub live: usize,
    /// Prompt length per sequence.
    pub prompt_tokens: usize,
    /// One sequence's prefill latency [ns]: its whole prompt as one warm
    /// conversion wave.
    pub prefill_pass_ns: f64,
    /// Steady-state decode step latency [ns]: one wave carrying one
    /// token from every live sequence, attention layers priced at their
    /// position-dependent effective stream (`GraphLayer::shape_at`).
    pub decode_step_ns: f64,
    /// Sustained generation throughput: `live` tokens per decode step.
    pub decode_tokens_per_s: f64,
    /// KV residency hits over the replayed serving trace.
    pub kv_hits: u64,
    /// KV residency misses (state restored/re-pinned).
    pub kv_misses: u64,
    /// KV entries evicted by the capacity bound.
    pub kv_evictions: u64,
    /// Hit fraction of all KV accesses (0 when the graph has no
    /// attention context, i.e. is not a decoder).
    pub kv_hit_rate: f64,
}

/// The scheduler: stateless; all methods derive from macro parameters
/// plus the serving topology (how many macros and dies run in parallel).
#[derive(Clone, Debug)]
pub struct Scheduler {
    pub params: MacroParams,
    /// Parallel macro shards serving column tiles. Energy and conversion
    /// counts are shard-independent (the same work happens somewhere);
    /// latency divides across shards because column tiles of the same
    /// layer convert concurrently.
    pub shards: usize,
    /// Independent dies serving the same layer. A served batch's vectors
    /// split across dies, so only `⌈m / dies⌉` of the activation stream
    /// serializes on any one die. Energy is die-independent.
    pub dies: usize,
}

impl Scheduler {
    pub fn new(params: &MacroParams) -> Self {
        Self::with_topology(params, 1, 1)
    }

    /// A scheduler that maps column tiles across `shards` parallel macros.
    pub fn with_shards(params: &MacroParams, shards: usize) -> Self {
        Self::with_topology(params, shards, 1)
    }

    /// Full serving topology: `shards` parallel macros per die, `dies`
    /// independent dies sharing the batch stream.
    pub fn with_topology(params: &MacroParams, shards: usize, dies: usize) -> Self {
        Scheduler { params: params.clone(), shards: shards.max(1), dies: dies.max(1) }
    }

    /// Row tiles needed for a reduction dimension `k`.
    pub fn row_tiles(&self, k: usize) -> u64 {
        (k as u64).div_ceil(self.params.active_rows as u64)
    }

    /// Column tiles for `n` outputs at `w_bits` weight planes.
    pub fn col_tiles(&self, n: usize, w_bits: u32) -> u64 {
        (n as u64 * w_bits as u64).div_ceil(self.params.cols as u64)
    }

    /// Weight-reload latency [ns] for one layer: every
    /// (row tile × column tile) SRAM load pays `t_wload_ns`; loads of
    /// different column shards target different macros and run
    /// concurrently, so only `⌈tiles / shards⌉` serialize. Dies each
    /// hold a full copy and load concurrently (no die division).
    pub fn weight_load_ns(&self, shape: &LinearShape, op: OperatingPoint) -> f64 {
        let tiles = self.row_tiles(shape.k) * self.col_tiles(shape.n, op.w_bits);
        tiles.div_ceil(self.shards.max(1) as u64) as f64 * self.params.t_wload_ns
    }

    /// Physical macro units one layer occupies: (row tiles) ×
    /// (whole-output column tiles, `⌊cols / w_bits⌋` outputs each — a
    /// multi-bit weight never straddles macros). The same unit the
    /// router places and `MacroShards` instantiates, so residency
    /// capacity is counted in real arrays.
    pub fn layer_units(&self, shape: &LinearShape, op: OperatingPoint) -> u64 {
        let cap_out = (self.params.cols as u64 / op.w_bits.max(1) as u64).max(1);
        self.row_tiles(shape.k) * (shape.n as u64).div_ceil(cap_out).max(1)
    }

    /// Weight-bit footprint of one layer resident on a pool die [bits]:
    /// `k · n · w_bits`, exactly the per-unit sum the router's
    /// `resident_bits` accounting places (each die of a pool holds a
    /// full copy, so per-die accounting is the whole story).
    pub fn layer_weight_bits(shape: &LinearShape, op: OperatingPoint) -> u64 {
        (shape.k as u64) * (shape.n as u64) * op.w_bits as u64
    }

    /// Per-die weight-SRAM residency capacity of class pool `pool`
    /// serving `graph` [bits]: the pool owns exactly the silicon its
    /// largest layer instantiates (`max layer_units` macro arrays per
    /// die), each array holding [`MacroParams::sram_bits_per_macro`]
    /// resident weight bits. `sram_bits_per_macro = 0` disables
    /// residency for every pool.
    pub fn pool_capacity_bits(&self, graph: &ModelGraph, pool: usize) -> u64 {
        graph
            .layers
            .iter()
            .filter(|l| class_pool(l.shape.class) == pool)
            .map(|l| self.layer_units(&l.shape, l.op))
            .max()
            .unwrap_or(0)
            .saturating_mul(self.params.sram_bits_per_macro)
    }

    /// Steady-state warm-pass residency per graph layer: simulate the
    /// pipeline executor's per-pool LRU resident-weight cache
    /// ([`lru_steady_hits`]) over the graph's cyclic layer walk, with
    /// each layer's footprint accounted against its class pool's
    /// capacity. `true` = a warm pass skips this layer's reload.
    pub fn steady_residency(&self, graph: &ModelGraph) -> Vec<bool> {
        let items: Vec<(usize, u64)> = graph
            .layers
            .iter()
            .map(|l| (class_pool(l.shape.class), Self::layer_weight_bits(&l.shape, l.op)))
            .collect();
        let caps: BTreeMap<usize, u64> = items
            .iter()
            .map(|&(pool, _)| (pool, self.pool_capacity_bits(graph, pool)))
            .collect();
        lru_steady_hits(&items, |pool| caps.get(&pool).copied().unwrap_or(0))
    }

    /// Plan a whole model graph: per-layer conversion plans plus the
    /// serial, double-buffered cold-pass and double-buffered warm-pass
    /// weight-reload accountings. The old per-layer path ignored reload
    /// latency entirely (equivalent to assuming every layer's weights
    /// were already resident); the revision before this one charged a
    /// full reload for every layer of every pass (equivalent to assuming
    /// nothing is ever resident). `plan_graph` now prices both ends —
    /// cold (`pipelined_ns`) and steady-state warm (`warm_pipelined_ns`
    /// under [`steady_residency`](Self::steady_residency)) — so served
    /// latency can be amortized honestly across repeated inferences.
    pub fn plan_graph(&self, graph: &ModelGraph) -> PipelinePlan {
        let resident = self.steady_residency(graph);
        PipelinePlan::from_layers(
            graph
                .layers
                .iter()
                .zip(&resident)
                .map(|(l, &res)| {
                    let reload = self.weight_load_ns(&l.shape, l.op);
                    (l.name(), self.plan_linear(&l.shape, l.op), reload, res)
                })
                .collect(),
        )
    }

    /// Price one streaming conversion wave of `wave_tokens` tokens over
    /// `graph`'s layer chain (see [`StreamPlan`] for the saturation
    /// model). The wave re-shapes every layer's activation stream to
    /// `wave_tokens` vectors ([`ModelGraph::with_stream_m`]) and runs
    /// through the same [`plan_graph`](Self::plan_graph) accounting as
    /// the fixed-batch tier, so `plan_stream(graph, m)` with `m` equal
    /// to the graph's own stream reproduces `plan_graph(graph)` exactly
    /// — the two admission models are comparable by construction.
    pub fn plan_stream(&self, graph: &ModelGraph, wave_tokens: usize) -> StreamPlan {
        let wt = wave_tokens.max(1);
        let pp = self.plan_graph(&graph.with_stream_m(wt));
        let conv = stats::sum_ordered(pp.layers.iter().map(|t| t.compute_ns));
        let warm = pp.warm_pipelined_ns;
        let (tokens_per_s, die_utilization) = if warm > 0.0 {
            (wt as f64 / (warm * 1e-9), conv / warm)
        } else {
            (0.0, 0.0)
        };
        StreamPlan {
            wave_tokens: wt,
            cold_wave_ns: pp.pipelined_ns,
            warm_wave_ns: warm,
            tokens_per_s,
            die_utilization,
            p50_token_latency_ns: 1.5 * warm,
            p99_token_latency_ns: 1.99 * warm,
        }
    }

    /// Price autoregressive generation over a decoder graph: the
    /// **prefill phase** (each sequence's `prompt_tokens`-token prompt
    /// as one warm conversion wave) against the **steady-state decode
    /// phase** (one wave per step carrying one token from each of `live`
    /// sequences, with attention layers priced at their
    /// position-dependent effective stream via `GraphLayer::shape_at`
    /// at the trace's mid-decode position).
    ///
    /// The KV counters replay the executor's residency policy — the
    /// *same* [`decode::SeqStateCache`] struct, fed the canonical
    /// serving trace ([`decode::replay_prefill`] then
    /// [`decode::replay_lockstep`]) whose access order matches the
    /// executor's serial decision pass — so planned KV hits equal
    /// measured hits by construction when the server runs that trace.
    pub fn plan_decode(
        &self,
        graph: &ModelGraph,
        live: usize,
        prompt_tokens: usize,
        decode_steps: usize,
        kv_capacity_bits: u64,
    ) -> DecodePlan {
        let live = live.max(1);
        let prompt = prompt_tokens.max(1);
        let steps = decode_steps.max(1);
        // Prefill: the prompt streams through every linear once, as one
        // warm wave (live sequences prefill in their own waves, so the
        // per-sequence latency is a single wave of `prompt` tokens).
        let prefill_pass_ns = self.plan_graph(&graph.with_stream_m(prompt)).warm_pipelined_ns;
        // Decode step: one token per live sequence per wave; attention
        // layers fold the KV window, so their effective stream at the
        // trace's mid-decode position is shape_at(pos).m per token.
        let pos = prompt + steps / 2;
        let mut step_graph = graph.clone();
        step_graph.batch = 1;
        for l in &mut step_graph.layers {
            l.shape.m = l.shape_at(pos).m.saturating_mul(live).max(1);
        }
        let decode_step_ns = self.plan_graph(&step_graph).warm_pipelined_ns;
        let decode_tokens_per_s =
            if decode_step_ns > 0.0 { live as f64 / (decode_step_ns * 1e-9) } else { 0.0 };
        // KV residency replay over the canonical trace: per-sequence
        // prefill waves, then lockstep decode steps.
        let kv_layer = graph
            .layers
            .iter()
            .find(|l| l.context > 0 && l.role == crate::vit::graph::LayerRole::Qkv);
        let shape = decode::ReplayShape {
            live,
            blocks: graph
                .layers
                .iter()
                .filter(|l| l.context > 0 && l.role == crate::vit::graph::LayerRole::Qkv)
                .count(),
            dim: kv_layer.map(|l| l.shape.k).unwrap_or(0),
            a_bits: kv_layer.map(|l| l.op.a_bits).unwrap_or(0),
            context: graph.context(),
        };
        let mut cache = decode::SeqStateCache::new(kv_capacity_bits);
        decode::replay_prefill(&mut cache, &shape, prompt);
        decode::replay_lockstep(&mut cache, &shape, prompt, steps);
        let total = cache.hits() + cache.misses();
        DecodePlan {
            live,
            prompt_tokens: prompt,
            prefill_pass_ns,
            decode_step_ns,
            decode_tokens_per_s,
            kv_hits: cache.hits(),
            kv_misses: cache.misses(),
            kv_evictions: cache.evictions(),
            kv_hit_rate: if total == 0 { 0.0 } else { cache.hits() as f64 / total as f64 },
        }
    }

    /// Plan one linear layer at an operating point.
    pub fn plan_linear(&self, shape: &LinearShape, op: OperatingPoint) -> TilePlan {
        let rt = self.row_tiles(shape.k);
        let ct = self.col_tiles(shape.n, op.w_bits);
        // Conversions: every (row tile, column, activation bit, vector).
        // All 78 columns of a column tile convert in parallel but each is
        // one ADC conversion for energy purposes.
        let cols_used = (shape.n as u64 * op.w_bits as u64).min(ct * self.params.cols as u64);
        let conversions = rt * cols_used * op.a_bits as u64 * shape.m as u64;
        // Latency: serial over (row tiles × column tiles × a_bits) cycles
        // per vector; vectors stream (one conversion cycle each, weights
        // stay loaded while m streams). Column tiles spread across macro
        // shards, so only ⌈ct / shards⌉ of them serialize; the batch's
        // vectors spread across dies, so only ⌈m / dies⌉ of the stream
        // serializes on any one die.
        let ct_serial = ct.div_ceil(self.shards.max(1) as u64);
        let m_per_die = (shape.m as u64).div_ceil(self.dies.max(1) as u64);
        let cycles = rt * ct_serial * op.a_bits as u64 * m_per_die;
        // Price the layer's own majority-voting point, not the deployment
        // default: `MacroShards::with_tiling` applies the same `with_mv`
        // override to the macros it builds, so the per-comparison counts
        // (latency) and the rebuilt energy model here equal what the
        // executor's macros measure — planned == measured by
        // construction, per vote point.
        let op_params = self
            .params
            .clone()
            .with_mv(op.noise.mv_votes as usize, op.noise.mv_last_bits as usize);
        let t_cycle = op_params.conversion_latency_ns(op.cb);
        // Row-tile accumulation reduce step: each extra row tile's
        // partial sum folds into the layer accumulator with one digital
        // add per streamed vector (pipelined across columns).
        let reduce_ns = self.params.t_accum_ns * (rt.saturating_sub(1) * m_per_die) as f64;
        let e_conv = EnergyModel::cr_cim(&op_params).conversion_energy_pj(op.cb);
        TilePlan {
            weight_loads: rt * ct,
            conversions,
            energy_pj: e_conv * conversions as f64,
            latency_ns: t_cycle * cycles as f64 + reduce_ns,
            ops_1b: 2.0
                * shape.k as f64
                * shape.n as f64
                * shape.m as f64
                * op.a_bits as f64
                * op.w_bits as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::netstats::LayerClass;
    use crate::util::prop::assert_prop;
    use crate::vit::plan::PrecisionPlan;

    fn shape(k: usize, n: usize, m: usize) -> LinearShape {
        LinearShape { class: LayerClass::TransformerMlp, k, n, m }
    }

    #[test]
    fn tile_counts() {
        let s = Scheduler::new(&MacroParams::default());
        assert_eq!(s.row_tiles(96), 1);
        assert_eq!(s.row_tiles(1024), 1);
        assert_eq!(s.row_tiles(1025), 2);
        assert_eq!(s.col_tiles(13, 6), 1); // 78 planes exactly
        assert_eq!(s.col_tiles(14, 6), 2);
        assert_eq!(s.col_tiles(10, 4), 1);
    }

    #[test]
    fn conversions_scale_with_everything() {
        let s = Scheduler::new(&MacroParams::default());
        let op = PrecisionPlan::paper_sac().mlp;
        let base = s.plan_linear(&shape(96, 13, 10), op);
        // 1 row tile × 78 cols × 6 abits × 10 vectors.
        assert_eq!(base.conversions, 78 * 6 * 10);
        let more_m = s.plan_linear(&shape(96, 13, 20), op);
        assert_eq!(more_m.conversions, 2 * base.conversions);
        let more_k = s.plan_linear(&shape(2048, 13, 10), op);
        assert_eq!(more_k.conversions, 2 * base.conversions);
    }

    #[test]
    fn shards_divide_latency_but_not_energy() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp; // 6b: 13 outs/tile
        let sh = shape(96, 52, 10); // 52·6 = 312 planes = 4 column tiles
        let s1 = Scheduler::new(&p).plan_linear(&sh, op);
        let s4 = Scheduler::with_shards(&p, 4).plan_linear(&sh, op);
        assert_eq!(s1.conversions, s4.conversions);
        assert!((s1.energy_pj - s4.energy_pj).abs() < 1e-9);
        assert!((s1.latency_ns / s4.latency_ns - 4.0).abs() < 1e-9, "4 shards must 4x the tiles");
        // More shards than tiles saturates at one serial tile.
        let s9 = Scheduler::with_shards(&p, 9).plan_linear(&sh, op);
        assert!((s9.latency_ns - s4.latency_ns).abs() < 1e-9);
        assert_eq!(Scheduler::with_shards(&p, 0).shards, 1);
    }

    #[test]
    fn dies_divide_stream_latency_but_not_energy() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp;
        let sh = shape(96, 13, 40);
        let d1 = Scheduler::new(&p).plan_linear(&sh, op);
        let d4 = Scheduler::with_topology(&p, 1, 4).plan_linear(&sh, op);
        assert_eq!(d1.conversions, d4.conversions);
        assert!((d1.energy_pj - d4.energy_pj).abs() < 1e-9);
        assert!((d1.latency_ns / d4.latency_ns - 4.0).abs() < 1e-9, "4 dies must 4x the stream");
        // More dies than vectors saturates at one vector per die.
        let d99 = Scheduler::with_topology(&p, 1, 99).plan_linear(&shape(96, 13, 4), op);
        let d4b = Scheduler::with_topology(&p, 1, 4).plan_linear(&shape(96, 13, 4), op);
        assert!((d99.latency_ns - d4b.latency_ns).abs() < 1e-9);
        assert_eq!(Scheduler::with_topology(&p, 0, 0).dies, 1);
    }

    #[test]
    fn row_tiled_layers_pay_the_accumulation_reduce_step() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp;
        let m = 10u64;
        let one = Scheduler::new(&p).plan_linear(&shape(1024, 13, m as usize), op);
        let three = Scheduler::new(&p).plan_linear(&shape(3072, 13, m as usize), op);
        // 3 row tiles: 3x the conversion cycles plus 2 digital adds per
        // streamed vector.
        let want = 3.0 * one.latency_ns + p.t_accum_ns * (2 * m) as f64;
        assert!(
            (three.latency_ns - want).abs() < 1e-9,
            "got {} want {want}",
            three.latency_ns
        );
        // The reduce step scales down with the die count like the stream.
        let three_d2 = Scheduler::with_topology(&p, 1, 2).plan_linear(&shape(3072, 13, 10), op);
        let want_d2 = 3.0 * one.latency_ns / 2.0 + p.t_accum_ns * (2 * m / 2) as f64;
        assert!((three_d2.latency_ns - want_d2).abs() < 1e-9);
    }

    #[test]
    fn cb_on_costs_more_energy_and_time_per_conversion() {
        let s = Scheduler::new(&MacroParams::default());
        let sh = shape(96, 13, 10);
        let on = s.plan_linear(&sh, OperatingPoint::new(6, 6, CbMode::On));
        let off = s.plan_linear(&sh, OperatingPoint::new(6, 6, CbMode::Off));
        assert_eq!(on.conversions, off.conversions);
        let e_ratio = on.energy_pj / off.energy_pj;
        assert!((e_ratio - 1.9).abs() < 0.2, "CB energy ratio {e_ratio}");
        assert!(on.latency_ns > off.latency_ns * 1.5);
    }

    #[test]
    fn lower_bits_cost_less() {
        let s = Scheduler::new(&MacroParams::default());
        let sh = shape(96, 13, 10);
        let b6 = s.plan_linear(&sh, OperatingPoint::new(6, 6, CbMode::Off));
        let b4 = s.plan_linear(&sh, OperatingPoint::new(4, 4, CbMode::Off));
        // 4b: fewer bit-serial cycles AND fewer weight planes.
        assert!(b4.energy_pj < b6.energy_pj * 0.6);
        assert!(b4.latency_ns < b6.latency_ns);
    }

    #[test]
    fn weight_load_latency_counts_tiles_and_divides_by_shards() {
        let p = MacroParams::default();
        let op = PrecisionPlan::paper_sac().mlp; // 6b
        // (3072, 768): 3 row tiles × ⌈768·6/78⌉ = 60 column tiles.
        let sh = shape(3072, 768, 1);
        let s1 = Scheduler::new(&p);
        assert!((s1.weight_load_ns(&sh, op) - 180.0 * p.t_wload_ns).abs() < 1e-9);
        let s4 = Scheduler::with_shards(&p, 4);
        assert!((s4.weight_load_ns(&sh, op) - 45.0 * p.t_wload_ns).abs() < 1e-9);
        // Dies do not divide the reload (each die loads its own copy).
        let d2 = Scheduler::with_topology(&p, 1, 2);
        assert!((d2.weight_load_ns(&sh, op) - 180.0 * p.t_wload_ns).abs() < 1e-9);
    }

    #[test]
    fn pipelined_reload_is_strictly_below_serial_for_vit_base_batch8() {
        // Acceptance anchor: double-buffered reloads must beat the
        // fully-serial accounting on the real target workload.
        use crate::vit::graph::ModelGraph;
        use crate::vit::VitConfig;
        let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
        for (shards, dies) in [(1usize, 1usize), (4, 2), (8, 4)] {
            let sched = Scheduler::with_topology(&MacroParams::default(), shards, dies);
            let pp = sched.plan_graph(&graph);
            assert_eq!(pp.layers.len(), 48);
            assert!(
                pp.pipelined_ns < pp.serial_ns,
                "overlap must strictly help: {} vs {} (shards {shards}, dies {dies})",
                pp.pipelined_ns,
                pp.serial_ns
            );
            // But it can never hide the conversions themselves.
            let conv: f64 = pp.layers.iter().map(|t| t.compute_ns).sum();
            assert!(pp.pipelined_ns >= conv);
            assert!(pp.overlap_saving() > 0.0 && pp.overlap_saving() < 1.0);
        }
    }

    #[test]
    fn pipeline_fold_matches_hand_computation() {
        let mk = |latency_ns: f64| TilePlan { latency_ns, ..TilePlan::default() };
        let pp = PipelinePlan::from_layers(vec![
            ("a".into(), mk(100.0), 10.0, false),
            ("b".into(), mk(50.0), 80.0, true),
            ("c".into(), mk(70.0), 20.0, false),
        ]);
        // serial: (10+100) + (80+50) + (20+70) = 330
        assert!((pp.serial_ns - 330.0).abs() < 1e-12);
        // pipelined: 10 + max(100, 80) + max(50, 20) + 70 = 230
        assert!((pp.pipelined_ns - 230.0).abs() < 1e-12);
        assert!((pp.overlap_saving() - (1.0 - 230.0 / 330.0)).abs() < 1e-12);
        // Stage period: widest of max(compute, warm reload) per layer —
        // max(max(100,10), max(50,0: b resident), max(70,20)) = 100.
        assert!((pp.stage_period_ns() - 100.0).abs() < 1e-12);
        // warm (only b resident): 10 + max(100, 0) + max(50, 20) + 70 =
        // 230 — b's reload was fully hidden anyway, so skipping it saves
        // nothing here.
        assert!((pp.warm_pipelined_ns - 230.0).abs() < 1e-12);
        assert_eq!(pp.resident_layers(), 1);
        // All-resident: warm collapses to the conversion sum.
        let all = PipelinePlan::from_layers(vec![
            ("a".into(), mk(100.0), 10.0, true),
            ("b".into(), mk(50.0), 80.0, true),
            ("c".into(), mk(70.0), 20.0, true),
        ]);
        assert!((all.warm_pipelined_ns - 220.0).abs() < 1e-12);
        assert!(all.residency_saving() > 0.0);
        // Nothing resident: warm equals the cold pipelined pass.
        let none = PipelinePlan::from_layers(vec![
            ("a".into(), mk(100.0), 10.0, false),
            ("b".into(), mk(50.0), 80.0, false),
        ]);
        assert!((none.warm_pipelined_ns - none.pipelined_ns).abs() < 1e-12);
        assert_eq!(none.residency_saving(), 0.0);
        // Amortization: pass 1 cold, the rest warm.
        assert!((all.amortized_pass_ns(1) - all.pipelined_ns).abs() < 1e-12);
        let a4 = all.amortized_pass_ns(4);
        assert!(a4 < all.pipelined_ns && a4 > all.warm_pipelined_ns);
        // Degenerate cases.
        let empty = PipelinePlan::from_layers(Vec::new());
        assert_eq!(empty.serial_ns, 0.0);
        assert_eq!(empty.pipelined_ns, 0.0);
        assert_eq!(empty.warm_pipelined_ns, 0.0);
        assert_eq!(empty.overlap_saving(), 0.0);
        assert_eq!(empty.stage_period_ns(), 0.0);
        let one = PipelinePlan::from_layers(vec![("x".into(), mk(40.0), 5.0, false)]);
        assert!((one.serial_ns - one.pipelined_ns).abs() < 1e-12);
    }

    #[test]
    fn plan_stream_is_comparable_to_plan_graph_and_models_saturation() {
        use crate::vit::graph::ModelGraph;
        use crate::vit::VitConfig;
        let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
        let sched = Scheduler::with_topology(&MacroParams::default(), 4, 2);
        // A wave of exactly the graph's activation stream reproduces the
        // fixed-batch plan: the two admission tiers price the same work
        // identically by construction.
        let m = graph.layers[0].shape.m; // 8 × 197 tokens
        let sp = sched.plan_stream(&graph, m);
        let pp = sched.plan_graph(&graph);
        assert_eq!(sp.wave_tokens, m);
        assert!((sp.cold_wave_ns - pp.pipelined_ns).abs() < 1e-9);
        assert!((sp.warm_wave_ns - pp.warm_pipelined_ns).abs() < 1e-9);
        // Saturation model: utilization is the conversion share of the
        // warm wave; tail latencies are fixed multiples of it.
        assert!(sp.die_utilization > 0.0 && sp.die_utilization <= 1.0);
        assert!((sp.p50_token_latency_ns - 1.5 * sp.warm_wave_ns).abs() < 1e-9);
        assert!((sp.p99_token_latency_ns - 1.99 * sp.warm_wave_ns).abs() < 1e-9);
        assert!(sp.tokens_per_s > 0.0);
        // Bigger waves amortize the exposed reloads: throughput and die
        // utilization never degrade as the wave grows.
        let small = sched.plan_stream(&graph, 197);
        assert!(sp.tokens_per_s >= small.tokens_per_s * (1.0 - 1e-9));
        assert!(sp.die_utilization >= small.die_utilization * (1.0 - 1e-9));
        // Degenerate wave sizes clamp to one token.
        assert_eq!(sched.plan_stream(&graph, 0).wave_tokens, 1);
    }

    #[test]
    fn lru_steady_hits_all_fit_all_hit_and_cyclic_overflow_never_hits() {
        // Four layers of 10 bits in one pool, capacity 40: everything
        // stays resident → warm passes hit every layer.
        let items = vec![(1usize, 10u64); 4];
        assert_eq!(lru_steady_hits(&items, |_| 40), vec![true; 4]);
        // Capacity 30 < 40: the cyclic walk evicts each layer just
        // before its next use — the classic LRU zero-hit steady state.
        assert_eq!(lru_steady_hits(&items, |_| 30), vec![false; 4]);
        // Capacity 0 disables residency outright.
        assert_eq!(lru_steady_hits(&items, |_| 0), vec![false; 4]);
        // Pools are independent: pool 2's small layer stays resident
        // even while pool 1 thrashes.
        let mixed = vec![(1usize, 10u64), (2, 5), (1, 10), (1, 10)];
        let hits = lru_steady_hits(&mixed, |pool| if pool == 2 { 8 } else { 20 });
        assert_eq!(hits, vec![false, true, false, false]);
        // An item bigger than its pool is never retained, but does not
        // evict what fits.
        let big = vec![(1usize, 50u64), (1, 10)];
        assert_eq!(lru_steady_hits(&big, |_| 20), vec![false, true]);
    }

    #[test]
    fn steady_residency_follows_the_sram_budget() {
        use crate::vit::graph::ModelGraph;
        use crate::vit::VitConfig;
        let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
        // Default budget (one array per macro): ViT-Base cannot stay
        // resident — ~14 Mbit per fc1/fc2 against a ~20 Mbit MLP pool
        // (one layer fits alone, never two; the cyclic walk then evicts
        // each just before its reuse).
        let s = Scheduler::new(&MacroParams::default());
        assert!(s.steady_residency(&graph).iter().all(|&r| !r));
        let pp = s.plan_graph(&graph);
        assert_eq!(pp.resident_layers(), 0);
        assert!((pp.warm_pipelined_ns - pp.pipelined_ns).abs() < 1e-9);
        // A deployment with banked weight SRAM holds the whole model:
        // every layer resident, warm pass strictly faster than cold and
        // exactly conversion-bound.
        let big = Scheduler::new(&MacroParams::default().with_sram_bits(1 << 26));
        assert!(big.steady_residency(&graph).iter().all(|&r| r));
        let wp = big.plan_graph(&graph);
        assert_eq!(wp.resident_layers(), 48);
        assert!(wp.warm_pipelined_ns < wp.pipelined_ns);
        let conv: f64 = wp.layers.iter().map(|t| t.compute_ns).sum();
        assert!((wp.warm_pipelined_ns - conv).abs() < 1e-9);
        // A zero budget forces full eviction regardless of geometry.
        let none = Scheduler::new(&MacroParams::default().with_sram_bits(0));
        assert!(none.steady_residency(&graph).iter().all(|&r| !r));
    }

    #[test]
    fn layer_units_match_router_packing_and_capacity_scales() {
        let s = Scheduler::new(&MacroParams::default());
        let op4 = OperatingPoint::new(4, 4, CbMode::Off);
        // qkv (768 → 2304) at 4b: ⌊78/4⌋ = 19 outputs per macro → 122
        // units (the router's whole-output packing, not plane packing).
        assert_eq!(s.layer_units(&shape(768, 2304, 1), op4), 122);
        let op6 = OperatingPoint::new(6, 6, CbMode::On);
        // fc2 (3072 → 768) at 6b: 3 row tiles × ⌈768/13⌉ = 180 units.
        assert_eq!(s.layer_units(&shape(3072, 768, 1), op6), 180);
        assert_eq!(Scheduler::layer_weight_bits(&shape(3072, 768, 1), op6), 3072 * 768 * 6);
    }

    #[test]
    fn plan_decode_prices_phases_and_replays_kv_counters() {
        use crate::vit::graph::{GraphConfig, ModelGraph};
        use crate::vit::VitConfig;
        let gc = GraphConfig { vit: VitConfig::default(), context: 16 };
        let graph = ModelGraph::decoder(&gc, &PrecisionPlan::paper_sac());
        let sched = Scheduler::with_topology(&MacroParams::default(), 2, 2);
        let dp = sched.plan_decode(&graph, 3, 4, 8, 1 << 30);
        assert_eq!((dp.live, dp.prompt_tokens), (3, 4));
        assert!(dp.prefill_pass_ns > 0.0 && dp.decode_step_ns > 0.0);
        assert!(dp.decode_tokens_per_s > 0.0);
        // All-fits capacity over the canonical trace: each of the
        // live × depth (seq, block) KV entries misses once (prompt
        // position 0) and hits for the remaining prompt positions and
        // every decode step.
        let blocks = gc.vit.depth as u64;
        assert_eq!(dp.kv_misses, 3 * blocks);
        assert_eq!(dp.kv_hits, 3 * blocks * (4 - 1 + 8));
        assert_eq!(dp.kv_evictions, 0);
        assert!(dp.kv_hit_rate > 0.85);
        // A tight KV budget thrashes: evictions appear, hit rate drops,
        // while the phase pricing is capacity-independent.
        let tight = sched.plan_decode(&graph, 3, 4, 8, 20_000);
        assert!(tight.kv_evictions > 0);
        assert!(tight.kv_hit_rate < dp.kv_hit_rate);
        assert!((tight.decode_step_ns - dp.decode_step_ns).abs() < 1e-9);
        // The counters are exactly a replay of the shared chokepoint —
        // the same SeqStateCache fed the same canonical trace.
        let shape = decode::ReplayShape {
            live: 3,
            blocks: blocks as usize,
            dim: gc.vit.dim,
            a_bits: PrecisionPlan::paper_sac().attention.a_bits,
            context: 16,
        };
        let mut cache = decode::SeqStateCache::new(20_000);
        decode::replay_prefill(&mut cache, &shape, 4);
        decode::replay_lockstep(&mut cache, &shape, 4, 8);
        assert_eq!(
            (tight.kv_hits, tight.kv_misses, tight.kv_evictions),
            (cache.hits(), cache.misses(), cache.evictions())
        );
        // An encoder graph has no KV trace: counters stay zero.
        let enc = ModelGraph::encoder(&VitConfig::default(), 1, &PrecisionPlan::paper_sac());
        let ep = sched.plan_decode(&enc, 2, 4, 4, 1 << 30);
        assert_eq!((ep.kv_hits, ep.kv_misses, ep.kv_evictions), (0, 0, 0));
        assert_eq!(ep.kv_hit_rate, 0.0);
    }

    #[test]
    fn prop_energy_positive_and_monotone_in_m() {
        assert_prop("scheduler-monotone", 48, |g| {
            let s = Scheduler::new(&MacroParams::default());
            let k = g.usize(1, 4096);
            let n = g.usize(1, 512);
            let m = g.usize(1, 64);
            let op = OperatingPoint::new(
                g.usize(1, 8) as u32,
                g.usize(1, 8) as u32,
                if g.bool() { CbMode::On } else { CbMode::Off },
            );
            let a = s.plan_linear(&shape(k, n, m), op);
            let b = s.plan_linear(&shape(k, n, m + 1), op);
            if a.energy_pj <= 0.0 || a.latency_ns <= 0.0 {
                return Err(format!("non-positive cost {a:?}"));
            }
            if b.conversions <= a.conversions {
                return Err("conversions must grow with m".into());
            }
            Ok(())
        });
    }
}
