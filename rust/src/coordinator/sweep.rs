//! Accuracy-vs-energy sweep harness: the accuracy tier's measurement
//! loop (`crcim sweep`, `rust/benches/accuracy.rs`, `BENCH_accuracy.json`).
//!
//! The rig runs the workload corpus ([`EvalSet::synthetic`]) through a
//! small noisy encoder once per **vote point** — a per-layer majority-
//! vote assignment carried by [`OperatingPoint::noise`] — and scores
//! every point three ways against the exact zero-noise reference walk
//! ([`ModelExecutor::reference_ints`], which shares the executor's
//! [`super::periphery`] glue):
//!
//! - **accuracy** — fraction of images whose noisy logit argmax matches
//!   the reference argmax (the deterministic stand-in for CIFAR top-1);
//! - **SQNR** — logit-domain `10·log10(Σ ref² / Σ (got − ref)²)` over
//!   the whole corpus;
//! - **energy** — measured conversion energy per inference from the
//!   executor's bank counters, cross-checked against
//!   [`Scheduler::plan_linear`] priced at the same per-layer vote
//!   points (planned == measured by construction: both sides read the
//!   macro parameter set produced by the same
//!   `MacroParams::with_mv` override).
//!
//! Besides the uniform vote grid, the sweep evaluates the **co-design
//! point**: [`codesign_votes`] searches per-layer assignments that are
//! strictly cheaper than uniform paper voting while keeping the modeled
//! comparator noise power (via [`Comparator::effective_sigma_mv`] and
//! the [`super::sac`] circuit↔graph bridge) within the uniform budget.
//! [`pareto_frontier`] then keeps the non-dominated points; sorted by
//! energy the frontier is monotone in (accuracy, SQNR) by construction.

use crate::cim::comparator::Comparator;
use crate::cim::params::{CbMode, MacroParams};
use crate::coordinator::pipeline::{ModelExecutor, PipelineConfig};
use crate::coordinator::sac::kernel_noise_sigma_for_row_tiles;
use crate::coordinator::scheduler::Scheduler;
use crate::util::json::Json;
use crate::util::stats::sum_ordered;
use crate::vit::graph::ModelGraph;
use crate::vit::plan::{OperatingPoint, PrecisionPlan};
use crate::vit::VitConfig;
use crate::workload::corpus::EvalSet;

/// SQNR cap reported when the noisy walk reproduces the reference
/// exactly (zero error power; cannot happen with a nonzero comparator
/// sigma, but the report must stay finite).
const SQNR_CAP_DB: f64 = 99.0;

/// Sweep configuration: corpus size, model geometry and the vote grid.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Corpus images (one activation vector each).
    pub images: usize,
    /// Synthetic-corpus image side (pixels).
    pub image: usize,
    /// Corpus seed.
    pub seed: u64,
    /// Encoder geometry.
    pub cfg: VitConfig,
    /// Uniform vote counts to sweep (each also a co-design move).
    pub grid: Vec<u32>,
    /// Boosted trailing SAR bits at every swept point.
    pub mv_last_bits: u32,
}

impl SweepConfig {
    /// The full sweep: the paper's vote ladder around the 6×3 point.
    pub fn full() -> Self {
        SweepConfig {
            images: 32,
            image: 16,
            seed: 0x5EE9,
            cfg: Self::rig_cfg(),
            // The paper's ladder around 6×3 plus the 8-vote step: the
            // co-design exchange pays an attention-layer cut back with
            // a cheap fc-layer 6→8 raise, which the coarser 6→12 jump
            // alone cannot do profitably at this geometry.
            grid: vec![1, 2, 3, 6, 8, 12],
            mv_last_bits: 3,
        }
    }

    /// CI-sized smoke sweep (`crcim sweep --smoke`, CRCIM_BENCH_FAST).
    pub fn smoke() -> Self {
        let mut c = Self::full();
        c.images = 8;
        c.grid = vec![1, 6, 12];
        c
    }

    /// The rig's encoder geometry: two blocks with `d_ff == dim`, so
    /// the 4b MLP linears stay small enough in conversions that one
    /// fc-layer vote raise can pay the noise bill of an attention-layer
    /// vote cut — the heterogeneity the co-design search trades on.
    fn rig_cfg() -> VitConfig {
        VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 1, num_classes: 4 }
    }
}

/// The noisy measurement rig: the pipeline test geometry (6b ADC,
/// 64×12 array) with every noise source quiet **except** the comparator
/// — the one knob majority voting acts on — so accuracy/SQNR deltas
/// across vote points are attributable to voting alone.
pub fn rig_params() -> MacroParams {
    let mut p = MacroParams::default();
    p.adc_bits = 6;
    p.active_rows = 64;
    p.rows = 64;
    p.cols = 12;
    p.sigma_cu_rel = 0.0;
    p.nonlin_cubic_lsb = 0.0;
    p.sigma_cmp_offset_lsb = 0.0;
    p.temperature_k = 0.0;
    p
}

/// The rig's precision plan: CB on everywhere (votes only act on
/// boosted bits), attention at 2b and MLP at 4b. The asymmetric bit
/// widths split the classes' noise-gain-per-conversion ratio
/// (`Σ4^a·Σ4^b` vs `a·w` scaling), which is what gives the co-design
/// search genuinely different per-layer trade curves.
pub fn rig_plan() -> PrecisionPlan {
    PrecisionPlan {
        name: "sweep rig: attn 2b w/CB, MLP 4b w/CB",
        attention: OperatingPoint::new(2, 2, CbMode::On),
        mlp: OperatingPoint::new(4, 4, CbMode::On),
    }
}

/// Overwrite the graph's per-layer vote points (`votes[i]` applies to
/// `graph.layers[i]`; CB-off layers keep the assignment but it has no
/// behavioral effect — `comparisons_per_conversion(Off)` ignores it).
pub fn set_votes(graph: &mut ModelGraph, votes: &[u32], mv_last_bits: u32) {
    assert_eq!(votes.len(), graph.layers.len(), "one vote count per layer");
    for (l, &v) in graph.layers.iter_mut().zip(votes) {
        l.op = l.op.with_votes(v, mv_last_bits);
    }
}

/// One evaluated vote point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    /// Per-layer vote counts, layer order.
    pub votes: Vec<u32>,
    /// Reference-argmax match rate over the corpus [0, 1].
    pub accuracy: f64,
    /// Logit-domain SQNR vs the exact reference walk [dB].
    pub sqnr_db: f64,
    /// Measured conversion energy per inference [pJ] (bank counters).
    pub energy_pj: f64,
    /// The same energy priced by `Scheduler::plan_linear` [pJ].
    pub planned_energy_pj: f64,
    /// Modeled comparator noise power (the co-design objective).
    pub modeled_noise: f64,
    /// SQNR figure of merit (TOPS/W · 10^(SQNR/20)).
    pub fom: f64,
}

/// Modeled comparator noise power of one layer at `votes`: the
/// per-output kernel sigma from the [`super::sac`] circuit↔graph bridge
/// (row tiles × per-bit gains), squared, times the layer's output
/// count — with the comparator sigma first collapsed through
/// [`Comparator::effective_sigma_mv`]. CB-off layers take the raw
/// sigma (no boosted bits to vote on).
pub fn layer_noise_power(
    params: &MacroParams,
    sched: &Scheduler,
    layer: &crate::vit::graph::GraphLayer,
    votes: u32,
) -> f64 {
    let cmp = Comparator::new(params.sigma_cmp_lsb, 0.0);
    let sigma = match layer.op.cb {
        CbMode::On => cmp.effective_sigma_mv(votes.max(1) as usize),
        CbMode::Off => params.sigma_cmp_lsb,
    };
    let tiles = sched.row_tiles(layer.shape.k) as usize;
    let per_output =
        kernel_noise_sigma_for_row_tiles(tiles, layer.op.a_bits, layer.op.w_bits, sigma);
    layer.shape.n as f64 * per_output * per_output
}

/// Planner-priced conversion energy [pJ] of the whole graph with
/// `vectors` activation vectors per layer (what one sweep pass feeds).
pub fn planned_energy_pj(sched: &Scheduler, graph: &ModelGraph, vectors: usize) -> f64 {
    sum_ordered(graph.layers.iter().map(|l| {
        let mut shape = l.shape;
        shape.m = vectors.max(1);
        sched.plan_linear(&shape, l.op).energy_pj
    }))
}

/// The co-design result: the chosen assignment plus the modeled
/// quantities the selection was made under.
#[derive(Clone, Debug)]
pub struct Codesign {
    /// Per-layer vote counts, layer order.
    pub votes: Vec<u32>,
    /// Planner energy per vector at the chosen assignment [pJ].
    pub energy_pj: f64,
    /// Planner energy per vector at the uniform baseline [pJ].
    pub uniform_energy_pj: f64,
    /// Modeled noise power at the chosen assignment.
    pub noise: f64,
    /// Modeled noise budget (= the uniform baseline's noise power).
    pub budget: f64,
}

/// Greedy exchange search for a per-layer vote assignment strictly
/// cheaper than uniform `baseline` voting at equal-or-better modeled
/// noise power. Starting from the uniform assignment, it repeatedly
/// applies the best feasible one- or two-layer move (cut one layer's
/// votes, optionally raising another layer's to pay the noise back)
/// until no move lowers energy. Energy decreases strictly every step
/// and feasibility (noise ≤ budget) is an invariant, so the result can
/// never be worse than the uniform baseline it starts from.
pub fn codesign_votes(
    params: &MacroParams,
    graph: &ModelGraph,
    grid: &[u32],
    mv_last_bits: u32,
    baseline: u32,
) -> Codesign {
    let sched = Scheduler::with_topology(params, 1, 1);
    let layers = &graph.layers;
    // Per-layer trade tables over the grid (per activation vector).
    let energy_of = |l: &crate::vit::graph::GraphLayer, v: u32| -> f64 {
        let mut shape = l.shape;
        shape.m = 1;
        sched.plan_linear(&shape, l.op.with_votes(v, mv_last_bits)).energy_pj
    };
    let noise_of =
        |l: &crate::vit::graph::GraphLayer, v: u32| layer_noise_power(params, &sched, l, v);
    // Movable layers: voting only acts where the CSNR boost is on.
    let movable: Vec<usize> =
        (0..layers.len()).filter(|&i| layers[i].op.cb == CbMode::On).collect();
    let mut votes = vec![baseline; layers.len()];
    let total_energy = |vs: &[u32]| -> f64 {
        sum_ordered(layers.iter().zip(vs).map(|(l, &v)| energy_of(l, v)))
    };
    let total_noise = |vs: &[u32]| -> f64 {
        sum_ordered(layers.iter().zip(vs).map(|(l, &v)| noise_of(l, v)))
    };
    let budget = total_noise(&votes);
    let uniform_energy_pj = total_energy(&votes);
    let mut energy = uniform_energy_pj;
    let mut noise = budget;
    loop {
        // Best feasible strictly-improving move: change one movable
        // layer's votes, optionally paired with a second layer's
        // change to buy the noise budget back. O((L·G)²) per step on a
        // handful of layers — exact enough to never miss an exchange.
        let mut best: Option<(f64, Vec<(usize, u32)>)> = None;
        let mut consider = |delta: &[(usize, u32)]| {
            let mut vs = votes.clone();
            for &(i, v) in delta {
                vs[i] = v;
            }
            let e = total_energy(&vs);
            let n = total_noise(&vs);
            if n <= budget + 1e-9 && e + 1e-9 < energy {
                let gain = energy - e;
                if best.as_ref().map(|(g, _)| gain > *g).unwrap_or(true) {
                    best = Some((gain, delta.to_vec()));
                }
            }
        };
        for &i in &movable {
            for &vi in grid {
                if vi == votes[i] {
                    continue;
                }
                consider(&[(i, vi)]);
                for &j in &movable {
                    if j == i {
                        continue;
                    }
                    for &vj in grid {
                        if vj == votes[j] {
                            continue;
                        }
                        consider(&[(i, vi), (j, vj)]);
                    }
                }
            }
        }
        match best {
            Some((_, delta)) => {
                for (i, v) in delta {
                    votes[i] = v;
                }
                energy = total_energy(&votes);
                noise = total_noise(&votes);
            }
            None => break,
        }
    }
    Codesign { votes, energy_pj: energy, uniform_energy_pj, noise, budget }
}

/// Evaluate one vote assignment on the corpus: fresh executor, one
/// forward wave of every image, scored against the shared zero-noise
/// reference logits.
fn eval_point(
    label: &str,
    params: &MacroParams,
    base: &ModelGraph,
    votes: &[u32],
    mv_last_bits: u32,
    xs: &[Vec<i32>],
    refs: &[Vec<i64>],
) -> Result<SweepPoint, String> {
    let mut graph = base.clone();
    set_votes(&mut graph, votes, mv_last_bits);
    let sched = Scheduler::with_topology(params, 1, 1);
    let planned = planned_energy_pj(&sched, &graph, xs.len());
    let modeled_noise = sum_ordered(
        graph.layers.iter().zip(votes).map(|(l, &v)| layer_noise_power(params, &sched, l, v)),
    );
    let mut exec = ModelExecutor::new(params, graph, PipelineConfig::default())?;
    let got = exec.forward_ints(xs)?;
    let costs = exec.layer_costs();
    let energy_total = sum_ordered(costs.iter().map(|c| c.energy_pj));
    let mut matches = 0usize;
    let mut sig = 0.0f64;
    let mut err = 0.0f64;
    for (g, r) in got.iter().zip(refs) {
        if argmax(g) == argmax(r) {
            matches += 1;
        }
        sig += sum_ordered(r.iter().map(|&v| (v as f64) * (v as f64)));
        err += sum_ordered(g.iter().zip(r).map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        }));
    }
    let sqnr_db =
        if err > 0.0 { (10.0 * (sig / err).log10()).min(SQNR_CAP_DB) } else { SQNR_CAP_DB };
    // 1b-normalized efficiency of this point feeds the paper's SQNR FoM.
    let ops_1b = sum_ordered(exec.graph.layers.iter().map(|l| {
        let mut shape = l.shape;
        shape.m = xs.len().max(1);
        sched.plan_linear(&shape, l.op).ops_1b
    }));
    let tops_per_watt = ops_1b / energy_total.max(1e-12);
    Ok(SweepPoint {
        label: label.to_string(),
        votes: votes.to_vec(),
        accuracy: matches as f64 / xs.len().max(1) as f64,
        sqnr_db,
        energy_pj: energy_total / xs.len().max(1) as f64,
        planned_energy_pj: planned / xs.len().max(1) as f64,
        modeled_noise,
        fom: crate::metrics::fom::sqnr_fom(tops_per_watt, sqnr_db),
    })
}

fn argmax(v: &[i64]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// Non-dominated subset, sorted by energy ascending. Quality is the
/// lexicographic pair (accuracy, SQNR): point `p` dominates `q` when
/// `p` is no more expensive and lexicographically no worse, with at
/// least one strict inequality. Sorting survivors by energy therefore
/// yields a frontier whose quality is strictly increasing — the
/// monotone accuracy-vs-energy curve the report publishes.
pub fn pareto_frontier(points: &[SweepPoint]) -> Vec<SweepPoint> {
    let quality_ge = |a: &SweepPoint, b: &SweepPoint| {
        a.accuracy > b.accuracy || (a.accuracy == b.accuracy && a.sqnr_db >= b.sqnr_db)
    };
    let mut keep: Vec<SweepPoint> = Vec::new();
    for (i, p) in points.iter().enumerate() {
        let dominated = points.iter().enumerate().any(|(j, q)| {
            if j == i {
                return false;
            }
            // Exact triple ties break by index so exactly one survives.
            let tie = q.energy_pj == p.energy_pj
                && q.accuracy == p.accuracy
                && q.sqnr_db == p.sqnr_db;
            q.energy_pj <= p.energy_pj && quality_ge(q, p) && (!tie || j < i)
        });
        if !dominated {
            keep.push(p.clone());
        }
    }
    keep.sort_by(|a, b| a.energy_pj.partial_cmp(&b.energy_pj).unwrap());
    keep
}

/// The whole sweep: grid points + the co-design point, frontier, JSON.
pub struct SweepReport {
    pub points: Vec<SweepPoint>,
    pub pareto: Vec<SweepPoint>,
    pub codesign: Codesign,
    pub json: Json,
}

/// Run the sweep end to end (the `crcim sweep` / bench entry point).
pub fn run_sweep(cfg: &SweepConfig) -> Result<SweepReport, String> {
    let params = rig_params();
    let plan = rig_plan();
    let base = ModelGraph::encoder(&cfg.cfg, 1, &plan);
    let set = EvalSet::synthetic(cfg.images, cfg.image, cfg.seed);
    let images: Vec<Vec<f32>> =
        (0..set.n).map(|i| set.image_slice(i).to_vec()).collect();
    // Featurization and the zero-noise reference are vote-independent:
    // compute both once, against the baseline graph.
    let probe = ModelExecutor::new(&params, base.clone(), PipelineConfig::default())?;
    let xs = probe.featurize_images(&images);
    let refs = probe.reference_ints(&xs);
    let layer_count = base.layers.len();
    let mut points = Vec::new();
    for &v in &cfg.grid {
        let votes = vec![v; layer_count];
        points.push(eval_point(
            &format!("uniform-{v}"),
            &params,
            &base,
            &votes,
            cfg.mv_last_bits,
            &xs,
            &refs,
        )?);
    }
    let codesign = codesign_votes(&params, &base, &cfg.grid, cfg.mv_last_bits, 6);
    points.push(eval_point(
        "codesign",
        &params,
        &base,
        &codesign.votes,
        cfg.mv_last_bits,
        &xs,
        &refs,
    )?);
    let pareto = pareto_frontier(&points);
    let json = report_json(cfg, &params, &points, &pareto, &codesign);
    Ok(SweepReport { points, pareto, codesign, json })
}

fn point_json(p: &SweepPoint) -> Json {
    let mut o = Json::obj();
    o.set("label", Json::str(p.label.clone()));
    o.set("votes", Json::arr(p.votes.iter().map(|&v| Json::num(v as f64))));
    o.set("accuracy", Json::num(p.accuracy));
    o.set("sqnr_db", Json::num(p.sqnr_db));
    o.set("energy_pj_per_inference", Json::num(p.energy_pj));
    o.set("planned_energy_pj_per_inference", Json::num(p.planned_energy_pj));
    let rel = (p.energy_pj - p.planned_energy_pj).abs() / p.planned_energy_pj.max(1e-12);
    o.set("planned_rel_err", Json::num(rel));
    o.set("modeled_noise", Json::num(p.modeled_noise));
    o.set("sqnr_fom", Json::num(p.fom));
    Json::Obj(o)
}

fn report_json(
    cfg: &SweepConfig,
    params: &MacroParams,
    points: &[SweepPoint],
    pareto: &[SweepPoint],
    codesign: &Codesign,
) -> Json {
    let mut root = Json::obj();
    root.set("title", Json::str("accuracy-vs-energy vote sweep"));
    root.set("model", Json::str(rig_plan().name));
    root.set("images", Json::num(cfg.images as f64));
    root.set("layers", Json::num(4.0 * cfg.cfg.depth as f64));
    root.set("sigma_cmp_lsb", Json::num(params.sigma_cmp_lsb));
    root.set("mv_last_bits", Json::num(cfg.mv_last_bits as f64));
    root.set("vote_grid", Json::arr(cfg.grid.iter().map(|&v| Json::num(v as f64))));
    root.set("points", Json::arr(points.iter().map(point_json)));
    root.set("pareto_points", Json::arr(pareto.iter().map(point_json)));
    // Scalar mirror of pareto_points.len() so the grep-based schema
    // guard (scripts/check_bench_schema.sh) can assert frontier size
    // without parsing nested JSON.
    root.set("pareto_count", Json::num(pareto.len() as f64));
    let mut cd = Json::obj();
    cd.set("votes", Json::arr(codesign.votes.iter().map(|&v| Json::num(v as f64))));
    cd.set("energy_pj_per_vector", Json::num(codesign.energy_pj));
    cd.set("uniform6_energy_pj_per_vector", Json::num(codesign.uniform_energy_pj));
    cd.set(
        "energy_vs_uniform6",
        Json::num(codesign.energy_pj / codesign.uniform_energy_pj.max(1e-12)),
    );
    cd.set("modeled_noise", Json::num(codesign.noise));
    cd.set("noise_budget", Json::num(codesign.budget));
    root.set("codesign", Json::Obj(cd));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_sweep() -> SweepConfig {
        let mut c = SweepConfig::smoke();
        c.images = 4;
        c
    }

    #[test]
    fn codesign_is_strictly_cheaper_than_uniform_six_within_budget() {
        let params = rig_params();
        let graph = ModelGraph::encoder(&SweepConfig::full().cfg, 1, &rig_plan());
        let cd = codesign_votes(&params, &graph, &[1, 2, 3, 6, 8, 12], 3, 6);
        assert!(
            cd.energy_pj < cd.uniform_energy_pj - 1e-9,
            "co-design must beat uniform-6: {} vs {}",
            cd.energy_pj,
            cd.uniform_energy_pj
        );
        assert!(cd.noise <= cd.budget + 1e-9, "noise {} over budget {}", cd.noise, cd.budget);
        assert!(cd.votes.iter().any(|&v| v != 6), "assignment must be non-uniform");
        assert_eq!(cd.votes.len(), graph.layers.len());
    }

    #[test]
    fn codesign_search_is_deterministic() {
        let params = rig_params();
        let graph = ModelGraph::encoder(&SweepConfig::full().cfg, 1, &rig_plan());
        let a = codesign_votes(&params, &graph, &[1, 2, 3, 6, 8, 12], 3, 6);
        let b = codesign_votes(&params, &graph, &[1, 2, 3, 6, 8, 12], 3, 6);
        assert_eq!(a.votes, b.votes);
        assert_eq!(a.energy_pj, b.energy_pj);
    }

    #[test]
    fn pareto_frontier_is_monotone_and_nondominated() {
        let mk = |e: f64, acc: f64, s: f64| SweepPoint {
            label: String::new(),
            votes: vec![],
            accuracy: acc,
            sqnr_db: s,
            energy_pj: e,
            planned_energy_pj: e,
            modeled_noise: 0.0,
            fom: 0.0,
        };
        let pts = vec![
            mk(1.0, 0.5, 10.0),
            mk(2.0, 0.5, 9.0),  // dominated: dearer, worse sqnr
            mk(3.0, 0.7, 12.0),
            mk(2.5, 0.7, 12.0), // dominates the 3.0 twin
            mk(4.0, 0.9, 8.0),  // frontier: best accuracy
        ];
        let front = pareto_frontier(&pts);
        let labels: Vec<f64> = front.iter().map(|p| p.energy_pj).collect();
        assert_eq!(labels, vec![1.0, 2.5, 4.0]);
        for w in front.windows(2) {
            assert!(w[1].energy_pj > w[0].energy_pj);
            assert!(
                w[1].accuracy > w[0].accuracy
                    || (w[1].accuracy == w[0].accuracy && w[1].sqnr_db > w[0].sqnr_db)
            );
        }
    }

    #[test]
    fn sweep_runs_and_prices_planned_equal_to_measured() {
        let report = run_sweep(&tiny_sweep()).unwrap();
        assert!(report.pareto.len() >= 2, "expected >= 2 frontier points");
        for p in &report.points {
            let rel = (p.energy_pj - p.planned_energy_pj).abs() / p.planned_energy_pj;
            assert!(
                rel < 1e-9,
                "{}: measured {} != planned {}",
                p.label,
                p.energy_pj,
                p.planned_energy_pj
            );
            assert!((0.0..=1.0).contains(&p.accuracy));
            assert!(p.sqnr_db.is_finite());
        }
        // The report carries the schema-checked keys.
        for key in ["points", "pareto_points", "codesign", "vote_grid", "images"] {
            assert!(report.json.get_path(key).is_some(), "missing report key {key}");
        }
        assert!(
            report.json.get_path("codesign.energy_vs_uniform6").and_then(|v| v.as_f64()).unwrap()
                < 1.0
        );
    }

    #[test]
    fn more_votes_never_increase_modeled_noise() {
        let params = rig_params();
        let graph = ModelGraph::encoder(&SweepConfig::full().cfg, 1, &rig_plan());
        let sched = Scheduler::with_topology(&params, 1, 1);
        for l in &graph.layers {
            let mut last = f64::INFINITY;
            for &v in &[1u32, 2, 3, 6, 8, 12] {
                let n = layer_noise_power(&params, &sched, l, v);
                assert!(n <= last + 1e-12, "{}: noise grew {last} -> {n} at v={v}", l.name());
                last = n;
            }
        }
    }
}
