//! Request server: a std-TCP, line-delimited-JSON inference service
//! (tokio is not in the vendored crate set; nonblocking sockets + a
//! readiness poll loop).
//!
//! Protocol (one JSON object per line; the full wire contract — every
//! request kind, response schema, `stats` field and error string — is
//! documented in `docs/SERVING.md`):
//!   → {"id": 1, "image": [3072 floats]}
//!   ← {"id": 1, "pred": 7, "logits": [...], "queue_us": ..., "batch": 16}
//!   → {"id": 2, "kind": "forward", "image": [...]}
//!   ← {"id": 2, "pred": ..., "logits": [...], "layers": 48, ...}
//!   → {"id": 3, "kind": "stream", "tokens": 4, "image": [...]}
//!   ← {"id": 3, "pred": ..., "logits": [...], "tokens": 4, "waves": 2, ...}
//!   → {"id": 4, "kind": "stream", "tokens": 4, "push": true, "image": [...]}
//!   ← {"id": 4, "event": "tokens", "done": 2, "tokens": 4}   (per wave)
//!   ← {"id": 4, "pred": ..., "logits": [...], ...}           (final)
//!   → {"cmd": "stats"}   ← the ledger report (incl. per-layer breakdown
//!                          and streaming fields when applicable)
//!   → {"cmd": "shutdown"}   ← {"ok": true}; begins a graceful drain
//!
//! The `"forward"` kind runs a whole encoder pass through a model-graph
//! executor (`coordinator::pipeline::ModelExecutor`); the default kind
//! classifies through the executor's single-layer path. The `"stream"`
//! kind admits the request to the token-level continuous-batching tier
//! (`coordinator::stream`): its image splits into per-token patch
//! chunks that coalesce with other requests' tokens into macro
//! conversion waves, complete out of order, and reassemble per request.
//!
//! Architecture — the event-driven connection tier: a single **reactor**
//! thread ([`super::reactor`]) owns the nonblocking listener and every
//! connection (buffered partial-line reads, write-queue flushing — no
//! per-connection threads, no sleep-polling). It parses request lines and
//! pushes classify/forward requests into a shared queue and stream
//! requests into the token stream, gated by **bounded admission**
//! (`max_inflight` concurrency permits + `queue_depth` bounds; over
//! either limit the request is answered with a documented load-shed
//! error instead of queueing unboundedly). A single **executor** loop
//! (on the thread that called [`Server::serve`] — PJRT executables are
//! not `Send`) forms batches (Batcher policy) and conversion waves
//! (TokenStream policy), runs the PJRT executable or the macro-simulator
//! pipeline, accounts costs in the Ledger, and stages responses in
//! per-connection outboxes the reactor flushes. Idle waits on both
//! threads are condvar wakeups with a bounded poll timeout, never sleep
//! loops. `{"cmd": "shutdown"}` starts a **graceful drain**: accepting
//! stops, new inference requests shed, in-flight waves finish (partial
//! batches close immediately), outboxes flush, then the server stops.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batch, Batcher, Request};
use crate::coordinator::decode::{GenStats, GenStep};
use crate::coordinator::ledger::{LayerCost, Ledger, ResidencyStats};
use crate::coordinator::sac::PlanCost;
use crate::coordinator::stream::{StreamConfig, TokenStream};
use crate::util::json::{self, Json};

/// What a request asks the executor to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Single-layer classification (the default; every executor).
    Classify,
    /// Whole model-graph forward pass (graph executors only).
    Forward,
    /// Token-level streaming forward pass: the request is admitted to
    /// the continuous-batching tier (`coordinator::stream`) instead of
    /// the fixed-batch queue, so this kind never appears in `pending`.
    Stream,
}

/// A parsed inference request payload.
#[derive(Clone, Debug)]
pub struct InferencePayload {
    pub image: Vec<f32>,
    pub conn_id: u64,
    /// The client's `"id"`, echoed back verbatim. `None` = the request
    /// carried no id, echoed as JSON `null` so clients can tell an
    /// absent id from a literal `0` (a non-numeric id is rejected
    /// outright at parse time).
    pub client_req_id: Option<f64>,
    pub kind: RequestKind,
}

/// Response sender side: per-connection outbox.
type Outbox = Arc<Mutex<BTreeMap<u64, Vec<String>>>>;

/// The batch executor abstraction (so tests can run without PJRT).
/// Deliberately NOT `Send`: PJRT executables are single-threaded, so the
/// executor loop runs on the thread that calls `serve` while the acceptor
/// and connection handlers run on spawned threads.
pub trait BatchExecutor {
    /// Execute `images` (n × image_floats) and return per-request logits.
    fn execute(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String>;
    /// Run a full model-graph forward pass (the `"kind": "forward"`
    /// request path). Default: single-layer executors don't support it.
    fn forward(&mut self, _images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        Err("this executor does not serve model-graph forward passes".to_string())
    }
    /// Run several forward batches (one per conversion wave), returning
    /// one result per batch in order. The default runs them serially;
    /// the pipelined model-graph executor overlaps the waves' die
    /// programming and conversion stages while keeping every batch's
    /// outputs bit-identical to a serial run.
    fn forward_many(&mut self, batches: &[Vec<Vec<f32>>]) -> Vec<Result<Vec<Vec<f32>>, String>> {
        batches.iter().map(|b| self.forward(b)).collect()
    }
    /// Layers in the executor's model graph (0 = not a graph executor).
    fn graph_layers(&self) -> usize {
        0
    }
    /// Cumulative per-layer accounting (empty = not a graph executor).
    /// The server refreshes the ledger's breakdown from this after every
    /// executed batch.
    fn layer_breakdown(&self) -> Vec<LayerCost> {
        Vec::new()
    }
    /// Resident-weight cache counters (`None` = this executor keeps no
    /// weights resident between passes). The server refreshes the
    /// ledger's snapshot from this after every executed batch.
    fn residency(&self) -> Option<ResidencyStats> {
        None
    }
    /// Run generation waves (the `"kind": "generate"` request path).
    /// Each wave is a list of token steps — prefill positions and decode
    /// feedbacks from many live sequences coalesced padding-free — and
    /// yields one logits row per step in wave order. Default: only
    /// graph executors hold die-resident KV state.
    fn decode_many(&mut self, waves: &[Vec<GenStep>]) -> Vec<Result<Vec<Vec<f32>>, String>> {
        waves
            .iter()
            .map(|_| Err("this executor does not serve autoregressive generation".to_string()))
            .collect()
    }
    /// Drop a finished (or failed) sequence's die-resident KV state so
    /// the capacity budget frees up for newly admitted sequences.
    fn release_seq(&mut self, _seq: u64) {}
    /// KV-cache counters (`None` = this executor keeps no KV state).
    /// The server folds these into the ledger's generation snapshot.
    fn gen_stats(&self) -> Option<GenStats> {
        None
    }
    /// Modeled per-inference macro cost for accounting.
    fn cost(&self) -> &PlanCost;
    fn num_classes(&self) -> usize;
}

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    pub batch_sizes: Vec<usize>,
    pub max_wait: Duration,
    /// Tokens coalesced into one streaming conversion wave (`"kind":
    /// "stream"` requests); the wave closes early on `max_wait` like a
    /// fixed batch. Must be ≥ 1.
    pub wave_tokens: usize,
    /// Streaming conversion waves the executor keeps in flight per
    /// step (≥ 1). Waves are *formed* under one stream-lock session and
    /// *completed in formation order*, so serving semantics match a
    /// one-wave server; a pipelined executor overlaps the in-flight
    /// waves' die programming and conversions for wall-clock speedup.
    pub max_waves: usize,
    /// Admission: inference requests allowed in flight at once (queued
    /// or executing, across both tiers). Request `max_inflight + 1`
    /// sheds with the documented overload error. Must be ≥ 1.
    pub max_inflight: usize,
    /// Admission: upper bound on queued work per tier — pending
    /// requests in the fixed-batch queue, and queued-plus-in-flight
    /// tokens in the streaming tier. Over the bound the request sheds
    /// with the documented queue-full error. Must be ≥ 1.
    pub queue_depth: usize,
    /// Graceful-drain bound: after `{"cmd": "shutdown"}` the server
    /// finishes in-flight work for at most this long, then force-stops
    /// (outboxes still flush). Must be nonzero.
    pub drain_timeout: Duration,
}

impl ServerConfig {
    /// Check the wave/admission knobs the CLI exposes (`--max-waves`,
    /// `--max-inflight`, `--queue-depth`, `--drain-timeout-ms`): zero is
    /// a config error, reported before any artifact loads or sockets
    /// bind. [`Server::new`] calls this, so programmatic construction
    /// gets the same checks. (Batch sizes and wave size are validated by
    /// the `Batcher`/`TokenStream` constructors.)
    pub fn validate(&self) -> Result<(), String> {
        if self.max_waves == 0 {
            return Err("max_waves must be at least 1".to_string());
        }
        if self.max_inflight == 0 {
            return Err("max_inflight must be at least 1".to_string());
        }
        if self.queue_depth == 0 {
            return Err("queue_depth must be at least 1".to_string());
        }
        if self.drain_timeout.is_zero() {
            return Err("drain_timeout must be nonzero".to_string());
        }
        Ok(())
    }
}

impl Default for ServerConfig {
    /// Paper-benchmark defaults; every field can be overridden with
    /// struct-update syntax (`..Default::default()`).
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".to_string(),
            batch_sizes: vec![1, 16],
            max_wait: Duration::from_millis(2),
            wave_tokens: 16,
            max_waves: 2,
            max_inflight: 256,
            queue_depth: 1024,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

/// Lifecycle states for the drain machine ([`Server::state`]).
const STATE_RUNNING: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_STOPPED: u8 = 2;

/// Documented load-shed error strings (`docs/SERVING.md` quotes these
/// verbatim; changing one is a wire-contract change).
pub const SHED_DRAINING: &str = "server draining: not accepting new requests";
pub const SHED_INFLIGHT: &str = "server overloaded: too many requests in flight";
pub const SHED_QUEUE_FULL: &str = "server overloaded: request queue is full";

/// A condvar-backed wakeup: waiters park with a bounded timeout and
/// are woken as soon as work (or a state change) arrives, replacing
/// the old sleep-poll loops. The flag is sticky until consumed by a
/// wait, so a notify that races ahead of the wait is never lost.
struct Notify {
    signal: Mutex<bool>,
    cv: Condvar,
}

impl Notify {
    fn new() -> Self {
        Notify { signal: Mutex::new(false), cv: Condvar::new() }
    }

    /// Wake every current waiter and mark the signal for the next one.
    fn notify(&self) {
        let mut signal = self.signal.lock().unwrap();
        *signal = true;
        self.cv.notify_all();
    }

    /// Park until notified or `timeout`, whichever first; consumes the
    /// pending signal (if any) so the next wait parks again.
    fn wait_timeout(&self, timeout: Duration) {
        let mut signal = self.signal.lock().unwrap();
        if !*signal {
            let (guard, _) = self.cv.wait_timeout(signal, timeout).unwrap();
            signal = guard;
        }
        *signal = false;
    }
}

/// Shared server state.
pub struct Server {
    /// FIFO request queue. A `VecDeque` so forming a batch pops from the
    /// front in O(batch) — draining the front of a `Vec` memmoved the
    /// entire remaining queue on every batch of the serve hot path.
    pending: Arc<Mutex<VecDeque<Request<InferencePayload>>>>,
    outbox: Outbox,
    ledger: Arc<Mutex<Ledger>>,
    /// Lifecycle: `STATE_RUNNING` → (`{"cmd": "shutdown"}`)
    /// `STATE_DRAINING` → (in-flight work finishes, or the drain
    /// timeout fires) `STATE_STOPPED`. Draining sheds new inference
    /// requests but keeps serving staged responses and control
    /// commands until the queues run dry.
    state: AtomicU8,
    /// Admission permits currently held: one per in-flight inference
    /// request (queued or executing, both tiers). Compared against
    /// `max_inflight` at admission; released when the request's
    /// response is staged or its connection is purged.
    inflight: AtomicUsize,
    /// Concurrency bound for `inflight` (≥ 1).
    max_inflight: usize,
    /// Queued-work bound per tier (≥ 1); see [`ServerConfig::queue_depth`].
    queue_depth: usize,
    /// Upper bound on the graceful-drain phase.
    drain_timeout: Duration,
    /// Wakes the executor loop when work arrives or state changes.
    exec_notify: Notify,
    /// Wakes the reactor when responses are staged or state changes.
    io_notify: Notify,
    /// Connection ids (outbox keys). Separate from `next_req`: sharing one
    /// counter let request ids collide with another connection's id range.
    next_conn: AtomicU64,
    /// Internal queue-order request ids.
    next_req: AtomicU64,
    /// Open connections. The executor only stages responses for live
    /// connections, so a client that disconnects with requests in flight
    /// cannot leak outbox entries (the old leak's remaining race).
    live_conns: Mutex<BTreeSet<u64>>,
    batcher: Batcher,
    /// The token-level streaming tier: per-token admission queue,
    /// conversion-wave formation and out-of-order reassembly. Connection
    /// threads enqueue under this lock; the executor loop forms and
    /// completes waves.
    stream: Mutex<TokenStream>,
    /// Conversion waves kept in flight per executor step (≥ 1).
    max_waves: usize,
}

impl Server {
    /// Build a server; fails on an invalid batching or admission config
    /// (empty or zero batch sizes, zero wave size, zero wave
    /// concurrency, zero admission bounds, zero drain timeout) instead
    /// of panicking the serving thread later.
    pub fn new(cfg: &ServerConfig) -> Result<Self, String> {
        cfg.validate()?;
        Ok(Server {
            pending: Arc::new(Mutex::new(VecDeque::new())),
            outbox: Arc::new(Mutex::new(BTreeMap::new())),
            ledger: Arc::new(Mutex::new(Ledger::new())),
            state: AtomicU8::new(STATE_RUNNING),
            inflight: AtomicUsize::new(0),
            max_inflight: cfg.max_inflight,
            queue_depth: cfg.queue_depth,
            drain_timeout: cfg.drain_timeout,
            exec_notify: Notify::new(),
            io_notify: Notify::new(),
            next_conn: AtomicU64::new(1),
            next_req: AtomicU64::new(1),
            live_conns: Mutex::new(BTreeSet::new()),
            batcher: Batcher::new(cfg.batch_sizes.clone(), cfg.max_wait)?,
            stream: Mutex::new(TokenStream::new(&StreamConfig {
                wave_tokens: cfg.wave_tokens,
                max_wait: cfg.max_wait,
            })?),
            max_waves: cfg.max_waves,
        })
    }

    /// Register a new connection and return its id. Responses are only
    /// staged for open connections; close with [`close_conn`](Self::close_conn).
    pub fn open_conn(&self) -> u64 {
        let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        self.live_conns.lock().unwrap().insert(id);
        id
    }

    /// Close a connection: stop staging its responses, drop anything
    /// already staged, and purge its queued (unserved) requests — from
    /// the fixed-batch queue and the token stream alike. Lock order
    /// matches `executor_step` (live before outbox) so the two cannot
    /// interleave into a leaked entry.
    pub fn close_conn(&self, conn_id: u64) {
        {
            let mut live = self.live_conns.lock().unwrap();
            live.remove(&conn_id);
            let mut outbox = self.outbox.lock().unwrap();
            outbox.remove(&conn_id);
        }
        let mut purged = 0usize;
        {
            let mut pending = self.pending.lock().unwrap();
            pending.retain(|r| {
                let keep = r.payload.conn_id != conn_id;
                if !keep {
                    purged += 1;
                }
                keep
            });
        }
        purged += self.stream.lock().unwrap().purge_conn(conn_id);
        // Purged requests will never stage a response, so their
        // admission permits return here.
        self.release_permits(purged);
    }

    pub fn ledger_json(&self) -> Json {
        self.refresh_admission();
        self.ledger.lock().unwrap().to_json()
    }

    /// The server has fully stopped (drain finished or timed out).
    pub fn is_shutdown(&self) -> bool {
        self.state.load(Ordering::SeqCst) == STATE_STOPPED
    }

    /// The server is draining: no longer accepting connections or new
    /// inference requests, still finishing in-flight work.
    pub fn is_draining(&self) -> bool {
        self.state.load(Ordering::SeqCst) == STATE_DRAINING
    }

    /// Begin a graceful drain (idempotent; a no-op once stopped).
    /// Accepting stops, new inference requests shed, in-flight waves
    /// finish, then the executor transitions to stopped.
    pub fn begin_drain(&self) {
        let _ = self.state.compare_exchange(
            STATE_RUNNING,
            STATE_DRAINING,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
        self.exec_notify.notify();
        self.io_notify.notify();
    }

    /// Force the stopped state (drain finished or timed out).
    fn force_stop(&self) {
        self.state.store(STATE_STOPPED, Ordering::SeqCst);
        self.exec_notify.notify();
        self.io_notify.notify();
    }

    /// Try to take one admission permit; `false` means the concurrency
    /// bound is reached and the request must shed.
    fn try_acquire_permit(&self) -> bool {
        self.inflight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n < self.max_inflight {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// Return `n` admission permits (saturating: a test that enqueues
    /// through the public API and purges twice must not underflow).
    fn release_permits(&self, n: usize) {
        if n == 0 {
            return;
        }
        let _ = self
            .inflight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |cur| Some(cur.saturating_sub(n)));
    }

    /// Block the reactor until responses are staged (or `timeout`).
    pub(crate) fn io_wait(&self, timeout: Duration) {
        self.io_notify.wait_timeout(timeout);
    }

    /// Enqueue a request (used by the connection tier and by tests).
    /// Takes an admission permit unconditionally — callers wanting
    /// bounded admission go through `handle_line`, which sheds *before*
    /// enqueueing — so release accounting stays uniform. Responses are
    /// staged only while `payload.conn_id` is a live connection (see
    /// [`open_conn`](Self::open_conn)).
    pub fn enqueue(&self, payload: InferencePayload) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.enqueue_admitted(payload);
    }

    /// Enqueue a request whose admission permit is already held
    /// (`handle_line` acquires before the queue-depth check).
    fn enqueue_admitted(&self, payload: InferencePayload) {
        let id = self.next_req.fetch_add(1, Ordering::Relaxed);
        self.pending.lock().unwrap().push_back(Request {
            id,
            payload,
            arrived: Instant::now(),
        });
        self.exec_notify.notify();
    }

    /// One executor step: form a fixed batch if policy allows, execute,
    /// account and stage responses; then form up to `max_waves`
    /// streaming token waves and do the same through the streaming
    /// tier (completions land in wave order). A formed
    /// batch can mix request kinds; each kind runs as its own sub-batch
    /// through the matching executor entry point (`execute` vs
    /// `forward`; `stream` requests never enter the batch queue).
    /// Returns the number of requests served — batch requests plus
    /// stream requests whose last token completed this step.
    pub fn executor_step(&self, exec: &mut dyn BatchExecutor) -> usize {
        self.step(exec).0
    }

    /// [`executor_step`](Self::executor_step) plus whether any work ran
    /// (a batch formed or a wave executed). The serve loop idles on the
    /// flag, not the served count: a conversion wave that completes no
    /// *request* (all its tokens belong to still-unfinished requests)
    /// is real work, and sleeping after it would throttle back-to-back
    /// waves of a multi-token backlog.
    fn step(&self, exec: &mut dyn BatchExecutor) -> (usize, bool) {
        // During a drain, partial batches and waves must close *now*
        // rather than wait out `max_wait` — advance the policy clock
        // past every deadline. The horizon changes only *when* work is
        // released, never its composition or order, so drained output
        // is bit-identical to what a longer-lived server would produce.
        let draining = self.is_draining();
        let horizon = if draining {
            Instant::now() + self.batcher.max_wait
        } else {
            Instant::now()
        };
        let batch = {
            let mut pending = self.pending.lock().unwrap();
            self.batcher.form_batch(&mut pending, horizon)
        };
        let mut served = 0usize;
        let batch_ran = batch.is_some();
        if let Some(batch) = batch {
            served += batch.requests.len();
            self.run_batch(exec, &batch);
        }
        // Streaming tier: up to `max_waves` conversion waves per step
        // (executed together so a pipelined executor can overlap them),
        // so batch and stream traffic interleave fairly on the executor
        // thread.
        let (completed, wave_ran) = self.stream_step(exec, horizon);
        served += completed;
        if draining {
            // Drain completes when both tiers are empty; everything
            // already staged flushes in the reactor before it exits.
            let pending_empty = self.pending.lock().unwrap().is_empty();
            let stream_empty = {
                let stream = self.stream.lock().unwrap();
                stream.queued_tokens() == 0 && stream.tokens_in_flight() == 0
            };
            if pending_empty && stream_empty {
                self.force_stop();
            }
        }
        if batch_ran || wave_ran {
            // Graph executors keep cumulative per-layer counters; refresh
            // the ledger's breakdown + residency + streaming snapshots
            // after the work.
            let layers = exec.layer_breakdown();
            let residency = exec.residency();
            if !layers.is_empty() || residency.is_some() {
                let mut ledger = self.ledger.lock().unwrap();
                if !layers.is_empty() {
                    ledger.set_layer_breakdown(layers);
                }
                if let Some(r) = residency {
                    ledger.set_residency(r);
                }
            }
            self.refresh_stream_stats();
            self.refresh_gen_stats(&*exec);
            self.refresh_admission();
        }
        (served, batch_ran || wave_ran)
    }

    /// Push the admission gauges (permits held, queued work) into the
    /// ledger. The two queue locks are taken one after the other, never
    /// simultaneously, so this respects the server's lock order.
    fn refresh_admission(&self) {
        let inflight = self.inflight.load(Ordering::SeqCst);
        let queued_batch = self.pending.lock().unwrap().len();
        let queued_tokens = self.stream.lock().unwrap().queued_tokens();
        self.ledger.lock().unwrap().set_admission(crate::coordinator::ledger::AdmissionSnapshot {
            inflight_permits: inflight as u64,
            max_inflight: self.max_inflight as u64,
            queued_work: (queued_batch + queued_tokens) as u64,
            queue_depth_limit: self.queue_depth as u64,
        });
    }

    /// Push the streaming tier's current snapshot into the ledger.
    /// Gated on *ever admitted* (not on the snapshot's own liveness):
    /// a purge back to all-zero counters must overwrite a previously
    /// stored snapshot instead of freezing stale tokens-in-flight, and
    /// a server that never saw a stream request keeps the `stream_*`
    /// fields out of its stats report entirely.
    fn refresh_stream_stats(&self) {
        let (snap, touched) = {
            let stream = self.stream.lock().unwrap();
            (stream.snapshot(), stream.ever_admitted())
        };
        if touched {
            self.ledger.lock().unwrap().set_stream(snap);
        }
    }

    /// Push the generation gauges (live sequences, KV hit/eviction
    /// counters, phase token totals, inter-token latency) into the
    /// ledger, folding the executor's KV counters into the stream
    /// tier's serving-side view. Gated on *ever admitted* like the
    /// streaming snapshot, and refreshed after every executed step so a
    /// stats probe (which has no executor access) reads current gauges.
    fn refresh_gen_stats(&self, exec: &dyn BatchExecutor) {
        let kv = exec.gen_stats().unwrap_or_default();
        let (snap, touched) = {
            let stream = self.stream.lock().unwrap();
            (stream.gen_snapshot(&kv), stream.gen_ever_admitted())
        };
        if touched {
            self.ledger.lock().unwrap().set_generation(snap);
        }
    }

    /// Execute one formed fixed batch: per-kind sub-batches, ledger
    /// accounting, response staging.
    fn run_batch(&self, exec: &mut dyn BatchExecutor, batch: &Batch<InferencePayload>) {
        // Queue time ends when the batch is formed, for every request in
        // it — measuring per sub-batch would charge the second kind for
        // the first kind's execution time.
        let formed_at = Instant::now();
        // handle_line never enqueues Stream payloads here (they go to
        // the token stream), but the public `enqueue` API can; such a
        // request degrades to a whole-image forward pass rather than
        // being silently dropped while counted as served.
        for kind in [RequestKind::Classify, RequestKind::Forward, RequestKind::Stream] {
            let reqs: Vec<&Request<InferencePayload>> =
                batch.requests.iter().filter(|r| r.payload.kind == kind).collect();
            if reqs.is_empty() {
                continue;
            }
            let images: Vec<Vec<f32>> = reqs.iter().map(|r| r.payload.image.clone()).collect();
            let exec_size = self.batcher.exec_size_for(reqs.len());
            let t0 = Instant::now();
            let result = match kind {
                RequestKind::Classify => exec.execute(&images),
                RequestKind::Forward | RequestKind::Stream => exec.forward(&images),
            };
            match result {
                Ok(logits) => {
                    let wall = t0.elapsed();
                    self.ledger.lock().unwrap().record_batch(
                        reqs.len(),
                        exec_size,
                        exec.cost(),
                        wall,
                    );
                    let layers = exec.graph_layers();
                    self.stage_responses(reqs.iter().zip(&logits).map(|(req, lg)| {
                        // Built eagerly (collected before locking) so JSON
                        // serialization never runs under the outbox lock.
                        let pred = if lg.is_empty() {
                            0
                        } else {
                            crate::util::stats::argmax_rows(lg, lg.len())[0]
                        };
                        let mut o = Json::obj();
                        o.set("id", Self::id_json(req.payload.client_req_id));
                        o.set("pred", Json::num(pred as f64));
                        o.set(
                            "logits",
                            Json::arr_f64(&lg.iter().map(|&x| x as f64).collect::<Vec<_>>()),
                        );
                        o.set(
                            "queue_us",
                            Json::num(formed_at.duration_since(req.arrived).as_secs_f64() * 1e6),
                        );
                        o.set("batch", Json::num(exec_size as f64));
                        if kind == RequestKind::Forward {
                            o.set("layers", Json::num(layers as f64));
                        }
                        (req.payload.conn_id, Json::Obj(o).to_string())
                    }));
                }
                Err(e) => {
                    self.stage_responses(reqs.iter().map(|req| {
                        let mut o = Json::obj();
                        o.set("id", Self::id_json(req.payload.client_req_id));
                        o.set("error", Json::str(&e));
                        (req.payload.conn_id, Json::Obj(o).to_string())
                    }));
                }
            }
            // Every request in the sub-batch got a response (result or
            // error) — its admission permit returns.
            self.release_permits(reqs.len());
        }
    }

    /// One streaming admission step: form up to `max_waves` token waves
    /// under a single stream-lock session (wave composition stays a
    /// pure function of the queue), execute them together through the
    /// executor's model-graph path (pools and the resident-weight cache
    /// included — a pipelined executor overlaps the waves' programming
    /// and conversion stages), then feed completions back **in wave
    /// order**, so reassembly and accounting are identical to a
    /// one-wave-at-a-time server. A wave-execution error (or a
    /// result-count mismatch) fails every request with a token in that
    /// wave without touching the other in-flight waves. Returns
    /// (completed stream requests, whether any wave ran). `horizon` is
    /// the policy clock for wave formation (advanced past the deadline
    /// during a drain so partial waves close immediately).
    fn stream_step(&self, exec: &mut dyn BatchExecutor, horizon: Instant) -> (usize, bool) {
        let mut waves = Vec::new();
        let purged = {
            let mut stream = self.stream.lock().unwrap();
            while waves.len() < self.max_waves {
                match stream.form_wave(horizon) {
                    Some(w) => waves.push(w),
                    None => break,
                }
            }
            stream.take_released()
        };
        // Sequences released outside a wave (client hung up, purge) drop
        // their die-resident KV state even when no wave forms this step.
        for seq in purged {
            exec.release_seq(seq);
        }
        if waves.is_empty() {
            return (0, false);
        }
        // Split each wave into its forward items (stream chunks) and its
        // generation items (prefill/decode token steps). Completion and
        // failure only read the items' identities, so the activation
        // chunks move out instead of being cloned per wave. The split is
        // positional: `splits[wi]` records which item slots each
        // sub-batch's outputs merge back into, keeping the wave's logits
        // in item order regardless of how the kinds interleave.
        let mut fwd_batches: Vec<Vec<Vec<f32>>> = Vec::new();
        let mut fwd_map: Vec<usize> = Vec::new();
        let mut gen_waves: Vec<Vec<GenStep>> = Vec::new();
        let mut gen_map: Vec<usize> = Vec::new();
        let mut splits: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
        for (wi, w) in waves.iter_mut().enumerate() {
            let mut fwd_idx = Vec::new();
            let mut gen_idx = Vec::new();
            let mut chunks = Vec::new();
            let mut steps = Vec::new();
            for (ii, t) in w.items.iter_mut().enumerate() {
                if let Some(gt) = t.gen {
                    gen_idx.push(ii);
                    steps.push(GenStep {
                        seq: t.req_seq,
                        pos: t.token_index,
                        tok: gt.tok,
                        decode: gt.decode,
                    });
                } else {
                    fwd_idx.push(ii);
                    chunks.push(std::mem::take(&mut t.chunk));
                }
            }
            if !chunks.is_empty() {
                fwd_map.push(wi);
                fwd_batches.push(chunks);
            }
            if !steps.is_empty() {
                gen_map.push(wi);
                gen_waves.push(steps);
            }
            splits.push((fwd_idx, gen_idx));
        }
        // Fixed structural execution order — all forward sub-waves, then
        // all generation sub-waves — so wave composition alone determines
        // engine call order (determinism under arrival interleaving).
        let fwd_results = if fwd_batches.is_empty() {
            Vec::new()
        } else {
            exec.forward_many(&fwd_batches)
        };
        let gen_results = if gen_waves.is_empty() {
            Vec::new()
        } else {
            exec.decode_many(&gen_waves)
        };
        // Merge the sub-results back into one result per wave, outputs
        // in item order. An error on either side fails the whole wave; a
        // well-behaved executor returns one result per sub-wave, so any
        // shortfall also fails its waves (no tokens leak in flight).
        let mut results: Vec<Result<Vec<Vec<f32>>, String>> =
            waves.iter().map(|w| Ok(vec![Vec::new(); w.items.len()])).collect();
        {
            let mut apply = |wi: usize, idxs: &[usize], r: Result<Vec<Vec<f32>>, String>| match r {
                Err(e) => results[wi] = Err(e),
                Ok(outs) if outs.len() != idxs.len() => {
                    results[wi] = Err(format!(
                        "executor returned {} outputs for {} wave items",
                        outs.len(),
                        idxs.len()
                    ));
                }
                Ok(outs) => {
                    if let Ok(slots) = results[wi].as_mut() {
                        for (i, o) in idxs.iter().zip(outs) {
                            slots[*i] = o;
                        }
                    }
                }
            };
            for (bi, wi) in fwd_map.iter().enumerate() {
                let r = fwd_results
                    .get(bi)
                    .cloned()
                    .unwrap_or_else(|| Err("executor returned too few wave results".to_string()));
                apply(*wi, &splits[*wi].0, r);
            }
            for (bi, wi) in gen_map.iter().enumerate() {
                let r = gen_results
                    .get(bi)
                    .cloned()
                    .unwrap_or_else(|| Err("executor returned too few wave results".to_string()));
                apply(*wi, &splits[*wi].1, r);
            }
        }
        let mut completed = 0usize;
        let mut responses: Vec<(u64, String)> = Vec::new();
        for (wave, result) in waves.iter().zip(&results) {
            let (finished, progress) = {
                let mut stream = self.stream.lock().unwrap();
                let finished = match result {
                    Ok(logits) if logits.len() == wave.items.len() => {
                        stream.complete_wave(wave, logits, Instant::now())
                    }
                    Ok(logits) => stream.fail_wave(
                        wave,
                        &format!(
                            "executor returned {} outputs for a {}-token wave",
                            logits.len(),
                            wave.items.len()
                        ),
                    ),
                    Err(e) => stream.fail_wave(wave, e),
                };
                (finished, stream.take_progress())
            };
            // Per-token push: progress events for requests this wave
            // advanced but did not finish, staged *before* the wave's
            // final responses so a push client always observes
            // monotonically increasing `done` then the final line.
            responses.extend(progress.iter().map(|p| {
                let mut o = Json::obj();
                o.set("id", Self::id_json(p.client_req_id));
                o.set("event", Json::str("tokens"));
                o.set("done", Json::num(p.done as f64));
                o.set("tokens", Json::num(p.tokens as f64));
                (p.conn_id, Json::Obj(o).to_string())
            }));
            completed += finished.iter().filter(|f| f.result.is_ok()).count();
            // Every finished request (ok or error) got its final
            // response — its admission permit returns.
            self.release_permits(finished.len());
            responses.extend(finished.iter().map(|f| {
                let mut o = Json::obj();
                o.set("id", Self::id_json(f.client_req_id));
                match &f.result {
                    Ok(out) => {
                        let pred = if out.logits.is_empty() {
                            0
                        } else {
                            crate::util::stats::argmax_rows(&out.logits, out.logits.len())[0]
                        };
                        o.set("pred", Json::num(pred as f64));
                        o.set(
                            "logits",
                            Json::arr_f64(
                                &out.logits.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                            ),
                        );
                        // Generation finishes carry the produced token
                        // ids; ordinary stream finishes don't.
                        if let Some(gen) = &out.produced {
                            o.set(
                                "generated",
                                Json::arr_f64(&gen.iter().map(|&t| t as f64).collect::<Vec<_>>()),
                            );
                        }
                        o.set("tokens", Json::num(out.tokens as f64));
                        o.set("waves", Json::num(out.waves as f64));
                        o.set("first_token_us", Json::num(out.first_token_us));
                        o.set("last_token_us", Json::num(out.last_token_us));
                    }
                    Err(e) => {
                        o.set("error", Json::str(e));
                    }
                }
                (f.conn_id, Json::Obj(o).to_string())
            }));
        }
        self.stage_responses(responses.into_iter());
        // Sequences that finished (or failed / were purged) this step
        // release their die-resident KV state so the capacity budget
        // frees up for newly admitted sequences.
        let released = self.stream.lock().unwrap().take_released();
        for seq in released {
            exec.release_seq(seq);
        }
        (completed, true)
    }

    /// The echoed `"id"`: the client's number, or JSON `null` when the
    /// request carried none.
    fn id_json(id: Option<f64>) -> Json {
        match id {
            Some(x) => Json::num(x),
            None => Json::Null,
        }
    }

    /// Stage response lines, dropping any whose connection is no longer
    /// live (client hung up while the batch ran). Lock order (live before
    /// outbox) matches `close_conn`, so a connection closed concurrently
    /// can never gain an outbox entry after its removal. Responses are
    /// collected up front so the locks only guard outbox pushes, not
    /// response construction.
    fn stage_responses(&self, responses: impl Iterator<Item = (u64, String)>) {
        let responses: Vec<(u64, String)> = responses.collect();
        let mut staged = false;
        {
            let live = self.live_conns.lock().unwrap();
            let mut outbox = self.outbox.lock().unwrap();
            for (conn_id, line) in responses {
                if live.contains(&conn_id) {
                    outbox.entry(conn_id).or_default().push(line);
                    staged = true;
                }
            }
        }
        if staged {
            self.io_notify.notify();
        }
    }

    /// Drain staged responses for a connection. Removes the map entry so
    /// finished connections don't leave an empty `Vec` behind forever.
    pub fn take_responses(&self, conn_id: u64) -> Vec<String> {
        self.outbox.lock().unwrap().remove(&conn_id).unwrap_or_default()
    }

    /// Connections with staged (undrained) responses — leak observability.
    pub fn staged_connections(&self) -> usize {
        self.outbox.lock().unwrap().len()
    }

    /// One line of error JSON with the message properly escaped (raw
    /// interpolation let a quote in the error break the wire protocol).
    pub(crate) fn error_line(e: &str) -> String {
        let mut o = Json::obj();
        o.set("error", Json::str(e));
        Json::Obj(o).to_string()
    }

    /// A load-shed response: the client's id echoed back with one of
    /// the documented backpressure error strings (`docs/SERVING.md`).
    /// Shed is an *answered* outcome — the request was well-formed but
    /// refused admission — so it returns `Ok(Some(..))`, unlike the
    /// `Err(..)` malformed-request path.
    fn shed_line(&self, client_req_id: Option<f64>, why: &str) -> String {
        self.ledger.lock().unwrap().record_shed();
        let mut o = Json::obj();
        o.set("id", Self::id_json(client_req_id));
        o.set("error", Json::str(why));
        Json::Obj(o).to_string()
    }

    /// Parse one request line. Returns Ok(None) for requests admitted to
    /// a queue, Ok(Some(..)) for immediate responses (control commands
    /// and load-shed errors), Err(..) for malformed requests. The `Err`
    /// path also counts into the ledger's `rejected_total`.
    pub fn handle_line(&self, line: &str, conn_id: u64) -> Result<Option<String>, String> {
        let r = self.handle_line_inner(line, conn_id);
        if r.is_err() {
            self.ledger.lock().unwrap().record_rejected();
        }
        r
    }

    fn handle_line_inner(&self, line: &str, conn_id: u64) -> Result<Option<String>, String> {
        let j = json::parse(line).map_err(|e| format!("bad json: {e}"))?;
        if let Some(cmd) = j.get_path("cmd").and_then(|c| c.as_str()) {
            return match cmd {
                "stats" => {
                    // Refresh the streaming snapshot first so a stats
                    // probe sees current tokens-in-flight, not the state
                    // as of the last executed wave.
                    self.refresh_stream_stats();
                    Ok(Some(self.ledger_json().to_string()))
                }
                "shutdown" => {
                    self.begin_drain();
                    Ok(Some(r#"{"ok": true}"#.to_string()))
                }
                other => Err(format!("unknown cmd '{other}'")),
            };
        }
        // Generate requests carry a token prompt instead of an image, so
        // they branch off *before* the image parse — otherwise every
        // generation request would be rejected with "missing 'image'".
        if j.get_path("kind").and_then(|k| k.as_str()) == Some("generate") {
            return self.handle_generate(&j, conn_id);
        }
        // Strict payload policy (matching the `'kind' must be a string`
        // rule): malformed requests are rejected, never silently coerced.
        // The old path mapped non-numeric / null entries to 0.0 pixels —
        // a corrupt image would classify as *something* instead of
        // erroring.
        let arr = j
            .get_path("image")
            .ok_or("missing 'image'")?
            .as_arr()
            .ok_or("'image' must be an array of numbers")?;
        if arr.is_empty() {
            return Err("'image' must not be empty".to_string());
        }
        let mut image = Vec::with_capacity(arr.len());
        for v in arr {
            image.push(v.as_f64().ok_or("'image' entries must be numbers")? as f32);
        }
        // A missing id is allowed (echoed as null); a present id must be
        // numeric — defaulting it let distinct malformed clients collide
        // on the same echoed id.
        let client_req_id = match j.get_path("id") {
            None => None,
            Some(v) => Some(v.as_f64().ok_or("'id' must be a number")?),
        };
        let kind = match j.get_path("kind") {
            None => RequestKind::Classify,
            Some(k) => match k.as_str() {
                Some("classify") => RequestKind::Classify,
                Some("forward") => RequestKind::Forward,
                Some("stream") => RequestKind::Stream,
                Some(other) => return Err(format!("unknown kind '{other}'")),
                // A present-but-non-string kind is a client bug, not a
                // silent classify.
                None => return Err("'kind' must be a string".to_string()),
            },
        };
        if kind == RequestKind::Stream {
            // `"tokens"` (stream only): how many patch chunks the image
            // splits into. Strictly validated like everything else —
            // absent means 1 (the whole image as a single token).
            let tokens = match j.get_path("tokens") {
                None => 1usize,
                Some(v) => {
                    let t = v.as_f64().ok_or("'tokens' must be a number")?;
                    if t.fract() != 0.0 || !(1.0..=1e9).contains(&t) {
                        return Err("'tokens' must be a positive integer".to_string());
                    }
                    t as usize
                }
            };
            if tokens > image.len() {
                return Err("'tokens' must not exceed the image length".to_string());
            }
            // `"push"` (stream only, optional): opt into per-token
            // progress events (`"event": "tokens"` lines) as each wave
            // completes, before the final response.
            let push = match j.get_path("push") {
                None => false,
                Some(v) => v.as_bool().ok_or("'push' must be a boolean")?,
            };
            // Admission runs *after* validation: a malformed request is
            // a parse error even under overload, never a shed.
            if self.is_draining() || self.is_shutdown() {
                return Ok(Some(self.shed_line(client_req_id, SHED_DRAINING)));
            }
            if !self.try_acquire_permit() {
                return Ok(Some(self.shed_line(client_req_id, SHED_INFLIGHT)));
            }
            {
                let mut stream = self.stream.lock().unwrap();
                if stream.queued_tokens() + stream.tokens_in_flight() as usize + tokens
                    > self.queue_depth
                {
                    drop(stream);
                    self.release_permits(1);
                    return Ok(Some(self.shed_line(client_req_id, SHED_QUEUE_FULL)));
                }
                let now = Instant::now();
                stream.enqueue_request(conn_id, client_req_id, &image, tokens, push, now);
            }
            self.exec_notify.notify();
            return Ok(None);
        }
        if self.is_draining() || self.is_shutdown() {
            return Ok(Some(self.shed_line(client_req_id, SHED_DRAINING)));
        }
        if !self.try_acquire_permit() {
            return Ok(Some(self.shed_line(client_req_id, SHED_INFLIGHT)));
        }
        if self.pending.lock().unwrap().len() >= self.queue_depth {
            self.release_permits(1);
            return Ok(Some(self.shed_line(client_req_id, SHED_QUEUE_FULL)));
        }
        self.enqueue_admitted(InferencePayload { image, conn_id, client_req_id, kind });
        Ok(None)
    }

    /// Parse and admit one `"kind": "generate"` request (autoregressive
    /// generation: prefill the prompt, then decode `max_new_tokens`
    /// greedily). Validation error strings are documented in
    /// `docs/SERVING.md`. Admission mirrors the stream tier — one
    /// permit per sequence held until the final token, prompt length
    /// priced against the token queue depth.
    fn handle_generate(&self, j: &Json, conn_id: u64) -> Result<Option<String>, String> {
        let arr = j
            .get_path("prompt")
            .ok_or("missing 'prompt'")?
            .as_arr()
            .ok_or("'prompt' must be an array of numbers")?;
        if arr.is_empty() {
            return Err("'prompt' must not be empty".to_string());
        }
        let mut prompt = Vec::with_capacity(arr.len());
        for v in arr {
            let t = v.as_f64().ok_or("'prompt' entries must be non-negative integers")?;
            if t.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&t) {
                return Err("'prompt' entries must be non-negative integers".to_string());
            }
            prompt.push(t as u32);
        }
        let max_new = {
            let v = j.get_path("max_new_tokens").ok_or("missing 'max_new_tokens'")?;
            let t = v.as_f64().ok_or("'max_new_tokens' must be a number")?;
            if t.fract() != 0.0 || !(1.0..=1e9).contains(&t) {
                return Err("'max_new_tokens' must be a positive integer".to_string());
            }
            t as usize
        };
        let client_req_id = match j.get_path("id") {
            None => None,
            Some(v) => Some(v.as_f64().ok_or("'id' must be a number")?),
        };
        let push = match j.get_path("push") {
            None => false,
            Some(v) => v.as_bool().ok_or("'push' must be a boolean")?,
        };
        // Admission runs *after* validation: a malformed request is a
        // parse error even under overload, never a shed.
        if self.is_draining() || self.is_shutdown() {
            return Ok(Some(self.shed_line(client_req_id, SHED_DRAINING)));
        }
        if !self.try_acquire_permit() {
            return Ok(Some(self.shed_line(client_req_id, SHED_INFLIGHT)));
        }
        {
            let mut stream = self.stream.lock().unwrap();
            // The sequence occupies its prompt tokens now; decode steps
            // later self-schedule one token at a time under the permit
            // it already holds, so the prefill burst is what admission
            // prices against the queue depth.
            if stream.queued_tokens() + stream.tokens_in_flight() as usize + prompt.len()
                > self.queue_depth
            {
                drop(stream);
                self.release_permits(1);
                return Ok(Some(self.shed_line(client_req_id, SHED_QUEUE_FULL)));
            }
            let now = Instant::now();
            stream.enqueue_generate(conn_id, client_req_id, &prompt, max_new, push, now);
        }
        self.exec_notify.notify();
        Ok(None)
    }

    /// Serve until shutdown. The executor loop runs on *this* thread
    /// (PJRT executables are not `Send`); all connection I/O — accept,
    /// reads, writes — runs on one reactor thread
    /// ([`super::reactor`]), never on per-connection threads. Both
    /// loops idle on condvar wakeups with bounded timeouts; neither
    /// sleep-polls.
    pub fn serve(
        self: Arc<Self>,
        cfg: &ServerConfig,
        mut exec: Box<dyn BatchExecutor>,
    ) -> std::io::Result<()> {
        let listener = std::net::TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let srv = self.clone();
        // The one intentional thread in the connection tier: the
        // reactor that owns the listener and every connection.
        // detlint: allow(hotpath-blocking) -- the single reactor spawn, not a per-connection thread
        let reactor = std::thread::spawn(move || crate::coordinator::reactor::run(srv, listener));
        // Executor loop on the current thread. Idle only when neither a
        // batch nor a wave ran, parked on the work condvar with a
        // timeout that bounds how late a batcher deadline can fire.
        let idle =
            self.batcher.max_wait.clamp(Duration::from_micros(100), Duration::from_millis(5));
        let mut drain_deadline: Option<Instant> = None;
        while !self.is_shutdown() {
            if self.is_draining() {
                let d = *drain_deadline.get_or_insert(Instant::now() + self.drain_timeout);
                if Instant::now() >= d {
                    // Drain bound exceeded: stop executing; whatever is
                    // already staged still flushes in the reactor.
                    self.force_stop();
                    break;
                }
            }
            if !self.step(exec.as_mut()).1 {
                self.exec_notify.wait_timeout(idle);
            }
        }
        reactor.join().ok();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};

    use crate::cim::params::MacroParams;
    use crate::coordinator::sac::evaluate_plan;
    use crate::coordinator::scheduler::Scheduler;
    use crate::vit::plan::PrecisionPlan;
    use crate::vit::VitConfig;

    /// Deterministic fake executor: logits[c] = mean(image) + c.
    struct FakeExec {
        cost: PlanCost,
    }

    impl FakeExec {
        fn new() -> Self {
            let sched = Scheduler::new(&MacroParams::default());
            FakeExec {
                cost: evaluate_plan(&sched, &VitConfig::default(), 1, &PrecisionPlan::paper_sac()),
            }
        }
    }

    impl BatchExecutor for FakeExec {
        fn execute(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
            Ok(images
                .iter()
                .map(|img| {
                    let m: f32 = img.iter().sum::<f32>() / img.len().max(1) as f32;
                    (0..10).map(|c| m + c as f32).collect()
                })
                .collect())
        }
        fn cost(&self) -> &PlanCost {
            &self.cost
        }
        fn num_classes(&self) -> usize {
            10
        }
    }

    fn test_server() -> Server {
        Server::new(&ServerConfig {
            addr: "unused".into(),
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(1),
            wave_tokens: 2,
            max_waves: 2,
            ..ServerConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn enqueue_and_execute_roundtrip() {
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 42, "image": [1.0, 2.0, 3.0]}"#, conn).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        let served = srv.executor_step(&mut exec);
        assert_eq!(served, 1);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 42.0);
        // logits[c] = 2 + c → argmax = 9.
        assert_eq!(j.get_path("pred").unwrap().as_f64().unwrap(), 9.0);
    }

    #[test]
    fn batches_multiple_requests() {
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conn = srv.open_conn();
        for i in 0..4 {
            srv.handle_line(&format!(r#"{{"id": {i}, "image": [0.5]}}"#), conn).unwrap();
        }
        let served = srv.executor_step(&mut exec);
        assert_eq!(served, 4);
        assert_eq!(srv.take_responses(conn).len(), 4);
        let stats = srv.ledger_json();
        assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 4.0);
    }

    #[test]
    fn control_commands() {
        let srv = test_server();
        let stats = srv.handle_line(r#"{"cmd": "stats"}"#, 1).unwrap().unwrap();
        assert!(stats.contains("requests"));
        assert!(!srv.is_shutdown());
        let ack = srv.handle_line(r#"{"cmd": "shutdown"}"#, 1).unwrap().unwrap();
        assert!(ack.contains("ok"));
        // Shutdown begins a graceful drain, not an instant stop …
        assert!(srv.is_draining());
        assert!(!srv.is_shutdown());
        // … and with nothing in flight the next executor step stops.
        let mut exec = FakeExec::new();
        srv.executor_step(&mut exec);
        assert!(srv.is_shutdown());
    }

    #[test]
    fn malformed_requests_error() {
        let srv = test_server();
        assert!(srv.handle_line("not json", 1).is_err());
        assert!(srv.handle_line(r#"{"nothing": 1}"#, 1).is_err());
        assert!(srv.handle_line(r#"{"cmd": "nope"}"#, 1).is_err());
        assert!(srv.handle_line(r#"{"id": 1, "kind": "nope", "image": [1.0]}"#, 1).is_err());
        // A non-string kind is rejected, not silently classified.
        assert!(srv.handle_line(r#"{"id": 1, "kind": 7, "image": [1.0]}"#, 1).is_err());
    }

    #[test]
    fn malformed_payloads_error_and_never_enqueue() {
        // Strict-parse table: every malformed shape yields a parse error
        // and leaves the queue untouched — no request is half-accepted.
        let srv = test_server();
        let cases = [
            // The old path coerced these entries to 0.0 pixels silently.
            (r#"{"id": 1, "image": [1.0, "x"]}"#, "non-numeric image entry"),
            (r#"{"id": 1, "image": [1.0, null]}"#, "null image entry"),
            (r#"{"id": 1, "image": [[1.0]]}"#, "nested-array image entry"),
            (r#"{"id": 1, "image": null}"#, "null image"),
            (r#"{"id": 1, "image": 3.0}"#, "non-array image"),
            (r#"{"id": 1, "image": []}"#, "empty image"),
            (r#"{"image": [1.0], "id": "abc"}"#, "non-numeric id"),
            (r#"{"image": [1.0], "id": null}"#, "null id"),
            (r#"{"image": [1.0], "id": [3]}"#, "array id"),
            (r#"{"id": 1, "kind": 7, "image": [1.0]}"#, "wrong-type kind"),
        ];
        for (line, why) in cases {
            assert!(srv.handle_line(line, 1).is_err(), "{why} must error: {line}");
            assert!(srv.pending.lock().unwrap().is_empty(), "{why} must never enqueue");
        }
        // A well-formed request still enqueues.
        srv.handle_line(r#"{"id": 1, "image": [1.0]}"#, 1).unwrap();
        assert_eq!(srv.pending.lock().unwrap().len(), 1);
    }

    #[test]
    fn malformed_stream_token_counts_error_and_never_enqueue() {
        let srv = test_server();
        let cases = [
            (r#"{"id": 1, "kind": "stream", "tokens": "x", "image": [1.0, 2.0]}"#, "string tokens"),
            (r#"{"id": 1, "kind": "stream", "tokens": null, "image": [1.0, 2.0]}"#, "null tokens"),
            (r#"{"id": 1, "kind": "stream", "tokens": 0, "image": [1.0, 2.0]}"#, "zero tokens"),
            (r#"{"id": 1, "kind": "stream", "tokens": -2, "image": [1.0, 2.0]}"#, "negative"),
            (r#"{"id": 1, "kind": "stream", "tokens": 1.5, "image": [1.0, 2.0]}"#, "fractional"),
            (r#"{"id": 1, "kind": "stream", "tokens": 3, "image": [1.0, 2.0]}"#, "tokens > len"),
        ];
        for (line, why) in cases {
            assert!(srv.handle_line(line, 1).is_err(), "{why} must error: {line}");
            assert_eq!(srv.stream.lock().unwrap().queued_tokens(), 0, "{why} must not enqueue");
        }
        // A valid stream request enqueues its tokens (and only into the
        // streaming tier — never the fixed-batch queue).
        srv.handle_line(r#"{"id": 1, "kind": "stream", "tokens": 2, "image": [1.0, 2.0]}"#, 1)
            .unwrap();
        assert_eq!(srv.stream.lock().unwrap().queued_tokens(), 2);
        assert!(srv.pending.lock().unwrap().is_empty());
        // An absent "tokens" means one token.
        srv.handle_line(r#"{"id": 2, "kind": "stream", "image": [1.0, 2.0]}"#, 1).unwrap();
        assert_eq!(srv.stream.lock().unwrap().queued_tokens(), 3);
    }

    #[test]
    fn stream_requests_error_per_request_on_single_layer_executors() {
        // FakeExec has no model graph: a wave fails as a unit and every
        // request with a token in it gets one error line.
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 9, "kind": "stream", "tokens": 2, "image": [1.0, 2.0]}"#, conn)
            .unwrap();
        assert_eq!(srv.executor_step(&mut exec), 0, "failed stream requests are not served");
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 9.0);
        assert!(j.get_path("error").is_some());
        assert_eq!(srv.stream.lock().unwrap().tokens_in_flight(), 0);
    }

    #[test]
    fn stream_requests_serve_through_a_graph_executor_with_stats() {
        // A 2-block tiny-geometry pipeline serves "stream" requests:
        // tokens coalesce into 2-token waves, responses reassemble per
        // request, and the stats report carries the streaming fields.
        use crate::coordinator::pipeline::{ModelExecutor, PipelineConfig};
        use crate::vit::graph::ModelGraph;
        use crate::vit::plan::OperatingPoint;
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        let op = OperatingPoint::new(2, 2, crate::cim::params::CbMode::Off);
        let plan = PrecisionPlan { name: "test 2b", attention: op, mlp: op };
        let mut cfg = VitConfig::default();
        cfg.image = 16;
        cfg.dim = 48;
        cfg.depth = 2;
        cfg.mlp_ratio = 2;
        cfg.num_classes = 4;
        let graph = ModelGraph::encoder(&cfg, 2, &plan);
        let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
        let srv = test_server();
        let conn = srv.open_conn();
        let img: Vec<f32> = (0..16).map(|j| (j % 7) as f32 / 7.0 - 0.4).collect();
        let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
        let payload = body.join(", ");
        let line = format!(r#"{{"id": 1, "kind": "stream", "tokens": 3, "image": [{payload}]}}"#);
        srv.handle_line(&line, conn).unwrap();
        // Wave 1 (2 tokens) leaves the request unfinished; wave 2 (the
        // deadline-closed single token) completes it.
        assert_eq!(srv.executor_step(&mut exec), 0);
        assert!(srv.take_responses(conn).is_empty());
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 1);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get_path("tokens").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get_path("waves").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get_path("logits").unwrap().as_arr().unwrap().len(), 48);
        assert!(j.get_path("first_token_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            j.get_path("last_token_us").unwrap().as_f64().unwrap()
                >= j.get_path("first_token_us").unwrap().as_f64().unwrap()
        );
        let stats = srv.ledger_json();
        assert_eq!(stats.get_path("stream_requests").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(stats.get_path("stream_tokens_served").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(stats.get_path("tokens_in_flight").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(stats.get_path("stream_waves").unwrap().as_f64().unwrap(), 2.0);
        let occ = stats.get_path("mean_wave_occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 0.75).abs() < 1e-12, "waves of 2/2 and 1/2 tokens: {occ}");
        assert!(stats.get_path("token_latency_p50_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            stats.get_path("token_latency_p99_us").unwrap().as_f64().unwrap()
                >= stats.get_path("token_latency_p50_us").unwrap().as_f64().unwrap()
        );
        // The streaming work shows up in the measured per-layer counters
        // even though it bypasses the fixed-batch ledger accounting.
        let layers = stats.get_path("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 8);
        assert!(layers
            .iter()
            .all(|l| l.get_path("conversions").unwrap().as_f64().unwrap() > 0.0));
    }

    #[test]
    fn absent_id_is_echoed_as_null() {
        // Distinct clients that omit "id" must not collide on a default
        // echoed 0 — an absent id round-trips as JSON null.
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"image": [1.0, 2.0]}"#, conn).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 1);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id"), Some(&Json::Null));
        assert!(j.get_path("pred").is_some());
    }

    #[test]
    fn bad_batch_config_is_rejected_at_construction() {
        let bad = ServerConfig { batch_sizes: vec![], ..ServerConfig::default() };
        assert!(Server::new(&bad).is_err());
        // A zero wave size is equally a config error, not a later panic.
        let bad_wave = ServerConfig { wave_tokens: 0, ..ServerConfig::default() };
        assert!(Server::new(&bad_wave).is_err());
        // Zero in-flight waves would make the streaming tier a no-op.
        let bad_concurrency = ServerConfig { max_waves: 0, ..ServerConfig::default() };
        assert!(Server::new(&bad_concurrency).is_err());
    }

    #[test]
    fn bad_admission_config_is_rejected_at_construction() {
        // The admission knobs are validated like --max-waves: zero is a
        // construction error, never a later panic or a wedged server.
        let no_permits = ServerConfig { max_inflight: 0, ..ServerConfig::default() };
        assert!(Server::new(&no_permits).is_err());
        let no_queue = ServerConfig { queue_depth: 0, ..ServerConfig::default() };
        assert!(Server::new(&no_queue).is_err());
        let no_drain = ServerConfig { drain_timeout: Duration::ZERO, ..ServerConfig::default() };
        assert!(Server::new(&no_drain).is_err());
        // The defaults themselves construct.
        assert!(Server::new(&ServerConfig::default()).is_ok());
    }

    #[test]
    fn forward_requests_error_on_single_layer_executors() {
        // FakeExec has no model graph: the forward kind must surface a
        // per-request error, not crash or silently classify.
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 9, "kind": "forward", "image": [1.0]}"#, conn).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 1);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 9.0);
        assert!(j.get_path("error").is_some());
    }

    #[test]
    fn mixed_kind_batches_split_into_sub_batches() {
        // A classify and a forward request in one formed batch: the
        // classify half succeeds through execute(), the forward half
        // errors (FakeExec is not a graph executor) — both get replies.
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 1, "image": [1.0]}"#, conn).unwrap();
        srv.handle_line(r#"{"id": 2, "kind": "forward", "image": [1.0]}"#, conn).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 2);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 2);
        let by_id: std::collections::HashMap<u64, Json> = resps
            .iter()
            .map(|r| {
                let j = json::parse(r).unwrap();
                (j.get_path("id").unwrap().as_f64().unwrap() as u64, j)
            })
            .collect();
        assert!(by_id[&1].get_path("pred").is_some());
        assert!(by_id[&2].get_path("error").is_some());
    }

    #[test]
    fn model_graph_forward_serves_with_per_layer_ledger() {
        // The smallest end-to-end pipeline: a 2-block encoder on a tiny
        // zero-noise geometry, served through the forward request kind.
        use crate::coordinator::pipeline::{ModelExecutor, PipelineConfig};
        use crate::vit::graph::ModelGraph;
        use crate::vit::plan::OperatingPoint;
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        let op = OperatingPoint::new(2, 2, crate::cim::params::CbMode::Off);
        let plan = PrecisionPlan { name: "test 2b", attention: op, mlp: op };
        let mut cfg = VitConfig::default();
        cfg.image = 16;
        cfg.dim = 48;
        cfg.depth = 2;
        cfg.mlp_ratio = 2;
        cfg.num_classes = 4;
        let graph = ModelGraph::encoder(&cfg, 2, &plan);
        let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
        let srv = test_server();
        let conn = srv.open_conn();
        for i in 0..2 {
            let img: Vec<f32> = (0..16).map(|j| ((i + j) % 7) as f32 / 7.0 - 0.4).collect();
            let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
            srv.handle_line(
                &format!(r#"{{"id": {i}, "kind": "forward", "image": [{}]}}"#, body.join(", ")),
                conn,
            )
            .unwrap();
        }
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 2);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 2);
        for r in resps {
            let j = json::parse(&r).unwrap();
            assert_eq!(j.get_path("layers").unwrap().as_f64().unwrap(), 8.0);
            assert_eq!(j.get_path("logits").unwrap().as_arr().unwrap().len(), 48);
        }
        let stats = srv.ledger_json();
        assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 2.0);
        let layers = stats.get_path("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 8);
        assert!(layers
            .iter()
            .all(|l| l.get_path("conversions").unwrap().as_f64().unwrap() > 0.0));
    }

    #[test]
    fn executor_idles_on_empty_queue() {
        let srv = test_server();
        let mut exec = FakeExec::new();
        assert_eq!(srv.executor_step(&mut exec), 0);
    }

    #[test]
    fn take_responses_leaves_no_empty_outbox_entries() {
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conns: Vec<u64> = (0..3).map(|_| srv.open_conn()).collect();
        for &conn in &conns {
            srv.handle_line(&format!(r#"{{"id": {conn}, "image": [1.0]}}"#), conn).unwrap();
        }
        std::thread::sleep(Duration::from_millis(3));
        while srv.executor_step(&mut exec) > 0 {}
        assert_eq!(srv.staged_connections(), 3);
        for &conn in &conns {
            assert_eq!(srv.take_responses(conn).len(), 1);
        }
        assert_eq!(srv.staged_connections(), 0, "drained connections must not leak map slots");
        // Draining an unknown connection is a no-op, not an insertion.
        assert!(srv.take_responses(999).is_empty());
        assert_eq!(srv.staged_connections(), 0);
    }

    #[test]
    fn closed_connections_never_leak_outbox_entries() {
        let srv = test_server();
        let mut exec = FakeExec::new();
        // Disconnect with a request still queued: the request is purged.
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 1, "image": [1.0]}"#, conn).unwrap();
        srv.close_conn(conn);
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 0, "queued request must be purged");
        assert_eq!(srv.staged_connections(), 0);
        // Disconnect racing an in-flight batch: the request executes but
        // nothing is staged for the dead connection (the residual leak).
        let conn2 = srv.open_conn();
        srv.handle_line(r#"{"id": 2, "image": [1.0]}"#, conn2).unwrap();
        srv.live_conns.lock().unwrap().remove(&conn2); // batch already formed upstream
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 1);
        assert_eq!(srv.staged_connections(), 0, "dead connections must not gain entries");
    }

    #[test]
    fn error_lines_escape_hostile_messages() {
        let e = "bad json: unexpected `\"` at line 1\nnext\t\\";
        let line = Server::error_line(e);
        let parsed = json::parse(&line).expect("error line must stay valid JSON");
        assert_eq!(parsed.get_path("error").unwrap().as_str().unwrap(), e);
        assert!(!line.contains('\n'), "wire protocol is line-delimited");
    }

    #[test]
    fn request_ids_and_conn_ids_use_separate_counters() {
        let srv = test_server();
        for i in 0..5 {
            srv.handle_line(&format!(r#"{{"id": {i}, "image": [0.1]}}"#), 1).unwrap();
        }
        let ids: Vec<u64> = srv.pending.lock().unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
        // Connection ids draw from their own sequence: enqueueing must not
        // advance it (the seed bug let request ids land in conn id ranges).
        assert_eq!(srv.next_conn.load(Ordering::Relaxed), 1);
        assert_eq!(srv.next_req.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn sim_executor_serves_through_the_batch_path() {
        use crate::coordinator::shard::SimExecutor;
        use crate::vit::plan::OperatingPoint;
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        let op = OperatingPoint::new(2, 2, crate::cim::params::CbMode::Off);
        let mut exec = SimExecutor::new(&p, 64, 10, op, 2).unwrap();
        let srv = test_server();
        let conn = srv.open_conn();
        for i in 0..4 {
            let img: Vec<f32> = (0..8).map(|j| ((i + j) % 5) as f32 / 5.0).collect();
            let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
            srv.handle_line(
                &format!(r#"{{"id": {i}, "image": [{}]}}"#, body.join(", ")),
                conn,
            )
            .unwrap();
        }
        let served = srv.executor_step(&mut exec);
        assert_eq!(served, 4);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 4);
        for r in resps {
            let j = json::parse(&r).unwrap();
            assert!(j.get_path("pred").unwrap().as_f64().unwrap() >= 0.0);
            assert_eq!(j.get_path("logits").unwrap().as_arr().unwrap().len(), 10);
        }
        let stats = srv.ledger_json();
        assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 4.0);
    }

    #[test]
    fn mlp_fc2_k3072_serves_across_two_dies() {
        // The paper's macro converts a fixed 1024-row tile, so a ViT MLP
        // fc2 (k = d_ff = 3072) must row-tile; the server path must route
        // such a layer across multiple dies without truncation.
        use crate::coordinator::shard::SimExecutor;
        use crate::vit::plan::OperatingPoint;
        let mut p = MacroParams::default(); // true 1024-row geometry
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        let op = OperatingPoint::new(2, 2, crate::cim::params::CbMode::Off);
        let mut exec = SimExecutor::with_dies(&p, 3072, 10, op, 2, 2).unwrap();
        assert_eq!(exec.die_count(), 2);
        let srv = test_server();
        let conn = srv.open_conn();
        for i in 0..4 {
            let img: Vec<f32> = (0..16).map(|j| ((i + j) % 7) as f32 / 7.0 - 0.4).collect();
            let body: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
            srv.handle_line(
                &format!(r#"{{"id": {i}, "image": [{}]}}"#, body.join(", ")),
                conn,
            )
            .unwrap();
        }
        let served = srv.executor_step(&mut exec);
        assert_eq!(served, 4);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 4);
        for r in resps {
            let j = json::parse(&r).unwrap();
            assert!(j.get_path("pred").unwrap().as_f64().unwrap() >= 0.0);
            let logits = j.get_path("logits").unwrap().as_arr().unwrap();
            assert_eq!(logits.len(), 10);
            assert!(logits.iter().all(|v| v.as_f64().unwrap().is_finite()));
        }
        let stats = srv.ledger_json();
        assert_eq!(stats.get_path("requests").unwrap().as_f64().unwrap(), 4.0);
    }

    #[test]
    fn end_to_end_over_tcp() {
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(1),
            wave_tokens: 2,
            max_waves: 2,
            ..ServerConfig::default()
        };
        // Bind manually to learn the port, then serve on it.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let cfg = ServerConfig { addr: addr.to_string(), ..cfg };
        let srv = Arc::new(Server::new(&cfg).unwrap());
        let srv2 = srv.clone();
        let handle = std::thread::spawn(move || {
            srv2.serve(&cfg, Box::new(FakeExec::new())).unwrap();
        });
        std::thread::sleep(Duration::from_millis(50));

        let mut sock = TcpStream::connect(addr).unwrap();
        writeln!(sock, r#"{{"id": 5, "image": [1.0, 1.0]}}"#).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = json::parse(resp.trim()).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.get_path("pred").unwrap().as_f64().unwrap(), 9.0);

        writeln!(sock, r#"{{"cmd": "shutdown"}}"#).unwrap();
        let mut ack = String::new();
        reader.read_line(&mut ack).unwrap();
        assert!(ack.contains("ok"));
        handle.join().unwrap();
    }

    /// The tiny 2-block zero-noise graph executor used by the streaming
    /// tests (the only executor kind that serves `"stream"` requests).
    fn tiny_graph_exec() -> crate::coordinator::pipeline::ModelExecutor {
        use crate::coordinator::pipeline::{ModelExecutor, PipelineConfig};
        use crate::vit::graph::ModelGraph;
        use crate::vit::plan::OperatingPoint;
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        let op = OperatingPoint::new(2, 2, crate::cim::params::CbMode::Off);
        let plan = PrecisionPlan { name: "test 2b", attention: op, mlp: op };
        let mut cfg = VitConfig::default();
        cfg.image = 16;
        cfg.dim = 48;
        cfg.depth = 2;
        cfg.mlp_ratio = 2;
        cfg.num_classes = 4;
        let graph = ModelGraph::encoder(&cfg, 2, &plan);
        ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap()
    }

    /// A 16-float image payload for the tiny graph.
    fn img16_payload() -> String {
        let img: Vec<String> =
            (0..16).map(|j| format!("{}", (j % 7) as f32 / 7.0 - 0.4)).collect();
        img.join(", ")
    }

    /// A tiny zero-noise *decoder* executor (2 blocks, dim 48, context
    /// 8) for generate-path tests: deterministic, so served output must
    /// be bit-identical to `reference_decode`.
    fn tiny_decoder_exec() -> crate::coordinator::pipeline::ModelExecutor {
        use crate::coordinator::pipeline::{ModelExecutor, PipelineConfig};
        use crate::vit::graph::{GraphConfig, ModelGraph};
        use crate::vit::plan::OperatingPoint;
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        let op = OperatingPoint::new(2, 2, crate::cim::params::CbMode::Off);
        let plan = PrecisionPlan { name: "test 2b", attention: op, mlp: op };
        let mut cfg = VitConfig::default();
        cfg.image = 16;
        cfg.dim = 48;
        cfg.depth = 2;
        cfg.mlp_ratio = 2;
        cfg.num_classes = 4;
        let graph = ModelGraph::decoder(&GraphConfig { vit: cfg, context: 8 }, &plan);
        ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap()
    }

    #[test]
    fn malformed_generate_payloads_error_and_never_enqueue() {
        // Strict-parse table for the generate wire contract: every
        // malformed shape yields the documented error (docs/SERVING.md)
        // and leaves the token queue untouched.
        let srv = test_server();
        let cases = [
            (r#"{"id": 1, "kind": "generate"}"#, "missing 'prompt'"),
            (r#"{"id": 1, "kind": "generate", "prompt": 3}"#, "'prompt' must be an array of numbers"),
            (r#"{"id": 1, "kind": "generate", "prompt": []}"#, "'prompt' must not be empty"),
            (
                r#"{"id": 1, "kind": "generate", "prompt": [1.5], "max_new_tokens": 2}"#,
                "'prompt' entries must be non-negative integers",
            ),
            (
                r#"{"id": 1, "kind": "generate", "prompt": [-1], "max_new_tokens": 2}"#,
                "'prompt' entries must be non-negative integers",
            ),
            (
                r#"{"id": 1, "kind": "generate", "prompt": ["x"], "max_new_tokens": 2}"#,
                "'prompt' entries must be non-negative integers",
            ),
            (r#"{"id": 1, "kind": "generate", "prompt": [1]}"#, "missing 'max_new_tokens'"),
            (
                r#"{"id": 1, "kind": "generate", "prompt": [1], "max_new_tokens": "x"}"#,
                "'max_new_tokens' must be a number",
            ),
            (
                r#"{"id": 1, "kind": "generate", "prompt": [1], "max_new_tokens": 0}"#,
                "'max_new_tokens' must be a positive integer",
            ),
            (
                r#"{"id": 1, "kind": "generate", "prompt": [1], "max_new_tokens": 2.5}"#,
                "'max_new_tokens' must be a positive integer",
            ),
            (
                r#"{"id": "x", "kind": "generate", "prompt": [1], "max_new_tokens": 2}"#,
                "'id' must be a number",
            ),
            (
                r#"{"id": 1, "kind": "generate", "prompt": [1], "max_new_tokens": 2, "push": 3}"#,
                "'push' must be a boolean",
            ),
        ];
        for (line, want) in cases {
            let got = srv.handle_line(line, 1).unwrap_err();
            assert_eq!(got, want, "wrong error for {line}");
            assert_eq!(
                srv.stream.lock().unwrap().queued_tokens(),
                0,
                "malformed generate must never enqueue: {line}"
            );
        }
        // A well-formed generate enqueues its prompt tokens — and parses
        // without an 'image' field (the generate branch runs before the
        // image parse).
        srv.handle_line(r#"{"id": 2, "kind": "generate", "prompt": [3, 1], "max_new_tokens": 2}"#, 1)
            .unwrap();
        assert_eq!(srv.stream.lock().unwrap().queued_tokens(), 2);
    }

    #[test]
    fn generate_serves_end_to_end_and_matches_reference_decode() {
        let srv = test_server();
        let mut exec = tiny_decoder_exec();
        let prompt = [3u32, 1, 2];
        let max_new = 2usize;
        let (ref_toks, _) = tiny_decoder_exec().reference_decode(&prompt, max_new);
        let conn = srv.open_conn();
        srv.handle_line(
            r#"{"id": 9, "kind": "generate", "prompt": [3, 1, 2], "max_new_tokens": 2, "push": true}"#,
            conn,
        )
        .unwrap();
        let mut resps: Vec<String> = Vec::new();
        for _ in 0..50 {
            std::thread::sleep(Duration::from_millis(3));
            srv.executor_step(&mut exec);
            resps.extend(srv.take_responses(conn));
            if resps.iter().any(|r| r.contains("generated")) {
                break;
            }
        }
        let finals: Vec<Json> = resps
            .iter()
            .map(|r| json::parse(r).unwrap())
            .filter(|j| j.get_path("generated").is_some())
            .collect();
        assert_eq!(finals.len(), 1, "expected one final generate response: {resps:?}");
        let j = &finals[0];
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 9.0);
        let generated: Vec<u32> = j
            .get_path("generated")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as u32)
            .collect();
        // Bit-identical to the schedule-free reference walk.
        assert_eq!(generated, ref_toks);
        // Token positions processed = prompt + max_new - 1 (the last
        // produced token is never fed back).
        assert_eq!(j.get_path("tokens").unwrap().as_f64().unwrap(), 4.0);
        // pred is the argmax of the final producing logits — i.e. the
        // last generated token.
        assert_eq!(
            j.get_path("pred").unwrap().as_f64().unwrap() as u32,
            *generated.last().unwrap()
        );
        // push=true: at least one per-token progress event preceded the
        // final line.
        let events = resps
            .iter()
            .map(|r| json::parse(r).unwrap())
            .filter(|j| j.get_path("event").is_some())
            .count();
        assert!(events >= 1, "expected push progress events: {resps:?}");
        // Generation gauges landed in the ledger; the sequence finished
        // so nothing is active and its permit returned.
        let stats = srv.ledger_json();
        assert_eq!(stats.get_path("sequences_active").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(stats.get_path("prefill_tokens").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(stats.get_path("decode_tokens").unwrap().as_f64().unwrap(), 1.0);
        assert!(stats.get_path("kv_hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn generate_errors_on_non_graph_executors() {
        // The default decode_many refuses generation; the sequence fails
        // cleanly and its admission permit returns.
        let srv = test_server();
        let mut exec = FakeExec::new();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 4, "kind": "generate", "prompt": [5], "max_new_tokens": 3}"#, conn)
            .unwrap();
        std::thread::sleep(Duration::from_millis(3));
        srv.executor_step(&mut exec);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 4.0);
        assert!(j
            .get_path("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("does not serve autoregressive generation"));
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn overload_sheds_with_documented_errors_and_never_enqueues() {
        let srv = Server::new(&ServerConfig {
            addr: "unused".into(),
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(1),
            wave_tokens: 2,
            max_waves: 2,
            max_inflight: 2,
            ..ServerConfig::default()
        })
        .unwrap();
        let conn = srv.open_conn();
        // Fill both permits.
        assert!(srv.handle_line(r#"{"id": 1, "image": [1.0]}"#, conn).unwrap().is_none());
        assert!(srv.handle_line(r#"{"id": 2, "image": [1.0]}"#, conn).unwrap().is_none());
        // The third request sheds with the documented overload error,
        // echoing the client id, without enqueueing anything.
        let resp = srv.handle_line(r#"{"id": 3, "image": [1.0]}"#, conn).unwrap().unwrap();
        let j = json::parse(&resp).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get_path("error").unwrap().as_str().unwrap(), SHED_INFLIGHT);
        assert_eq!(srv.pending.lock().unwrap().len(), 2);
        // Stream requests draw from the same permit pool.
        let resp = srv
            .handle_line(r#"{"id": 4, "kind": "stream", "image": [1.0]}"#, conn)
            .unwrap()
            .unwrap();
        let j = json::parse(&resp).unwrap();
        assert_eq!(j.get_path("error").unwrap().as_str().unwrap(), SHED_INFLIGHT);
        assert_eq!(srv.stream.lock().unwrap().queued_tokens(), 0);
        // Serving the backlog frees the permits; admission resumes.
        let mut exec = FakeExec::new();
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 2);
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 0);
        assert!(srv.handle_line(r#"{"id": 5, "image": [1.0]}"#, conn).unwrap().is_none());
        // Shed accounting is observable in the stats report.
        let stats = srv.ledger_json();
        assert_eq!(stats.get_path("shed_requests").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(stats.get_path("rejected_total").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(stats.get_path("inflight_permits").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(stats.get_path("max_inflight").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn full_queues_shed_with_the_documented_error() {
        let cfg = ServerConfig {
            addr: "unused".into(),
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(1),
            wave_tokens: 2,
            max_waves: 2,
            queue_depth: 2,
            ..ServerConfig::default()
        };
        // Fixed-batch tier: the queue bound is in requests.
        let srv = Server::new(&cfg).unwrap();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 1, "image": [1.0]}"#, conn).unwrap();
        srv.handle_line(r#"{"id": 2, "image": [1.0]}"#, conn).unwrap();
        let resp = srv.handle_line(r#"{"id": 3, "image": [1.0]}"#, conn).unwrap().unwrap();
        let j = json::parse(&resp).unwrap();
        assert_eq!(j.get_path("error").unwrap().as_str().unwrap(), SHED_QUEUE_FULL);
        assert_eq!(srv.pending.lock().unwrap().len(), 2);
        // The shed returned its permit: only the two queued requests hold one.
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 2);
        // Streaming tier: the bound is in tokens (queued + in flight).
        let srv = Server::new(&cfg).unwrap();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 1, "kind": "stream", "tokens": 2, "image": [1.0, 2.0]}"#, conn)
            .unwrap();
        let resp = srv
            .handle_line(r#"{"id": 2, "kind": "stream", "image": [1.0]}"#, conn)
            .unwrap()
            .unwrap();
        let j = json::parse(&resp).unwrap();
        assert_eq!(j.get_path("error").unwrap().as_str().unwrap(), SHED_QUEUE_FULL);
        assert_eq!(srv.stream.lock().unwrap().queued_tokens(), 2);
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn purged_connections_return_their_admission_permits() {
        let srv = test_server();
        let conn = srv.open_conn();
        srv.handle_line(r#"{"id": 1, "image": [1.0]}"#, conn).unwrap();
        srv.handle_line(r#"{"id": 2, "kind": "stream", "tokens": 2, "image": [1.0, 2.0]}"#, conn)
            .unwrap();
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 2);
        srv.close_conn(conn);
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 0, "purged requests must free permits");
    }

    #[test]
    fn graceful_drain_completes_in_flight_stream_requests() {
        let mut exec = tiny_graph_exec();
        // A 60s batching deadline: the partial remainder wave can only
        // close through the drain horizon, never by waiting it out.
        let srv = Server::new(&ServerConfig {
            addr: "unused".into(),
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_secs(60),
            wave_tokens: 2,
            max_waves: 2,
            ..ServerConfig::default()
        })
        .unwrap();
        let conn = srv.open_conn();
        let line = format!(
            r#"{{"id": 1, "kind": "stream", "tokens": 3, "image": [{}]}}"#,
            img16_payload()
        );
        srv.handle_line(&line, conn).unwrap();
        // The full 2-token wave runs; the 1-token remainder stays queued
        // behind the (far-future) deadline.
        assert_eq!(srv.executor_step(&mut exec), 0);
        assert_eq!(srv.stream.lock().unwrap().queued_tokens(), 1);
        // Begin the drain. New inference requests shed...
        let ack = srv.handle_line(r#"{"cmd": "shutdown"}"#, conn).unwrap().unwrap();
        assert!(ack.contains("ok"));
        assert!(srv.is_draining());
        let resp = srv.handle_line(r#"{"id": 2, "image": [1.0]}"#, conn).unwrap().unwrap();
        let j = json::parse(&resp).unwrap();
        assert_eq!(j.get_path("error").unwrap().as_str().unwrap(), SHED_DRAINING);
        // ...but the in-flight stream request completes — the drain
        // horizon closes its partial wave immediately — and only then
        // does the server stop.
        assert_eq!(srv.executor_step(&mut exec), 1);
        assert!(srv.is_shutdown());
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1, "the staged final response must survive the drain");
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get_path("tokens").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get_path("waves").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(srv.inflight.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn push_stream_requests_emit_progress_events_in_wave_order() {
        let mut exec = tiny_graph_exec();
        let srv = test_server();
        let conn = srv.open_conn();
        let line = format!(
            r#"{{"id": 7, "kind": "stream", "tokens": 3, "push": true, "image": [{}]}}"#,
            img16_payload()
        );
        srv.handle_line(&line, conn).unwrap();
        // Wave 1 (2 of 3 tokens) advances but does not finish the
        // request: one progress event, no final line.
        assert_eq!(srv.executor_step(&mut exec), 0);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(j.get_path("event").unwrap().as_str().unwrap(), "tokens");
        assert_eq!(j.get_path("done").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get_path("tokens").unwrap().as_f64().unwrap(), 3.0);
        assert!(j.get_path("logits").is_none());
        // Wave 2 (the deadline-closed remainder) finishes the request:
        // the final response only — a finishing wave never emits a
        // trailing progress event.
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(srv.executor_step(&mut exec), 1);
        let resps = srv.take_responses(conn);
        assert_eq!(resps.len(), 1);
        let j = json::parse(&resps[0]).unwrap();
        assert!(j.get_path("pred").is_some());
        assert_eq!(j.get_path("waves").unwrap().as_f64().unwrap(), 2.0);
        // Without "push" no progress events appear (the existing stream
        // tests cover that shape); a non-boolean "push" is rejected.
        assert!(srv
            .handle_line(r#"{"id": 1, "kind": "stream", "push": 1, "image": [1.0]}"#, conn)
            .is_err());
    }

    #[test]
    fn partial_line_and_slow_writer_clients_cannot_stall_others() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let cfg = ServerConfig {
            addr: addr.to_string(),
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(1),
            wave_tokens: 2,
            max_waves: 2,
            ..ServerConfig::default()
        };
        let srv = Arc::new(Server::new(&cfg).unwrap());
        let srv2 = srv.clone();
        let handle = std::thread::spawn(move || {
            srv2.serve(&cfg, Box::new(FakeExec::new())).unwrap();
        });
        std::thread::sleep(Duration::from_millis(50));
        // Client A writes half a request line — no newline — and stalls.
        let mut stall = TcpStream::connect(addr).unwrap();
        stall.write_all(br#"{"id": 99, "image": [1.0"#).unwrap();
        stall.flush().unwrap();
        std::thread::sleep(Duration::from_millis(20));
        // Client B is served normally in the meantime.
        let mut sock = TcpStream::connect(addr).unwrap();
        writeln!(sock, r#"{{"id": 5, "image": [1.0, 1.0]}}"#).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = json::parse(resp.trim()).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.get_path("pred").unwrap().as_f64().unwrap(), 9.0);
        // Client A completes its line and is served too — the buffered
        // partial line survived the other client's traffic.
        stall.write_all(b", 2.0]}\n").unwrap();
        stall.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut sreader = BufReader::new(stall.try_clone().unwrap());
        let mut sresp = String::new();
        sreader.read_line(&mut sresp).unwrap();
        let j = json::parse(sresp.trim()).unwrap();
        assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 99.0);
        assert!(j.get_path("pred").is_some());
        writeln!(sock, r#"{{"cmd": "shutdown"}}"#).unwrap();
        let mut ack = String::new();
        reader.read_line(&mut ack).unwrap();
        assert!(ack.contains("ok"));
        handle.join().unwrap();
    }

    #[test]
    fn tcp_drain_flushes_in_flight_responses_before_exit() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let cfg = ServerConfig {
            addr: addr.to_string(),
            batch_sizes: vec![1, 4],
            max_wait: Duration::from_millis(1),
            wave_tokens: 2,
            max_waves: 2,
            ..ServerConfig::default()
        };
        let srv = Arc::new(Server::new(&cfg).unwrap());
        let srv2 = srv.clone();
        let handle = std::thread::spawn(move || {
            srv2.serve(&cfg, Box::new(FakeExec::new())).unwrap();
        });
        std::thread::sleep(Duration::from_millis(50));
        // A request and the shutdown command back-to-back: the drain
        // must still serve the in-flight request and flush its response
        // before the server exits.
        let mut sock = TcpStream::connect(addr).unwrap();
        writeln!(sock, r#"{{"id": 6, "image": [2.0, 2.0]}}"#).unwrap();
        writeln!(sock, r#"{{"cmd": "shutdown"}}"#).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..2 {
            let mut l = String::new();
            reader.read_line(&mut l).unwrap();
            lines.push(l.trim().to_string());
        }
        handle.join().unwrap();
        let mut saw_ack = false;
        let mut saw_resp = false;
        for l in &lines {
            let j = json::parse(l).unwrap();
            if j.get_path("ok").is_some() {
                saw_ack = true;
            }
            if j.get_path("pred").is_some() {
                assert_eq!(j.get_path("id").unwrap().as_f64().unwrap(), 6.0);
                saw_resp = true;
            }
        }
        assert!(saw_ack, "shutdown ack must flush: {lines:?}");
        assert!(saw_resp, "the drained request's response must flush: {lines:?}");
    }
}
