//! Streaming token-level batching: the continuous-admission tier of the
//! serving stack.
//!
//! Transformer serving is token-shaped: a request is a ViT patch
//! *sequence*, not an indivisible image, and a macro that waits for
//! whole-request batches idles between batch boundaries. This module
//! makes the **token** the unit of admission:
//!
//! 1. **Tokenization** ([`split_tokens`]): a request's image floats
//!    split into `tokens` contiguous patch chunks; each chunk featurizes
//!    into one activation vector exactly like a standalone image, so the
//!    token path reuses the model-graph executor's
//!    [`forward`](super::server::BatchExecutor::forward) — per-layer-class
//!    die pools and the resident-weight cache included.
//! 2. **Continuous admission** ([`TokenStream::form_wave`]): queued
//!    tokens — *from any mix of requests* — coalesce into the next
//!    macro **conversion wave** under the same size/deadline policy the
//!    fixed-batch [`Batcher`](super::batcher::Batcher) uses (a wave
//!    closes at `wave_tokens` tokens or when the oldest token has waited
//!    `max_wait`). Admission is **depth-fair**: a wave takes the queued
//!    tokens with the smallest `(token index, request sequence)` —
//!    breadth-first across requests, FIFO within a depth level — so a
//!    short request admitted behind a long one streams through the next
//!    waves instead of waiting for the long request to drain. An
//!    **aging guard** bounds the other direction: once any token has
//!    waited past `max_wait`, the wave admits in arrival order instead,
//!    so sustained fresh traffic cannot starve a long request's deeper
//!    tokens. Waves carry no padding: occupancy is the admitted token
//!    count over the wave size.
//! 3. **Out-of-order completion** ([`TokenStream::complete_wave`]): a
//!    request finishes when its last token's wave lands, so a short
//!    request admitted after a long one can complete first. Token
//!    outputs reassemble per request in **token-index order** (never
//!    completion order) and mean-pool into the response logits
//!    ([`pool_tokens`]); per-token latency feeds the p50/p99 accounting
//!    the ledger reports ([`StreamSnapshot`]).
//!
//! # Determinism under out-of-order arrival
//!
//! The macro's noise draws key on `seed → class pool → die → row tile →
//! global column → conversion counter`, so *conversion order* is part of
//! the served contract. The streaming tier pins that order structurally:
//!
//! - token sequence numbers are assigned **inside** the stream lock
//!   ([`TokenStream::enqueue_request`]), so the queue is totally ordered
//!   even when connection threads race;
//! - within a wave, tokens execute in `(request sequence, token index)`
//!   order — [`form_wave`](TokenStream::form_wave) sorts before
//!   returning, so the conversion-counter sequence is a pure function of
//!   the wave's *composition*, never of scheduler timing;
//! - waves are serialized by the single executor loop, and each wave
//!   runs through the ordinary deterministic graph walk. With the
//!   pipelined executor the server keeps **multiple waves in flight**
//!   per step: they are *formed* under one lock session (so their
//!   composition is a pure function of the queue) and *completed in
//!   wave order*, so the reassembly and accounting sequence is the same
//!   as if they had run one at a time.
//!
//! A request that dies while its tokens ride a wave — its connection
//! closed ([`TokenStream::purge_conn`]) or a sibling wave failed
//! ([`TokenStream::fail_wave`]) — becomes **defunct**: its in-flight
//! tokens are remembered and settled when their waves land, *without*
//! counting toward served-token or latency stats and without disturbing
//! the other requests sharing those waves.
//!
//! Consequences (test-enforced in `rust/tests/stream.rs`): at zero noise
//! streamed token outputs are bit-identical to the fixed-batch forward
//! path and to the exact reference walk for **any** arrival interleaving
//! and **any** wave partitioning; with noise, results are bit-identical
//! at any thread count and any column-shard count for a fixed request
//! trace. What legitimately changes noisy results is wave *composition*
//! (which tokens share a wave) — exactly as the batch composition does
//! on real silicon.
//!
//! # Autoregressive generation
//!
//! `"kind": "generate"` sequences ride the same waves: a prompt admits
//! as prefill [`TokenItem`]s in one shot, and completing a sequence's
//! producing position selects the next token ([`decode::argmax`]) and
//! self-enqueues it as a decode item — so decode steps of many live
//! sequences coalesce with each other and with prefill chunks,
//! padding-free. Admission gets one extra rule, **decode-priority
//! aging**: a decode step that has waited half the admission window
//! outranks everything else, so one long fresh prompt cannot starve
//! every live sequence's token cadence (see
//! [`form_wave`](TokenStream::form_wave)). Sequence lifecycle events
//! surface through [`TokenStream::take_released`] so the server can
//! drop die-resident KV state; `docs/ARCHITECTURE.md` § "Decode tier"
//! carries the full phase-split and residency model.
//!
//! The wire protocol (`"kind": "stream"` / `"kind": "generate"`, the
//! `stats` fields) is documented in `docs/SERVING.md`; the
//! occupancy/latency planning model lives in
//! [`Scheduler::plan_stream`](super::scheduler::Scheduler::plan_stream)
//! and [`Scheduler::plan_decode`](super::scheduler::Scheduler::plan_decode).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::stats::percentile;

use super::batcher::Batcher;
use super::decode;
use super::ledger::{GenSnapshot, StreamSnapshot};

/// Bounded ring of token-latency samples backing the p50/p99 report
/// (old samples are overwritten once the ring is full).
const LATENCY_SAMPLE_CAP: usize = 16_384;

/// Streaming admission policy: wave size and deadline.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Tokens coalesced into one conversion wave (the streaming
    /// analogue of a compiled batch size), ≥ 1.
    pub wave_tokens: usize,
    /// Close a partial wave once its oldest token has waited this long.
    pub max_wait: Duration,
}

/// The generation payload of a queued token item: autoregressive
/// sequences queue token *ids* (embedded by the decode executor), not
/// patch chunks, and carry their phase so admission can prioritize
/// decode cadence and the executor can count phase tokens.
#[derive(Clone, Copy, Debug)]
pub struct GenTok {
    /// Token id at this position (prompt token for prefill items, the
    /// previously produced token for decode items).
    pub tok: u32,
    /// `true` for steady-state decode steps, `false` for prefill.
    pub decode: bool,
}

/// One queued unit of work: a single token of a request — a patch chunk
/// for `forward`-style stream requests, a generation step
/// (`gen: Some(..)`) for autoregressive sequences. Both kinds coalesce
/// into the same conversion waves.
#[derive(Clone, Debug)]
pub struct TokenItem {
    /// Admission sequence number of the owning request (assigned under
    /// the stream lock — the total order conversions follow).
    pub req_seq: u64,
    /// Connection that owns the response.
    pub conn_id: u64,
    /// The client's echoed `"id"` (None = absent, echoed as null).
    pub client_req_id: Option<f64>,
    /// Position of this token within its request. For generation items
    /// this is the absolute sequence position (prompt positions first,
    /// then one per decode step).
    pub token_index: usize,
    /// The token's patch chunk (featurized by the executor). Empty for
    /// generation items, which carry a token id in `gen` instead.
    pub chunk: Vec<f32>,
    /// When this item entered the queue (request arrival for stream
    /// tokens and prefill items; the previous step's completion for
    /// decode items — the decode-priority aging clock).
    pub arrived: Instant,
    /// Generation payload; `None` for ordinary stream tokens.
    pub gen: Option<GenTok>,
}

/// A formed conversion wave: tokens sorted by `(req_seq, token_index)`,
/// ready to execute as one batch through the graph executor.
#[derive(Debug)]
pub struct Wave {
    pub items: Vec<TokenItem>,
    /// Admitted tokens over the configured wave size (waves carry no
    /// padding, so occupancy < 1 only for deadline-closed waves).
    pub occupancy: f64,
}

/// Aggregated per-request logits and latency accounting, emitted when a
/// request's last token completes.
#[derive(Clone, Debug)]
pub struct StreamOutput {
    /// Mean-pooled logits over the request's tokens ([`pool_tokens`]).
    pub logits: Vec<f32>,
    /// Tokens the request was split into.
    pub tokens: usize,
    /// Conversion waves the request's tokens rode.
    pub waves: u64,
    /// Request arrival → first completed token [µs].
    pub first_token_us: f64,
    /// Request arrival → last completed token [µs].
    pub last_token_us: f64,
    /// Generated token ids, for `"kind": "generate"` sequences only
    /// (`None` for stream requests; `logits` then holds the final
    /// step's logits rather than a pooled mean).
    pub produced: Option<Vec<u32>>,
}

/// A request leaving the streaming tier: either its pooled output or
/// the wave-execution error that killed it.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    pub conn_id: u64,
    pub client_req_id: Option<f64>,
    pub result: Result<StreamOutput, String>,
}

/// An incremental progress event for a push-enabled (`"push": true`)
/// stream request: emitted when a wave completes some of the request's
/// tokens but the request is not yet finished (the final wave's event is
/// the response itself). Drained in wave order by
/// [`TokenStream::take_progress`], so the event sequence per request is
/// monotone in `done` and as deterministic as the wave schedule.
#[derive(Clone, Debug)]
pub struct StreamProgress {
    pub conn_id: u64,
    pub client_req_id: Option<f64>,
    /// Tokens completed so far (strictly less than `tokens`).
    pub done: usize,
    /// Total tokens the request was split into.
    pub tokens: usize,
}

/// Reassembly state of one in-flight request.
struct StreamRequest {
    conn_id: u64,
    client_req_id: Option<f64>,
    arrived: Instant,
    /// Per-token logits slots, indexed by token position.
    logits: Vec<Option<Vec<f32>>>,
    /// Slots filled so far.
    done: usize,
    /// Waves that carried at least one of this request's tokens.
    waves: u64,
    first_token_us: Option<f64>,
    last_token_us: f64,
    /// Whether the client opted into per-token progress events
    /// (`"push": true`): each wave that advances the request emits a
    /// [`StreamProgress`] until the final response supersedes them.
    push: bool,
}

/// State of one live autoregressive sequence (`"kind": "generate"`).
/// Unlike a stream request, a sequence grows its own work: completing
/// the producing position selects the next token
/// ([`decode::argmax`]) and enqueues it as the next decode item, so a
/// sequence keeps exactly one in-flight producing item and its cadence
/// interleaves with other sequences' steps wave by wave.
struct GenSeq {
    conn_id: u64,
    client_req_id: Option<f64>,
    arrived: Instant,
    /// Prompt length (prefill positions `0..prompt_len`).
    prompt_len: usize,
    /// Tokens to generate before the sequence finishes.
    max_new: usize,
    /// Generated token ids so far.
    produced: Vec<u32>,
    /// Token items issued (prefill + decode); `issued - completed` is
    /// what rides queues and waves when the sequence dies.
    issued: usize,
    /// Token items whose waves have completed.
    completed: usize,
    /// Waves that carried at least one of this sequence's items.
    waves: u64,
    first_token_us: Option<f64>,
    last_token_us: f64,
    /// Completion instant of the last *produced* token, the
    /// inter-token latency reference.
    last_emit: Option<Instant>,
    /// Whether the client opted into per-token progress events.
    push: bool,
}

/// Split a request's image floats into `tokens` contiguous patch
/// chunks (balanced, remainder spread — chunk `t` covers
/// `[t·len/T, (t+1)·len/T)`). `tokens` is clamped to `[1, len]` so
/// every chunk is non-empty; the server's strict parse rejects
/// out-of-range token counts before they reach this clamp.
pub fn split_tokens(image: &[f32], tokens: usize) -> Vec<Vec<f32>> {
    let len = image.len();
    let t = tokens.clamp(1, len.max(1));
    (0..t).map(|i| image[i * len / t..(i + 1) * len / t].to_vec()).collect()
}

/// Deterministic mean-pool over a request's per-token logits, applied
/// in token-index order: f64 accumulation with a single f32 rounding at
/// the end, so out-of-order *completion* cannot perturb the pooled
/// response.
pub fn pool_tokens(token_logits: &[Vec<f32>]) -> Vec<f32> {
    let Some(first) = token_logits.first() else {
        return Vec::new();
    };
    let mut sums = vec![0f64; first.len()];
    for lg in token_logits {
        for (s, &v) in sums.iter_mut().zip(lg) {
            *s += v as f64;
        }
    }
    let n = token_logits.len() as f64;
    sums.into_iter().map(|s| (s / n) as f32).collect()
}

/// The token-level admission queue + reassembly buffer. One instance
/// per server, shared behind a mutex: connection threads enqueue,
/// the executor loop forms and completes waves.
pub struct TokenStream {
    /// Wave policy — a one-size [`Batcher`] (size = `wave_tokens`), so
    /// the streaming and fixed-batch tiers share the close-on-size /
    /// close-on-deadline decision logic.
    policy: Batcher,
    wave_tokens: usize,
    /// Queued tokens. Order is immaterial: admission selects by the
    /// depth-fair `(token_index, req_seq)` key and the deadline scans
    /// for the oldest arrival.
    queue: Vec<TokenItem>,
    requests: BTreeMap<u64, StreamRequest>,
    /// Next request sequence number (assigned under the stream lock so
    /// the queue is totally ordered even when connections race).
    next_seq: u64,
    /// Tokens admitted to a wave and not yet completed/failed.
    executing: usize,
    /// Requests that died with tokens still riding in-flight waves
    /// (`req_seq` → tokens outstanding): the connection closed mid-wave
    /// or a sibling wave failed the request. Their completions settle
    /// the count without touching served-token/latency stats, so a dead
    /// request cannot poison a shared wave's accounting. Entries drop at
    /// zero, so the map stays wave-sized.
    defunct: BTreeMap<u64, usize>,
    waves: u64,
    occupancy_sum: f64,
    completed_requests: u64,
    tokens_served: u64,
    latencies_us: Vec<f64>,
    /// Next ring slot to overwrite once `latencies_us` is full; always
    /// points at the oldest sample.
    latency_cursor: usize,
    /// Progress events for push-enabled requests, appended in wave
    /// order by [`complete_wave`](Self::complete_wave) and drained by
    /// [`take_progress`](Self::take_progress).
    progress: Vec<StreamProgress>,
    /// Live autoregressive sequences, keyed by `req_seq` (the same
    /// admission-order namespace stream requests use, so mixed waves
    /// still execute in one total `(req_seq, token_index)` order).
    gens: BTreeMap<u64, GenSeq>,
    /// Sequences that left the tier (finished, failed, or purged) since
    /// the last [`take_released`](Self::take_released) drain: the server
    /// releases their die-resident KV state and admission permits.
    released: Vec<u64>,
    /// Prefill token items served (generation sequences only).
    prefill_served: u64,
    /// Decode token items served (generation sequences only).
    decode_served: u64,
    /// Inter-token latency ring (µs between consecutive produced
    /// tokens of a sequence), same capacity policy as `latencies_us`.
    intertoken_us: Vec<f64>,
    intertoken_cursor: usize,
    /// Whether any generate sequence was ever admitted — drives the
    /// server's generation-gauge refresh the way
    /// [`ever_admitted`](Self::ever_admitted) drives the stream one.
    gen_admitted: bool,
}

impl TokenStream {
    /// Build the streaming tier; rejects a zero wave size (by the same
    /// policy validation the fixed-batch `Batcher` applies).
    pub fn new(cfg: &StreamConfig) -> Result<Self, String> {
        let policy = Batcher::new(vec![cfg.wave_tokens], cfg.max_wait)?;
        Ok(TokenStream {
            policy,
            wave_tokens: cfg.wave_tokens,
            queue: Vec::new(),
            requests: BTreeMap::new(),
            next_seq: 1,
            executing: 0,
            defunct: BTreeMap::new(),
            waves: 0,
            occupancy_sum: 0.0,
            completed_requests: 0,
            tokens_served: 0,
            latencies_us: Vec::new(),
            latency_cursor: 0,
            progress: Vec::new(),
            gens: BTreeMap::new(),
            released: Vec::new(),
            prefill_served: 0,
            decode_served: 0,
            intertoken_us: Vec::new(),
            intertoken_cursor: 0,
            gen_admitted: false,
        })
    }

    /// Admit a request: split its image into `tokens` patch chunks and
    /// enqueue them as per-token work items. `push` opts the request
    /// into per-token progress events ([`StreamProgress`]). Returns the
    /// token count.
    pub fn enqueue_request(
        &mut self,
        conn_id: u64,
        client_req_id: Option<f64>,
        image: &[f32],
        tokens: usize,
        push: bool,
        now: Instant,
    ) -> usize {
        let chunks = split_tokens(image, tokens);
        let n = chunks.len();
        let req_seq = self.next_seq;
        self.next_seq += 1;
        self.requests.insert(
            req_seq,
            StreamRequest {
                conn_id,
                client_req_id,
                arrived: now,
                logits: vec![None; n],
                done: 0,
                waves: 0,
                first_token_us: None,
                last_token_us: 0.0,
                push,
            },
        );
        for (token_index, chunk) in chunks.into_iter().enumerate() {
            self.queue.push(TokenItem {
                req_seq,
                conn_id,
                client_req_id,
                token_index,
                chunk,
                arrived: now,
                gen: None,
            });
        }
        n
    }

    /// Admit an autoregressive sequence (`"kind": "generate"`): its
    /// whole prompt enqueues as prefill items in one admission (so a
    /// prompt rides as few waves as the policy allows), and the
    /// sequence then self-schedules one decode item per produced token
    /// from [`complete_wave`](Self::complete_wave). Returns the prompt
    /// length (the prefill token count admitted now). The caller
    /// guarantees a non-empty prompt and `max_new_tokens ≥ 1`.
    pub fn enqueue_generate(
        &mut self,
        conn_id: u64,
        client_req_id: Option<f64>,
        prompt: &[u32],
        max_new_tokens: usize,
        push: bool,
        now: Instant,
    ) -> usize {
        let req_seq = self.next_seq;
        self.next_seq += 1;
        self.gen_admitted = true;
        self.gens.insert(
            req_seq,
            GenSeq {
                conn_id,
                client_req_id,
                arrived: now,
                prompt_len: prompt.len(),
                max_new: max_new_tokens,
                produced: Vec::new(),
                issued: prompt.len(),
                completed: 0,
                waves: 0,
                first_token_us: None,
                last_token_us: 0.0,
                last_emit: None,
                push,
            },
        );
        for (token_index, &tok) in prompt.iter().enumerate() {
            self.queue.push(TokenItem {
                req_seq,
                conn_id,
                client_req_id,
                token_index,
                chunk: Vec::new(),
                arrived: now,
                gen: Some(GenTok { tok, decode: false }),
            });
        }
        prompt.len()
    }

    /// Form the next conversion wave if the policy allows. Admission is
    /// **depth-fair** continuous batching: the wave takes the queued
    /// tokens with the smallest `(token_index, req_seq)` — breadth-first
    /// across requests, FIFO within a depth level — so tokens of
    /// different requests mix freely and short requests overtake long
    /// ones. **Aging guard:** once any queued token has waited past the
    /// admission window (`max_wait`), the wave admits in arrival
    /// (request-FIFO) order instead, so a deep token can never starve
    /// behind an endless stream of fresh first tokens — full waves of
    /// new arrivals would otherwise outrank `token_index ≥ 1` forever.
    /// The admitted tokens are then re-sorted by
    /// `(req_seq, token_index)` so conversion order within the wave is a
    /// pure function of its composition, never of scheduler timing.
    pub fn form_wave(&mut self, now: Instant) -> Option<Wave> {
        let oldest_wait = self.queue.iter().map(|t| now.duration_since(t.arrived)).max();
        let take = self.policy.decide(self.queue.len(), oldest_wait);
        if take == 0 {
            return None;
        }
        // Re-sorting the whole queue per wave is deliberate: the queue
        // is near-sorted between waves (appends are per-request runs),
        // so the sort is ~linear, and a wave's cost is dominated by the
        // macro conversions it triggers, not this bookkeeping.
        //
        // Decode-priority aging: a *decode* step that has waited half
        // the admission window outranks everything else, whatever the
        // regime below. A decode token's `token_index` is its absolute
        // sequence position — large by construction — so under pure
        // depth-fair admission one long fresh prompt (a run of small
        // token indices) could starve every live sequence's next token
        // and collapse token cadence; the half-window boost bounds
        // inter-token latency at `max_wait / 2` + one wave instead.
        let half_wait = self.policy.max_wait / 2;
        let starved = |t: &TokenItem| {
            t.gen.is_some_and(|g| g.decode) && now.duration_since(t.arrived) >= half_wait
        };
        let aged = oldest_wait.is_some_and(|w| w >= self.policy.max_wait);
        if aged {
            self.queue.sort_by_key(|t| (!starved(t), t.req_seq, t.token_index));
        } else {
            self.queue.sort_by_key(|t| (!starved(t), t.token_index, t.req_seq));
        }
        let mut items: Vec<TokenItem> = self.queue.drain(..take).collect();
        items.sort_by_key(|t| (t.req_seq, t.token_index));
        self.executing += items.len();
        self.waves += 1;
        let occupancy = items.len() as f64 / self.wave_tokens as f64;
        self.occupancy_sum += occupancy;
        Some(Wave { items, occupancy })
    }

    /// Settle one in-flight token of a defunct request. Returns whether
    /// `req_seq` was defunct (the caller then skips all stats and
    /// reassembly for the token — the request already left the tier).
    fn settle_defunct(&mut self, req_seq: u64) -> bool {
        let Some(left) = self.defunct.get_mut(&req_seq) else {
            return false;
        };
        *left = left.saturating_sub(1);
        if *left == 0 {
            self.defunct.remove(&req_seq);
        }
        true
    }

    fn push_latency(&mut self, us: f64) {
        if self.latencies_us.len() < LATENCY_SAMPLE_CAP {
            self.latencies_us.push(us);
        } else {
            self.latencies_us[self.latency_cursor] = us;
        }
        self.latency_cursor = (self.latency_cursor + 1) % LATENCY_SAMPLE_CAP;
    }

    /// Ring of gaps between consecutive produced tokens of a sequence
    /// — the inter-token latency the generation gauges report.
    fn push_intertoken(&mut self, us: f64) {
        if self.intertoken_us.len() < LATENCY_SAMPLE_CAP {
            self.intertoken_us.push(us);
        } else {
            self.intertoken_us[self.intertoken_cursor] = us;
        }
        self.intertoken_cursor = (self.intertoken_cursor + 1) % LATENCY_SAMPLE_CAP;
    }

    /// Record a wave's outputs (one logits row per wave token, in wave
    /// order): per-token latency samples, per-request reassembly, and
    /// the finished requests whose last token just landed.
    pub fn complete_wave(
        &mut self,
        wave: &Wave,
        outputs: &[Vec<f32>],
        now: Instant,
    ) -> Vec<FinishedRequest> {
        debug_assert_eq!(wave.items.len(), outputs.len());
        let mut finished = Vec::new();
        let mut seen: Vec<u64> = Vec::new();
        let mut seen_gens: Vec<u64> = Vec::new();
        for (item, lg) in wave.items.iter().zip(outputs) {
            self.executing = self.executing.saturating_sub(1);
            // A token of a defunct request (connection closed mid-wave,
            // or a sibling wave failed it): settle the in-flight count
            // and skip the stats — counting a dead request's tokens as
            // served poisoned the wave's accounting for everyone else.
            if self.settle_defunct(item.req_seq) {
                continue;
            }
            self.tokens_served += 1;
            let us = now.duration_since(item.arrived).as_secs_f64() * 1e6;
            self.push_latency(us);
            if let Some(gt) = item.gen {
                if gt.decode {
                    self.decode_served += 1;
                } else {
                    self.prefill_served += 1;
                }
                // Advance the sequence under the `gens` borrow; effects
                // that touch other `self` fields (the next decode item,
                // the inter-token sample, the release) apply after it.
                let mut next: Option<(usize, GenTok)> = None;
                let mut finish = false;
                let mut emit_gap: Option<f64> = None;
                {
                    let Some(g) = self.gens.get_mut(&item.req_seq) else {
                        continue;
                    };
                    g.completed += 1;
                    if !seen_gens.contains(&item.req_seq) {
                        seen_gens.push(item.req_seq);
                        g.waves += 1;
                    }
                    let rel_us = now.duration_since(g.arrived).as_secs_f64() * 1e6;
                    if g.first_token_us.is_none() {
                        g.first_token_us = Some(rel_us);
                    }
                    g.last_token_us = rel_us;
                    // The producing position is always the deepest
                    // issued one: position `prompt_len - 1 + produced`
                    // (the reference walk's semantics — the last token
                    // of `max_new` is selected but never fed back).
                    if item.token_index + 1 == g.prompt_len + g.produced.len()
                        && g.produced.len() < g.max_new
                    {
                        let tok = decode::argmax(lg);
                        g.produced.push(tok);
                        if let Some(prev) = g.last_emit {
                            emit_gap = Some(now.duration_since(prev).as_secs_f64() * 1e6);
                        }
                        g.last_emit = Some(now);
                        if g.produced.len() == g.max_new {
                            finish = true;
                        } else {
                            let pos = g.prompt_len - 1 + g.produced.len();
                            g.issued += 1;
                            next = Some((pos, GenTok { tok, decode: true }));
                        }
                    }
                }
                if let Some(gap) = emit_gap {
                    self.push_intertoken(gap);
                }
                if let Some((pos, gt_next)) = next {
                    // The next decode step bypasses admission *entry*
                    // (the sequence holds its permit until it finishes)
                    // but not admission *policy*: it queues like any
                    // token and rides whatever wave admits it.
                    self.queue.push(TokenItem {
                        req_seq: item.req_seq,
                        conn_id: item.conn_id,
                        client_req_id: item.client_req_id,
                        token_index: pos,
                        chunk: Vec::new(),
                        arrived: now,
                        gen: Some(gt_next),
                    });
                }
                if finish {
                    let g = self.gens.remove(&item.req_seq).expect("sequence is present");
                    self.completed_requests += 1;
                    self.released.push(item.req_seq);
                    finished.push(FinishedRequest {
                        conn_id: g.conn_id,
                        client_req_id: g.client_req_id,
                        result: Ok(StreamOutput {
                            logits: lg.clone(),
                            tokens: g.issued,
                            waves: g.waves,
                            first_token_us: g.first_token_us.unwrap_or(0.0),
                            last_token_us: g.last_token_us,
                            produced: Some(g.produced),
                        }),
                    });
                }
                continue;
            }
            let Some(req) = self.requests.get_mut(&item.req_seq) else {
                continue;
            };
            if !seen.contains(&item.req_seq) {
                seen.push(item.req_seq);
                req.waves += 1;
            }
            let rel_us = now.duration_since(req.arrived).as_secs_f64() * 1e6;
            if req.first_token_us.is_none() {
                req.first_token_us = Some(rel_us);
            }
            req.last_token_us = rel_us;
            if req.logits[item.token_index].is_none() {
                req.done += 1;
            }
            req.logits[item.token_index] = Some(lg.clone());
            if req.done == req.logits.len() {
                let req = self.requests.remove(&item.req_seq).expect("request is present");
                self.completed_requests += 1;
                let toks: Vec<Vec<f32>> =
                    req.logits.into_iter().map(|o| o.expect("all token slots filled")).collect();
                finished.push(FinishedRequest {
                    conn_id: req.conn_id,
                    client_req_id: req.client_req_id,
                    result: Ok(StreamOutput {
                        logits: pool_tokens(&toks),
                        tokens: toks.len(),
                        waves: req.waves,
                        first_token_us: req.first_token_us.unwrap_or(rel_us),
                        last_token_us: req.last_token_us,
                        produced: None,
                    }),
                });
            }
        }
        // Push-enabled requests the wave advanced but did not finish
        // emit one progress event each, in `seen` order (= first-touch
        // order within the wave = ascending req_seq, since wave items
        // are sorted) — the event stream is a pure function of the wave
        // schedule, like everything else in this tier.
        for seq in &seen {
            if let Some(req) = self.requests.get(seq) {
                if req.push {
                    self.progress.push(StreamProgress {
                        conn_id: req.conn_id,
                        client_req_id: req.client_req_id,
                        done: req.done,
                        tokens: req.logits.len(),
                    });
                }
            }
        }
        // Push-enabled sequences report produced tokens over `max_new`
        // — one event per producing wave (pure-prefill waves that
        // produced nothing stay silent), the final token's event
        // superseded by the response.
        for seq in &seen_gens {
            if let Some(g) = self.gens.get(seq) {
                if g.push && !g.produced.is_empty() {
                    self.progress.push(StreamProgress {
                        conn_id: g.conn_id,
                        client_req_id: g.client_req_id,
                        done: g.produced.len(),
                        tokens: g.max_new,
                    });
                }
            }
        }
        finished
    }

    /// Drain the progress events accumulated by completed waves (push
    /// requests only), in wave order. The server stages these as
    /// incremental `"event": "tokens"` lines between waves.
    pub fn take_progress(&mut self) -> Vec<StreamProgress> {
        std::mem::take(&mut self.progress)
    }

    /// Drain the sequence ids that left the tier since the last drain
    /// (finished, failed, or purged). The server forwards each to the
    /// executor's `release_seq`, dropping the sequence's die-resident
    /// KV state and returning its admission permit.
    pub fn take_released(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.released)
    }

    /// A wave's execution failed: every request with a token in the
    /// wave fails as a unit — its reassembly state and any still-queued
    /// tokens are purged, and one error response per request is emitted.
    /// A failed request's tokens riding *other* in-flight waves become
    /// defunct, so those waves settle them silently instead of counting
    /// a dead request's tokens as served.
    pub fn fail_wave(&mut self, wave: &Wave, error: &str) -> Vec<FinishedRequest> {
        let mut finished = Vec::new();
        // (req_seq, unfinished token count) per newly failed request.
        let mut failed: Vec<(u64, usize)> = Vec::new();
        for item in &wave.items {
            self.executing = self.executing.saturating_sub(1);
            // Already-defunct tokens riding the failing wave settle as
            // on the success path; their request emitted its response
            // (or error) long ago.
            if self.settle_defunct(item.req_seq) {
                continue;
            }
            if item.gen.is_some() {
                // A generation item: the whole sequence fails — and is
                // released, so the server drops its die-resident KV
                // state and admission permit. `issued - completed`
                // counts this wave's items too (the fail path never
                // increments `completed`), matching the sweep below.
                if let Some(g) = self.gens.remove(&item.req_seq) {
                    failed.push((item.req_seq, g.issued - g.completed));
                    self.released.push(item.req_seq);
                    finished.push(FinishedRequest {
                        conn_id: g.conn_id,
                        client_req_id: g.client_req_id,
                        result: Err(error.to_string()),
                    });
                }
                continue;
            }
            if let Some(req) = self.requests.remove(&item.req_seq) {
                failed.push((item.req_seq, req.logits.len() - req.done));
                finished.push(FinishedRequest {
                    conn_id: req.conn_id,
                    client_req_id: req.client_req_id,
                    result: Err(error.to_string()),
                });
            }
        }
        // One queue sweep for the whole wave (not one per failed
        // request); `failed` is at most wave-sized, so the lookup stays
        // cheap. unfinished = this wave's tokens + queued tokens +
        // tokens riding other waves; the last group goes defunct.
        if !failed.is_empty() {
            for &(seq, unfinished) in &failed {
                let in_this_wave = wave.items.iter().filter(|t| t.req_seq == seq).count();
                let queued = self.queue.iter().filter(|t| t.req_seq == seq).count();
                let elsewhere = unfinished.saturating_sub(in_this_wave + queued);
                if elsewhere > 0 {
                    self.defunct.insert(seq, elsewhere);
                }
            }
            self.queue.retain(|t| !failed.iter().any(|&(seq, _)| seq == t.req_seq));
        }
        finished
    }

    /// Drop a closed connection's queued tokens and reassembly state.
    /// Tokens already admitted to a wave finish executing — the macro
    /// cannot recall a conversion — but they are recorded as defunct so
    /// their completions settle without polluting served-token stats or
    /// the wave they share with live requests. Returns how many
    /// requests were dropped unanswered (the server releases their
    /// admission permits).
    pub fn purge_conn(&mut self, conn_id: u64) -> usize {
        // Queued tokens per request of this connection, counted before
        // the sweep: the in-flight remainder (total − done − queued) is
        // what rides waves right now and must settle later.
        let mut queued: BTreeMap<u64, usize> = BTreeMap::new();
        for t in &self.queue {
            if t.conn_id == conn_id {
                *queued.entry(t.req_seq).or_insert(0) += 1;
            }
        }
        self.queue.retain(|t| t.conn_id != conn_id);
        let defunct = &mut self.defunct;
        let mut dropped = 0usize;
        self.requests.retain(|seq, r| {
            if r.conn_id != conn_id {
                return true;
            }
            let unfinished = r.logits.len() - r.done;
            let in_waves = unfinished.saturating_sub(*queued.get(seq).unwrap_or(&0));
            if in_waves > 0 {
                defunct.insert(*seq, in_waves);
            }
            dropped += 1;
            false
        });
        // The connection's live sequences die the same way: in-flight
        // items settle defunct, and the sequence ids are released so the
        // server drops their die-resident KV state without poisoning
        // the waves they ride.
        let released = &mut self.released;
        self.gens.retain(|seq, g| {
            if g.conn_id != conn_id {
                return true;
            }
            let unfinished = g.issued - g.completed;
            let in_waves = unfinished.saturating_sub(*queued.get(seq).unwrap_or(&0));
            if in_waves > 0 {
                defunct.insert(*seq, in_waves);
            }
            released.push(*seq);
            dropped += 1;
            false
        });
        dropped
    }

    /// Whether any stream request was ever admitted. Drives the
    /// server's ledger refresh: once true, every snapshot is pushed —
    /// including the all-zero one after a disconnecting client's queued
    /// tokens are purged, which would otherwise leave a stale
    /// `tokens_in_flight` frozen in the stats report.
    pub fn ever_admitted(&self) -> bool {
        self.next_seq > 1
    }

    /// Tokens queued for admission.
    pub fn queued_tokens(&self) -> usize {
        self.queue.len()
    }

    /// Tokens somewhere in the tier: queued or mid-wave.
    pub fn tokens_in_flight(&self) -> u64 {
        (self.queue.len() + self.executing) as u64
    }

    /// The accounting snapshot the ledger's `stats` report carries.
    pub fn snapshot(&self) -> StreamSnapshot {
        let (p50, p99) = if self.latencies_us.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&self.latencies_us, 0.5), percentile(&self.latencies_us, 0.99))
        };
        StreamSnapshot {
            requests: self.completed_requests,
            tokens_served: self.tokens_served,
            tokens_in_flight: self.tokens_in_flight(),
            waves: self.waves,
            mean_wave_occupancy: if self.waves == 0 {
                0.0
            } else {
                self.occupancy_sum / self.waves as f64
            },
            token_latency_p50_us: p50,
            token_latency_p99_us: p99,
        }
    }

    /// Whether any generate sequence was ever admitted (the
    /// generation-gauge analogue of [`ever_admitted`](Self::ever_admitted)).
    pub fn gen_ever_admitted(&self) -> bool {
        self.gen_admitted
    }

    /// Live autoregressive sequences.
    pub fn sequences_active(&self) -> usize {
        self.gens.len()
    }

    /// The generation-gauge snapshot for the ledger's `stats` report:
    /// serving-side cadence counters from this tier, KV residency
    /// counters from the executor's [`decode::GenStats`].
    pub fn gen_snapshot(&self, kv: &decode::GenStats) -> GenSnapshot {
        let (p50, p99) = if self.intertoken_us.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&self.intertoken_us, 0.5), percentile(&self.intertoken_us, 0.99))
        };
        let span_us: f64 = self.intertoken_us.iter().sum();
        let decode_tokens_per_s =
            if span_us > 0.0 { self.intertoken_us.len() as f64 * 1e6 / span_us } else { 0.0 };
        GenSnapshot {
            sequences_active: self.gens.len() as u64,
            kv_hits: kv.kv_hits,
            kv_misses: kv.kv_misses,
            kv_evictions: kv.kv_evictions,
            prefill_tokens: self.prefill_served,
            decode_tokens: self.decode_served,
            decode_tokens_per_s,
            intertoken_p50_us: p50,
            intertoken_p99_us: p99,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(wave_tokens: usize, wait_ms: u64) -> StreamConfig {
        StreamConfig { wave_tokens, max_wait: Duration::from_millis(wait_ms) }
    }

    fn img(n: usize) -> Vec<f32> {
        (0..n).map(|i| i as f32).collect()
    }

    #[test]
    fn split_tokens_is_balanced_and_lossless() {
        let image = img(10);
        for t in [1usize, 2, 3, 4, 10] {
            let chunks = split_tokens(&image, t);
            assert_eq!(chunks.len(), t);
            assert!(chunks.iter().all(|c| !c.is_empty()), "tokens {t}");
            let flat: Vec<f32> = chunks.concat();
            assert_eq!(flat, image, "tokens {t}");
            let (min, max) = chunks
                .iter()
                .fold((usize::MAX, 0), |(lo, hi), c| (lo.min(c.len()), hi.max(c.len())));
            assert!(max - min <= 1, "balanced split, tokens {t}");
        }
        // Out-of-range token counts clamp instead of producing empties.
        assert_eq!(split_tokens(&image, 0).len(), 1);
        assert_eq!(split_tokens(&image, 99).len(), 10);
    }

    #[test]
    fn wave_forms_on_size_or_deadline() {
        let mut ts = TokenStream::new(&cfg(4, 50)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(1, Some(1.0), &img(6), 3, false, now);
        // 3 < 4 queued and the deadline has not passed: keep waiting.
        assert!(ts.form_wave(now).is_none());
        ts.enqueue_request(1, Some(2.0), &img(4), 2, false, now);
        // 5 ≥ 4: a full wave closes immediately, one token stays queued.
        let wave = ts.form_wave(now).unwrap();
        assert_eq!(wave.items.len(), 4);
        assert!((wave.occupancy - 1.0).abs() < 1e-12);
        assert_eq!(ts.queued_tokens(), 1);
        // The leftover closes alone once its deadline passes.
        assert!(ts.form_wave(now).is_none());
        let later = now + Duration::from_millis(60);
        let tail = ts.form_wave(later).unwrap();
        assert_eq!(tail.items.len(), 1);
        assert!((tail.occupancy - 0.25).abs() < 1e-12);
        assert_eq!(ts.tokens_in_flight(), 5);
    }

    #[test]
    fn zero_wave_size_is_rejected() {
        assert!(TokenStream::new(&cfg(0, 1)).is_err());
        assert!(TokenStream::new(&cfg(1, 1)).is_ok());
    }

    #[test]
    fn waves_execute_in_request_then_token_order() {
        let mut ts = TokenStream::new(&cfg(8, 1)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(1, Some(10.0), &img(6), 3, false, now); // seq 1
        ts.enqueue_request(2, Some(20.0), &img(4), 2, false, now); // seq 2
        let wave = ts.form_wave(now + Duration::from_millis(5)).unwrap();
        let order: Vec<(u64, usize)> =
            wave.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
        assert_eq!(order, vec![(1, 0), (1, 1), (1, 2), (2, 0), (2, 1)]);
    }

    #[test]
    fn short_requests_overtake_long_ones_and_reassemble_per_request() {
        // Request 1 (4 tokens) arrives before request 2 (2 tokens).
        // Depth-fair 2-token waves: w1 = {r1t0, r2t0}, w2 = {r1t1, r2t1}
        // — the *later* request completes first (wave 2), the earlier
        // one finishes in wave 3. Out-of-order completion by design.
        let mut ts = TokenStream::new(&cfg(2, 1)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(7, Some(1.0), &img(8), 4, false, now); // seq 1
        ts.enqueue_request(8, Some(2.0), &img(4), 2, false, now); // seq 2
        let outs: Vec<Vec<f32>> = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let w1 = ts.form_wave(now).unwrap();
        let keys1: Vec<(u64, usize)> =
            w1.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
        assert_eq!(keys1, vec![(1, 0), (2, 0)]);
        assert!(ts.complete_wave(&w1, &outs, now + Duration::from_millis(1)).is_empty());
        let w2 = ts.form_wave(now).unwrap();
        let keys2: Vec<(u64, usize)> =
            w2.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
        assert_eq!(keys2, vec![(1, 1), (2, 1)]);
        let done2 = ts.complete_wave(&w2, &outs, now + Duration::from_millis(2));
        assert_eq!(done2.len(), 1, "the short request completes first");
        assert_eq!(done2[0].client_req_id, Some(2.0));
        let out = done2[0].result.as_ref().unwrap();
        assert_eq!(out.tokens, 2);
        assert_eq!(out.waves, 2);
        // Mean pool over r2's tokens, both of which got [3, 4]: r2t0 is
        // item 1 of wave 1 and r2t1 item 1 of wave 2.
        assert_eq!(out.logits, vec![3.0, 4.0]);
        assert!(out.first_token_us > 0.0 && out.last_token_us >= out.first_token_us);
        // Wave 3 finishes the long request.
        let w3 = ts.form_wave(now).unwrap();
        let keys3: Vec<(u64, usize)> =
            w3.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
        assert_eq!(keys3, vec![(1, 2), (1, 3)]);
        let done3 = ts.complete_wave(&w3, &outs, now + Duration::from_millis(3));
        assert_eq!(done3.len(), 1);
        assert_eq!(done3[0].client_req_id, Some(1.0));
        assert_eq!(done3[0].result.as_ref().unwrap().waves, 3);
        assert_eq!(ts.tokens_in_flight(), 0);
        let snap = ts.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.tokens_served, 6);
        assert_eq!(snap.waves, 3);
        assert!((snap.mean_wave_occupancy - 1.0).abs() < 1e-12);
        assert!(snap.token_latency_p50_us > 0.0);
        assert!(snap.token_latency_p99_us >= snap.token_latency_p50_us);
    }

    #[test]
    fn aged_queues_fall_back_to_arrival_order() {
        // Fresh traffic admits depth-fair; once the oldest token has
        // waited past the window, the wave admits request-FIFO so deep
        // tokens of old requests cannot starve behind new first tokens.
        let mut ts = TokenStream::new(&cfg(2, 50)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(1, Some(1.0), &img(4), 2, false, now); // seq 1
        ts.enqueue_request(2, Some(2.0), &img(4), 2, false, now); // seq 2
        let aged = now + Duration::from_millis(60);
        let wave = ts.form_wave(aged).unwrap();
        let keys: Vec<(u64, usize)> =
            wave.items.iter().map(|t| (t.req_seq, t.token_index)).collect();
        // Arrival order: the whole of request 1 first — not {r1t0, r2t0}.
        assert_eq!(keys, vec![(1, 0), (1, 1)]);
    }

    #[test]
    fn a_request_spanning_waves_counts_them() {
        let mut ts = TokenStream::new(&cfg(2, 1)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(1, None, &img(8), 4, false, now);
        let outs = vec![vec![1.0f32], vec![2.0]];
        let w1 = ts.form_wave(now).unwrap();
        assert!(ts.complete_wave(&w1, &outs, now).is_empty());
        let w2 = ts.form_wave(now).unwrap();
        let done = ts.complete_wave(&w2, &outs, now);
        assert_eq!(done.len(), 1);
        let out = done[0].result.as_ref().unwrap();
        assert_eq!(out.tokens, 4);
        assert_eq!(out.waves, 2);
        assert_eq!(done[0].client_req_id, None);
    }

    #[test]
    fn fail_wave_purges_the_whole_request() {
        let mut ts = TokenStream::new(&cfg(2, 1)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(3, Some(5.0), &img(6), 3, false, now);
        let wave = ts.form_wave(now).unwrap();
        assert_eq!(wave.items.len(), 2);
        assert_eq!(ts.queued_tokens(), 1);
        let failed = ts.fail_wave(&wave, "boom");
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].conn_id, 3);
        assert_eq!(failed[0].result.as_ref().err().unwrap(), "boom");
        // The third (queued) token is gone with its request.
        assert_eq!(ts.queued_tokens(), 0);
        assert_eq!(ts.tokens_in_flight(), 0);
        // Failed requests are not counted as served.
        assert_eq!(ts.snapshot().requests, 0);
    }

    #[test]
    fn purge_conn_drops_queue_and_reassembly() {
        let mut ts = TokenStream::new(&cfg(2, 1)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(1, Some(1.0), &img(4), 2, false, now);
        ts.enqueue_request(2, Some(2.0), &img(4), 2, false, now);
        ts.purge_conn(1);
        assert_eq!(ts.queued_tokens(), 2);
        // Mid-wave purge: completions for the dead request are dropped.
        let wave = ts.form_wave(now).unwrap();
        ts.purge_conn(2);
        let done = ts.complete_wave(&wave, &[vec![1.0], vec![2.0]], now);
        assert!(done.is_empty());
        assert_eq!(ts.tokens_in_flight(), 0);
        // The dead request's settled tokens never count as served.
        assert_eq!(ts.snapshot().tokens_served, 0);
    }

    #[test]
    fn pool_tokens_is_token_order_mean() {
        assert_eq!(pool_tokens(&[]), Vec::<f32>::new());
        assert_eq!(pool_tokens(&[vec![1.0, -2.0]]), vec![1.0, -2.0]);
        let pooled = pool_tokens(&[vec![1.0, 0.0], vec![2.0, 6.0], vec![3.0, 0.0]]);
        assert_eq!(pooled, vec![2.0, 2.0]);
    }

    #[test]
    fn gen_sequence_self_schedules_decode_steps_and_finishes() {
        let mut ts = TokenStream::new(&cfg(4, 50)).unwrap();
        let now = Instant::now();
        let admitted = ts.enqueue_generate(9, Some(1.0), &[5, 6], 3, true, now);
        assert_eq!(admitted, 2);
        assert_eq!(ts.sequences_active(), 1);
        assert!(ts.gen_ever_admitted());
        // Prefill rides one deadline-closed wave; position 1
        // (= prompt_len − 1) is the producing position for token 1.
        assert!(ts.form_wave(now).is_none());
        let w1 = ts.form_wave(now + Duration::from_millis(60)).unwrap();
        let keys: Vec<(usize, bool)> =
            w1.items.iter().map(|t| (t.token_index, t.gen.unwrap().decode)).collect();
        assert_eq!(keys, vec![(0, false), (1, false)]);
        let t1 = now + Duration::from_millis(61);
        let outs1 = vec![vec![0.0, 0.0], vec![0.0, 3.0, 1.0]];
        assert!(ts.complete_wave(&w1, &outs1, t1).is_empty());
        // Token 1 (argmax of the producing row) selected; the next
        // decode step self-enqueued with it fed back.
        assert_eq!(ts.queued_tokens(), 1);
        let prog = ts.take_progress();
        assert_eq!(prog.len(), 1);
        assert_eq!((prog[0].done, prog[0].tokens), (1, 3));
        let w2 = ts.form_wave(t1 + Duration::from_millis(60)).unwrap();
        assert_eq!(w2.items.len(), 1);
        let gt = w2.items[0].gen.unwrap();
        assert!(gt.decode);
        assert_eq!(gt.tok, 1);
        assert_eq!(w2.items[0].token_index, 2);
        let t2 = t1 + Duration::from_millis(90);
        assert!(ts.complete_wave(&w2, &[vec![9.0, 0.0]], t2).is_empty());
        // The final decode step: producing token 3 finishes the
        // sequence (the last token is selected but never fed back).
        let w3 = ts.form_wave(t2 + Duration::from_millis(60)).unwrap();
        assert_eq!(w3.items[0].gen.unwrap().tok, 0);
        assert_eq!(w3.items[0].token_index, 3);
        let done = ts.complete_wave(&w3, &[vec![0.0, 0.0, 7.0]], t2 + Duration::from_millis(70));
        assert_eq!(done.len(), 1);
        let out = done[0].result.as_ref().unwrap();
        assert_eq!(out.produced, Some(vec![1, 0, 2]));
        assert_eq!(out.tokens, 4, "2 prefill + 2 decode items executed");
        assert_eq!(out.waves, 3);
        assert_eq!(out.logits, vec![0.0, 0.0, 7.0]);
        assert_eq!(ts.take_released(), vec![1]);
        assert_eq!(ts.sequences_active(), 0);
        assert_eq!(ts.tokens_in_flight(), 0);
        let snap = ts.gen_snapshot(&decode::GenStats::default());
        assert_eq!(snap.prefill_tokens, 2);
        assert_eq!(snap.decode_tokens, 2);
        assert_eq!(snap.sequences_active, 0);
        assert!(snap.intertoken_p50_us > 0.0);
        assert!(snap.decode_tokens_per_s > 0.0);
        assert_eq!(ts.snapshot().requests, 1);
    }

    #[test]
    fn starved_decode_steps_outrank_fresh_prefill() {
        // Wave size 1, window 100 ms (decode boost threshold 50 ms). A
        // live sequence's decode step competes with a fresh prompt's
        // first token; depth-fair admission alone would pick
        // `token_index` 0 forever.
        let mut ts = TokenStream::new(&cfg(1, 100)).unwrap();
        let now = Instant::now();
        ts.enqueue_generate(1, None, &[4], 2, false, now); // seq 1
        let w1 = ts.form_wave(now).unwrap();
        let t1 = now + Duration::from_millis(2);
        assert!(ts.complete_wave(&w1, &[vec![1.0, 0.0]], t1).is_empty());
        // The decode step (position 1) queues, clocked from t1.
        assert_eq!(ts.queued_tokens(), 1);
        ts.enqueue_generate(2, None, &[7, 8, 9], 1, false, t1 + Duration::from_millis(10));
        // While the decode step is young, depth-fair admission prefers
        // the fresh prompt's first token.
        let young = ts.form_wave(t1 + Duration::from_millis(20)).unwrap();
        assert_eq!((young.items[0].req_seq, young.items[0].token_index), (2, 0));
        // Past half the window the decode step outranks everything,
        // bounding inter-token latency under prefill pressure.
        let starved = ts.form_wave(t1 + Duration::from_millis(60)).unwrap();
        assert_eq!((starved.items[0].req_seq, starved.items[0].token_index), (1, 1));
        assert!(starved.items[0].gen.unwrap().decode);
    }

    #[test]
    fn gen_failure_and_purge_release_sequences() {
        let mut ts = TokenStream::new(&cfg(2, 1)).unwrap();
        let now = Instant::now();
        ts.enqueue_generate(3, Some(7.0), &[1, 2], 2, false, now);
        let wave = ts.form_wave(now).unwrap();
        assert_eq!(wave.items.len(), 2);
        let failed = ts.fail_wave(&wave, "boom");
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].conn_id, 3);
        assert!(failed[0].result.is_err());
        assert_eq!(ts.take_released(), vec![1]);
        assert_eq!(ts.sequences_active(), 0);
        assert_eq!(ts.tokens_in_flight(), 0);

        // Mid-wave disconnect: the sequence's in-flight items settle
        // defunct and the id is released exactly once.
        ts.enqueue_generate(4, None, &[1, 2], 2, false, now); // seq 2
        let w = ts.form_wave(now).unwrap();
        assert_eq!(ts.purge_conn(4), 1);
        assert_eq!(ts.take_released(), vec![2]);
        let done = ts.complete_wave(&w, &[vec![1.0], vec![2.0]], now);
        assert!(done.is_empty());
        assert_eq!(ts.tokens_in_flight(), 0);
        assert_eq!(ts.sequences_active(), 0);
        // The dead sequence's tokens never count as served.
        assert_eq!(ts.gen_snapshot(&decode::GenStats::default()).prefill_tokens, 0);
    }

    #[test]
    fn mixed_stream_and_gen_waves_execute_in_admission_order() {
        let mut ts = TokenStream::new(&cfg(8, 1)).unwrap();
        let now = Instant::now();
        ts.enqueue_request(1, None, &img(4), 2, false, now); // seq 1
        ts.enqueue_generate(2, None, &[3, 4], 1, false, now); // seq 2
        let wave = ts.form_wave(now + Duration::from_millis(5)).unwrap();
        let keys: Vec<(u64, usize, bool)> =
            wave.items.iter().map(|t| (t.req_seq, t.token_index, t.gen.is_some())).collect();
        assert_eq!(keys, vec![(1, 0, false), (1, 1, false), (2, 0, true), (2, 1, true)]);
    }
}
