//! Model-graph pipeline executor: serves full ViT encoder forward
//! passes through the tiled multi-die macro simulator.
//!
//! The unit of work here is a [`ModelGraph`] — the typed chain of
//! per-block qkv / attn-proj / fc1 / fc2 linears — not a single matvec.
//! Per layer, the executor:
//!
//! 1. **draws macros from a per-layer-class die pool**: attention-class
//!    and MLP-class layers own disjoint pools
//!    ([`MacroParams::for_pool`] via [`DieBank::in_pool`]), sized by the
//!    router's LPT mass split
//!    ([`PipelineConfig::sized_by_router`]). Resizing one class's pool
//!    never re-seeds the other's silicon;
//! 2. **executes through the existing tiled path**: the layer's weights
//!    load onto the pool dies as a [`DieBank`] of
//!    (row tile × column shard) [`MacroShards`](super::shard::MacroShards)
//!    units — every conversion runs the true column circuit model;
//! 3. **keeps programmed dies resident**: a per-pool LRU
//!    resident-weight cache holds each layer's programmed [`DieBank`]
//!    across forward passes, keyed by `(layer index, pool)`, bounded by
//!    the pool's weight-SRAM budget
//!    ([`Scheduler::pool_capacity_bits`] against
//!    [`MacroParams::sram_bits_per_macro`]). A warm pass skips the
//!    reload for every resident layer;
//! 4. **prices the reload double-buffered**: the modeled pass latency
//!    is [`Scheduler::plan_graph`]'s pipelined accounting, where layer
//!    i+1's weight reload hides behind layer i's bit-serial
//!    conversions (`PipelinePlan::pipelined_ns` cold,
//!    `PipelinePlan::warm_pipelined_ns` with steady-state residency),
//!    replacing the old fully-serial and always-reload assumptions.
//!
//! Between linears, the digital periphery (softmax / GELU / LayerNorm
//! on 65 nm silicon) is modeled by the deterministic fixed-point
//! kernels of [`super::periphery`], dispatched on the producing layer's
//! role by [`periphery::glue`]; the glue is pure integer, so the macro
//! walk and the `matvec_exact` reference walk
//! ([`ModelExecutor::reference_ints`]) stay comparable bit for bit.
//!
//! # Staged wavefront execution
//!
//! Execution is **actually pipelined**, not just priced that way. A
//! pass over `W` waves (input batches) of an `L`-layer graph runs as
//! `W + L` barrier-separated **stages**: stage `s` executes, in
//! parallel, every *program* task on diagonal `w + l = s` (layer `l`'s
//! weights loading onto its pool for wave `w`'s first use) and every
//! *convert* task on diagonal `w + l = s - 1` (wave `w`'s conversions
//! through layer `l`). With `W = 1` this is exactly the planner's
//! double-buffered fold — layer `i+1`'s die programming overlaps layer
//! `i`'s conversion waves; with `W > 1`, consecutive waves run
//! different layers simultaneously, so attention-pool and MLP-pool
//! conversions are in flight at once on their disjoint silicon. A
//! stage's tasks are claimed by worker threads stealing from a
//! [`WorkQueue`](crate::util::pool::WorkQueue); `PipelineConfig::overlap
//! = false` runs the *same* decision and stage structure inline, which
//! is why the toggle cannot change any output bit (see below).
//!
//! # Determinism contract
//!
//! The substream hierarchy extends to
//! `seed → class pool → die → row tile → global column → conversion
//! counter`. Consequences (test-enforced in `rust/tests/pipeline.rs`
//! and the `rust/tests/perturb.rs` schedule-perturbation campaign):
//! full-pass outputs are **bit-identical at any worker-thread count,
//! any column-shard count, and with overlap on or off** even with
//! noise; at zero noise any (threads × shards × per-class dies ×
//! overlap) decomposition equals the exact reference walk — **whether
//! a pass is cold or warm**: cache state may change *when* reloads are
//! priced, never *what* a conversion computes. Concurrency cannot
//! reorder conversion semantics because (a) all cache decisions (which
//! wave/layer hits, misses, evicts) happen in a serial wave-major
//! decision pass before any task runs, (b) tasks sharing a programmed
//! bank always sit on *different* stage diagonals, so the barrier
//! serializes them in wave order, and (c) tasks within one stage touch
//! disjoint banks and disjoint wave states — completion order inside a
//! stage is free, the per-bank conversion-counter sequence is not.
//! Changing a pool's die count re-routes vectors onto different
//! physical silicon, which legitimately changes noisy outputs —
//! per-class pools make that re-mapping *local to the class*. A
//! resident layer's dies keep converting on the same silicon across
//! passes, so its conversion counters *continue* rather than restart —
//! physically honest (the chip does not reset between inferences) and
//! still exactly reproducible for a fixed configuration and request
//! sequence; evicted/cold layers reprogram and restart their counters,
//! exactly as a real reload rewrites the array.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cim::macro_::matvec_exact;
use crate::cim::netstats::LayerClass;
use crate::cim::params::CbMode;
use crate::cim::MacroParams;
use crate::util::pool::{default_threads, perturb, WorkQueue};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::vit::graph::{GraphLayer, LayerRole, ModelGraph};
use crate::vit::plan::OperatingPoint;

use super::decode::{self, GenStats, GenStep, SeqStateCache};
use super::ledger::{LayerCost, ResidencyStats};
use super::multidie::DieBank;
use super::periphery;
use super::router::Router;
use super::sac::PlanCost;
use super::scheduler::{PipelinePlan, ResidentLru, Scheduler};
use super::server::BatchExecutor;

pub use super::scheduler::class_pool;

/// Seed salt for the deterministic stand-in weights each graph layer
/// loads (a fixed pretrained checkpoint stand-in, keyed by layer index).
const WEIGHT_SEED_SALT: u64 = 0x57E1_6475_EED5_0115;

/// Topology of the pipeline executor: the column-shard request per
/// layer plus the per-layer-class die pools.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    /// Column-shard request per layer (raised per layer to the minimum
    /// its outputs need, exactly like [`MacroShards::new`]).
    ///
    /// [`MacroShards::new`]: super::shard::MacroShards::new
    pub shards: usize,
    /// Dies in the attention-class pool.
    pub attention_dies: usize,
    /// Dies in the MLP-class pool (also serves `CnnConv` layers).
    pub mlp_dies: usize,
    /// Run the staged wavefront engine with real worker threads
    /// (`true`) or execute the identical stage structure inline
    /// (`false`). The toggle affects wall-clock only: outputs, stats
    /// and cache state are bit-identical either way — the
    /// schedule-perturbation campaign in `rust/tests/perturb.rs`
    /// enforces this across seeds × thread counts.
    pub overlap: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { shards: 1, attention_dies: 1, mlp_dies: 1, overlap: true }
    }
}

impl PipelineConfig {
    /// Size the class pools from a total die budget using the router's
    /// LPT mass split over the graph (the class with more placed unit
    /// latency gets proportionally more dies, each pool at least one —
    /// so a budget below 2 yields `(1, 1)`, slightly over budget rather
    /// than an empty pool; see `Router::class_pool_split`).
    pub fn sized_by_router(
        params: &MacroParams,
        graph: &ModelGraph,
        shards: usize,
        total_dies: usize,
    ) -> Self {
        let router = Router::new(params, total_dies.max(1));
        let (attention_dies, mlp_dies) = router.class_pool_split(graph, total_dies);
        PipelineConfig { shards: shards.max(1), attention_dies, mlp_dies, overlap: true }
    }

    /// Pool size serving `class`.
    pub fn dies_for(&self, class: LayerClass) -> usize {
        match class {
            LayerClass::TransformerAttention => self.attention_dies.max(1),
            LayerClass::TransformerMlp | LayerClass::CnnConv => self.mlp_dies.max(1),
        }
    }
}

/// One resident-cache entry of the staged engine: the programmed pool
/// bank (or the programming error), filled in by its *program* task and
/// consumed by the *convert* tasks of every wave that hit on it. The
/// `Arc` keeps a bank alive for in-flight converts even if a later
/// decision evicts its cache entry — exactly the serial semantics where
/// an eviction takes effect on the *next* miss, never mid-use. `None`
/// only before the program task ran; the stage barrier guarantees
/// converts never observe it.
type BankSlot = Arc<Mutex<Option<Result<DieBank, String>>>>;

/// What a stage task does: load a layer's weights onto its pool, or
/// stream one wave's activations through a programmed bank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TaskKind {
    Program,
    Convert,
}

/// One unit of stage work, pinned to diagonal `stage` of the wavefront:
/// program tasks run at `wave + layer`, convert tasks one stage later.
struct StageTask {
    kind: TaskKind,
    wave: usize,
    li: usize,
    stage: usize,
    slot: BankSlot,
}

/// Mutable per-wave execution state, shared with the stage workers.
/// Tasks of the same wave sit on distinct diagonals, so the lock is
/// never contended *within* a wave — it exists because different
/// waves' convert tasks run concurrently in one stage and the borrow
/// checker cannot see the diagonal disjointness.
struct WaveState {
    /// Activations entering the next un-run layer.
    acts: Vec<Vec<i32>>,
    /// Last layer's raw outputs once the wave's final convert lands.
    out: Vec<Vec<i64>>,
    /// First error in layer order; set once, converts after it no-op —
    /// the wave fails as a unit without touching other waves.
    err: Option<String>,
    /// Per-layer (conversions, energy_pj) deltas, folded into the
    /// executor's stats after the pass in fixed wave-major order.
    deltas: Vec<Option<(u64, f64)>>,
}

/// Cumulative per-layer simulation counters.
#[derive(Clone, Debug, Default)]
struct LayerStats {
    calls: u64,
    conversions: u64,
    energy_pj: f64,
    /// Passes that found this layer's weights resident (reload skipped).
    reload_hits: u64,
    /// Passes that had to (re)program this layer onto its pool.
    reload_misses: u64,
}

// Digital inter-layer glue: `periphery::glue` (role-keyed integer
// softmax/LayerNorm/GELU) replaced the former `requantize` hash-mix
// stand-in. It stays a pure integer map applied identically by the
// macro walk and the exact reference walks, so the zero-noise equality
// contract is unchanged in structure.

/// Quantize one image's floats into a `k`-long activation vector in the
/// operating point's `a_bits` range (the patch-embed stand-in; mirror
/// of `SimExecutor::featurize`).
pub fn featurize(op: OperatingPoint, k: usize, img: &[f32]) -> Vec<i32> {
    let (a_lo, a_hi) = op.a_range();
    (0..k)
        .map(|r| {
            if img.is_empty() {
                return 0;
            }
            let v = img[r * img.len() / k];
            let q = (v.clamp(-1.0, 1.0) * a_hi.max(1) as f32).round() as i32;
            q.clamp(a_lo, a_hi)
        })
        .collect()
}

/// Walks a [`ModelGraph`] layer by layer through per-class die pools —
/// the server's whole-model [`BatchExecutor`]. Weights are a
/// deterministic pretrained stand-in (keyed by layer index off the die
/// seed). Programmed pool banks stay **resident** across forward passes
/// in a per-pool LRU cache bounded by the weight-SRAM budget
/// ([`MacroParams::sram_bits_per_macro`]): a warm pass skips the reload
/// for every resident layer — exactly the cold/warm stream the
/// `Scheduler`'s double-buffered accounting prices — and memory stays
/// bounded by the cache budget plus one in-flight layer's bank.
pub struct ModelExecutor {
    params: MacroParams,
    pub graph: ModelGraph,
    pub config: PipelineConfig,
    pipeline: PipelinePlan,
    cost: PlanCost,
    stats: Vec<LayerStats>,
    /// The resident-weight cache: programmed pool banks kept alive
    /// across passes, keyed `(layer index, pool)`, bounded per pool by
    /// [`Scheduler::pool_capacity_bits`]. The *same*
    /// [`ResidentLru`] policy drives the planner's
    /// [`Scheduler::steady_residency`] simulation, so planned warm-pass
    /// hit flags and measured hits agree structurally. Values are
    /// [`BankSlot`]s so the staged engine can program a missed layer
    /// concurrently with earlier layers' conversions: the slot is
    /// inserted at decision time, filled by its program task.
    cache: ResidentLru<BankSlot>,
    /// Modeled reload latency actually paid so far [ns] (missed layers
    /// only; the amortization numerator).
    paid_reload_ns: f64,
    /// Forward passes executed.
    passes: u64,
    /// Modeled latency of the most recent engine pass [ns]: the staged
    /// fold (widest task per stage), with only the layers that actually
    /// missed paying their reload. On a steady warm pass this equals
    /// the plan's `warm_pipelined_ns`; cold, its `pipelined_ns`.
    last_pass_ns: f64,
    /// The same pass priced fully serially [ns]: Σ (paid reload +
    /// compute) over every executed (wave, layer).
    last_serial_ns: f64,
    /// Host-side per-sequence KV state *values*: the fold digest of
    /// every `(sequence id, block)` a generate wave has touched. Always
    /// kept (correctness), regardless of what the residency policy says
    /// is die-pinned — eviction is a pricing event. Locked after the
    /// wave/slot locks inside convert tasks (lock rank `kv`, see
    /// `analysis::rules::LOCK_ORDER`).
    kv: Arc<Mutex<BTreeMap<(u64, usize), Vec<i64>>>>,
    /// The KV residency *policy* (metadata): which sequences' state is
    /// die-pinned, run live during the serial decision pass so measured
    /// hit/miss/eviction counters are schedule-independent — the decode
    /// sibling of the weight `cache`, replayed identically by
    /// `Scheduler::plan_decode`.
    seq_cache: SeqStateCache,
    /// Prefill positions executed (prompt tokens through the graph).
    prefill_tokens: u64,
    /// Decode steps executed (generated tokens through the graph).
    decode_tokens: u64,
}

impl ModelExecutor {
    pub fn new(
        params: &MacroParams,
        graph: ModelGraph,
        config: PipelineConfig,
    ) -> Result<Self, String> {
        if graph.layers.is_empty() {
            return Err("model graph has no layers".to_string());
        }
        for l in &graph.layers {
            l.op.validate()?;
        }
        // Price each layer with its own class pool's topology: latency
        // divides by that pool's die count, conversions/energy are
        // topology-independent.
        let att = Scheduler::with_topology(
            params,
            config.shards.max(1),
            config.dies_for(LayerClass::TransformerAttention),
        );
        let mlp = Scheduler::with_topology(
            params,
            config.shards.max(1),
            config.dies_for(LayerClass::TransformerMlp),
        );
        let sched_for = |class: LayerClass| match class {
            LayerClass::TransformerAttention => &att,
            LayerClass::TransformerMlp | LayerClass::CnnConv => &mlp,
        };
        // Steady-state residency is a capacity property (params-level),
        // identical for every topology — and, by shared policy, to what
        // the live cache will actually do (lru_steady_hits).
        let resident = att.steady_residency(&graph);
        let plan_with = |per_batch: bool| {
            PipelinePlan::from_layers(
                graph
                    .layers
                    .iter()
                    .zip(&resident)
                    .map(|(l, &res)| {
                        let s = sched_for(l.shape.class);
                        // The graph's m is batch × tokens, so the
                        // per-inference stream is exactly m / batch.
                        let mut shape = l.shape;
                        if !per_batch {
                            shape.m /= graph.batch.max(1);
                        }
                        let reload = s.weight_load_ns(&shape, l.op);
                        (l.name(), s.plan_linear(&shape, l.op), reload, res)
                    })
                    .collect(),
            )
        };
        // Full-batch timing for reporting (layer_costs, pipeline()).
        let pipeline = plan_with(true);
        // The ledger contract is per-inference: `record_batch`
        // multiplies cost energy/conversions/ops by the executed batch
        // size, so the installed PlanCost must price ONE inference —
        // with its reload-overlapped pipeline latency, not the bare
        // conversion sum. (SimExecutor keeps the same convention via
        // m = 1.)
        let per_inference = plan_with(false);
        let mut total = per_inference.total;
        total.latency_ns = per_inference.pipelined_ns;
        let cost = PlanCost::from_total(
            "model-graph pipeline (per-class pools, overlapped reloads)",
            total,
        );
        let stats = vec![LayerStats::default(); graph.layers.len()];
        let pool_capacity: BTreeMap<usize, u64> = graph
            .layers
            .iter()
            .map(|l| class_pool(l.shape.class))
            .map(|pool| (pool, att.pool_capacity_bits(&graph, pool)))
            .collect();
        let cache = ResidentLru::new(pool_capacity);
        // KV state shares the attention pool's weight-SRAM budget: the
        // same banked capacity that pins weights pins per-sequence state.
        let kv_capacity =
            att.pool_capacity_bits(&graph, class_pool(LayerClass::TransformerAttention));
        let params = params.clone();
        Ok(ModelExecutor {
            params,
            graph,
            config,
            pipeline,
            cost,
            stats,
            cache,
            paid_reload_ns: 0.0,
            passes: 0,
            last_pass_ns: 0.0,
            last_serial_ns: 0.0,
            kv: Arc::new(Mutex::new(BTreeMap::new())),
            seq_cache: SeqStateCache::new(kv_capacity),
            prefill_tokens: 0,
            decode_tokens: 0,
        })
    }

    /// The modeled full-pass timing (serial vs overlapped reloads).
    pub fn pipeline(&self) -> &PipelinePlan {
        &self.pipeline
    }

    /// Forward passes executed so far.
    pub fn passes(&self) -> u64 {
        self.passes
    }

    /// Modeled latency of the most recent engine pass [ns]: the staged
    /// fold — each stage as wide as its widest task — with only the
    /// layers that actually missed paying their reload. Warm steady
    /// passes land on [`PipelinePlan::warm_pipelined_ns`], cold ones on
    /// `pipelined_ns`; `rust/tests/overlap.rs` anchors both.
    pub fn last_pass_ns(&self) -> f64 {
        self.last_pass_ns
    }

    /// The most recent pass priced fully serially [ns] — every executed
    /// (wave, layer)'s compute plus each paid reload, no overlap. The
    /// staged fold can never exceed this.
    pub fn last_serial_ns(&self) -> f64 {
        self.last_serial_ns
    }

    /// The deterministic stand-in weight matrix of one graph layer
    /// (same draw for the macro walk and the reference walk). An
    /// associated fn so program tasks can draw weights while the
    /// executor's cache is mid-decision.
    fn layer_weights(params: &MacroParams, layer: &GraphLayer) -> Vec<Vec<i32>> {
        let root = Rng::salted(params.seed, WEIGHT_SEED_SALT);
        let mut rng = root.substream(0x0057_E167, layer.index as u64);
        let (lo, _) = layer.op.w_range();
        let span = 1u64 << layer.op.w_bits;
        (0..layer.shape.k)
            .map(|_| (0..layer.shape.n).map(|_| lo + rng.below(span) as i32).collect())
            .collect()
    }

    /// The one graph walk both the macro run and the exact reference
    /// share: per layer, `run_layer` produces the outputs (banked
    /// simulation or `matvec_exact`), then the [`periphery::glue`]
    /// digital periphery derives the next layer's activations. Keeping
    /// the walk single keeps the zero-noise equality contract
    /// structural instead of coincidental.
    fn walk_graph<F>(
        graph: &ModelGraph,
        xs: &[Vec<i32>],
        mut run_layer: F,
    ) -> Result<Vec<Vec<i64>>, String>
    where
        F: FnMut(usize, &GraphLayer, &[Vec<i32>]) -> Result<Vec<Vec<i64>>, String>,
    {
        let layer_count = graph.layers.len();
        let mut acts = xs.to_vec();
        let mut last = Vec::new();
        for li in 0..layer_count {
            let layer = &graph.layers[li];
            let ys = run_layer(li, layer, &acts)?;
            if li + 1 < layer_count {
                let next = &graph.layers[li + 1];
                acts = ys
                    .iter()
                    .map(|y| periphery::glue(layer.role, y, next.shape.k, next.op.a_bits))
                    .collect();
            } else {
                last = ys;
            }
        }
        Ok(last)
    }

    /// Run integer activation vectors through the full graph on the
    /// macro simulator; returns the last layer's raw integer outputs.
    /// A layer resident in the cache reuses its programmed pool bank
    /// (reload *hit*); otherwise the weights (re)program onto the pool
    /// (reload *miss*, paying the modeled reload latency) and the fresh
    /// bank is retained LRU-bounded by the pool's SRAM budget. Memory
    /// stays O(cache budget + largest layer) even at ViT-Base scale.
    /// One wave of the staged engine
    /// ([`forward_ints_many`](Self::forward_ints_many)): with overlap
    /// on, layer `i+1`'s die programming runs concurrently with layer
    /// `i`'s conversions.
    pub fn forward_ints(&mut self, xs: &[Vec<i32>]) -> Result<Vec<Vec<i64>>, String> {
        let waves = [xs.to_vec()];
        self.forward_ints_many(&waves).pop().expect("one wave in, one result out")
    }

    /// The staged wavefront engine: run `W` independent waves of
    /// activation vectors through the `L`-layer graph as `W + L`
    /// barrier-separated stages (see the module docs). Returns one
    /// result per wave; a failing wave fails as a unit without
    /// poisoning the others.
    ///
    /// **Decision pass** (serial, wave-major): every cache touch,
    /// insert and eviction happens here, in exactly the order a serial
    /// wave-by-wave walk would produce — so hit/miss flags, eviction
    /// victims and therefore *which silicon converts what* are
    /// independent of how the stage tasks later interleave.
    ///
    /// **Stage execution**: stage `s` runs all program tasks on
    /// diagonal `w + l = s` and all convert tasks on diagonal
    /// `w + l = s - 1`. Same-stage tasks always touch distinct banks
    /// and distinct waves (equal diagonal + distinct layer ⇒ distinct
    /// cache key), so their completion order is free; tasks sharing a
    /// bank sit on different diagonals and the barrier serializes them
    /// in wave order — the per-bank conversion-counter sequence, and
    /// hence every noise draw, is fixed by construction.
    pub fn forward_ints_many(
        &mut self,
        waves_in: &[Vec<Vec<i32>>],
    ) -> Vec<Result<Vec<Vec<i64>>, String>> {
        self.run_waves(waves_in, None)
    }

    /// The engine body shared by the encoder path
    /// ([`forward_ints_many`](Self::forward_ints_many), `meta = None`)
    /// and the generate path ([`decode_many`](Self::decode_many), one
    /// [`GenStep`] per wave item). With metadata, each wave item is one
    /// (sequence, position) of a generating sequence: at every
    /// attention-context `qkv` layer the item's raw outputs fold into
    /// the sequence's per-block KV state ([`decode::fold_kv`]), and the
    /// serial decision pass runs the KV residency policy
    /// ([`SeqStateCache::access`]) in (wave → block → item) order —
    /// which is why planner-replayed counters can equal measured ones
    /// exactly. Fold determinism mirrors the conversion-counter
    /// argument: folds of one `(sequence, block)` always happen at the
    /// same layer index, so cross-wave folds sit on distinct stage
    /// diagonals (barrier-ordered in wave order) and within-wave folds
    /// follow item order, which the stream tier fixes to position order.
    fn run_waves(
        &mut self,
        waves_in: &[Vec<Vec<i32>>],
        meta: Option<&[Vec<GenStep>]>,
    ) -> Vec<Result<Vec<Vec<i64>>, String>> {
        if waves_in.is_empty() {
            return Vec::new();
        }
        let graph = self.graph.clone();
        let layer_count = graph.layers.len();
        let wave_count = waves_in.len();
        let stage_count = wave_count + layer_count;
        let wave_states: Vec<Mutex<WaveState>> = waves_in
            .iter()
            .map(|xs| {
                Mutex::new(WaveState {
                    acts: xs.clone(),
                    out: Vec::new(),
                    err: None,
                    deltas: vec![None; layer_count],
                })
            })
            .collect();
        // Decision pass. Reload hit/miss bookkeeping happens here (it
        // is a property of the decision, not of task timing); the
        // conversion/energy deltas are folded in after the stages run.
        let mut tasks: Vec<StageTask> = Vec::new();
        let mut serial_ns = 0.0f64;
        for w in 0..wave_count {
            if let Some(meta) = meta {
                for g in &meta[w] {
                    if g.decode {
                        self.decode_tokens += 1;
                    } else {
                        self.prefill_tokens += 1;
                    }
                }
            }
            for (li, layer) in graph.layers.iter().enumerate() {
                // KV residency decisions ride the same serial pass as
                // the weight-cache decisions: per wave, per qkv layer
                // (blocks ascending), per item in wave order — the
                // exact access stream `decode::replay_prefill` /
                // `replay_lockstep` reproduce for the planner.
                if let Some(meta) = meta {
                    if layer.context > 0 && layer.role == LayerRole::Qkv {
                        for g in &meta[w] {
                            let fp = decode::kv_footprint_bits(
                                layer.shape.k,
                                layer.op.a_bits,
                                g.pos,
                                layer.context,
                            );
                            self.seq_cache.access((g.seq, layer.block), fp);
                        }
                    }
                }
                let key = (layer.index, class_pool(layer.shape.class));
                let hit = self.cache.touch(key);
                let slot = if hit {
                    self.cache.value_mut(key).clone()
                } else {
                    let slot: BankSlot = Arc::new(Mutex::new(None));
                    let footprint = Scheduler::layer_weight_bits(&layer.shape, layer.op);
                    self.cache.insert(key, slot.clone(), footprint);
                    tasks.push(StageTask {
                        kind: TaskKind::Program,
                        wave: w,
                        li,
                        stage: w + li,
                        slot: slot.clone(),
                    });
                    slot
                };
                let st = &mut self.stats[li];
                if hit {
                    st.reload_hits += 1;
                } else {
                    st.reload_misses += 1;
                    self.paid_reload_ns += self.pipeline.layers[li].reload_ns;
                    serial_ns += self.pipeline.layers[li].reload_ns;
                }
                serial_ns += self.pipeline.layers[li].compute_ns;
                tasks.push(StageTask { kind: TaskKind::Convert, wave: w, li, stage: w + li + 1, slot });
            }
        }
        // Measured-modeled pass latency: each barrier-separated stage
        // is as wide as its widest task (program = the layer's reload,
        // convert = its conversions) — the staged analogue of the
        // planner's double-buffer fold, with only real misses paying.
        let mut stage_ns = vec![0.0f64; stage_count];
        let mut by_stage: Vec<Vec<usize>> = vec![Vec::new(); stage_count];
        for (i, t) in tasks.iter().enumerate() {
            let width = match t.kind {
                TaskKind::Program => self.pipeline.layers[t.li].reload_ns,
                TaskKind::Convert => self.pipeline.layers[t.li].compute_ns,
            };
            stage_ns[t.stage] = stage_ns[t.stage].max(width);
            by_stage[t.stage].push(i);
        }
        let staged_ns = stats::sum_ordered(stage_ns.iter().copied());

        let params = &self.params;
        let config = self.config;
        let kv = self.kv.clone();
        let run_task = |t: &StageTask| match t.kind {
            TaskKind::Program => {
                perturb::maybe_yield(perturb::TASK_PROGRAM);
                let layer = &graph.layers[t.li];
                let w = Self::layer_weights(params, layer);
                let built = DieBank::in_pool(
                    params,
                    &w,
                    layer.op,
                    config.shards.max(1),
                    config.dies_for(layer.shape.class),
                    class_pool(layer.shape.class),
                );
                let slot = &t.slot;
                let mut sg = slot.lock().expect("bank slot lock");
                *sg = Some(built);
            }
            TaskKind::Convert => {
                perturb::maybe_yield(perturb::TASK_CONVERT);
                let layer = &graph.layers[t.li];
                let wave = &wave_states[t.wave];
                let mut wg = wave.lock().expect("wave state lock");
                if wg.err.is_some() {
                    return;
                }
                let slot = &t.slot;
                let mut sg = slot.lock().expect("bank slot lock");
                let bank = match sg.as_mut() {
                    Some(Ok(bank)) => bank,
                    Some(Err(e)) => {
                        wg.err = Some(format!("{}: {e}", layer.name()));
                        return;
                    }
                    None => {
                        wg.err = Some(format!("{}: die bank never programmed", layer.name()));
                        return;
                    }
                };
                let c0 = bank.total_conversions();
                let e0 = bank.total_energy_pj();
                let mut ys = match bank.matvec_batch(&wg.acts) {
                    Ok(ys) => ys,
                    Err(e) => {
                        wg.err = Some(format!("{}: {e}", layer.name()));
                        return;
                    }
                };
                wg.deltas[t.li] =
                    Some((bank.total_conversions() - c0, bank.total_energy_pj() - e0));
                drop(sg);
                // Generate waves: fold each item's raw qkv outputs into
                // its sequence's per-block KV state (wave lock held,
                // bank slot released — lock order wave → kv).
                if let Some(meta) = meta {
                    if layer.context > 0 && layer.role == LayerRole::Qkv {
                        let mut states = kv.lock().expect("kv state lock");
                        for (i, g) in meta[t.wave].iter().enumerate() {
                            decode::fold_kv(
                                states.entry((g.seq, layer.block)).or_default(),
                                &mut ys[i],
                            );
                        }
                    }
                }
                if t.li + 1 < layer_count {
                    let next = &graph.layers[t.li + 1];
                    wg.acts = ys
                        .iter()
                        .map(|y| periphery::glue(layer.role, y, next.shape.k, next.op.a_bits))
                        .collect();
                } else {
                    wg.out = ys;
                }
            }
        };
        let threads = default_threads();
        for ids in &by_stage {
            if ids.is_empty() {
                continue;
            }
            if self.config.overlap && threads > 1 && ids.len() > 1 {
                // Work stealing: stage tasks are claimed from a shared
                // queue by whichever worker frees up first.
                let queue = WorkQueue::new();
                for &i in ids {
                    let _accepted = queue.push(i);
                }
                queue.close();
                std::thread::scope(|scope| {
                    for _ in 0..threads.min(ids.len()) {
                        scope.spawn(|| {
                            while let Some(i) = queue.pop() {
                                run_task(&tasks[i]);
                            }
                        });
                    }
                });
            } else {
                for &i in ids {
                    run_task(&tasks[i]);
                }
            }
        }
        drop(run_task);
        // Fold per-task deltas into the cumulative stats in fixed
        // wave-major order, then emit per-wave results.
        let mut results = Vec::with_capacity(wave_count);
        for ws in wave_states {
            let ws = ws.into_inner().expect("wave state lock");
            for (li, d) in ws.deltas.iter().enumerate() {
                if let Some((conversions, energy_pj)) = d {
                    let st = &mut self.stats[li];
                    st.calls += 1;
                    st.conversions += conversions;
                    st.energy_pj += energy_pj;
                }
            }
            self.passes += 1;
            results.push(match ws.err {
                Some(e) => Err(e),
                None => Ok(ws.out),
            });
        }
        self.last_pass_ns = staged_ns;
        self.last_serial_ns = serial_ns;
        results
    }

    /// Resident-weight cache counters: measured reload hits/misses,
    /// paid reload latency (the amortization numerator), current
    /// residency against capacity, and the modeled cold/warm full-pass
    /// latencies.
    pub fn residency_stats(&self) -> ResidencyStats {
        ResidencyStats {
            reload_hits: self.stats.iter().map(|s| s.reload_hits).sum(),
            reload_misses: self.stats.iter().map(|s| s.reload_misses).sum(),
            evictions: self.cache.evictions(),
            resident_bits: self.cache.resident_bits(),
            capacity_bits: self.cache.total_capacity_bits(),
            paid_reload_ns: self.paid_reload_ns,
            passes: self.passes,
            cold_pass_ns: self.pipeline.pipelined_ns,
            warm_pass_ns: self.pipeline.warm_pipelined_ns,
        }
    }

    /// Run generation waves through the staged engine: one
    /// [`GenStep`] per wave item, each embedded deterministically
    /// ([`decode::embed_token`]) and folded through its sequence's KV
    /// state at every attention-context `qkv` layer. Returns the scaled
    /// logits per wave item — the serving tier picks next tokens from
    /// them via [`decode::argmax`]. Prefill positions and decode steps
    /// ride the same waves; the caller (the stream tier) fixes item
    /// order to (sequence, position).
    pub fn decode_many(&mut self, waves: &[Vec<GenStep>]) -> Vec<Result<Vec<Vec<f32>>, String>> {
        let first = &self.graph.layers[0];
        let (k0, a0) = (first.shape.k, first.op.a_bits);
        let acts: Vec<Vec<Vec<i32>>> = waves
            .iter()
            .map(|w| w.iter().map(|g| decode::embed_token(g.tok, k0, a0)).collect())
            .collect();
        let outs = self.run_waves(&acts, Some(waves));
        outs.into_iter().map(|r| r.map(|ys| self.scale_outputs(ys))).collect()
    }

    /// Drop a finished sequence's KV state: its host-side fold digests
    /// and its residency entries (freeing die capacity for live ones).
    pub fn release_seq(&mut self, seq: u64) {
        self.seq_cache.remove_seq(seq);
        let mut states = self.kv.lock().expect("kv state lock");
        let keys: Vec<(u64, usize)> =
            states.range((seq, 0)..=(seq, usize::MAX)).map(|(key, _)| *key).collect();
        for key in keys {
            states.remove(&key);
        }
    }

    /// Measured generation counters: the live [`SeqStateCache`]'s
    /// hit/miss/eviction stream plus the executed prefill/decode token
    /// counts. The KV counters are decided in the serial decision pass,
    /// so they are identical across thread counts and overlap settings
    /// — and equal to `Scheduler::plan_decode`'s replay over the same
    /// trace.
    pub fn gen_stats(&self) -> GenStats {
        GenStats {
            kv_hits: self.seq_cache.hits(),
            kv_misses: self.seq_cache.misses(),
            kv_evictions: self.seq_cache.evictions(),
            prefill_tokens: self.prefill_tokens,
            decode_tokens: self.decode_tokens,
        }
    }

    /// Replace the KV residency budget (e.g. to mirror a planner
    /// scenario). Resets the policy's entries and counters; the
    /// host-side state values — and therefore served outputs — are
    /// untouched, because residency is pricing, not correctness.
    pub fn set_kv_capacity_bits(&mut self, capacity_bits: u64) {
        self.seq_cache = SeqStateCache::new(capacity_bits);
    }

    /// The exact reference **decode walk**: schedule-free greedy
    /// generation with `matvec_exact`, the same deterministic embedding,
    /// per-block KV folds, periphery glue, output scaling and argmax
    /// tie-break as the staged engine's generate path. Returns the
    /// produced tokens and the scaled logits at each producing position
    /// (the last entry is the finished sequence's final logits). At zero
    /// noise, serving `"kind": "generate"` must reproduce this exactly
    /// for any arrival interleaving × thread count × overlap setting.
    pub fn reference_decode(
        &self,
        prompt: &[u32],
        max_new_tokens: usize,
    ) -> (Vec<u32>, Vec<Vec<f32>>) {
        if prompt.is_empty() || max_new_tokens == 0 {
            return (Vec::new(), Vec::new());
        }
        let first = &self.graph.layers[0];
        let (k0, a0) = (first.shape.k, first.op.a_bits);
        let layer_count = self.graph.layers.len();
        let mut states: BTreeMap<usize, Vec<i64>> = BTreeMap::new();
        let mut tokens: Vec<u32> = prompt.to_vec();
        let mut produced: Vec<u32> = Vec::new();
        let mut logits_trace: Vec<Vec<f32>> = Vec::new();
        let positions = prompt.len() + max_new_tokens - 1;
        for pos in 0..positions {
            let mut acts = vec![decode::embed_token(tokens[pos], k0, a0)];
            let mut last: Vec<Vec<i64>> = Vec::new();
            for li in 0..layer_count {
                let layer = &self.graph.layers[li];
                let w = Self::layer_weights(&self.params, layer);
                let mut ys: Vec<Vec<i64>> = acts.iter().map(|x| matvec_exact(&w, x)).collect();
                if layer.context > 0 && layer.role == LayerRole::Qkv {
                    decode::fold_kv(states.entry(layer.block).or_default(), &mut ys[0]);
                }
                if li + 1 < layer_count {
                    let next = &self.graph.layers[li + 1];
                    acts = ys
                        .iter()
                        .map(|y| periphery::glue(layer.role, y, next.shape.k, next.op.a_bits))
                        .collect();
                } else {
                    last = ys;
                }
            }
            if pos + 1 >= prompt.len() {
                let lg = self
                    .scale_outputs(last)
                    .pop()
                    .expect("reference decode emits one vector per position");
                let next = decode::argmax(&lg);
                logits_trace.push(lg);
                produced.push(next);
                tokens.push(next);
            }
        }
        (produced, logits_trace)
    }

    /// The exact digital reference: the same walk (same weights, same
    /// featurization and glue) with `matvec_exact` instead of the macro
    /// banks. At zero noise, [`forward_ints`](Self::forward_ints) must
    /// equal this for any (threads × shards × dies) decomposition.
    pub fn reference_ints(&self, xs: &[Vec<i32>]) -> Vec<Vec<i64>> {
        Self::walk_graph(&self.graph, xs, |_, layer, acts| {
            let w = Self::layer_weights(&self.params, layer);
            Ok(acts.iter().map(|x| matvec_exact(&w, x)).collect())
        })
        .expect("exact reference walk is infallible")
    }

    /// The exact reference walk as *served* logits: featurization,
    /// [`reference_ints`](Self::reference_ints) and the same output
    /// scaling [`execute`](BatchExecutor::execute) applies — so the
    /// server-level streaming and fixed-batch paths can be anchored to
    /// the digital reference end to end (f32 for f32), not just at the
    /// integer layer.
    pub fn reference_logits(&self, images: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let xs = self.featurize_images(images);
        self.scale_outputs(self.reference_ints(&xs))
    }

    /// Featurize images into the first layer's input vectors.
    pub fn featurize_images(&self, images: &[Vec<f32>]) -> Vec<Vec<i32>> {
        let first = &self.graph.layers[0];
        images.iter().map(|img| featurize(first.op, first.shape.k, img)).collect()
    }

    /// Cumulative per-layer accounting: measured bank counters plus the
    /// modeled per-pass compute/reload latencies.
    pub fn layer_costs(&self) -> Vec<LayerCost> {
        self.graph
            .layers
            .iter()
            .zip(&self.stats)
            .zip(&self.pipeline.layers)
            .map(|((l, s), t)| {
                // Report the *effective* voting point: CbMode::Off never
                // votes, whatever the plan's NoisePoint says.
                let (votes, last_bits) = match l.op.cb {
                    CbMode::On => (l.op.noise.mv_votes as u64, l.op.noise.mv_last_bits as u64),
                    CbMode::Off => (1, 0),
                };
                LayerCost {
                    name: l.name(),
                    class: l.shape.class.label(),
                    calls: s.calls,
                    conversions: s.conversions,
                    energy_pj: s.energy_pj,
                    compute_ns: t.compute_ns,
                    reload_ns: t.reload_ns,
                    reload_hits: s.reload_hits,
                    reload_misses: s.reload_misses,
                    mv_votes: votes,
                    mv_last_bits: last_bits,
                }
            })
            .collect()
    }

    /// Scale raw last-layer integers into O(1) logits (argmax-invariant).
    fn scale_outputs(&self, ys: Vec<Vec<i64>>) -> Vec<Vec<f32>> {
        let last = self.graph.layers.last().expect("graph has layers");
        let (_, w_hi) = last.op.w_range();
        let (_, a_hi) = last.op.a_range();
        let scale =
            (last.shape.k as f64 * (w_hi.max(1) as f64) * (a_hi.max(1) as f64)).recip();
        ys.into_iter()
            .map(|y| y.into_iter().map(|v| (v as f64 * scale) as f32).collect())
            .collect()
    }
}

impl BatchExecutor for ModelExecutor {
    fn execute(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let xs = self.featurize_images(images);
        let ys = self.forward_ints(&xs)?;
        Ok(self.scale_outputs(ys))
    }

    fn forward(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute(images)
    }

    /// Multiple stream waves in one staged engine pass: wave `w`'s
    /// layer-`l` conversions overlap wave `w+1`'s layer-`l-1` work on
    /// disjoint pools. Bit-identical to calling
    /// [`forward`](BatchExecutor::forward) per wave in order — the
    /// decision pass is wave-major — so the server can batch waves
    /// freely without changing any served logit.
    fn forward_many(&mut self, batches: &[Vec<Vec<f32>>]) -> Vec<Result<Vec<Vec<f32>>, String>> {
        let mut results: Vec<Option<Result<Vec<Vec<f32>>, String>>> =
            batches.iter().map(|b| if b.is_empty() { Some(Ok(Vec::new())) } else { None }).collect();
        let waves: Vec<Vec<Vec<i32>>> = batches
            .iter()
            .filter(|b| !b.is_empty())
            .map(|b| self.featurize_images(b))
            .collect();
        let outs = self.forward_ints_many(&waves);
        let mut it = outs.into_iter();
        for r in results.iter_mut() {
            if r.is_none() {
                let wave = it.next().expect("engine returns one result per wave");
                *r = Some(wave.map(|ys| self.scale_outputs(ys)));
            }
        }
        results.into_iter().map(|r| r.expect("every wave slot filled")).collect()
    }

    fn decode_many(&mut self, waves: &[Vec<GenStep>]) -> Vec<Result<Vec<Vec<f32>>, String>> {
        ModelExecutor::decode_many(self, waves)
    }

    fn release_seq(&mut self, seq: u64) {
        ModelExecutor::release_seq(self, seq);
    }

    fn gen_stats(&self) -> Option<GenStats> {
        Some(ModelExecutor::gen_stats(self))
    }

    fn graph_layers(&self) -> usize {
        self.graph.layer_count()
    }

    fn layer_breakdown(&self) -> Vec<LayerCost> {
        self.layer_costs()
    }

    fn residency(&self) -> Option<ResidencyStats> {
        Some(self.residency_stats())
    }

    fn cost(&self) -> &PlanCost {
        &self.cost
    }

    fn num_classes(&self) -> usize {
        self.graph.output_dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::plan::PrecisionPlan;
    use crate::vit::VitConfig;

    fn quiet_params() -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.sigma_cmp_lsb = 0.0;
        p.sigma_cmp_offset_lsb = 0.0;
        p.temperature_k = 0.0;
        p
    }

    fn plan_2b() -> PrecisionPlan {
        PrecisionPlan {
            name: "test 2b/2b",
            attention: OperatingPoint::new(2, 2, CbMode::Off),
            mlp: OperatingPoint::new(2, 2, CbMode::Off),
        }
    }

    fn tiny_cfg() -> VitConfig {
        // d_ff = 96 > 64 active rows: fc2 row-tiles even in the tiny rig.
        VitConfig { image: 16, patch: 4, dim: 48, depth: 2, heads: 4, mlp_ratio: 2, num_classes: 4 }
    }

    fn images(n: usize, k: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|i| (0..k).map(|j| ((i * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect())
            .collect()
    }

    #[test]
    fn periphery_glue_stays_in_range_and_is_deterministic() {
        let y = vec![123_456_789i64, -987, 0, 42];
        for role in
            [LayerRole::Qkv, LayerRole::AttnProj, LayerRole::Fc1, LayerRole::Fc2]
        {
            for a_bits in [1u32, 2, 4, 8] {
                let lo = -(1i32 << (a_bits - 1));
                let hi = (1i32 << (a_bits - 1)) - 1;
                let x = periphery::glue(role, &y, 11, a_bits);
                assert_eq!(x.len(), 11);
                assert!(
                    x.iter().all(|&v| v >= lo && v <= hi),
                    "{role:?} a_bits {a_bits}: {x:?}"
                );
                assert_eq!(x, periphery::glue(role, &y, 11, a_bits));
            }
        }
    }

    #[test]
    fn zero_noise_forward_equals_reference_walk() {
        let p = quiet_params();
        let graph = ModelGraph::encoder(&tiny_cfg(), 2, &plan_2b());
        let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
        let xs = exec.featurize_images(&images(3, 32));
        let want = exec.reference_ints(&xs);
        let got = exec.forward_ints(&xs).unwrap();
        assert_eq!(got, want);
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(|y| y.len() == exec.graph.output_dim()));
        assert_eq!(exec.passes(), 1);
    }

    #[test]
    fn layer_stats_accumulate_across_passes() {
        let p = quiet_params();
        let graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan_2b());
        let mut exec = ModelExecutor::new(&p, graph, PipelineConfig::default()).unwrap();
        let xs = exec.featurize_images(&images(2, 32));
        exec.forward_ints(&xs).unwrap();
        let once = exec.layer_costs();
        assert_eq!(once.len(), 8); // 2 blocks × 4 linears
        assert!(once.iter().all(|l| l.calls == 1 && l.conversions > 0 && l.energy_pj > 0.0));
        assert!(once.iter().all(|l| l.compute_ns > 0.0 && l.reload_ns > 0.0));
        exec.forward_ints(&xs).unwrap();
        let twice = exec.layer_costs();
        for (a, b) in once.iter().zip(&twice) {
            assert_eq!(b.calls, 2);
            assert_eq!(b.conversions, 2 * a.conversions, "{}", a.name);
            // Every pass is either a reload hit or a miss — per-pass
            // conversion deltas stay exact either way.
            assert_eq!(b.reload_hits + b.reload_misses, 2, "{}", a.name);
        }
        // Class labels partition the graph 50/50 for the encoder.
        let att = twice.iter().filter(|l| l.class == "Transformer attention").count();
        assert_eq!(att, 4);
    }

    #[test]
    fn executor_cost_is_per_inference_with_pipelined_latency() {
        let p = quiet_params();
        // Batch 1: the per-inference cost IS the full-pass pipeline.
        let one = ModelExecutor::new(
            &p,
            ModelGraph::encoder(&tiny_cfg(), 1, &plan_2b()),
            PipelineConfig::default(),
        )
        .unwrap();
        let pp1 = one.pipeline();
        assert!(pp1.pipelined_ns < pp1.serial_ns, "{} vs {}", pp1.pipelined_ns, pp1.serial_ns);
        assert!((one.cost.total.latency_ns - pp1.pipelined_ns).abs() < 1e-9);
        assert!(one.cost.energy_uj > 0.0);
        // Batch 4: the installed cost stays per-inference (the server's
        // record_batch multiplies by exec_size), while pipeline()
        // reports the full batch.
        let four = ModelExecutor::new(
            &p,
            ModelGraph::encoder(&tiny_cfg(), 4, &plan_2b()),
            PipelineConfig::default(),
        )
        .unwrap();
        assert!((four.cost.total.energy_pj - one.cost.total.energy_pj).abs() < 1e-6);
        assert_eq!(four.cost.total.conversions, one.cost.total.conversions);
        assert!(four.pipeline().total.energy_pj > 3.9 * one.cost.total.energy_pj);
    }

    #[test]
    fn rejects_empty_graph_and_bad_ops() {
        let p = quiet_params();
        let mut graph = ModelGraph::encoder(&tiny_cfg(), 1, &plan_2b());
        graph.layers.clear();
        assert!(ModelExecutor::new(&p, graph, PipelineConfig::default()).is_err());
        let mut bad = ModelGraph::encoder(&tiny_cfg(), 1, &plan_2b());
        bad.layers[0].op.a_bits = 0;
        assert!(ModelExecutor::new(&p, bad, PipelineConfig::default()).is_err());
    }

    #[test]
    fn overlap_toggle_and_multi_wave_are_bit_identical_even_with_noise() {
        // The strong engine contract: threading (overlap on/off) and
        // wave batching (forward_ints_many vs one-by-one) change
        // wall-clock only — every output bit, every cache decision and
        // every noise draw is identical, because conversion order is
        // fixed by the decision pass + stage diagonals, not by timing.
        let mut p = MacroParams::default(); // noise stays ON
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 12;
        let graph = ModelGraph::encoder(&tiny_cfg(), 2, &plan_2b());
        let mk = |overlap: bool| {
            ModelExecutor::new(
                &p,
                graph.clone(),
                PipelineConfig { shards: 2, attention_dies: 2, mlp_dies: 1, overlap },
            )
            .unwrap()
        };
        let mut on = mk(true);
        let mut off = mk(false);
        let w1 = on.featurize_images(&images(3, 32));
        let w2 = on.featurize_images(&images(2, 32));
        // Cold pass then warm pass: on == off bit for bit.
        for pass in 0..2 {
            let a = on.forward_ints(&w1).unwrap();
            let b = off.forward_ints(&w1).unwrap();
            assert_eq!(a, b, "pass {pass}");
            assert!(on.last_pass_ns() <= on.last_serial_ns() + 1e-9);
        }
        // Multi-wave == the same waves run one by one, stats included.
        let mut seq = mk(true);
        let mut many = mk(true);
        let got: Vec<_> = many
            .forward_ints_many(&[w1.clone(), w2.clone(), w1.clone()])
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        let r1 = seq.forward_ints(&w1).unwrap();
        let r2 = seq.forward_ints(&w2).unwrap();
        let r3 = seq.forward_ints(&w1).unwrap();
        assert_eq!(got, vec![r1, r2, r3]);
        assert_eq!(many.passes(), 3);
        let (sm, ss) = (many.residency_stats(), seq.residency_stats());
        assert_eq!(
            (sm.reload_hits, sm.reload_misses, sm.evictions),
            (ss.reload_hits, ss.reload_misses, ss.evictions)
        );
        assert!((sm.paid_reload_ns - ss.paid_reload_ns).abs() < 1e-9);
    }

    fn decoder_exec(context: usize) -> ModelExecutor {
        use crate::vit::graph::GraphConfig;
        let gc = GraphConfig { vit: tiny_cfg(), context };
        let graph = ModelGraph::decoder(&gc, &plan_2b());
        ModelExecutor::new(&quiet_params(), graph, PipelineConfig::default()).unwrap()
    }

    fn prefill_wave(seq: u64, prompt: &[u32]) -> Vec<GenStep> {
        prompt
            .iter()
            .enumerate()
            .map(|(pos, &tok)| GenStep { seq, pos, tok, decode: false })
            .collect()
    }

    #[test]
    fn zero_noise_decode_matches_reference_walk() {
        let prompt = [3u32, 1, 4];
        let max_new = 3usize;
        let exec = decoder_exec(8);
        let (want_toks, want_logits) = exec.reference_decode(&prompt, max_new);
        assert_eq!(want_toks.len(), max_new);
        assert_eq!(want_logits.len(), max_new);
        // The same walk through the staged engine: the prompt as one
        // prefill wave, then one decode step per produced token.
        let mut engine = decoder_exec(8);
        let mut wave = prefill_wave(1, &prompt);
        let mut got_toks = Vec::new();
        let mut got_logits = Vec::new();
        let mut next_pos = prompt.len();
        loop {
            let out = engine.decode_many(&[wave.clone()]).pop().unwrap().unwrap();
            let lg = out.last().unwrap().clone();
            let tok = decode::argmax(&lg);
            got_logits.push(lg);
            got_toks.push(tok);
            if got_toks.len() == max_new {
                break;
            }
            wave = vec![GenStep { seq: 1, pos: next_pos, tok, decode: true }];
            next_pos += 1;
        }
        assert_eq!(got_toks, want_toks);
        assert_eq!(got_logits, want_logits);
        let gs = engine.gen_stats();
        assert_eq!(gs.prefill_tokens, prompt.len() as u64);
        assert_eq!(gs.decode_tokens, (max_new - 1) as u64);
    }

    #[test]
    fn release_seq_resets_kv_state_and_state_accumulates_without_it() {
        let mut exec = decoder_exec(8);
        let wave = prefill_wave(1, &[5, 2]);
        let a = exec.decode_many(&[wave.clone()]);
        // Releasing the sequence clears its fold state: the same prompt
        // replays bit-identically.
        exec.release_seq(1);
        let b = exec.decode_many(&[wave.clone()]);
        assert_eq!(
            a.iter().map(|r| r.as_ref().unwrap()).collect::<Vec<_>>(),
            b.iter().map(|r| r.as_ref().unwrap()).collect::<Vec<_>>()
        );
        // Without a release, the per-block state keeps accumulating, so
        // re-folding the same positions yields different digests.
        let c = exec.decode_many(&[wave]);
        assert_ne!(
            b.iter().map(|r| r.as_ref().unwrap()).collect::<Vec<_>>(),
            c.iter().map(|r| r.as_ref().unwrap()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn measured_kv_counters_equal_planner_replay_over_canonical_trace() {
        // The acceptance-criterion chokepoint, at the unit level: drive
        // the executor with the canonical serving trace (per-sequence
        // prefill waves, then lockstep decode waves) and compare its
        // measured KV counters to the planner-side replay of the same
        // trace at the same capacity.
        let prompt = [7u32, 7, 7];
        // Tight enough that grown footprints force evictions mid-trace.
        let (live, steps, cap) = (2usize, 4usize, 2_500u64);
        let mut exec = decoder_exec(8);
        exec.set_kv_capacity_bits(cap);
        let prefills: Vec<Vec<GenStep>> =
            (1..=live as u64).map(|seq| prefill_wave(seq, &prompt)).collect();
        exec.decode_many(&prefills);
        for step in 0..steps {
            let wave: Vec<GenStep> = (1..=live as u64)
                .map(|seq| GenStep { seq, pos: prompt.len() + step, tok: 1, decode: true })
                .collect();
            exec.decode_many(&[wave]);
        }
        let gs = exec.gen_stats();
        let shape = decode::ReplayShape {
            live,
            blocks: exec.graph.cfg.depth,
            dim: exec.graph.cfg.dim,
            a_bits: plan_2b().attention.a_bits,
            context: 8,
        };
        let mut cache = SeqStateCache::new(cap);
        decode::replay_prefill(&mut cache, &shape, prompt.len());
        decode::replay_lockstep(&mut cache, &shape, prompt.len(), steps);
        assert_eq!(
            (gs.kv_hits, gs.kv_misses, gs.kv_evictions),
            (cache.hits(), cache.misses(), cache.evictions())
        );
        assert!(gs.kv_hits + gs.kv_misses > 0);
        assert_eq!(gs.prefill_tokens, (live * prompt.len()) as u64);
        assert_eq!(gs.decode_tokens, (live * steps) as u64);
    }

    #[test]
    fn class_pools_are_stable_under_the_other_pools_resizing() {
        // Attention pool die seeds must not move when the MLP pool
        // grows: the per-class salt isolates them. (Noisy *outputs*
        // still change downstream because activations flow through MLP
        // layers — the invariant is at the silicon-identity level.)
        let p = MacroParams::default();
        let a1 = p.clone().for_pool(class_pool(LayerClass::TransformerAttention)).for_die(0);
        let a2 = p.clone().for_pool(class_pool(LayerClass::TransformerAttention)).for_die(0);
        assert_eq!(a1.seed, a2.seed);
        let m = p.clone().for_pool(class_pool(LayerClass::TransformerMlp)).for_die(0);
        assert_ne!(a1.seed, m.seed);
    }

    #[test]
    fn sized_by_router_gives_both_classes_dies() {
        let p = MacroParams::default();
        let graph = ModelGraph::encoder(&VitConfig::vit_base(), 8, &PrecisionPlan::paper_sac());
        let cfg = PipelineConfig::sized_by_router(&p, &graph, 2, 6);
        assert_eq!(cfg.attention_dies + cfg.mlp_dies, 6);
        assert!(cfg.attention_dies >= 1 && cfg.mlp_dies >= 1);
        assert_eq!(cfg.shards, 2);
    }
}
