//! Power/latency/energy ledger: the coordinator's accounting of what the
//! macro spent, per inference and cumulatively. Drives the serving
//! metrics report (J/inference, inferences/s, effective TOPS/W) of the
//! end-to-end example and the Fig. 4/6 ablation benches.
//!
//! The ledger owns no counters of its own beyond the per-batch tallies:
//! graph executors push their cumulative per-layer breakdown
//! ([`LayerCost`]) and resident-weight cache snapshot
//! ([`ResidencyStats`]) after every executed batch, and the streaming
//! tier pushes its wave/occupancy/token-latency snapshot
//! ([`StreamSnapshot`]) after every conversion wave. [`Ledger::to_json`]
//! is the single source of the server's `{"cmd": "stats"}` report —
//! every field it emits is documented in `docs/SERVING.md`.

use std::time::Duration;

use crate::coordinator::sac::PlanCost;
use crate::util::json::Json;
use crate::util::stats::Moments;

/// Cumulative per-layer accounting reported by a model-graph executor
/// (see `coordinator::pipeline::ModelExecutor::layer_costs`): what each
/// graph layer actually spent across all forward passes so far.
#[derive(Clone, Debug)]
pub struct LayerCost {
    /// Graph layer name (`block3.fc2`).
    pub name: String,
    /// SAC class label (`Transformer attention` / `Transformer MLP`).
    pub class: &'static str,
    /// Forward passes this layer has executed.
    pub calls: u64,
    /// Simulated conversions actually performed (bank counters).
    pub conversions: u64,
    /// Simulated conversion energy [pJ] actually spent.
    pub energy_pj: f64,
    /// Modeled per-pass conversion latency [ns].
    pub compute_ns: f64,
    /// Modeled per-pass weight-reload latency [ns] (hidden behind the
    /// previous layer's conversions in the pipelined accounting; paid
    /// only on reload misses).
    pub reload_ns: f64,
    /// Passes that found this layer resident on its pool (reload
    /// skipped by the resident-weight cache).
    pub reload_hits: u64,
    /// Passes that (re)programmed this layer onto its pool.
    pub reload_misses: u64,
    /// Majority votes per boosted comparison at this layer's operating
    /// point (effective only when the CSNR boost is on; 1 when off).
    pub mv_votes: u64,
    /// Trailing SAR bits boosted at this layer's operating point
    /// (0 when the CSNR boost is off).
    pub mv_last_bits: u64,
}

/// Resident-weight cache counters reported by a graph executor (see
/// `coordinator::pipeline::ModelExecutor::residency_stats`): measured
/// reload hits/misses across all forward passes, the modeled reload
/// latency actually paid, the cache's current residency against its
/// capacity, and the modeled cold/warm full-pass latencies.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResidencyStats {
    /// Layer executions that skipped the reload (weights resident).
    pub reload_hits: u64,
    /// Layer executions that paid the reload (cold or evicted).
    pub reload_misses: u64,
    /// LRU evictions performed by the cache so far.
    pub evictions: u64,
    /// Weight bits currently resident across all pools.
    pub resident_bits: u64,
    /// Total residency capacity across all pools [bits].
    pub capacity_bits: u64,
    /// Modeled reload latency actually paid so far [ns].
    pub paid_reload_ns: f64,
    /// Forward passes executed.
    pub passes: u64,
    /// Modeled cold-pass (every layer reloads) pipelined latency [ns].
    pub cold_pass_ns: f64,
    /// Modeled warm-pass (steady-state residency) pipelined latency [ns].
    pub warm_pass_ns: f64,
}

impl ResidencyStats {
    /// Reload latency amortized over the passes that actually ran [ns]:
    /// `paid / passes` — the honest per-inference reload charge, cold
    /// first pass included.
    pub fn amortized_reload_ns(&self) -> f64 {
        if self.passes == 0 {
            0.0
        } else {
            self.paid_reload_ns / self.passes as f64
        }
    }

    /// Fraction of layer executions that found weights resident.
    pub fn hit_rate(&self) -> f64 {
        let total = self.reload_hits + self.reload_misses;
        if total == 0 {
            0.0
        } else {
            self.reload_hits as f64 / total as f64
        }
    }
}

/// Streaming-tier accounting snapshot reported by the server's
/// token-level admission loop (`coordinator::stream::TokenStream`,
/// method `snapshot`): continuous-batching waves, their occupancy, and
/// the per-token latency distribution. Refreshed wholesale like the
/// other executor-owned snapshots; `None` on the ledger = no streaming
/// request was ever admitted.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamSnapshot {
    /// Stream requests fully served (all tokens completed).
    pub requests: u64,
    /// Tokens executed across all conversion waves.
    pub tokens_served: u64,
    /// Tokens currently queued or mid-wave.
    pub tokens_in_flight: u64,
    /// Conversion waves executed.
    pub waves: u64,
    /// Mean admitted-tokens / wave-size (waves carry no padding, so
    /// this is true macro occupancy, < 1 only for deadline-closed
    /// waves).
    pub mean_wave_occupancy: f64,
    /// p50 of measured token latency (arrival → wave completion) [µs].
    pub token_latency_p50_us: f64,
    /// p99 of measured token latency [µs].
    pub token_latency_p99_us: f64,
}

impl StreamSnapshot {
    /// Whether this snapshot carries live streaming state (waves ran or
    /// tokens are in flight). Note the server's refresh gate is
    /// *ever-admitted*, not this: an all-zero snapshot still overwrites
    /// a stale one after a purge.
    pub fn is_active(&self) -> bool {
        self.waves > 0 || self.tokens_in_flight > 0
    }
}

/// Generation-tier gauges for autoregressive (`"kind": "generate"`)
/// serving, composed by the server from two owners: the stream tier's
/// cadence counters (`coordinator::stream::TokenStream::gen_snapshot`)
/// and the executor's KV residency counters
/// (`coordinator::decode::GenStats`). `None` on the ledger = no
/// generate sequence was ever admitted.
#[derive(Clone, Copy, Debug, Default)]
pub struct GenSnapshot {
    /// Sequences currently mid-generation.
    pub sequences_active: u64,
    /// KV residency hits across all (sequence, block) accesses.
    pub kv_hits: u64,
    /// KV residency misses.
    pub kv_misses: u64,
    /// Sequence state evicted by the KV capacity bound.
    pub kv_evictions: u64,
    /// Prefill token items served.
    pub prefill_tokens: u64,
    /// Decode token items served.
    pub decode_tokens: u64,
    /// Produced-token throughput from the inter-token latency samples.
    pub decode_tokens_per_s: f64,
    /// p50 gap between consecutive produced tokens of a sequence [µs].
    pub intertoken_p50_us: f64,
    /// p99 inter-token gap [µs].
    pub intertoken_p99_us: f64,
}

impl GenSnapshot {
    /// Hit fraction of all KV residency accesses (0 when nothing ran).
    pub fn kv_hit_rate(&self) -> f64 {
        let total = self.kv_hits + self.kv_misses;
        if total == 0 {
            0.0
        } else {
            self.kv_hits as f64 / total as f64
        }
    }
}

/// Admission-control gauges pushed by the server (the server owns the
/// permits and queues; the ledger only reports them). `None` = the
/// serving path never refreshed them (e.g. a bare ledger in tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionSnapshot {
    /// Admission permits currently held (in-flight requests).
    pub inflight_permits: u64,
    /// The concurrency bound those permits are drawn from.
    pub max_inflight: u64,
    /// Work currently queued: pending fixed-batch requests plus queued
    /// stream tokens.
    pub queued_work: u64,
    /// The per-tier queue bound (`--queue-depth`).
    pub queue_depth_limit: u64,
}

/// Running serving statistics.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    inferences: u64,
    requests: u64,
    batches: u64,
    /// Well-formed requests refused admission (backpressure): answered
    /// with a documented shed error instead of queueing.
    shed: u64,
    /// Malformed requests rejected at parse/validation time.
    rejected_other: u64,
    /// Latest admission gauges from the server (refreshed after each
    /// executor step and on every `stats` request).
    admission: Option<AdmissionSnapshot>,
    macro_energy_pj: f64,
    macro_latency_ns: f64,
    host_latency: Moments,
    occupancy: Moments,
    conversions: u64,
    ops_1b: f64,
    /// Latest per-layer breakdown from a graph executor (cumulative on
    /// the executor side; refreshed wholesale after each batch).
    layers: Vec<LayerCost>,
    /// Latest resident-weight cache snapshot from a graph executor
    /// (refreshed wholesale after each batch; `None` = the serving
    /// executor keeps no weights resident).
    residency: Option<ResidencyStats>,
    /// Latest streaming-tier snapshot (refreshed after each conversion
    /// wave and on every `stats` request; `None` = no streaming request
    /// was ever admitted).
    stream: Option<StreamSnapshot>,
    /// Latest generation-tier gauges (refreshed like `stream`; `None` =
    /// no generate sequence was ever admitted).
    generation: Option<GenSnapshot>,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one executed batch: the modeled macro cost (from the SAC
    /// plan evaluation) plus the measured host-side wall time.
    pub fn record_batch(
        &mut self,
        requests: usize,
        exec_size: usize,
        cost_per_inference: &PlanCost,
        host_wall: Duration,
    ) {
        self.batches += 1;
        self.requests += requests as u64;
        self.inferences += exec_size as u64;
        self.macro_energy_pj += cost_per_inference.total.energy_pj * exec_size as f64;
        self.macro_latency_ns += cost_per_inference.total.latency_ns;
        self.conversions += cost_per_inference.total.conversions * exec_size as u64;
        self.ops_1b += cost_per_inference.total.ops_1b * exec_size as f64;
        self.host_latency.push(host_wall.as_secs_f64() * 1e6); // µs
        self.occupancy.push(requests as f64 / exec_size.max(1) as f64);
    }

    pub fn inferences(&self) -> u64 {
        self.inferences
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Modeled macro energy per useful request [µJ].
    pub fn energy_per_request_uj(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.macro_energy_pj * 1e-6 / self.requests as f64
    }

    /// Effective 1b-normalized TOPS/W of the macro over the session.
    pub fn effective_tops_per_watt(&self) -> f64 {
        if self.macro_energy_pj <= 0.0 {
            return 0.0;
        }
        self.ops_1b / (self.macro_energy_pj * 1e-12) / 1e12
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.mean()
    }

    pub fn mean_host_latency_us(&self) -> f64 {
        self.host_latency.mean()
    }

    /// Replace the per-layer breakdown with the executor's latest
    /// cumulative snapshot (the executor owns the counters; the ledger
    /// only reports them).
    pub fn set_layer_breakdown(&mut self, layers: Vec<LayerCost>) {
        self.layers = layers;
    }

    /// Latest per-layer breakdown (empty if no graph executor ran).
    pub fn layer_breakdown(&self) -> &[LayerCost] {
        &self.layers
    }

    /// Replace the residency snapshot with the executor's latest (the
    /// executor owns the cache; the ledger only reports it).
    pub fn set_residency(&mut self, residency: ResidencyStats) {
        self.residency = Some(residency);
    }

    /// Latest resident-weight cache snapshot, if a caching executor ran.
    pub fn residency(&self) -> Option<&ResidencyStats> {
        self.residency.as_ref()
    }

    /// Replace the streaming snapshot with the token stream's latest
    /// (the stream owns the counters; the ledger only reports them).
    pub fn set_stream(&mut self, stream: StreamSnapshot) {
        self.stream = Some(stream);
    }

    /// Latest streaming-tier snapshot, if any stream request was served.
    pub fn stream(&self) -> Option<&StreamSnapshot> {
        self.stream.as_ref()
    }

    /// Replace the generation gauges with the serving path's latest
    /// (stream tier + executor own the counters; the ledger reports).
    pub fn set_generation(&mut self, generation: GenSnapshot) {
        self.generation = Some(generation);
    }

    /// Latest generation gauges, if any generate sequence was admitted.
    pub fn generation(&self) -> Option<&GenSnapshot> {
        self.generation.as_ref()
    }

    /// Count one load-shed response (admission refused a well-formed
    /// request). Sheds also count into `rejected_total`.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Count one malformed-request rejection (parse/validation error).
    pub fn record_rejected(&mut self) {
        self.rejected_other += 1;
    }

    /// Requests shed by admission control.
    pub fn shed_requests(&self) -> u64 {
        self.shed
    }

    /// Every request that got an error instead of service: sheds plus
    /// malformed rejections.
    pub fn rejected_total(&self) -> u64 {
        self.shed + self.rejected_other
    }

    /// Replace the admission gauges with the server's latest (the
    /// server owns permits and queues; the ledger only reports them).
    pub fn set_admission(&mut self, admission: AdmissionSnapshot) {
        self.admission = Some(admission);
    }

    /// Latest admission gauges, if the serving path refreshed them.
    pub fn admission(&self) -> Option<&AdmissionSnapshot> {
        self.admission.as_ref()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests", Json::num(self.requests as f64));
        o.set("inferences", Json::num(self.inferences as f64));
        o.set("batches", Json::num(self.batches as f64));
        o.set("conversions", Json::num(self.conversions as f64));
        o.set("macro_energy_uj", Json::num(self.macro_energy_pj * 1e-6));
        o.set("energy_per_request_uj", Json::num(self.energy_per_request_uj()));
        o.set("effective_tops_per_watt", Json::num(self.effective_tops_per_watt()));
        o.set("mean_host_latency_us", Json::num(self.mean_host_latency_us()));
        o.set("mean_occupancy", Json::num(self.mean_occupancy()));
        // Rejection accounting is always emitted (zero is informative:
        // it distinguishes "no shedding" from "not measured").
        o.set("shed_requests", Json::num(self.shed as f64));
        o.set("rejected_total", Json::num(self.rejected_total() as f64));
        if let Some(a) = &self.admission {
            o.set("inflight_permits", Json::num(a.inflight_permits as f64));
            o.set("max_inflight", Json::num(a.max_inflight as f64));
            o.set("queue_depth", Json::num(a.queued_work as f64));
            o.set("queue_depth_limit", Json::num(a.queue_depth_limit as f64));
        }
        if let Some(r) = &self.residency {
            o.set("reload_hits", Json::num(r.reload_hits as f64));
            o.set("reload_misses", Json::num(r.reload_misses as f64));
            o.set("reload_hit_rate", Json::num(r.hit_rate()));
            o.set("cache_evictions", Json::num(r.evictions as f64));
            o.set("resident_bits", Json::num(r.resident_bits as f64));
            o.set("cache_capacity_bits", Json::num(r.capacity_bits as f64));
            o.set("amortized_reload_us", Json::num(r.amortized_reload_ns() * 1e-3));
            o.set("cold_pass_us", Json::num(r.cold_pass_ns * 1e-3));
            o.set("warm_pass_us", Json::num(r.warm_pass_ns * 1e-3));
        }
        if let Some(s) = &self.stream {
            o.set("stream_requests", Json::num(s.requests as f64));
            o.set("stream_tokens_served", Json::num(s.tokens_served as f64));
            o.set("tokens_in_flight", Json::num(s.tokens_in_flight as f64));
            o.set("stream_waves", Json::num(s.waves as f64));
            o.set("mean_wave_occupancy", Json::num(s.mean_wave_occupancy));
            o.set("token_latency_p50_us", Json::num(s.token_latency_p50_us));
            o.set("token_latency_p99_us", Json::num(s.token_latency_p99_us));
        }
        if let Some(g) = &self.generation {
            o.set("sequences_active", Json::num(g.sequences_active as f64));
            o.set("kv_hit_rate", Json::num(g.kv_hit_rate()));
            o.set("kv_evictions", Json::num(g.kv_evictions as f64));
            o.set("prefill_tokens", Json::num(g.prefill_tokens as f64));
            o.set("decode_tokens", Json::num(g.decode_tokens as f64));
            o.set("decode_tokens_per_s", Json::num(g.decode_tokens_per_s));
            o.set("intertoken_latency_p50_us", Json::num(g.intertoken_p50_us));
            o.set("intertoken_latency_p99_us", Json::num(g.intertoken_p99_us));
        }
        if !self.layers.is_empty() {
            let rows = self
                .layers
                .iter()
                .map(|l| {
                    let mut r = Json::obj();
                    r.set("layer", Json::str(&l.name));
                    r.set("class", Json::str(l.class));
                    r.set("calls", Json::num(l.calls as f64));
                    r.set("conversions", Json::num(l.conversions as f64));
                    r.set("energy_uj", Json::num(l.energy_pj * 1e-6));
                    r.set("compute_us", Json::num(l.compute_ns * 1e-3));
                    r.set("reload_us", Json::num(l.reload_ns * 1e-3));
                    r.set("reload_hits", Json::num(l.reload_hits as f64));
                    r.set("reload_misses", Json::num(l.reload_misses as f64));
                    r.set("mv_votes", Json::num(l.mv_votes as f64));
                    r.set("mv_last_bits", Json::num(l.mv_last_bits as f64));
                    Json::Obj(r)
                })
                .collect();
            o.set("layers", Json::Arr(rows));
        }
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::params::MacroParams;
    use crate::coordinator::sac::evaluate_plan;
    use crate::coordinator::scheduler::Scheduler;
    use crate::vit::plan::PrecisionPlan;
    use crate::vit::VitConfig;

    fn one_cost() -> PlanCost {
        let sched = Scheduler::new(&MacroParams::default());
        evaluate_plan(&sched, &VitConfig::default(), 1, &PrecisionPlan::paper_sac())
    }

    #[test]
    fn accounting_adds_up() {
        let cost = one_cost();
        let mut l = Ledger::new();
        l.record_batch(3, 4, &cost, Duration::from_micros(500));
        l.record_batch(4, 4, &cost, Duration::from_micros(700));
        assert_eq!(l.requests(), 7);
        assert_eq!(l.inferences(), 8);
        // Energy per *request* exceeds per-inference cost because padding
        // is wasted work.
        let per_req = l.energy_per_request_uj();
        assert!(per_req > cost.energy_uj, "{per_req} vs {}", cost.energy_uj);
        assert!((l.mean_occupancy() - (0.75 + 1.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn effective_tops_per_watt_matches_plan() {
        let cost = one_cost();
        let mut l = Ledger::new();
        l.record_batch(4, 4, &cost, Duration::from_micros(100));
        let got = l.effective_tops_per_watt();
        assert!((got - cost.tops_per_watt_effective).abs() / got < 1e-9);
    }

    #[test]
    fn empty_ledger_is_zeroes() {
        let l = Ledger::new();
        assert_eq!(l.energy_per_request_uj(), 0.0);
        assert_eq!(l.effective_tops_per_watt(), 0.0);
    }

    #[test]
    fn json_report_has_fields() {
        let mut l = Ledger::new();
        l.record_batch(1, 1, &one_cost(), Duration::from_micros(10));
        let j = l.to_json();
        for key in ["requests", "energy_per_request_uj", "effective_tops_per_watt"] {
            assert!(j.get_path(key).is_some(), "{key}");
        }
        // No graph executor ran: no layers key.
        assert!(j.get_path("layers").is_none());
    }

    #[test]
    fn layer_breakdown_is_reported_in_json() {
        let mut l = Ledger::new();
        l.set_layer_breakdown(vec![
            LayerCost {
                name: "block0.qkv".into(),
                class: "Transformer attention",
                calls: 2,
                conversions: 1000,
                energy_pj: 5e6,
                compute_ns: 1e5,
                reload_ns: 4e4,
                reload_hits: 1,
                reload_misses: 1,
                mv_votes: 1,
                mv_last_bits: 0,
            },
            LayerCost {
                name: "block0.fc2".into(),
                class: "Transformer MLP",
                calls: 2,
                conversions: 3000,
                energy_pj: 2e7,
                compute_ns: 3e5,
                reload_ns: 1.8e5,
                reload_hits: 0,
                reload_misses: 2,
                mv_votes: 6,
                mv_last_bits: 3,
            },
        ]);
        let j = l.to_json();
        let rows = j.get_path("layers").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get_path("layer").unwrap().as_str().unwrap(), "block0.qkv");
        assert_eq!(rows[1].get_path("conversions").unwrap().as_f64().unwrap(), 3000.0);
        assert!((rows[1].get_path("energy_uj").unwrap().as_f64().unwrap() - 20.0).abs() < 1e-9);
        assert_eq!(rows[0].get_path("reload_hits").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(rows[1].get_path("reload_misses").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(rows[1].get_path("mv_votes").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(rows[1].get_path("mv_last_bits").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(rows[0].get_path("mv_votes").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(l.layer_breakdown().len(), 2);
        // Refresh replaces wholesale.
        l.set_layer_breakdown(Vec::new());
        assert!(l.to_json().get_path("layers").is_none());
    }

    #[test]
    fn residency_snapshot_is_reported_in_json() {
        let mut l = Ledger::new();
        // No caching executor ran: no residency keys at all.
        assert!(l.to_json().get_path("reload_hits").is_none());
        let r = ResidencyStats {
            reload_hits: 40,
            reload_misses: 8,
            evictions: 2,
            resident_bits: 1_000,
            capacity_bits: 4_000,
            paid_reload_ns: 80_000.0,
            passes: 6,
            cold_pass_ns: 50_000.0,
            warm_pass_ns: 30_000.0,
        };
        assert!((r.amortized_reload_ns() - 80_000.0 / 6.0).abs() < 1e-9);
        assert!((r.hit_rate() - 40.0 / 48.0).abs() < 1e-12);
        l.set_residency(r);
        let j = l.to_json();
        assert_eq!(j.get_path("reload_hits").unwrap().as_f64().unwrap(), 40.0);
        assert_eq!(j.get_path("reload_misses").unwrap().as_f64().unwrap(), 8.0);
        assert!((j.get_path("reload_hit_rate").unwrap().as_f64().unwrap() - 40.0 / 48.0).abs()
            < 1e-12);
        assert_eq!(j.get_path("cache_evictions").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get_path("resident_bits").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(j.get_path("cache_capacity_bits").unwrap().as_f64().unwrap(), 4000.0);
        assert!(
            (j.get_path("amortized_reload_us").unwrap().as_f64().unwrap() - 80.0 / 6.0).abs()
                < 1e-9
        );
        assert!((j.get_path("cold_pass_us").unwrap().as_f64().unwrap() - 50.0).abs() < 1e-12);
        assert!((j.get_path("warm_pass_us").unwrap().as_f64().unwrap() - 30.0).abs() < 1e-12);
        // Degenerate snapshot divides by nothing.
        let zero = ResidencyStats::default();
        assert_eq!(zero.amortized_reload_ns(), 0.0);
        assert_eq!(zero.hit_rate(), 0.0);
    }

    #[test]
    fn stream_snapshot_is_reported_in_json() {
        let mut l = Ledger::new();
        // No streaming tier ran: none of the stream keys appear.
        assert!(l.to_json().get_path("stream_waves").is_none());
        assert!(l.to_json().get_path("tokens_in_flight").is_none());
        let s = StreamSnapshot {
            requests: 3,
            tokens_served: 17,
            tokens_in_flight: 2,
            waves: 5,
            mean_wave_occupancy: 0.85,
            token_latency_p50_us: 120.0,
            token_latency_p99_us: 480.0,
        };
        assert!(s.is_active());
        l.set_stream(s);
        let j = l.to_json();
        assert_eq!(j.get_path("stream_requests").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get_path("stream_tokens_served").unwrap().as_f64().unwrap(), 17.0);
        assert_eq!(j.get_path("tokens_in_flight").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get_path("stream_waves").unwrap().as_f64().unwrap(), 5.0);
        let occ = j.get_path("mean_wave_occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 0.85).abs() < 1e-12);
        assert_eq!(j.get_path("token_latency_p50_us").unwrap().as_f64().unwrap(), 120.0);
        assert_eq!(j.get_path("token_latency_p99_us").unwrap().as_f64().unwrap(), 480.0);
        assert_eq!(l.stream().unwrap().waves, 5);
        // The empty snapshot reports nothing worth including.
        assert!(!StreamSnapshot::default().is_active());
    }

    #[test]
    fn generation_snapshot_is_reported_in_json() {
        let mut l = Ledger::new();
        // No generate sequence was ever admitted: no generation keys.
        assert!(l.to_json().get_path("kv_hit_rate").is_none());
        assert!(l.to_json().get_path("sequences_active").is_none());
        let g = GenSnapshot {
            sequences_active: 2,
            kv_hits: 30,
            kv_misses: 10,
            kv_evictions: 4,
            prefill_tokens: 12,
            decode_tokens: 7,
            decode_tokens_per_s: 2_500.0,
            intertoken_p50_us: 350.0,
            intertoken_p99_us: 900.0,
        };
        assert!((g.kv_hit_rate() - 0.75).abs() < 1e-12);
        l.set_generation(g);
        let j = l.to_json();
        assert_eq!(j.get_path("sequences_active").unwrap().as_f64().unwrap(), 2.0);
        assert!((j.get_path("kv_hit_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-12);
        assert_eq!(j.get_path("kv_evictions").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(j.get_path("prefill_tokens").unwrap().as_f64().unwrap(), 12.0);
        assert_eq!(j.get_path("decode_tokens").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(j.get_path("decode_tokens_per_s").unwrap().as_f64().unwrap(), 2500.0);
        assert_eq!(j.get_path("intertoken_latency_p50_us").unwrap().as_f64().unwrap(), 350.0);
        assert_eq!(j.get_path("intertoken_latency_p99_us").unwrap().as_f64().unwrap(), 900.0);
        assert_eq!(l.generation().unwrap().kv_misses, 10);
        // Degenerate gauges divide by nothing.
        assert_eq!(GenSnapshot::default().kv_hit_rate(), 0.0);
    }

    #[test]
    fn rejection_accounting_is_reported_in_json() {
        let mut l = Ledger::new();
        // The counters are always present — zero distinguishes "no
        // shedding" from "not measured" — but the gauges only appear
        // once the serving path refreshes them.
        let j = l.to_json();
        assert_eq!(j.get_path("shed_requests").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(j.get_path("rejected_total").unwrap().as_f64().unwrap(), 0.0);
        assert!(j.get_path("inflight_permits").is_none());
        l.record_shed();
        l.record_shed();
        l.record_rejected();
        l.set_admission(AdmissionSnapshot {
            inflight_permits: 3,
            max_inflight: 8,
            queued_work: 5,
            queue_depth_limit: 16,
        });
        let j = l.to_json();
        assert_eq!(j.get_path("shed_requests").unwrap().as_f64().unwrap(), 2.0);
        // rejected_total = sheds + malformed rejections.
        assert_eq!(j.get_path("rejected_total").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get_path("inflight_permits").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get_path("max_inflight").unwrap().as_f64().unwrap(), 8.0);
        assert_eq!(j.get_path("queue_depth").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.get_path("queue_depth_limit").unwrap().as_f64().unwrap(), 16.0);
        assert_eq!(l.shed_requests(), 2);
        assert_eq!(l.rejected_total(), 3);
        assert_eq!(l.admission().unwrap().max_inflight, 8);
    }
}
