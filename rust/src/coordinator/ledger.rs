//! Power/latency/energy ledger: the coordinator's accounting of what the
//! macro spent, per inference and cumulatively. Drives the serving
//! metrics report (J/inference, inferences/s, effective TOPS/W) of the
//! end-to-end example and the Fig. 4/6 ablation benches.

use std::time::Duration;

use crate::coordinator::sac::PlanCost;
use crate::util::json::Json;
use crate::util::stats::Moments;

/// Running serving statistics.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    inferences: u64,
    requests: u64,
    batches: u64,
    macro_energy_pj: f64,
    macro_latency_ns: f64,
    host_latency: Moments,
    occupancy: Moments,
    conversions: u64,
    ops_1b: f64,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one executed batch: the modeled macro cost (from the SAC
    /// plan evaluation) plus the measured host-side wall time.
    pub fn record_batch(
        &mut self,
        requests: usize,
        exec_size: usize,
        cost_per_inference: &PlanCost,
        host_wall: Duration,
    ) {
        self.batches += 1;
        self.requests += requests as u64;
        self.inferences += exec_size as u64;
        self.macro_energy_pj += cost_per_inference.total.energy_pj * exec_size as f64;
        self.macro_latency_ns += cost_per_inference.total.latency_ns;
        self.conversions += cost_per_inference.total.conversions * exec_size as u64;
        self.ops_1b += cost_per_inference.total.ops_1b * exec_size as f64;
        self.host_latency.push(host_wall.as_secs_f64() * 1e6); // µs
        self.occupancy.push(requests as f64 / exec_size.max(1) as f64);
    }

    pub fn inferences(&self) -> u64 {
        self.inferences
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Modeled macro energy per useful request [µJ].
    pub fn energy_per_request_uj(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.macro_energy_pj * 1e-6 / self.requests as f64
    }

    /// Effective 1b-normalized TOPS/W of the macro over the session.
    pub fn effective_tops_per_watt(&self) -> f64 {
        if self.macro_energy_pj <= 0.0 {
            return 0.0;
        }
        self.ops_1b / (self.macro_energy_pj * 1e-12) / 1e12
    }

    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy.mean()
    }

    pub fn mean_host_latency_us(&self) -> f64 {
        self.host_latency.mean()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests", Json::num(self.requests as f64));
        o.set("inferences", Json::num(self.inferences as f64));
        o.set("batches", Json::num(self.batches as f64));
        o.set("conversions", Json::num(self.conversions as f64));
        o.set("macro_energy_uj", Json::num(self.macro_energy_pj * 1e-6));
        o.set("energy_per_request_uj", Json::num(self.energy_per_request_uj()));
        o.set("effective_tops_per_watt", Json::num(self.effective_tops_per_watt()));
        o.set("mean_host_latency_us", Json::num(self.mean_host_latency_us()));
        o.set("mean_occupancy", Json::num(self.mean_occupancy()));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::params::MacroParams;
    use crate::coordinator::sac::evaluate_plan;
    use crate::coordinator::scheduler::Scheduler;
    use crate::vit::plan::PrecisionPlan;
    use crate::vit::VitConfig;

    fn one_cost() -> PlanCost {
        let sched = Scheduler::new(&MacroParams::default());
        evaluate_plan(&sched, &VitConfig::default(), 1, &PrecisionPlan::paper_sac())
    }

    #[test]
    fn accounting_adds_up() {
        let cost = one_cost();
        let mut l = Ledger::new();
        l.record_batch(3, 4, &cost, Duration::from_micros(500));
        l.record_batch(4, 4, &cost, Duration::from_micros(700));
        assert_eq!(l.requests(), 7);
        assert_eq!(l.inferences(), 8);
        // Energy per *request* exceeds per-inference cost because padding
        // is wasted work.
        let per_req = l.energy_per_request_uj();
        assert!(per_req > cost.energy_uj, "{per_req} vs {}", cost.energy_uj);
        assert!((l.mean_occupancy() - (0.75 + 1.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn effective_tops_per_watt_matches_plan() {
        let cost = one_cost();
        let mut l = Ledger::new();
        l.record_batch(4, 4, &cost, Duration::from_micros(100));
        let got = l.effective_tops_per_watt();
        assert!((got - cost.tops_per_watt_effective).abs() / got < 1e-9);
    }

    #[test]
    fn empty_ledger_is_zeroes() {
        let l = Ledger::new();
        assert_eq!(l.energy_per_request_uj(), 0.0);
        assert_eq!(l.effective_tops_per_watt(), 0.0);
    }

    #[test]
    fn json_report_has_fields() {
        let mut l = Ledger::new();
        l.record_batch(1, 1, &one_cost(), Duration::from_micros(10));
        let j = l.to_json();
        for key in ["requests", "energy_per_request_uj", "effective_tops_per_watt"] {
            assert!(j.get_path(key).is_some(), "{key}");
        }
    }
}
