//! Deterministic fixed-point digital periphery: the integer softmax,
//! LayerNorm and GELU kernels that sit between macro-mapped linears.
//!
//! The CR-CIM macro only computes linear layers; everything between them
//! — attention-score softmax, the residual-path LayerNorms, the MLP GELU
//! — runs in the 65 nm digital periphery. This module models that tier
//! as **pure integer** kernels so the macro walk and the exact reference
//! walk (`matvec_exact`) apply byte-identical glue: zero-noise serving
//! equals the reference bit-for-bit *structurally*, whatever the
//! thread/shard/die/wave decomposition, because no kernel here ever
//! touches a float or an iteration-order-dependent reduction.
//!
//! # Q-formats
//!
//! All fractional arithmetic is **Q16** (16 fractional bits, `i64`
//! carriers, `i128` intermediates): `ONE_Q == 1 << 16` represents 1.0.
//!
//! | kernel            | input            | output                        |
//! |-------------------|------------------|-------------------------------|
//! | [`iexp_q`]        | Q16, `z ≤ 0`     | Q16 in `[0, ~1.0003]`         |
//! | [`int_softmax`]   | raw `i64` logits | Q16 probabilities, `Σ ≈ 1.0`  |
//! | [`int_layernorm`] | raw `i64`        | Q16 z-scores (σ units)        |
//! | [`igelu_q`]       | Q16              | Q16                           |
//!
//! Every kernel has an `*_ref` f64 reference computed with the **same
//! integer pre-scaling decisions** (so the comparison isolates the
//! fixed-point rounding, not a different algorithm). The documented
//! error bands, enforced by `rust/tests/periphery.rs` golden vectors:
//!
//! - `iexp_q`: ≤ 262 Q16 ULP (4e-3 absolute) vs `exp` over `[-16, 0]` —
//!   the I-BERT-style second-order polynomial's error plus one trailing
//!   truncation per ln2 reduction step.
//! - `int_softmax`: ≤ 328 Q16 ULP (5e-3) per probability vs the f64
//!   softmax at the same integer input scale.
//! - `int_layernorm`: `|Δz| ≤ (1 + |z_ref|)/σ + 4·2⁻¹⁶` — the integer
//!   mean is floored (≤ 1 off) and the integer σ is `isqrt`-floored
//!   (relative error ≤ 1/σ).
//! - `igelu_q`: ≤ 0.02 absolute over `[-4, 4]` vs the sigmoid-form f64
//!   reference `z·σ(1.702·z)`.
//!
//! # Inter-layer glue
//!
//! [`glue`] is the one entry point the executor's walks use: it keys the
//! kernel on the **producing** layer's [`LayerRole`] (qkv → softmax,
//! fc1 → GELU, attn_proj/fc2 → LayerNorm), adapts the output length to
//! the next layer's reduction dimension by cyclic replication, and maps
//! the kernel's Q16 range into the next layer's signed `a_bits`
//! activation range. It replaces the former `requantize` stand-in.

use crate::vit::graph::LayerRole;

/// Q16 fixed point: fractional bits of every kernel in this module.
pub const Q: u32 = 16;
/// 1.0 in Q16.
pub const ONE_Q: i64 = 1 << Q;
/// ln 2 in Q16 (`round(0.6931472 · 2^16)`).
const LN2_Q: i64 = 45_426;
/// The exp polynomial on the ln2 remainder `r ∈ (-ln2, 0]`:
/// `exp(r) ≈ 0.3585·(r + 1.353)² + 0.344` (I-BERT's integer-friendly
/// second-order fit). Coefficients in Q16.
const EXP_A_Q: i64 = 23_497; // 0.3585
const EXP_B_Q: i64 = 88_670; // 1.353
const EXP_C_Q: i64 = 22_544; // 0.344
/// GELU's sigmoid slope 1.702 in Q16.
const GELU_K_Q: i64 = 111_542;

/// Fixed-point `exp(z)` for non-positive Q16 `z`, clamped to `[-16, 0]`
/// (Q16 underflows to 0 well before −16). Range reduction
/// `z = −q·ln2 + r` with `r ∈ (−ln2, 0]`, the Q16 polynomial above on
/// `r`, then an arithmetic right shift by `q`.
pub fn iexp_q(z: i64) -> i64 {
    let z = z.clamp(-(16 * ONE_Q), 0);
    let q = ((-z) / LN2_Q) as u32;
    let r = -((-z) % LN2_Q); // (-ln2, 0]
    let t = r + EXP_B_Q;
    let t2 = (t * t) >> Q; // t ≤ 1.353·2^16: t² < 2^34, no overflow
    let poly = ((EXP_A_Q * t2) >> Q) + EXP_C_Q;
    poly >> q.min(62)
}

/// f64 reference for [`iexp_q`] (the true exponential; the documented
/// band covers the polynomial *and* the fixed-point truncation).
pub fn iexp_ref(z: f64) -> f64 {
    z.clamp(-16.0, 0.0).exp()
}

/// Integer softmax over raw accumulator outputs, returning Q16
/// probabilities (`Σ ≈ ONE_Q`, short by at most one ULP per element
/// from the division floor).
///
/// The inputs are shift-normalized against the max (`d = x − max ≤ 0`)
/// and pre-scaled by the integer step `s = (max − min)/8 + 1` so every
/// exponent argument lands in `(-8, 0]` — inside [`iexp_q`]'s accurate
/// range whatever the accumulator magnitude. The scale is derived from
/// the data by integer ops only, so it is exactly reproducible.
pub fn int_softmax(x: &[i64]) -> Vec<i64> {
    debug_assert!(!x.is_empty(), "softmax needs at least one logit");
    let mx = *x.iter().max().expect("non-empty");
    let mn = *x.iter().min().expect("non-empty");
    let s = (mx as i128 - mn as i128) / 8 + 1;
    let es: Vec<i64> = x
        .iter()
        .map(|&v| {
            let arg = -(((mx as i128 - v as i128) * ONE_Q as i128) / s);
            iexp_q(arg as i64)
        })
        .collect();
    // Integer sum of n values ≤ ~2^17 each: overflows only beyond ~2^46
    // elements. (Integer reductions are order-independent — the lint's
    // float-reduction rule does not apply.)
    let sum: i64 = es.iter().sum::<i64>().max(1);
    es.iter().map(|&e| ((e as i128 * ONE_Q as i128) / sum as i128) as i64).collect()
}

/// f64 reference for [`int_softmax`]: the softmax of the inputs at the
/// **same integer scale** `s` (isolating the fixed-point error from the
/// scaling decision, which is shared).
pub fn softmax_ref(x: &[i64]) -> Vec<f64> {
    assert!(!x.is_empty());
    let mx = *x.iter().max().expect("non-empty");
    let mn = *x.iter().min().expect("non-empty");
    let s = ((mx as i128 - mn as i128) / 8 + 1) as f64;
    let es: Vec<f64> = x.iter().map(|&v| (-((mx - v) as f64) / s).exp()).collect();
    let sum = crate::util::stats::sum_ordered(es.iter().copied());
    es.iter().map(|&e| e / sum).collect()
}

/// Floor integer square root (Newton's method on integers; exact floor
/// for any `v ≥ 0`).
pub fn isqrt(v: i64) -> i64 {
    debug_assert!(v >= 0, "isqrt of negative");
    if v < 2 {
        return v.max(0);
    }
    let mut x = v;
    let mut y = (x + 1) / 2;
    while y < x {
        x = y;
        y = (x + v / x) / 2;
    }
    x
}

/// Integer LayerNorm: per-element z-scores `(x − µ)/σ` in Q16, with the
/// integer population mean (floored), variance accumulated in `i128`,
/// and `σ = isqrt(var)` (floored; `σ = 0` normalizes to 0 via the
/// `max(σ, 1)` guard). Affine scale/shift is identity — the macro's
/// stand-in weights carry no trained γ/β.
pub fn int_layernorm(x: &[i64]) -> Vec<i64> {
    debug_assert!(!x.is_empty(), "layernorm needs at least one element");
    let n = x.len() as i128;
    let sum: i128 = x.iter().map(|&v| v as i128).sum();
    let mean = sum.div_euclid(n) as i64;
    let sumsq: i128 = x.iter().map(|&v| (v as i128 - mean as i128).pow(2)).sum();
    let var = (sumsq / n).min(i64::MAX as i128) as i64;
    let sigma = isqrt(var).max(1);
    x.iter()
        .map(|&v| (((v as i128 - mean as i128) * ONE_Q as i128) / sigma as i128) as i64)
        .collect()
}

/// f64 reference for [`int_layernorm`] (population mean/σ; σ = 0 → 0).
pub fn layernorm_ref(x: &[i64]) -> Vec<f64> {
    assert!(!x.is_empty());
    let n = x.len() as f64;
    let mean = crate::util::stats::sum_ordered(x.iter().map(|&v| v as f64)) / n;
    let var =
        crate::util::stats::sum_ordered(x.iter().map(|&v| (v as f64 - mean).powi(2))) / n;
    let sigma = var.sqrt();
    if sigma == 0.0 {
        return vec![0.0; x.len()];
    }
    x.iter().map(|&v| (v as f64 - mean) / sigma).collect()
}

/// Fixed-point GELU (sigmoid form `z·σ(1.702·z)`) on Q16 inputs clamped
/// to `[-8, 8]`. The sigmoid is computed from [`iexp_q`] on the
/// negative half and mirrored (`σ(-u) = 1 − σ(u)`), so both tails use
/// the exponential in its accurate range.
pub fn igelu_q(z: i64) -> i64 {
    let z = z.clamp(-8 * ONE_Q, 8 * ONE_Q);
    let u = ((z as i128 * GELU_K_Q as i128) >> Q) as i64;
    let e = iexp_q(-u.abs());
    let s_hi = ((ONE_Q as i128 * ONE_Q as i128) / ((ONE_Q + e) as i128)) as i64;
    let sig = if u >= 0 { s_hi } else { ONE_Q - s_hi };
    ((z as i128 * sig as i128) >> Q) as i64
}

/// f64 reference for [`igelu_q`]: the sigmoid-form GELU.
pub fn gelu_ref(z: f64) -> f64 {
    let z = z.clamp(-8.0, 8.0);
    z / (1.0 + (-1.702 * z).exp())
}

/// Two's-complement activation range at `a_bits` (mirror of
/// `OperatingPoint::a_range`, kept local so the glue stays a pure
/// function of its arguments).
fn a_range(a_bits: u32) -> (i64, i64) {
    (-(1i64 << (a_bits - 1)), (1i64 << (a_bits - 1)) - 1)
}

/// Cyclic source index for adapting a kernel's `n`-long output to the
/// next layer's `k`-long reduction dimension (the stand-in for the
/// residual/reshape plumbing a real ViT block carries).
#[inline]
fn cyclic(i: usize, n: usize) -> usize {
    i % n
}

/// The digital inter-layer glue: apply the producing layer's periphery
/// kernel to its raw `i64` outputs and emit the next layer's `k`-long
/// activation vector in the next layer's signed `a_bits` range.
///
/// Kernel dispatch is keyed on the **producing** role:
///
/// - `Qkv` → [`int_softmax`] (attention scores): Q16 probabilities map
///   to `[0, a_hi]` (probabilities are non-negative).
/// - `Fc1` → [`igelu_q`] on inputs pre-scaled into `±4` by the integer
///   step `s = max|y|/4 + 1`; the `[-4, 4]`-ish GELU output maps to the
///   full signed range (±4 full scale).
/// - `AttnProj`/`Fc2` → [`int_layernorm`] (the residual-path norms):
///   z-scores map at ±4σ full scale, clamped.
///
/// Pure integer end to end: byte-identical between the macro walk and
/// the exact reference walk, at any thread/shard/die decomposition.
pub fn glue(role: LayerRole, y: &[i64], k: usize, a_bits: u32) -> Vec<i32> {
    debug_assert!(!y.is_empty(), "periphery glue needs at least one output");
    debug_assert!((1..=31).contains(&a_bits));
    let (lo, hi) = a_range(a_bits);
    let n = y.len();
    match role {
        LayerRole::Qkv => {
            let probs = int_softmax(y);
            (0..k)
                .map(|i| ((probs[cyclic(i, n)] as i128 * hi as i128) >> Q) as i32)
                .collect()
        }
        LayerRole::Fc1 => {
            let m = y.iter().map(|v| v.unsigned_abs()).max().expect("non-empty");
            let s = (m as i128) / 4 + 1;
            let g: Vec<i64> = y
                .iter()
                .map(|&v| igelu_q(((v as i128 * ONE_Q as i128) / s) as i64))
                .collect();
            (0..k)
                .map(|i| {
                    let v = (g[cyclic(i, n)] as i128 * hi as i128) / (4 * ONE_Q as i128);
                    (v as i64).clamp(lo, hi) as i32
                })
                .collect()
        }
        LayerRole::AttnProj | LayerRole::Fc2 => {
            let z = int_layernorm(y);
            (0..k)
                .map(|i| {
                    let v = (z[cyclic(i, n)] as i128 * hi as i128) / (4 * ONE_Q as i128);
                    (v as i64).clamp(lo, hi) as i32
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iexp_matches_reference_within_band() {
        // The documented band: ≤ 262 Q16 ULP (4e-3) over [-16, 0].
        for i in 0..=1600 {
            let zf = -(i as f64) / 100.0;
            let z = (zf * ONE_Q as f64).round() as i64;
            let got = iexp_q(z) as f64 / ONE_Q as f64;
            let want = iexp_ref(z as f64 / ONE_Q as f64);
            assert!(
                (got - want).abs() <= 4e-3,
                "z={zf}: got {got} want {want}"
            );
        }
        assert_eq!(iexp_q(-17 * ONE_Q), iexp_q(-16 * ONE_Q), "clamped below -16");
        assert_eq!(iexp_q(-40 * ONE_Q), 0, "deep tail underflows to zero");
    }

    #[test]
    fn softmax_is_a_distribution_and_tracks_reference() {
        let x: Vec<i64> = vec![-1200, 3400, 0, 911, -77, 2600, 15];
        let p = int_softmax(&x);
        let r = softmax_ref(&x);
        let total: i64 = p.iter().sum();
        // Floor divisions lose at most one ULP per element.
        assert!(total <= ONE_Q && total >= ONE_Q - x.len() as i64, "Σp = {total}");
        for (pi, ri) in p.iter().zip(&r) {
            assert!(*pi >= 0);
            let got = *pi as f64 / ONE_Q as f64;
            assert!((got - ri).abs() <= 5e-3, "got {got} want {ri}");
        }
        // Order-preserving: larger logits never get smaller probability.
        assert!(p[1] >= p[5] && p[5] >= p[3] && p[3] >= p[0]);
    }

    #[test]
    fn softmax_handles_degenerate_inputs() {
        // All-equal logits: exactly uniform (identical integer path).
        let p = int_softmax(&[42, 42, 42, 42]);
        assert_eq!(p[0], p[1]);
        assert_eq!(p[1], p[2]);
        assert_eq!(p[2], p[3]);
        // Single logit: probability ≈ 1 (one ULP of floor loss allowed).
        let one = int_softmax(&[-5]);
        assert!(one[0] >= ONE_Q - 1 && one[0] <= ONE_Q);
        // Huge spread stays in range (no overflow, args clamped).
        let wide = int_softmax(&[i64::MIN / 4, 0, i64::MAX / 4]);
        assert!(wide.iter().all(|&v| (0..=ONE_Q).contains(&v)));
        assert!(wide[2] > wide[1] && wide[1] >= wide[0]);
    }

    #[test]
    fn isqrt_is_exact_floor() {
        for v in 0..2000i64 {
            let r = isqrt(v);
            assert!(r * r <= v && (r + 1) * (r + 1) > v, "isqrt({v}) = {r}");
        }
        for &v in &[1i64 << 40, (1 << 52) + 12345, i64::MAX] {
            let r = isqrt(v);
            assert!(r as i128 * r as i128 <= v as i128);
            assert!((r as i128 + 1) * (r as i128 + 1) > v as i128);
        }
    }

    #[test]
    fn layernorm_matches_reference_within_band() {
        let x: Vec<i64> = (0..64i64).map(|i| (i * i * 37) % 4001 - 2000).collect();
        let z = int_layernorm(&x);
        let r = layernorm_ref(&x);
        let sigma = {
            let n = x.len() as f64;
            let mean = x.iter().map(|&v| v as f64).fold(0.0, |a, b| a + b) / n;
            (x.iter().map(|&v| (v as f64 - mean).powi(2)).fold(0.0, |a, b| a + b) / n).sqrt()
        };
        assert!(sigma > 100.0, "test vector must have healthy spread, σ = {sigma}");
        for (zi, ri) in z.iter().zip(&r) {
            let got = *zi as f64 / ONE_Q as f64;
            let band = (1.0 + ri.abs()) / sigma + 4.0 / ONE_Q as f64;
            assert!((got - ri).abs() <= band, "got {got} want {ri} band {band}");
        }
    }

    #[test]
    fn layernorm_degenerate_constant_vector_is_zero() {
        assert!(int_layernorm(&[7, 7, 7]).iter().all(|&v| v == 0));
        assert!(int_layernorm(&[0]).iter().all(|&v| v == 0));
    }

    #[test]
    fn gelu_matches_reference_within_band() {
        for i in -400..=400 {
            let zf = i as f64 / 100.0;
            let z = (zf * ONE_Q as f64).round() as i64;
            let got = igelu_q(z) as f64 / ONE_Q as f64;
            let want = gelu_ref(zf);
            assert!((got - want).abs() <= 0.02, "z={zf}: got {got} want {want}");
        }
        // Identity-ish for large positive, ~0 for large negative.
        assert!(igelu_q(8 * ONE_Q) > 7 * ONE_Q + ONE_Q / 2);
        assert!(igelu_q(-8 * ONE_Q).abs() < ONE_Q / 100);
        assert_eq!(igelu_q(0), 0);
    }

    #[test]
    fn glue_stays_in_range_and_is_deterministic() {
        let y: Vec<i64> = vec![120, -3400, 77, 0, 55_000, -9, 1234];
        for role in
            [LayerRole::Qkv, LayerRole::AttnProj, LayerRole::Fc1, LayerRole::Fc2]
        {
            for a_bits in [1u32, 2, 4, 8] {
                let x = glue(role, &y, 11, a_bits);
                assert_eq!(x.len(), 11);
                let lo = -(1i32 << (a_bits - 1));
                let hi = (1i32 << (a_bits - 1)) - 1;
                assert!(
                    x.iter().all(|&v| v >= lo && v <= hi),
                    "{role:?} a_bits={a_bits}: {x:?}"
                );
                assert_eq!(x, glue(role, &y, 11, a_bits), "pure function");
            }
        }
        // Softmax glue is non-negative; k > n replicates cyclically.
        let s = glue(LayerRole::Qkv, &y, 14, 6);
        assert!(s.iter().all(|&v| v >= 0));
        assert_eq!(s[0], s[7], "cyclic replication across k > n");
    }
}
