//! L3 coordinator: the paper's system layer.
//!
//! - [`scheduler`] — tiles linear layers onto the 1088×78 macro; prices
//!                   whole model graphs with serial vs double-buffered
//!                   weight reloads
//! - [`sac`]       — the software-analog co-design policy engine: per-layer
//!                   CB/bit-width selection, circuit↔graph noise bridge,
//!                   plan cost evaluation (Fig. 4's 2.1×, Fig. 6 ablation)
//! - [`router`]    — LPT placement of every (row tile × column tile)
//!                   unit of a model graph; sizes the per-class die pools
//! - [`batcher`]   — time/size-bounded dynamic batching over the compiled
//!                   batch sizes
//! - [`ledger`]    — energy/latency/occupancy accounting, with a
//!                   per-layer breakdown when a graph executor serves
//! - [`server`]    — std-TCP line-JSON inference service (request path;
//!                   `classify`, whole-graph `forward` and token-level
//!                   `stream` kinds; bounded admission + graceful drain)
//! - [`reactor`]   — the connection tier's readiness poll loop: one
//!                   thread, nonblocking sockets, buffered partial-line
//!                   reads and write-queue flushing (no per-connection
//!                   threads, no sleep-polling)
//! - [`shard`]     — 2-D tiled macro execution (row tiles × column
//!                   shards) + the macro-simulator batch executor for
//!                   the serving path
//! - [`multidie`]  — the multi-die tier: one layer replicated across
//!                   independent dies (optionally inside a per-class die
//!                   pool), batches routed across them
//! - [`pipeline`]  — the model-graph pipeline executor: full ViT encoder
//!                   forward passes through per-class die pools
//! - [`stream`]    — streaming token-level batching: continuous
//!                   admission of per-token work items into macro
//!                   conversion waves, with out-of-order per-request
//!                   reassembly
//! - [`decode`]    — autoregressive generation primitives: token
//!                   embedding, the per-sequence KV fold, next-token
//!                   selection, and the capacity-bounded
//!                   [`decode::SeqStateCache`] residency policy the
//!                   executor runs live and the scheduler replays
//! - [`periphery`] — the deterministic fixed-point digital periphery:
//!                   integer softmax/LayerNorm/GELU kernels (Q16) and
//!                   the role-keyed inter-layer glue both the macro walk
//!                   and the exact reference walks share
//! - [`sweep`]     — the accuracy-vs-energy sweep harness: per-layer
//!                   vote grids over the workload corpus, Pareto
//!                   frontier extraction, and the greedy vote co-design
//!                   search (`crcim sweep`, `BENCH_accuracy.json`)
//!
//! See `docs/ARCHITECTURE.md` for the layer map, the 2-D tiling model,
//! the pipeline/pool model, the streaming-admission model and the
//! determinism contract, and `docs/SERVING.md` for the server's wire
//! protocol end to end.

pub mod batcher;
pub mod decode;
pub mod ledger;
pub mod multidie;
pub mod periphery;
pub mod pipeline;
pub(crate) mod reactor;
pub mod router;
pub mod sac;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod stream;
pub mod sweep;

pub use decode::{GenStats, GenStep, SeqStateCache};
pub use multidie::DieBank;
pub use pipeline::{ModelExecutor, PipelineConfig};
pub use router::Router;
pub use sac::{NoiseCalibration, PlanCost};
pub use scheduler::{DecodePlan, PipelinePlan, Scheduler, StreamPlan, TilePlan};
pub use shard::{MacroShards, SimExecutor};
pub use stream::{StreamConfig, TokenStream};
