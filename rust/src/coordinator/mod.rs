//! L3 coordinator: the paper's system layer.
//!
//! - [`scheduler`] — tiles linear layers onto the 1088×78 macro
//! - [`sac`]       — the software-analog co-design policy engine: per-layer
//!                   CB/bit-width selection, circuit↔graph noise bridge,
//!                   plan cost evaluation (Fig. 4's 2.1×, Fig. 6 ablation)
//! - [`batcher`]   — time/size-bounded dynamic batching over the compiled
//!                   batch sizes
//! - [`ledger`]    — energy/latency/occupancy accounting
//! - [`server`]    — std-TCP line-JSON inference service (request path)
//! - [`shard`]     — 2-D tiled macro execution (row tiles × column
//!                   shards) + the macro-simulator batch executor for
//!                   the serving path
//! - [`multidie`]  — the multi-die tier: one layer replicated across
//!                   independent dies, batches routed across them
//!
//! See `docs/ARCHITECTURE.md` for the layer map, the 2-D tiling model
//! and the determinism contract.

pub mod batcher;
pub mod ledger;
pub mod multidie;
pub mod router;
pub mod sac;
pub mod scheduler;
pub mod server;
pub mod shard;

pub use multidie::DieBank;
pub use sac::{NoiseCalibration, PlanCost};
pub use scheduler::{Scheduler, TilePlan};
pub use shard::{MacroShards, SimExecutor};
