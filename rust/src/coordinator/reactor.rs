//! The connection-tier reactor: a `std`-only readiness poll loop over
//! nonblocking sockets (tokio stays out of the dependency-free build).
//!
//! One thread owns the listener and every connection. Each pass it
//! accepts pending connections (until the server drains), flushes each
//! connection's write queue (staged responses append to a per-connection
//! buffer; partial writes keep their tail for the next pass), reads
//! whatever bytes are ready into a per-connection line buffer, and
//! dispatches every complete newline-terminated line through
//! [`Server::handle_line`]. There are **no per-connection threads** and
//! **no sleep-polling**: a pass that makes no progress parks on the
//! server's I/O condvar ([`Server::io_wait`]) with a bounded timeout, so
//! the loop wakes the instant the executor stages a response.
//!
//! Fairness: reads are budgeted per connection per pass, so a client
//! firehosing partial lines — or one that never drains its responses
//! (slow writer; its buffer just grows until it reads) — cannot stall
//! dispatch for other connections.
//!
//! Shutdown: while the server drains, accepting stops but existing
//! connections still read (admission sheds inference requests with the
//! documented errors; control commands still answer). Once the server
//! stops, reads stop too and the reactor exits as soon as every staged
//! response has flushed, bounded by [`FINAL_FLUSH_TIMEOUT`] so one
//! stalled writer cannot hold the process open.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::server::Server;

/// Upper bound on one request line; a connection that exceeds it
/// without a newline is answered with an error and closed (an unbounded
/// line buffer would let one client exhaust memory).
const MAX_LINE_BYTES: usize = 8 * 1024 * 1024;

/// Bytes read per connection per pass before yielding to the next
/// connection (fairness under a firehosing client).
const READ_BUDGET: usize = 64 * 1024;

/// Idle park between passes when nothing progressed; the executor's
/// staging notify cuts this short, so it only bounds wakeup latency
/// for socket readiness (accept/read/write), not for responses.
const POLL_INTERVAL: Duration = Duration::from_millis(1);

/// After the server stops, how long the reactor keeps trying to flush
/// remaining response bytes to slow writers before giving up.
const FINAL_FLUSH_TIMEOUT: Duration = Duration::from_secs(1);

/// Per-connection state: the nonblocking socket plus its partial-line
/// read buffer and pending-write tail.
struct Conn {
    stream: TcpStream,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    /// Peer half-closed (EOF) or errored: stop reading, flush what
    /// remains, then close.
    closing: bool,
}

/// What one service pass did to a connection.
enum ConnFate {
    /// Keep polling it.
    Keep { progressed: bool },
    /// Remove it (EOF with nothing left to write, or a socket error).
    Close,
}

/// Run the reactor until the server stops and every staged response
/// has been flushed (or the final-flush bound expires). Takes ownership
/// of the (already nonblocking) listener.
pub(crate) fn run(server: Arc<Server>, listener: TcpListener) {
    let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
    let mut flush_deadline: Option<Instant> = None;
    loop {
        let stopped = server.is_shutdown();
        let mut progressed = false;
        // Accept everything pending, unless the server is winding down.
        if !stopped && !server.is_draining() {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let conn_id = server.open_conn();
                        conns.insert(
                            conn_id,
                            Conn {
                                stream,
                                read_buf: Vec::new(),
                                write_buf: Vec::new(),
                                closing: false,
                            },
                        );
                        progressed = true;
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }
        // Service every connection: flush, then read + dispatch.
        let mut dead: Vec<u64> = Vec::new();
        for (&conn_id, conn) in conns.iter_mut() {
            match service_conn(&server, conn_id, conn, stopped) {
                ConnFate::Keep { progressed: p } => progressed |= p,
                ConnFate::Close => dead.push(conn_id),
            }
        }
        for conn_id in dead {
            if let Some(conn) = conns.remove(&conn_id) {
                // Best effort: hand the kernel whatever was still
                // queued before unregistering the connection.
                let mut stream = conn.stream;
                let _ = stream.write_all(&conn.write_buf);
                server.close_conn(conn_id);
                progressed = true;
            }
        }
        if stopped {
            let all_flushed =
                conns.values().all(|c| c.write_buf.is_empty()) && server.staged_connections() == 0;
            let deadline = *flush_deadline.get_or_insert(Instant::now() + FINAL_FLUSH_TIMEOUT);
            if all_flushed || Instant::now() >= deadline {
                for (conn_id, _) in conns {
                    server.close_conn(conn_id);
                }
                return;
            }
        }
        if !progressed {
            server.io_wait(POLL_INTERVAL);
        }
    }
}

/// One pass over one connection: move staged responses into the write
/// buffer, flush as much as the socket accepts, then (until the server
/// stops or the peer half-closes) read ready bytes and dispatch every
/// complete line.
fn service_conn(server: &Arc<Server>, conn_id: u64, conn: &mut Conn, stopped: bool) -> ConnFate {
    let mut progressed = false;
    // Stage → write buffer. Responses drain even while closing: a peer
    // that half-closed its write side may still be reading ours.
    for resp in server.take_responses(conn_id) {
        conn.write_buf.extend_from_slice(resp.as_bytes());
        conn.write_buf.push(b'\n');
        progressed = true;
    }
    // Flush the write buffer without blocking; keep the tail on
    // WouldBlock (slow writer) for the next pass.
    let mut written = 0usize;
    while written < conn.write_buf.len() {
        match conn.stream.write(&conn.write_buf[written..]) {
            Ok(0) => {
                conn.closing = true;
                break;
            }
            Ok(n) => {
                written += n;
                progressed = true;
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.closing = true;
                break;
            }
        }
    }
    conn.write_buf.drain(..written);
    if conn.closing {
        return if conn.write_buf.is_empty() {
            ConnFate::Close
        } else {
            ConnFate::Keep { progressed }
        };
    }
    if stopped {
        // Wind-down: no new reads, just keep flushing.
        return ConnFate::Keep { progressed };
    }
    // Read ready bytes (bounded per pass for fairness) and dispatch
    // complete lines.
    let mut scratch = [0u8; 4096];
    let mut taken = 0usize;
    loop {
        if taken >= READ_BUDGET {
            // More may be ready; the next pass continues here. Count it
            // as progress so the loop does not park with data pending.
            progressed = true;
            break;
        }
        match conn.stream.read(&mut scratch) {
            Ok(0) => {
                conn.closing = true;
                break;
            }
            Ok(n) => {
                conn.read_buf.extend_from_slice(&scratch[..n]);
                taken += n;
                progressed = true;
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.closing = true;
                break;
            }
        }
    }
    // Dispatch every complete line in the buffer.
    while let Some(pos) = conn.read_buf.iter().position(|&b| b == b'\n') {
        let line_bytes: Vec<u8> = conn.read_buf.drain(..=pos).collect();
        let line = String::from_utf8_lossy(&line_bytes);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        progressed = true;
        match server.handle_line(line, conn_id) {
            Ok(Some(imm)) => {
                conn.write_buf.extend_from_slice(imm.as_bytes());
                conn.write_buf.push(b'\n');
            }
            Ok(None) => {}
            Err(e) => {
                let err = Server::error_line(&e);
                conn.write_buf.extend_from_slice(err.as_bytes());
                conn.write_buf.push(b'\n');
            }
        }
    }
    // A partial line beyond the cap will never complete within bounds:
    // answer with an error and close.
    if conn.read_buf.len() > MAX_LINE_BYTES {
        let err = Server::error_line("request line exceeds the 8 MiB limit");
        conn.write_buf.extend_from_slice(err.as_bytes());
        conn.write_buf.push(b'\n');
        conn.closing = true;
        progressed = true;
    }
    if conn.closing && conn.write_buf.is_empty() {
        ConnFate::Close
    } else {
        ConnFate::Keep { progressed }
    }
}
