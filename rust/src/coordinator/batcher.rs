//! Dynamic batcher: groups incoming inference requests into macro-friendly
//! batches (the AOT artifacts are compiled at fixed batch sizes, so the
//! batcher packs to the largest compiled size, padding the tail).
//!
//! Policy: close a batch when (a) it reaches `max_batch`, or (b) the
//! oldest request has waited `max_wait`, mirroring a vLLM-style
//! time/size-bounded batching window.
//!
//! This is the **fixed-batch** admission tier: the unit of admission is
//! a whole request, and a partial batch pads to a compiled size. The
//! streaming tier ([`super::stream`]) reuses the same [`Batcher::decide`]
//! policy with the *token* as the unit of admission and no padding; the
//! two tiers' occupancy numbers are directly comparable in the ledger's
//! `stats` report (`mean_occupancy` vs `mean_wave_occupancy` — see
//! `docs/SERVING.md`).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One queued request.
#[derive(Clone, Debug)]
pub struct Request<T> {
    pub id: u64,
    pub payload: T,
    pub arrived: Instant,
}

/// A closed batch ready for execution.
#[derive(Debug)]
pub struct Batch<T> {
    pub requests: Vec<Request<T>>,
    /// Padded execution size (one of the compiled batch sizes).
    pub exec_size: usize,
}

impl<T> Batch<T> {
    pub fn occupancy(&self) -> f64 {
        self.requests.len() as f64 / self.exec_size as f64
    }
}

/// Batch-forming policy over compiled batch sizes.
#[derive(Clone, Debug)]
pub struct Batcher {
    /// Compiled batch sizes, ascending (e.g. [1, 16]), all ≥ 1.
    pub sizes: Vec<usize>,
    pub max_wait: Duration,
}

impl Batcher {
    /// Build a policy over the compiled batch sizes. Rejects an empty
    /// list (there would be no valid execution size — the old assert
    /// panicked the server thread instead of surfacing a config error)
    /// and any zero size (a 0-size batch has undefined occupancy).
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> Result<Self, String> {
        if sizes.is_empty() {
            return Err("batcher needs at least one compiled batch size".to_string());
        }
        if sizes.contains(&0) {
            return Err("compiled batch sizes must be >= 1".to_string());
        }
        sizes.sort_unstable();
        Ok(Batcher { sizes, max_wait })
    }

    pub fn max_batch(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Smallest compiled size that fits `n` requests (or the max size).
    pub fn exec_size_for(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max_batch()
    }

    /// Decide whether to close a batch now given the queue state.
    /// Returns how many requests to take (0 = keep waiting).
    pub fn decide(&self, queued: usize, oldest_wait: Option<Duration>) -> usize {
        if queued == 0 {
            return 0;
        }
        if queued >= self.max_batch() {
            return self.max_batch();
        }
        match oldest_wait {
            Some(w) if w >= self.max_wait => queued,
            _ => 0,
        }
    }

    /// Form a batch from `pending` (drains up to the decision count).
    /// The queue is a `VecDeque`: popping `take` requests off the front
    /// is O(take), where draining the front of a `Vec` memmoved the
    /// whole remaining queue on every batch — an O(queue) tax per batch
    /// on the serve hot path. Batch-formation order is unchanged (FIFO).
    pub fn form_batch<T>(
        &self,
        pending: &mut VecDeque<Request<T>>,
        now: Instant,
    ) -> Option<Batch<T>> {
        let oldest_wait = pending.front().map(|r| now.duration_since(r.arrived));
        let take = self.decide(pending.len(), oldest_wait);
        if take == 0 {
            return None;
        }
        let requests: Vec<Request<T>> = pending.drain(..take).collect();
        let exec_size = self.exec_size_for(requests.len());
        Some(Batch { requests, exec_size })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize, age: Duration) -> VecDeque<Request<u32>> {
        let now = Instant::now();
        (0..n)
            .map(|i| Request { id: i as u64, payload: i as u32, arrived: now - age })
            .collect()
    }

    #[test]
    fn full_batch_closes_immediately() {
        let b = Batcher::new(vec![1, 16], Duration::from_millis(5)).unwrap();
        assert_eq!(b.decide(16, Some(Duration::ZERO)), 16);
        assert_eq!(b.decide(20, Some(Duration::ZERO)), 16);
    }

    #[test]
    fn partial_batch_waits_until_deadline() {
        let b = Batcher::new(vec![1, 16], Duration::from_millis(5)).unwrap();
        assert_eq!(b.decide(3, Some(Duration::from_millis(1))), 0);
        assert_eq!(b.decide(3, Some(Duration::from_millis(6))), 3);
        assert_eq!(b.decide(0, None), 0);
    }

    #[test]
    fn exec_size_picks_smallest_fitting() {
        let b = Batcher::new(vec![1, 4, 16], Duration::from_millis(5)).unwrap();
        assert_eq!(b.exec_size_for(1), 1);
        assert_eq!(b.exec_size_for(2), 4);
        assert_eq!(b.exec_size_for(5), 16);
        assert_eq!(b.exec_size_for(40), 16);
    }

    #[test]
    fn form_batch_drains_and_pads() {
        let b = Batcher::new(vec![1, 16], Duration::from_millis(5)).unwrap();
        let mut pending = reqs(3, Duration::from_millis(10));
        let batch = b.form_batch(&mut pending, Instant::now()).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.exec_size, 16);
        assert!((batch.occupancy() - 3.0 / 16.0).abs() < 1e-12);
        assert!(pending.is_empty());
    }

    #[test]
    fn form_batch_returns_none_when_waiting() {
        let b = Batcher::new(vec![16], Duration::from_secs(10)).unwrap();
        let mut pending = reqs(2, Duration::ZERO);
        assert!(b.form_batch(&mut pending, Instant::now()).is_none());
        assert_eq!(pending.len(), 2);
    }

    #[test]
    fn empty_or_zero_sizes_are_rejected() {
        // An empty list used to panic via assert (and before that,
        // silently produced a 0-size max batch); it is a config error.
        assert!(Batcher::new(vec![], Duration::from_millis(5)).is_err());
        assert!(Batcher::new(vec![0, 4], Duration::from_millis(5)).is_err());
        assert!(Batcher::new(vec![4], Duration::from_millis(5)).is_ok());
    }

    #[test]
    fn fifo_order_preserved() {
        let b = Batcher::new(vec![2], Duration::ZERO).unwrap();
        let mut pending = reqs(5, Duration::from_millis(1));
        let batch = b.form_batch(&mut pending, Instant::now()).unwrap();
        assert_eq!(batch.requests[0].id, 0);
        assert_eq!(batch.requests[1].id, 1);
        assert_eq!(pending[0].id, 2);
    }
}
