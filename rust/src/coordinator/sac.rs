//! The software-analog co-design (SAC) policy engine — the paper's L3
//! contribution.
//!
//! Responsibilities:
//! 1. choose each layer class's operating point (bits + CB) from its
//!    noise tolerance (Fig. 4's "required CSNR" analysis);
//! 2. bridge the circuit simulator's calibrated read noise into the L2
//!    graph's σ inputs (`kernel_noise_sigma` mirrors
//!    `python/compile/kernels/cim_matmul.py::output_noise_sigma`);
//! 3. quantify the end-to-end efficiency of a plan over the ViT workload
//!    (the Fig. 4 "up to 2.1×" and Fig. 6 ablation bars).

use crate::cim::netstats::{LayerClass, ToleranceModel};
use crate::cim::params::{CbMode, MacroParams};
use crate::metrics::csnr::{measure_csnr, CsnrEnsemble};
use crate::metrics::CsnrResult;
use crate::vit::plan::{OperatingPoint, PrecisionPlan};
use crate::vit::{linear_workload, VitConfig};

use super::scheduler::{Scheduler, TilePlan};

/// Calibrated per-mode read noise (σ per conversion, in LSB), measured
/// once from the circuit simulator and cached.
#[derive(Clone, Copy, Debug)]
pub struct NoiseCalibration {
    pub sigma_cb_on: f64,
    pub sigma_cb_off: f64,
    pub csnr_on: CsnrResult,
    pub csnr_off: CsnrResult,
}

impl NoiseCalibration {
    /// Run the calibration measurement on column 0 of the die. `threads`
    /// follows the engine convention: 0 = use `params.effective_threads()`
    /// (the same worker pool the column-parallel matvec engine uses);
    /// either way the measurement is deterministic in the die seed.
    pub fn measure(params: &MacroParams, threads: usize) -> Result<Self, String> {
        let threads = if threads == 0 { params.effective_threads() } else { threads };
        let col = crate::cim::Column::new(params, 0)?;
        let ens = CsnrEnsemble::default();
        let on = measure_csnr(&col, CbMode::On, &ens, threads);
        let off = measure_csnr(&col, CbMode::Off, &ens, threads);
        // σ per conversion: strip the quantization floor from the
        // measured dynamic error.
        let strip = |r: &CsnrResult| {
            (r.sigma_error_lsb * r.sigma_error_lsb - 1.0 / 12.0).max(0.0).sqrt()
        };
        Ok(NoiseCalibration {
            sigma_cb_on: strip(&on),
            sigma_cb_off: strip(&off),
            csnr_on: on,
            csnr_off: off,
        })
    }

    pub fn sigma(&self, cb: CbMode) -> f64 {
        match cb {
            CbMode::On => self.sigma_cb_on,
            CbMode::Off => self.sigma_cb_off,
        }
    }
}

/// Row replication factor for small-K layers on `rows_per_tile`-row
/// macros: idle rows integrate extra copies of the dot product,
/// recovering dynamic range at constant read noise.
pub fn row_replication_for(k: usize, rows_per_tile: usize) -> usize {
    if k == 0 || k >= rows_per_tile {
        1
    } else {
        (rows_per_tile / k).max(1)
    }
}

/// Row replication on the paper's 1024-row macro (mirror of python
/// `row_replication`).
pub fn row_replication(k: usize) -> usize {
    row_replication_for(k, 1024)
}

/// Integer-domain output noise σ of one logical output accumulated from
/// `row_tiles` independently-seeded macro tiles: each tile contributes
/// an independent per-conversion read error, so per-tile σ adds **in
/// quadrature** through the digital accumulator (×√row_tiles). The
/// weighted sums over activation/weight bit planes (Σ 4^b) account for
/// the shift-add reconstruction. This is the tiled form the 2-D
/// executor reports through
/// [`MacroShards::kernel_sigma`](super::shard::MacroShards::kernel_sigma),
/// keeping SAC plans honest for k > 1024 layers.
pub fn kernel_noise_sigma_for_row_tiles(
    row_tiles: usize,
    a_bits: u32,
    w_bits: u32,
    sigma_read_lsb: f64,
) -> f64 {
    let sa = crate::util::stats::sum_ordered((0..a_bits).map(|a| 4f64.powi(a as i32)));
    let sb = crate::util::stats::sum_ordered((0..w_bits).map(|b| 4f64.powi(b as i32)));
    sigma_read_lsb * (row_tiles.max(1) as f64 * sa * sb).sqrt()
}

/// [`kernel_noise_sigma_for_row_tiles`] with the tile count derived from
/// the layer depth and an explicit tile geometry, plus the small-K row
/// replication gain.
pub fn kernel_noise_sigma_tiled(
    k: usize,
    rows_per_tile: usize,
    a_bits: u32,
    w_bits: u32,
    sigma_read_lsb: f64,
) -> f64 {
    let tiles = k.div_ceil(rows_per_tile.max(1)).max(1);
    let r = row_replication_for(k, rows_per_tile) as f64;
    kernel_noise_sigma_for_row_tiles(tiles, a_bits, w_bits, sigma_read_lsb) / r
}

/// Mirror of python `output_noise_sigma`: integer-domain output noise of
/// one linear output given per-conversion read noise — the L3↔L2 bridge,
/// on the paper's 1024-row tile geometry.
pub fn kernel_noise_sigma(k: usize, a_bits: u32, w_bits: u32, sigma_read_lsb: f64) -> f64 {
    kernel_noise_sigma_tiled(k, 1024, a_bits, w_bits, sigma_read_lsb)
}

/// Layer-class CSNR requirement (Fig. 4) at a target accuracy drop.
pub fn required_csnr_db(class: LayerClass, max_drop: f64) -> f64 {
    ToleranceModel::for_class(class).required_csnr_db(max_drop)
}

/// The policy decision: cheapest operating point whose delivered CSNR
/// meets the layer's requirement. Candidate points are ordered by cost.
pub fn choose_operating_point(
    class: LayerClass,
    calib: &NoiseCalibration,
    max_drop: f64,
) -> OperatingPoint {
    let need = required_csnr_db(class, max_drop);
    // Candidates ordered by cost (cheapest first).
    let candidates = [
        OperatingPoint::new(4, 4, CbMode::Off),
        OperatingPoint::new(6, 6, CbMode::Off),
        OperatingPoint::new(4, 4, CbMode::On),
        OperatingPoint::new(6, 6, CbMode::On),
        OperatingPoint::new(8, 8, CbMode::On),
    ];
    for op in candidates {
        let analog = match op.cb {
            CbMode::On => calib.csnr_on.csnr_db,
            CbMode::Off => calib.csnr_off.csnr_db,
        };
        if delivered_csnr_db(analog, op.a_bits) >= need {
            return op;
        }
    }
    *candidates.last().unwrap()
}

/// Total delivered compute SNR at an operating point: analog error and
/// operand-quantization error powers add. Quantization CSNR of b-bit
/// operands on ViT activation statistics ≈ 6·b + 2 dB (empirical PTQ
/// scaling; +6 dB per bit).
pub fn delivered_csnr_db(analog_csnr_db: f64, bits: u32) -> f64 {
    let quant_db = 6.0 * bits as f64 + 2.0;
    let p_err = 10f64.powf(-analog_csnr_db / 10.0) + 10f64.powf(-quant_db / 10.0);
    -10.0 * p_err.log10()
}

/// Cost of one full inference under a plan.
#[derive(Clone, Debug)]
pub struct PlanCost {
    pub plan_name: &'static str,
    pub total: TilePlan,
    /// Energy per inference [µJ].
    pub energy_uj: f64,
    /// Latency per inference [µs].
    pub latency_us: f64,
    /// Effective 1b-normalized TOPS/W over the workload.
    pub tops_per_watt_effective: f64,
}

impl PlanCost {
    /// Derive the summary figures (µJ, µs, TOPS/W) from a tile-plan
    /// total — the one place that math lives; every executor and
    /// evaluator builds its `PlanCost` through here.
    pub fn from_total(plan_name: &'static str, total: TilePlan) -> Self {
        PlanCost {
            plan_name,
            total,
            energy_uj: total.energy_pj * 1e-6,
            latency_us: total.latency_ns * 1e-3,
            tops_per_watt_effective: total.ops_1b / (total.energy_pj * 1e-12) / 1e12,
        }
    }
}

/// Evaluate a plan over the ViT linear workload.
pub fn evaluate_plan(
    sched: &Scheduler,
    cfg: &VitConfig,
    batch: usize,
    plan: &PrecisionPlan,
) -> PlanCost {
    let mut total = TilePlan::default();
    for shape in linear_workload(cfg, batch) {
        let op = plan.point(shape.class);
        total.add(&sched.plan_linear(&shape, op));
    }
    PlanCost::from_total(plan.name, total)
}

/// Evaluate an explicit model graph (the pipeline executor's unit of
/// work): per-layer operating points come from the graph itself, and
/// the reported latency is the reload-overlapped pipeline
/// (`Scheduler::plan_graph`'s `pipelined_ns`), not the bare conversion
/// sum `evaluate_plan` reports.
pub fn evaluate_graph(sched: &Scheduler, graph: &crate::vit::graph::ModelGraph) -> PlanCost {
    let pp = sched.plan_graph(graph);
    let mut total = pp.total;
    total.latency_ns = pp.pipelined_ns;
    PlanCost::from_total(graph.plan_name, total)
}

/// The Fig. 4 headline: energy ratio of the safe uniform plan over the
/// SAC plan ("inference efficiency improved up to 2.1×").
pub fn sac_efficiency_improvement(sched: &Scheduler, cfg: &VitConfig, batch: usize) -> f64 {
    let safe = evaluate_plan(sched, cfg, batch, &PrecisionPlan::uniform_safe());
    let sac = evaluate_plan(sched, cfg, batch, &PrecisionPlan::paper_sac());
    safe.energy_uj / sac.energy_uj
}

/// Workload-weighted attention share of conversions (used by benches to
/// explain where the saving comes from).
pub fn attention_conversion_share(sched: &Scheduler, cfg: &VitConfig, plan: &PrecisionPlan) -> f64 {
    let mut att = 0u64;
    let mut all = 0u64;
    for shape in linear_workload(cfg, 1) {
        let op = plan.point(shape.class);
        let c = sched.plan_linear(&shape, op).conversions;
        all += c;
        if shape.class == LayerClass::TransformerAttention {
            att += c;
        }
    }
    att as f64 / all as f64
}

/// Helper for benches: the per-layer-class noise sigmas the L2 graph
/// needs, under a plan.
pub fn plan_sigmas(plan: &PrecisionPlan, calib: &NoiseCalibration) -> (f64, f64) {
    (calib.sigma(plan.attention.cb), calib.sigma(plan.mlp.cb))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calib() -> NoiseCalibration {
        NoiseCalibration::measure(&MacroParams::default(), 4).unwrap()
    }

    #[test]
    fn calibration_matches_characterization_scale() {
        let c = calib();
        assert!((c.sigma_cb_on - 0.58).abs() < 0.15, "σ_on = {}", c.sigma_cb_on);
        assert!(c.sigma_cb_off > c.sigma_cb_on * 1.3, "off {} on {}", c.sigma_cb_off, c.sigma_cb_on);
        assert!(c.csnr_on.csnr_db > c.csnr_off.csnr_db + 2.0);
    }

    #[test]
    fn measure_auto_threads_matches_explicit() {
        let p = MacroParams::default();
        let a = NoiseCalibration::measure(&p, 0).unwrap();
        let b = NoiseCalibration::measure(&p, 2).unwrap();
        assert_eq!(a.sigma_cb_on.to_bits(), b.sigma_cb_on.to_bits());
        assert_eq!(a.sigma_cb_off.to_bits(), b.sigma_cb_off.to_bits());
    }

    #[test]
    fn kernel_noise_sigma_mirrors_python() {
        // Values cross-checked against python tests (test_kernel.py).
        let a = kernel_noise_sigma(96, 4, 4, 0.5);
        let b = kernel_noise_sigma(96, 4, 4, 1.0);
        assert!((b - 2.0 * a).abs() < 1e-12);
        // k_tiles doubling.
        let c = kernel_noise_sigma(1025, 4, 4, 1.0);
        let d = kernel_noise_sigma(1024, 4, 4, 1.0);
        assert!((c / d - 2f64.sqrt()).abs() < 1e-9);
        // Exact value: sqrt(1 · 85 · 85) · σ for 4b/4b single tile.
        let sa: f64 = 1.0 + 4.0 + 16.0 + 64.0;
        assert!((d - (sa * sa).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn tiled_sigma_composes_in_quadrature() {
        // Per-tile σ adds in quadrature: 4 tiles double the output σ.
        let one = kernel_noise_sigma_for_row_tiles(1, 4, 4, 0.5);
        let four = kernel_noise_sigma_for_row_tiles(4, 4, 4, 0.5);
        assert!((four / one - 2.0).abs() < 1e-12);
        // The 1024-row convenience wrapper is the tiled form.
        for k in [96usize, 1024, 1025, 3072] {
            let a = kernel_noise_sigma(k, 6, 6, 0.58);
            let b = kernel_noise_sigma_tiled(k, 1024, 6, 6, 0.58);
            assert_eq!(a.to_bits(), b.to_bits(), "k={k}");
        }
        // d_ff = 3072 on 1024-row tiles: 3 tiles, √3 over a single tile.
        let d3 = kernel_noise_sigma(3072, 6, 6, 1.0);
        let d1 = kernel_noise_sigma(1024, 6, 6, 1.0);
        assert!((d3 / d1 - 3f64.sqrt()).abs() < 1e-12);
        // Small-k replication still applies in the tiled form.
        assert_eq!(row_replication_for(512, 1024), 2);
        assert_eq!(row_replication_for(512, 512), 1);
        assert_eq!(row_replication_for(0, 1024), 1);
    }

    #[test]
    fn policy_picks_cheap_point_for_attention_and_safe_for_mlp() {
        let c = calib();
        let att = choose_operating_point(LayerClass::TransformerAttention, &c, 0.01);
        let mlp = choose_operating_point(LayerClass::TransformerMlp, &c, 0.01);
        assert_eq!(att.cb, CbMode::Off, "attention tolerates no-CB: {att:?}");
        assert_eq!(mlp.cb, CbMode::On, "MLP needs CB: {mlp:?}");
        assert!(att.a_bits <= mlp.a_bits);
    }

    #[test]
    fn sac_improvement_close_to_paper_2p1x() {
        let sched = Scheduler::new(&MacroParams::default());
        let gain = sac_efficiency_improvement(&sched, &VitConfig::vit_small(), 1);
        // Paper: "up to 2.1x". Our workload weighting lands at ~2.5x; the
        // shape claim is the order of the gain, not its third digit.
        assert!(
            (gain - 2.1).abs() < 0.6,
            "SAC efficiency improvement {gain:.2}x (paper: up to 2.1x)"
        );
    }

    #[test]
    fn ablation_is_monotone() {
        let sched = Scheduler::new(&MacroParams::default());
        let cfg = VitConfig::vit_small();
        let costs: Vec<f64> = PrecisionPlan::ablation_series()
            .iter()
            .map(|p| evaluate_plan(&sched, &cfg, 1, p).energy_uj)
            .collect();
        assert!(costs[0] > costs[1] && costs[1] > costs[2], "{costs:?}");
    }

    #[test]
    fn attention_share_drops_under_sac() {
        let sched = Scheduler::new(&MacroParams::default());
        let cfg = VitConfig::vit_small();
        let uniform = attention_conversion_share(&sched, &cfg, &PrecisionPlan::uniform_safe());
        let sac = attention_conversion_share(&sched, &cfg, &PrecisionPlan::paper_sac());
        assert!(sac < uniform, "sac {sac} vs uniform {uniform}");
    }

    #[test]
    fn plan_cost_has_positive_components() {
        let sched = Scheduler::new(&MacroParams::default());
        let cost = evaluate_plan(&sched, &VitConfig::default(), 4, &PrecisionPlan::paper_sac());
        assert!(cost.energy_uj > 0.0);
        assert!(cost.latency_us > 0.0);
        assert!(cost.tops_per_watt_effective > 50.0);
    }

    #[test]
    fn graph_cost_matches_workload_energy_and_adds_reload_latency() {
        use crate::vit::graph::ModelGraph;
        let sched = Scheduler::new(&MacroParams::default());
        let cfg = VitConfig::vit_small();
        let plan = PrecisionPlan::paper_sac();
        let graph = ModelGraph::encoder(&cfg, 1, &plan);
        let g = evaluate_graph(&sched, &graph);
        // Same conversions/energy as pricing the encoder layers directly.
        let mut body = TilePlan::default();
        for l in &graph.layers {
            body.add(&sched.plan_linear(&l.shape, l.op));
        }
        assert_eq!(g.total.conversions, body.conversions);
        assert!((g.total.energy_pj - body.energy_pj).abs() < 1e-6);
        // The graph latency carries the (overlapped) reload term the
        // flat workload evaluation ignores.
        assert!(g.total.latency_ns > body.latency_ns);
        assert_eq!(g.plan_name, plan.name);
    }
}
