//! The software-analog co-design (SAC) policy engine — the paper's L3
//! contribution.
//!
//! Responsibilities:
//! 1. choose each layer class's operating point (bits + CB) from its
//!    noise tolerance (Fig. 4's "required CSNR" analysis);
//! 2. bridge the circuit simulator's calibrated read noise into the L2
//!    graph's σ inputs (`kernel_noise_sigma` mirrors
//!    `python/compile/kernels/cim_matmul.py::output_noise_sigma`);
//! 3. quantify the end-to-end efficiency of a plan over the ViT workload
//!    (the Fig. 4 "up to 2.1×" and Fig. 6 ablation bars).

use crate::cim::netstats::{LayerClass, ToleranceModel};
use crate::cim::params::{CbMode, MacroParams};
use crate::metrics::csnr::{measure_csnr, CsnrEnsemble};
use crate::metrics::CsnrResult;
use crate::vit::plan::{OperatingPoint, PrecisionPlan};
use crate::vit::{linear_workload, VitConfig};

use super::scheduler::{Scheduler, TilePlan};

/// Calibrated per-mode read noise (σ per conversion, in LSB), measured
/// once from the circuit simulator and cached.
#[derive(Clone, Copy, Debug)]
pub struct NoiseCalibration {
    pub sigma_cb_on: f64,
    pub sigma_cb_off: f64,
    pub csnr_on: CsnrResult,
    pub csnr_off: CsnrResult,
}

impl NoiseCalibration {
    /// Run the calibration measurement on column 0 of the die. `threads`
    /// follows the engine convention: 0 = use `params.effective_threads()`
    /// (the same worker pool the column-parallel matvec engine uses);
    /// either way the measurement is deterministic in the die seed.
    pub fn measure(params: &MacroParams, threads: usize) -> Result<Self, String> {
        let threads = if threads == 0 { params.effective_threads() } else { threads };
        let col = crate::cim::Column::new(params, 0)?;
        let ens = CsnrEnsemble::default();
        let on = measure_csnr(&col, CbMode::On, &ens, threads);
        let off = measure_csnr(&col, CbMode::Off, &ens, threads);
        // σ per conversion: strip the quantization floor from the
        // measured dynamic error.
        let strip = |r: &CsnrResult| {
            (r.sigma_error_lsb * r.sigma_error_lsb - 1.0 / 12.0).max(0.0).sqrt()
        };
        Ok(NoiseCalibration {
            sigma_cb_on: strip(&on),
            sigma_cb_off: strip(&off),
            csnr_on: on,
            csnr_off: off,
        })
    }

    pub fn sigma(&self, cb: CbMode) -> f64 {
        match cb {
            CbMode::On => self.sigma_cb_on,
            CbMode::Off => self.sigma_cb_off,
        }
    }
}

/// Row replication factor for small-K layers (mirror of python
/// `row_replication`): idle rows integrate extra copies of the dot
/// product, recovering dynamic range at constant read noise.
pub fn row_replication(k: usize) -> usize {
    if k >= 1024 {
        1
    } else {
        (1024 / k).max(1)
    }
}

/// Mirror of python `output_noise_sigma`: integer-domain output noise of
/// one linear output given per-conversion read noise — the L3↔L2 bridge.
pub fn kernel_noise_sigma(k: usize, a_bits: u32, w_bits: u32, sigma_read_lsb: f64) -> f64 {
    let k_tiles = k.div_ceil(1024) as f64;
    let r = row_replication(k) as f64;
    let sa: f64 = (0..a_bits).map(|a| 4f64.powi(a as i32)).sum();
    let sb: f64 = (0..w_bits).map(|b| 4f64.powi(b as i32)).sum();
    sigma_read_lsb / r * (k_tiles * sa * sb).sqrt()
}

/// Layer-class CSNR requirement (Fig. 4) at a target accuracy drop.
pub fn required_csnr_db(class: LayerClass, max_drop: f64) -> f64 {
    ToleranceModel::for_class(class).required_csnr_db(max_drop)
}

/// The policy decision: cheapest operating point whose delivered CSNR
/// meets the layer's requirement. Candidate points are ordered by cost.
pub fn choose_operating_point(
    class: LayerClass,
    calib: &NoiseCalibration,
    max_drop: f64,
) -> OperatingPoint {
    let need = required_csnr_db(class, max_drop);
    // Candidates ordered by cost (cheapest first).
    let candidates = [
        OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::Off },
        OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::Off },
        OperatingPoint { a_bits: 4, w_bits: 4, cb: CbMode::On },
        OperatingPoint { a_bits: 6, w_bits: 6, cb: CbMode::On },
        OperatingPoint { a_bits: 8, w_bits: 8, cb: CbMode::On },
    ];
    for op in candidates {
        let analog = match op.cb {
            CbMode::On => calib.csnr_on.csnr_db,
            CbMode::Off => calib.csnr_off.csnr_db,
        };
        if delivered_csnr_db(analog, op.a_bits) >= need {
            return op;
        }
    }
    *candidates.last().unwrap()
}

/// Total delivered compute SNR at an operating point: analog error and
/// operand-quantization error powers add. Quantization CSNR of b-bit
/// operands on ViT activation statistics ≈ 6·b + 2 dB (empirical PTQ
/// scaling; +6 dB per bit).
pub fn delivered_csnr_db(analog_csnr_db: f64, bits: u32) -> f64 {
    let quant_db = 6.0 * bits as f64 + 2.0;
    let p_err = 10f64.powf(-analog_csnr_db / 10.0) + 10f64.powf(-quant_db / 10.0);
    -10.0 * p_err.log10()
}

/// Cost of one full inference under a plan.
#[derive(Clone, Debug)]
pub struct PlanCost {
    pub plan_name: &'static str,
    pub total: TilePlan,
    /// Energy per inference [µJ].
    pub energy_uj: f64,
    /// Latency per inference [µs].
    pub latency_us: f64,
    /// Effective 1b-normalized TOPS/W over the workload.
    pub tops_per_watt_effective: f64,
}

/// Evaluate a plan over the ViT linear workload.
pub fn evaluate_plan(
    sched: &Scheduler,
    cfg: &VitConfig,
    batch: usize,
    plan: &PrecisionPlan,
) -> PlanCost {
    let mut total = TilePlan::default();
    for shape in linear_workload(cfg, batch) {
        let op = plan.point(shape.class);
        total.add(&sched.plan_linear(&shape, op));
    }
    let energy_uj = total.energy_pj * 1e-6;
    let latency_us = total.latency_ns * 1e-3;
    let tops_per_watt_effective = total.ops_1b / (total.energy_pj * 1e-12) / 1e12;
    PlanCost { plan_name: plan.name, total, energy_uj, latency_us, tops_per_watt_effective }
}

/// The Fig. 4 headline: energy ratio of the safe uniform plan over the
/// SAC plan ("inference efficiency improved up to 2.1×").
pub fn sac_efficiency_improvement(sched: &Scheduler, cfg: &VitConfig, batch: usize) -> f64 {
    let safe = evaluate_plan(sched, cfg, batch, &PrecisionPlan::uniform_safe());
    let sac = evaluate_plan(sched, cfg, batch, &PrecisionPlan::paper_sac());
    safe.energy_uj / sac.energy_uj
}

/// Workload-weighted attention share of conversions (used by benches to
/// explain where the saving comes from).
pub fn attention_conversion_share(sched: &Scheduler, cfg: &VitConfig, plan: &PrecisionPlan) -> f64 {
    let mut att = 0u64;
    let mut all = 0u64;
    for shape in linear_workload(cfg, 1) {
        let op = plan.point(shape.class);
        let c = sched.plan_linear(&shape, op).conversions;
        all += c;
        if shape.class == LayerClass::TransformerAttention {
            att += c;
        }
    }
    att as f64 / all as f64
}

/// Helper for benches: the per-layer-class noise sigmas the L2 graph
/// needs, under a plan.
pub fn plan_sigmas(plan: &PrecisionPlan, calib: &NoiseCalibration) -> (f64, f64) {
    (calib.sigma(plan.attention.cb), calib.sigma(plan.mlp.cb))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calib() -> NoiseCalibration {
        NoiseCalibration::measure(&MacroParams::default(), 4).unwrap()
    }

    #[test]
    fn calibration_matches_characterization_scale() {
        let c = calib();
        assert!((c.sigma_cb_on - 0.58).abs() < 0.15, "σ_on = {}", c.sigma_cb_on);
        assert!(c.sigma_cb_off > c.sigma_cb_on * 1.3, "off {} on {}", c.sigma_cb_off, c.sigma_cb_on);
        assert!(c.csnr_on.csnr_db > c.csnr_off.csnr_db + 2.0);
    }

    #[test]
    fn measure_auto_threads_matches_explicit() {
        let p = MacroParams::default();
        let a = NoiseCalibration::measure(&p, 0).unwrap();
        let b = NoiseCalibration::measure(&p, 2).unwrap();
        assert_eq!(a.sigma_cb_on.to_bits(), b.sigma_cb_on.to_bits());
        assert_eq!(a.sigma_cb_off.to_bits(), b.sigma_cb_off.to_bits());
    }

    #[test]
    fn kernel_noise_sigma_mirrors_python() {
        // Values cross-checked against python tests (test_kernel.py).
        let a = kernel_noise_sigma(96, 4, 4, 0.5);
        let b = kernel_noise_sigma(96, 4, 4, 1.0);
        assert!((b - 2.0 * a).abs() < 1e-12);
        // k_tiles doubling.
        let c = kernel_noise_sigma(1025, 4, 4, 1.0);
        let d = kernel_noise_sigma(1024, 4, 4, 1.0);
        assert!((c / d - 2f64.sqrt()).abs() < 1e-9);
        // Exact value: sqrt(1 · 85 · 85) · σ for 4b/4b single tile.
        let sa: f64 = 1.0 + 4.0 + 16.0 + 64.0;
        assert!((d - (sa * sa).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn policy_picks_cheap_point_for_attention_and_safe_for_mlp() {
        let c = calib();
        let att = choose_operating_point(LayerClass::TransformerAttention, &c, 0.01);
        let mlp = choose_operating_point(LayerClass::TransformerMlp, &c, 0.01);
        assert_eq!(att.cb, CbMode::Off, "attention tolerates no-CB: {att:?}");
        assert_eq!(mlp.cb, CbMode::On, "MLP needs CB: {mlp:?}");
        assert!(att.a_bits <= mlp.a_bits);
    }

    #[test]
    fn sac_improvement_close_to_paper_2p1x() {
        let sched = Scheduler::new(&MacroParams::default());
        let gain = sac_efficiency_improvement(&sched, &VitConfig::vit_small(), 1);
        // Paper: "up to 2.1x". Our workload weighting lands at ~2.5x; the
        // shape claim is the order of the gain, not its third digit.
        assert!(
            (gain - 2.1).abs() < 0.6,
            "SAC efficiency improvement {gain:.2}x (paper: up to 2.1x)"
        );
    }

    #[test]
    fn ablation_is_monotone() {
        let sched = Scheduler::new(&MacroParams::default());
        let cfg = VitConfig::vit_small();
        let costs: Vec<f64> = PrecisionPlan::ablation_series()
            .iter()
            .map(|p| evaluate_plan(&sched, &cfg, 1, p).energy_uj)
            .collect();
        assert!(costs[0] > costs[1] && costs[1] > costs[2], "{costs:?}");
    }

    #[test]
    fn attention_share_drops_under_sac() {
        let sched = Scheduler::new(&MacroParams::default());
        let cfg = VitConfig::vit_small();
        let uniform = attention_conversion_share(&sched, &cfg, &PrecisionPlan::uniform_safe());
        let sac = attention_conversion_share(&sched, &cfg, &PrecisionPlan::paper_sac());
        assert!(sac < uniform, "sac {sac} vs uniform {uniform}");
    }

    #[test]
    fn plan_cost_has_positive_components() {
        let sched = Scheduler::new(&MacroParams::default());
        let cost = evaluate_plan(&sched, &VitConfig::default(), 4, &PrecisionPlan::paper_sac());
        assert!(cost.energy_uj > 0.0);
        assert!(cost.latency_us > 0.0);
        assert!(cost.tops_per_watt_effective > 50.0);
    }
}
