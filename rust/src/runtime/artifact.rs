//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. The manifest records every artifact's input/output
//! shapes and dtypes; the loader validates against it so a stale or
//! mismatched artifact fails loudly at startup, not at execute time.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Input/output tensor signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self, String> {
        let shape = j
            .get_path("shape")
            .and_then(|s| s.as_arr())
            .ok_or("missing shape")?
            .iter()
            .map(|v| v.as_f64().map(|x| x as usize).ok_or("bad dim"))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = j
            .get_path("dtype")
            .and_then(|d| d.as_str())
            .ok_or("missing dtype")?
            .to_string();
        Ok(TensorSig { shape, dtype })
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    /// Training metadata passed through from python.
    pub acc_fp: Option<f64>,
    pub config: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e}"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let j = json::parse(text).map_err(|e| format!("manifest json: {e}"))?;
        let arts = j
            .get_path("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::new();
        for (name, entry) in arts.iter() {
            let sigs = |key: &str| -> Result<Vec<TensorSig>, String> {
                entry
                    .get_path(key)
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| format!("artifact {name} missing {key}"))?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect()
            };
            artifacts.push(Artifact {
                name: name.clone(),
                path: dir.join(format!("{name}.hlo.txt")),
                inputs: sigs("inputs")?,
                outputs: sigs("outputs")?,
            });
        }
        Ok(Manifest {
            artifacts,
            acc_fp: j.get_path("acc_fp").and_then(|x| x.as_f64()),
            config: j.get_path("config").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Verify every artifact file exists.
    pub fn check_files(&self) -> Result<(), String> {
        for a in &self.artifacts {
            if !a.path.exists() {
                return Err(format!("artifact file missing: {}", a.path.display()));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"dim": 96},
      "acc_fp": 0.97,
      "artifacts": {
        "vit_cim_b1": {
          "inputs": [
            {"shape": [1, 32, 32, 3], "dtype": "f32"},
            {"shape": [], "dtype": "i32"},
            {"shape": [], "dtype": "f32"},
            {"shape": [], "dtype": "f32"}
          ],
          "outputs": [{"shape": [1, 10], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("vit_cim_b1").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[0].shape, vec![1, 32, 32, 3]);
        assert_eq!(a.inputs[1].dtype, "i32");
        assert_eq!(a.outputs[0].elements(), 10);
        assert_eq!(m.acc_fp, Some(0.97));
        assert_eq!(a.path, Path::new("/tmp/a/vit_cim_b1.hlo.txt"));
    }

    #[test]
    fn scalar_sig_has_one_element() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert_eq!(m.get("vit_cim_b1").unwrap().inputs[1].elements(), 1);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}", Path::new("/tmp")).is_err());
        assert!(Manifest::parse(r#"{"artifacts": {"x": {}}}"#, Path::new("/tmp")).is_err());
    }

    #[test]
    fn check_files_fails_on_missing() {
        let m = Manifest::parse(SAMPLE, Path::new("/nonexistent-dir-xyz")).unwrap();
        assert!(m.check_files().is_err());
    }
}
