//! xla-crate wrapper: PJRT CPU client + typed executable handles.
//!
//! Load path (see /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Text is mandatory: the crate's
//! xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit ids).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::Artifact;

/// The PJRT client plus compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact's HLO text into an executable.
    pub fn load(&self, artifact: &Artifact) -> Result<Executable> {
        let exe = self.load_path(&artifact.path)?;
        Ok(Executable {
            exe: exe.exe,
            name: artifact.name.clone(),
            n_inputs: artifact.inputs.len(),
        })
    }

    /// Compile a bare HLO text file (no manifest entry).
    pub fn load_path(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string(), n_inputs: usize::MAX })
    }
}

/// A compiled computation with a typed call interface.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    n_inputs: usize,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened f32 outputs of
    /// the 1-tuple result (aot.py lowers with return_tuple=True).
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        if self.n_inputs != usize::MAX && inputs.len() != self.n_inputs {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.n_inputs,
                inputs.len()
            ));
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Typed handle for the ViT artifacts: images (+ noise controls) → logits.
pub struct VitExecutable {
    exe: Executable,
    pub batch: usize,
    pub image: usize,
    pub num_classes: usize,
    /// Whether this is the CIM path (takes seed + sigmas) or fp reference.
    pub is_cim: bool,
}

impl VitExecutable {
    pub fn new(runtime: &Runtime, artifact: &Artifact) -> Result<Self> {
        let exe = runtime.load(artifact)?;
        let in0 = &artifact.inputs[0];
        if in0.shape.len() != 4 {
            return Err(anyhow!("{}: expected NHWC input", artifact.name));
        }
        let out0 = &artifact.outputs[0];
        Ok(VitExecutable {
            exe,
            batch: in0.shape[0],
            image: in0.shape[1],
            num_classes: out0.shape[1],
            is_cim: artifact.inputs.len() == 4,
        })
    }

    /// Run a batch of images (len = batch·image·image·3). For the CIM
    /// path, `seed` and per-class read-noise sigmas must be provided.
    pub fn infer(
        &self,
        images: &[f32],
        seed: i32,
        sigma_attn: f32,
        sigma_mlp: f32,
    ) -> Result<Vec<f32>> {
        let expect = self.batch * self.image * self.image * 3;
        if images.len() != expect {
            return Err(anyhow!(
                "{}: expected {} image floats, got {}",
                self.exe.name,
                expect,
                images.len()
            ));
        }
        let img = xla::Literal::vec1(images).reshape(&[
            self.batch as i64,
            self.image as i64,
            self.image as i64,
            3,
        ])?;
        let logits = if self.is_cim {
            self.exe.run_f32(&[
                img,
                xla::Literal::scalar(seed),
                xla::Literal::scalar(sigma_attn),
                xla::Literal::scalar(sigma_mlp),
            ])?
        } else {
            self.exe.run_f32(&[img])?
        };
        if logits.len() != self.batch * self.num_classes {
            return Err(anyhow!("unexpected logits length {}", logits.len()));
        }
        Ok(logits)
    }

    /// Argmax per batch row.
    pub fn predict(&self, logits: &[f32]) -> Vec<usize> {
        argmax_rows(logits, self.num_classes)
    }
}

/// Argmax of each `width`-sized row of a flattened logits buffer
/// (re-exported from the dependency-free stats module).
pub use crate::util::stats::argmax_rows;

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/runtime_roundtrip.rs (they
    // need built artifacts); here we only test pure helpers.
    use super::*;
    use crate::runtime::artifact::TensorSig;

    #[test]
    fn predict_argmax_rows() {
        let logits = vec![0.1, 0.9, 0.0, 0.0, 0.0, /* row2 */ 0.0, 0.0, 0.0, 0.0, 2.0];
        assert_eq!(argmax_rows(&logits, 5), vec![1, 4]);
        assert_eq!(argmax_rows(&[], 5), Vec::<usize>::new());
    }

    #[test]
    fn tensor_sig_elements() {
        let t = TensorSig { shape: vec![2, 3, 4], dtype: "f32".into() };
        assert_eq!(t.elements(), 24);
        let s = TensorSig { shape: vec![], dtype: "i32".into() };
        assert_eq!(s.elements(), 1);
    }
}
