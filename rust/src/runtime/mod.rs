//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client from the request path. Python is
//! never involved at runtime — the HLO text is the only interchange.

pub mod artifact;
pub mod client;

pub use artifact::{Artifact, Manifest};
pub use client::{Runtime, VitExecutable};
