//! Area model (65 nm logic rules) for the macro and for the
//! ADC-resolution scaling argument of Fig. 1(B).
//!
//! The key structural fact: a conventional charge-domain CIM that wants a
//! B-bit SAR readout must place a *separate* binary C-DAC (2^B unit caps
//! per column) next to the array, so its ADC area grows exponentially in
//! B. CR-CIM reuses the compute caps as the C-DAC, so its per-column ADC
//! area is just comparator + SAR logic, independent of B (as long as
//! 2^B ≤ rows).

use super::params::MacroParams;

/// Areas in µm² unless noted.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// CR-CIM 10T cell area (paper: 2.3 µm², ≈2× a 6T SRAM cell).
    pub cell_um2: f64,
    /// Unit C-DAC capacitor area if placed separately (fringe cap +
    /// wiring pitch).
    pub dac_unit_cap_um2: f64,
    /// Comparator area per column.
    pub comparator_um2: f64,
    /// SAR logic + registers per column.
    pub sar_logic_um2: f64,
    /// Fixed periphery (row drivers, IO, controller) as a fraction of the
    /// cell-array area.
    pub periphery_frac: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            cell_um2: 2.3,
            dac_unit_cap_um2: 1.1,
            comparator_um2: 180.0,
            sar_logic_um2: 260.0,
            periphery_frac: 1.30,
        }
    }
}

impl AreaModel {
    /// Total CR-CIM macro area [mm²].
    pub fn cr_cim_macro_mm2(&self, p: &MacroParams) -> f64 {
        let array = p.rows as f64 * p.cols as f64 * self.cell_um2;
        let per_col = self.comparator_um2 + self.sar_logic_um2;
        let adc = p.cols as f64 * per_col;
        (array * (1.0 + self.periphery_frac) + adc) * 1e-6
    }

    /// Per-column ADC area [µm²] for a CR-CIM at `bits` resolution: flat,
    /// because the caps are reused (valid while 2^bits ≤ rows).
    pub fn cr_cim_adc_col_um2(&self, _bits: u32) -> f64 {
        self.comparator_um2 + self.sar_logic_um2
    }

    /// Per-column ADC area [µm²] for a conventional charge CIM at `bits`:
    /// a separate binary C-DAC of 2^bits unit caps plus comparator+logic.
    pub fn conventional_adc_col_um2(&self, bits: u32) -> f64 {
        let dac = (1u64 << bits) as f64 * self.dac_unit_cap_um2;
        dac + self.comparator_um2 + self.sar_logic_um2
    }

    /// Fig. 1(B) series: (bits, conventional ADC area, CR-CIM ADC area)
    /// per column, normalized to the 4-bit conventional point.
    pub fn fig1b_series(&self, bit_range: std::ops::RangeInclusive<u32>) -> Vec<(u32, f64, f64)> {
        let base = self.conventional_adc_col_um2(4);
        bit_range
            .map(|b| {
                (
                    b,
                    self.conventional_adc_col_um2(b) / base,
                    self.cr_cim_adc_col_um2(b) / base,
                )
            })
            .collect()
    }

    /// 1b-normalized areal efficiency [TOPS/mm²] given a throughput.
    pub fn tops_per_mm2(&self, p: &MacroParams, tops: f64) -> f64 {
        tops / self.cr_cim_macro_mm2(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::energy::EnergyModel;
    use crate::cim::params::CbMode;

    #[test]
    fn macro_area_is_sub_mm2_scale() {
        let a = AreaModel::default();
        let p = MacroParams::default();
        let mm2 = a.cr_cim_macro_mm2(&p);
        // 1088×78 cells at 2.3 µm² ≈ 0.195 mm² array; with periphery the
        // macro should land at a few tenths of a mm².
        assert!(mm2 > 0.3 && mm2 < 0.8, "macro area {mm2} mm²");
    }

    #[test]
    fn areal_efficiency_near_paper() {
        let a = AreaModel::default();
        let p = MacroParams::default().with_supply(1.1);
        let tops = EnergyModel::cr_cim(&p).tops(CbMode::Off);
        let tpmm = a.tops_per_mm2(&p, tops);
        // Paper: 2.5 TOPS/mm² (1b-normalized).
        assert!((tpmm - 2.5).abs() / 2.5 < 0.35, "TOPS/mm2 = {tpmm}");
    }

    #[test]
    fn conventional_adc_area_explodes_with_bits() {
        let a = AreaModel::default();
        let at = |b| a.conventional_adc_col_um2(b);
        assert!(at(10) / at(4) > 3.0);
        // Each extra bit roughly doubles the DAC contribution at high B.
        assert!(at(12) / at(11) > 1.5);
        // CR-CIM stays flat.
        assert_eq!(a.cr_cim_adc_col_um2(4), a.cr_cim_adc_col_um2(12));
    }

    #[test]
    fn fig1b_series_shapes() {
        let a = AreaModel::default();
        let series = a.fig1b_series(4..=12);
        assert_eq!(series.len(), 9);
        // Conventional normalized to 1.0 at 4 bits and increasing.
        assert!((series[0].1 - 1.0).abs() < 1e-12);
        for w in series.windows(2) {
            assert!(w[1].1 > w[0].1);
            assert!((w[1].2 - w[0].2).abs() < 1e-12, "CR-CIM flat");
        }
        // At 10 bits the gap is large (the paper's "impractical" point).
        let ten = series.iter().find(|s| s.0 == 10).unwrap();
        assert!(ten.1 / ten.2 > 2.0, "10b conventional/CR-CIM = {}", ten.1 / ten.2);
    }
}
