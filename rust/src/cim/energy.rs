//! Energy / timing / efficiency model of the CR-CIM macro.
//!
//! The model is *compositional*: a conversion's energy is the sum of the
//! physical contributors (array sampling CV², C-DAC switching, N
//! comparator firings at the noise-limited energy law, SAR logic), so the
//! paper's claims fall out rather than being hard-coded:
//!
//! - CB costs 25 comparisons instead of 10 ⇒ with the comparator at ~60%
//!   of conversion energy the power overhead is ≈1.9× and the SAR-phase
//!   time overhead is 2.5× (Fig. 4).
//! - A conventional charge-redistribution CIM needs a comparator with 2×
//!   lower noise (half the swing reaches it) ⇒ 4× comparator energy at
//!   equal accuracy (Fig. 1/2 discussion).

use super::comparator::comparator_energy_pj;
use super::params::{CbMode, MacroParams};

/// Energy breakdown of one column conversion [pJ].
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub array_sample_pj: f64,
    pub dac_switch_pj: f64,
    pub comparator_pj: f64,
    pub logic_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.array_sample_pj + self.dac_switch_pj + self.comparator_pj + self.logic_pj
    }

    pub fn comparator_share(&self) -> f64 {
        self.comparator_pj / self.total_pj()
    }
}

/// Energy/latency model bound to a parameter set.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub params: MacroParams,
    /// Signal-swing advantage of CR-CIM over charge-redistribution
    /// readout: 1.0 = full swing (CR-CIM), 0.5 = conventional attenuation.
    pub swing_factor: f64,
}

impl EnergyModel {
    pub fn cr_cim(params: &MacroParams) -> Self {
        EnergyModel { params: params.clone(), swing_factor: 1.0 }
    }

    /// Conventional charge-redistribution readout: the MAC charge is
    /// shared with a separate C-DAC of equal size, halving the swing the
    /// comparator sees. To keep the same conversion accuracy the
    /// comparator noise spec tightens by the same factor.
    pub fn conventional(params: &MacroParams) -> Self {
        EnergyModel { params: params.clone(), swing_factor: 0.5 }
    }

    /// Comparator energy per firing [pJ] at the current supply, honoring
    /// the noise-limited law: halving the available swing means the
    /// comparator must be 2× quieter ⇒ 4× the energy.
    pub fn comparator_energy_per_firing_pj(&self) -> f64 {
        let p = &self.params;
        // Reference point: e_cmp_pj buys sigma_cmp_lsb of input-referred
        // noise at nominal supply with full swing.
        let sigma_ref_v = p.sigma_cmp_lsb * (p.supply_nominal_v / p.levels() as f64);
        // Required noise at the *attenuated* swing to keep the same
        // accuracy in LSB of the original signal:
        let sigma_req_v = sigma_ref_v * self.swing_factor * (p.supply_v / p.supply_nominal_v);
        comparator_energy_pj(p.e_cmp_pj, sigma_ref_v, p.supply_nominal_v, sigma_req_v, p.supply_v)
    }

    /// Full breakdown for one column conversion in `mode`.
    pub fn conversion(&self, mode: CbMode) -> EnergyBreakdown {
        let p = &self.params;
        let v = p.supply_v;
        let cv2_pj = p.c_total_f() * v * v * 1e12; // ΣC·V² in pJ
        let vr2 = (v / p.supply_nominal_v).powi(2);
        // A conventional architecture switches *two* arrays (CIM + C-DAC);
        // CR-CIM reconfigures one. swing_factor doubles as the marker.
        let dac_arrays = if self.swing_factor < 1.0 { 2.0 } else { 1.0 };
        EnergyBreakdown {
            array_sample_pj: p.alpha_sample * cv2_pj,
            dac_switch_pj: p.alpha_dac * cv2_pj * dac_arrays,
            comparator_pj: p.comparisons_per_conversion(mode) as f64
                * self.comparator_energy_per_firing_pj(),
            logic_pj: p.e_logic_pj * vr2,
        }
    }

    /// 1b-normalized energy efficiency [TOPS/W] in `mode`.
    pub fn tops_per_watt(&self, mode: CbMode) -> f64 {
        let e_pj = self.conversion(mode).total_pj();
        self.params.ops_per_conversion() / (e_pj * 1e-12) / 1e12
    }

    /// Macro-level 1b-normalized throughput [TOPS] in `mode`: all columns
    /// convert in parallel once per conversion cycle.
    pub fn tops(&self, mode: CbMode) -> f64 {
        let t_ns = self.params.conversion_latency_ns(mode);
        let ops = self.params.ops_per_conversion() * self.params.cols as f64;
        ops / (t_ns * 1e-9) / 1e12
    }

    /// Average power of the macro running flat out [mW].
    pub fn power_mw(&self, mode: CbMode) -> f64 {
        let e_pj = self.conversion(mode).total_pj() * self.params.cols as f64;
        let t_ns = self.params.conversion_latency_ns(mode);
        e_pj / t_ns // pJ/ns = mW
    }

    /// Energy of one column conversion [pJ].
    pub fn conversion_energy_pj(&self, mode: CbMode) -> f64 {
        self.conversion(mode).total_pj()
    }
}

/// A point of the supply sweep in Fig. 6 (TOPS vs TOPS/W trade).
#[derive(Clone, Copy, Debug)]
pub struct SupplyPoint {
    pub supply_v: f64,
    pub tops: f64,
    pub tops_per_watt: f64,
}

/// Sweep the supply range the paper reports (0.6–1.1 V).
pub fn supply_sweep(base: &MacroParams, mode: CbMode, points: usize) -> Vec<SupplyPoint> {
    (0..points)
        .map(|i| {
            let v = 0.6 + (1.1 - 0.6) * i as f64 / (points - 1).max(1) as f64;
            let p = base.clone().with_supply(v);
            let m = EnergyModel::cr_cim(&p);
            SupplyPoint { supply_v: v, tops: m.tops(mode), tops_per_watt: m.tops_per_watt(mode) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_efficiency_near_818_tops_per_watt() {
        // Peak = lowest supply, CB off (fastest/cheapest conversions).
        let p = MacroParams::default().with_supply(0.6);
        let m = EnergyModel::cr_cim(&p);
        let tpw = m.tops_per_watt(CbMode::Off);
        assert!(
            (tpw - 818.0).abs() / 818.0 < 0.10,
            "calibration drifted: {tpw:.0} TOPS/W (target 818)"
        );
    }

    #[test]
    fn comparator_dominates_conversion_energy() {
        let p = MacroParams::default();
        let m = EnergyModel::cr_cim(&p);
        let share = m.conversion(CbMode::Off).comparator_share();
        assert!(share > 0.45 && share < 0.75, "comparator share {share}");
    }

    #[test]
    fn cb_power_overhead_close_to_paper_1p9x() {
        let p = MacroParams::default();
        let m = EnergyModel::cr_cim(&p);
        let ratio = m.conversion_energy_pj(CbMode::On) / m.conversion_energy_pj(CbMode::Off);
        assert!(
            (ratio - 1.9).abs() < 0.15,
            "CB energy overhead {ratio:.2}x (paper: 1.9x)"
        );
    }

    #[test]
    fn cb_sar_time_overhead_is_2p5x() {
        let p = MacroParams::default();
        let sar_off = p.comparisons_per_conversion(CbMode::Off) as f64 * p.t_cmp_ns;
        let sar_on = p.comparisons_per_conversion(CbMode::On) as f64 * p.t_cmp_ns;
        assert!((sar_on / sar_off - 2.5).abs() < 1e-9);
    }

    #[test]
    fn conventional_comparator_pays_4x() {
        let p = MacroParams::default();
        let cr = EnergyModel::cr_cim(&p);
        let conv = EnergyModel::conventional(&p);
        let ratio = conv.comparator_energy_per_firing_pj() / cr.comparator_energy_per_firing_pj();
        assert!((ratio - 4.0).abs() < 1e-9, "attenuation should cost 4x: {ratio}");
    }

    #[test]
    fn peak_tops_near_paper_at_max_supply() {
        let p = MacroParams::default().with_supply(1.1);
        let m = EnergyModel::cr_cim(&p);
        let tops = m.tops(CbMode::Off);
        assert!((tops - 1.2).abs() / 1.2 < 0.35, "peak TOPS {tops} (paper 1.2)");
    }

    #[test]
    fn supply_sweep_monotone_tradeoff() {
        let pts = supply_sweep(&MacroParams::default(), CbMode::Off, 6);
        assert_eq!(pts.len(), 6);
        for w in pts.windows(2) {
            assert!(w[1].tops > w[0].tops, "throughput rises with supply");
            assert!(w[1].tops_per_watt < w[0].tops_per_watt, "efficiency falls with supply");
        }
    }

    #[test]
    fn power_is_energy_over_time_consistent() {
        let p = MacroParams::default();
        let m = EnergyModel::cr_cim(&p);
        let mode = CbMode::Off;
        let direct = m.power_mw(mode);
        let recomputed = m.conversion_energy_pj(mode) * p.cols as f64
            / p.conversion_latency_ns(mode);
        assert!((direct - recomputed).abs() < 1e-9);
    }
}
