//! CR-CIM macro simulator: the substrate the paper's silicon evaluation
//! ran on, rebuilt as a Monte-Carlo circuit model.
//!
//! Layering (bottom-up):
//! - [`params`]     — every physical constant + calibration rationale
//! - [`cell`]       — 10T cell & the Reset→Compute→Adc phase contract
//! - [`capacitor`]  — mismatch-sampled dual-role capacitor bank
//! - [`comparator`] — noise / offset / majority voting / energy law
//! - [`sar`]        — successive approximation over the reconfigured bank
//! - [`column`]     — one full column (the Fig. 5 unit of measurement)
//! - [`macro_`]     — 1088×78 macro: bit-serial, bit-sliced multi-bit MACs
//! - [`energy`]     — conversion energy/latency, TOPS/W, supply sweeps
//! - [`area`]       — 65 nm area model & the Fig. 1(B) scaling argument
//! - [`baselines`]  — [2]/[4]/[6]-like comparison architectures
//! - [`netstats`]   — accuracy-vs-CSNR layer tolerance models (Fig. 1A/4)

pub mod area;
pub mod baselines;
pub mod calibration;
pub mod capacitor;
pub mod cell;
pub mod column;
pub mod comparator;
pub mod energy;
pub mod macro_;
pub mod montecarlo;
pub mod netstats;
pub mod params;
pub mod sar;

pub use column::Column;
pub use energy::EnergyModel;
pub use macro_::CimMacro;
pub use params::{CbMode, MacroParams};
