//! The full 1088×78 CR-CIM macro: multi-bit matrix-vector products built
//! from binary column conversions.
//!
//! Multi-bit scheme (as in Fig. 6's "configurable" precisions):
//! - **weights** are bit-sliced across adjacent physical columns
//!   (two's complement: the MSB plane carries weight −2^(w_bits−1));
//! - **activations** are applied bit-serially over a_bits conversion
//!   cycles (two's complement MSB cycle subtracted);
//! - the periphery reconstructs y = Σ_{a,b} ±2^{a+b}·code[a,b] with a
//!   digital shift-add, exactly like the chip's registered output path.
//!
//! Every binary cycle of every used column goes through the full analog
//! column model (mismatch, nonlinearity, kT/C, comparator noise, optional
//! majority voting), so layer-level accuracy experiments see the true
//! hardware error statistics.
//!
//! **Execution model (column-parallel engine).** The chip converts all
//! used columns in the same cycle, so the simulator fans the
//! `n_out × w_bits` column conversions across a worker pool
//! ([`parallel_map_mut`]). Every column draws noise from its *owned*
//! substream keyed by (die seed, column index, conversion counter), so
//! the output is bit-identical at any `MacroParams::threads` setting —
//! the determinism contract the Monte-Carlo sweeps rely on. Within a
//! column, conversions run in activation-bit order per vector, exactly
//! the per-column sequence the serial engine produced.

use crate::util::pool::parallel_map_mut;
#[cfg(test)]
use crate::util::rng::Rng;

use super::column::Column;
use super::energy::EnergyModel;
use super::params::{CbMode, MacroParams};

/// Outcome of a macro-level matvec: values plus the hardware cost.
#[derive(Clone, Debug)]
pub struct MacrunResult {
    /// Reconstructed outputs (one per logical output channel).
    pub y: Vec<i64>,
    /// Total column conversions performed.
    pub conversions: u64,
    /// Total energy [pJ] (conversion energy × conversions).
    pub energy_pj: f64,
    /// Wall latency [ns] (bit-serial cycles × conversion latency).
    pub latency_ns: f64,
}

/// The macro: a bank of columns plus the digital reconstruction periphery.
///
/// One macro converts a fixed tile: at most `active_rows` rows of the
/// reduction dimension and `cols / w_bits` logical outputs. Layers that
/// exceed either bound split across macros — column shards over the
/// outputs and row tiles over the reduction dimension, with row-tile
/// partial sums accumulated digitally — by
/// [`MacroShards`](crate::coordinator::MacroShards) (see
/// `docs/ARCHITECTURE.md` for the 2-D tiling model).
pub struct CimMacro {
    /// Die parameters this macro was instantiated with (seed identifies
    /// the die; `col_base` keys this macro's columns into a wider
    /// logical column array when it serves as a shard).
    pub params: MacroParams,
    columns: Vec<Column>,
    energy: EnergyModel,
    /// Loaded weight configuration.
    loaded: Option<LoadedWeights>,
}

#[derive(Clone, Debug)]
struct LoadedWeights {
    rows: usize,
    n_out: usize,
    w_bits: u32,
}

/// Below this many conversions per call the scoped-thread spawn/join cost
/// outweighs the conversion work, so the engine runs serially. Outputs
/// are identical either way (the determinism contract), only wall time
/// changes.
const PARALLEL_MIN_CONVERSIONS: u64 = 256;

impl CimMacro {
    /// Instantiate the die's macro: every column samples its mismatch and
    /// noise substreams from (`params.seed`, global column index).
    pub fn new(params: &MacroParams) -> Result<Self, String> {
        params.validate()?;
        let columns = (0..params.cols)
            .map(|c| Column::new(params, c))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(CimMacro {
            params: params.clone(),
            columns,
            energy: EnergyModel::cr_cim(params),
            loaded: None,
        })
    }

    /// An ideal macro (no analog error): digital reference datapath.
    pub fn ideal(params: &MacroParams) -> Result<Self, String> {
        params.validate()?;
        let columns = (0..params.cols)
            .map(|_| Column::ideal(params))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(CimMacro {
            params: params.clone(),
            columns,
            energy: EnergyModel::cr_cim(params),
            loaded: None,
        })
    }

    /// Physical columns needed for `n_out` logical outputs at `w_bits`.
    pub fn columns_needed(n_out: usize, w_bits: u32) -> usize {
        n_out * w_bits as usize
    }

    /// Maximum logical outputs a tile can hold at `w_bits`.
    pub fn max_outputs(&self, w_bits: u32) -> usize {
        self.params.cols / w_bits as usize
    }

    /// Load a signed weight tile `w[row][out]` (two's complement range
    /// checked against w_bits). Rows beyond `w.len()` are zero-padded.
    pub fn load_weights(
        &mut self,
        w: &[Vec<i32>],
        w_bits: u32,
    ) -> Result<(), String> {
        if w_bits == 0 || w_bits > 31 {
            return Err(format!("w_bits {w_bits} out of range 1..=31"));
        }
        let rows = w.len();
        if rows == 0 || rows > self.params.active_rows {
            return Err(format!(
                "weight tile rows {rows} out of range 1..={}",
                self.params.active_rows
            ));
        }
        let n_out = w[0].len();
        if Self::columns_needed(n_out, w_bits) > self.params.cols {
            return Err(format!(
                "{n_out} outputs at {w_bits}b need {} columns, macro has {}",
                Self::columns_needed(n_out, w_bits),
                self.params.cols
            ));
        }
        let lo = -(1i32 << (w_bits - 1));
        let hi = (1i32 << (w_bits - 1)) - 1;
        let n = self.params.active_rows;
        for (j, out) in (0..n_out).map(|j| (j, j * w_bits as usize)) {
            for b in 0..w_bits {
                let mut bits = vec![false; n];
                for (r, wrow) in w.iter().enumerate() {
                    let v = wrow[j];
                    if v < lo || v > hi {
                        return Err(format!("weight {v} exceeds {w_bits}-bit range"));
                    }
                    // Two's complement bit b of v.
                    let u = (v as i64 & ((1i64 << w_bits) - 1)) as u64;
                    bits[r] = (u >> b) & 1 == 1;
                }
                self.columns[out + b as usize].load_weights(&bits);
            }
        }
        self.loaded = Some(LoadedWeights { rows, n_out, w_bits });
        Ok(())
    }

    /// Run a signed activation vector through the loaded tile.
    /// `x[r]` must fit in `a_bits` two's complement.
    pub fn matvec(&mut self, x: &[i32], a_bits: u32, mode: CbMode) -> Result<MacrunResult, String> {
        let mut results = self.matvec_batch(std::slice::from_ref(&x), a_bits, mode)?;
        Ok(results.pop().expect("batch of one yields one result"))
    }

    /// Run a batch of activation vectors through the loaded tile,
    /// amortizing bit-plane construction and worker fan-out over the whole
    /// batch. Column conversions fan out across
    /// `self.params.effective_threads()` workers; because each column owns
    /// its noise substream, the results are bit-identical to calling
    /// [`matvec`](Self::matvec) once per vector, at any thread count.
    pub fn matvec_batch<V: AsRef<[i32]>>(
        &mut self,
        xs: &[V],
        a_bits: u32,
        mode: CbMode,
    ) -> Result<Vec<MacrunResult>, String> {
        let loaded = self
            .loaded
            .clone()
            .ok_or_else(|| "no weights loaded".to_string())?;
        if a_bits == 0 || a_bits > 31 {
            return Err(format!("a_bits {a_bits} out of range 1..=31"));
        }
        let lo = -(1i32 << (a_bits - 1));
        let hi = (1i32 << (a_bits - 1)) - 1;
        for (v, x) in xs.iter().enumerate() {
            let x = x.as_ref();
            if x.len() != loaded.rows {
                return Err(format!(
                    "activation {v} length {} != loaded rows {}",
                    x.len(),
                    loaded.rows
                ));
            }
            for &val in x {
                if val < lo || val > hi {
                    return Err(format!("activation {val} exceeds {a_bits}-bit range"));
                }
            }
        }
        let n = self.params.active_rows;
        let w_bits = loaded.w_bits;
        let used_cols = Self::columns_needed(loaded.n_out, w_bits);
        // Bit planes for every (vector, activation bit), built once for
        // the whole batch and shared read-only by all workers.
        let planes: Vec<Vec<Vec<bool>>> = xs
            .iter()
            .map(|x| {
                let x = x.as_ref();
                (0..a_bits)
                    .map(|a| {
                        let mut plane = vec![false; n];
                        for (r, &v) in x.iter().enumerate() {
                            let u = (v as i64 & ((1i64 << a_bits) - 1)) as u64;
                            plane[r] = (u >> a) & 1 == 1;
                        }
                        plane
                    })
                    .collect()
            })
            .collect();
        let planes = &planes;
        let total_conversions = used_cols as u64 * a_bits as u64 * xs.len() as u64;
        let threads = if total_conversions < PARALLEL_MIN_CONVERSIONS {
            1
        } else {
            self.params.effective_threads()
        };
        // Fan the column conversions across the worker pool: each physical
        // column runs its full bit-serial schedule for the whole batch.
        let partials: Vec<Vec<i64>> =
            parallel_map_mut(&mut self.columns[..used_cols], threads, |c, col| {
                let b = (c % w_bits as usize) as u32;
                let w_weight: i64 = if b == w_bits - 1 { -(1i64 << b) } else { 1i64 << b };
                planes
                    .iter()
                    .map(|vec_planes| {
                        let mut acc = 0i64;
                        for (a, plane) in vec_planes.iter().enumerate() {
                            let a_weight: i64 = if a as u32 == a_bits - 1 {
                                -(1i64 << a)
                            } else {
                                1i64 << a
                            };
                            let conv = col.mac_convert_owned(plane, mode);
                            acc += a_weight * conv.code as i64;
                        }
                        w_weight * acc
                    })
                    .collect()
            });
        let conversions_per_vec = used_cols as u64 * a_bits as u64;
        let e_conv = self.energy.conversion_energy_pj(mode);
        let latency = a_bits as f64 * self.params.conversion_latency_ns(mode);
        let results = (0..xs.len())
            .map(|v| {
                let mut y = vec![0i64; loaded.n_out];
                for (c, per_vec) in partials.iter().enumerate() {
                    y[c / w_bits as usize] += per_vec[v];
                }
                MacrunResult {
                    y,
                    conversions: conversions_per_vec,
                    energy_pj: e_conv * conversions_per_vec as f64,
                    latency_ns: latency,
                }
            })
            .collect();
        Ok(results)
    }

    /// Exact integer reference for the loaded tile (periphery bypass).
    /// An empty weight matrix has no outputs.
    pub fn matvec_exact(&self, w: &[Vec<i32>], x: &[i32]) -> Vec<i64> {
        matvec_exact(w, x)
    }

    /// 1b-normalized op count of one matvec on the loaded tile.
    pub fn ops_matvec(&self, a_bits: u32) -> Option<f64> {
        let l = self.loaded.as_ref()?;
        Some(2.0 * l.rows as f64 * l.n_out as f64 * a_bits as f64 * l.w_bits as f64)
    }

    /// Monte-Carlo estimate of output-referred noise (std of y around the
    /// exact value) for the loaded tile at the given precision and mode.
    /// This is what calibrates the L1 behavioral kernel's σ.
    pub fn calibrate_output_noise(
        &mut self,
        w: &[Vec<i32>],
        x: &[i32],
        a_bits: u32,
        mode: CbMode,
        trials: usize,
    ) -> Result<f64, String> {
        if trials == 0 {
            return Err("calibrate_output_noise: trials must be > 0".to_string());
        }
        let exact = self.matvec_exact(w, x);
        if exact.is_empty() {
            return Err("calibrate_output_noise: empty weight matrix".to_string());
        }
        let mut sq = 0.0;
        let mut count = 0usize;
        for _ in 0..trials {
            let r = self.matvec(x, a_bits, mode)?;
            for (got, want) in r.y.iter().zip(&exact) {
                let d = (*got - *want) as f64;
                sq += d * d;
                count += 1;
            }
        }
        Ok((sq / count as f64).sqrt())
    }
}

/// Exact integer matvec `y[j] = Σ_r w[r][j]·x[r]` — the digital
/// reference every analog decomposition is tested against. Free
/// function so graph-level reference walks (`coordinator::pipeline`)
/// can use it without instantiating a macro.
pub fn matvec_exact(w: &[Vec<i32>], x: &[i32]) -> Vec<i64> {
    let n_out = match w.first() {
        Some(row) => row.len(),
        None => return Vec::new(),
    };
    let mut y = vec![0i64; n_out];
    for (r, wrow) in w.iter().enumerate() {
        for (j, &wv) in wrow.iter().enumerate() {
            y[j] += wv as i64 * x[r] as i64;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 8;
        p.active_rows = 256;
        p.rows = 256;
        p.cols = 12;
        p
    }

    fn tile(rows: usize, n_out: usize, w_bits: u32, seed: u64) -> (Vec<Vec<i32>>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let lo = -(1i32 << (w_bits - 1));
        let hi = (1i32 << (w_bits - 1)) - 1;
        let w: Vec<Vec<i32>> = (0..rows)
            .map(|_| {
                (0..n_out)
                    .map(|_| lo + rng.below((hi - lo + 1) as u64) as i32)
                    .collect()
            })
            .collect();
        let x: Vec<i32> = (0..rows).map(|_| lo + rng.below((hi - lo + 1) as u64) as i32).collect();
        (w, x)
    }

    #[test]
    fn ideal_macro_matches_exact_integer_matvec() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        for seed in 0..3 {
            let (w, x) = tile(200, 3, 4, seed);
            m.load_weights(&w, 4).unwrap();
            let got = m.matvec(&x, 4, CbMode::Off).unwrap();
            let want = m.matvec_exact(&w, &x);
            assert_eq!(got.y, want, "seed {seed}");
        }
    }

    #[test]
    fn ideal_macro_exact_at_mixed_precisions() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        for (a_bits, w_bits) in [(1u32, 1u32), (2, 3), (6, 2), (4, 4)] {
            let (w, x) = tile(128, (12 / w_bits) as usize, w_bits, 7);
            let mut xq = x;
            // Clamp activations into a_bits range.
            let lo = -(1i32 << (a_bits - 1));
            let hi = (1i32 << (a_bits - 1)) - 1;
            for v in xq.iter_mut() {
                *v = (*v).clamp(lo, hi);
            }
            m.load_weights(&w, w_bits).unwrap();
            let got = m.matvec(&xq, a_bits, CbMode::Off).unwrap();
            let want = m.matvec_exact(&w, &xq);
            assert_eq!(got.y, want, "a={a_bits} w={w_bits}");
        }
    }

    #[test]
    fn conversions_and_energy_accounting() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        let (w, x) = tile(100, 2, 3, 1);
        m.load_weights(&w, 3).unwrap();
        let r = m.matvec(&x, 4, CbMode::Off).unwrap();
        // 4 input cycles × (2 outputs × 3 planes) conversions.
        assert_eq!(r.conversions, 4 * 6);
        assert!(r.energy_pj > 0.0);
        assert!(r.latency_ns > 0.0);
        // CB costs more energy and time for the same tile.
        let r_cb = m.matvec(&x, 4, CbMode::On).unwrap();
        assert!(r_cb.energy_pj > r.energy_pj * 1.5);
        assert!(r_cb.latency_ns > r.latency_ns * 1.5);
    }

    #[test]
    fn rejects_out_of_range_operands() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        let w = vec![vec![7i32, -8], vec![3, 2]];
        assert!(m.load_weights(&w, 4).is_ok());
        let w_bad = vec![vec![8i32, 0]];
        assert!(m.load_weights(&w_bad, 4).is_err());
        m.load_weights(&w, 4).unwrap();
        assert!(m.matvec(&[8, 0], 4, CbMode::Off).is_err()); // activation range
        assert!(m.matvec(&[1], 4, CbMode::Off).is_err()); // length mismatch
    }

    #[test]
    fn rejects_oversized_tiles() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        // 5 outputs × 3 bits = 15 columns > 12.
        let w = vec![vec![1i32; 5]; 10];
        assert!(m.load_weights(&w, 3).is_err());
        assert_eq!(m.max_outputs(3), 4);
        // Too many rows.
        let w = vec![vec![1i32; 2]; 1000];
        assert!(m.load_weights(&w, 3).is_err());
    }

    #[test]
    fn real_macro_close_to_exact_but_noisy() {
        let mut p = tiny_params();
        p.sigma_cmp_lsb = 1.1;
        let mut m = CimMacro::new(&p).unwrap();
        let (w, x) = tile(256, 2, 4, 3);
        m.load_weights(&w, 4).unwrap();
        let want = m.matvec_exact(&w, &x);
        let got = m.matvec(&x, 4, CbMode::Off).unwrap();
        for (g, e) in got.y.iter().zip(&want) {
            let err = (*g - *e).abs() as f64;
            // Error should be small vs the output magnitude scale
            // (~N·2^(a+w)/4) but generally nonzero.
            assert!(err < 2000.0, "err={err} got={g} want={e}");
        }
    }

    #[test]
    fn matvec_bit_identical_across_thread_counts() {
        let mut base = tiny_params();
        base.sigma_cmp_lsb = 1.1; // real noise, so determinism is nontrivial
        let (w, _) = tile(256, 3, 4, 11);
        // Batch of 8: 12 cols × 4 bits × 8 = 384 conversions, above the
        // serial-fallback threshold, so the worker pool actually engages.
        let xs: Vec<Vec<i32>> = (0..8).map(|s| tile(256, 3, 4, 50 + s).1).collect();
        let run = |threads: usize| {
            let p = base.clone().with_threads(threads);
            let mut m = CimMacro::new(&p).unwrap();
            m.load_weights(&w, 4).unwrap();
            m.matvec_batch(&xs, 4, CbMode::On)
                .unwrap()
                .into_iter()
                .map(|r| r.y)
                .collect::<Vec<_>>()
        };
        let serial = run(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(run(threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn matvec_batch_matches_serial_matvec_calls() {
        let mut p = tiny_params();
        p.sigma_cmp_lsb = 1.1;
        p.threads = 4;
        let (w, _) = tile(200, 3, 4, 21);
        let xs: Vec<Vec<i32>> = (0..5).map(|s| tile(200, 3, 4, 100 + s).1).collect();
        let mut m1 = CimMacro::new(&p).unwrap();
        m1.load_weights(&w, 4).unwrap();
        let batch = m1.matvec_batch(&xs, 4, CbMode::Off).unwrap();
        let mut m2 = CimMacro::new(&p).unwrap();
        m2.load_weights(&w, 4).unwrap();
        for (v, x) in xs.iter().enumerate() {
            let one = m2.matvec(x, 4, CbMode::Off).unwrap();
            assert_eq!(batch[v].y, one.y, "vector {v}");
            assert_eq!(batch[v].conversions, one.conversions);
        }
    }

    #[test]
    fn matvec_exact_handles_empty_weight_matrix() {
        let p = tiny_params();
        let m = CimMacro::ideal(&p).unwrap();
        assert_eq!(m.matvec_exact(&[], &[]), Vec::<i64>::new());
    }

    #[test]
    fn rejects_oversized_bit_widths() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        let (w, x) = tile(100, 2, 3, 1);
        assert!(m.load_weights(&w, 0).is_err());
        assert!(m.load_weights(&[vec![]], 40).is_err());
        m.load_weights(&w, 3).unwrap();
        assert!(m.matvec(&x, 0, CbMode::Off).is_err());
        assert!(m.matvec(&x, 32, CbMode::Off).is_err());
    }

    #[test]
    fn calibrate_rejects_zero_trials_and_empty_weights() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        let (w, x) = tile(100, 2, 3, 1);
        m.load_weights(&w, 3).unwrap();
        assert!(m.calibrate_output_noise(&w, &x, 3, CbMode::Off, 0).is_err());
        assert!(m.calibrate_output_noise(&[], &x, 3, CbMode::Off, 4).is_err());
    }

    #[test]
    fn calibrated_noise_cb_beats_no_cb() {
        let mut p = tiny_params();
        p.sigma_cmp_lsb = 1.1;
        p.sigma_cu_rel = 0.0; // isolate comparator noise
        p.nonlin_cubic_lsb = 0.0;
        let mut m = CimMacro::new(&p).unwrap();
        let (w, x) = tile(256, 2, 2, 9);
        m.load_weights(&w, 2).unwrap();
        let s_off = m.calibrate_output_noise(&w, &x, 2, CbMode::Off, 60).unwrap();
        let s_on = m.calibrate_output_noise(&w, &x, 2, CbMode::On, 60).unwrap();
        assert!(s_on < s_off, "CB should reduce noise: on={s_on} off={s_off}");
    }
}
