//! The full 1088×78 CR-CIM macro: multi-bit matrix-vector products built
//! from binary column conversions.
//!
//! Multi-bit scheme (as in Fig. 6's "configurable" precisions):
//! - **weights** are bit-sliced across adjacent physical columns
//!   (two's complement: the MSB plane carries weight −2^(w_bits−1));
//! - **activations** are applied bit-serially over a_bits conversion
//!   cycles (two's complement MSB cycle subtracted);
//! - the periphery reconstructs y = Σ_{a,b} ±2^{a+b}·code[a,b] with a
//!   digital shift-add, exactly like the chip's registered output path.
//!
//! Every binary cycle of every used column goes through the full analog
//! column model (mismatch, nonlinearity, kT/C, comparator noise, optional
//! majority voting), so layer-level accuracy experiments see the true
//! hardware error statistics.

use crate::util::rng::Rng;

use super::column::Column;
use super::energy::EnergyModel;
use super::params::{CbMode, MacroParams};

/// Outcome of a macro-level matvec: values plus the hardware cost.
#[derive(Clone, Debug)]
pub struct MacrunResult {
    /// Reconstructed outputs (one per logical output channel).
    pub y: Vec<i64>,
    /// Total column conversions performed.
    pub conversions: u64,
    /// Total energy [pJ] (conversion energy × conversions).
    pub energy_pj: f64,
    /// Wall latency [ns] (bit-serial cycles × conversion latency).
    pub latency_ns: f64,
}

/// The macro: a bank of columns plus the digital reconstruction periphery.
pub struct CimMacro {
    pub params: MacroParams,
    columns: Vec<Column>,
    energy: EnergyModel,
    /// Loaded weight configuration.
    loaded: Option<LoadedWeights>,
    rng: Rng,
}

#[derive(Clone, Debug)]
struct LoadedWeights {
    rows: usize,
    n_out: usize,
    w_bits: u32,
}

impl CimMacro {
    pub fn new(params: &MacroParams) -> Result<Self, String> {
        params.validate()?;
        let columns = (0..params.cols)
            .map(|c| Column::new(params, c))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(CimMacro {
            params: params.clone(),
            columns,
            energy: EnergyModel::cr_cim(params),
            loaded: None,
            rng: Rng::new(params.seed ^ 0xACC0_57A7E),
        })
    }

    /// An ideal macro (no analog error): digital reference datapath.
    pub fn ideal(params: &MacroParams) -> Result<Self, String> {
        params.validate()?;
        let columns = (0..params.cols)
            .map(|_| Column::ideal(params))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(CimMacro {
            params: params.clone(),
            columns,
            energy: EnergyModel::cr_cim(params),
            loaded: None,
            rng: Rng::new(params.seed ^ 0xACC0_57A7E),
        })
    }

    /// Physical columns needed for `n_out` logical outputs at `w_bits`.
    pub fn columns_needed(n_out: usize, w_bits: u32) -> usize {
        n_out * w_bits as usize
    }

    /// Maximum logical outputs a tile can hold at `w_bits`.
    pub fn max_outputs(&self, w_bits: u32) -> usize {
        self.params.cols / w_bits as usize
    }

    /// Load a signed weight tile `w[row][out]` (two's complement range
    /// checked against w_bits). Rows beyond `w.len()` are zero-padded.
    pub fn load_weights(
        &mut self,
        w: &[Vec<i32>],
        w_bits: u32,
    ) -> Result<(), String> {
        let rows = w.len();
        if rows == 0 || rows > self.params.active_rows {
            return Err(format!(
                "weight tile rows {rows} out of range 1..={}",
                self.params.active_rows
            ));
        }
        let n_out = w[0].len();
        if Self::columns_needed(n_out, w_bits) > self.params.cols {
            return Err(format!(
                "{n_out} outputs at {w_bits}b need {} columns, macro has {}",
                Self::columns_needed(n_out, w_bits),
                self.params.cols
            ));
        }
        let lo = -(1i32 << (w_bits - 1));
        let hi = (1i32 << (w_bits - 1)) - 1;
        let n = self.params.active_rows;
        for (j, out) in (0..n_out).map(|j| (j, j * w_bits as usize)) {
            for b in 0..w_bits {
                let mut bits = vec![false; n];
                for (r, wrow) in w.iter().enumerate() {
                    let v = wrow[j];
                    if v < lo || v > hi {
                        return Err(format!("weight {v} exceeds {w_bits}-bit range"));
                    }
                    // Two's complement bit b of v.
                    let u = (v as i64 & ((1i64 << w_bits) - 1)) as u64;
                    bits[r] = (u >> b) & 1 == 1;
                }
                self.columns[out + b as usize].load_weights(&bits);
            }
        }
        self.loaded = Some(LoadedWeights { rows, n_out, w_bits });
        Ok(())
    }

    /// Run a signed activation vector through the loaded tile.
    /// `x[r]` must fit in `a_bits` two's complement.
    pub fn matvec(&mut self, x: &[i32], a_bits: u32, mode: CbMode) -> Result<MacrunResult, String> {
        let loaded = self
            .loaded
            .clone()
            .ok_or_else(|| "no weights loaded".to_string())?;
        if x.len() != loaded.rows {
            return Err(format!(
                "activation length {} != loaded rows {}",
                x.len(),
                loaded.rows
            ));
        }
        let lo = -(1i32 << (a_bits - 1));
        let hi = (1i32 << (a_bits - 1)) - 1;
        for &v in x {
            if v < lo || v > hi {
                return Err(format!("activation {v} exceeds {a_bits}-bit range"));
            }
        }
        let n = self.params.active_rows;
        let used_cols = Self::columns_needed(loaded.n_out, loaded.w_bits);
        let mut y = vec![0i64; loaded.n_out];
        let mut conversions = 0u64;

        // Bit-serial input cycles.
        for a in 0..a_bits {
            let a_weight: i64 = if a == a_bits - 1 {
                -(1i64 << a)
            } else {
                1i64 << a
            };
            // Input bit plane for this cycle.
            let mut in_bits = vec![false; n];
            for (r, &v) in x.iter().enumerate() {
                let u = (v as i64 & ((1i64 << a_bits) - 1)) as u64;
                in_bits[r] = (u >> a) & 1 == 1;
            }
            // All used columns convert in parallel (same cycle).
            for j in 0..loaded.n_out {
                for b in 0..loaded.w_bits {
                    let col = j * loaded.w_bits as usize + b as usize;
                    let w_weight: i64 = if b == loaded.w_bits - 1 {
                        -(1i64 << b)
                    } else {
                        1i64 << b
                    };
                    let conv = self.columns[col].mac_convert(&in_bits, mode, &mut self.rng);
                    conversions += 1;
                    y[j] += a_weight * w_weight * conv.code as i64;
                }
            }
        }
        let _ = used_cols; // columns convert in parallel; latency is per cycle
        let e_conv = self.energy.conversion_energy_pj(mode);
        let latency = a_bits as f64 * self.params.conversion_latency_ns(mode);
        Ok(MacrunResult { y, conversions, energy_pj: e_conv * conversions as f64, latency_ns: latency })
    }

    /// Exact integer reference for the loaded tile (periphery bypass).
    pub fn matvec_exact(&self, w: &[Vec<i32>], x: &[i32]) -> Vec<i64> {
        let n_out = w[0].len();
        let mut y = vec![0i64; n_out];
        for (r, wrow) in w.iter().enumerate() {
            for (j, &wv) in wrow.iter().enumerate() {
                y[j] += wv as i64 * x[r] as i64;
            }
        }
        y
    }

    /// 1b-normalized op count of one matvec on the loaded tile.
    pub fn ops_matvec(&self, a_bits: u32) -> Option<f64> {
        let l = self.loaded.as_ref()?;
        Some(2.0 * l.rows as f64 * l.n_out as f64 * a_bits as f64 * l.w_bits as f64)
    }

    /// Monte-Carlo estimate of output-referred noise (std of y around the
    /// exact value) for the loaded tile at the given precision and mode.
    /// This is what calibrates the L1 behavioral kernel's σ.
    pub fn calibrate_output_noise(
        &mut self,
        w: &[Vec<i32>],
        x: &[i32],
        a_bits: u32,
        mode: CbMode,
        trials: usize,
    ) -> Result<f64, String> {
        let exact = self.matvec_exact(w, x);
        let mut sq = 0.0;
        let mut count = 0usize;
        for _ in 0..trials {
            let r = self.matvec(x, a_bits, mode)?;
            for (got, want) in r.y.iter().zip(&exact) {
                let d = (*got - *want) as f64;
                sq += d * d;
                count += 1;
            }
        }
        Ok((sq / count.max(1) as f64).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 8;
        p.active_rows = 256;
        p.rows = 256;
        p.cols = 12;
        p
    }

    fn tile(rows: usize, n_out: usize, w_bits: u32, seed: u64) -> (Vec<Vec<i32>>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let lo = -(1i32 << (w_bits - 1));
        let hi = (1i32 << (w_bits - 1)) - 1;
        let w: Vec<Vec<i32>> = (0..rows)
            .map(|_| {
                (0..n_out)
                    .map(|_| lo + rng.below((hi - lo + 1) as u64) as i32)
                    .collect()
            })
            .collect();
        let x: Vec<i32> = (0..rows).map(|_| lo + rng.below((hi - lo + 1) as u64) as i32).collect();
        (w, x)
    }

    #[test]
    fn ideal_macro_matches_exact_integer_matvec() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        for seed in 0..3 {
            let (w, x) = tile(200, 3, 4, seed);
            m.load_weights(&w, 4).unwrap();
            let got = m.matvec(&x, 4, CbMode::Off).unwrap();
            let want = m.matvec_exact(&w, &x);
            assert_eq!(got.y, want, "seed {seed}");
        }
    }

    #[test]
    fn ideal_macro_exact_at_mixed_precisions() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        for (a_bits, w_bits) in [(1u32, 1u32), (2, 3), (6, 2), (4, 4)] {
            let (w, x) = tile(128, (12 / w_bits) as usize, w_bits, 7);
            let mut xq = x;
            // Clamp activations into a_bits range.
            let lo = -(1i32 << (a_bits - 1));
            let hi = (1i32 << (a_bits - 1)) - 1;
            for v in xq.iter_mut() {
                *v = (*v).clamp(lo, hi);
            }
            m.load_weights(&w, w_bits).unwrap();
            let got = m.matvec(&xq, a_bits, CbMode::Off).unwrap();
            let want = m.matvec_exact(&w, &xq);
            assert_eq!(got.y, want, "a={a_bits} w={w_bits}");
        }
    }

    #[test]
    fn conversions_and_energy_accounting() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        let (w, x) = tile(100, 2, 3, 1);
        m.load_weights(&w, 3).unwrap();
        let r = m.matvec(&x, 4, CbMode::Off).unwrap();
        // 4 input cycles × (2 outputs × 3 planes) conversions.
        assert_eq!(r.conversions, 4 * 6);
        assert!(r.energy_pj > 0.0);
        assert!(r.latency_ns > 0.0);
        // CB costs more energy and time for the same tile.
        let r_cb = m.matvec(&x, 4, CbMode::On).unwrap();
        assert!(r_cb.energy_pj > r.energy_pj * 1.5);
        assert!(r_cb.latency_ns > r.latency_ns * 1.5);
    }

    #[test]
    fn rejects_out_of_range_operands() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        let w = vec![vec![7i32, -8], vec![3, 2]];
        assert!(m.load_weights(&w, 4).is_ok());
        let w_bad = vec![vec![8i32, 0]];
        assert!(m.load_weights(&w_bad, 4).is_err());
        m.load_weights(&w, 4).unwrap();
        assert!(m.matvec(&[8, 0], 4, CbMode::Off).is_err()); // activation range
        assert!(m.matvec(&[1], 4, CbMode::Off).is_err()); // length mismatch
    }

    #[test]
    fn rejects_oversized_tiles() {
        let p = tiny_params();
        let mut m = CimMacro::ideal(&p).unwrap();
        // 5 outputs × 3 bits = 15 columns > 12.
        let w = vec![vec![1i32; 5]; 10];
        assert!(m.load_weights(&w, 3).is_err());
        assert_eq!(m.max_outputs(3), 4);
        // Too many rows.
        let w = vec![vec![1i32; 2]; 1000];
        assert!(m.load_weights(&w, 3).is_err());
    }

    #[test]
    fn real_macro_close_to_exact_but_noisy() {
        let mut p = tiny_params();
        p.sigma_cmp_lsb = 1.1;
        let mut m = CimMacro::new(&p).unwrap();
        let (w, x) = tile(256, 2, 4, 3);
        m.load_weights(&w, 4).unwrap();
        let want = m.matvec_exact(&w, &x);
        let got = m.matvec(&x, 4, CbMode::Off).unwrap();
        for (g, e) in got.y.iter().zip(&want) {
            let err = (*g - *e).abs() as f64;
            // Error should be small vs the output magnitude scale
            // (~N·2^(a+w)/4) but generally nonzero.
            assert!(err < 2000.0, "err={err} got={g} want={e}");
        }
    }

    #[test]
    fn calibrated_noise_cb_beats_no_cb() {
        let mut p = tiny_params();
        p.sigma_cmp_lsb = 1.1;
        p.sigma_cu_rel = 0.0; // isolate comparator noise
        p.nonlin_cubic_lsb = 0.0;
        let mut m = CimMacro::new(&p).unwrap();
        let (w, x) = tile(256, 2, 2, 9);
        m.load_weights(&w, 2).unwrap();
        let s_off = m.calibrate_output_noise(&w, &x, 2, CbMode::Off, 60).unwrap();
        let s_on = m.calibrate_output_noise(&w, &x, 2, CbMode::On, 60).unwrap();
        assert!(s_on < s_off, "CB should reduce noise: on={s_on} off={s_off}");
    }
}
