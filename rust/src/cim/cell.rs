//! Behavioral model of the 10T CR-CIM bit cell (Fig. 3).
//!
//! The cell is a 6T SRAM (weight storage) plus a 4T compute/reconfigure
//! port driving the bottom plate of the cell's 1.5 fF fringe cap. The
//! bottom plate has exactly three drivers, selected by phase:
//!
//! - `Reset`   — the shared D_DAC/Reset node carries V_reset (the D_DAC
//!               path is *reused* as the reset path: no in-cell reset
//!               switch, which is what keeps the cell at 10T / 2.3 µm²).
//! - `Compute` — the local product IN·W (1b AND) drives the plate.
//! - `Adc`     — the shared node carries the SAR feedback bit for the
//!               cell's binary group.
//!
//! The phase sequencing constraint (Reset → Compute → Adc → Reset) is
//! enforced here so the column model can't silently skip the reset that
//! the shared-node design makes mandatory.

/// Operating phase of a cell / column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Reset,
    Compute,
    Adc,
}

/// Error for illegal phase transitions.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
#[error("illegal phase transition {from:?} -> {to:?}")]
pub struct PhaseError {
    pub from: Phase,
    pub to: Phase,
}

/// The legal cycle: Reset → Compute → Adc → Reset (Reset is also allowed
/// from itself, e.g. on power-up, and Compute may return to Reset if a
/// conversion is aborted).
pub fn check_transition(from: Phase, to: Phase) -> Result<(), PhaseError> {
    use Phase::*;
    let ok = matches!(
        (from, to),
        (Reset, Compute) | (Compute, Adc) | (Adc, Reset) | (Reset, Reset) | (Compute, Reset)
    );
    if ok {
        Ok(())
    } else {
        Err(PhaseError { from, to })
    }
}

/// One 10T cell: stored weight bit + bottom-plate state.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// 6T SRAM content.
    pub weight: bool,
    /// Current bottom-plate logic level.
    pub plate: bool,
    /// Which binary C-DAC group this cell belongs to (bit index 0..bits),
    /// or None for the LSB-terminating dummy / offset cells.
    pub dac_group: Option<u8>,
}

impl Cell {
    pub fn new(dac_group: Option<u8>) -> Self {
        Cell { weight: false, plate: false, dac_group }
    }

    /// Write the weight bit (SRAM write; allowed in any phase — the 6T
    /// port is independent of the compute port).
    pub fn write_weight(&mut self, w: bool) {
        self.weight = w;
    }

    /// The 1b×1b product this cell contributes during compute.
    #[inline]
    pub fn product(&self, input: bool) -> bool {
        input & self.weight
    }

    /// Drive the plate for the given phase.
    ///
    /// - Reset: plate <- false (V_reset) via the shared node.
    /// - Compute: plate <- IN·W.
    /// - Adc: plate <- D_DAC bit of this cell's group (dummy cells stay
    ///   at reset level — they terminate the bank).
    pub fn drive(&mut self, phase: Phase, input: bool, dac_code: u32) {
        self.plate = match phase {
            Phase::Reset => false,
            Phase::Compute => self.product(input),
            Phase::Adc => match self.dac_group {
                Some(b) => dac_code & (1 << b) != 0,
                None => false,
            },
        };
    }
}

/// Phase sequencer shared by a column's cells; single source of truth for
/// the Reset→Compute→Adc cycle.
#[derive(Clone, Copy, Debug)]
pub struct PhaseSequencer {
    pub phase: Phase,
}

impl Default for PhaseSequencer {
    fn default() -> Self {
        PhaseSequencer { phase: Phase::Reset }
    }
}

impl PhaseSequencer {
    pub fn advance(&mut self, to: Phase) -> Result<(), PhaseError> {
        check_transition(self.phase, to)?;
        self.phase = to;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn product_truth_table() {
        let mut c = Cell::new(Some(0));
        for (w, i, expect) in [(false, false, false), (false, true, false), (true, false, false), (true, true, true)] {
            c.write_weight(w);
            assert_eq!(c.product(i), expect, "w={w} in={i}");
        }
    }

    #[test]
    fn compute_drives_product_onto_plate() {
        let mut c = Cell::new(Some(3));
        c.write_weight(true);
        c.drive(Phase::Compute, true, 0);
        assert!(c.plate);
        c.drive(Phase::Compute, false, 0);
        assert!(!c.plate);
    }

    #[test]
    fn adc_phase_follows_group_bit() {
        let mut c = Cell::new(Some(4));
        c.drive(Phase::Adc, true, 1 << 4);
        assert!(c.plate);
        c.drive(Phase::Adc, true, !(1u32 << 4));
        assert!(!c.plate);
        // Dummy cells never follow the DAC.
        let mut d = Cell::new(None);
        d.drive(Phase::Adc, true, u32::MAX);
        assert!(!d.plate);
    }

    #[test]
    fn reset_clears_plate_regardless_of_state() {
        let mut c = Cell::new(Some(0));
        c.write_weight(true);
        c.drive(Phase::Compute, true, 0);
        assert!(c.plate);
        c.drive(Phase::Reset, true, u32::MAX);
        assert!(!c.plate);
        // Weight survives reset (SRAM is independent).
        assert!(c.weight);
    }

    #[test]
    fn sequencer_enforces_cycle() {
        let mut s = PhaseSequencer::default();
        assert_eq!(s.phase, Phase::Reset);
        s.advance(Phase::Compute).unwrap();
        s.advance(Phase::Adc).unwrap();
        s.advance(Phase::Reset).unwrap();
        // Skipping compute is illegal: Reset -> Adc.
        let err = s.advance(Phase::Adc).unwrap_err();
        assert_eq!(err, PhaseError { from: Phase::Reset, to: Phase::Adc });
        // Abort from compute back to reset is allowed.
        s.advance(Phase::Compute).unwrap();
        s.advance(Phase::Reset).unwrap();
    }

    #[test]
    fn adc_without_reset_after_adc_is_illegal() {
        let mut s = PhaseSequencer::default();
        s.advance(Phase::Compute).unwrap();
        s.advance(Phase::Adc).unwrap();
        // The shared D_DAC/reset node means a new conversion cannot start
        // until the bank is reset.
        assert!(s.advance(Phase::Compute).is_err());
        assert!(s.advance(Phase::Adc).is_err());
    }
}
