//! Mismatch-sampled capacitor bank: the heart of CR-CIM.
//!
//! One physical bank of `active_rows` unit capacitors serves two roles:
//!
//! 1. **Compute phase** — every cell's bottom plate is driven by its local
//!    1b product (IN AND W); the floating top plate settles to
//!    `V_FS · Σ cᵢ·dᵢ / ΣC` — a charge-domain MAC with *no* attenuation,
//!    because the charge never leaves the bank.
//! 2. **ADC phase** — the same cells are regrouped into a binary-weighted
//!    C-DAC (bit b drives 2^b cells) for successive approximation.
//!
//! Mismatch is sampled once per instance (per die) from N(1, σ_u²) per
//! unit cap, with substream-stable RNG so every (seed, column) pair gives
//! the same die, independent of evaluation order or thread count.

use crate::util::rng::Rng;

use super::params::MacroParams;

/// A column's capacitor bank with per-unit mismatch.
#[derive(Clone, Debug)]
pub struct CapacitorBank {
    /// Normalized per-cell capacitance (mean 1.0).
    cells: Vec<f64>,
    /// Sum of all normalized cells.
    total: f64,
    /// Per-binary-group capacitance sums: `group[b] = Σ cells in bit b`,
    /// group b has 2^b cells. Cell 0 is the LSB dummy terminating the bank.
    groups: Vec<f64>,
    /// Prefix sums: `prefix[i] = Σ cells[..i]` — makes the transfer-curve
    /// sweep's `mac_level_prefix` O(1) instead of O(cells) (§Perf).
    prefix: Vec<f64>,
    bits: u32,
}

impl CapacitorBank {
    /// Sample a bank for `column` of the die identified by `params.seed`.
    pub fn sample(params: &MacroParams, column: usize) -> Self {
        let n = params.active_rows;
        // σ_u = 0 collapses every draw to exactly 1.0, so skip the 2^bits
        // gauss draws. Bit-identical (the bank owns its substream, so the
        // skipped draws are invisible to every other consumer); makes
        // zero-noise model-graph walks at ViT-Base scale cheap to
        // instantiate.
        if params.sigma_cu_rel == 0.0 {
            return Self::from_cells(vec![1.0; n], params.adc_bits);
        }
        let root = Rng::new(params.seed);
        let mut rng = root.substream(0x00C4_B44C, column as u64);
        let mut cells = Vec::with_capacity(n);
        for _ in 0..n {
            // Truncate at ±6σ: a real cap cannot go negative.
            let c = 1.0 + params.sigma_cu_rel * rng.gauss().clamp(-6.0, 6.0);
            cells.push(c.max(1e-3));
        }
        Self::from_cells(cells, params.adc_bits)
    }

    /// Build from explicit normalized cell values (testing / what-if).
    pub fn from_cells(cells: Vec<f64>, bits: u32) -> Self {
        assert_eq!(cells.len(), 1usize << bits, "bank must have 2^bits cells");
        // detlint: allow(float-reduction) -- sequential sum over the fixed cell order, never parallel
        let total: f64 = cells.iter().sum();
        // Binary grouping: cells[1..2] -> bit0, cells[2..4] -> bit1, ...
        // cells[2^b .. 2^(b+1)] -> bit b. cells[0] is the terminating dummy.
        let mut groups = Vec::with_capacity(bits as usize);
        for b in 0..bits {
            let lo = 1usize << b;
            let hi = 1usize << (b + 1);
            groups.push(cells[lo..hi].iter().sum());
        }
        let mut prefix = Vec::with_capacity(cells.len() + 1);
        let mut acc = 0.0;
        prefix.push(0.0);
        for &c in &cells {
            acc += c;
            prefix.push(acc);
        }
        CapacitorBank { cells, total, groups, prefix, bits }
    }

    /// An ideal (mismatch-free) bank.
    pub fn ideal(bits: u32) -> Self {
        Self::from_cells(vec![1.0; 1usize << bits], bits)
    }

    pub fn num_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn total(&self) -> f64 {
        self.total
    }

    /// Compute-phase MAC: normalized top-plate level in [0,1] for the given
    /// per-cell product bits. `products.len()` must equal the cell count.
    /// This is where CR-CIM differs from conventional CIM — the level is
    /// referenced to the *full* bank, no redistribution loss.
    pub fn mac_level(&self, products: &[bool]) -> f64 {
        debug_assert_eq!(products.len(), self.cells.len());
        let mut q = 0.0;
        for (c, &p) in self.cells.iter().zip(products) {
            if p {
                q += c;
            }
        }
        q / self.total
    }

    /// Compute-phase MAC for an (input, weight) bit pair without
    /// materializing the product vector (§Perf: saves an allocation and a
    /// pass on the macro matvec hot loop).
    pub fn mac_level_and(&self, inputs: &[bool], weights: &[bool]) -> f64 {
        debug_assert_eq!(inputs.len(), self.cells.len());
        debug_assert_eq!(weights.len(), self.cells.len());
        let mut q = 0.0;
        for ((c, &i), &w) in self.cells.iter().zip(inputs).zip(weights) {
            if i & w {
                q += c;
            }
        }
        q / self.total
    }

    /// MAC level when the driven pattern is given as a *count* with a
    /// deterministic fill order (cells 0..count driven). Used by the fast
    /// transfer-curve sweeps where the specific pattern is irrelevant.
    pub fn mac_level_prefix(&self, count: usize) -> f64 {
        debug_assert!(count <= self.cells.len());
        self.prefix[count] / self.total
    }

    /// DAC level (normalized, in [0,1)) produced when the bank is
    /// reconfigured as a binary C-DAC and driven with `code`.
    pub fn dac_level(&self, code: u32) -> f64 {
        debug_assert!(code < (1u32 << self.bits) as u32);
        let mut q = 0.0;
        for b in 0..self.bits {
            if code & (1 << b) != 0 {
                q += self.groups[b as usize];
            }
        }
        q / self.total
    }

    /// The bit-b group weight normalized by total (ideal: 2^b / 2^bits).
    pub fn group_weight(&self, bit: u32) -> f64 {
        self.groups[bit as usize] / self.total
    }

    /// Static INL of the reconfigured C-DAC in LSB: deviation of each code's
    /// level from the endpoint-fit line. This is the mismatch component of
    /// the readout INL (the full transfer INL also includes the residual
    /// cubic nonlinearity, applied in `column.rs`).
    pub fn dac_inl_lsb(&self) -> Vec<f64> {
        let n = 1usize << self.bits;
        let lsb = 1.0 / n as f64;
        let l0 = self.dac_level(0);
        let l_max = self.dac_level((n - 1) as u32);
        let span = l_max - l0;
        (0..n)
            .map(|code| {
                let ideal = l0 + span * code as f64 / (n - 1) as f64;
                (self.dac_level(code as u32) - ideal) / lsb
            })
            .collect()
    }

    /// DNL in LSB for each code transition (length 2^bits - 1).
    pub fn dac_dnl_lsb(&self) -> Vec<f64> {
        let n = 1usize << self.bits;
        let lsb_actual = (self.dac_level((n - 1) as u32) - self.dac_level(0)) / (n - 1) as f64;
        (1..n)
            .map(|code| {
                let step = self.dac_level(code as u32) - self.dac_level(code as u32 - 1);
                step / lsb_actual - 1.0
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_prop;

    fn small_params(sigma: f64) -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.sigma_cu_rel = sigma;
        p
    }

    #[test]
    fn ideal_bank_is_perfectly_linear() {
        let bank = CapacitorBank::ideal(10);
        for code in [0u32, 1, 511, 512, 1023] {
            let lvl = bank.dac_level(code);
            assert!((lvl - code as f64 / 1024.0).abs() < 1e-12, "code {code}");
        }
        let inl = bank.dac_inl_lsb();
        assert!(inl.iter().all(|x| x.abs() < 1e-9));
        let dnl = bank.dac_dnl_lsb();
        assert!(dnl.iter().all(|x| x.abs() < 1e-9));
    }

    #[test]
    fn mac_level_counts_driven_cells() {
        let bank = CapacitorBank::ideal(8);
        let mut products = vec![false; 256];
        for p in products.iter_mut().take(100) {
            *p = true;
        }
        assert!((bank.mac_level(&products) - 100.0 / 256.0).abs() < 1e-12);
        assert!((bank.mac_level_prefix(100) - 100.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn sampling_is_deterministic_per_column() {
        let p = small_params(0.01);
        let a = CapacitorBank::sample(&p, 5);
        let b = CapacitorBank::sample(&p, 5);
        assert_eq!(a.cells, b.cells);
        let c = CapacitorBank::sample(&p, 6);
        assert_ne!(a.cells, c.cells);
    }

    #[test]
    fn zero_sigma_fast_path_equals_ideal_bank() {
        // The σ = 0 shortcut must be bit-identical to the drawn path
        // (every draw would collapse to 1.0 anyway).
        let p = small_params(0.0);
        let sampled = CapacitorBank::sample(&p, 3);
        let ideal = CapacitorBank::ideal(p.adc_bits);
        assert_eq!(sampled.cells, ideal.cells);
        assert_eq!(sampled.total(), ideal.total());
    }

    #[test]
    fn mismatch_inl_grows_with_sigma() {
        let max_inl = |sigma: f64| {
            let p = small_params(sigma);
            let bank = CapacitorBank::sample(&p, 0);
            bank.dac_inl_lsb().iter().fold(0.0f64, |m, x| m.max(x.abs()))
        };
        let small = max_inl(0.001);
        let large = max_inl(0.05);
        assert!(large > small * 3.0, "small={small} large={large}");
    }

    #[test]
    fn midcode_transition_is_worst_dnl_hotspot() {
        // The MSB transition (011..1 -> 100..0) swaps the whole bank; with
        // mismatch it should on average be among the largest DNL entries.
        let p = small_params(0.02);
        let mut worst_at_mid = 0;
        for col in 0..20 {
            let bank = CapacitorBank::sample(&p, col);
            let dnl = bank.dac_dnl_lsb();
            let mid = 1usize << (p.adc_bits - 1);
            let mid_val = dnl[mid - 1].abs();
            let max_val = dnl.iter().fold(0.0f64, |m, x| m.max(x.abs()));
            if (mid_val - max_val).abs() < 1e-12 {
                worst_at_mid += 1;
            }
        }
        assert!(worst_at_mid >= 10, "mid-code worst in {worst_at_mid}/20 dies");
    }

    #[test]
    fn prop_dac_levels_monotone_enough_and_bounded() {
        assert_prop("dac-level-bounds", 64, |g| {
            let bits = g.usize(4, 8) as u32;
            let sigma = g.f64(0.0, 0.03);
            let mut p = MacroParams::default();
            p.adc_bits = bits;
            p.active_rows = 1 << bits;
            p.rows = p.active_rows;
            p.sigma_cu_rel = sigma;
            let bank = CapacitorBank::sample(&p, g.usize(0, 30));
            let n = 1usize << bits;
            for code in 0..n {
                let lvl = bank.dac_level(code as u32);
                if !(0.0..=1.0).contains(&lvl) {
                    return Err(format!("level {lvl} out of [0,1] at code {code}"));
                }
            }
            // Endpoint-referenced INL must vanish at the endpoints.
            let inl = bank.dac_inl_lsb();
            if inl[0].abs() > 1e-9 || inl[n - 1].abs() > 1e-9 {
                return Err("endpoint INL nonzero".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mac_plus_complement_sums_to_one() {
        assert_prop("mac-complement", 48, |g| {
            let bits = 6u32;
            let mut p = MacroParams::default();
            p.adc_bits = bits;
            p.active_rows = 1 << bits;
            p.rows = p.active_rows;
            p.sigma_cu_rel = g.f64(0.0, 0.05);
            let bank = CapacitorBank::sample(&p, 0);
            let n = 1usize << bits;
            let pattern: Vec<bool> = (0..n).map(|_| g.bool()).collect();
            let complement: Vec<bool> = pattern.iter().map(|&b| !b).collect();
            let sum = bank.mac_level(&pattern) + bank.mac_level(&complement);
            if (sum - 1.0).abs() > 1e-9 {
                return Err(format!("levels sum to {sum}, not 1"));
            }
            Ok(())
        });
    }
}
