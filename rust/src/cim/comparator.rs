//! Dynamic comparator model: noise, offset, majority voting, and the
//! noise-limited energy law that drives the paper's 4× comparator-energy
//! claim.
//!
//! A StrongARM-style dynamic comparator's input-referred noise is set by
//! the sampling capacitance of its input pair: σ² ∝ kT/C_eff, while its
//! energy is ∝ C_eff·V². Halving σ therefore costs 4× energy — which is
//! exactly why CR-CIM's 2× larger signal swing (no charge-redistribution
//! attenuation) buys a 4× comparator energy saving at equal accuracy.

use crate::util::rng::Rng;

/// Comparator instance with per-column offset and per-decision noise.
#[derive(Clone, Debug)]
pub struct Comparator {
    /// Input-referred noise 1σ, in readout LSB.
    pub sigma_lsb: f64,
    /// Static offset in LSB (sampled once per column; auto-zero residual).
    pub offset_lsb: f64,
}

impl Comparator {
    pub fn new(sigma_lsb: f64, offset_lsb: f64) -> Self {
        Comparator { sigma_lsb, offset_lsb }
    }

    /// Sample a column's comparator from process statistics.
    pub fn sample(sigma_lsb: f64, sigma_offset_lsb: f64, rng: &mut Rng) -> Self {
        Comparator { sigma_lsb, offset_lsb: sigma_offset_lsb * rng.gauss() }
    }

    /// One decision: returns true iff (vp - vn + offset + noise) ≥ 0,
    /// with all quantities in LSB. The ≥ makes the zero-noise limit
    /// deterministic at exact code boundaries (truncating converter).
    #[inline]
    pub fn decide(&self, delta_lsb: f64, rng: &mut Rng) -> bool {
        self.decide_scaled(delta_lsb, 1.0, rng)
    }

    /// Decision with a noise-scaling factor. Asynchronous SARs give early
    /// (MSB) comparisons long regeneration times and large differential
    /// inputs, so their effective input-referred noise is a fraction of
    /// the timing-critical LSB decisions'; the SAR model passes that
    /// fraction here for the unvoted upper bits.
    #[inline]
    pub fn decide_scaled(&self, delta_lsb: f64, sigma_scale: f64, rng: &mut Rng) -> bool {
        let z = delta_lsb + self.offset_lsb;
        let sigma = sigma_scale * self.sigma_lsb;
        // §Perf: beyond 8σ the flip probability is < 1e-15 — below any
        // Monte-Carlo resolution this simulator runs at — so skip the
        // Gaussian draw. Most early SAR decisions land here (the residual
        // is many LSB from the threshold), cutting draws ~3× per
        // conversion. Also makes the σ=0 limit exactly deterministic.
        if z.abs() > 8.0 * sigma {
            return z >= 0.0;
        }
        z + sigma * rng.gauss() >= 0.0
    }

    /// Majority-voted decision: `votes` independent decisions, majority
    /// wins (ties broken toward `true`, matching a latch that keeps its
    /// last state — the choice is irrelevant at the paper's 6 votes since
    /// ties are rare and unbiased).
    #[inline]
    pub fn decide_mv(&self, delta_lsb: f64, votes: usize, rng: &mut Rng) -> bool {
        debug_assert!(votes >= 1);
        let mut ups = 0usize;
        for _ in 0..votes {
            if self.decide(delta_lsb, rng) {
                ups += 1;
            }
        }
        2 * ups >= votes
    }

    /// Probability that a single decision returns `true` at input
    /// `delta_lsb` (analytic; used by tests and the order-statistics
    /// analysis of majority voting).
    pub fn p_up(&self, delta_lsb: f64) -> f64 {
        phi((delta_lsb + self.offset_lsb) / self.sigma_lsb)
    }

    /// Effective input-referred noise of a `votes`-way majority vote,
    /// defined as the σ of the equivalent single comparator that has the
    /// same decision-threshold slope at 50%:
    /// majority-of-n sharpens the decision curve; for n=6 the equivalent
    /// σ is ≈ 0.48·σ (computed numerically).
    pub fn effective_sigma_mv(&self, votes: usize) -> f64 {
        if votes <= 1 {
            return self.sigma_lsb;
        }
        // Slope of P(majority up) vs delta at delta = -offset (P=1/2).
        // P_maj(p) = Σ_{k>=ceil(n/2)} C(n,k) p^k (1-p)^(n-k), with tie->up:
        // for even n the threshold is k >= n/2.
        let n = votes;
        let thresh = n.div_ceil(2);
        let dp = 1e-5;
        let p_maj = |p: f64| -> f64 {
            let mut sum = 0.0;
            for k in thresh..=n {
                sum += binom(n, k) * p.powi(k as i32) * (1.0 - p).powi((n - k) as i32);
            }
            // Even n: add half-weight for exact tie when threshold = n/2
            // is already included above (tie -> up), so nothing extra.
            sum
        };
        // Chain rule: dP_maj/dΔ = (dP_maj/dp)·(dp/dΔ); the equivalent
        // single comparator has slope 1/(σ_eq·√2π) at threshold, so
        // σ_eq = σ / (dP_maj/dp at p = 1/2).
        let dmaj_dp = (p_maj(0.5 + dp) - p_maj(0.5 - dp)) / (2.0 * dp);
        self.sigma_lsb / dmaj_dp
    }
}

/// Standard normal CDF via erf approximation (Abramowitz & Stegun 7.1.26,
/// |err| < 1.5e-7 — plenty for circuit modeling).
pub fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

fn binom(n: usize, k: usize) -> f64 {
    let k = k.min(n - k);
    let mut r = 1.0;
    for i in 0..k {
        r = r * (n - i) as f64 / (i + 1) as f64;
    }
    r
}

/// Noise-limited comparator energy law: energy per comparison to achieve
/// input-referred noise `sigma_v` (volts) at supply `v`:
/// E = kT·γ_eff·(V/σ_v)²·margin. Returned in picojoules given a reference
/// calibration point (e_ref_pj at sigma_ref_v, v_ref).
pub fn comparator_energy_pj(
    e_ref_pj: f64,
    sigma_ref_v: f64,
    v_ref: f64,
    sigma_v: f64,
    v: f64,
) -> f64 {
    e_ref_pj * (sigma_ref_v / sigma_v).powi(2) * (v / v_ref).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::Moments;

    #[test]
    fn decide_is_deterministic_with_zero_noise() {
        let c = Comparator::new(1e-30, 0.0);
        let mut rng = Rng::new(1);
        assert!(c.decide(0.5, &mut rng));
        assert!(!c.decide(-0.5, &mut rng));
    }

    #[test]
    fn decision_probability_matches_phi() {
        let c = Comparator::new(1.0, 0.0);
        let mut rng = Rng::new(2);
        for &delta in &[-1.5, -0.5, 0.0, 0.5, 1.5] {
            let n = 60_000;
            let ups = (0..n).filter(|_| c.decide(delta, &mut rng)).count();
            let p_emp = ups as f64 / n as f64;
            let p_ana = c.p_up(delta);
            assert!(
                (p_emp - p_ana).abs() < 0.01,
                "delta={delta}: emp={p_emp} ana={p_ana}"
            );
        }
    }

    #[test]
    fn offset_shifts_threshold() {
        let c = Comparator::new(0.5, 1.0);
        // At delta = -1 the offset cancels: P(up) = 0.5.
        assert!((c.p_up(-1.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn majority_voting_sharpens_decisions() {
        let c = Comparator::new(1.0, 0.0);
        let mut rng = Rng::new(3);
        let delta = 0.6;
        let n = 40_000;
        let single_err = (0..n).filter(|_| !c.decide(delta, &mut rng)).count() as f64 / n as f64;
        let mv_err =
            (0..n).filter(|_| !c.decide_mv(delta, 6, &mut rng)).count() as f64 / n as f64;
        assert!(
            mv_err < single_err * 0.5,
            "single={single_err} mv={mv_err}"
        );
    }

    #[test]
    fn effective_sigma_mv6_is_about_half() {
        let c = Comparator::new(1.0, 0.0);
        let eff = c.effective_sigma_mv(6);
        // Numerically the 6-vote majority slope gain is ~2.07 ⇒ σ_eff ≈ 0.48.
        assert!(eff > 0.40 && eff < 0.56, "eff={eff}");
        assert_eq!(c.effective_sigma_mv(1), 1.0);
    }

    #[test]
    fn mv_empirical_noise_reduction_matches_effective_sigma() {
        // The tie→up rule biases the majority curve (P(0) ≈ 0.66 for n=6),
        // so test the *slope*, which is what σ_eff encodes: the symmetric
        // difference P(δ)−P(−δ) ≈ 2·φ(0)·δ/σ_eff for small δ.
        let c = Comparator::new(1.0, 0.0);
        let mut rng = Rng::new(7);
        let eff = c.effective_sigma_mv(6);
        let delta = 0.2;
        let n = 200_000;
        let p_pos =
            (0..n).filter(|_| c.decide_mv(delta, 6, &mut rng)).count() as f64 / n as f64;
        let p_neg =
            (0..n).filter(|_| c.decide_mv(-delta, 6, &mut rng)).count() as f64 / n as f64;
        let slope_emp = (p_pos - p_neg) / (2.0 * delta);
        let slope_pred = 1.0 / (eff * (2.0 * std::f64::consts::PI).sqrt());
        assert!(
            (slope_emp - slope_pred).abs() / slope_pred < 0.10,
            "slope emp={slope_emp} pred={slope_pred} (eff={eff})"
        );
    }

    #[test]
    fn mv_decision_curves_match_effective_sigma_across_vote_grid() {
        // The planner prices per-layer voting through effective_sigma_mv:
        // anchor the analytic slope model to the sampled decide_mv
        // behavior over the whole vote grid the sweep harness uses,
        // including the even count (tie -> up) and the no-vote identity.
        let c = Comparator::new(1.0, 0.0);
        let delta = 0.2;
        let n = 200_000;
        for (vi, &votes) in [1usize, 2, 6, 12].iter().enumerate() {
            let mut rng = Rng::new(0xC0DE + vi as u64);
            let eff = c.effective_sigma_mv(votes);
            assert!(eff > 0.0 && eff <= c.sigma_lsb + 1e-12, "votes={votes}: eff={eff}");
            let p_pos =
                (0..n).filter(|_| c.decide_mv(delta, votes, &mut rng)).count() as f64
                    / n as f64;
            let p_neg =
                (0..n).filter(|_| c.decide_mv(-delta, votes, &mut rng)).count() as f64
                    / n as f64;
            // Symmetric difference cancels the tie->up bias; the slope is
            // what sigma_eff encodes (see the test above for n = 6).
            let slope_emp = (p_pos - p_neg) / (2.0 * delta);
            let slope_pred = 1.0 / (eff * (2.0 * std::f64::consts::PI).sqrt());
            assert!(
                (slope_emp - slope_pred).abs() / slope_pred < 0.10,
                "votes={votes}: slope emp={slope_emp} pred={slope_pred} (eff={eff})"
            );
        }
        // More votes never hurt: the equivalent sigma is non-increasing
        // over the grid (the planner's monotone pricing assumption).
        let effs: Vec<f64> =
            [1usize, 2, 6, 12].iter().map(|&v| c.effective_sigma_mv(v)).collect();
        for w in effs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "effective sigma must not grow with votes: {effs:?}");
        }
    }

    #[test]
    fn energy_law_quarters_when_sigma_doubles() {
        let relaxed = comparator_energy_pj(1.0, 1.0, 1.0, 2.0, 1.0);
        assert!((relaxed - 0.25).abs() < 1e-12);
        // And scales with V².
        let hv = comparator_energy_pj(1.0, 1.0, 1.0, 1.0, 2.0);
        assert!((hv - 4.0).abs() < 1e-12);
    }

    #[test]
    fn erf_and_phi_sane() {
        // A&S 7.1.26 has |err| < 1.5e-7.
        assert!((erf(0.0)).abs() < 2e-7);
        assert!((erf(3.0) - 0.99997791).abs() < 1e-5);
        assert!((phi(0.0) - 0.5).abs() < 2e-7);
        assert!((phi(1.0) - 0.8413).abs() < 1e-3);
        assert!((phi(-1.0) - 0.1587).abs() < 1e-3);
    }

    #[test]
    fn sampled_offsets_have_requested_spread() {
        let mut rng = Rng::new(9);
        let mut m = Moments::new();
        for _ in 0..5000 {
            let c = Comparator::sample(1.0, 0.5, &mut rng);
            m.push(c.offset_lsb);
        }
        assert!(m.mean().abs() < 0.05);
        assert!((m.std() - 0.5).abs() < 0.05);
    }
}
