//! Multi-die Monte-Carlo: yield and environmental analysis.
//!
//! A fab lot is a set of dies = a set of mismatch seeds. This module
//! sweeps dies (and operating temperature) through the Fig. 5
//! characterization to answer the questions a chip paper's shmoo plots
//! answer: what fraction of dies meets the INL/SQNR/CSNR spec, and how
//! the accuracy metrics move with temperature and supply.

use crate::metrics::csnr::{measure_csnr, CsnrEnsemble};
use crate::metrics::sqnr::sqnr_db;
use crate::metrics::transfer::{characterize, CharacterizeOpts};
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;
use crate::util::stats::Moments;

use super::column::Column;
use super::macro_::CimMacro;
use super::params::{CbMode, MacroParams};

/// Per-die measurement summary.
#[derive(Clone, Copy, Debug)]
pub struct DieResult {
    pub seed: u64,
    pub max_inl_lsb: f64,
    pub mean_noise_lsb: f64,
    pub sqnr_db: f64,
    pub csnr_db: f64,
}

/// Acceptance spec (the paper's published numbers as limits).
#[derive(Clone, Copy, Debug)]
pub struct YieldSpec {
    pub max_inl_lsb: f64,
    pub min_sqnr_db: f64,
    pub min_csnr_db: f64,
}

impl Default for YieldSpec {
    fn default() -> Self {
        // Modest guard-bands below the headline numbers.
        YieldSpec { max_inl_lsb: 3.0, min_sqnr_db: 43.0, min_csnr_db: 29.0 }
    }
}

impl YieldSpec {
    pub fn passes(&self, die: &DieResult) -> bool {
        die.max_inl_lsb <= self.max_inl_lsb
            && die.sqnr_db >= self.min_sqnr_db
            && die.csnr_db >= self.min_csnr_db
    }
}

/// Characterize `dies` independent mismatch samples of column 0.
pub fn sweep_dies(
    base: &MacroParams,
    mode: CbMode,
    dies: usize,
    opts: &CharacterizeOpts,
    threads: usize,
) -> Vec<DieResult> {
    parallel_map(dies, threads, |i| {
        let params = base.clone().with_seed(base.seed.wrapping_add(1 + i as u64 * 7919));
        let col = Column::new(&params, 0).expect("valid params");
        // Inner sweeps single-threaded; parallelism is across dies.
        let inner = CharacterizeOpts { threads: 1, ..*opts };
        let curve = characterize(&col, mode, &inner);
        let ens = CsnrEnsemble { vectors: 48, reads_per_vector: 10, ..Default::default() };
        let csnr = measure_csnr(&col, mode, &ens, 1);
        DieResult {
            seed: params.seed,
            max_inl_lsb: curve.max_abs_inl(),
            mean_noise_lsb: curve.mean_noise_lsb(),
            sqnr_db: sqnr_db(&curve),
            csnr_db: csnr.csnr_db,
        }
    })
}

/// Macro-level output-noise Monte-Carlo: for `dies` mismatch seeds, load
/// a shared multi-bit tile and measure output-referred noise through the
/// column-parallel matvec engine. Parallelism is across dies (the inner
/// engine runs single-threaded per die so the two pools don't multiply),
/// and results are deterministic at any `threads` because every die gets
/// its own seed and every column its own substream.
pub fn sweep_macro_noise(
    base: &MacroParams,
    mode: CbMode,
    dies: usize,
    a_bits: u32,
    w_bits: u32,
    trials: usize,
    threads: usize,
) -> Result<Vec<f64>, String> {
    if a_bits == 0 || a_bits > 31 {
        return Err(format!("a_bits {a_bits} out of range 1..=31"));
    }
    if w_bits == 0 || w_bits > 31 {
        return Err(format!("w_bits {w_bits} out of range 1..=31"));
    }
    let n_out = base.cols / w_bits as usize;
    if n_out == 0 {
        return Err(format!("w_bits {w_bits} exceeds macro columns {}", base.cols));
    }
    let rows = base.active_rows;
    let mut trng = Rng::salted(base.seed, 0x711E_5EED);
    let lo = -(1i32 << (w_bits - 1));
    let hi = (1i32 << (w_bits - 1)) - 1;
    let span = (hi - lo + 1) as u64;
    let w: Vec<Vec<i32>> = (0..rows)
        .map(|_| (0..n_out).map(|_| lo + trng.below(span) as i32).collect())
        .collect();
    let a_lo = -(1i32 << (a_bits - 1));
    let a_span = (1u64 << a_bits).max(1);
    let x: Vec<i32> = (0..rows).map(|_| a_lo + trng.below(a_span) as i32).collect();
    let results = parallel_map(dies, threads, |i| {
        let params = base
            .clone()
            .with_seed(base.seed.wrapping_add(1 + i as u64 * 7919))
            .with_threads(1);
        let mut mac = CimMacro::new(&params)?;
        mac.load_weights(&w, w_bits)?;
        mac.calibrate_output_noise(&w, &x, a_bits, mode, trials)
    });
    results.into_iter().collect()
}

/// Lot summary: yield plus metric distributions.
#[derive(Clone, Debug)]
pub struct LotSummary {
    pub dies: usize,
    pub yield_fraction: f64,
    pub sqnr: Moments,
    pub csnr: Moments,
    pub inl: Moments,
}

pub fn summarize(results: &[DieResult], spec: &YieldSpec) -> LotSummary {
    let mut sqnr = Moments::new();
    let mut csnr = Moments::new();
    let mut inl = Moments::new();
    let mut pass = 0usize;
    for r in results {
        sqnr.push(r.sqnr_db);
        csnr.push(r.csnr_db);
        inl.push(r.max_inl_lsb);
        if spec.passes(r) {
            pass += 1;
        }
    }
    LotSummary {
        dies: results.len(),
        yield_fraction: pass as f64 / results.len().max(1) as f64,
        sqnr,
        csnr,
        inl,
    }
}

/// Temperature sweep of one die's accuracy metrics (kT/C and comparator
/// noise scale as √T around the 300 K calibration point).
pub fn temperature_sweep(
    base: &MacroParams,
    mode: CbMode,
    temps_k: &[f64],
    opts: &CharacterizeOpts,
) -> Vec<(f64, f64, f64)> {
    temps_k
        .iter()
        .map(|&t| {
            let mut p = base.clone();
            p.temperature_k = t;
            // Comparator thermal noise power ∝ T.
            p.sigma_cmp_lsb = base.sigma_cmp_lsb * (t / base.temperature_k).sqrt();
            let col = Column::new(&p, 0).expect("valid params");
            let curve = characterize(&col, mode, opts);
            (t, curve.mean_noise_lsb(), sqnr_db(&curve))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> CharacterizeOpts {
        CharacterizeOpts { step: 32, trials: 16, threads: 1, stream: 5 }
    }

    #[test]
    fn lot_yield_is_high_at_default_corner() {
        let results = sweep_dies(&MacroParams::default(), CbMode::On, 8, &quick_opts(), 8);
        let lot = summarize(&results, &YieldSpec::default());
        assert_eq!(lot.dies, 8);
        assert!(lot.yield_fraction >= 0.75, "yield {}", lot.yield_fraction);
        // Die-to-die variation exists but is bounded.
        assert!(lot.sqnr.std() < 3.0);
    }

    #[test]
    fn dies_actually_differ() {
        // Max-INL can tie across dies (the deterministic cubic dominates
        // and static codes are integers), so discriminate on the
        // noise/SQNR measurements, which carry the per-die streams.
        let results = sweep_dies(&MacroParams::default(), CbMode::On, 4, &quick_opts(), 4);
        let sqnrs: Vec<f64> = results.iter().map(|r| r.sqnr_db).collect();
        assert!(sqnrs.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9), "{sqnrs:?}");
        let seeds: Vec<u64> = results.iter().map(|r| r.seed).collect();
        assert!(seeds.windows(2).all(|w| w[0] != w[1]));
    }

    #[test]
    fn tight_spec_fails_loose_spec_passes() {
        let results = sweep_dies(&MacroParams::default(), CbMode::On, 6, &quick_opts(), 6);
        let tight = YieldSpec { max_inl_lsb: 0.1, min_sqnr_db: 60.0, min_csnr_db: 40.0 };
        let loose = YieldSpec { max_inl_lsb: 10.0, min_sqnr_db: 0.0, min_csnr_db: 0.0 };
        assert_eq!(summarize(&results, &tight).yield_fraction, 0.0);
        assert_eq!(summarize(&results, &loose).yield_fraction, 1.0);
    }

    #[test]
    fn macro_noise_sweep_runs_and_is_deterministic() {
        let mut p = MacroParams::default();
        p.adc_bits = 6;
        p.active_rows = 64;
        p.rows = 64;
        p.cols = 8;
        let a = sweep_macro_noise(&p, CbMode::Off, 3, 2, 2, 4, 1).unwrap();
        let b = sweep_macro_noise(&p, CbMode::Off, 3, 2, 2, 4, 4).unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a, b, "die sweep must not depend on thread count");
        assert!(a.iter().all(|s| s.is_finite() && *s >= 0.0), "{a:?}");
        // Bad geometry is rejected, not panicked on.
        assert!(sweep_macro_noise(&p, CbMode::Off, 1, 2, 9, 2, 1).is_err());
        assert!(sweep_macro_noise(&p, CbMode::Off, 1, 0, 2, 2, 1).is_err());
        assert!(sweep_macro_noise(&p, CbMode::Off, 1, 2, 0, 2, 1).is_err());
        assert!(sweep_macro_noise(&p, CbMode::Off, 1, 40, 2, 2, 1).is_err());
    }

    #[test]
    fn hotter_is_noisier() {
        let pts = temperature_sweep(
            &MacroParams::default(),
            CbMode::On,
            &[250.0, 300.0, 400.0],
            &quick_opts(),
        );
        assert_eq!(pts.len(), 3);
        assert!(pts[2].1 > pts[0].1, "noise at 400K {} vs 250K {}", pts[2].1, pts[0].1);
        assert!(pts[2].2 < pts[0].2 + 0.5, "SQNR should not improve when hot");
    }
}
