//! Per-column digital calibration — the "software" half of the co-design
//! that absorbs *static* transfer error (offset, gain, INL), leaving only
//! dynamic read noise on the error budget. This is why CSNR (which the
//! SAC policy consumes) excludes static INL: a real deployment measures
//! each die once at bring-up and corrects codes digitally, exactly as
//! this module does.
//!
//! Pipeline: `CalibrationTable::measure` sweeps the static transfer curve
//! (foreground calibration, no noise averaging needed beyond `trials`),
//! builds an inverse lookup, and `correct()` maps raw codes to corrected
//! counts. Gain/offset are endpoint-fit; the residual is a per-code LUT.

use crate::util::pool::parallel_map;
use crate::util::rng::Rng;

use super::column::Column;
use super::params::CbMode;

/// A measured per-column correction table.
#[derive(Clone, Debug)]
pub struct CalibrationTable {
    /// corrected_count[code] — inverse transfer lookup.
    inverse: Vec<u16>,
    /// Endpoint-fit gain (codes per count).
    pub gain: f64,
    /// Endpoint-fit offset (codes at count 0).
    pub offset: f64,
}

impl CalibrationTable {
    /// Foreground-calibrate a column: drive every count, average a few
    /// reads, build the inverse map. `trials` ≥ 8 suppresses read noise
    /// enough for the static curve to dominate.
    pub fn measure(column: &Column, mode: CbMode, trials: usize, threads: usize) -> Self {
        let levels = column.params.levels();
        let root = Rng::salted(column.params.seed, 0xCA11_B4A7);
        // Mean measured code for each driven count.
        let mean_code = parallel_map(levels, threads, |count| {
            let mut rng = root.substream(3, count as u64);
            let mut sum = 0.0;
            for _ in 0..trials {
                sum += column.read_count(count, mode, &mut rng).code as f64;
            }
            sum / trials as f64
        });
        let offset = mean_code[0];
        let gain = (mean_code[levels - 1] - mean_code[0]) / (levels - 1) as f64;
        // Inverse: for each possible raw code, the count whose mean code
        // is nearest. mean_code is monotone (up to noise), so a merge
        // scan suffices.
        let mut inverse = vec![0u16; levels];
        let mut count = 0usize;
        for (code, inv) in inverse.iter_mut().enumerate() {
            while count + 1 < levels && mean_code[count + 1] <= code as f64 {
                count += 1;
            }
            // Pick the nearer of count / count+1.
            let best = if count + 1 < levels
                && (mean_code[count + 1] - code as f64).abs()
                    < (mean_code[count] - code as f64).abs()
            {
                count + 1
            } else {
                count
            };
            *inv = best as u16;
        }
        CalibrationTable { inverse, gain, offset }
    }

    /// Correct one raw code to a calibrated count.
    #[inline]
    pub fn correct(&self, code: u32) -> u32 {
        self.inverse[(code as usize).min(self.inverse.len() - 1)] as u32
    }

    /// Residual static error after correction, over the full sweep [LSB].
    pub fn residual_inl(&self, column: &Column) -> Vec<f64> {
        (0..column.params.levels())
            .map(|count| {
                let raw = column.static_code(count);
                self.correct(raw) as f64 - count as f64
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::params::MacroParams;
    use crate::util::stats::rms;

    fn col() -> Column {
        Column::new(&MacroParams::default(), 0).unwrap()
    }

    #[test]
    fn calibration_reduces_static_error() {
        let column = col();
        let table = CalibrationTable::measure(&column, CbMode::On, 16, 4);
        // Raw static error (the 2-LSB INL)...
        let raw_err: Vec<f64> = (0..1024)
            .map(|c| column.static_code(c) as f64 - c as f64)
            .collect();
        // ...vs corrected.
        let res = table.residual_inl(&column);
        assert!(
            rms(&res) < rms(&raw_err) * 0.6,
            "calibration must cut static error: raw rms {} -> {}",
            rms(&raw_err),
            rms(&res)
        );
        assert!(rms(&res) < 0.8, "residual {} LSB", rms(&res));
    }

    #[test]
    fn ideal_column_calibration_is_identity() {
        let column = Column::ideal(&MacroParams::default()).unwrap();
        let table = CalibrationTable::measure(&column, CbMode::Off, 4, 2);
        for code in [0u32, 1, 100, 512, 1023] {
            assert_eq!(table.correct(code), code);
        }
        assert!((table.gain - 1.0).abs() < 1e-9);
        assert!(table.offset.abs() < 1e-9);
    }

    #[test]
    fn correct_clamps_out_of_range() {
        let column = col();
        let table = CalibrationTable::measure(&column, CbMode::On, 8, 2);
        // No panic at the top code.
        let _ = table.correct(1023);
        let _ = table.correct(4096);
    }

    #[test]
    fn gain_and_offset_are_near_nominal() {
        let column = col();
        let table = CalibrationTable::measure(&column, CbMode::On, 8, 4);
        assert!((table.gain - 1.0).abs() < 0.05, "gain {}", table.gain);
        assert!(table.offset.abs() < 4.0, "offset {}", table.offset);
    }
}
