//! Successive-approximation logic over the reconfigured capacitor bank.
//!
//! After the compute phase the top plate holds the MAC level; the SAR
//! controller then drives D_DAC[b] for b = MSB..LSB through the *same*
//! capacitor bank (reconfiguration) and asks the comparator whether the
//! residual is positive. In CB mode the last `mv_last_bits` decisions are
//! each repeated `mv_votes` times and majority-voted.
//!
//! The D_DAC/reset sharing of the 10T cell (Fig. 3) is modeled by
//! `reset()` driving the same node: behaviorally, a conversion always
//! starts from a cleanly reset bank, and the shared node imposes *no*
//! extra cell switches — which is why the cell stays at 10T. The cost
//! shows up only in the energy model (shared driver), not in the transfer
//! function.

use crate::util::rng::Rng;

use super::capacitor::CapacitorBank;
use super::comparator::Comparator;
use super::params::{CbMode, MacroParams};

/// Result of one A/D conversion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conversion {
    /// Output code in [0, 2^bits).
    pub code: u32,
    /// Comparator decisions actually performed (energy/latency driver).
    pub comparisons: u32,
}

/// SAR controller bound to a column's bank and comparator.
pub struct SarAdc<'a> {
    pub bank: &'a CapacitorBank,
    pub cmp: &'a Comparator,
    pub bits: u32,
    pub mv_votes: usize,
    pub mv_last_bits: usize,
    /// Noise scale of the early (MSB-side) comparisons (see
    /// `MacroParams::sigma_cmp_early_factor`).
    pub early_factor: f64,
}

impl<'a> SarAdc<'a> {
    pub fn new(params: &MacroParams, bank: &'a CapacitorBank, cmp: &'a Comparator) -> Self {
        SarAdc {
            bank,
            cmp,
            bits: params.adc_bits,
            mv_votes: params.mv_votes,
            mv_last_bits: params.mv_last_bits,
            early_factor: params.sigma_cmp_early_factor,
        }
    }

    /// Convert a sampled (normalized, [0,1]) top-plate level to a code.
    ///
    /// `level` already contains the signal plus any sampled noise (kT/C);
    /// comparator noise is drawn fresh inside each decision. The
    /// comparator sees the residual in *LSB* units — CR-CIM's key property
    /// is that one LSB here is the full V_FS/2^bits, with no attenuation.
    pub fn convert(&self, level: f64, mode: CbMode, rng: &mut Rng) -> Conversion {
        let n_levels = 1u32 << self.bits;
        let lsb = 1.0 / n_levels as f64;
        let mut code: u32 = 0;
        let mut comparisons = 0u32;
        // Incremental DAC level (§Perf): ℓ(code | bit) = ℓ(code) + w_bit,
        // so each SAR step is O(1) instead of re-summing all set bits.
        let mut level_code = self.bank.dac_level(0);
        for step in 0..self.bits {
            let bit = self.bits - 1 - step; // MSB first
            let trial_level = level_code + self.bank.group_weight(bit);
            // Residual at the comparator input, in LSB, including the
            // converter's half-LSB offset (standard SAR practice): code k
            // covers [k−½, k+½) LSB, so a MAC count of k lands mid-bin —
            // maximally far from both transitions. Without this offset a
            // count would sit exactly on a code transition, where the
            // final comparison is a coin flip that no amount of majority
            // voting can fix.
            let delta_lsb = (level - trial_level) / lsb + 0.5;
            let late = (bit as usize) < self.mv_last_bits;
            let boosted = mode == CbMode::On && late;
            let up = if boosted {
                comparisons += self.mv_votes as u32;
                self.cmp.decide_mv(delta_lsb, self.mv_votes, rng)
            } else {
                comparisons += 1;
                let scale = if late { 1.0 } else { self.early_factor };
                self.cmp.decide_scaled(delta_lsb, scale, rng)
            };
            if up {
                code |= 1 << bit;
                level_code = trial_level;
            }
        }
        Conversion { code, comparisons }
    }

    /// Noise-free, comparator-ideal conversion (quantization + bank
    /// mismatch only). Used to separate static nonlinearity from noise in
    /// the characterization benches.
    pub fn convert_ideal_comparator(&self, level: f64) -> u32 {
        let lsb = 1.0 / (1u64 << self.bits) as f64;
        let mut code: u32 = 0;
        for step in 0..self.bits {
            let bit = self.bits - 1 - step;
            let trial = code | (1 << bit);
            if level - self.bank.dac_level(trial) + 0.5 * lsb >= 0.0 {
                code = trial;
            }
        }
        code
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_prop;
    use crate::util::stats::Moments;

    fn ideal_setup(bits: u32) -> (CapacitorBank, Comparator) {
        (CapacitorBank::ideal(bits), Comparator::new(0.0, 0.0))
    }

    fn params_for(bits: u32) -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = bits;
        p.active_rows = 1 << bits;
        p.rows = p.active_rows;
        p
    }

    #[test]
    fn ideal_conversion_recovers_exact_codes() {
        let p = params_for(10);
        let (bank, cmp) = ideal_setup(10);
        let adc = SarAdc::new(&p, &bank, &cmp);
        let mut rng = Rng::new(1);
        for &code in &[0u32, 1, 2, 3, 511, 512, 513, 1000, 1023] {
            // A level exactly at code/1024 quantizes to code (truncating
            // converter, ≥ comparator semantics).
            let level = code as f64 / 1024.0;
            let conv = adc.convert(level, CbMode::Off, &mut rng);
            assert_eq!(conv.code, code, "level for code {code}");
            assert_eq!(conv.comparisons, 10);
        }
    }

    #[test]
    fn cb_mode_counts_25_comparisons_at_10_bits() {
        let p = params_for(10);
        let (bank, cmp) = ideal_setup(10);
        let adc = SarAdc::new(&p, &bank, &cmp);
        let mut rng = Rng::new(2);
        let conv = adc.convert(0.5, CbMode::On, &mut rng);
        assert_eq!(conv.comparisons, 7 + 3 * 6);
    }

    #[test]
    fn converter_rounds_to_nearest_code() {
        let p = params_for(8);
        let (bank, cmp) = ideal_setup(8);
        let adc = SarAdc::new(&p, &bank, &cmp);
        let mut rng = Rng::new(3);
        // Code k covers [k−½, k+½) LSB: anywhere inside the bin reads k.
        let lsb = 1.0 / 256.0;
        for frac in [-0.49, -0.25, 0.0, 0.25, 0.49] {
            let code = adc.convert((100.0 + frac) * lsb, CbMode::Off, &mut rng).code;
            assert_eq!(code, 100, "frac={frac}");
        }
        assert_eq!(adc.convert(100.51 * lsb, CbMode::Off, &mut rng).code, 101);
        assert_eq!(adc.convert(99.49 * lsb, CbMode::Off, &mut rng).code, 99);
    }

    #[test]
    fn noise_spreads_codes_and_cb_tightens_them() {
        let p = params_for(10);
        let bank = CapacitorBank::ideal(10);
        let cmp = Comparator::new(1.1, 0.0);
        let adc = SarAdc::new(&p, &bank, &cmp);
        let mut rng = Rng::new(4);
        let level = 0.5 + 0.3 / 1024.0; // mid-scale, off-center
        let spread = |mode: CbMode, rng: &mut Rng| {
            let mut m = Moments::new();
            for _ in 0..3000 {
                m.push(adc.convert(level, mode, rng).code as f64);
            }
            m.std()
        };
        let s_off = spread(CbMode::Off, &mut rng);
        let s_on = spread(CbMode::On, &mut rng);
        assert!(s_off > 0.5, "noise should spread codes: {s_off}");
        assert!(
            s_on < s_off * 0.85,
            "CB must tighten read noise: off={s_off} on={s_on}"
        );
    }

    #[test]
    fn ideal_comparator_path_matches_noiseless_convert() {
        let p = params_for(9);
        let (bank, cmp) = ideal_setup(9);
        let adc = SarAdc::new(&p, &bank, &cmp);
        let mut rng = Rng::new(5);
        for i in 0..200 {
            let level = i as f64 / 200.0;
            assert_eq!(
                adc.convert(level, CbMode::Off, &mut rng).code,
                adc.convert_ideal_comparator(level)
            );
        }
    }

    #[test]
    fn prop_conversion_error_bounded_without_noise() {
        assert_prop("sar-quantization-error", 60, |g| {
            let bits = g.usize(4, 10) as u32;
            let p = params_for(bits);
            let bank = CapacitorBank::ideal(bits);
            let cmp = Comparator::new(0.0, 0.0);
            let adc = SarAdc::new(&p, &bank, &cmp);
            let mut rng = Rng::new(g.rng.next_u64());
            let level = g.f64(0.0, 0.999);
            let code = adc.convert(level, CbMode::Off, &mut rng).code as f64;
            let n = (1u32 << bits) as f64;
            // Rounding converter: code = round(level·n), so the residual
            // level·n − code lies in [−½, ½].
            let resid = level * n - code;
            if !(-0.5 - 1e-9..=0.5 + 1e-9).contains(&resid) {
                return Err(format!("bits={bits} level={level} code={code} resid={resid}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_codes_monotone_in_level_without_noise() {
        assert_prop("sar-monotone", 40, |g| {
            let bits = 8u32;
            let p = params_for(bits);
            let bank = CapacitorBank::ideal(bits);
            let cmp = Comparator::new(0.0, 0.0);
            let adc = SarAdc::new(&p, &bank, &cmp);
            let a = g.f64(0.0, 1.0);
            let b = g.f64(0.0, 1.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let c_lo = adc.convert_ideal_comparator(lo);
            let c_hi = adc.convert_ideal_comparator(hi);
            if c_lo > c_hi {
                return Err(format!("monotonicity violated: {lo}->{c_lo}, {hi}->{c_hi}"));
            }
            Ok(())
        });
    }
}
