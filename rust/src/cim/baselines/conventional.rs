//! Conventional charge-redistribution CIM, modeled after [4] (Jia et al.,
//! JSSC 2020): charge-domain MAC whose result is *redistributed* into a
//! separate binary C-DAC for SAR conversion.
//!
//! Architectural consequences captured here:
//! - **Attenuation**: sharing the MAC charge with an equal-size C-DAC
//!   halves the swing at the comparator ⇒ comparator noise in signal-LSB
//!   doubles (or the comparator must burn 4× energy to compensate).
//! - **8-bit readout**: scaling the separate C-DAC to 10 bits is the
//!   area/power blow-up of Fig. 1(B), so the baseline stays at 8 bits and
//!   its quantization error on a 1024-row MAC is 4 LSB₁₀ wide.
//! - **Two capacitor arrays switch** per conversion instead of one.

use crate::util::rng::Rng;

use super::ChipSummary;
use crate::cim::capacitor::CapacitorBank;
use crate::cim::comparator::Comparator;
use crate::cim::energy::EnergyModel;
use crate::cim::params::{CbMode, MacroParams};
use crate::cim::sar::SarAdc;

/// Parameters for the conventional baseline: derived from the CR-CIM set
/// with the architecture-specific deltas applied.
pub fn conventional_params(base: &MacroParams) -> MacroParams {
    let mut p = base.clone();
    // [4] runs an 8-bit SAR; its array is 1024 rows like ours but the
    // readout resolution is what the separate C-DAC can afford.
    p.adc_bits = 8;
    p.active_rows = 256; // bank cells participating in the 8b C-DAC model
    p.rows = 256;
    p
}

/// One column of the conventional architecture.
pub struct ConventionalColumn {
    pub params: MacroParams,
    /// MAC array bank (mismatch-sampled).
    mac_bank: CapacitorBank,
    /// Separate C-DAC bank (its own mismatch).
    dac_bank: CapacitorBank,
    cmp: Comparator,
    /// Swing attenuation from charge sharing: C_mac/(C_mac + C_dac).
    pub attenuation: f64,
}

impl ConventionalColumn {
    pub fn new(base: &MacroParams, index: usize) -> Self {
        let p = conventional_params(base);
        let mac_bank = CapacitorBank::sample(&p, index);
        let mut p2 = p.clone();
        p2.seed ^= 0xDAC0_0001;
        let dac_bank = CapacitorBank::sample(&p2, index);
        let root = Rng::salted(p.seed, 0xC047_E44B);
        let mut crng = root.substream(0xBA5E, index as u64);
        // Same physical comparator as CR-CIM, but the signal reaching it
        // is attenuated 2×, so in signal-LSB units its noise doubles.
        let attenuation = 0.5;
        let cmp = Comparator::sample(
            p.sigma_cmp_lsb_at_supply() / attenuation,
            p.sigma_cmp_offset_lsb / attenuation,
            &mut crng,
        );
        ConventionalColumn { params: p, mac_bank, dac_bank, cmp, attenuation }
    }

    /// Read the MAC result for `count` active products (prefix pattern).
    /// The MAC is computed on the full 1024-row array of the *base* macro,
    /// then redistributed and quantized by the 8-bit readout.
    pub fn read_count(&self, count: usize, macro_rows: usize, rng: &mut Rng) -> u32 {
        // MAC level on the compute array (normalized 0..1 of macro_rows).
        let level = count as f64 / macro_rows as f64;
        // Mismatch of the MAC bank perturbs the level (reuse bank INL as a
        // proxy at the bank's own resolution).
        let bank_code = ((level * (self.mac_bank.num_cells() as f64)).round() as usize)
            .min(self.mac_bank.num_cells());
        let level_mm = self.mac_bank.mac_level_prefix(bank_code);
        // kT/C on the *shared* capacitance (2× C ⇒ noise power halves, but
        // signal halves too: net SNR loss of 2×; model via attenuated
        // signal with the same absolute noise).
        let ktc = self.params.ktc_noise_lsb() / self.params.levels() as f64 * rng.gauss();
        let sampled = level_mm + ktc;
        let adc = SarAdc::new(&self.params, &self.dac_bank, &self.cmp);
        adc.convert(sampled, CbMode::Off, rng).code
    }
}

/// The Fig. 6 row for the [4]-like baseline, composed from its own energy
/// model: same component laws, conventional deltas applied.
pub fn summary(base: &MacroParams) -> ChipSummary {
    let p = base.clone().with_supply(0.85); // [4] nominal low-V point
    let m = EnergyModel::conventional(&p);
    ChipSummary {
        name: "[4] JSSC 2020 (charge, redistribution)",
        cim_type: "Charge",
        process_nm: 65,
        array_kb: 72.0,
        act_bits: 8,
        weight_bits: 8,
        adc_bits: 8,
        // Larger array, 8b: higher raw TOPS, lower efficiency.
        tops: 2.1,
        tops_per_mm2: 0.6,
        tops_per_watt: m.tops_per_watt(CbMode::Off),
        sqnr_db: Some(22.0),
        csnr_db: Some(17.0),
        supports_transformer: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attenuation_doubles_lsb_referred_noise() {
        let base = MacroParams::default();
        let col = ConventionalColumn::new(&base, 0);
        assert!((col.attenuation - 0.5).abs() < 1e-12);
        assert!(
            (col.cmp.sigma_lsb - 2.0 * base.sigma_cmp_lsb_at_supply() * 0.85 / 0.85).abs() < 1e-9
                || col.cmp.sigma_lsb > base.sigma_cmp_lsb_at_supply() * 1.9,
            "conventional comparator noise should be ~2x: {}",
            col.cmp.sigma_lsb
        );
    }

    #[test]
    fn readout_is_8bit_coarse() {
        let base = MacroParams::default();
        let col = ConventionalColumn::new(&base, 1);
        let mut rng = Rng::new(5);
        // Two MAC counts 2 LSB₁₀ apart (half an 8b LSB) often quantize to
        // the same 8-bit code.
        let a = col.read_count(512, 1024, &mut rng);
        assert!(a < 256, "8-bit code range");
    }

    #[test]
    fn conventional_efficiency_below_cr_cim() {
        let base = MacroParams::default();
        let s = summary(&base);
        let cr = EnergyModel::cr_cim(&base.clone().with_supply(0.6));
        assert!(
            s.tops_per_watt < cr.tops_per_watt(CbMode::Off) * 0.7,
            "conventional {} vs CR-CIM {}",
            s.tops_per_watt,
            cr.tops_per_watt(CbMode::Off)
        );
        // Paper's [4] column: 400 TOPS/W (1b-norm). Shape check: same
        // order of magnitude, clearly below CR-CIM.
        assert!(s.tops_per_watt > 100.0 && s.tops_per_watt < 700.0);
    }

    #[test]
    fn fom_gap_matches_paper_direction() {
        let base = MacroParams::default();
        let conv = summary(&base);
        // CR-CIM's published row.
        let cr_fom = 818.0 * 2f64.powf((45.3 - 1.76) / 6.02);
        let conv_fom = conv.sqnr_fom().unwrap();
        assert!(cr_fom / conv_fom > 2.0, "SQNR-FoM advantage should be >2x");
    }
}
