//! Fully-digital CIM baseline, modeled after [6] (Fujiwara et al., ISSCC
//! 2022, 5 nm): exact multiply-accumulate in SRAM-adjacent logic.
//!
//! Mechanisms captured:
//! - **No analog error**: the transfer function is exact (infinite
//!   CSNR/SQNR up to the operand quantization itself).
//! - **Energy per op is set by digital logic in the process node**: the
//!   paper's point is that digital CIM matches analog efficiency only at
//!   advanced nodes (254 TOPS/W at 5 nm). We model energy/op as a node-
//!   scaled constant so "what would digital cost at 65 nm" is answerable —
//!   that is the comparison Fig. 1 implies for CMOS edge/IoT devices.

use super::{node_energy_scale, ChipSummary};

/// Digital MAC energy model.
#[derive(Clone, Copy, Debug)]
pub struct DigitalCim {
    pub process_nm: u32,
    /// Energy per 1b-normalized op at the reference node [fJ].
    pub fj_per_op_ref: f64,
    /// Reference node [nm].
    pub ref_nm: u32,
}

impl DigitalCim {
    /// [6]'s published operating point: 254 TOPS/W at 5 nm ⇒ ~3.9 fJ/op.
    pub fn at_node(process_nm: u32) -> Self {
        DigitalCim { process_nm, fj_per_op_ref: 1e3 / 254.0, ref_nm: 5 }
    }

    pub fn fj_per_op(&self) -> f64 {
        self.fj_per_op_ref * node_energy_scale(self.ref_nm, self.process_nm)
    }

    pub fn tops_per_watt(&self) -> f64 {
        1e3 / self.fj_per_op()
    }

    /// Digital matvec is exact: the reference the analog error is judged
    /// against.
    pub fn matvec(&self, w: &[Vec<i32>], x: &[i32]) -> Vec<i64> {
        let n_out = w.first().map(|r| r.len()).unwrap_or(0);
        let mut y = vec![0i64; n_out];
        for (r, wrow) in w.iter().enumerate() {
            for (j, &wv) in wrow.iter().enumerate() {
                y[j] += wv as i64 * x[r] as i64;
            }
        }
        y
    }
}

/// Fig. 6-style row for the digital baseline at its native 5 nm node.
pub fn summary() -> ChipSummary {
    let d = DigitalCim::at_node(5);
    ChipSummary {
        name: "[6] ISSCC 2022 (digital, 5nm)",
        cim_type: "Digital",
        process_nm: 5,
        array_kb: 64.0,
        act_bits: 8,
        weight_bits: 8,
        adc_bits: 0,
        tops: 221.0 / 40.0, // headline is TOPS/mm²; representative TOPS
        tops_per_mm2: 221.0,
        tops_per_watt: d.tops_per_watt(),
        // Digital: error is quantization-only; effectively "very high".
        sqnr_db: None,
        csnr_db: None,
        supports_transformer: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digital_is_exact() {
        let d = DigitalCim::at_node(5);
        let w = vec![vec![3, -2], vec![-1, 4], vec![5, 0]];
        let x = vec![2, -3, 1];
        assert_eq!(d.matvec(&w, &x), vec![3 * 2 - 1 * -3 + 5, -2 * 2 + 4 * -3]);
    }

    #[test]
    fn node_scaling_kills_digital_at_65nm() {
        let at5 = DigitalCim::at_node(5).tops_per_watt();
        let at65 = DigitalCim::at_node(65).tops_per_watt();
        // 5 nm digital ≈ 254 TOPS/W; at 65 nm it collapses by (65/5)² —
        // which is the paper's argument for analog CIM at mature nodes.
        assert!((at5 - 254.0).abs() / 254.0 < 0.01);
        assert!(at65 < 3.0, "65nm digital = {at65} TOPS/W");
    }

    #[test]
    fn cr_cim_beats_digital_at_same_node() {
        use crate::cim::energy::EnergyModel;
        use crate::cim::params::{CbMode, MacroParams};
        let analog = EnergyModel::cr_cim(&MacroParams::default()).tops_per_watt(CbMode::Off);
        let digital = DigitalCim::at_node(65).tops_per_watt();
        assert!(
            analog / digital > 50.0,
            "at 65nm analog CIM should dominate: {analog} vs {digital}"
        );
    }
}
