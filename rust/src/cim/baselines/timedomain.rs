//! Time-domain CIM baseline, modeled after [3] (Wu et al., ISSCC 2022,
//! 28 nm): each cell contributes a weight-dependent *delay*; the MAC is
//! the accumulated edge time, digitized by a time-to-digital converter
//! (TDC).
//!
//! Mechanisms captured:
//! - **Delay-cell mismatch**: per-cell delay varies (Vth/RC mismatch) and
//!   is *supply- and slope-dependent*, so linearity drifts with operating
//!   point — the intro's claim that time-domain CIMs "have difficulty
//!   achieving >8bit linearity".
//! - **Accumulative jitter**: delay noise accumulates along the chain as
//!   √N (unlike charge summation where kT/C is fixed by total C).
//! - **TDC quantization**: resolution set by the reference delay step.

use crate::util::rng::Rng;

use super::ChipSummary;

/// One time-domain column (a delay chain + TDC).
pub struct TimeDomainColumn {
    /// Per-cell nominal-1.0 delay factors (mismatch).
    cell_delay: Vec<f64>,
    /// Per-cell jitter σ relative to one cell delay.
    jitter_rel: f64,
    /// TDC bits.
    bits: u32,
    /// Second-order supply-pushout nonlinearity amplitude (fraction of
    /// full scale at full input).
    nonlin_quadratic: f64,
}

impl TimeDomainColumn {
    pub fn new(rows: usize, sigma_delay: f64, seed: u64, index: usize) -> Self {
        let root = Rng::new(seed);
        let mut rng = root.substream(0x7D_C0DE, index as u64);
        let cell_delay = (0..rows)
            .map(|_| (1.0 + sigma_delay * rng.gauss()).max(0.05))
            .collect();
        TimeDomainColumn { cell_delay, jitter_rel: 0.05, bits: 6, nonlin_quadratic: 0.03 }
    }

    pub fn rows(&self) -> usize {
        self.cell_delay.len()
    }

    /// Read a MAC of `count` active cells: accumulate their delays (with
    /// per-cell jitter), apply the supply-pushout compression, quantize
    /// with the TDC.
    pub fn read_count(&self, count: usize, rng: &mut Rng) -> u32 {
        let count = count.min(self.rows());
        let mut t = 0.0;
        for d in &self.cell_delay[..count] {
            t += d + self.jitter_rel * rng.gauss();
        }
        let x = t / self.rows() as f64;
        // Quadratic pushout: later edges arrive through a drooped supply.
        let x = x - self.nonlin_quadratic * x * x;
        let n = (1u32 << self.bits) as f64;
        ((x * n).round().max(0.0) as u32).min((1u32 << self.bits) - 1)
    }

    pub fn ideal_code(&self, count: usize) -> u32 {
        let n = (1u32 << self.bits) as f64;
        (((count as f64 / self.rows() as f64) * n).round() as u32).min((1u32 << self.bits) - 1)
    }
}

/// Fig. 6-adjacent row for the [3]-like chip (from its paper: 28 nm,
/// 37 TOPS/W at 8b-MAC ⇒ ~2368 1b-normalized).
pub fn summary() -> ChipSummary {
    ChipSummary {
        name: "[3] ISSCC 2022 (time-domain, 28nm)",
        cim_type: "Time",
        process_nm: 28,
        array_kb: 128.0,
        act_bits: 8,
        weight_bits: 8,
        adc_bits: 6,
        tops: 1.24,
        tops_per_mm2: 8.0,
        tops_per_watt: 37.01 * 64.0,
        sqnr_db: Some(19.0),
        csnr_db: None,
        supports_transformer: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Moments;

    #[test]
    fn noiseless_mismatch_free_chain_is_linear() {
        let mut col = TimeDomainColumn::new(256, 0.0, 1, 0);
        col.jitter_rel = 0.0;
        col.nonlin_quadratic = 0.0;
        let mut rng = Rng::new(2);
        for count in [0usize, 32, 128, 255] {
            assert_eq!(col.read_count(count, &mut rng), col.ideal_code(count));
        }
    }

    #[test]
    fn jitter_accumulates_with_count() {
        let col = TimeDomainColumn::new(256, 0.0, 1, 0);
        let mut rng = Rng::new(3);
        let spread = |count: usize, rng: &mut Rng| {
            let mut m = Moments::new();
            for _ in 0..400 {
                // Measure pre-quantization: use many reads of the code.
                m.push(col.read_count(count, rng) as f64);
            }
            m.std()
        };
        let lo = spread(16, &mut rng);
        let hi = spread(240, &mut rng);
        assert!(hi > lo, "accumulative jitter: {lo} -> {hi}");
    }

    #[test]
    fn pushout_compresses_high_codes() {
        let mut col = TimeDomainColumn::new(256, 0.0, 1, 0);
        col.jitter_rel = 0.0;
        let mut rng = Rng::new(4);
        let got = col.read_count(250, &mut rng);
        assert!(got < col.ideal_code(250));
    }

    #[test]
    fn summary_is_non_transformer_grade() {
        let s = summary();
        assert!(!s.supports_transformer);
        assert!(s.sqnr_fom().unwrap() < 118841.0 * 0.5, "below this work's FoM");
    }
}
