//! Behavioral models of the comparison chips in Fig. 6 and the
//! architectures Fig. 1(B) argues against.
//!
//! These are *mechanism-level* models, not datasheet copies: each baseline
//! reproduces the error/energy structure that its architecture implies
//! (charge-redistribution attenuation, current-mirror mismatch
//! nonlinearity, digital exactness), so the comparison table's *shape* —
//! who wins which column and why — regenerates from first principles.

pub mod conventional;
pub mod current;
pub mod digital;
pub mod published;
pub mod timedomain;

use crate::cim::params::MacroParams;

/// A row of the Fig. 6 comparison table, produced by each baseline.
#[derive(Clone, Debug)]
pub struct ChipSummary {
    pub name: &'static str,
    pub cim_type: &'static str,
    pub process_nm: u32,
    pub array_kb: f64,
    pub act_bits: u32,
    pub weight_bits: u32,
    pub adc_bits: u32,
    /// 1b-normalized peak throughput [TOPS].
    pub tops: f64,
    /// 1b-normalized areal efficiency [TOPS/mm²].
    pub tops_per_mm2: f64,
    /// 1b-normalized energy efficiency [TOPS/W].
    pub tops_per_watt: f64,
    pub sqnr_db: Option<f64>,
    pub csnr_db: Option<f64>,
    pub supports_transformer: bool,
}

impl ChipSummary {
    /// SQNR-FoM = TOPS/W · 2^((SQNR-1.76)/6.02)  (Fig. 6 footnote).
    pub fn sqnr_fom(&self) -> Option<f64> {
        self.sqnr_db.map(|s| self.tops_per_watt * 2f64.powf((s - 1.76) / 6.02))
    }

    /// CSNR-FoM = TOPS/W · 2^((CSNR-1.76)/6.02).
    pub fn csnr_fom(&self) -> Option<f64> {
        self.csnr_db.map(|s| self.tops_per_watt * 2f64.powf((s - 1.76) / 6.02))
    }
}

/// Shared scaling helper: rough digital/analog energy scaling between
/// process nodes (gate energy ∝ node²·V² to first order; used only to put
/// the 28 nm / 7 nm baselines on the same table, not for our own chip).
pub fn node_energy_scale(from_nm: u32, to_nm: u32) -> f64 {
    let f = from_nm as f64;
    let t = to_nm as f64;
    (t / f).powi(2)
}

/// Default parameter set a baseline derives its own variant from.
pub fn base_params() -> MacroParams {
    MacroParams::default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fom_footnote_formula() {
        let chip = ChipSummary {
            name: "x",
            cim_type: "Charge",
            process_nm: 65,
            array_kb: 10.0,
            act_bits: 6,
            weight_bits: 6,
            adc_bits: 10,
            tops: 1.2,
            tops_per_mm2: 2.5,
            tops_per_watt: 818.0,
            sqnr_db: Some(45.3),
            csnr_db: Some(31.3),
            supports_transformer: true,
        };
        // Paper: SQNR-FoM ≈ 118841, CSNR-FoM ≈ 24541 (the table rounds
        // its inputs, so allow a few percent).
        let sf = chip.sqnr_fom().unwrap();
        let cf = chip.csnr_fom().unwrap();
        assert!((sf - 118841.0).abs() / 118841.0 < 0.05, "sqnr fom {sf}");
        assert!((cf - 24541.0).abs() / 24541.0 < 0.05, "csnr fom {cf}");
    }

    #[test]
    fn node_scaling_is_quadratic() {
        assert!((node_energy_scale(65, 65) - 1.0).abs() < 1e-12);
        assert!(node_energy_scale(65, 28) < 0.25);
        assert!(node_energy_scale(28, 65) > 4.0);
    }
}
