//! Published rows of the Fig. 6 comparison table, verbatim from the paper.
//!
//! The fig6 bench prints these next to our *regenerated* rows so the
//! reader can see which columns come out of the simulator and how far off
//! they are. `[5]` (VLSI 2021) is kept as a published-only row: it is a
//! floating-point exponent-CIM whose mechanism is orthogonal to this
//! paper's contribution, so we reproduce its table entry, not its circuit.

use super::ChipSummary;

/// "This work" — the paper's own published numbers (for delta reporting).
pub fn this_work_published() -> ChipSummary {
    ChipSummary {
        name: "This work (published)",
        cim_type: "Charge",
        process_nm: 65,
        array_kb: 10.0,
        act_bits: 6,
        weight_bits: 6,
        adc_bits: 10,
        tops: 1.2,
        tops_per_mm2: 2.5,
        tops_per_watt: 818.0,
        sqnr_db: Some(45.3),
        csnr_db: Some(31.3),
        supports_transformer: true,
    }
}

/// [4] Jia et al., JSSC 2020 — published row.
pub fn jssc2020_published() -> ChipSummary {
    ChipSummary {
        name: "[4] JSSC 2020 (published)",
        cim_type: "Charge",
        process_nm: 65,
        array_kb: 72.0,
        act_bits: 8,
        weight_bits: 8,
        adc_bits: 8,
        tops: 2.1,
        tops_per_mm2: 0.6,
        tops_per_watt: 400.0,
        sqnr_db: Some(22.0),
        csnr_db: Some(17.0),
        supports_transformer: false,
    }
}

/// [5] Lee et al., VLSI 2021 — published row (28 nm, exponent CIM).
pub fn vlsi2021_published() -> ChipSummary {
    ChipSummary {
        name: "[5] VLSI 2021 (published)",
        cim_type: "Charge",
        process_nm: 28,
        array_kb: 36.0,
        act_bits: 5,
        weight_bits: 1,
        adc_bits: 8,
        tops: 6.1,
        tops_per_mm2: 12.0,
        tops_per_watt: 5796.0,
        sqnr_db: Some(17.5),
        csnr_db: Some(10.5),
        supports_transformer: false,
    }
}

/// [2] Dong et al., ISSCC 2020 — published row (7 nm, current).
pub fn isscc2020_published() -> ChipSummary {
    ChipSummary {
        name: "[2] ISSCC 2020 (published)",
        cim_type: "Current",
        process_nm: 7,
        array_kb: 0.5,
        act_bits: 4,
        weight_bits: 4,
        adc_bits: 4,
        tops: 5.9,
        tops_per_mm2: 112.0,
        tops_per_watt: 5616.0,
        sqnr_db: Some(21.0),
        csnr_db: None,
        supports_transformer: false,
    }
}

/// All published rows in table order.
pub fn all_published() -> Vec<ChipSummary> {
    vec![
        this_work_published(),
        jssc2020_published(),
        vlsi2021_published(),
        isscc2020_published(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn this_work_fom_ratios_match_paper_headline() {
        // The paper claims 2.3× SQNR-FoM and 1.5× CSNR-FoM over the best
        // previous work. Verify the footnote formula reproduces that from
        // the published columns.
        let rows = all_published();
        let this = &rows[0];
        let best_other_sqnr = rows[1..]
            .iter()
            .filter_map(|r| r.sqnr_fom())
            .fold(0.0f64, f64::max);
        let best_other_csnr = rows[1..]
            .iter()
            .filter_map(|r| r.csnr_fom())
            .fold(0.0f64, f64::max);
        let sqnr_ratio = this.sqnr_fom().unwrap() / best_other_sqnr;
        let csnr_ratio = this.csnr_fom().unwrap() / best_other_csnr;
        assert!((sqnr_ratio - 2.3).abs() < 0.3, "SQNR-FoM ratio {sqnr_ratio}");
        assert!((csnr_ratio - 1.5).abs() < 0.3, "CSNR-FoM ratio {csnr_ratio}");
    }

    #[test]
    fn only_this_work_supports_transformers() {
        let rows = all_published();
        assert!(rows[0].supports_transformer);
        assert!(rows[1..].iter().all(|r| !r.supports_transformer));
    }
}
