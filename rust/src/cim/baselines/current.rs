//! Current-domain CIM baseline, modeled after [2] (Dong et al., ISSCC
//! 2020, 7 nm): each cell sinks a weight-dependent current onto the
//! bitline; the summed current is digitized by a coarse (4-bit) ADC.
//!
//! Mechanisms captured:
//! - **Transistor mismatch**: cell currents vary with Vth mismatch
//!   (log-normal-ish; we use a clipped Gaussian on the current factor),
//!   which — unlike capacitor mismatch — drifts with operating point and
//!   cannot reach >8b linearity (the paper's Fig. 1 claim).
//! - **I–V nonlinearity**: the bitline voltage droops as more cells pull,
//!   compressing large MAC values (saturating transfer).
//! - **4-bit readout** with high energy efficiency: current-domain
//!   summation is cheap, which is why its TOPS/W is high despite the poor
//!   compute accuracy.

use crate::util::rng::Rng;

use super::ChipSummary;

/// One current-domain column.
pub struct CurrentColumn {
    /// Per-cell current factor (nominal 1.0) — Vth mismatch.
    cell_factor: Vec<f64>,
    /// Saturation knee: fraction of full-scale where compression starts.
    knee: f64,
    /// ADC bits.
    bits: u32,
    /// Readout noise in LSB of the coarse ADC.
    sigma_read_lsb: f64,
}

impl CurrentColumn {
    pub fn new(rows: usize, sigma_cell: f64, seed: u64, index: usize) -> Self {
        let root = Rng::new(seed);
        let mut rng = root.substream(0xC44E_17, index as u64);
        let cell_factor = (0..rows)
            .map(|_| (1.0 + sigma_cell * rng.gauss()).max(0.05))
            .collect();
        CurrentColumn { cell_factor, knee: 0.7, bits: 4, sigma_read_lsb: 0.3 }
    }

    pub fn rows(&self) -> usize {
        self.cell_factor.len()
    }

    /// Saturating bitline transfer: linear up to the knee, then
    /// soft-compressing (models IR droop / transistor triode entry).
    fn compress(&self, x: f64) -> f64 {
        if x <= self.knee {
            x
        } else {
            self.knee + (1.0 - self.knee) * (1.0 - (-(x - self.knee) / (1.0 - self.knee)).exp())
        }
    }

    /// Read a MAC of `count` active cells (prefix pattern), returning the
    /// coarse ADC code.
    pub fn read_count(&self, count: usize, rng: &mut Rng) -> u32 {
        // detlint: allow(float-reduction) -- sequential sum over the fixed row prefix, never parallel
        let i_sum: f64 = self.cell_factor[..count.min(self.rows())].iter().sum();
        let level = self.compress(i_sum / self.rows() as f64);
        let n = (1u32 << self.bits) as f64;
        let noisy = level * n + self.sigma_read_lsb * rng.gauss();
        (noisy.round().max(0.0) as u32).min((1u32 << self.bits) - 1)
    }

    /// Ideal code for comparison.
    pub fn ideal_code(&self, count: usize) -> u32 {
        let n = (1u32 << self.bits) as f64;
        (((count as f64 / self.rows() as f64) * n).round() as u32).min((1u32 << self.bits) - 1)
    }
}

/// Fig. 6 row for the [2]-like current-domain chip.
pub fn summary() -> ChipSummary {
    ChipSummary {
        name: "[2] ISSCC 2020 (current, 7nm)",
        cim_type: "Current",
        process_nm: 7,
        array_kb: 0.5,
        act_bits: 4,
        weight_bits: 4,
        adc_bits: 4,
        tops: 5.9,
        tops_per_mm2: 112.0,
        // Advanced node + coarse readout: very high raw efficiency.
        tops_per_watt: 5616.0,
        sqnr_db: Some(21.0),
        csnr_db: None, // N.A. in the paper's table
        supports_transformer: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rms;

    #[test]
    fn linear_region_tracks_ideal() {
        let col = CurrentColumn::new(256, 0.0, 1, 0);
        let mut rng = Rng::new(2);
        // No mismatch, low counts: codes match ideal.
        let mut col0 = col;
        col0.sigma_read_lsb = 0.0;
        for count in [0usize, 16, 64, 128] {
            assert_eq!(col0.read_count(count, &mut rng), col0.ideal_code(count));
        }
    }

    #[test]
    fn compression_bends_high_end() {
        let mut col = CurrentColumn::new(256, 0.0, 1, 0);
        col.sigma_read_lsb = 0.0;
        let mut rng = Rng::new(3);
        // Near full scale the code falls below ideal.
        let got = col.read_count(250, &mut rng);
        let ideal = col.ideal_code(250);
        assert!(got < ideal, "compressed {got} vs ideal {ideal}");
    }

    #[test]
    fn mismatch_limits_linearity_vs_charge_domain() {
        // Current-domain INL (in its own LSB) grows quickly with cell σ.
        let err_at = |sigma: f64| {
            let col = CurrentColumn::new(256, sigma, 7, 0);
            let mut errs = Vec::new();
            for count in (0..=180).step_by(4) {
                // stay in linear region
                let i_sum: f64 = col.cell_factor[..count].iter().sum();
                let ideal = count as f64 / 256.0;
                errs.push((i_sum / 256.0 - ideal) * 16.0); // in 4b LSB
            }
            rms(&errs)
        };
        assert!(err_at(0.08) > 4.0 * err_at(0.01));
    }

    #[test]
    fn summary_matches_paper_table_values() {
        let s = summary();
        assert_eq!(s.adc_bits, 4);
        assert!(s.csnr_fom().is_none());
        // SQNR-FoM from the table: 51466.
        let fom = s.sqnr_fom().unwrap();
        assert!((fom - 51466.0).abs() / 51466.0 < 0.05, "fom={fom}");
    }
}
