//! Full CR-CIM column: capacitor bank + comparator + SAR + noise sources,
//! sequenced through the Reset → Compute → Adc cycle.
//!
//! This is the object the characterization benches (Fig. 5) run Monte-Carlo
//! over, and the golden model the behavioral L1 kernel's noise parameters
//! are calibrated from.

use crate::util::rng::Rng;

use super::capacitor::CapacitorBank;
use super::cell::{Phase, PhaseSequencer};
use super::comparator::Comparator;
use super::params::{CbMode, MacroParams};
use super::sar::{Conversion, SarAdc};

/// Stream-purpose tag for per-conversion noise substreams (see
/// [`Column::mac_convert_owned`]).
const CONVERSION_STREAM: u64 = 0x00C0_4A11;

/// One column of the macro with its sampled (per-die) nonidealities.
#[derive(Clone, Debug)]
pub struct Column {
    pub params: MacroParams,
    pub bank: CapacitorBank,
    pub cmp: Comparator,
    pub index: usize,
    weights: Vec<bool>,
    seq: PhaseSequencer,
    /// Root of this column's owned noise substreams, derived from
    /// (die seed, global column index `col_base + index`) at construction.
    noise_root: Rng,
    /// Conversions performed through the owned stream — the third key of
    /// the (die seed, global column, conversion counter) substream triple.
    conversions: u64,
}

impl Column {
    /// Instantiate column `index` of the die identified by `params.seed`.
    ///
    /// All per-column substreams (capacitor mismatch, comparator sample,
    /// conversion noise) key on the **global** column index
    /// `params.col_base + index`, so a macro that models a slice of a
    /// wider logical column array (a column shard) draws exactly the
    /// noise the unsharded wide macro would — the decomposition is
    /// invisible to the noise model.
    pub fn new(params: &MacroParams, index: usize) -> Result<Self, String> {
        params.validate()?;
        let global = params.col_base + index;
        let bank = CapacitorBank::sample(params, global);
        let root = Rng::new(params.seed);
        let mut crng = root.substream(0x00C0_33A4, global as u64);
        let cmp = Comparator::sample(
            params.sigma_cmp_lsb_at_supply(),
            params.sigma_cmp_offset_lsb,
            &mut crng,
        );
        let noise_root = root.substream(CONVERSION_STREAM, global as u64);
        Ok(Column {
            params: params.clone(),
            bank,
            cmp,
            index,
            weights: vec![false; params.active_rows],
            seq: PhaseSequencer::default(),
            noise_root,
            conversions: 0,
        })
    }

    /// An idealized column (no mismatch, no noise, no nonlinearity):
    /// useful as the "digital reference" in accuracy comparisons.
    pub fn ideal(params: &MacroParams) -> Result<Self, String> {
        params.validate()?;
        let mut p = params.clone();
        p.sigma_cu_rel = 0.0;
        p.nonlin_cubic_lsb = 0.0;
        p.temperature_k = 0.0; // no kT/C in the digital-reference column
        let noise_root = Rng::new(p.seed).substream(CONVERSION_STREAM, u64::MAX);
        Ok(Column {
            bank: CapacitorBank::ideal(p.adc_bits),
            cmp: Comparator::new(0.0, 0.0),
            index: usize::MAX,
            weights: vec![false; p.active_rows],
            seq: PhaseSequencer::default(),
            params: p,
            noise_root,
            conversions: 0,
        })
    }

    /// Load the column's weight bits (6T SRAM write).
    pub fn load_weights(&mut self, bits: &[bool]) {
        assert_eq!(bits.len(), self.params.active_rows, "weight vector length");
        self.weights.copy_from_slice(bits);
    }

    pub fn weights(&self) -> &[bool] {
        &self.weights
    }

    fn static_level_prefix(&self, count: usize) -> f64 {
        self.apply_nonlin(self.bank.mac_level_prefix(count))
    }

    /// Residual cubic nonlinearity from switch parasitics / signal-dependent
    /// charge injection, calibrated by `nonlin_cubic_lsb` (peak, in LSB).
    fn apply_nonlin(&self, level: f64) -> f64 {
        let peak = self.params.nonlin_cubic_lsb / self.params.levels() as f64;
        // x(x-1/2)(x-1) has extrema ±1/(12√3) ≈ ±0.0481 on [0,1].
        let shape = level * (level - 0.5) * (level - 1.0) / 0.048_112_522_4;
        level + peak * shape
    }

    /// Sample the kT/C + any residual sampled noise onto the level.
    fn sample_noise(&self, rng: &mut Rng) -> f64 {
        self.params.ktc_noise_lsb() / self.params.levels() as f64 * rng.gauss()
    }

    /// One full MAC + conversion for a binary input vector, enforcing the
    /// phase cycle that the shared D_DAC/reset node requires.
    pub fn mac_convert(&mut self, inputs: &[bool], mode: CbMode, rng: &mut Rng) -> Conversion {
        assert_eq!(inputs.len(), self.params.active_rows, "input vector length");
        self.seq.advance(Phase::Compute).expect("phase: reset -> compute");
        let level = self.apply_nonlin(self.bank.mac_level_and(inputs, &self.weights))
            + self.sample_noise(rng);
        self.seq.advance(Phase::Adc).expect("phase: compute -> adc");
        let adc = SarAdc::new(&self.params, &self.bank, &self.cmp);
        let conv = adc.convert(level, mode, rng);
        self.seq.advance(Phase::Reset).expect("phase: adc -> reset");
        conv
    }

    /// Like [`mac_convert`](Self::mac_convert), but drawing noise from the
    /// column's *owned* substream instead of a caller-provided RNG. Each
    /// conversion gets a fresh stream keyed by (die seed, column index,
    /// conversion counter), so columns never contend on a shared RNG and
    /// macro-level results are bit-identical at any worker-thread count.
    pub fn mac_convert_owned(&mut self, inputs: &[bool], mode: CbMode) -> Conversion {
        let mut rng = self.noise_root.substream(CONVERSION_STREAM, self.conversions);
        self.conversions = self.conversions.wrapping_add(1);
        self.mac_convert(inputs, mode, &mut rng)
    }

    /// Conversions performed through the owned substream so far.
    pub fn conversion_count(&self) -> u64 {
        self.conversions
    }

    /// Characterization read: drive exactly `count` cells (prefix pattern)
    /// and convert. This sweeps the transfer curve without constructing
    /// input vectors.
    pub fn read_count(&self, count: usize, mode: CbMode, rng: &mut Rng) -> Conversion {
        let level = self.static_level_prefix(count) + self.sample_noise(rng);
        let adc = SarAdc::new(&self.params, &self.bank, &self.cmp);
        adc.convert(level, mode, rng)
    }

    /// Static (noise-free, ideal-comparator) transfer point. Isolates the
    /// INL contribution of mismatch + residual nonlinearity.
    pub fn static_code(&self, count: usize) -> u32 {
        let level = self.static_level_prefix(count);
        let adc = SarAdc::new(&self.params, &self.bank, &self.cmp);
        adc.convert_ideal_comparator(level)
    }

    /// The ideal (error-free) expected code for `count` driven cells.
    pub fn ideal_code(&self, count: usize) -> u32 {
        (count as u32).min((self.params.levels() - 1) as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Moments;

    fn fast_params() -> MacroParams {
        let mut p = MacroParams::default();
        p.adc_bits = 8;
        p.active_rows = 256;
        p.rows = 256;
        p
    }

    #[test]
    fn ideal_column_is_exact() {
        let p = fast_params();
        let col = Column::ideal(&p).unwrap();
        let mut rng = Rng::new(1);
        for count in [0usize, 1, 50, 128, 255] {
            let conv = col.read_count(count, CbMode::Off, &mut rng);
            assert_eq!(conv.code, count as u32, "count={count}");
        }
        // count = levels saturates at the top code.
        assert_eq!(col.read_count(256, CbMode::Off, &mut rng).code, 255);
    }

    #[test]
    fn mac_convert_computes_dot_product() {
        let p = fast_params();
        let mut col = Column::ideal(&p).unwrap();
        let mut rng = Rng::new(2);
        let weights: Vec<bool> = (0..256).map(|i| i % 2 == 0).collect();
        let inputs: Vec<bool> = (0..256).map(|i| i % 3 == 0).collect();
        col.load_weights(&weights);
        let expect: u32 = inputs
            .iter()
            .zip(&weights)
            .filter(|(&i, &w)| i & w)
            .count() as u32;
        let got = col.mac_convert(&inputs, CbMode::Off, &mut rng).code;
        assert_eq!(got, expect);
    }

    #[test]
    fn phase_cycle_allows_repeated_conversions() {
        let p = fast_params();
        let mut col = Column::ideal(&p).unwrap();
        let mut rng = Rng::new(3);
        let inputs = vec![true; 256];
        for _ in 0..3 {
            let _ = col.mac_convert(&inputs, CbMode::Off, &mut rng);
        }
    }

    #[test]
    fn real_column_noise_matches_spec_scale() {
        let p = MacroParams::default();
        let col = Column::new(&p, 0).unwrap();
        let mut rng = Rng::new(4);
        // Repeated reads of a fixed mid-scale pattern: code std should be
        // on the order of sigma_cmp (w/o CB) and visibly lower with CB.
        let mut m_off = Moments::new();
        let mut m_on = Moments::new();
        for _ in 0..1200 {
            m_off.push(col.read_count(512, CbMode::Off, &mut rng).code as f64);
            m_on.push(col.read_count(512, CbMode::On, &mut rng).code as f64);
        }
        assert!(m_off.std() > 0.4, "off-mode noise too small: {}", m_off.std());
        assert!(
            m_on.std() < m_off.std(),
            "CB should reduce noise: on={} off={}",
            m_on.std(),
            m_off.std()
        );
    }

    #[test]
    fn static_inl_is_bounded_by_spec() {
        let p = MacroParams::default();
        // Check a few columns of the default die: |INL| < ~2.5 LSB
        // (paper: < 2 LSB measured; our calibration allows a small margin).
        for colidx in 0..4 {
            let col = Column::new(&p, colidx).unwrap();
            let mut worst = 0.0f64;
            for count in (0..=1024).step_by(16) {
                let code = col.static_code(count) as f64;
                let ideal = col.ideal_code(count) as f64;
                worst = worst.max((code - ideal).abs());
            }
            assert!(worst < 3.0, "col {colidx}: worst static error {worst} LSB");
            assert!(worst > 0.1, "col {colidx}: suspiciously perfect ({worst})");
        }
    }

    #[test]
    fn deterministic_per_die_seed() {
        let p = MacroParams::default();
        let a = Column::new(&p, 3).unwrap();
        let b = Column::new(&p, 3).unwrap();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        for count in [10usize, 200, 700] {
            assert_eq!(
                a.read_count(count, CbMode::On, &mut r1).code,
                b.read_count(count, CbMode::On, &mut r2).code
            );
        }
    }

    #[test]
    fn owned_stream_is_deterministic_and_keyed_by_counter() {
        let p = MacroParams::default();
        let inputs: Vec<bool> = (0..p.active_rows).map(|i| i % 3 == 0).collect();
        let weights: Vec<bool> = (0..p.active_rows).map(|i| i % 2 == 0).collect();
        let run = || {
            let mut col = Column::new(&p, 5).unwrap();
            col.load_weights(&weights);
            (0..6).map(|_| col.mac_convert_owned(&inputs, CbMode::Off).code).collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same (seed, column, counter) must replay exactly");
        // The counter advances, so repeated conversions see fresh noise
        // (codes are not all identical for a noisy column).
        assert!(a.windows(2).any(|w| w[0] != w[1]) || a.len() < 2, "{a:?}");
        let col = {
            let mut c = Column::new(&p, 5).unwrap();
            c.load_weights(&weights);
            let _ = c.mac_convert_owned(&inputs, CbMode::Off);
            c
        };
        assert_eq!(col.conversion_count(), 1);
    }

    #[test]
    fn col_base_shifts_the_global_noise_key() {
        let p = MacroParams::default();
        // Column 3 of a standalone die == column 0 of a shard macro whose
        // col_base is 3: same mismatch, same conversion noise stream.
        let mut direct = Column::new(&p, 3).unwrap();
        let mut sharded = Column::new(&p.clone().with_col_base(3), 0).unwrap();
        let weights: Vec<bool> = (0..p.active_rows).map(|i| i % 2 == 0).collect();
        let inputs: Vec<bool> = (0..p.active_rows).map(|i| i % 3 == 0).collect();
        direct.load_weights(&weights);
        sharded.load_weights(&weights);
        for _ in 0..4 {
            assert_eq!(
                direct.mac_convert_owned(&inputs, CbMode::Off).code,
                sharded.mac_convert_owned(&inputs, CbMode::Off).code,
            );
        }
        // A different global column is a different stream.
        let mut other = Column::new(&p, 4).unwrap();
        other.load_weights(&weights);
        let a: Vec<u32> =
            (0..8).map(|_| other.mac_convert_owned(&inputs, CbMode::Off).code).collect();
        let mut replay = Column::new(&p, 3).unwrap();
        replay.load_weights(&weights);
        let b: Vec<u32> =
            (0..8).map(|_| replay.mac_convert_owned(&inputs, CbMode::Off).code).collect();
        assert_ne!(a, b, "distinct global columns must not share noise");
    }

    #[test]
    #[should_panic(expected = "input vector length")]
    fn wrong_input_length_panics() {
        let p = fast_params();
        let mut col = Column::ideal(&p).unwrap();
        let mut rng = Rng::new(8);
        let _ = col.mac_convert(&[true; 10], CbMode::Off, &mut rng);
    }
}
