//! Process / circuit parameters for the CR-CIM macro and its baselines.
//!
//! Every physical constant the simulator uses lives here, with the
//! calibration rationale. The defaults are tuned so the *published*
//! figures of merit emerge from the mechanisms the paper describes (see
//! DESIGN.md §Circuit & energy model and EXPERIMENTS.md §Calibration):
//!
//! - 1088×78 array, 10-bit reconfigured SAR readout
//! - read noise ≈ 0.58 LSB rms with CSNR-boost, ~2× without
//! - |INL| < 2 LSB over the 10-bit transfer curve
//! - peak efficiency ≈ 818 TOPS/W (1b-normalized) at 0.6 V
//! - CB overhead: 1.9× power, 2.5× conversion time
//! - comparator energy share ≈ 60% of a conversion (high-resolution SAR)

/// Boltzmann constant [J/K].
pub const K_BOLTZMANN: f64 = 1.380_649e-23;

/// ADC / readout mode: whether the CSNR-boost (majority voting) is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CbMode {
    /// Plain 10-comparison SAR conversion.
    Off,
    /// CSNR boost: the last `mv_last_bits` comparisons are repeated
    /// `mv_votes` times and majority-voted (paper: 6×3).
    On,
}

impl CbMode {
    pub fn label(self) -> &'static str {
        match self {
            CbMode::Off => "wo/CB",
            CbMode::On => "w/CB",
        }
    }
}

/// Full parameter set for one CR-CIM macro instance.
#[derive(Clone, Debug)]
pub struct MacroParams {
    // ---- array geometry ----
    /// Physical rows (1088 = 1024 binary-bank cells + 64 offset/cal cells).
    pub rows: usize,
    /// Cells participating in the binary capacitor bank (2^adc_bits).
    pub active_rows: usize,
    /// Columns, each with its own reconfigured SAR readout.
    pub cols: usize,
    /// ADC resolution; the capacitor bank is binary-weighted to this depth.
    pub adc_bits: u32,

    // ---- capacitors ----
    /// Unit (cell) capacitance [fF]; custom fringe cap.
    pub c_unit_ff: f64,
    /// Relative 1σ mismatch of a unit cap.
    pub sigma_cu_rel: f64,
    /// Signal-dependent residual nonlinearity (switch parasitics, charge
    /// injection), as a cubic term peak amplitude in LSB at full scale.
    /// Calibrated so measured |INL| ≈ 2 LSB like Fig. 5.
    pub nonlin_cubic_lsb: f64,

    // ---- comparator ----
    /// Comparator input-referred noise at nominal supply, in LSB of the
    /// 10-bit readout. CR-CIM keeps the full signal swing so this spec is
    /// 2× relaxed vs a conventional charge-redistribution CIM at equal
    /// conversion accuracy.
    pub sigma_cmp_lsb: f64,
    /// Comparator offset 1σ across columns [LSB] (auto-zeroed residual).
    pub sigma_cmp_offset_lsb: f64,
    /// Effective noise of the early (non-voted, MSB-side) comparisons
    /// relative to `sigma_cmp_lsb`. In the asynchronous SAR the first
    /// decisions see large inputs and enjoy long regeneration, so their
    /// input-referred noise is a fraction of the LSB decisions'.
    pub sigma_cmp_early_factor: f64,

    // ---- majority voting (CSNR boost) ----
    /// Votes per boosted comparison (paper: 6).
    pub mv_votes: usize,
    /// Number of trailing SAR comparisons that get voted (paper: 3).
    pub mv_last_bits: usize,

    // ---- supply / timing ----
    /// Supply voltage [V]; the paper sweeps 0.6–1.1 V.
    pub supply_v: f64,
    /// Nominal supply for which the noise/energy constants are quoted [V].
    pub supply_nominal_v: f64,
    /// Comparator decision + DAC settle time per SAR step at nominal
    /// supply [ns]. Scales with supply (gate overdrive).
    pub t_cmp_ns: f64,
    /// Compute-phase (sample + MAC settle) time [ns].
    pub t_compute_ns: f64,

    // ---- energy model (per column conversion, at nominal supply) ----
    /// Comparator energy per comparison at `sigma_cmp_lsb` [pJ]. Scales as
    /// 1/σ² (noise-limited dynamic comparator) and V².
    pub e_cmp_pj: f64,
    /// Array sampling energy factor: fraction of ΣC·V² actually switched
    /// during the compute phase (activity + bottom-plate scheme).
    pub alpha_sample: f64,
    /// C-DAC switching energy factor relative to ΣC·V² (monotonic
    /// switching ≈ 0.37 in theory; includes driver overhead).
    pub alpha_dac: f64,
    /// SAR logic + row drivers + periphery energy per conversion [pJ],
    /// digital: scales as V².
    pub e_logic_pj: f64,

    // ---- digital periphery ----
    /// Latency of one digital partial-sum accumulation step [ns]: the
    /// adder that folds a row tile's output register into the layer
    /// accumulator when a reduction dimension spans multiple tiles
    /// (k > `active_rows`). Per extra row tile, per streamed vector.
    pub t_accum_ns: f64,
    /// Latency of reprogramming one (row tile × column tile) weight load
    /// [ns]: a row-parallel 6T SRAM write of the tile (≈1 ns/row over
    /// 1024 active rows on the paper geometry). Paid per tile whenever a
    /// layer's weights move onto a macro; the `Scheduler` can hide it
    /// behind the previous layer's bit-serial conversions
    /// (double-buffered reload, see `Scheduler::plan_graph`).
    pub t_wload_ns: f64,
    /// Weight-SRAM budget per macro array [bits]: how many weight bits
    /// one macro can keep *resident* between forward passes. The default
    /// is one **paper-geometry** array (1088 × 78 = 84 864 bits — a
    /// macro holds exactly the tile programmed into it); a banked-SRAM
    /// deployment raises it ([`with_sram_bits`](Self::with_sram_bits)),
    /// `0` disables residency entirely (every pass reloads every
    /// layer). This is a fixed deployment knob, **not** derived from
    /// `rows`/`cols` — a shrunken test geometry keeps the paper-scale
    /// budget (generously resident) unless it sets its own. The budget
    /// bounds the resident-weight cache: the pipeline executor keeps a
    /// layer's programmed dies alive across passes only while the
    /// pool's resident footprint fits `pool macros × sram_bits_per_macro`
    /// (see `coordinator::Scheduler::pool_capacity_bits`), and the same
    /// number seeds `coordinator::Router::sram_bits_per_macro` for the
    /// placement-level residency check.
    pub sram_bits_per_macro: u64,

    // ---- environment ----
    /// Junction temperature [K].
    pub temperature_k: f64,
    /// Mismatch / noise Monte-Carlo master seed (identifies the die; see
    /// [`for_die`](Self::for_die) / [`for_row_tile`](Self::for_row_tile)
    /// for the seed-derivation hierarchy).
    pub seed: u64,

    // ---- simulation execution (not a circuit property) ----
    /// Worker threads for the column-parallel matvec engine. 0 = auto
    /// (one per available core, capped). Results are bit-identical at any
    /// thread count: every column owns its noise substream, keyed by
    /// (die seed, **global** column index, conversion counter).
    pub threads: usize,
    /// Global index of this macro's column 0 within a wider logical
    /// column array. A column-sharded layer gives each shard macro the
    /// `col_base` of the first weight-bit plane it owns, so mismatch and
    /// conversion-noise substreams key on the *logical* column — the
    /// shard decomposition is then invisible to the noise model and
    /// results are bit-identical at any shard count. Not a circuit
    /// property; 0 for a standalone macro.
    pub col_base: usize,
}

impl Default for MacroParams {
    fn default() -> Self {
        MacroParams {
            rows: 1088,
            active_rows: 1024,
            cols: 78,
            adc_bits: 10,
            c_unit_ff: 1.5,
            // 1.5 fF fringe caps match to ~1%; the residual cubic term
            // carries the rest of the measured INL (see DESIGN.md).
            sigma_cu_rel: 0.010,
            nonlin_cubic_lsb: 2.2,
            // Calibration: conversion-referred read noise ≈ 1.16 LSB w/o CB
            // and ≈ 0.58 LSB with 6×-MV on the last 3 bits (Fig. 5).
            sigma_cmp_lsb: 1.10,
            sigma_cmp_offset_lsb: 0.5,
            sigma_cmp_early_factor: 0.25,
            mv_votes: 6,
            mv_last_bits: 3,
            supply_v: 0.6,
            supply_nominal_v: 0.6,
            // Timing calibrated to the paper's peak 1.2 TOPS (1b-norm) at
            // max supply (1.1 V): 78 cols × 2048 ops / t_conv with the
            // gate-overdrive speedup (≈3×) from 0.6 V nominal.
            t_cmp_ns: 35.0,
            t_compute_ns: 50.0,
            // Energy split calibrated to 818 TOPS/W @0.6 V with the
            // comparator at ~60% of conversion energy, which is exactly
            // what makes CB's 25-vs-10 comparisons cost 1.9× power.
            e_cmp_pj: 0.150,
            alpha_sample: 0.50,
            alpha_dac: 0.45,
            e_logic_pj: 0.60,
            // One registered add in the output periphery (65 nm digital).
            t_accum_ns: 2.0,
            // Row-parallel SRAM write of one weight tile: ~1 ns/row.
            t_wload_ns: 1000.0,
            // One paper-geometry array of weight storage per macro (a
            // deployment knob — it does not track rows/cols mutations).
            sram_bits_per_macro: 1088 * 78,
            temperature_k: 300.0,
            seed: 0x5EED_C100,
            threads: 0,
            col_base: 0,
        }
    }
}

/// Seed salt separating independent dies (multi-die serving tier).
const DIE_SEED_SALT: u64 = 0xD1E5_EED5_A17E_D1E5;
/// Seed salt separating the physical macros that hold different row tiles
/// of one layer (the k > `active_rows` accumulation path).
const TILE_SEED_SALT: u64 = 0x7113_5EED_5A17_7113;
/// Seed salt separating per-layer-class die pools (the pipeline executor
/// keeps attention-class and MLP-class layers on disjoint silicon).
const POOL_SEED_SALT: u64 = 0x9001_5EED_0C1A_55E5;

impl MacroParams {
    /// Number of ADC codes (2^adc_bits).
    pub fn levels(&self) -> usize {
        1usize << self.adc_bits
    }

    /// LSB size in volts at the current supply (full scale = supply).
    pub fn lsb_v(&self) -> f64 {
        self.supply_v / self.levels() as f64
    }

    /// Total column capacitance [F].
    pub fn c_total_f(&self) -> f64 {
        self.active_rows as f64 * self.c_unit_ff * 1e-15
    }

    /// kT/C sampling noise, expressed in LSB of the readout.
    pub fn ktc_noise_lsb(&self) -> f64 {
        let sigma_v = (K_BOLTZMANN * self.temperature_k / self.c_total_f()).sqrt();
        sigma_v / self.lsb_v()
    }

    /// Comparator input-referred noise [LSB] at the *current* supply.
    /// The LSB shrinks with supply while the comparator's input-referred
    /// voltage noise is roughly supply-independent, so LSB-referred noise
    /// grows as Vnom/V.
    pub fn sigma_cmp_lsb_at_supply(&self) -> f64 {
        self.sigma_cmp_lsb * self.supply_nominal_v / self.supply_v
    }

    /// Number of comparator decisions for one conversion in `mode`.
    pub fn comparisons_per_conversion(&self, mode: CbMode) -> usize {
        let b = self.adc_bits as usize;
        match mode {
            CbMode::Off => b,
            CbMode::On => b - self.mv_last_bits + self.mv_last_bits * self.mv_votes,
        }
    }

    /// Conversion latency [ns] (compute phase + SAR phase) in `mode`,
    /// at the current supply (gate-overdrive delay scaling).
    pub fn conversion_latency_ns(&self, mode: CbMode) -> f64 {
        let vt = 0.35; // 65 nm nominal threshold [V]
        let speedup = ((self.supply_v - vt) / (self.supply_nominal_v - vt)).max(0.2);
        let sar = self.comparisons_per_conversion(mode) as f64 * self.t_cmp_ns;
        (sar + self.t_compute_ns) / speedup
    }

    /// 1b-normalized MAC operations per column conversion (multiply +
    /// accumulate per active row, as normalized in Fig. 6).
    pub fn ops_per_conversion(&self) -> f64 {
        2.0 * self.active_rows as f64
    }

    /// Sanity checks on parameter consistency; called by constructors of
    /// the simulator objects.
    pub fn validate(&self) -> Result<(), String> {
        if self.active_rows != self.levels() {
            return Err(format!(
                "active_rows ({}) must equal 2^adc_bits ({})",
                self.active_rows,
                self.levels()
            ));
        }
        if self.rows < self.active_rows {
            return Err("rows must be >= active_rows".into());
        }
        if !(0.2..=2.0).contains(&self.supply_v) {
            return Err(format!("supply {} V out of range", self.supply_v));
        }
        // Any vote count >= 1 is legal, including even counts: the
        // comparator breaks even-vote ties toward "up" (`2*ups >= votes`
        // in `Comparator::decide_mv`), matching a latch that keeps its
        // last state. Zero votes would make boosted SAR bits undecidable.
        if self.mv_votes < 1 {
            return Err("mv_votes must be >= 1".into());
        }
        if self.mv_last_bits as u32 > self.adc_bits {
            return Err("mv_last_bits exceeds adc_bits".into());
        }
        Ok(())
    }

    /// A reduced-resolution variant (the macro supports configurable
    /// activation/weight precisions; the bank stays 10-bit but the input
    /// DAC/driver precision changes).
    pub fn with_supply(mut self, v: f64) -> Self {
        self.supply_v = v;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Parameters of independent die `die` in a multi-die deployment: the
    /// master seed is mixed with the die index, so every die samples its
    /// own mismatch and noise. Die 0 keeps the master seed unchanged (a
    /// one-die deployment is byte-for-byte the single-macro simulator).
    pub fn for_die(mut self, die: usize) -> Self {
        self.seed ^= (die as u64).wrapping_mul(DIE_SEED_SALT);
        self
    }

    /// Parameters of the physical macro holding row tile `tile` of a
    /// layer whose reduction dimension spans several tiles. Each tile is
    /// a distinct physical macro, so it gets its own mismatch/noise seed
    /// (tile 0 keeps the die seed: a single-tile layer is unchanged).
    /// Per-tile output noise is therefore independent and the digitally
    /// accumulated total composes in quadrature — the contract
    /// `coordinator::sac::kernel_noise_sigma_for_row_tiles` encodes.
    pub fn for_row_tile(mut self, tile: usize) -> Self {
        self.seed ^= (tile as u64).wrapping_mul(TILE_SEED_SALT);
        self
    }

    /// Parameters of die pool `pool` in a per-layer-class deployment:
    /// the master seed is mixed with the pool index so each pool's dies
    /// are disjoint physical silicon — resizing one class's pool never
    /// re-seeds the other's. Pool 0 keeps the master seed (the default
    /// shared pool: a pool-less `DieBank` is unchanged). Composes with
    /// [`for_die`](Self::for_die) / [`for_row_tile`](Self::for_row_tile)
    /// into the hierarchy `seed → pool → die → row tile → column`.
    pub fn for_pool(mut self, pool: usize) -> Self {
        self.seed ^= (pool as u64).wrapping_mul(POOL_SEED_SALT);
        self
    }

    /// Set the per-macro weight-SRAM residency budget [bits]
    /// (see [`sram_bits_per_macro`](Self::sram_bits_per_macro); 0
    /// disables weight residency).
    pub fn with_sram_bits(mut self, bits: u64) -> Self {
        self.sram_bits_per_macro = bits;
        self
    }

    /// Set the noise-keying base for logical column 0 (see `col_base`).
    pub fn with_col_base(mut self, col_base: usize) -> Self {
        self.col_base = col_base;
        self
    }

    /// Set the majority-voting point (votes per boosted comparison and
    /// how many trailing SAR bits are boosted). This is how a per-layer
    /// `vit::plan::NoisePoint` reaches the macro: the shard constructor
    /// overrides its cloned params with the layer's point, so the SAR
    /// comparison counts *and* the energy model of that macro both price
    /// the layer's own voting configuration.
    pub fn with_mv(mut self, mv_votes: usize, mv_last_bits: usize) -> Self {
        self.mv_votes = mv_votes;
        self.mv_last_bits = mv_last_bits;
        self
    }

    /// Row tiles needed to hold a reduction dimension of `k` rows.
    pub fn row_tiles_needed(&self, k: usize) -> usize {
        k.div_ceil(self.active_rows).max(1)
    }

    /// Set the matvec worker-thread count (0 = auto).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Resolved worker-thread count for the matvec engine.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            self.threads
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_params_validate() {
        MacroParams::default().validate().unwrap();
    }

    #[test]
    fn levels_and_lsb() {
        let p = MacroParams::default();
        assert_eq!(p.levels(), 1024);
        assert!((p.lsb_v() - 0.6 / 1024.0).abs() < 1e-12);
    }

    #[test]
    fn ktc_noise_is_small_but_nonzero() {
        let p = MacroParams::default();
        let n = p.ktc_noise_lsb();
        // ~52 µV on a 0.586 mV LSB ≈ 0.089 LSB at 0.6 V.
        assert!(n > 0.02 && n < 0.2, "kT/C = {n} LSB");
    }

    #[test]
    fn cb_comparison_counts_match_paper() {
        let p = MacroParams::default();
        assert_eq!(p.comparisons_per_conversion(CbMode::Off), 10);
        assert_eq!(p.comparisons_per_conversion(CbMode::On), 25);
        // Paper: 2.5× conversion-time overhead for the SAR phase.
        let t_off = 10.0 * p.t_cmp_ns;
        let t_on = 25.0 * p.t_cmp_ns;
        assert!((t_on / t_off - 2.5).abs() < 1e-12);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut p = MacroParams::default();
        p.active_rows = 1000;
        assert!(p.validate().is_err());

        let mut p = MacroParams::default();
        p.rows = 100;
        assert!(p.validate().is_err());

        let mut p = MacroParams::default();
        p.mv_last_bits = 11;
        assert!(p.validate().is_err());
    }

    #[test]
    fn zero_votes_rejected_and_any_positive_count_accepted() {
        // Regression: the original guard read `mv_votes % 2 != 0 &&
        // mv_votes < 1`, which no usize satisfies, so mv_votes = 0 slipped
        // through validation and hung boosted SAR bits.
        let p = MacroParams::default().with_mv(0, 3);
        assert!(p.validate().is_err());
        // Even counts are legal (tie -> up, see Comparator::decide_mv),
        // as is 1 (voting off) and the paper's 6.
        for votes in [1usize, 2, 6, 12] {
            assert!(MacroParams::default().with_mv(votes, 3).validate().is_ok());
        }
    }

    #[test]
    fn with_mv_changes_comparison_count_and_latency() {
        let p = MacroParams::default().with_mv(2, 2);
        assert_eq!(p.comparisons_per_conversion(CbMode::On), 10 - 2 + 2 * 2);
        // CbMode::Off never votes, whatever the point says.
        assert_eq!(p.comparisons_per_conversion(CbMode::Off), 10);
        let base = MacroParams::default();
        assert!(
            p.conversion_latency_ns(CbMode::On) < base.conversion_latency_ns(CbMode::On),
            "fewer comparisons must shorten the boosted conversion"
        );
    }

    #[test]
    fn thread_knob_resolves() {
        let p = MacroParams::default();
        assert_eq!(p.threads, 0);
        assert!(p.effective_threads() >= 1);
        assert_eq!(p.clone().with_threads(3).effective_threads(), 3);
        // The knob is an execution parameter, not a circuit property.
        assert!(p.with_threads(7).validate().is_ok());
    }

    #[test]
    fn seed_hierarchy_is_stable_and_identity_at_zero() {
        let p = MacroParams::default();
        // Die 0 / tile 0 keep the master seed: single-die single-tile
        // deployments replay the bare macro exactly.
        assert_eq!(p.clone().for_die(0).seed, p.seed);
        assert_eq!(p.clone().for_row_tile(0).seed, p.seed);
        // Distinct dies and tiles get distinct seeds, deterministically.
        let d1 = p.clone().for_die(1).seed;
        let d2 = p.clone().for_die(2).seed;
        assert_ne!(d1, p.seed);
        assert_ne!(d1, d2);
        assert_eq!(p.clone().for_die(1).seed, d1);
        let t1 = p.clone().for_row_tile(1).seed;
        assert_ne!(t1, p.seed);
        assert_ne!(t1, d1, "die and tile salts must not collide");
        // The two axes compose: (die, tile) pairs are all distinct.
        let dt = p.clone().for_die(1).for_row_tile(1).seed;
        assert_ne!(dt, d1);
        assert_ne!(dt, t1);
    }

    #[test]
    fn class_pool_seeds_are_disjoint_and_identity_at_zero() {
        let p = MacroParams::default();
        // Pool 0 is the default shared pool: byte-for-byte unchanged.
        assert_eq!(p.clone().for_pool(0).seed, p.seed);
        let p1 = p.clone().for_pool(1).seed;
        let p2 = p.clone().for_pool(2).seed;
        assert_ne!(p1, p.seed);
        assert_ne!(p1, p2);
        // Pool salting must not collide with the die/tile axes.
        assert_ne!(p1, p.clone().for_die(1).seed);
        assert_ne!(p1, p.clone().for_row_tile(1).seed);
        // Die i of pool 1 differs from die i of pool 2: resizing one
        // class's pool cannot alias the other's silicon.
        assert_ne!(
            p.clone().for_pool(1).for_die(1).seed,
            p.clone().for_pool(2).for_die(1).seed
        );
    }

    #[test]
    fn sram_budget_defaults_to_one_paper_array_and_is_settable() {
        let p = MacroParams::default();
        // One paper-geometry array — for the default geometry this is
        // exactly rows × cols.
        assert_eq!(p.sram_bits_per_macro, 1088 * 78);
        assert_eq!(p.sram_bits_per_macro, (p.rows * p.cols) as u64);
        // It is a deployment knob, not derived: shrinking the array
        // geometry does not shrink the budget.
        let mut tiny = p.clone();
        tiny.rows = 64;
        tiny.cols = 12;
        assert_eq!(tiny.sram_bits_per_macro, 1088 * 78);
        let big = p.clone().with_sram_bits(1 << 26);
        assert_eq!(big.sram_bits_per_macro, 1 << 26);
        // The budget is an accounting knob, not a circuit property.
        assert!(big.validate().is_ok());
        assert!(p.with_sram_bits(0).validate().is_ok());
    }

    #[test]
    fn weight_load_latency_is_positive_and_row_scale() {
        let p = MacroParams::default();
        // ~1 ns/row over 1024 rows: the reload must be comparable to a
        // handful of conversion cycles, or pipelining it is meaningless.
        assert!(p.t_wload_ns > 0.0);
        assert!(p.t_wload_ns < 100.0 * p.conversion_latency_ns(CbMode::Off));
    }

    #[test]
    fn row_tiles_needed_matches_geometry() {
        let p = MacroParams::default(); // 1024 active rows
        assert_eq!(p.row_tiles_needed(1), 1);
        assert_eq!(p.row_tiles_needed(1024), 1);
        assert_eq!(p.row_tiles_needed(1025), 2);
        assert_eq!(p.row_tiles_needed(3072), 3);
        assert_eq!(p.row_tiles_needed(0), 1, "degenerate k still maps to one tile");
    }

    #[test]
    fn col_base_is_an_execution_parameter() {
        let p = MacroParams::default().with_col_base(78);
        assert_eq!(p.col_base, 78);
        assert!(p.validate().is_ok());
        assert_eq!(MacroParams::default().col_base, 0);
    }

    #[test]
    fn supply_scaling_directions() {
        let lo = MacroParams::default().with_supply(0.6);
        let hi = MacroParams::default().with_supply(1.1);
        // Higher supply: faster conversion, lower LSB-referred cmp noise.
        assert!(hi.conversion_latency_ns(CbMode::Off) < lo.conversion_latency_ns(CbMode::Off));
        assert!(hi.sigma_cmp_lsb_at_supply() < lo.sigma_cmp_lsb_at_supply());
    }
}
